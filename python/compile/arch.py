"""Architecture constants + layer table for the FM velocity network.

This file is the single python-side source of truth for the model shape.
`aot.py` serialises the table into artifacts/manifest.json; the rust side
(`rust/src/model/spec.rs`) regenerates the same table independently and an
integration test asserts the two agree byte-for-byte, so the flat-theta
layout can never drift between layers of the stack.

Layout of the flat parameter vector theta[P] (row-major matrices):

    w_in [D,H]  b_in [H]  w_t [TEMB,H]  b_t [H]
    ( w1_i [H,H]  b1_i [H]  w2_i [H,H]  b2_i [H] ) for i in 0..BLOCKS
    w_out [H,D]  b_out [D]

Weight matrices (the quantized tensors) are the entries with ndim == 2.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

# ---------------------------------------------------------------- constants
D = 768          # flattened image: 16 x 16 x 3
IMG_HW = 16
IMG_C = 3
H = 512          # hidden width
TEMB_FREQS = 32  # sinusoidal frequencies
TEMB = 2 * TEMB_FREQS
BLOCKS = 3       # residual blocks
B_TRAIN = 64     # training batch
B_SAMPLE = 16    # sampling batch
K_MAX = 256      # codebook slots (8-bit); smaller bit-widths pad
FREQ_MAX = 1000.0

# padding value for unused codebook slots: far away from any real weight so
# nearest-centroid assignment can never pick a padded slot.
CODEBOOK_PAD = 1.0e30


@dataclass(frozen=True)
class LayerEntry:
    name: str
    shape: tuple  # () handled as 1-d
    offset: int   # into flat theta

    @property
    def size(self) -> int:
        return int(math.prod(self.shape))

    @property
    def is_weight(self) -> bool:
        return len(self.shape) == 2


def layer_table() -> list:
    """Ordered layer table with offsets into flat theta."""
    entries = []
    off = 0

    def add(name, shape):
        nonlocal off
        entries.append(LayerEntry(name, tuple(shape), off))
        off += int(math.prod(shape))

    add("w_in", (D, H))
    add("b_in", (H,))
    add("w_t", (TEMB, H))
    add("b_t", (H,))
    for i in range(BLOCKS):
        add(f"w1_{i}", (H, H))
        add(f"b1_{i}", (H,))
        add(f"w2_{i}", (H, H))
        add(f"b2_{i}", (H,))
    add("w_out", (H, D))
    add("b_out", (D,))
    return entries


TABLE = layer_table()
P = sum(e.size for e in TABLE)                     # total params
WEIGHTS = [e for e in TABLE if e.is_weight]        # quantized tensors
BIASES = [e for e in TABLE if not e.is_weight]
PW = sum(e.size for e in WEIGHTS)                  # quantized param count
PB = sum(e.size for e in BIASES)
N_WEIGHTS = len(WEIGHTS)

# offsets of each weight tensor inside the packed codes vector codes[PW],
# and of each bias inside the packed bias vector biases[PB].
_wo = 0
WEIGHT_OFFSETS = {}
for e in WEIGHTS:
    WEIGHT_OFFSETS[e.name] = _wo
    _wo += e.size
_bo = 0
BIAS_OFFSETS = {}
for e in BIASES:
    BIAS_OFFSETS[e.name] = _bo
    _bo += e.size


def manifest_dict() -> dict:
    """JSON-serialisable manifest consumed by the rust runtime."""
    return {
        "d": D,
        "img_hw": IMG_HW,
        "img_c": IMG_C,
        "hidden": H,
        "temb_freqs": TEMB_FREQS,
        "blocks": BLOCKS,
        "b_train": B_TRAIN,
        "b_sample": B_SAMPLE,
        "k_max": K_MAX,
        "freq_max": FREQ_MAX,
        "p": P,
        "pw": PW,
        "pb": PB,
        "n_weights": N_WEIGHTS,
        "layers": [
            {
                "name": e.name,
                "shape": list(e.shape),
                "offset": e.offset,
                "size": e.size,
                "is_weight": e.is_weight,
            }
            for e in TABLE
        ],
    }


if __name__ == "__main__":
    for e in TABLE:
        print(f"{e.name:8s} shape={e.shape} offset={e.offset}")
    print(f"P={P} PW={PW} PB={PB} n_weights={N_WEIGHTS}")
