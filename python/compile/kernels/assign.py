"""L1 Pallas kernel: nearest-centroid code assignment.

Steps 9-11 of the paper's Algorithm 1 (OT_Quantize): after the equal-mass
codebook is built, every weight is mapped to the index of its nearest
centroid. This is the O(N*K) hot loop of quantization itself; expressing it
as a kernel lets the coordinator quantize *on device* when deploying.

TPU mapping: values stream through VMEM in (1, bn)-shaped lane tiles; the
K-entry centroid vector is VMEM-resident; |v - c| is a (bn x K) VPU
broadcast and the argmin reduces along the K (sublane-expanded) axis.
Padded centroid slots hold CODEBOOK_PAD (1e30) so they are never selected.

Interpret mode on CPU PJRT; validated against `ref.assign_ref`.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_block(dim: int, pref: int = 1024) -> int:
    for cand in (pref, 512, 256, 128, 64, 32, 16, 8, 4, 2, 1):
        if cand <= dim and dim % cand == 0:
            return cand
    return dim


def _assign_kernel(vals_ref, cent_ref, out_ref):
    v = vals_ref[...]          # f32[bn]
    c = cent_ref[...]          # f32[K]
    d = jnp.abs(v[:, None] - c[None, :])   # f32[bn, K] VPU broadcast
    out_ref[...] = jnp.argmin(d, axis=1).astype(jnp.int32)


def assign(vals, centroids, *, bn: int | None = None, interpret: bool = True):
    """vals f32[N], centroids f32[K] -> codes int32[N]."""
    (n,) = vals.shape
    bn = bn or _pick_block(n)
    grid = (n // bn,)
    return pl.pallas_call(
        _assign_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec(centroids.shape, lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=interpret,
    )(vals, centroids)
