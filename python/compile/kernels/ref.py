"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: `python/tests/test_kernels.py`
sweeps shapes/dtypes with hypothesis and asserts the Pallas kernels
(interpret mode) match these to float tolerance. The rust CPU reference
(`rust/src/flow/cpu_ref.rs`) is in turn validated against the lowered HLO,
closing the three-way loop.
"""

from __future__ import annotations

import jax.numpy as jnp


def qmm_ref(x, codes, codebook):
    """Dequantize-then-matmul reference.

    x        f32[B, M]
    codes    int32[M, N]   (indices into codebook)
    codebook f32[K]
    returns  f32[B, N] = x @ codebook[codes]
    """
    w = codebook[codes]
    return jnp.dot(x, w, preferred_element_type=jnp.float32)


def assign_ref(vals, centroids):
    """Nearest-centroid assignment reference.

    vals      f32[N]
    centroids f32[K]   (padded slots hold CODEBOOK_PAD, never selected)
    returns   int32[N] = argmin_k |vals - centroids[k]|
    """
    d = jnp.abs(vals[:, None] - centroids[None, :])
    return jnp.argmin(d, axis=1).astype(jnp.int32)


def dequant_ref(codes, codebook):
    """codes int32[...], codebook f32[K] -> f32[...]."""
    return codebook[codes]
