"""L1 Pallas kernel: fused dequantize + matmul over codebook codes.

The serving hot-spot of OT-quantized flow matching: every Euler step of the
probability-flow ODE multiplies activations by weight matrices stored as
low-bit codebook indices. Instead of materialising the dequantized f32
matrix in HBM, this kernel gathers codebook entries inside the tile and
feeds the MXU directly:

    out[b, n] = sum_m x[b, m] * codebook[codes[m, n]]

TPU mapping (see DESIGN.md §Hardware-Adaptation): the <=256-entry codebook
(1 KiB) is VMEM-resident for the whole grid; `codes` streams HBM->VMEM as
int32 (bm, bn) tiles via BlockSpec — the role a CUDA kernel would give to
threadblock shared-memory staging; the gathered tile is consumed by a
(bm x bn) MXU matmul and accumulated over the reduction grid axis.

Executed with interpret=True on CPU PJRT (a real-TPU lowering emits a
Mosaic custom-call the CPU plugin cannot run). Numerics are validated
against `ref.qmm_ref` by pytest/hypothesis.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_block(dim: int, pref: int = 128) -> int:
    """Largest power-of-two block <= pref that divides dim (>= 8 if possible)."""
    for cand in (pref, 64, 32, 16, 8, 4, 2, 1):
        if cand <= dim and dim % cand == 0:
            return cand
    return dim


def _qmm_kernel(x_ref, codes_ref, cb_ref, o_ref, *, nsteps: int):
    """One (b-tile, n-tile, m-step) grid cell.

    x_ref     f32[bb, bm]   activation tile
    codes_ref int32[bm, bn] code tile
    cb_ref    f32[K]        full codebook (VMEM-resident)
    o_ref     f32[bb, bn]   output tile, accumulated over the m axis
    """
    m = pl.program_id(2)

    @pl.when(m == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    w = cb_ref[codes_ref[...]]  # gather: dequantize inside VMEM
    o_ref[...] += jnp.dot(
        x_ref[...], w, preferred_element_type=jnp.float32
    )


def qmm(x, codes, codebook, *, bb: int | None = None, bm: int | None = None,
        bn: int | None = None, interpret: bool = True):
    """x f32[B, M] @ dequant(codes int32[M, N], codebook f32[K]) -> f32[B, N]."""
    b, m = x.shape
    m2, n = codes.shape
    assert m == m2, f"reduction mismatch: x has M={m}, codes has M={m2}"
    bb = bb or _pick_block(b, 128)
    bm = bm or _pick_block(m, 128)
    bn = bn or _pick_block(n, 128)
    grid = (b // bb, n // bn, m // bm)

    return pl.pallas_call(
        functools.partial(_qmm_kernel, nsteps=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, bm), lambda i, j, k: (i, k)),
            pl.BlockSpec((bm, bn), lambda i, j, k: (k, j)),
            # whole codebook in every cell: K<=256 -> 1 KiB of VMEM
            pl.BlockSpec(codebook.shape, lambda i, j, k: (0,)),
        ],
        out_specs=pl.BlockSpec((bb, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.float32),
        interpret=interpret,
    )(x, codes, codebook)
