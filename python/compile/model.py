"""L2: the flow-matching velocity network + train/sample steps in JAX.

Everything here is build-time only: `aot.py` lowers these functions to HLO
text once, and the rust coordinator executes the compiled artifacts through
PJRT at run time. The quantized sampling path routes every weight matmul
through the L1 Pallas `qmm` kernel so dequantization happens inside the
kernel tile, never materialising f32 weights in the graph.

Parameterisation: a single flat f32 theta[P] whose layout is defined by
`arch.TABLE` (shared with rust via artifacts/manifest.json).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from . import arch
from .kernels.assign import assign as pallas_assign
from .kernels.qmm import qmm as pallas_qmm

# --------------------------------------------------------------- utilities

_OFFSETS = {e.name: (e.offset, e.shape) for e in arch.TABLE}


def slice_param(theta, name):
    """Static slice of one layer out of flat theta (trace-time constants)."""
    off, shape = _OFFSETS[name]
    size = int(math.prod(shape))
    return jax.lax.dynamic_slice_in_dim(theta, off, size).reshape(shape)


def silu(x):
    return x * jax.nn.sigmoid(x)


def time_features(t):
    """Sinusoidal features of t in [0, 1].

    t f32[B] -> f32[B, 2*F]; frequencies geometric in [1, FREQ_MAX].
    Mirrored exactly by rust/src/flow/cpu_ref.rs.
    """
    f = arch.TEMB_FREQS
    i = jnp.arange(f, dtype=jnp.float32)
    # max(f-1, 1): a single-frequency embedding degenerates to freq = 1
    # instead of 0/0 -> NaN (mirrors the clamp in cpu_ref.rs)
    freqs = jnp.exp(i / max(f - 1, 1) * jnp.log(arch.FREQ_MAX))  # [F]
    ang = t[:, None] * freqs[None, :]                      # [B, F]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=1)


# ------------------------------------------------------- full-precision fwd

def velocity(theta, x, t):
    """v = f_theta(x, t).   x f32[B, D], t f32[B] -> f32[B, D]."""
    temb = time_features(t)
    ht = silu(temb @ slice_param(theta, "w_t") + slice_param(theta, "b_t"))
    h = x @ slice_param(theta, "w_in") + slice_param(theta, "b_in") + ht
    for i in range(arch.BLOCKS):
        u = silu(h @ slice_param(theta, f"w1_{i}") + slice_param(theta, f"b1_{i}"))
        h = h + u @ slice_param(theta, f"w2_{i}") + slice_param(theta, f"b2_{i}")
    return h @ slice_param(theta, "w_out") + slice_param(theta, "b_out")


def sample_step(theta, x, t, dt):
    """One explicit-Euler step of the probability-flow ODE.

    Signed dt: dt > 0 integrates noise -> data (generation); dt < 0
    integrates data -> noise (latent encoding for the Fig. 4 experiment).
    t is a scalar shared across the batch.
    """
    tb = jnp.full((x.shape[0],), t, dtype=jnp.float32)
    return x + dt * velocity(theta, x, tb)


# ---------------------------------------------------------- quantized fwd

def _q_weight_inputs(codes, codebooks, name):
    """Slice one weight's codes + its codebook row (trace-time offsets)."""
    off = arch.WEIGHT_OFFSETS[name]
    _, shape = _OFFSETS[name]
    size = int(math.prod(shape))
    c = jax.lax.dynamic_slice_in_dim(codes, off, size).reshape(shape)
    row = [w.name for w in arch.WEIGHTS].index(name)
    cb = codebooks[row]
    return c, cb


def _bias(biases, name):
    off = arch.BIAS_OFFSETS[name]
    _, shape = _OFFSETS[name]
    return jax.lax.dynamic_slice_in_dim(biases, off, shape[0])


def qvelocity(codes, biases, codebooks, x, t):
    """Quantized velocity: every weight matmul runs through Pallas qmm.

    codes     int32[PW]          codebook indices, weights packed in order
    biases    f32[PB]            biases stay full precision (standard PTQ)
    codebooks f32[N_WEIGHTS, K_MAX]  per-tensor codebooks, padded rows
    """
    temb = time_features(t)
    c, cb = _q_weight_inputs(codes, codebooks, "w_t")
    ht = silu(pallas_qmm(temb, c, cb) + _bias(biases, "b_t"))
    c, cb = _q_weight_inputs(codes, codebooks, "w_in")
    h = pallas_qmm(x, c, cb) + _bias(biases, "b_in") + ht
    for i in range(arch.BLOCKS):
        c, cb = _q_weight_inputs(codes, codebooks, f"w1_{i}")
        u = silu(pallas_qmm(h, c, cb) + _bias(biases, f"b1_{i}"))
        c, cb = _q_weight_inputs(codes, codebooks, f"w2_{i}")
        h = h + pallas_qmm(u, c, cb) + _bias(biases, f"b2_{i}")
    c, cb = _q_weight_inputs(codes, codebooks, "w_out")
    return pallas_qmm(h, c, cb) + _bias(biases, "b_out")


def qsample_step(codes, biases, codebooks, x, t, dt):
    """Euler step with quantized weights (the serving hot path)."""
    tb = jnp.full((x.shape[0],), t, dtype=jnp.float32)
    return x + dt * qvelocity(codes, biases, codebooks, x, tb)


# -------------------------------------------------------------- training

def cfm_loss(theta, x1, x0, t):
    """Conditional flow-matching loss with linear (OT) interpolation paths.

    x_t = (1 - t) x0 + t x1, target velocity u = x1 - x0:
        L = E || f_theta(x_t, t) - (x1 - x0) ||^2
    """
    xt = (1.0 - t[:, None]) * x0 + t[:, None] * x1
    v = velocity(theta, xt, t)
    return jnp.mean(jnp.sum((v - (x1 - x0)) ** 2, axis=1))


def train_step(theta, m, v, step, x1, x0, t, lr):
    """One Adam step on the CFM loss.

    All state flows in and out so rust owns the loop. step is a float32
    scalar (1-based) used for bias correction.
    """
    beta1, beta2, eps = 0.9, 0.999, 1e-8
    loss, g = jax.value_and_grad(cfm_loss)(theta, x1, x0, t)
    m = beta1 * m + (1.0 - beta1) * g
    v = beta2 * v + (1.0 - beta2) * g * g
    mhat = m / (1.0 - beta1 ** step)
    vhat = v / (1.0 - beta2 ** step)
    theta = theta - lr * mhat / (jnp.sqrt(vhat) + eps)
    return theta, m, v, loss


# -------------------------------------------------- on-device quantization

def assign_codes(vals, centroids):
    """Nearest-centroid assignment via the Pallas kernel (1-D chunk)."""
    return pallas_assign(vals, centroids)


def dequantize_theta(codes, biases, codebooks):
    """Reconstruct the flat fp32 theta from quantized storage, on device.

    The dequantize-on-load serving mode: run once per model deployment,
    then sample with the fp32 `sample_step` — uploads stay small (codes at
    int32, 4x less than theta; bit-packed on the wire in rust) and the
    per-step gather of the on-the-fly mode disappears. The Pallas `qmm`
    path remains the dequantize-on-the-fly mode for VMEM-rich targets.
    """
    parts = []
    for e in arch.TABLE:
        if e.is_weight:
            wo = arch.WEIGHT_OFFSETS[e.name]
            c = jax.lax.dynamic_slice_in_dim(codes, wo, e.size)
            row = [w.name for w in arch.WEIGHTS].index(e.name)
            parts.append(codebooks[row][c])
        else:
            bo = arch.BIAS_OFFSETS[e.name]
            parts.append(jax.lax.dynamic_slice_in_dim(biases, bo, e.size))
    return jnp.concatenate(parts)
