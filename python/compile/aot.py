"""AOT lowering: jax -> HLO *text* artifacts for the rust PJRT runtime.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (what
the published `xla` 0.1.6 crate links) rejects (`proto.id() <= INT_MAX`).
The text parser reassigns ids and round-trips cleanly — see
/opt/xla-example/gen_hlo.py and its README.

Run via `make artifacts` (no-op when inputs are unchanged):

    cd python && python -m compile.aot --out-dir ../artifacts

Emits:
    velocity_fwd.hlo.txt   (theta, x[S,D], t[S])                -> (v,)
    sample_step.hlo.txt    (theta, x[S,D], t, dt)               -> (x',)
    qsample_step.hlo.txt   (codes, biases, codebooks, x, t, dt) -> (x',)
    train_step.hlo.txt     (theta, m, v, step, x1, x0, t, lr)   -> (theta', m', v', loss)
    assign.hlo.txt         (vals[CHUNK], centroids[K_MAX])      -> (codes,)
    manifest.json          shapes + layer table (rust cross-checks its own)
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import arch, model

ASSIGN_CHUNK = 65536  # vals per on-device assignment dispatch


def to_hlo_text(lowered, return_tuple: bool = True) -> str:
    """Lower to HLO text.

    return_tuple=False emits a single-array root instead of a 1-tuple —
    required for the device-resident sampling sessions on the rust side,
    where the output buffer of step t feeds straight back in as the input
    buffer of step t+1 without a host round trip (PJRT cannot cheaply
    untuple a device buffer through this API).
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=return_tuple
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower_all() -> dict:
    """Lower every entry point; returns {artifact_name: hlo_text}."""
    f32, i32 = jnp.float32, jnp.int32
    P, PW, PB = arch.P, arch.PW, arch.PB
    D, S, B = arch.D, arch.B_SAMPLE, arch.B_TRAIN
    NW, K = arch.N_WEIGHTS, arch.K_MAX

    out = {}

    def low(name, fn, *specs, return_tuple=False):
        out[name] = to_hlo_text(jax.jit(fn).lower(*specs), return_tuple)
        print(f"  lowered {name}: {len(out[name])} chars")

    # single-array roots: outputs can chain as inputs on device (rust
    # sampling sessions) — see to_hlo_text.
    low(
        "velocity_fwd",
        model.velocity,
        _spec((P,)), _spec((S, D)), _spec((S,)),
    )
    low(
        "sample_step",
        model.sample_step,
        _spec((P,)), _spec((S, D)), _spec(()), _spec(()),
    )
    low(
        "qsample_step",
        model.qsample_step,
        _spec((PW,), i32), _spec((PB,)), _spec((NW, K)),
        _spec((S, D)), _spec(()), _spec(()),
    )
    # multi-output: stays a tuple
    low(
        "train_step",
        model.train_step,
        _spec((P,)), _spec((P,)), _spec((P,)), _spec(()),
        _spec((B, D)), _spec((B, D)), _spec((B,)), _spec(()),
        return_tuple=True,
    )
    low(
        "assign",
        model.assign_codes,
        _spec((ASSIGN_CHUNK,)), _spec((K,)),
    )
    low(
        "dequant_theta",
        model.dequantize_theta,
        _spec((PW,), i32), _spec((PB,)), _spec((NW, K)),
    )
    return out


def write_artifacts(out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    artifacts = lower_all()
    for name, text in artifacts.items():
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
    manifest = arch.manifest_dict()
    manifest["assign_chunk"] = ASSIGN_CHUNK
    manifest["artifacts"] = sorted(artifacts.keys())
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"wrote {len(artifacts)} artifacts + manifest.json to {out_dir}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    write_artifacts(args.out_dir)


if __name__ == "__main__":
    main()
