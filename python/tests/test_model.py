"""L2 model correctness: shapes, training signal, quantized-path equivalence."""

import math

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import arch, model

RNG = np.random.default_rng(7)


def random_theta(scale=0.05):
    return jnp.asarray(RNG.standard_normal(arch.P).astype(np.float32) * scale)


def test_layer_table_layout():
    # offsets are contiguous, sizes sum to P, weight split matches PW/PB.
    off = 0
    for e in arch.TABLE:
        assert e.offset == off
        off += e.size
    assert off == arch.P
    assert sum(e.size for e in arch.WEIGHTS) == arch.PW
    assert sum(e.size for e in arch.BIASES) == arch.PB
    assert arch.P == arch.PW + arch.PB


def test_time_features_shape_and_range():
    t = jnp.asarray(np.linspace(0, 1, 9).astype(np.float32))
    f = model.time_features(t)
    assert f.shape == (9, arch.TEMB)
    assert np.all(np.abs(np.asarray(f)) <= 1.0 + 1e-6)
    # t=0: sin block is 0, cos block is 1
    np.testing.assert_allclose(np.asarray(f)[0, : arch.TEMB_FREQS], 0.0, atol=1e-7)
    np.testing.assert_allclose(np.asarray(f)[0, arch.TEMB_FREQS :], 1.0, atol=1e-7)


def test_velocity_shape_finite():
    theta = random_theta()
    x = jnp.asarray(RNG.standard_normal((4, arch.D)).astype(np.float32))
    t = jnp.asarray(RNG.uniform(0, 1, 4).astype(np.float32))
    v = model.velocity(theta, x, t)
    assert v.shape == (4, arch.D)
    assert np.all(np.isfinite(np.asarray(v)))


def test_sample_step_euler_consistency():
    theta = random_theta()
    x = jnp.asarray(RNG.standard_normal((4, arch.D)).astype(np.float32))
    dt = 0.125
    x1 = model.sample_step(theta, x, 0.25, dt)
    tb = jnp.full((4,), 0.25, dtype=jnp.float32)
    np.testing.assert_allclose(
        np.asarray(x1),
        np.asarray(x + dt * model.velocity(theta, x, tb)),
        rtol=1e-6, atol=1e-6,
    )


def test_sample_step_reverse_inverts_small_dt():
    # forward then backward with tiny dt returns near the start (O(dt^2) err)
    theta = random_theta()
    x = jnp.asarray(RNG.standard_normal((2, arch.D)).astype(np.float32))
    dt = 1e-3
    y = model.sample_step(theta, x, 0.5, dt)
    x_back = model.sample_step(theta, y, 0.5 + dt, -dt)
    err = float(jnp.max(jnp.abs(x_back - x)))
    assert err < 5e-4, err


def _equal_mass_codebook(w, bits):
    """Numpy reference of the paper's Algorithm 1 (per-tensor)."""
    k = 2 ** bits
    s = np.sort(w)
    # equal-mass split: group j gets s[floor(j*N/K) : floor((j+1)*N/K)]
    edges = (np.arange(k + 1) * len(s)) // k
    cents = np.array(
        [s[a:b].mean() if b > a else 0.0 for a, b in zip(edges[:-1], edges[1:])],
        dtype=np.float32,
    )
    return cents


def quantize_theta(theta, bits):
    """Quantize weights per-tensor with equal-mass codebooks; biases raw."""
    theta = np.asarray(theta)
    codes = np.zeros(arch.PW, dtype=np.int32)
    biases = np.zeros(arch.PB, dtype=np.float32)
    cbs = np.full((arch.N_WEIGHTS, arch.K_MAX), arch.CODEBOOK_PAD, dtype=np.float32)
    for row, e in enumerate(arch.WEIGHTS):
        w = theta[e.offset : e.offset + e.size]
        cents = _equal_mass_codebook(w, bits)
        cbs[row, : len(cents)] = cents
        idx = np.abs(w[:, None] - cents[None, :]).argmin(axis=1)
        wo = arch.WEIGHT_OFFSETS[e.name]
        codes[wo : wo + e.size] = idx
    for e in arch.BIASES:
        bo = arch.BIAS_OFFSETS[e.name]
        biases[bo : bo + e.size] = theta[e.offset : e.offset + e.size]
    return jnp.asarray(codes), jnp.asarray(biases), jnp.asarray(cbs)


def test_qvelocity_tracks_velocity():
    """The Pallas-quantized path approximates the fp32 path, and the error
    shrinks monotonically with bit-width (the paper's central premise:
    error ~ 2^{-b} per Theorems 3/6)."""
    theta = random_theta()
    x = jnp.asarray(RNG.standard_normal((4, arch.D)).astype(np.float32))
    t = jnp.asarray(RNG.uniform(0, 1, 4).astype(np.float32))
    v = np.asarray(model.velocity(theta, x, t))
    rels = {}
    for bits in (2, 4, 8):
        codes, biases, cbs = quantize_theta(theta, bits)
        vq = np.asarray(model.qvelocity(codes, biases, cbs, x, t))
        rels[bits] = np.linalg.norm(vq - v) / (np.linalg.norm(v) + 1e-9)
    assert rels[8] < rels[4] < rels[2], rels
    assert rels[8] < 0.15, rels
    # roughly geometric decay: 4 extra bits should buy >= 4x error reduction
    assert rels[8] < rels[4] / 2.0, rels


def test_qvelocity_exact_when_codebook_exact():
    """If every weight value appears verbatim in the codebook, the quantized
    path must reproduce fp32 bit-near-exactly (pure gather + matmul)."""
    # build theta whose weights only take 16 distinct values
    levels = np.linspace(-0.1, 0.1, 16).astype(np.float32)
    theta = np.zeros(arch.P, dtype=np.float32)
    for e in arch.TABLE:
        seg = RNG.integers(0, 16, e.size)
        theta[e.offset : e.offset + e.size] = levels[seg]
    theta_j = jnp.asarray(theta)
    # build the exact codebook directly (equal-mass would merge tied values)
    codes = np.zeros(arch.PW, dtype=np.int32)
    biases = np.zeros(arch.PB, dtype=np.float32)
    cbs = np.full((arch.N_WEIGHTS, arch.K_MAX), arch.CODEBOOK_PAD, dtype=np.float32)
    for row, e in enumerate(arch.WEIGHTS):
        cbs[row, :16] = levels
        w = theta[e.offset : e.offset + e.size]
        wo = arch.WEIGHT_OFFSETS[e.name]
        codes[wo : wo + e.size] = np.abs(w[:, None] - levels[None, :]).argmin(axis=1)
    for e in arch.BIASES:
        bo = arch.BIAS_OFFSETS[e.name]
        biases[bo : bo + e.size] = theta[e.offset : e.offset + e.size]
    theta = theta_j
    codes, biases, cbs = jnp.asarray(codes), jnp.asarray(biases), jnp.asarray(cbs)
    x = jnp.asarray(RNG.standard_normal((2, arch.D)).astype(np.float32))
    t = jnp.asarray(np.array([0.3, 0.8], dtype=np.float32))
    v = np.asarray(model.velocity(theta, x, t))
    vq = np.asarray(model.qvelocity(codes, biases, cbs, x, t))
    np.testing.assert_allclose(vq, v, rtol=1e-4, atol=1e-4)


def test_cfm_loss_positive_and_grad_finite():
    theta = random_theta()
    x1 = jnp.asarray(RNG.standard_normal((8, arch.D)).astype(np.float32))
    x0 = jnp.asarray(RNG.standard_normal((8, arch.D)).astype(np.float32))
    t = jnp.asarray(RNG.uniform(0, 1, 8).astype(np.float32))
    loss, g = jax.value_and_grad(model.cfm_loss)(theta, x1, x0, t)
    assert float(loss) > 0
    assert np.all(np.isfinite(np.asarray(g)))


def test_train_step_decreases_loss():
    theta = random_theta()
    m = jnp.zeros(arch.P)
    v = jnp.zeros(arch.P)
    x1 = jnp.asarray(RNG.standard_normal((arch.B_TRAIN, arch.D)).astype(np.float32))
    x0 = jnp.asarray(RNG.standard_normal((arch.B_TRAIN, arch.D)).astype(np.float32))
    t = jnp.asarray(RNG.uniform(0, 1, arch.B_TRAIN).astype(np.float32))
    step = jax.jit(model.train_step)
    losses = []
    for i in range(8):  # same batch: loss must fall
        theta, m, v, loss = step(theta, m, v, float(i + 1), x1, x0, t, 1e-3)
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses


def test_adam_bias_correction_first_step():
    # after one step from zero moments, update direction ~ -lr * sign(g)
    theta = random_theta()
    x1 = jnp.asarray(RNG.standard_normal((arch.B_TRAIN, arch.D)).astype(np.float32))
    x0 = jnp.zeros((arch.B_TRAIN, arch.D), dtype=jnp.float32)
    t = jnp.asarray(RNG.uniform(0, 1, arch.B_TRAIN).astype(np.float32))
    lr = 1e-3
    th1, _, _, _ = model.train_step(
        theta, jnp.zeros(arch.P), jnp.zeros(arch.P), 1.0, x1, x0, t, lr
    )
    upd = np.asarray(th1 - theta)
    nz = np.abs(upd) > 0
    assert np.abs(upd[nz]).max() <= lr * 1.01
