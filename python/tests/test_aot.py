"""AOT pipeline: HLO-text emission sanity (fast entry points only).

Full artifact generation is exercised by `make artifacts`; here we lower the
cheap entry points and check the HLO text is well-formed and carries the
right parameter signature, plus manifest consistency.
"""

import json

import jax
import jax.numpy as jnp

from compile import aot, arch, model


def test_manifest_dict_consistent():
    m = arch.manifest_dict()
    assert m["p"] == arch.P
    assert m["pw"] + m["pb"] == m["p"]
    assert len(m["layers"]) == len(arch.TABLE)
    assert m["n_weights"] == sum(1 for l in m["layers"] if l["is_weight"])
    # layout is contiguous
    off = 0
    for l in m["layers"]:
        assert l["offset"] == off
        off += l["size"]
    # round-trips through JSON
    assert json.loads(json.dumps(m)) == m


def test_assign_artifact_lowers_to_hlo_text():
    lowered = jax.jit(
        lambda vals, cents: (model.assign_codes(vals, cents),)
    ).lower(
        jax.ShapeDtypeStruct((aot.ASSIGN_CHUNK,), jnp.float32),
        jax.ShapeDtypeStruct((arch.K_MAX,), jnp.float32),
    )
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert f"f32[{aot.ASSIGN_CHUNK}]" in text
    assert f"s32[{aot.ASSIGN_CHUNK}]" in text  # output codes


def test_sample_step_lowers_to_hlo_text():
    spec = jax.ShapeDtypeStruct
    lowered = jax.jit(
        lambda th, x, t, dt: (model.sample_step(th, x, t, dt),)
    ).lower(
        spec((arch.P,), jnp.float32),
        spec((arch.B_SAMPLE, arch.D), jnp.float32),
        spec((), jnp.float32),
        spec((), jnp.float32),
    )
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert f"f32[{arch.P}]" in text
