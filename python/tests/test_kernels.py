"""L1 kernel correctness: Pallas (interpret) vs pure-jnp oracles.

Hypothesis sweeps shapes and value regimes; explicit cases pin the shapes
the production artifacts actually use.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels.assign import assign
from compile.kernels.qmm import qmm, _pick_block
from compile.kernels.ref import assign_ref, dequant_ref, qmm_ref

RNG = np.random.default_rng(0)


def _mk_qmm(b, m, n, k, scale=1.0):
    x = (RNG.standard_normal((b, m)) * scale).astype(np.float32)
    codes = RNG.integers(0, k, size=(m, n), dtype=np.int32)
    cb = np.sort(RNG.standard_normal(k).astype(np.float32))
    return jnp.asarray(x), jnp.asarray(codes), jnp.asarray(cb)


# ------------------------------------------------------------------- qmm

@pytest.mark.parametrize(
    "b,m,n,k",
    [
        (16, 768, 512, 256),   # w_in @ sample batch (production shape)
        (16, 64, 512, 256),    # w_t
        (16, 512, 512, 16),    # block weight, 4-bit codebook
        (16, 512, 768, 4),     # w_out, 2-bit codebook
        (1, 8, 8, 2),          # degenerate small
    ],
)
def test_qmm_production_shapes(b, m, n, k):
    x, codes, cb = _mk_qmm(b, m, n, k)
    got = qmm(x, codes, cb)
    want = qmm_ref(x, codes, cb)
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=1e-3)


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 3).map(lambda e: 2 ** e),
    m=st.integers(3, 7).map(lambda e: 2 ** e),
    n=st.integers(3, 7).map(lambda e: 2 ** e),
    kbits=st.integers(1, 8),
)
def test_qmm_hypothesis_shapes(b, m, n, kbits):
    x, codes, cb = _mk_qmm(b, m, n, 2 ** kbits)
    np.testing.assert_allclose(
        qmm(x, codes, cb), qmm_ref(x, codes, cb), rtol=3e-4, atol=1e-3
    )


@settings(max_examples=10, deadline=None)
@given(scale=st.sampled_from([1e-4, 1e-2, 1.0, 1e2, 1e4]))
def test_qmm_value_regimes(scale):
    x, codes, cb = _mk_qmm(8, 64, 64, 16, scale=scale)
    cb = cb * scale
    got, want = qmm(x, codes, cb), qmm_ref(x, codes, cb)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4 * scale * scale)


def test_qmm_non_pow2_blocks():
    # M = 96 forces a 32-wide reduction block; checks _pick_block fallback.
    x, codes, cb = _mk_qmm(4, 96, 160, 8)
    np.testing.assert_allclose(
        qmm(x, codes, cb), qmm_ref(x, codes, cb), rtol=3e-4, atol=1e-3
    )


def test_pick_block():
    assert _pick_block(768) == 128
    assert _pick_block(512) == 128
    assert _pick_block(64) == 64
    assert _pick_block(96) == 32
    assert _pick_block(7) == 1


def test_qmm_matches_dense_matmul():
    # dequantized-dense equivalence: qmm == x @ codebook[codes]
    x, codes, cb = _mk_qmm(4, 32, 32, 256)
    w = dequant_ref(codes, cb)
    np.testing.assert_allclose(qmm(x, codes, cb), x @ w, rtol=3e-4, atol=1e-3)


# ----------------------------------------------------------------- assign

@pytest.mark.parametrize("n,k", [(65536, 256), (1024, 4), (512, 2), (8, 256)])
def test_assign_shapes(n, k):
    vals = jnp.asarray(RNG.standard_normal(n).astype(np.float32))
    cents = jnp.asarray(np.sort(RNG.standard_normal(k).astype(np.float32)))
    got, want = assign(vals, cents), assign_ref(vals, cents)
    np.testing.assert_array_equal(got, want)


@settings(max_examples=25, deadline=None)
@given(
    nexp=st.integers(3, 12),
    kbits=st.integers(1, 8),
    seed=st.integers(0, 2 ** 31 - 1),
)
def test_assign_hypothesis(nexp, kbits, seed):
    r = np.random.default_rng(seed)
    vals = jnp.asarray(r.standard_normal(2 ** nexp).astype(np.float32))
    cents = jnp.asarray(np.sort(r.standard_normal(2 ** kbits)).astype(np.float32))
    np.testing.assert_array_equal(assign(vals, cents), assign_ref(vals, cents))


def test_assign_padded_slots_never_selected():
    # padded slots carry CODEBOOK_PAD = 1e30 — argmin must avoid them.
    vals = jnp.asarray(RNG.standard_normal(256).astype(np.float32))
    cents = np.full(256, 1.0e30, dtype=np.float32)
    cents[:4] = np.array([-1.0, -0.3, 0.3, 1.0], dtype=np.float32)
    codes = np.asarray(assign(vals, jnp.asarray(cents)))
    assert codes.max() < 4


def test_assign_exact_centroid_values():
    # values sitting exactly on a centroid map to that centroid.
    cents = np.array([-2.0, -1.0, 0.0, 1.0], dtype=np.float32)
    codes = np.asarray(assign(jnp.asarray(cents), jnp.asarray(cents)))
    np.testing.assert_array_equal(codes, np.arange(4))
