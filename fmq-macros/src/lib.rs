//! Marker attributes for the `fmq` workspace, consumed by `cargo xtask lint`.
//!
//! The attributes expand to their input unchanged — they carry no runtime
//! behavior. Their only job is to make invariants *visible in the source*
//! so the xtask static-analysis pass (and human readers) can find them:
//!
//! - [`macro@no_alloc`] marks a function as part of the zero-allocation
//!   hot path (PR 4's contract: 0 heap allocations per ODE step in steady
//!   state). `cargo xtask lint` walks the local call graph from every
//!   marked function and rejects `vec!`/`collect`/`clone`/`format!`/
//!   `Box::new`/… anywhere reachable. See `docs/STATIC_ANALYSIS.md`.
//!
//! The crate deliberately has **zero dependencies** (no `syn`, no
//! `quote`): the expansion is the identity, so nothing needs parsing, and
//! the workspace keeps building in offline environments.
//!
//! Note: stable Rust only guarantees attribute macros on module-level
//! items, so `#[fmq_macros::no_alloc]` is applied to *free functions*
//! (e.g. the blocked-sweep kernels); methods inside `impl` blocks are
//! enrolled via the `[no_alloc] roots` list in `lint.toml` instead. Both
//! spellings feed the same lint set.

use proc_macro::TokenStream;

/// Marks a function as belonging to the zero-allocation hot path.
///
/// Pass-through: the annotated item is returned unchanged. The attribute
/// is read back out of the source text by `cargo xtask lint`, which
/// enforces alloc-freedom transitively over the local call graph.
#[proc_macro_attribute]
pub fn no_alloc(_attr: TokenStream, item: TokenStream) -> TokenStream {
    item
}
