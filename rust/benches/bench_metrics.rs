//! PERF component bench: metric throughput (SSIM windows, PSNR, feature
//! embedding + Fréchet distance, latent stats) — the evaluation-side cost
//! of regenerating Figs. 3/4.

use fmq::bench::Bencher;
use fmq::data::{Dataset, IMG_D};
use fmq::metrics::features::FeatureNet;
use fmq::metrics::fid::fid_images;
use fmq::metrics::latent::latent_stats;
use fmq::metrics::psnr::batch_psnr;
use fmq::metrics::ssim::batch_ssim;
use fmq::util::rng::Pcg64;

fn main() {
    let mut b = Bencher::default();
    let mut rng = Pcg64::seed(3);
    let n = 64usize;
    let a_imgs = Dataset::SynthCifar.batch(&mut rng, n);
    let b_imgs = Dataset::SynthCifar.batch(&mut rng, n);

    let r = b.bench("ssim batch (64 imgs)", || batch_ssim(&a_imgs, &b_imgs, IMG_D)).clone();
    println!("{:<44}   -> {:.0} imgs/s", "", n as f64 / r.mean_s);

    let r = b.bench("psnr batch (64 imgs)", || batch_psnr(&a_imgs, &b_imgs, IMG_D)).clone();
    println!("{:<44}   -> {:.0} imgs/s", "", n as f64 / r.mean_s);

    let net = FeatureNet::standard(IMG_D);
    let r = b.bench("feature embed (64 imgs)", || net.embed(&a_imgs)).clone();
    println!("{:<44}   -> {:.0} imgs/s", "", n as f64 / r.mean_s);

    b.bench("fid (64 vs 64 imgs, d=64 feats)", || {
        fid_images(&net, &a_imgs, &b_imgs)
    });

    let latents: Vec<f32> = (0..n * IMG_D).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    b.bench("latent stats (64 x 768)", || latent_stats(&latents, IMG_D));

    // dataset generation cost (workload synthesis)
    for ds in Dataset::ALL {
        let r = b
            .bench(&format!("gen {} (x16)", ds.name()), || {
                ds.batch(&mut Pcg64::seed(9), 16)
            })
            .clone();
        println!("{:<44}   -> {:.0} imgs/s", "", 16.0 / r.mean_s);
    }
}
