//! THEORY-RHO / THEORY-BOUND / COR13 harness: regenerates the paper's
//! analytic tables — α(f_W) closed forms, the α³/R² histogram ratios for
//! Gaussian/Laplace at k ∈ {8, 9, 10}σ (paper: 0.33 Gaussian, 0.54
//! Laplace at k=10), ρ(b) < 1, the FID-bound curves with their 2^{-2b}
//! slope, and the Corollary 13.1 bit-budget table.
//!
//! FMQ_BENCH_FAST=1 trims the table ranges for CI smoke runs; every
//! closed-form check (slope, rho < 1, paper constants) still executes.

use fmq::stats::dist::{alpha_gaussian, alpha_laplace};
use fmq::theory::bounds::BoundInputs;

fn main() {
    let fast = std::env::var("FMQ_BENCH_FAST").is_ok();
    let sigma = 0.05f64;

    println!("== alpha^3/R^2 histogram ratios (paper Eq. 18 block) ==");
    println!("{:>6} {:>12} {:>12}", "k", "gaussian", "laplace");
    let ks: &[f64] = if fast { &[10.0] } else { &[8.0, 9.0, 10.0] };
    for &k in ks {
        let r = k * sigma;
        let g = alpha_gaussian(sigma).powi(3) / (r * r);
        let l = alpha_laplace(sigma / std::f64::consts::SQRT_2).powi(3) / (r * r);
        println!("{k:>6.0} {g:>12.4} {l:>12.4}");
    }
    println!("(paper quotes: gaussian k=10 -> 0.33, laplace k=10 -> 0.54)");

    let b = BoundInputs::paper_defaults(sigma, 10.0);
    println!("\n== FID bound curves (Theorems 3/6) ==");
    println!("{:>6} {:>14} {:>14} {:>8}", "bits", "uniform", "OT", "OT/U");
    let mut prev_u = f64::NAN;
    let mut slope_ok = true;
    for bits in 2..=8u8 {
        let u = b.fid_bound_uniform(bits);
        let e = b.fid_bound_ot(bits);
        println!("{bits:>6} {u:>14.4e} {e:>14.4e} {:>8.4}", e / u);
        if prev_u.is_finite() && ((prev_u / u) - 4.0).abs() > 1e-6 {
            slope_ok = false;
        }
        prev_u = u;
    }
    println!(
        "2^-2b slope (4x per bit): {}",
        if slope_ok { "CONFIRMED" } else { "VIOLATED" }
    );
    println!("rho = {:.4e} (<1 = OT tighter: {})", b.rho(), b.rho() < 1.0);

    println!("\n== Corollary 13.1: bit budgets (relative to C_U) ==");
    println!("{:>14} {:>9} {:>6} {:>9}", "FID budget", "uniform", "OT", "headroom");
    let max_exp = if fast { 2 } else { 5 };
    for exp in 0..=max_exp {
        let delta = b.c_uniform() * 10f64.powi(-exp);
        let bu = b.bit_budget(delta, false);
        let bo = b.bit_budget(delta, true);
        println!("{delta:>14.3e} {bu:>9} {bo:>6} {:>9}", bu as i32 - bo as i32);
    }

    println!("\n== Corollary 13.2: achievable FID per bit-width ==");
    println!("{:>6} {:>14} {:>14}", "bits", "uniform", "OT");
    for bits in [2u8, 3, 4, 6, 8] {
        println!(
            "{bits:>6} {:>14.4e} {:>14.4e}",
            b.achievable_fid(bits, false),
            b.achievable_fid(bits, true)
        );
    }

    // eps trajectory bounds over t (Lemmas 1/5)
    println!("\n== trajectory error bounds eps(t, b=4) ==");
    println!("{:>6} {:>14} {:>14}", "t", "eps_U", "eps_E");
    for i in 0..=4 {
        let t = i as f64 / 4.0;
        println!(
            "{t:>6.2} {:>14.4e} {:>14.4e}",
            b.eps_uniform(t, 4),
            b.eps_ot(t, 4)
        );
    }
}
