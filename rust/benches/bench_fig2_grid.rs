//! FIG2 + FIG5–8 harness: the qualitative sample grids — fp32 reference
//! next to every (method, bits) variant, written as viewable .ppm files,
//! plus the per-grid PSNR footer that quantifies the visual comparison.
//!
//! Fig. 2 is the synth-celeba grid over all methods; Figs. 5–8 are the OT
//! grids for the other four datasets. FMQ_BENCH_FAST=1 shrinks everything.

use fmq::coordinator::experiment::{pseudo_trained_theta, EvalContext};
use fmq::coordinator::report;
use fmq::data::Dataset;
use fmq::metrics::psnr::batch_psnr;
use fmq::model::checkpoint;
use fmq::model::spec::ModelSpec;
use fmq::quant::{quantize_model, QuantMethod};
use fmq::runtime::{artifacts, ArtifactSet};

fn main() -> anyhow::Result<()> {
    let fast = std::env::var("FMQ_BENCH_FAST").is_ok();
    let spec = ModelSpec::default_spec();
    let art = if artifacts::available(&artifacts::default_dir()) {
        Some(ArtifactSet::load(&artifacts::default_dir())?)
    } else {
        None
    };
    let ctx = EvalContext {
        spec: spec.clone(),
        art: art.as_ref(),
        steps: if fast { 4 } else { 32 },
        n: 16,
        seed: 7,
        engine: None,
    };
    let out = std::path::PathBuf::from("results/grids");

    let theta_for = |ds: Dataset| {
        let ckpt = std::path::PathBuf::from(format!("checkpoints/model-{}.fmq", ds.name()));
        if ckpt.exists() {
            checkpoint::load_theta(&ckpt, &spec).unwrap()
        } else {
            pseudo_trained_theta(&spec, ds)
        }
    };

    // --- Fig. 2: celeba-like, all methods x bits -------------------------
    let ds = Dataset::SynthCeleba;
    let theta = theta_for(ds);
    let x0 = ctx.start_noise();
    let reference = ctx.generate_fp32(&theta, &x0)?;
    report::write_image_grid(&out.join("fig2").join("fp32.ppm"), &reference, 8)?;
    println!("Fig. 2 ({}) — per-variant PSNR vs fp32 grid:", ds.name());
    let bits: &[u8] = if fast { &[2, 8] } else { &[2, 3, 4, 6, 8] };
    for m in QuantMethod::PAPER {
        print!("  {:<8}", m.name());
        for &b in bits {
            let qm = quantize_model(&spec, &theta, m, b);
            let imgs = ctx.generate_quant(&qm, &x0)?;
            report::write_image_grid(
                &out.join("fig2").join(format!("{}{}.ppm", m.name(), b)),
                &imgs,
                8,
            )?;
            print!(" {b}b:{:>5.1}dB", batch_psnr(&reference, &imgs, spec.d));
        }
        println!();
    }

    // --- Figs. 5-8: OT grids per remaining dataset ------------------------
    let others = [
        (Dataset::SynthMnist, "fig5"),
        (Dataset::SynthFashion, "fig6"),
        (Dataset::SynthCifar, "fig7"),
        (Dataset::SynthImagenet, "fig8"),
    ];
    for (ds, fig) in others {
        let theta = theta_for(ds);
        let x0 = ctx.start_noise();
        let reference = ctx.generate_fp32(&theta, &x0)?;
        report::write_image_grid(&out.join(fig).join("fp32.ppm"), &reference, 8)?;
        print!("{fig} ({}) OT:", ds.name());
        for &b in bits {
            let qm = quantize_model(&spec, &theta, QuantMethod::Ot, b);
            let imgs = ctx.generate_quant(&qm, &x0)?;
            report::write_image_grid(&out.join(fig).join(format!("ot{b}.ppm")), &imgs, 8)?;
            print!(" {b}b:{:>5.1}dB", batch_psnr(&reference, &imgs, spec.d));
        }
        println!();
    }
    println!("grids -> {out:?} (plain PPM, open with any image viewer)");
    Ok(())
}
