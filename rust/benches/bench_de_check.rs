//! DE-CHECK (DESIGN.md): empirical per-weight distortion vs the paper's
//! Bennett estimate D_E = α(f_W)³/12 · 2^{-2b}, for Gaussian and Laplace
//! weight laws, across bit-widths — the quantitative core of Theorem 6.
//!
//! Prints measured/Bennett ratios for plain equal-mass (Algorithm 1) and
//! Lloyd-refined OT, plus the uniform baseline with its own R²/12·2^{-2b}·4
//! worst-case estimate. Also verifies the 2^{-2b} slope by OLS in log space.

use fmq::bench::Bencher;
use fmq::quant::otq::{equal_mass_codebook, otq_refined_codebook, w2_sq};
use fmq::quant::uniform::uniform_codebook;
use fmq::stats::dist::{alpha_gaussian, alpha_laplace};
use fmq::stats::{mse, ols_slope};
use fmq::util::rng::Pcg64;

fn main() {
    let mut rng = Pcg64::seed(4);
    let sigma = 0.05f64;
    let n = 1usize << 18;
    let gauss: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, sigma as f32)).collect();
    let beta = sigma / std::f64::consts::SQRT_2;
    let lap: Vec<f32> = (0..n).map(|_| rng.laplace(beta) as f32).collect();

    for (name, w, alpha) in [
        ("gaussian", &gauss, alpha_gaussian(sigma)),
        ("laplace", &lap, alpha_laplace(beta)),
    ] {
        println!("\n== {name} weights (sigma={sigma}, N=2^18) ==");
        println!(
            "{:>5} {:>12} {:>12} {:>12} {:>9} {:>9}",
            "bits", "bennett D_E", "equal-mass", "lloyd-OT", "em/DE", "ll/DE"
        );
        let mut log_d = Vec::new();
        let mut bits_f = Vec::new();
        for bits in 2..=8u8 {
            let de = alpha.powi(3) / 12.0 * 2.0f64.powi(-2 * bits as i32);
            // Lloyd needs more iterations as K grows (slow high-K
            // convergence); scale the budget so the slope fit is fair.
            let iters = 120 * (1usize << bits) / 4;
            let d_em = w2_sq(w, &equal_mass_codebook(w, bits));
            let d_ll = w2_sq(w, &otq_refined_codebook(w, bits, iters.min(4000)));
            println!(
                "{bits:>5} {de:>12.3e} {d_em:>12.3e} {d_ll:>12.3e} {:>9.2} {:>9.2}",
                d_em / de,
                d_ll / de
            );
            // fit the 2^-2b law over b <= 6: beyond that the empirical
            // quantizer is limited by Lloyd convergence + sample noise
            // (K=256 cells over 2^18 draws = 1k points/cell).
            if bits <= 6 {
                log_d.push(d_ll.ln());
                bits_f.push(bits as f64);
            }
        }
        // slope of ln D vs b should be -2 ln 2 = -1.386
        let slope = ols_slope(&bits_f, &log_d);
        println!(
            "log-slope {slope:.3} (theory -2ln2 = {:.3}) — 2^-2b law {}",
            -2.0 * std::f64::consts::LN_2,
            if (slope + 2.0 * std::f64::consts::LN_2).abs() < 0.2 {
                "CONFIRMED"
            } else {
                "VIOLATED"
            }
        );
        // uniform comparison at 3 bits (the paper's front-constant gap)
        let e_un = mse(w, &uniform_codebook(w, 3).reconstruct(w));
        let e_ot = w2_sq(w, &equal_mass_codebook(w, 3));
        println!(
            "@3 bits: uniform {e_un:.3e} vs OT {e_ot:.3e} -> OT advantage x{:.2}",
            e_un / e_ot
        );
    }

    // timing footnote so `cargo bench` reports cost too
    let mut b = Bencher::new(0.3);
    b.bench("equal_mass_codebook 2^18 @4b", || {
        equal_mass_codebook(&gauss, 4)
    });
}
