//! FIG4 harness: regenerates the paper's Figure 4 — latent-variance
//! standard deviation vs bit-width per method and dataset (reverse-ODE
//! encoding) — and checks the expected shape: OT stays near the fp32
//! baseline at every bit-width while uniform/log2 disperse at low bits.
//!
//! FMQ_BENCH_FAST=1 shrinks the grid.

use fmq::coordinator::experiment::{pseudo_trained_theta, EvalContext};
use fmq::coordinator::report;
use fmq::data::Dataset;
use fmq::model::checkpoint;
use fmq::model::spec::ModelSpec;
use fmq::quant::QuantMethod;
use fmq::runtime::{artifacts, ArtifactSet};

fn main() -> anyhow::Result<()> {
    let fast = std::env::var("FMQ_BENCH_FAST").is_ok();
    let spec = ModelSpec::default_spec();
    let art = if artifacts::available(&artifacts::default_dir()) {
        Some(ArtifactSet::load(&artifacts::default_dir())?)
    } else {
        None
    };
    let ctx = EvalContext {
        spec: spec.clone(),
        art: art.as_ref(),
        steps: if fast { 4 } else { 16 },
        n: if fast { 8 } else { 16 },
        seed: 11,
        engine: None,
    };
    let datasets: &[Dataset] = if fast {
        &[Dataset::SynthCifar]
    } else {
        &Dataset::ALL
    };
    let bits: &[u8] = if fast { &[2, 8] } else { &[2, 3, 4, 5, 6, 8] };
    let methods = QuantMethod::PAPER;

    let mut all = Vec::new();
    let t0 = std::time::Instant::now();
    for &ds in datasets {
        let ckpt = std::path::PathBuf::from(format!("checkpoints/model-{}.fmq", ds.name()));
        let theta = if ckpt.exists() {
            checkpoint::load_theta(&ckpt, &spec)?
        } else {
            pseudo_trained_theta(&spec, ds)
        };
        let pts = ctx.latent_sweep(ds, &theta, &methods, bits)?;
        println!("\n[{}] latent var-std (fp32 baseline in col 2):", ds.name());
        print!("{:>6} {:>9} |", "bits", "fp32");
        for m in methods {
            print!(" {:>9} |", m.name());
        }
        println!();
        for &b in bits {
            let base = pts
                .iter()
                .find(|p| p.bits == b && p.method == QuantMethod::Ot)
                .unwrap()
                .baseline_var_std;
            print!("{b:>6} {base:>9.4} |");
            for m in methods {
                let p = pts.iter().find(|p| p.method == m && p.bits == b).unwrap();
                print!(" {:>9.4} |", p.stats.var_std);
            }
            println!();
        }
        all.extend(pts);
    }
    println!("\nsweep wall-clock: {:.1}s", t0.elapsed().as_secs_f64());

    // shape check: at the lowest bit-width, OT's dispersion is the closest
    // to baseline among all methods (paper's central Fig. 4 finding)
    let mut ok = true;
    for &ds in datasets {
        let dev = |m: QuantMethod| {
            let p = all
                .iter()
                .find(|p| p.dataset == ds.name() && p.method == m && p.bits == bits[0])
                .unwrap();
            (p.stats.var_std - p.baseline_var_std).abs()
        };
        let d_ot = dev(QuantMethod::Ot);
        for m in [QuantMethod::Uniform, QuantMethod::Log2] {
            if d_ot > dev(m) + 0.05 {
                println!(
                    "SHAPE VIOLATION: {} OT dev {:.4} > {} dev {:.4}",
                    ds.name(),
                    d_ot,
                    m.name(),
                    dev(m)
                );
                ok = false;
            }
        }
    }
    println!("fig4 shape: {}", if ok { "OK (matches paper)" } else { "VIOLATIONS — see above" });

    std::fs::create_dir_all("results")?;
    report::latent_csv(std::path::Path::new("results/fig4_latent.csv"), &all)?;
    println!("-> results/fig4_latent.csv");
    Ok(())
}
