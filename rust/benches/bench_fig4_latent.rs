//! FIG4 harness: regenerates the paper's Figure 4 — latent-variance
//! standard deviation vs bit-width per method and dataset (reverse-ODE
//! encoding) — as a thin wrapper over the `sweep` runner, and checks the
//! expected shape: OT stays near the fp32 baseline at every bit-width
//! while uniform/log2 disperse at low bits.
//!
//! FMQ_BENCH_FAST=1 runs the smoke tier.

use fmq::coordinator::report;
use fmq::flow::ode::Solver;
use fmq::quant::QuantMethod;
use fmq::sweep::{conformance, run_grid, GridSpec};

fn main() -> anyhow::Result<()> {
    let fast = std::env::var("FMQ_BENCH_FAST").is_ok();
    let spec = GridSpec {
        solvers: vec![Solver::Euler],
        seed: 11,
        ..if fast { GridSpec::smoke() } else { GridSpec::full() }
    };
    let t0 = std::time::Instant::now();
    let res = run_grid(&spec)?;

    let mut rows = Vec::new();
    for &ds in &spec.datasets {
        println!("\n[{}] latent var-std (fp32 baseline in col 2):", ds.name());
        print!("{:>6} {:>9} |", "bits", "fp32");
        for m in &spec.methods {
            print!(" {:>9} |", m.name());
        }
        println!();
        for &b in &spec.bits {
            let base = res
                .cell(ds, QuantMethod::Ot, b, Solver::Euler)
                .map(|c| c.baseline_var_std)
                .unwrap_or(f64::NAN);
            print!("{b:>6} {base:>9.4} |");
            for &m in &spec.methods {
                let Some(c) = res.cell(ds, m, b, Solver::Euler) else {
                    continue;
                };
                print!(" {:>9.4} |", c.latent_var_std);
                rows.push(format!(
                    "{},{},{b},{:.6},{:.6},{:.6},{:.6}",
                    ds.name(),
                    m.name(),
                    c.latent_var_std,
                    c.baseline_var_std,
                    c.latent_mean_abs,
                    c.latent_max_abs
                ));
            }
            println!();
        }
    }
    println!("\nsweep wall-clock: {:.1}s", t0.elapsed().as_secs_f64());

    // shape check: at the lowest bit-width, OT's dispersion is the
    // closest to baseline among all methods (the central Fig. 4 finding)
    let mut ok = true;
    let lowest = spec.bits.iter().copied().min().unwrap_or(2);
    for &ds in &spec.datasets {
        let dev = |m: QuantMethod| {
            res.cell(ds, m, lowest, Solver::Euler)
                .map(|c| (c.latent_var_std - c.baseline_var_std).abs())
                .unwrap_or(f64::NAN)
        };
        let d_ot = dev(QuantMethod::Ot);
        for m in [QuantMethod::Uniform, QuantMethod::Log2] {
            if d_ot > dev(m) + 0.05 {
                println!(
                    "SHAPE VIOLATION: {} OT dev {:.4} > {} dev {:.4}",
                    ds.name(),
                    d_ot,
                    m.name(),
                    dev(m)
                );
                ok = false;
            }
        }
    }

    // plus the shared grid invariants (monotonicity, bounds, engines)
    let violations = conformance::check(&res);
    for v in &violations {
        println!("SHAPE VIOLATION: {v}");
        ok = false;
    }
    println!(
        "fig4 shape: {}",
        if ok { "OK (matches paper)" } else { "VIOLATIONS — see above" }
    );

    std::fs::create_dir_all("results")?;
    report::write_csv(
        std::path::Path::new("results/fig4_latent.csv"),
        "dataset,method,bits,var_std,baseline_var_std,mean_abs,max_abs",
        &rows,
    )?;
    println!("-> results/fig4_latent.csv");
    if !ok {
        anyhow::bail!("fig4 shape violations");
    }
    Ok(())
}
