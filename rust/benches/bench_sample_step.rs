//! PERF e2e bench: one Euler sampling step — fp32 vs quantized, HLO vs CPU
//! reference. This is the serving hot path; the fp32-vs-quantized delta is
//! the price of on-the-fly dequantization (Pallas qmm gather) and the
//! HLO-vs-CPU delta is what AOT compilation buys.

use fmq::bench::Bencher;
use fmq::flow::cpu_ref;
use fmq::model::spec::ModelSpec;
use fmq::quant::{quantize_model, QuantMethod};
use fmq::runtime::{artifacts, ArtifactSet};
use fmq::util::rng::Pcg64;

fn main() {
    let spec = ModelSpec::default_spec();
    let mut rng = Pcg64::seed(2);
    let theta = spec.init_theta(&mut rng);
    let mut b = Bencher::default();

    let bs = 16usize;
    let x: Vec<f32> = (0..bs * spec.d).map(|_| rng.normal_f32(0.0, 1.0)).collect();

    // CPU reference paths
    b.bench("cpu fp32 sample_step (B=16)", || {
        cpu_ref::sample_step(&spec, &theta, &x, 0.5, 0.0625)
    });
    b.note_throughput(bs as f64, "samples");
    for bits in [2u8, 8] {
        let qm = quantize_model(&spec, &theta, QuantMethod::Ot, bits);
        b.bench(&format!("cpu ot{bits} qsample_step (B=16)"), || {
            cpu_ref::qsample_step(&qm, &x, 0.5, 0.0625)
        });
    }

    // compiled HLO paths (the real serving numbers)
    let dir = artifacts::default_dir();
    if !artifacts::available(&dir) {
        println!("(artifacts missing — skipping HLO benches; run `make artifacts`)");
        return;
    }
    let art = ArtifactSet::load(&dir).expect("load artifacts");
    b.bench("hlo fp32 sample_step (B=16)", || {
        art.sample_step(&theta, &x, 0.5, 0.0625).unwrap()
    });
    b.note_throughput(bs as f64, "samples");
    for bits in [2u8, 4, 8] {
        let qm = quantize_model(&spec, &theta, QuantMethod::Ot, bits);
        let codes = qm.codes_i32();
        let biases = qm.biases.clone();
        let cbs = qm.codebooks_padded();
        b.bench(&format!("hlo ot{bits} qsample_step (B=16)"), || {
            art.qsample_step(&codes, &biases, &cbs, &x, 0.5, 0.0625)
                .unwrap()
        });
        b.note_throughput(bs as f64, "samples");
    }

    // full 32-step generation, fp32 vs quantized: one-shot (re-upload
    // weights every step) vs device-resident session (§Perf opt 1)
    let qm = quantize_model(&spec, &theta, QuantMethod::Ot, 4);
    let codes = qm.codes_i32();
    let biases = qm.biases.clone();
    let cbs = qm.codebooks_padded();
    b.bench("hlo fp32 gen x32 (one-shot steps)", || {
        let mut xx = x.clone();
        let dt = 1.0 / 32.0;
        for s in 0..32 {
            xx = art.sample_step(&theta, &xx, s as f32 * dt, dt).unwrap();
        }
        xx
    });
    b.bench("hlo fp32 gen x32 (device session)", || {
        art.sample_session(&theta)
            .unwrap()
            .integrate(&x, 0.0, 1.0, 32)
            .unwrap()
    });
    b.note_throughput(16.0, "images");
    b.bench("hlo ot4 gen x32 (one-shot steps)", || {
        let mut xx = x.clone();
        let dt = 1.0 / 32.0;
        for s in 0..32 {
            xx = art
                .qsample_step(&codes, &biases, &cbs, &xx, s as f32 * dt, dt)
                .unwrap();
        }
        xx
    });
    b.bench("hlo ot4 gen x32 (on-the-fly session)", || {
        art.qsample_session(&qm)
            .unwrap()
            .integrate(&x, 0.0, 1.0, 32)
            .unwrap()
    });
    b.note_throughput(16.0, "images");
    b.bench("hlo ot4 gen x32 (dequant-on-load)", || {
        art.qsample_session_dequant(&qm)
            .unwrap()
            .integrate(&x, 0.0, 1.0, 32)
            .unwrap()
    });
    b.note_throughput(16.0, "images");
    // staging cost itself (once per model deployment)
    b.bench("qsample_session staging (2.34M codes)", || {
        art.qsample_session(&qm).unwrap()
    });
    b.bench("dequant-on-load staging (incl. gather)", || {
        art.qsample_session_dequant(&qm).unwrap()
    });
}
