//! PERF serving bench: end-to-end TCP request latency/throughput with the
//! dynamic batcher, plus batching-efficiency accounting and a serving
//! determinism/exact-n smoke under concurrent load (the CI smoke runs
//! this with FMQ_BENCH_FAST=1). §Perf target: batching overhead
//! (non-compute latency) < 1 ms p50.

use std::sync::Arc;
use std::time::{Duration, Instant};

use fmq::coordinator::experiment::pseudo_trained_theta;
use fmq::coordinator::registry::Registry;
use fmq::coordinator::server::{serve, Client, ServerConfig};
use fmq::data::Dataset;
use fmq::model::spec::ModelSpec;
use fmq::quant::QuantMethod;
use fmq::runtime::{artifacts, ArtifactSet, SharedArtifacts};

fn main() -> anyhow::Result<()> {
    let fast = std::env::var("FMQ_BENCH_FAST").is_ok();
    let spec = ModelSpec::default_spec();
    let theta = pseudo_trained_theta(&spec, Dataset::SynthMnist);
    let registry = Arc::new(Registry::build_fleet(
        &spec,
        &theta,
        &[QuantMethod::Ot],
        &[4],
    ));
    let art = if artifacts::available(&artifacts::default_dir()) {
        Some(Arc::new(SharedArtifacts::new(ArtifactSet::load(
            &artifacts::default_dir(),
        )?)))
    } else {
        None
    };
    let hlo = art.is_some();
    let server = serve(
        registry,
        art,
        ServerConfig {
            addr: "127.0.0.1:0".into(),
            steps: if fast { 2 } else { 8 },
            linger: Duration::from_millis(3),
            engine: None,
            ..Default::default()
        },
    )?;
    let addr = server.addr.to_string();
    println!("backend: {}", if hlo { "compiled HLO" } else { "CPU reference" });

    // sequential latency (unbatched floor)
    let mut cli = Client::connect(&addr)?;
    let seq_n = if fast { 3 } else { 10 };
    let mut lats = Vec::new();
    for i in 0..seq_n {
        let t = Instant::now();
        cli.generate("ot4", 1, i)?;
        lats.push(t.elapsed().as_secs_f64());
    }
    lats.sort_by(f64::total_cmp);
    println!(
        "sequential 1-sample requests: p50 {:.1}ms  min {:.1}ms",
        lats[lats.len() / 2] * 1e3,
        lats[0] * 1e3
    );

    // concurrent load (batched throughput)
    let clients = if fast { 4 } else { 12 };
    let per = if fast { 2 } else { 4 };
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..clients {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || -> anyhow::Result<f64> {
            let mut cli = Client::connect(&addr)?;
            let mut total = 0.0;
            for r in 0..per {
                let t = Instant::now();
                cli.generate("ot4", 2, (c * 1000 + r) as u64)?;
                total += t.elapsed().as_secs_f64();
            }
            Ok(total / per as f64)
        }));
    }
    let mut mean_lat = 0.0;
    for h in handles {
        mean_lat += h.join().unwrap()?;
    }
    mean_lat /= clients as f64;
    let wall = t0.elapsed().as_secs_f64();
    let samples = clients * per * 2;
    let reqs = server.stats.requests.get();
    let batches = server.stats.batches.get();
    println!(
        "concurrent: {samples} samples / {wall:.2}s = {:.1} samples/s; mean latency {:.1}ms",
        samples as f64 / wall,
        mean_lat * 1e3
    );
    println!(
        "batching: {reqs} requests -> {batches} batches ({:.2} req/batch)",
        reqs as f64 / batches.max(1) as f64
    );

    // determinism + exact-n smoke under load: the same (model, n, seed)
    // must be bit-identical whether it runs alone or races a burst of
    // co-batched traffic, and n > model batch must come back exact
    let probe_n = if fast { 20 } else { 40 }; // > model batch (16): sliced
    let solo = Client::connect(&addr)?.generate("ot4", probe_n, 4242)?;
    assert_eq!(solo.len(), probe_n * 768, "exact-n delivery");
    let mut handles = Vec::new();
    let bg_clients: u64 = if fast { 3 } else { 6 };
    for c in 0..bg_clients {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || -> anyhow::Result<Vec<f32>> {
            let mut cli = Client::connect(&addr)?;
            cli.generate("ot4", 2, 9000 + c)?; // background noise traffic
            cli.generate("ot4", probe_n, 4242) // the probe, co-batched
        }));
    }
    for h in handles {
        let probe = h.join().unwrap()?;
        assert_eq!(probe, solo, "co-batching changed a deterministic reply");
    }
    println!("determinism smoke: {probe_n}-sample probe bit-identical under load");

    // encode round trip + stats op
    let mut cli = Client::connect(&addr)?;
    let imgs = cli.generate("ot4", 2, 7)?;
    let latents = cli.encode("ot4", &imgs)?;
    assert_eq!(latents.len(), imgs.len());
    let s = cli.stats()?;
    println!(
        "stats op: requests={} batches={} samples={} encodes={} queue_depth={}",
        s.req("requests")?.as_f64().unwrap_or(0.0),
        s.req("batches")?.as_f64().unwrap_or(0.0),
        s.req("samples")?.as_f64().unwrap_or(0.0),
        s.req("encodes")?.as_f64().unwrap_or(0.0),
        s.req("queue_depth")?.as_f64().unwrap_or(0.0),
    );
    server.stop();
    Ok(())
}
