//! Ablation benches for the design choices DESIGN.md §6 calls out:
//!   1. per-tensor vs per-channel codebooks (Algorithm 1's C loop),
//!   2. plain equal-mass vs Lloyd-refined OT,
//!   3. codebook utilization + code entropy per method (the paper's
//!      future-work §codebook-utilization analysis),
//!   4. bit-packing storage vs naive u8 codes.

use fmq::bench::Bencher;
use fmq::model::spec::ModelSpec;
use fmq::quant::otq::{equal_mass_codebook, otq_refined_codebook, w2_sq};
use fmq::quant::packing::PackedCodes;
use fmq::quant::{
    dequant_per_channel, quantize_model, quantize_per_channel, quantize_tensor, QuantMethod,
};
use fmq::stats::mse;
use fmq::util::rng::Pcg64;

fn main() {
    let mut rng = Pcg64::seed(6);
    let spec = ModelSpec::default_spec();
    let theta = spec.init_theta(&mut rng);

    // ---- 1. per-tensor vs per-channel on a real layer -------------------
    println!("== ablation 1: per-tensor vs per-channel codebooks (w_in, 768x512) ==");
    let w = theta.layer(&spec, "w_in").to_vec();
    let (rows, cols) = (768usize, 512usize);
    println!("{:>5} {:>14} {:>14} {:>8}", "bits", "per-tensor", "per-channel", "gain");
    for bits in [2u8, 3, 4] {
        let (cb, codes) = quantize_tensor(QuantMethod::Ot, &w, bits);
        let e_t = mse(&w, &cb.dequant(&codes));
        let (cbs, ccodes) = quantize_per_channel(QuantMethod::Ot, &w, rows, cols, bits);
        let e_c = mse(&w, &dequant_per_channel(&cbs, &ccodes, rows, cols));
        println!("{bits:>5} {e_t:>14.4e} {e_c:>14.4e} {:>7.2}x", e_t / e_c);
    }
    println!("(cost: per-channel stores {cols} codebooks instead of 1)");

    // ---- 2. equal-mass vs lloyd-refined ---------------------------------
    println!("\n== ablation 2: Algorithm 1 vs + Lloyd refinement ==");
    let wg: Vec<f32> = (0..65536).map(|_| rng.normal_f32(0.0, 0.05)).collect();
    println!("{:>5} {:>14} {:>14} {:>8}", "bits", "equal-mass", "lloyd-120", "gain");
    for bits in [2u8, 3, 4, 6] {
        let e0 = w2_sq(&wg, &equal_mass_codebook(&wg, bits));
        let e1 = w2_sq(&wg, &otq_refined_codebook(&wg, bits, 120));
        println!("{bits:>5} {e0:>14.4e} {e1:>14.4e} {:>7.2}x", e0 / e1);
    }

    // ---- 3. codebook utilization / entropy per method -------------------
    println!("\n== ablation 3: codebook utilization + code entropy @4 bits ==");
    println!("{:>9} {:>12} {:>14}", "method", "utilization", "entropy(bits)");
    for m in QuantMethod::ALL {
        let qm = quantize_model(&spec, &theta, m, 4);
        // entropy over the first weight layer's codes
        let l = &spec.weight_layers()[0];
        let codes: Vec<u32> = qm.codes[..l.size()].to_vec();
        let ent = qm.codebooks[0].code_entropy(&codes);
        println!(
            "{:>9} {:>11.1}% {:>14.3}",
            m.name(),
            100.0 * qm.mean_utilization(),
            ent
        );
    }
    println!("(equal-mass fills every level and maxes entropy by construction)");

    // ---- 4. storage formats ---------------------------------------------
    println!("\n== ablation 4: packed bitstream vs naive u8 codes (2.34M weights) ==");
    let qm = quantize_model(&spec, &theta, QuantMethod::Ot, 3);
    let packed = qm.pack_codes().unwrap();
    println!(
        "fp32 {} KB | u8-codes {} KB | packed-3b {} KB (x{:.1} vs fp32)",
        spec.pw() * 4 / 1024,
        qm.codes.len() / 1024,
        packed.byte_len() / 1024,
        (spec.pw() * 4) as f64 / packed.byte_len() as f64
    );

    // ---- 5. entropy coding: Huffman vs plain packing --------------------
    println!("\n== ablation 5: Huffman vs bit-packed codes @4 bits (w_in) ==");
    println!("{:>9} {:>12} {:>12} {:>8}", "method", "packed KB", "huffman KB", "saved");
    for m in QuantMethod::ALL {
        let (_, codes) = quantize_tensor(m, &w, 4);
        let (h, p) = fmq::quant::huffman::compare_storage(&codes, 4, 16).unwrap();
        println!(
            "{:>9} {:>12.1} {:>12.1} {:>7.1}%",
            m.name(),
            p as f64 / 1024.0,
            h as f64 / 1024.0,
            100.0 * (1.0 - h as f64 / p as f64)
        );
    }
    println!("(OT codes are ~uniform -> incompressible; skewed baselines compress,");
    println!(" i.e. they under-used their bit budget — the information-theoretic");
    println!(" echo of equal-mass optimality)");

    // ---- 6. mode coverage under quantization (paper future-work) --------
    println!("\n== ablation 6: mode coverage of quantized samplers (synth-mnist, CPU) ==");
    {
        use fmq::coordinator::experiment::EvalContext;
        use fmq::data::Dataset;
        use fmq::metrics::coverage::{coverage, Templates};
        let fast = std::env::var("FMQ_BENCH_FAST").is_ok();
        let mut trng = Pcg64::seed(17);
        let templates = Templates::build(Dataset::SynthMnist, &mut trng, 150, 6);
        let ckpt = std::path::Path::new("checkpoints/model-synth-mnist.fmq");
        let theta2 = if ckpt.exists() {
            fmq::model::checkpoint::load_theta(ckpt, &spec).unwrap()
        } else {
            theta.clone()
        };
        let ctx = EvalContext {
            spec: spec.clone(),
            art: None,
            steps: if fast { 4 } else { 12 },
            n: if fast { 16 } else { 48 },
            seed: 23,
            engine: None,
        };
        let x0 = ctx.start_noise();
        println!("{:>9} {:>9} {:>9} {:>9}", "variant", "bits", "covered", "entropy");
        let fp = ctx.generate_fp32(&theta2, &x0).unwrap();
        let c = coverage(&templates, &fp);
        println!("{:>9} {:>9} {:>9.2} {:>9.2}", "fp32", "-", c.covered, c.entropy);
        for (m, bits) in [
            (QuantMethod::Ot, 2u8),
            (QuantMethod::Ot, 4),
            (QuantMethod::Uniform, 2),
            (QuantMethod::Log2, 2),
        ] {
            let qm2 = quantize_model(&spec, &theta2, m, bits);
            let imgs = ctx.generate_quant(&qm2, &x0).unwrap();
            let c = coverage(&templates, &imgs);
            println!(
                "{:>9} {:>9} {:>9.2} {:>9.2}",
                m.name(),
                bits,
                c.covered,
                c.entropy
            );
        }
    }

    // timing for the ablation paths
    let mut b = Bencher::new(0.3);
    b.bench("per-channel ot4 w_in", || {
        quantize_per_channel(QuantMethod::Ot, &w, rows, cols, 4)
    });
    b.bench("pack 2.34M codes @3b", || {
        PackedCodes::pack(&qm.codes, 3).unwrap()
    });
    let (_, codes4) = quantize_tensor(QuantMethod::Uniform, &w, 4);
    b.bench("huffman encode 393k codes", || {
        let t = fmq::quant::huffman::HuffmanTable::build(
            &fmq::quant::huffman::frequencies(&codes4, 16),
        )
        .unwrap();
        t.encode(&codes4).unwrap()
    });
}
