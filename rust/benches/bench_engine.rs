//! PERF: the native LUT-GEMM engine vs dequantize-then-f32-GEMM vs the
//! compiled HLO runtime, across serving bit-widths and batch sizes.
//!
//! The dequantize-then-GEMM path (`cpu_ref::qvelocity`) is what the serve
//! stack did before `engine/` existed: reconstruct every weight matrix to
//! dense f32, then dense matmul. The LUT engine runs the same math from
//! the packed codes, so the delta is pure memory traffic + fused gather.
//! Acceptance target (ISSUE 2): LUT >= 2x the dequantize path at b <= 4
//! on batch 512.
//!
//!   cargo bench --bench bench_engine             # full grid
//!   FMQ_BENCH_FAST=1 cargo bench --bench bench_engine   # CI smoke

use fmq::bench::Bencher;
use fmq::engine::{Engine, LutEngine, Pool};
use fmq::flow::cpu_ref;
use fmq::model::spec::ModelSpec;
use fmq::quant::{quantize_model, QuantMethod};
use fmq::runtime::{artifacts, ArtifactSet};
use fmq::util::rng::Pcg64;

fn main() {
    let fast = std::env::var("FMQ_BENCH_FAST").is_ok();
    let spec = ModelSpec::default_spec();
    let mut rng = Pcg64::seed(51);
    let theta = spec.init_theta(&mut rng);
    let mut b = Bencher::default();

    let batches: &[usize] = if fast { &[1, 16] } else { &[1, 64, 512] };
    let bit_widths = [2u8, 3, 4, 8];

    // fp32 dense GEMM baseline (the ceiling dequantize-then-GEMM pays for)
    for &bs in batches {
        let x: Vec<f32> = (0..bs * spec.d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let t = vec![0.5f32; bs];
        b.bench(&format!("cpu-ref fp32 velocity (B={bs})"), || {
            cpu_ref::velocity(&spec, &theta, &x, &t)
        });
        b.note_throughput(bs as f64, "samples");
    }

    let mut speedups: Vec<(u8, usize, f64)> = Vec::new();
    for &bits in &bit_widths {
        let qm = quantize_model(&spec, &theta, QuantMethod::Ot, bits);
        let engine = LutEngine::with_pool(&qm, Pool::serial()).expect("pack model");
        let pooled = LutEngine::new(&qm).expect("pack model");
        println!(
            "-- ot{bits}: resident {} KB packed vs {} KB fp32",
            engine.model().resident_bytes() / 1024,
            spec.p() * 4 / 1024
        );
        for &bs in batches {
            let x: Vec<f32> = (0..bs * spec.d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let t = vec![0.5f32; bs];
            let dequant = b
                .bench(&format!("dequant+GEMM ot{bits} velocity (B={bs})"), || {
                    cpu_ref::qvelocity(&qm, &x, &t)
                })
                .mean_s;
            let lut = b
                .bench(&format!("lut-gemm    ot{bits} velocity (B={bs})"), || {
                    engine.velocity(&x, &t).unwrap()
                })
                .mean_s;
            b.note_throughput(bs as f64, "samples");
            if bs > 1 {
                b.bench(
                    &format!(
                        "lut-gemm    ot{bits} velocity (B={bs}, {} threads)",
                        pooled.pool().threads()
                    ),
                    || pooled.velocity(&x, &t).unwrap(),
                );
                b.note_throughput(bs as f64, "samples");
            }
            speedups.push((bits, bs, dequant / lut));
        }
    }

    println!("\nLUT-GEMM speedup vs dequantize-then-GEMM (single thread):");
    for (bits, bs, s) in &speedups {
        let flag = if *bits <= 4 && *bs >= 512 && *s < 2.0 {
            "  <-- BELOW 2x TARGET"
        } else {
            ""
        };
        println!("  ot{bits} B={bs:<4} {s:>6.2}x{flag}");
    }

    // compiled HLO runtime, when artifacts exist (the `runtime` engine)
    let dir = artifacts::default_dir();
    if !artifacts::available(&dir) {
        println!("(artifacts missing — skipping runtime-engine benches)");
        return;
    }
    let art = ArtifactSet::load(&dir).expect("load artifacts");
    let bs = art.b_sample;
    let x: Vec<f32> = (0..bs * spec.d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let t = vec![0.5f32; bs];
    b.bench(&format!("runtime fp32 velocity (B={bs})"), || {
        art.velocity(&theta, &x, &t).unwrap()
    });
    for &bits in &bit_widths {
        let qm = quantize_model(&spec, &theta, QuantMethod::Ot, bits);
        b.bench(&format!("runtime ot{bits} qsample_step (B={bs})"), || {
            art.qsample_step_model(&qm, &x, 0.5, 0.0625).unwrap()
        });
    }
}
