//! PERF: the native LUT-GEMM engines (v1 `lut`, v2 `lut2`) vs
//! dequantize-then-f32-GEMM vs the compiled HLO runtime, across serving
//! bit-widths and batch sizes — plus the **steady-state sampling
//! section**: per-Euler-step latency and a heap-allocation count through
//! the `EngineStep` hot loop, measured with a counting global allocator.
//! After one warm-up run (arena growth + autotune + temb-cache fill) the
//! `velocity_into` path must report **allocs/step = 0** for both LUT
//! engines; any regression prints a flag on the table.
//!
//! The dequantize-then-GEMM path (`cpu_ref::qvelocity`) is what the serve
//! stack did before `engine/` existed: reconstruct every weight matrix to
//! dense f32, then dense matmul. The v1 LUT engine runs the same math from
//! the packed codes; the v2 engine adds bulk tile decode, fused multi-code
//! lookup tables and tile autotuning (see `docs/BENCHMARKS.md`).
//! Acceptance targets: LUT >= 2x dequantize at b <= 4, batch 512 (ISSUE 2);
//! v2 >= 2x v1 at b in {2,3,4}, batch >= 64 (ISSUE 3); allocs/step = 0
//! for lut and lut2 in steady state (ISSUE 5).
//!
//!   cargo bench --bench bench_engine             # full grid
//!   FMQ_BENCH_FAST=1 cargo bench --bench bench_engine   # CI smoke
//!
//! Besides the stdout tables, the velocity grid is dumped to
//! `results/bench_engine.json` and the steady-state sampling cells to
//! `BENCH_engine.json` at the **repo root** (machine-readable perf
//! trajectory; field meanings in `docs/BENCHMARKS.md`).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use fmq::bench::Bencher;
use fmq::engine::{Engine, LutEngine, LutV2Engine, Pool, Tuner};
use fmq::flow::cpu_ref;
use fmq::flow::sampler::{EngineStep, StepBackend};
use fmq::model::params::ParamStore;
use fmq::model::spec::ModelSpec;
use fmq::quant::{quantize_model, QuantMethod};
use fmq::runtime::{artifacts, ArtifactSet};
use fmq::util::json::Json;
use fmq::util::rng::Pcg64;

/// Bench-only counting allocator: every allocator entry that can hand
/// out memory (alloc / alloc_zeroed / realloc) bumps one relaxed
/// counter, so a snapshot around N Euler steps yields allocs/step.
/// Deallocation is not counted (frees are paired with the allocations
/// we already count).
struct CountingAlloc;

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// One (bits, batch) cell of the engine grid, all times mean seconds.
struct Cell {
    bits: u8,
    batch: usize,
    dequant_s: f64,
    lut_s: f64,
    lut2_s: f64,
    lut2_pooled_s: f64,
}

/// One steady-state sampling cell: serial engine, per-step latency and
/// heap allocations per Euler step after a one-run warm-up.
struct HotCell {
    bits: u8,
    batch: usize,
    engine: &'static str,
    step_s: f64,
    allocs_per_step: f64,
}

impl HotCell {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("bits", Json::Num(self.bits as f64)),
            ("batch", Json::Num(self.batch as f64)),
            ("engine", Json::Str(self.engine.into())),
            ("step_latency_s", Json::Num(self.step_s)),
            ("allocs_per_step", Json::Num(self.allocs_per_step)),
        ])
    }
}

/// Measure the sampling hot loop (`EngineStep::run`) in steady state:
/// one warm-up run grows the arenas, fills the per-step time-embedding
/// cache and settles the autotuner; the measured run over the same
/// t-grid is then timed and alloc-counted (the input clone happens
/// outside the counted window, so every count is a hot-path alloc).
fn hot_cell(
    engine: &dyn Engine,
    name: &'static str,
    bits: u8,
    x0: &[f32],
    bs: usize,
    steps: usize,
) -> HotCell {
    let mut be = EngineStep::new(engine);
    let warm = be.run(x0.to_vec(), 0.0, 1.0, steps).expect("warm-up run");
    std::hint::black_box(warm);
    let x = x0.to_vec();
    let a0 = ALLOC_CALLS.load(Ordering::Relaxed);
    let t0 = std::time::Instant::now();
    let out = be.run(x, 0.0, 1.0, steps).expect("measured run");
    let wall = t0.elapsed().as_secs_f64();
    let a1 = ALLOC_CALLS.load(Ordering::Relaxed);
    std::hint::black_box(out);
    HotCell {
        bits,
        batch: bs,
        engine: name,
        step_s: wall / steps as f64,
        allocs_per_step: (a1 - a0) as f64 / steps as f64,
    }
}

/// Run the steady-state grid and dump `BENCH_engine.json` at the repo
/// root (the machine-readable allocs/step + latency trajectory).
fn steady_state_section(
    spec: &ModelSpec,
    theta: &ParamStore,
    rng: &mut Pcg64,
    batches: &[usize],
    bit_widths: &[u8],
    fast: bool,
) {
    let hot_steps = if fast { 3 } else { 4 };
    println!(
        "\nsteady-state sampling (EngineStep::run, serial engines, \
         {hot_steps} Euler steps after one warm-up run):"
    );
    println!(
        "  {:<8} {:<6} {:>6} {:>14} {:>12}",
        "engine", "bits", "batch", "step latency", "allocs/step"
    );
    let mut hot: Vec<HotCell> = Vec::new();
    for &bits in bit_widths {
        let qm = quantize_model(spec, theta, QuantMethod::Ot, bits);
        let v1 = LutEngine::with_pool(&qm, Pool::serial()).expect("pack model");
        let v2 = LutV2Engine::with_config(&qm, Pool::serial(), Tuner::measured())
            .expect("pack model");
        for &bs in batches {
            let x0: Vec<f32> = (0..bs * spec.d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            for (name, engine) in [("lut", &v1 as &dyn Engine), ("lut2", &v2 as &dyn Engine)] {
                let cell = hot_cell(engine, name, bits, &x0, bs, hot_steps);
                let flag = if cell.allocs_per_step > 0.0 {
                    "  <-- HOT PATH ALLOCATES (must be 0)"
                } else {
                    ""
                };
                println!(
                    "  {:<8} {:<6} {:>6} {:>14} {:>12.2}{flag}",
                    cell.engine,
                    cell.bits,
                    cell.batch,
                    fmq::bench::fmt_time(cell.step_s),
                    cell.allocs_per_step
                );
                hot.push(cell);
            }
        }
        println!(
            "  (ot{bits}: v2 autotuner settled on {} GEMM shapes)",
            v2.tuner().cached_plans()
        );
    }
    let obs_cells = obs_overhead_section(spec, theta, rng, fast);
    let json = Json::obj(vec![
        ("bench", Json::Str("bench_engine".into())),
        ("section", Json::Str("steady_state_sampling".into())),
        ("fast_mode", Json::Bool(fast)),
        ("steps", Json::Num(hot_steps as f64)),
        ("cells", Json::Arr(hot.iter().map(HotCell::to_json).collect())),
        ("obs_overhead", Json::Arr(obs_cells)),
    ]);
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(std::path::Path::to_path_buf)
        .unwrap_or_else(|| std::path::PathBuf::from("."));
    let path = root.join("BENCH_engine.json");
    match std::fs::write(&path, json.to_string()) {
        Ok(()) => println!("-> {}", path.display()),
        Err(e) => eprintln!("(could not write {}: {e})", path.display()),
    }
}

/// Observability overhead gate: per-Euler-step latency through
/// `EngineStep::run` with span timing enabled must stay within 3% of the
/// same loop with timing disabled (plus a small absolute grace for clock
/// jitter on sub-microsecond steps). Min-of-k on both sides so scheduler
/// noise cannot fail the gate spuriously; under the `no-obs` feature the
/// spans compile to nothing and the two sides are the same code. Panics
/// (failing the bench run, which CI treats as a failure) on breach.
fn obs_overhead_section(
    spec: &ModelSpec,
    theta: &ParamStore,
    rng: &mut Pcg64,
    fast: bool,
) -> Vec<Json> {
    let steps = if fast { 3 } else { 6 };
    let reps = if fast { 7 } else { 15 };
    let bs = 16usize;
    let bits = 4u8;
    let qm = quantize_model(spec, theta, QuantMethod::Ot, bits);
    let v1 = LutEngine::with_pool(&qm, Pool::serial()).expect("pack model");
    let mut be = EngineStep::new(&v1);
    let x0: Vec<f32> = (0..bs * spec.d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let warm = be.run(x0.clone(), 0.0, 1.0, steps).expect("warm-up run");
    std::hint::black_box(warm);
    let mut min_step = |on: bool| {
        fmq::obs::set_timing_enabled(on);
        let mut best = f64::INFINITY;
        for _ in 0..reps {
            let x = x0.clone();
            let t0 = std::time::Instant::now();
            let out = be.run(x, 0.0, 1.0, steps).expect("measured run");
            best = best.min(t0.elapsed().as_secs_f64() / steps as f64);
            std::hint::black_box(out);
        }
        best
    };
    // off first, then on, then re-check off: taking the min of both off
    // passes guards against frequency ramp-up biasing the comparison
    let off_a = min_step(false);
    let on = min_step(true);
    let off = off_a.min(min_step(false));
    fmq::obs::set_timing_enabled(true);
    let overhead = on / off - 1.0;
    println!(
        "\nobs overhead (ot{bits}, B={bs}, min of {reps}): \
         step {} off vs {} on ({:+.2}%)",
        fmq::bench::fmt_time(off),
        fmq::bench::fmt_time(on),
        overhead * 100.0
    );
    // 3% relative + 200ns absolute grace (timer granularity floor)
    let budget = off * 1.03 + 200e-9;
    assert!(
        on <= budget,
        "span timing overhead breaks the 3% gate: {:.3}us on vs {:.3}us off",
        on * 1e6,
        off * 1e6
    );
    vec![Json::obj(vec![
        ("engine", Json::Str("lut".into())),
        ("bits", Json::Num(bits as f64)),
        ("batch", Json::Num(bs as f64)),
        ("step_timing_off_s", Json::Num(off)),
        ("step_timing_on_s", Json::Num(on)),
        ("overhead_frac", Json::Num(overhead)),
        ("gate_frac", Json::Num(0.03)),
    ])]
}

impl Cell {
    fn v2_vs_v1(&self) -> f64 {
        self.lut_s / self.lut2_s
    }
    fn v2_vs_dequant(&self) -> f64 {
        self.dequant_s / self.lut2_s
    }
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("bits", Json::Num(self.bits as f64)),
            ("batch", Json::Num(self.batch as f64)),
            ("dequant_gemm_s", Json::Num(self.dequant_s)),
            ("lut_v1_s", Json::Num(self.lut_s)),
            ("lut_v2_s", Json::Num(self.lut2_s)),
            ("lut_v2_pooled_s", Json::Num(self.lut2_pooled_s)),
            ("speedup_v2_vs_v1", Json::Num(self.v2_vs_v1())),
            ("speedup_v2_vs_dequant", Json::Num(self.v2_vs_dequant())),
        ])
    }
}

fn main() {
    let fast = std::env::var("FMQ_BENCH_FAST").is_ok();
    let spec = ModelSpec::default_spec();
    let mut rng = Pcg64::seed(51);
    let theta = spec.init_theta(&mut rng);
    let mut b = Bencher::default();

    let batches: &[usize] = if fast { &[1, 16] } else { &[1, 64, 512] };
    let bit_widths = [2u8, 3, 4, 8];

    // fp32 dense GEMM baseline (the ceiling dequantize-then-GEMM pays for)
    for &bs in batches {
        let x: Vec<f32> = (0..bs * spec.d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let t = vec![0.5f32; bs];
        b.bench(&format!("cpu-ref fp32 velocity (B={bs})"), || {
            cpu_ref::velocity(&spec, &theta, &x, &t)
        });
        b.note_throughput(bs as f64, "samples");
    }

    let mut cells: Vec<Cell> = Vec::new();
    for &bits in &bit_widths {
        let qm = quantize_model(&spec, &theta, QuantMethod::Ot, bits);
        let v1 = LutEngine::with_pool(&qm, Pool::serial()).expect("pack model");
        let v2 = LutV2Engine::with_config(&qm, Pool::serial(), Tuner::measured())
            .expect("pack model");
        let v2_pooled = LutV2Engine::new(&qm).expect("pack model");
        println!(
            "-- ot{bits}: resident {} KB packed vs {} KB fp32",
            v1.model().resident_bytes() / 1024,
            spec.p() * 4 / 1024
        );
        for &bs in batches {
            let x: Vec<f32> = (0..bs * spec.d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let t = vec![0.5f32; bs];
            let dequant = b
                .bench(&format!("dequant+GEMM ot{bits} velocity (B={bs})"), || {
                    cpu_ref::qvelocity(&qm, &x, &t)
                })
                .mean_s;
            let lut = b
                .bench(&format!("lut-gemm v1 ot{bits} velocity (B={bs})"), || {
                    v1.velocity(&x, &t).unwrap()
                })
                .mean_s;
            // warm the v2 autotune cache outside the timed region so the
            // cells measure steady-state dispatch, not first-call tuning
            let _ = v2.velocity(&x, &t).unwrap();
            let lut2 = b
                .bench(&format!("lut-gemm v2 ot{bits} velocity (B={bs})"), || {
                    v2.velocity(&x, &t).unwrap()
                })
                .mean_s;
            b.note_throughput(bs as f64, "samples");
            let _ = v2_pooled.velocity(&x, &t).unwrap();
            let lut2_pooled = b
                .bench(
                    &format!(
                        "lut-gemm v2 ot{bits} velocity (B={bs}, {} threads)",
                        v2_pooled.pool().threads()
                    ),
                    || v2_pooled.velocity(&x, &t).unwrap(),
                )
                .mean_s;
            b.note_throughput(bs as f64, "samples");
            cells.push(Cell {
                bits,
                batch: bs,
                dequant_s: dequant,
                lut_s: lut,
                lut2_s: lut2,
                lut2_pooled_s: lut2_pooled,
            });
        }
    }

    println!("\nspeedups (single thread), acceptance flags per docs/BENCHMARKS.md:");
    println!(
        "  {:<6} {:>6} {:>14} {:>14} {:>14}",
        "bits", "batch", "v1/dequant", "v2/v1", "v2/dequant"
    );
    for c in &cells {
        let v1_vs_dequant = c.dequant_s / c.lut_s;
        let mut misses: Vec<&str> = Vec::new();
        if c.bits <= 4 && c.batch >= 512 && v1_vs_dequant < 2.0 {
            misses.push("v1 BELOW 2x vs dequant");
        }
        if c.bits <= 4 && c.batch >= 64 && c.v2_vs_v1() < 2.0 {
            misses.push("v2 BELOW 2x vs v1");
        }
        let flag = if misses.is_empty() {
            String::new()
        } else {
            format!("  <-- {}", misses.join("; "))
        };
        println!(
            "  ot{:<4} {:>6} {:>13.2}x {:>13.2}x {:>13.2}x{flag}",
            c.bits,
            c.batch,
            v1_vs_dequant,
            c.v2_vs_v1(),
            c.v2_vs_dequant()
        );
    }

    // machine-readable trajectory for docs/BENCHMARKS.md and CI archiving
    let json = Json::obj(vec![
        ("bench", Json::Str("bench_engine".into())),
        ("fast_mode", Json::Bool(fast)),
        ("model_params", Json::Num(spec.p() as f64)),
        (
            "cells",
            Json::Arr(cells.iter().map(Cell::to_json).collect()),
        ),
    ]);
    if let Err(e) = std::fs::create_dir_all("results")
        .and_then(|_| std::fs::write("results/bench_engine.json", json.to_string()))
    {
        eprintln!("(could not write results/bench_engine.json: {e})");
    } else {
        println!("\n-> results/bench_engine.json");
    }

    // steady-state sampling: allocs/step (must be 0) + per-step latency,
    // dumped to BENCH_engine.json at the repo root
    steady_state_section(&spec, &theta, &mut rng, batches, &bit_widths, fast);

    // compiled HLO runtime, when artifacts exist (the `runtime` engine)
    let dir = artifacts::default_dir();
    if !artifacts::available(&dir) {
        println!("(artifacts missing — skipping runtime-engine benches)");
        return;
    }
    let art = ArtifactSet::load(&dir).expect("load artifacts");
    let bs = art.b_sample;
    let x: Vec<f32> = (0..bs * spec.d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let t = vec![0.5f32; bs];
    b.bench(&format!("runtime fp32 velocity (B={bs})"), || {
        art.velocity(&theta, &x, &t).unwrap()
    });
    for &bits in &bit_widths {
        let qm = quantize_model(&spec, &theta, QuantMethod::Ot, bits);
        b.bench(&format!("runtime ot{bits} qsample_step (B={bs})"), || {
            art.qsample_step_model(&qm, &x, 0.5, 0.0625).unwrap()
        });
    }
}
