//! FIG3 harness: regenerates the paper's Figure 3 (SSIM panel A, PSNR
//! panel B) series — per dataset, method, bit-width — as a thin wrapper
//! over the `sweep` runner (the same engine path, metrics, and theory
//! bounds the `figgrid` subcommand exercises), prints the rows the paper
//! plots, and runs the full conformance invariant set on the result.
//!
//! FMQ_BENCH_FAST=1 runs the smoke tier.

use fmq::coordinator::report;
use fmq::flow::ode::Solver;
use fmq::quant::QuantMethod;
use fmq::sweep::{conformance, run_grid, GridSpec};

fn main() -> anyhow::Result<()> {
    let fast = std::env::var("FMQ_BENCH_FAST").is_ok();
    // Fig. 3 is the euler panel of the paper grid; the other solvers are
    // the figgrid subcommand's job.
    let spec = GridSpec {
        solvers: vec![Solver::Euler],
        ..if fast { GridSpec::smoke() } else { GridSpec::full() }
    };
    let t0 = std::time::Instant::now();
    let res = run_grid(&spec)?;

    let mut rows = Vec::new();
    for &ds in &spec.datasets {
        println!("\n[{}] SSIM (A) | PSNR (B):", ds.name());
        print!("{:>6} |", "bits");
        for m in &spec.methods {
            print!(" {:>15} |", m.name());
        }
        println!();
        for &b in &spec.bits {
            print!("{b:>6} |");
            for &m in &spec.methods {
                let Some(c) = res.cell(ds, m, b, Solver::Euler) else {
                    continue;
                };
                print!(" {:>6.4}/{:>5.1}dB |", c.ssim, c.psnr);
                rows.push(format!(
                    "{},{},{b},{:.6},{:.4},{:.4},{:.6e}",
                    ds.name(),
                    m.name(),
                    c.ssim,
                    c.psnr,
                    c.fid,
                    c.w2_sq
                ));
            }
            println!();
        }
    }
    println!(
        "\nsweep wall-clock: {:.1}s ({} grid cells)",
        t0.elapsed().as_secs_f64(),
        res.cells.len()
    );

    // the paper's qualitative claims, as the shared invariant set
    let violations = conformance::check(&res);
    for v in &violations {
        println!("SHAPE VIOLATION: {v}");
    }
    println!(
        "fig3 shape: {}",
        if violations.is_empty() {
            "OK (matches paper)"
        } else {
            "VIOLATIONS — see above"
        }
    );

    // headline: OT vs the baselines at 2 bits on the hardest rung
    if let (Some(ot), Some(un)) = (
        spec.datasets.last().and_then(|&ds| res.cell(ds, QuantMethod::Ot, 2, Solver::Euler)),
        spec.datasets.last().and_then(|&ds| res.cell(ds, QuantMethod::Uniform, 2, Solver::Euler)),
    ) {
        println!(
            "hardest rung @2b: OT ssim {:.4} / w2 {:.2e} vs uniform {:.4} / {:.2e}",
            ot.ssim, ot.w2_sq, un.ssim, un.w2_sq
        );
    }

    std::fs::create_dir_all("results")?;
    report::write_csv(
        std::path::Path::new("results/fig3_fidelity.csv"),
        "dataset,method,bits,ssim,psnr,fid,w2_sq",
        &rows,
    )?;
    println!("-> results/fig3_fidelity.csv");
    if !violations.is_empty() {
        anyhow::bail!("{} conformance violation(s)", violations.len());
    }
    Ok(())
}
