//! FIG3 harness: regenerates the paper's Figure 3 (SSIM panel A, PSNR
//! panel B) series — per dataset, method, bit-width — and prints the same
//! rows the paper plots, plus a pass/fail on the expected shape:
//!   * fidelity rises with bits for every method,
//!   * OT is at/above the baselines at 2–3 bits,
//!   * degradation accelerates below 5 bits for the baselines.
//!
//! Uses the trained checkpoint when `checkpoints/model-<ds>.fmq` exists
//! (run examples/e2e_pipeline first), pseudo-trained weights otherwise.
//! FMQ_BENCH_FAST=1 shrinks the grid for smoke runs.

use fmq::coordinator::experiment::{pseudo_trained_theta, EvalContext};
use fmq::coordinator::report;
use fmq::data::Dataset;
use fmq::model::checkpoint;
use fmq::model::spec::ModelSpec;
use fmq::quant::QuantMethod;
use fmq::runtime::{artifacts, ArtifactSet};

fn main() -> anyhow::Result<()> {
    let fast = std::env::var("FMQ_BENCH_FAST").is_ok();
    let spec = ModelSpec::default_spec();
    let art = if artifacts::available(&artifacts::default_dir()) {
        Some(ArtifactSet::load(&artifacts::default_dir())?)
    } else {
        None
    };
    let ctx = EvalContext {
        spec: spec.clone(),
        art: art.as_ref(),
        steps: if fast { 4 } else { 16 },
        n: if fast { 8 } else { 16 },
        seed: 7,
        engine: None,
    };
    let datasets: &[Dataset] = if fast {
        &[Dataset::SynthMnist, Dataset::SynthCeleba]
    } else {
        &Dataset::ALL
    };
    let bits: &[u8] = if fast { &[2, 4, 8] } else { &[2, 3, 4, 5, 6, 8] };
    let methods = QuantMethod::PAPER;

    let mut all = Vec::new();
    let t0 = std::time::Instant::now();
    for &ds in datasets {
        let ckpt = std::path::PathBuf::from(format!("checkpoints/model-{}.fmq", ds.name()));
        let theta = if ckpt.exists() {
            checkpoint::load_theta(&ckpt, &spec)?
        } else {
            pseudo_trained_theta(&spec, ds)
        };
        let pts = ctx.fidelity_sweep(ds, &theta, &methods, bits)?;
        println!("\n[{}] SSIM (A) | PSNR (B):", ds.name());
        print!("{:>6} |", "bits");
        for m in methods {
            print!(" {:>15} |", m.name());
        }
        println!();
        for &b in bits {
            print!("{b:>6} |");
            for m in methods {
                let p = pts.iter().find(|p| p.method == m && p.bits == b).unwrap();
                print!(" {:>6.4}/{:>5.1}dB |", p.ssim, p.psnr);
            }
            println!();
        }
        all.extend(pts);
    }
    println!("\nsweep wall-clock: {:.1}s ({} grid points)", t0.elapsed().as_secs_f64(), all.len());

    // shape checks (paper's qualitative claims)
    let mut shape_ok = true;
    for &ds in datasets {
        for m in methods {
            let at = |b: u8| {
                all.iter()
                    .find(|p| p.dataset == ds.name() && p.method == m && p.bits == b)
                    .unwrap()
            };
            let lo = at(bits[0]);
            let hi = at(*bits.last().unwrap());
            if hi.ssim + 1e-9 < lo.ssim {
                println!("SHAPE VIOLATION: {} {} ssim falls with bits", ds.name(), m.name());
                shape_ok = false;
            }
        }
        // OT at/above baselines at the lowest bit-width
        let ot = all
            .iter()
            .find(|p| p.dataset == ds.name() && p.method == QuantMethod::Ot && p.bits == bits[0])
            .unwrap();
        for m in [QuantMethod::Uniform, QuantMethod::Log2] {
            let base = all
                .iter()
                .find(|p| p.dataset == ds.name() && p.method == m && p.bits == bits[0])
                .unwrap();
            if ot.ssim + 0.02 < base.ssim {
                println!(
                    "SHAPE VIOLATION: {} OT@{}b ssim {:.4} < {} {:.4}",
                    ds.name(),
                    bits[0],
                    ot.ssim,
                    m.name(),
                    base.ssim
                );
                shape_ok = false;
            }
        }
    }
    println!("fig3 shape: {}", if shape_ok { "OK (matches paper)" } else { "VIOLATIONS — see above" });

    std::fs::create_dir_all("results")?;
    report::fidelity_csv(std::path::Path::new("results/fig3_fidelity.csv"), &all)?;
    println!("-> results/fig3_fidelity.csv");
    Ok(())
}
