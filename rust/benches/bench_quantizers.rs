//! PERF component bench: quantizer throughput per method and tensor size
//! (the coordinator-side cost of deployment-time PTQ). §Perf target:
//! >= 100 MB/s of weights per core for the OT path (sort-bound).

use fmq::bench::Bencher;
use fmq::model::spec::ModelSpec;
use fmq::quant::{quantize_model, quantize_tensor, QuantMethod};
use fmq::util::rng::Pcg64;

fn main() {
    let mut b = Bencher::default();
    let mut rng = Pcg64::seed(1);

    for &n in &[4096usize, 65536, 393216] {
        let w: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 0.05)).collect();
        for method in QuantMethod::ALL {
            let r = b
                .bench(&format!("{}/{}k", method.name(), n / 1024), || {
                    quantize_tensor(method, &w, 4)
                })
                .clone();
            let mbs = (n * 4) as f64 / r.mean_s / 1e6;
            println!("{:<44}   -> {:.1} MB/s", "", mbs);
        }
    }

    // whole-model quantization (9 tensors, 2.34M weights)
    let spec = ModelSpec::default_spec();
    let theta = spec.init_theta(&mut rng);
    for method in QuantMethod::ALL {
        let r = b
            .bench(&format!("model/{}@4b", method.name()), || {
                quantize_model(&spec, &theta, method, 4)
            })
            .clone();
        let mbs = (spec.pw() * 4) as f64 / r.mean_s / 1e6;
        println!("{:<44}   -> {:.1} MB/s whole-model", "", mbs);
    }

    // lloyd refinement cost (the optional accuracy knob)
    let w: Vec<f32> = (0..65536).map(|_| rng.normal_f32(0.0, 0.05)).collect();
    b.bench("ot+lloyd30/64k", || {
        fmq::quant::otq::otq_refined_codebook(&w, 4, 30)
    });

    // bit-packing throughput
    let codes: Vec<u32> = (0..1_000_000).map(|_| rng.below(16) as u32).collect();
    let r = b
        .bench("pack 1M codes @4b", || {
            fmq::quant::packing::PackedCodes::pack(&codes, 4).unwrap()
        })
        .clone();
    println!("{:<44}   -> {:.1} Mcodes/s", "", 1.0 / r.mean_s / 1e6 * 1e6);
}
