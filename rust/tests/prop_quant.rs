//! Property-based tests over the quantizer suite (the proptest-style
//! coverage the paper's claims rest on), via `fmq::util::check`.

use fmq::quant::codebook::Codebook;
use fmq::quant::otq::{equal_mass_codebook, equal_mass_levels, lloyd_refine, w2_sq};
use fmq::quant::packing::PackedCodes;
use fmq::quant::uniform::{delta_u, symmetric_range, uniform_codebook};
use fmq::quant::{quantize_tensor, QuantMethod};
use fmq::stats::{mse, sorted_copy};
use fmq::util::check::{forall, Gen};

/// Every method, every bit-width: codes index valid levels, reconstruction
/// error is bounded by the data range, dedup keeps levels sorted+unique.
#[test]
fn prop_all_methods_basic_contract() {
    forall("quantizer contract", 120, |g: &mut Gen| {
        let w = g.nasty_weights(1..=800);
        let bits = g.usize_in(2..=8) as u8;
        let method = match g.usize_in(0..=3) {
            0 => QuantMethod::Ot,
            1 => QuantMethod::Uniform,
            2 => QuantMethod::Pwl,
            _ => QuantMethod::Log2,
        };
        let (cb, codes) = quantize_tensor(method, &w, bits);
        let sorted_ok = cb.levels.windows(2).all(|p| p[0] < p[1]);
        let k_ok = cb.levels.len() <= 1usize << bits;
        let codes_ok = codes.iter().all(|&c| (c as usize) < cb.levels.len());
        let span = {
            let s = sorted_copy(&w);
            (s[s.len() - 1] - s[0]).abs().max(1.0)
        };
        let rec = cb.dequant(&codes);
        let err_ok = w
            .iter()
            .zip(rec.iter())
            .all(|(&x, &y)| (x - y).abs() <= span + 1.0);
        sorted_ok && k_ok && codes_ok && err_ok
    });
}

/// Equal-mass optimality vs random same-size codebooks: no random codebook
/// of the same K beats the Lloyd-refined OT codebook on W₂².
#[test]
fn prop_ot_not_beaten_by_random_codebooks() {
    forall("ot vs random codebooks", 40, |g: &mut Gen| {
        let w = g.normal_vec(64..=1024, 0.1);
        if w.len() < 8 {
            return true;
        }
        let bits = g.usize_in(2..=4) as u8;
        let cb = equal_mass_codebook(&w, bits);
        let cb = lloyd_refine(&w, &cb, 40);
        let base = w2_sq(&w, &cb);
        let k = cb.levels.len();
        // random competitor with the same number of levels
        let lo = w.iter().fold(f32::INFINITY, |a, &b| a.min(b));
        let hi = w.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let competitor: Vec<f32> = (0..k).map(|_| g.f32_in(lo..=hi)).collect();
        let ccb = Codebook::new(competitor, 8);
        w2_sq(&w, &ccb) >= base * (1.0 - 1e-5)
    });
}

/// Uniform worst-case bound δ_U = R·2^{1−b} holds on arbitrary data.
#[test]
fn prop_uniform_delta_bound() {
    forall("uniform delta bound", 120, |g: &mut Gen| {
        let w = g.nasty_weights(1..=600);
        let bits = g.usize_in(2..=8) as u8;
        let cb = uniform_codebook(&w, bits);
        let bound = delta_u(symmetric_range(&w) as f64, bits) + 1e-6;
        let rec = cb.reconstruct(&w);
        w.iter()
            .zip(rec.iter())
            .all(|(&x, &y)| ((x - y).abs() as f64) <= bound)
    });
}

/// Equal-mass split: group sizes differ by at most 1, and group means are
/// monotone (the quantile-coupling structure of the 1-D OT solution).
#[test]
fn prop_equal_mass_structure() {
    forall("equal-mass structure", 120, |g: &mut Gen| {
        let mut w = g.normal_vec(16..=2048, 1.0);
        if w.is_empty() {
            return true;
        }
        w.sort_by(f32::total_cmp);
        let k = 1usize << g.usize_in(1..=6);
        let levels = equal_mass_levels(&w, k);
        // monotone means
        let monotone = levels.windows(2).all(|p| p[0] <= p[1]);
        // group sizes from the same split rule differ by <= 1
        let n = w.len();
        let mut sizes = vec![];
        for j in 0..k {
            sizes.push((j + 1) * n / k - j * n / k);
        }
        let min = sizes.iter().min().unwrap();
        let max = sizes.iter().max().unwrap();
        monotone && max - min <= 1
    });
}

/// Lloyd refinement is monotone in MSE and idempotent at the fixed point.
#[test]
fn prop_lloyd_monotone_idempotent() {
    forall("lloyd monotone+idempotent", 40, |g: &mut Gen| {
        let w = g.nasty_weights(32..=1024);
        let bits = g.usize_in(2..=5) as u8;
        let cb0 = equal_mass_codebook(&w, bits);
        let cb1 = lloyd_refine(&w, &cb0, 60);
        let cb2 = lloyd_refine(&w, &cb1, 10);
        let e0 = w2_sq(&w, &cb0);
        let e1 = w2_sq(&w, &cb1);
        let e2 = w2_sq(&w, &cb2);
        e1 <= e0 * (1.0 + 1e-6) && e2 <= e1 * (1.0 + 1e-6)
    });
}

/// Pack/unpack at every bit-width is the identity, and the byte size is
/// exactly ceil(n·b/64)·8.
#[test]
fn prop_packing_roundtrip_and_size() {
    forall("packing roundtrip", 150, |g: &mut Gen| {
        let bits = g.usize_in(1..=12) as u8;
        let n = g.len(0..=700);
        let codes: Vec<u32> = (0..n)
            .map(|_| g.usize_in(0..=(1usize << bits) - 1) as u32)
            .collect();
        let p = PackedCodes::pack(&codes, bits).unwrap();
        let size_ok = p.byte_len() == (n * bits as usize).div_ceil(64) * 8;
        p.unpack() == codes && size_ok
    });
}

/// Quantization error never grows when bits increase (all methods), on
/// nasty mixed-regime weights.
#[test]
fn prop_bits_monotone_error() {
    forall("bits monotone", 30, |g: &mut Gen| {
        let w = g.nasty_weights(256..=2048);
        let method = match g.usize_in(0..=3) {
            0 => QuantMethod::Ot,
            1 => QuantMethod::Uniform,
            2 => QuantMethod::Pwl,
            _ => QuantMethod::Log2,
        };
        let mut prev = f64::INFINITY;
        for bits in [2u8, 4, 6, 8] {
            let (cb, codes) = quantize_tensor(method, &w, bits);
            let e = mse(&w, &cb.dequant(&codes));
            if e > prev * 1.1 {
                return false;
            }
            prev = e;
        }
        true
    });
}

/// Scale equivariance: quantizing s·w gives s·(quantized w) for OT and
/// uniform (both are scale-covariant constructions).
#[test]
fn prop_scale_equivariance() {
    forall("scale equivariance", 60, |g: &mut Gen| {
        let w = g.normal_vec(32..=512, 0.5);
        if w.is_empty() {
            return true;
        }
        let s = 2.0f32.powi(g.usize_in(0..=6) as i32 - 3); // powers of two: exact in f32
        let bits = g.usize_in(2..=6) as u8;
        for method in [QuantMethod::Ot, QuantMethod::Uniform] {
            let (cb_a, codes_a) = quantize_tensor(method, &w, bits);
            let ws: Vec<f32> = w.iter().map(|&x| x * s).collect();
            let (cb_b, codes_b) = quantize_tensor(method, &ws, bits);
            let rec_a = cb_a.dequant(&codes_a);
            let rec_b = cb_b.dequant(&codes_b);
            for (a, b) in rec_a.iter().zip(rec_b.iter()) {
                if (a * s - b).abs() > 1e-4 * (1.0 + b.abs()) {
                    return false;
                }
            }
        }
        true
    });
}

/// Packing roundtrip at *every* bit-width 1..=8, with lengths chosen so
/// the stream never ends on a word boundary (the straddle-heavy regime
/// the engine's sequential u8 unpacker feeds on).
#[test]
fn prop_packing_roundtrip_every_bit_width() {
    forall("pack/unpack all b, ragged lengths", 80, |g: &mut Gen| {
        for bits in 1..=8u8 {
            // force n*bits % 64 != 0 so the last word is partial
            let mut n = g.usize_in(1..=700);
            if (n * bits as usize) % 64 == 0 {
                n += 1;
            }
            let max = 1u32 << bits;
            let codes: Vec<u32> = (0..n)
                .map(|_| g.rng().below(max as usize) as u32)
                .collect();
            let p = PackedCodes::pack(&codes, bits).unwrap();
            if p.unpack() != codes {
                return false;
            }
            // random access agrees with sequential u8 unpack
            let i = g.rng().below(n);
            let mut one = [0u8; 1];
            p.unpack_range_u8(i, &mut one);
            if p.get(i) != codes[i] || one[0] as u32 != codes[i] {
                return false;
            }
        }
        true
    });
}

/// Huffman encode -> decode is the identity on skewed code histograms
/// (the uniform/log2 regime where entropy coding actually claws back
/// storage), including degenerate single-symbol streams.
#[test]
fn prop_huffman_roundtrip_on_skewed_codes() {
    use fmq::quant::huffman::{frequencies, HuffmanTable};
    forall("huffman encode/decode identity", 60, |g: &mut Gen| {
        let k = g.usize_in(1..=64);
        // zipf-ish skew: weight 1/(rank+1)^2, so a few symbols dominate
        let weights: Vec<f32> = (0..k).map(|i| 1.0 / ((i + 1) as f32).powi(2)).collect();
        let n = g.usize_in(1..=4000);
        let codes: Vec<u32> = (0..n).map(|_| g.rng().pick_weighted(&weights) as u32).collect();
        let freqs = frequencies(&codes, k);
        let table = match HuffmanTable::build(&freqs) {
            Ok(t) => t,
            Err(_) => return false,
        };
        let Ok((words, total_bits)) = table.encode(&codes) else {
            return false;
        };
        let Ok(back) = table.decode(&words, total_bits, codes.len()) else {
            return false;
        };
        // identity, and Huffman optimality: never worse than fixed-width
        // (all-equal lengths are themselves a valid prefix code)
        let ceil_log2_k = (usize::BITS - (k - 1).leading_zeros()) as usize;
        let fixed_bits = codes.len() * ceil_log2_k.max(1);
        back == codes && total_bits <= fixed_bits
    });
}
