//! Loom model of the workspace-slot lease protocol, exploring *real*
//! atomics/mutex interleavings (including spurious wakeups and weak
//! orderings the in-process checker in `tests/slot_interleavings.rs`
//! cannot model).
//!
//! Loom is an optional dependency behind the `loom` feature, and this
//! file additionally requires `--cfg loom` (the cfg loom itself uses to
//! swap in its model types), so the default build compiles none of it
//! and stays on the offline anyhow-only dependency policy. To run the
//! model — locally or in the CI `loom` job:
//!
//!     RUSTFLAGS="--cfg loom" cargo test --release --features loom --test loom_lease
//!
//! `check-cfg` for `cfg(loom)` is declared in the workspace lints table.
#![cfg(all(loom, feature = "loom"))]

use loom::sync::{Arc, Mutex};
use loom::thread;

/// Model of one pool slot: the arena is a grow-only Vec guarded by the
/// slot mutex, exactly like `Pool`'s `Mutex<Workspace>`.
type Slot = Arc<Mutex<Vec<usize>>>;

/// The pool's real protocol: hold the guard across the whole compute.
/// Loom explores every schedule; in all of them both threads' writes
/// must land and each thread's writes must be contiguous.
#[test]
fn guard_held_lease_is_exclusive_and_lossless() {
    loom::model(|| {
        let slot: Slot = Arc::new(Mutex::new(Vec::new()));
        let handles: Vec<_> = (0..2)
            .map(|tid| {
                let slot = Arc::clone(&slot);
                thread::spawn(move || {
                    let mut ws = slot.lock().unwrap();
                    // two-step compute under the guard: another thread
                    // interleaving here would break contiguity
                    ws.push(tid);
                    ws.push(tid);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let buf = slot.lock().unwrap();
        assert_eq!(buf.len(), 4, "every write survives: {buf:?}");
        assert!(
            buf[0] == buf[1] && buf[2] == buf[3] && buf[0] != buf[2],
            "writes of each thread stay contiguous under the guard: {buf:?}"
        );
    });
}

/// Two shards leasing *different* slots (the pool's actual sharded
/// layout: shard `idx` leases slot `idx`) never contend: both computes
/// land in their own arena in every schedule.
#[test]
fn disjoint_slots_never_interfere() {
    loom::model(|| {
        let slots: Vec<Slot> = (0..2).map(|_| Arc::new(Mutex::new(Vec::new()))).collect();
        let handles: Vec<_> = slots
            .iter()
            .enumerate()
            .map(|(tid, slot)| {
                let slot = Arc::clone(slot);
                thread::spawn(move || {
                    let mut ws = slot.lock().unwrap();
                    ws.push(tid);
                    ws.push(tid);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        for (tid, slot) in slots.iter().enumerate() {
            assert_eq!(*slot.lock().unwrap(), vec![tid, tid]);
        }
    });
}

/// The batcher handoff shape: the worker takes owned work out under the
/// lock (`mem::take`, as `next_batch` moves `x0` out of the active set),
/// computes outside the lock, and hands the result back under the lock.
/// The hand-back must *merge* (extend), not overwrite — loom finds the
/// lost-update schedule if this is replaced with an assignment, which is
/// exactly the hazard `tests/slot_interleavings.rs` demonstrates.
#[test]
fn take_compute_merge_back_loses_nothing() {
    loom::model(|| {
        let slot: Slot = Arc::new(Mutex::new(Vec::new()));
        let handles: Vec<_> = (0..2)
            .map(|tid| {
                let slot = Arc::clone(&slot);
                thread::spawn(move || {
                    // lease: take owned work out under the lock
                    let mut local = std::mem::take(&mut *slot.lock().unwrap());
                    // compute outside the lock
                    local.push(tid);
                    // hand back: merge into whatever is there now
                    slot.lock().unwrap().extend(local);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let mut buf = slot.lock().unwrap().clone();
        buf.sort_unstable();
        assert_eq!(buf, vec![0, 1], "merge-back must keep both results");
    });
}
