//! Deterministic concurrency tests for the serving layer's two handoff
//! protocols, exhaustively enumerating thread interleavings with a small
//! in-process model checker (no loom, no extra dependencies — this runs
//! under plain `cargo test` as part of tier-1). The loom twin of the
//! pool model lives in `tests/loom_lease.rs` behind `--cfg loom`.
//!
//! Part A — workspace-slot leasing ([`fmq::engine::Pool::workspace`]):
//! a DFS scheduler drives every interleaving of two logical threads
//! against a model of the slot mutex, checking exclusivity and
//! buffer-possession invariants after every step. Two protocols are
//! modeled: the one the pool actually uses (guard held across the
//! compute), which keeps the arena's growth monotone in every
//! interleaving, and the tempting take/compute-outside/put-back variant,
//! for which the checker *finds* the interleaving that silently discards
//! one thread's arena growth — the reason the pool holds its guard.
//!
//! Part B — batcher slot accounting ([`fmq::coordinator::batcher`]):
//! super-batches are assembled up front and completed in **every
//! permutation** of their hand-back order, over a grid of
//! (max_batch, n1, n2). Replies must be exact-n, bit-identical to the
//! request's private noise stream regardless of slicing or completion
//! order, and the backlog must drain to zero.
//!
//! Part C — supervisor respawn handoff: a worker panic fails the
//! in-flight super-batch with the typed `worker_panic` error and leaves
//! the batcher's queue intact for the respawned worker. The handoff is
//! checked under **every** interleaving of a racing submit against the
//! panic/complete/respawn sequence: queued requests survive untouched
//! and reply with their exact private-noise bits.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Duration;

use fmq::coordinator::batcher::{Batcher, GenRequest, Reply, SuperBatch, Work};
use fmq::coordinator::errors::{ErrClass, ServeError};
use fmq::obs::Metrics;
use fmq::util::rng::Pcg64;

/// A batcher wired to a throwaway metrics registry (these tests assert
/// on replies, not counters).
fn mk_batcher(max_batch: usize, d: usize, queue_cap: usize) -> Batcher {
    Batcher::new(
        max_batch,
        Duration::ZERO,
        d,
        queue_cap,
        Arc::new(Metrics::new()),
    )
}

// ---------------------------------------------------------------------
// Part A: exhaustive interleavings of the slot-lease protocol.
// ---------------------------------------------------------------------

/// One atomic step of a modeled thread.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Step {
    /// Acquire the slot mutex (blocks while another thread holds it).
    Lock,
    /// `mem::take` the buffer out of the slot (requires the lock).
    TakeBuf,
    /// Append to the slot's buffer in place (requires the lock).
    ComputeInSlot,
    /// Append to the thread's taken-out buffer (no lock required).
    ComputeLocal,
    /// Put the taken buffer back into the slot (requires the lock).
    PutBuf,
    /// Release the slot mutex.
    Unlock,
}

#[derive(Clone, Debug)]
struct Model {
    /// Which thread holds the slot mutex.
    holder: Option<usize>,
    /// The slot's buffer; `None` while taken out by some thread.
    slot: Option<Vec<usize>>,
    /// Per-thread taken-out buffer.
    local: Vec<Option<Vec<usize>>>,
    /// Per-thread program counter.
    pc: Vec<usize>,
}

impl Model {
    fn new(threads: usize) -> Self {
        Model {
            holder: None,
            slot: Some(Vec::new()),
            local: vec![None; threads],
            pc: vec![0; threads],
        }
    }
}

/// Can a thread execute `step` now? Only `Lock` ever blocks; the other
/// steps are protocol-guaranteed to run under the lock and are asserted
/// (not blocked) in `apply`.
fn enabled(m: &Model, step: Step) -> bool {
    match step {
        Step::Lock => m.holder.is_none(),
        _ => true,
    }
}

fn apply(m: &mut Model, t: usize, step: Step) {
    match step {
        Step::Lock => {
            assert!(m.holder.is_none(), "lock acquired while held");
            m.holder = Some(t);
        }
        Step::TakeBuf => {
            assert_eq!(m.holder, Some(t), "take without holding the lock");
            // mem::take semantics: a second taker gets a fresh default
            m.local[t] = Some(m.slot.take().unwrap_or_default());
        }
        Step::ComputeInSlot => {
            assert_eq!(m.holder, Some(t), "in-slot compute without the lock");
            m.slot
                .as_mut()
                .expect("guard-held protocol never takes the buffer out")
                .push(t);
        }
        Step::ComputeLocal => {
            m.local[t]
                .as_mut()
                .expect("local compute before take")
                .push(t);
        }
        Step::PutBuf => {
            assert_eq!(m.holder, Some(t), "put without holding the lock");
            // overwrites whatever is in the slot — this is the hazard
            m.slot = m.local[t].take();
        }
        Step::Unlock => {
            assert_eq!(m.holder, Some(t), "unlock by non-holder");
            m.holder = None;
        }
    }
}

/// Invariants that must hold in every reachable state.
fn check_state(m: &Model) {
    if m.slot.is_none() {
        assert!(
            m.local.iter().any(|l| l.is_some()),
            "buffer vanished: not in the slot and not taken by any thread"
        );
    }
}

/// DFS over every interleaving; returns the slot buffer of each distinct
/// complete schedule (one entry per schedule, duplicates preserved).
fn explore(threads: &[&[Step]], m: &Model, out: &mut Vec<Vec<usize>>) {
    let runnable: Vec<usize> = (0..threads.len())
        .filter(|&t| {
            let steps = threads[t];
            m.pc[t] < steps.len() && enabled(m, steps[m.pc[t]])
        })
        .collect();
    if runnable.is_empty() {
        let done = (0..threads.len()).all(|t| m.pc[t] == threads[t].len());
        assert!(done, "deadlock: no runnable thread but work remains: {m:?}");
        assert!(m.holder.is_none(), "terminated with the lock held");
        let finals = m.slot.clone().expect("buffer must be handed back");
        out.push(finals);
        return;
    }
    for t in runnable {
        let mut next = m.clone();
        apply(&mut next, t, threads[t][next.pc[t]]);
        next.pc[t] += 1;
        check_state(&next);
        explore(threads, &next, out);
    }
}

/// The pool's real protocol: the `MutexGuard` from `Pool::workspace` is
/// held across the whole compute. Exhaustive check: the mutex serializes
/// the two critical sections (exactly two schedules), both threads'
/// writes always survive, and each thread's writes are contiguous.
#[test]
fn guard_held_lease_keeps_every_threads_growth() {
    let prog: &[Step] = &[
        Step::Lock,
        Step::ComputeInSlot,
        Step::ComputeInSlot,
        Step::Unlock,
    ];
    let mut outcomes = Vec::new();
    explore(&[prog, prog], &Model::new(2), &mut outcomes);
    assert_eq!(
        outcomes.len(),
        2,
        "the guard must serialize the critical sections (A-first / B-first)"
    );
    for buf in &outcomes {
        assert_eq!(buf.len(), 4, "all four writes must survive: {buf:?}");
        assert!(
            buf[..2] != buf[2..] && buf[0] == buf[1] && buf[2] == buf[3],
            "each thread's writes must be contiguous (mutual exclusion): {buf:?}"
        );
    }
}

/// The tempting alternative — take the buffer out, compute outside the
/// lock, put it back — admits an interleaving where the second taker
/// receives a fresh default buffer and its put-back discards the first
/// thread's growth. The checker must find both the lossless and the
/// lossy schedules; this is the documented reason `Pool::workspace`
/// holds its guard across the compute instead.
#[test]
fn take_compute_put_lease_can_lose_growth() {
    let prog: &[Step] = &[
        Step::Lock,
        Step::TakeBuf,
        Step::Unlock,
        Step::ComputeLocal,
        Step::Lock,
        Step::PutBuf,
        Step::Unlock,
    ];
    let mut outcomes = Vec::new();
    explore(&[prog, prog], &Model::new(2), &mut outcomes);
    assert!(
        outcomes.len() > 2,
        "unlocking during the compute must admit extra schedules, got {}",
        outcomes.len()
    );
    let lens: Vec<usize> = outcomes.iter().map(|b| b.len()).collect();
    assert!(
        lens.contains(&2),
        "serialized schedules keep both writes: {lens:?}"
    );
    assert!(
        lens.contains(&1),
        "the overlapping schedule must drop one thread's growth: {lens:?}"
    );
    assert!(
        lens.iter().all(|&l| l == 1 || l == 2),
        "no schedule may fabricate or lose more than the overlap: {lens:?}"
    );
}

// ---------------------------------------------------------------------
// Part B: batcher slot accounting under every completion order.
// ---------------------------------------------------------------------

fn gen_req(n: usize, seed: u64) -> (GenRequest, mpsc::Receiver<Reply>) {
    let (rtx, rrx) = mpsc::channel();
    (
        GenRequest {
            work: Work::Generate { n, seed },
            deadline: None,
            reply: rtx,
        },
        rrx,
    )
}

fn encode_req(rows: Vec<f32>) -> (GenRequest, mpsc::Receiver<Reply>) {
    let (rtx, rrx) = mpsc::channel();
    (
        GenRequest {
            work: Work::Encode { rows },
            deadline: None,
            reply: rtx,
        },
        rrx,
    )
}

/// The first `n*d` normals of the request's own seed — the noise stream
/// the determinism contract pins regardless of co-batching.
fn expected_noise(seed: u64, n: usize, d: usize) -> Vec<f32> {
    let mut rng = Pcg64::seed(seed);
    (0..n * d).map(|_| rng.normal_f32(0.0, 1.0)).collect()
}

/// Stand-in for the integrator: a row-independent marker transform, so
/// reassembly errors (wrong offset, wrong slice) change the output.
fn integrate(x: &[f32]) -> Vec<f32> {
    x.iter().map(|v| v.mul_add(2.0, 1.0)).collect()
}

fn permutations(n: usize) -> Vec<Vec<usize>> {
    if n == 0 {
        return vec![Vec::new()];
    }
    let mut out = Vec::new();
    for p in permutations(n - 1) {
        for at in 0..=p.len() {
            let mut q = p.clone();
            q.insert(at, n - 1);
            out.push(q);
        }
    }
    out
}

/// Drain exactly the batches needed to issue every pending row. Panics
/// (test failure) if the batcher stops producing rows early.
fn drain_batches(b: &mut Batcher, total_rows: usize) -> Vec<SuperBatch> {
    let mut got = 0;
    let mut batches = Vec::new();
    while got < total_rows {
        let batch = b.next_batch().expect("batcher alive");
        assert!(!batch.is_empty(), "batcher idled with rows still pending");
        got += batch.rows;
        batches.push(batch);
    }
    assert_eq!(got, total_rows, "issued rows must match admitted rows");
    batches
}

/// Two generate requests over a grid of batch sizes, completed in every
/// possible hand-back order: replies must be exact-n, equal to the
/// integrate() of each request's private noise stream (independent of
/// slicing and completion order), and the backlog must drain to zero.
#[test]
fn completion_order_grid_reassembles_exact_n() {
    let d = 3;
    let grid = [(2usize, 3usize, 2usize), (1, 2, 3), (3, 7, 2), (4, 4, 4), (8, 3, 2)];
    for (max_batch, n1, n2) in grid {
        let n_batches = (n1 + n2).div_ceil(max_batch);
        for perm in permutations(n_batches) {
            let mut b = mk_batcher(max_batch, d, 8);
            let tx = b.submitter();
            let (r1, rx1) = gen_req(n1, 41);
            let (r2, rx2) = gen_req(n2, 42);
            tx.send(r1).expect("queue_cap accommodates both");
            tx.send(r2).expect("queue_cap accommodates both");

            let batches = drain_batches(&mut b, n1 + n2);
            assert_eq!(batches.len(), n_batches, "slot accounting drives batch count");
            for batch in &batches {
                assert!(batch.rows <= max_batch, "assemble must respect max_batch");
            }

            let mut handed: Vec<Option<SuperBatch>> = batches.into_iter().map(Some).collect();
            for &i in &perm {
                let batch = handed[i].take().expect("each batch completed once");
                let out = integrate(&batch.x0);
                b.complete(batch, Ok(&out));
            }

            for (rx, n, seed) in [(&rx1, n1, 41u64), (&rx2, n2, 42u64)] {
                let got = rx
                    .try_recv()
                    .expect("reply must be ready once all rows are back")
                    .expect("reply must be Ok");
                assert_eq!(got.len(), n * d, "exact-n reply");
                assert_eq!(
                    got,
                    integrate(&expected_noise(seed, n, d)),
                    "noise stream must be private to the request \
                     (max_batch={max_batch}, perm={perm:?})"
                );
            }
            assert_eq!(b.backlog_rows(), 0, "backlog must drain to zero");
        }
    }
}

/// Encode requests ride the same slot accounting: client rows come back
/// transformed in order, sliced or not.
#[test]
fn encode_rows_reassemble_in_order() {
    let d = 2;
    let n = 5;
    let rows: Vec<f32> = (0..n * d).map(|i| i as f32).collect();
    for max_batch in [2usize, 5, 8] {
        let mut b = mk_batcher(max_batch, d, 4);
        let tx = b.submitter();
        let (req, rrx) = encode_req(rows.clone());
        tx.send(req).expect("queue has room");
        let batches = drain_batches(&mut b, n);
        for batch in batches {
            let out = integrate(&batch.x0);
            b.complete(batch, Ok(&out));
        }
        let got = rrx.try_recv().expect("reply ready").expect("Ok reply");
        assert_eq!(got, integrate(&rows), "max_batch={max_batch}");
        assert_eq!(b.backlog_rows(), 0);
    }
}

/// A generate and an encode request never share a super-batch (each
/// batch integrates one direction), and both still reply exactly.
#[test]
fn directions_split_but_both_reply() {
    let d = 2;
    let (n1, n2) = (3usize, 2usize);
    let rows: Vec<f32> = (0..n2 * d).map(|i| 10.0 + i as f32).collect();
    let mut b = mk_batcher(8, d, 4);
    let tx = b.submitter();
    let (g, grx) = gen_req(n1, 7);
    let (e, erx) = encode_req(rows.clone());
    tx.send(g).expect("room");
    tx.send(e).expect("room");
    let batches = drain_batches(&mut b, n1 + n2);
    assert_eq!(batches.len(), 2, "directions must not mix in one batch");
    assert_ne!(batches[0].dir, batches[1].dir);
    // hand back in reverse order to cross the directions' completions
    for batch in batches.into_iter().rev() {
        let out = integrate(&batch.x0);
        b.complete(batch, Ok(&out));
    }
    let got_g = grx.try_recv().expect("ready").expect("Ok");
    assert_eq!(got_g, integrate(&expected_noise(7, n1, d)));
    let got_e = erx.try_recv().expect("ready").expect("Ok");
    assert_eq!(got_e, integrate(&rows));
    assert_eq!(b.backlog_rows(), 0);
}

// ---------------------------------------------------------------------
// Part C: supervisor respawn handoff under every submit interleaving.
// ---------------------------------------------------------------------

/// Submit the racing probe request (n=1, its own seed).
fn send_probe(tx: &mpsc::SyncSender<GenRequest>) -> mpsc::Receiver<Reply> {
    let (rb, rbx) = gen_req(1, 102);
    tx.send(rb).expect("room for the probe");
    rbx
}

/// The supervisor's panic handoff (server.rs `run_batches` returning
/// `Panicked`, then the respawn loop reusing the same batcher), modeled
/// at the batcher layer and exercised with a racing client submit landing
/// at **every** point of the sequence: before the doomed batch assembles,
/// while it is in flight, right after the supervisor fails it, and after
/// the respawned worker takes over. In every interleaving:
///
/// * the panicked super-batch's request fails exactly once with the
///   retryable `worker_panic` class — unissued tail rows die with it
///   (a half-served request must not limp on under a fresh engine);
/// * the request queued behind it and the racing submit both survive the
///   respawn untouched, replying with their exact private-noise bits;
/// * the backlog drains to zero — the handoff strands nothing.
#[test]
fn respawn_handoff_preserves_queued_requests_in_every_interleaving() {
    let d = 3;
    // n_a = 2: the doomed request exactly fills its super-batch;
    // n_a = 3: it is sliced, and the unissued tail must die with it.
    for n_a in [2usize, 3] {
        for inject_at in 0..4usize {
            let ctx = format!("n_a={n_a} inject_at={inject_at}");
            let mut b = mk_batcher(2, d, 8);
            let tx = b.submitter();
            let (ra, arx) = gen_req(n_a, 100);
            let (rc, crx) = gen_req(2, 101);
            tx.send(ra).expect("room");
            tx.send(rc).expect("room");
            let mut brx = None;

            // interleaving point 0: probe lands before the doomed batch
            if inject_at == 0 {
                brx = Some(send_probe(&tx));
            }
            let doomed = b.next_batch().expect("batcher alive");
            assert_eq!(doomed.rows, 2, "A's slice fills the super-batch ({ctx})");
            // interleaving point 1: probe lands while the batch is in flight
            if inject_at == 1 {
                brx = Some(send_probe(&tx));
            }
            // the supervisor catches the worker panic and fails exactly
            // the in-flight super-batch with the typed, retryable class
            let err = ServeError::worker_panic("worker panicked while serving this batch");
            b.complete(doomed, Err(&err));
            // interleaving point 2: probe lands during the respawn window
            if inject_at == 2 {
                brx = Some(send_probe(&tx));
            }
            // respawn boundary: the batcher carries over untouched — the
            // handoff contract is that there is NO reset to perform here
            // interleaving point 3: probe lands at the respawned worker
            if inject_at == 3 {
                brx = Some(send_probe(&tx));
            }

            // the doomed request failed eagerly, exactly once, tail included
            let got = arx.try_recv().expect("failure delivered before respawn");
            let e = got.expect_err("in-flight batch must fail");
            assert_eq!(e.class, ErrClass::WorkerPanic, "{ctx}");
            assert!(arx.try_recv().is_err(), "exactly one reply per request ({ctx})");

            // the respawned worker drains the survivors: C's 2 rows + probe
            let batches = drain_batches(&mut b, 3);
            for batch in batches {
                let out = integrate(&batch.x0);
                b.complete(batch, Ok(&out));
            }
            let got_c = crx.try_recv().expect("C ready").expect("C unharmed");
            assert_eq!(
                got_c,
                integrate(&expected_noise(101, 2, d)),
                "queued request must cross the respawn bit-exact ({ctx})"
            );
            let got_b = brx
                .expect("probe injected at every interleaving point")
                .try_recv()
                .expect("probe ready")
                .expect("probe unharmed");
            assert_eq!(
                got_b,
                integrate(&expected_noise(102, 1, d)),
                "racing submit must cross the respawn bit-exact ({ctx})"
            );
            assert_eq!(b.backlog_rows(), 0, "handoff strands nothing ({ctx})");
        }
    }
}
