//! Engine-layer integration: the native LUT-GEMM engines (v1 `lut`, v2
//! `lut2`) must reproduce the dequantize-then-GEMM CPU reference — per
//! element, for every quantization method, at every serving bit-width —
//! and stay exact through the pool sharding (both axes), the sampler
//! adapter and the serving layer.

use fmq::engine::{
    build_quantized, CpuRefEngine, Engine, EngineKind, LutEngine, LutModel, LutV2Engine, Pool,
    TilePlan, Tuner, Workspace,
};
use fmq::flow::cpu_ref;
use fmq::flow::sampler::{self, CpuQStep, EngineStep};
use fmq::model::params::ParamStore;
use fmq::model::spec::{Layer, ModelSpec};
use fmq::quant::{quantize_model, QuantMethod};
use fmq::util::rng::Pcg64;

fn setup() -> (ModelSpec, ParamStore) {
    let spec = ModelSpec::default_spec();
    let mut rng = Pcg64::seed(41);
    let theta = spec.init_theta(&mut rng);
    (spec, theta)
}

/// A structurally-identical but small velocity net, so the exhaustive
/// (method x bits) equivalence grid — including the Lloyd-refined OT
/// quantizer — stays fast in debug-mode `cargo test`. The kernels are
/// size-agnostic; the full-size spec is spot-checked separately below.
fn small_spec() -> ModelSpec {
    let (d, hidden, temb_freqs, blocks) = (24usize, 32usize, 4usize, 2usize);
    let mut layers = Vec::new();
    let mut off = 0usize;
    let mut add = |layers: &mut Vec<Layer>, name: &str, shape: Vec<usize>| {
        let l = Layer {
            name: name.to_string(),
            shape,
            offset: off,
        };
        off += l.size();
        layers.push(l);
    };
    add(&mut layers, "w_in", vec![d, hidden]);
    add(&mut layers, "b_in", vec![hidden]);
    add(&mut layers, "w_t", vec![2 * temb_freqs, hidden]);
    add(&mut layers, "b_t", vec![hidden]);
    for i in 0..blocks {
        add(&mut layers, &format!("w1_{i}"), vec![hidden, hidden]);
        add(&mut layers, &format!("b1_{i}"), vec![hidden]);
        add(&mut layers, &format!("w2_{i}"), vec![hidden, hidden]);
        add(&mut layers, &format!("b2_{i}"), vec![hidden]);
    }
    add(&mut layers, "w_out", vec![hidden, d]);
    add(&mut layers, "b_out", vec![d]);
    ModelSpec {
        layers,
        d,
        hidden,
        blocks,
        temb_freqs,
        k_max: 256,
        freq_max: 1000.0,
    }
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b.iter())
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f32::max)
}

/// The acceptance pin: |engine − cpu_ref| < 1e-5 per element for all
/// `QuantMethod`s at 2/3/4/8 bits — for **both** kernel generations.
/// (The v1 kernel is written to be *bit-exact*; the v2 blocked kernel
/// re-associates sums through its fused group tables, and the tolerance
/// also guards against platform-specific float contraction.)
#[test]
fn lut_engines_equal_cpu_ref_all_methods_all_bits() {
    let spec = small_spec();
    let mut rng = Pcg64::seed(41);
    let theta = spec.init_theta(&mut rng);
    let mut rng = Pcg64::seed(42);
    let b = 3usize;
    let x: Vec<f32> = (0..b * spec.d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let t = [0.1f32, 0.55, 0.95];
    for method in QuantMethod::ALL {
        for bits in [2u8, 3, 4, 8] {
            let qm = quantize_model(&spec, &theta, method, bits);
            let v_ref = cpu_ref::qvelocity(&qm, &x, &t);
            for kind in [EngineKind::Lut, EngineKind::Lut2] {
                let engine = build_quantized(kind, &qm).unwrap();
                let v_eng = engine.velocity(&x, &t).unwrap();
                let d = max_abs_diff(&v_eng, &v_ref);
                assert!(
                    d < 1e-5,
                    "{method:?} @ {bits} bits ({kind:?}): max |engine - cpu_ref| = {d}"
                );
            }
        }
    }
}

/// Same pin at the full default architecture (2.4M params), one paper
/// method per bit-width to keep debug-mode test time sane.
#[test]
fn lut_engine_equals_cpu_ref_full_size_model() {
    let (spec, theta) = setup();
    let mut rng = Pcg64::seed(47);
    let x: Vec<f32> = (0..2 * spec.d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let t = [0.35f32, 0.8];
    for (method, bits) in [
        (QuantMethod::Ot, 2u8),
        (QuantMethod::Uniform, 3),
        (QuantMethod::Pwl, 4),
        (QuantMethod::Log2, 8),
    ] {
        let qm = quantize_model(&spec, &theta, method, bits);
        let v_ref = cpu_ref::qvelocity(&qm, &x, &t);
        let engine = LutEngine::new(&qm).unwrap();
        let d = max_abs_diff(&engine.velocity(&x, &t).unwrap(), &v_ref);
        assert!(d < 1e-5, "{method:?} @ {bits} bits full-size: {d}");
        let v2 = LutV2Engine::new(&qm).unwrap();
        let d = max_abs_diff(&v2.velocity(&x, &t).unwrap(), &v_ref);
        assert!(d < 1e-5, "{method:?} @ {bits} bits full-size (v2): {d}");
    }
}

/// Euler steps through the Engine trait match the reference step.
#[test]
fn engine_step_equals_cpu_ref_step() {
    let (spec, theta) = setup();
    let mut rng = Pcg64::seed(43);
    let x: Vec<f32> = (0..2 * spec.d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    for bits in [2u8, 4] {
        let qm = quantize_model(&spec, &theta, QuantMethod::Ot, bits);
        let engine = LutEngine::new(&qm).unwrap();
        let y_eng = engine.step(&x, 0.3, 0.0625).unwrap();
        let y_ref = cpu_ref::qsample_step(&qm, &x, 0.3, 0.0625);
        let d = max_abs_diff(&y_eng, &y_ref);
        assert!(d < 1e-5, "bits={bits}: step diff {d}");
    }
}

/// v2 determinism pin: output is bit-identical across thread counts —
/// in both the row-sharding (batch >= threads) and the column-sharding
/// (batch < threads) regime — and across tile plans and tuner policies.
/// Only `group` (a pure function of bits) affects accumulation order.
#[test]
fn v2_sharding_and_tile_plans_are_exact() {
    let (spec, theta) = setup();
    let qm = quantize_model(&spec, &theta, QuantMethod::Ot, 2);
    let mut rng = Pcg64::seed(48);
    for b in [2usize, 11] {
        let x: Vec<f32> = (0..b * spec.d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let t: Vec<f32> = (0..b).map(|i| (i as f32 + 0.5) / b as f32).collect();
        let serial = LutV2Engine::with_config(&qm, Pool::serial(), Tuner::Heuristic)
            .unwrap()
            .velocity(&x, &t)
            .unwrap();
        for threads in [2usize, 3, 8] {
            let eng =
                LutV2Engine::with_config(&qm, Pool::new(threads), Tuner::measured()).unwrap();
            assert_eq!(
                eng.velocity(&x, &t).unwrap(),
                serial,
                "b={b} threads={threads} must be bit-identical"
            );
            // again on the SAME engine: the pool-slot arenas (and, for
            // b < threads, the leased column-shard stripe buffers) are
            // now dirty from the first call — reuse must not change a
            // bit. This pins the dirty-arena property on the stripe
            // lease/scatter path, which small_spec layers (cols < 2 *
            // COL_SHARD_MIN) can never reach.
            let mut ws = fmq::engine::Workspace::new();
            let mut out = vec![f32::NAN; b * spec.d];
            eng.velocity_into(&x, &t, &mut out, &mut ws).unwrap();
            assert_eq!(
                out, serial,
                "b={b} threads={threads}: dirty pool arenas must be invisible"
            );
        }
        // explicit tile plans: k_tile is numerically invisible
        for k_tile in [16usize, 64, 128] {
            let plan = TilePlan { k_tile, group: fmq::engine::tune::max_group(2) };
            let eng =
                LutV2Engine::with_config(&qm, Pool::serial(), Tuner::Fixed(plan)).unwrap();
            assert_eq!(eng.velocity(&x, &t).unwrap(), serial, "k_tile={k_tile}");
        }
    }
}

/// v2 through the sampler adapter and `build_quantized` selector: the
/// full generation/encoding loop agrees with the legacy backend within
/// the integration tolerance (amplified per Euler step).
#[test]
fn v2_generation_through_adapter_tracks_legacy_backend() {
    let (spec, theta) = setup();
    let qm = quantize_model(&spec, &theta, QuantMethod::Uniform, 4);
    let mut rng = Pcg64::seed(49);
    let x0: Vec<f32> = (0..3 * spec.d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let mut legacy = CpuQStep { qm: &qm };
    let want = sampler::generate_from(&mut legacy, &x0, 8).unwrap();
    let engine = build_quantized(EngineKind::Lut2, &qm).unwrap();
    assert_eq!(engine.name(), "lut2");
    let mut be = EngineStep::new(engine.as_ref());
    let got = sampler::generate_from(&mut be, &x0, 8).unwrap();
    let d = max_abs_diff(&got, &want);
    assert!(d < 1e-4, "v2 generation drift vs legacy: {d}");
    // reverse encoding (the Fig. 4 path) through the same adapter
    let lat_v2 = sampler::encode(&mut be, &want, 8).unwrap();
    let lat_ref = sampler::encode(&mut legacy, &want, 8).unwrap();
    let d = max_abs_diff(&lat_v2, &lat_ref);
    assert!(d < 1e-3, "v2 encoding drift vs legacy: {d}");
}

/// The zero-allocation entry point is numerically invisible: for every
/// quant method × serving bit-width × kernel generation × pool thread
/// count × tile plan, `velocity_into` through one continuously-reused
/// (dirty) workspace — and a dirty output buffer — is bit-identical to
/// the fresh-allocation `velocity` path. This is the property the
/// workspace arena refactor must uphold.
#[test]
fn velocity_into_reused_workspace_is_bit_identical() {
    let spec = small_spec();
    let mut rng = Pcg64::seed(52);
    let theta = spec.init_theta(&mut rng);
    let b = 5usize;
    let x: Vec<f32> = (0..b * spec.d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let t: Vec<f32> = (0..b).map(|i| (i as f32 + 0.5) / b as f32).collect();
    // one workspace reused (never cleared) across every configuration:
    // whatever state a previous model/shape left behind must not leak
    let mut ws = Workspace::new();
    for method in QuantMethod::ALL {
        for bits in [2u8, 3, 4, 8] {
            let qm = quantize_model(&spec, &theta, method, bits);
            let mut engines: Vec<(String, Box<dyn Engine>)> = Vec::new();
            for threads in [1usize, 3] {
                engines.push((
                    format!("lut/{threads}t"),
                    Box::new(LutEngine::with_pool(&qm, Pool::new(threads)).unwrap()),
                ));
                engines.push((
                    format!("lut2/{threads}t"),
                    Box::new(
                        LutV2Engine::with_config(&qm, Pool::new(threads), Tuner::measured())
                            .unwrap(),
                    ),
                ));
            }
            for k_tile in [16usize, 64] {
                let plan = TilePlan {
                    k_tile,
                    group: fmq::engine::tune::max_group(bits),
                };
                engines.push((
                    format!("lut2/fixed{k_tile}"),
                    Box::new(
                        LutV2Engine::with_config(&qm, Pool::serial(), Tuner::Fixed(plan)).unwrap(),
                    ),
                ));
            }
            for (name, engine) in &engines {
                let want = engine.velocity(&x, &t).unwrap();
                let mut out = vec![f32::NAN; b * spec.d]; // poisoned output
                engine.velocity_into(&x, &t, &mut out, &mut ws).unwrap();
                assert_eq!(
                    out, want,
                    "{method:?} @ {bits} bits ({name}): dirty-workspace drift"
                );
            }
        }
    }
    assert!(ws.high_water_bytes() > 0, "the arena must have been used");
}

/// Pool sharding is numerically invisible at any thread count, including
/// counts that don't divide the batch.
#[test]
fn pool_sharding_is_exact() {
    let (spec, theta) = setup();
    let qm = quantize_model(&spec, &theta, QuantMethod::Pwl, 3);
    let model = LutModel::new(&qm).unwrap();
    let mut rng = Pcg64::seed(44);
    let b = 11usize;
    let x: Vec<f32> = (0..b * spec.d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let t: Vec<f32> = (0..b).map(|i| (i as f32 + 0.5) / b as f32).collect();
    let serial = model.velocity(&x, &t);
    for threads in [2usize, 3, 8] {
        let eng = LutEngine::with_pool(&qm, Pool::new(threads)).unwrap();
        let pooled = eng.velocity(&x, &t).unwrap();
        assert_eq!(pooled, serial, "threads={threads} must be bit-identical");
    }
}

/// Full ODE integration through the sampler's EngineStep adapter matches
/// the legacy CpuQStep backend image-for-image.
#[test]
fn generation_through_engine_adapter_matches_legacy_backend() {
    let (spec, theta) = setup();
    let qm = quantize_model(&spec, &theta, QuantMethod::Ot, 2);
    let mut rng = Pcg64::seed(45);
    let x0: Vec<f32> = (0..4 * spec.d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let mut legacy = CpuQStep { qm: &qm };
    let want = sampler::generate_from(&mut legacy, &x0, 8).unwrap();
    for kind in [EngineKind::CpuRef, EngineKind::Lut] {
        let engine = build_quantized(kind, &qm).unwrap();
        let mut be = EngineStep::new(engine.as_ref());
        let got = sampler::generate_from(&mut be, &x0, 8).unwrap();
        assert_eq!(got, want, "kind={kind:?}");
    }
    // reverse encoding (the Fig. 4 path) through the adapter, too
    let engine = LutEngine::new(&qm).unwrap();
    let mut be = EngineStep::new(&engine);
    let lat_eng = sampler::encode(&mut be, &want, 8).unwrap();
    let lat_ref = sampler::encode(&mut legacy, &want, 8).unwrap();
    assert_eq!(lat_eng, lat_ref);
}

/// The packed engine never materializes dense weights: its resident
/// footprint at low bits must be a small fraction of fp32, while output
/// stays exact. This is the "compression is real at inference time" pin.
#[test]
fn resident_footprint_beats_fp32() {
    let (spec, theta) = setup();
    let fp32_bytes = spec.p() * 4;
    for (bits, max_ratio) in [(2u8, 0.15), (3, 0.18), (4, 0.22)] {
        let qm = quantize_model(&spec, &theta, QuantMethod::Ot, bits);
        let model = LutModel::new(&qm).unwrap();
        let ratio = model.resident_bytes() as f64 / fp32_bytes as f64;
        assert!(
            ratio < max_ratio,
            "{bits}-bit resident ratio {ratio:.3} (limit {max_ratio})"
        );
    }
}

/// CpuRefEngine (fp32 flavor) matches the raw cpu_ref forward, so the
/// serving layer can route full-precision variants through the same
/// Engine interface.
#[test]
fn fp32_engine_matches_cpu_ref() {
    let (spec, theta) = setup();
    let engine = CpuRefEngine::fp32(&spec, &theta);
    let mut rng = Pcg64::seed(46);
    let x: Vec<f32> = (0..2 * spec.d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let t = [0.25, 0.75];
    assert_eq!(
        engine.velocity(&x, &t).unwrap(),
        cpu_ref::velocity(&spec, &theta, &x, &t)
    );
}
