//! Runtime integration: the compiled HLO artifacts against the rust CPU
//! reference — the three-implementations-one-model cross-check.
//!
//! These tests require `make artifacts`; they are skipped (with a loud
//! message) when the artifact set is missing so `cargo test` stays green
//! in a fresh checkout.

use fmq::data::Dataset;
use fmq::flow::cpu_ref;
use fmq::model::spec::ModelSpec;
use fmq::quant::{quantize_model, QuantMethod};
use fmq::runtime::{artifacts, ArtifactSet};
use fmq::util::rng::Pcg64;

fn load() -> Option<ArtifactSet> {
    let dir = artifacts::default_dir();
    if !artifacts::available(&dir) {
        eprintln!("SKIP: artifacts missing at {dir:?} — run `make artifacts`");
        return None;
    }
    Some(ArtifactSet::load(&dir).expect("artifact set must load"))
}

fn rel_err(a: &[f32], b: &[f32]) -> f64 {
    let num: f64 = a
        .iter()
        .zip(b.iter())
        .map(|(&x, &y)| ((x - y) as f64).powi(2))
        .sum::<f64>()
        .sqrt();
    let den: f64 = b.iter().map(|&y| (y as f64).powi(2)).sum::<f64>().sqrt();
    num / den.max(1e-12)
}

#[test]
fn hlo_velocity_matches_cpu_reference() {
    let Some(art) = load() else { return };
    let spec = ModelSpec::default_spec();
    let mut rng = Pcg64::seed(1);
    let theta = spec.init_theta(&mut rng);
    let b = art.b_sample;
    let x: Vec<f32> = (0..b * spec.d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let t: Vec<f32> = (0..b).map(|_| rng.uniform() as f32).collect();
    let v_hlo = art.velocity(&theta, &x, &t).unwrap();
    let v_cpu = cpu_ref::velocity(&spec, &theta, &x, &t);
    assert_eq!(v_hlo.len(), v_cpu.len());
    let rel = rel_err(&v_hlo, &v_cpu);
    assert!(rel < 1e-4, "rust-vs-HLO velocity rel err {rel}");
}

#[test]
fn hlo_sample_step_matches_cpu_reference() {
    let Some(art) = load() else { return };
    let spec = ModelSpec::default_spec();
    let mut rng = Pcg64::seed(2);
    let theta = spec.init_theta(&mut rng);
    let b = art.b_sample;
    let x: Vec<f32> = (0..b * spec.d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    for (t, dt) in [(0.0f32, 0.125f32), (0.5, 0.03125), (1.0, -0.125)] {
        let y_hlo = art.sample_step(&theta, &x, t, dt).unwrap();
        let y_cpu = cpu_ref::sample_step(&spec, &theta, &x, t, dt);
        let rel = rel_err(&y_hlo, &y_cpu);
        assert!(rel < 1e-4, "t={t} dt={dt}: rel err {rel}");
    }
}

#[test]
fn hlo_qsample_step_matches_cpu_quantized_path() {
    let Some(art) = load() else { return };
    let spec = ModelSpec::default_spec();
    let mut rng = Pcg64::seed(3);
    let theta = spec.init_theta(&mut rng);
    let b = art.b_sample;
    let x: Vec<f32> = (0..b * spec.d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    for (method, bits) in [
        (QuantMethod::Ot, 3u8),
        (QuantMethod::Ot, 8),
        (QuantMethod::Uniform, 4),
        (QuantMethod::Log2, 2),
    ] {
        let qm = quantize_model(&spec, &theta, method, bits);
        let y_hlo = art.qsample_step_model(&qm, &x, 0.25, 0.0625).unwrap();
        let y_cpu = cpu_ref::qsample_step(&qm, &x, 0.25, 0.0625);
        let rel = rel_err(&y_hlo, &y_cpu);
        assert!(
            rel < 1e-4,
            "{method:?} b={bits}: Pallas-qmm-vs-rust rel err {rel}"
        );
    }
}

#[test]
fn hlo_train_step_decreases_loss_and_stays_finite() {
    let Some(art) = load() else { return };
    let spec = ModelSpec::default_spec();
    let mut rng = Pcg64::seed(4);
    let mut theta = spec.init_theta(&mut rng);
    let p = spec.p();
    let mut m = vec![0f32; p];
    let mut v = vec![0f32; p];
    let b = art.b_train;
    // fixed batch: loss must drop when stepping on it repeatedly
    let x1 = Dataset::SynthMnist.batch(&mut rng, b);
    let x0: Vec<f32> = (0..b * spec.d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let t: Vec<f32> = (0..b).map(|_| rng.uniform() as f32).collect();
    let mut losses = Vec::new();
    for step in 1..=10 {
        let (th2, m2, v2, loss) = art
            .train_step(&theta, &m, &v, step as f32, &x1, &x0, &t, 2e-3)
            .unwrap();
        assert!(loss.is_finite());
        theta = fmq::model::params::ParamStore::new(th2);
        m = m2;
        v = v2;
        losses.push(loss);
    }
    assert!(
        losses.last().unwrap() < losses.first().unwrap(),
        "loss did not drop: {losses:?}"
    );
    assert!(theta.as_slice().iter().all(|x| x.is_finite()));
}

#[test]
fn hlo_assign_matches_rust_codebook_assign() {
    let Some(art) = load() else { return };
    let mut rng = Pcg64::seed(5);
    let n = art.assign_chunk;
    let vals: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 0.05)).collect();
    let cb = fmq::quant::otq::equal_mass_codebook(&vals, 4);
    let padded = cb.padded_levels(256);
    let codes_hlo = art.assign_chunk_exec(&vals, &padded).unwrap();
    let codes_rust = cb.assign(&vals);
    let mut mismatches = 0usize;
    for (i, (&h, &r)) in codes_hlo.iter().zip(codes_rust.iter()).enumerate() {
        if h as u32 != r {
            // ties may break differently across implementations; accept
            // only if reconstruction is identical
            let lh = cb.levels[h as usize];
            let lr = cb.levels[r as usize];
            assert!(
                (lh - vals[i]).abs() == (lr - vals[i]).abs(),
                "idx {i}: hlo {h} rust {r} not a tie"
            );
            mismatches += 1;
        }
    }
    assert!(
        mismatches < n / 1000,
        "too many tie-mismatches: {mismatches}"
    );
}

#[test]
fn manifest_layer_table_cross_check() {
    let Some(art) = load() else { return };
    // ArtifactSet::load already cross-checks; assert the numbers again here
    let spec = ModelSpec::default_spec();
    assert_eq!(art.manifest.req_usize("p").unwrap(), spec.p());
    assert_eq!(art.manifest.req_usize("pw").unwrap(), spec.pw());
    assert_eq!(art.manifest.req_usize("pb").unwrap(), spec.pb());
    assert_eq!(
        art.manifest.req_usize("n_weights").unwrap(),
        spec.weight_layers().len()
    );
}

#[test]
fn hlo_dequant_theta_matches_rust_dequantize() {
    let Some(art) = load() else { return };
    let spec = ModelSpec::default_spec();
    let mut rng = Pcg64::seed(6);
    let theta = spec.init_theta(&mut rng);
    let qm = quantize_model(&spec, &theta, QuantMethod::Ot, 3);
    let hlo = art.dequantize(&qm).unwrap();
    let rust = qm.dequantize();
    assert_eq!(hlo.len(), rust.len());
    for (i, (a, b)) in hlo.iter().zip(rust.as_slice().iter()).enumerate() {
        assert!((a - b).abs() < 1e-6, "idx {i}: {a} vs {b}");
    }
}

#[test]
fn dequant_on_load_session_matches_on_the_fly() {
    use fmq::flow::sampler::{HloQStep, StepBackend};
    let Some(art) = load() else { return };
    let spec = ModelSpec::default_spec();
    let mut rng = Pcg64::seed(7);
    let theta = spec.init_theta(&mut rng);
    let qm = quantize_model(&spec, &theta, QuantMethod::Ot, 4);
    let x: Vec<f32> = (0..art.b_sample * spec.d)
        .map(|_| rng.normal_f32(0.0, 1.0))
        .collect();
    let a = HloQStep::new(&art, &qm)
        .unwrap()
        .run(x.clone(), 0.0, 1.0, 8)
        .unwrap();
    let b = HloQStep::new_on_the_fly(&art, &qm)
        .unwrap()
        .run(x, 0.0, 1.0, 8)
        .unwrap();
    let rel = rel_err(&a, &b);
    assert!(rel < 1e-4, "serving modes diverged: rel {rel}");
}

#[test]
fn on_device_quantization_matches_host() {
    use fmq::quant::device::quantize_model_on_device;
    let Some(art) = load() else { return };
    let spec = ModelSpec::default_spec();
    let mut rng = Pcg64::seed(8);
    let theta = spec.init_theta(&mut rng);
    for (method, bits) in [(QuantMethod::Ot, 3u8), (QuantMethod::Uniform, 5)] {
        let host = quantize_model(&spec, &theta, method, bits);
        let dev = quantize_model_on_device(&art, &spec, &theta, method, bits).unwrap();
        // codes may differ only on exact distance ties
        let mut diff = 0usize;
        for (row, l) in spec.weight_layers().iter().enumerate() {
            let off = spec.weight_offset(&l.name);
            let cb = &host.codebooks[row];
            let w = theta.layer(&spec, &l.name);
            for i in 0..l.size() {
                let (h, d) = (host.codes[off + i], dev.codes[off + i]);
                if h != d {
                    let eh = (cb.levels[h as usize] - w[i]).abs();
                    let ed = (cb.levels[d as usize] - w[i]).abs();
                    assert!(eh == ed, "{method:?} b={bits} idx {i}: not a tie");
                    diff += 1;
                }
            }
        }
        assert!(diff < spec.pw() / 1000, "{method:?}: {diff} tie-mismatches");
        // reconstruction identical up to those ties
        let dh = host.dequantize();
        let dd = dev.dequantize();
        assert!(dh.max_abs_diff(&dd) < 1e-6 || diff > 0);
    }
}
