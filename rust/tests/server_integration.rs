//! Serving-layer integration: real TCP round trips, dynamic batching,
//! protocol errors, and concurrent clients (CPU backend; the HLO path is
//! covered by runtime_integration + examples/serve_quantized).

use std::sync::Arc;
use std::time::Duration;

use fmq::coordinator::registry::Registry;
use fmq::coordinator::server::{serve, Client, ServerConfig};
use fmq::model::spec::ModelSpec;
use fmq::quant::QuantMethod;
use fmq::util::json::Json;
use fmq::util::rng::Pcg64;

fn start_server_with_engine(
    engine: Option<fmq::engine::EngineKind>,
) -> (fmq::coordinator::server::Server, String) {
    let spec = ModelSpec::default_spec();
    let theta = spec.init_theta(&mut Pcg64::seed(5));
    let registry = Arc::new(Registry::build_fleet(
        &spec,
        &theta,
        &[QuantMethod::Ot],
        &[2, 8],
    ));
    let cfg = ServerConfig {
        addr: "127.0.0.1:0".to_string(), // ephemeral port
        steps: 2,                        // fast for tests
        linger: Duration::from_millis(3),
        engine,
    };
    let server = serve(registry, None, cfg).expect("server start");
    let addr = server.addr.to_string();
    (server, addr)
}

fn start_server() -> (fmq::coordinator::server::Server, String) {
    start_server_with_engine(None)
}

/// The LUT engine is bit-exact against the dequantize-then-GEMM reference,
/// so two servers differing only in `--engine` must serve identical images
/// for the same model + seed.
#[test]
fn explicit_engines_agree_over_tcp() {
    use fmq::engine::EngineKind;
    let (s_lut, addr_lut) = start_server_with_engine(Some(EngineKind::Lut));
    let (s_ref, addr_ref) = start_server_with_engine(Some(EngineKind::CpuRef));
    let a = Client::connect(&addr_lut)
        .unwrap()
        .generate("ot2", 2, 1234)
        .unwrap();
    let b = Client::connect(&addr_ref)
        .unwrap()
        .generate("ot2", 2, 1234)
        .unwrap();
    assert_eq!(a, b, "lut and cpu-ref engines must serve identical images");
    // fp32 under the lut choice falls back to the reference and still works
    let f = Client::connect(&addr_lut)
        .unwrap()
        .generate("fp32", 1, 7)
        .unwrap();
    assert_eq!(f.len(), ModelSpec::default_spec().d);
    s_lut.stop();
    s_ref.stop();
}

/// The v2 blocked engine re-associates sums through its fused tables,
/// so it serves *equivalent* (not bit-identical) images: per-pixel drift
/// vs the reference stays tiny after the Euler loop.
#[test]
fn v2_engine_serves_equivalent_images_over_tcp() {
    use fmq::engine::EngineKind;
    let (s_v2, addr_v2) = start_server_with_engine(Some(EngineKind::Lut2));
    let (s_ref, addr_ref) = start_server_with_engine(Some(EngineKind::CpuRef));
    let a = Client::connect(&addr_v2)
        .unwrap()
        .generate("ot2", 2, 77)
        .unwrap();
    let b = Client::connect(&addr_ref)
        .unwrap()
        .generate("ot2", 2, 77)
        .unwrap();
    assert_eq!(a.len(), b.len());
    let max = a
        .iter()
        .zip(b.iter())
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max);
    assert!(max < 1e-3, "lut2 vs cpu-ref drift over TCP: {max}");
    s_v2.stop();
    s_ref.stop();
}

#[test]
fn ping_models_and_generate() {
    let (server, addr) = start_server();
    let mut c = Client::connect(&addr).unwrap();

    let pong = c.call(&Json::obj(vec![("op", Json::Str("ping".into()))])).unwrap();
    assert_eq!(pong.get("ok").unwrap().as_bool(), Some(true));

    let models = c
        .call(&Json::obj(vec![("op", Json::Str("models".into()))]))
        .unwrap();
    let names: Vec<String> = models
        .get("models")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|j| j.as_str().unwrap().to_string())
        .collect();
    assert!(names.contains(&"fp32".to_string()));
    assert!(names.contains(&"ot2".to_string()));
    assert!(names.contains(&"ot8".to_string()));

    let imgs = c.generate("ot2", 2, 42).unwrap();
    let d = ModelSpec::default_spec().d;
    assert_eq!(imgs.len(), 2 * d);
    assert!(imgs.iter().all(|&p| (-1.0..=1.0).contains(&p)));

    server.stop();
}

#[test]
fn unknown_model_and_bad_json_are_reported() {
    let (server, addr) = start_server();
    let mut c = Client::connect(&addr).unwrap();

    let resp = c
        .call(&Json::obj(vec![
            ("op", Json::Str("generate".into())),
            ("model", Json::Str("nope9".into())),
            ("n", Json::Num(1.0)),
        ]))
        .unwrap();
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false));
    assert!(resp.req_str("error").unwrap().contains("unknown model"));

    // raw garbage line
    use std::io::{BufRead, BufReader, Write};
    let stream = std::net::TcpStream::connect(&addr).unwrap();
    let mut r = BufReader::new(stream.try_clone().unwrap());
    let mut w = stream;
    w.write_all(b"this is not json\n").unwrap();
    w.flush().unwrap();
    let mut line = String::new();
    r.read_line(&mut line).unwrap();
    assert!(line.contains("\"ok\":false"));

    server.stop();
}

#[test]
fn concurrent_clients_are_batched() {
    let (server, addr) = start_server();
    let mut handles = Vec::new();
    for i in 0..6 {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || {
            let mut c = Client::connect(&addr).unwrap();
            c.generate("ot8", 2, i).unwrap().len()
        }));
    }
    let d = ModelSpec::default_spec().d;
    for h in handles {
        assert_eq!(h.join().unwrap(), 2 * d);
    }
    let reqs = server
        .stats
        .requests
        .load(std::sync::atomic::Ordering::Relaxed);
    let batches = server
        .stats
        .batches
        .load(std::sync::atomic::Ordering::Relaxed);
    assert_eq!(reqs, 6);
    assert!(batches >= 1, "no batches recorded");
    // dynamic batching must have merged at least some requests
    assert!(
        batches <= reqs,
        "batches {batches} should not exceed requests {reqs}"
    );
    server.stop();
}

#[test]
fn same_seed_same_images() {
    let (server, addr) = start_server();
    let mut c = Client::connect(&addr).unwrap();
    let a = c.generate("fp32", 1, 99).unwrap();
    let b = c.generate("fp32", 1, 99).unwrap();
    assert_eq!(a, b, "generation must be deterministic per seed");
    server.stop();
}
