//! Serving-layer integration: real TCP round trips, dynamic batching,
//! determinism under co-batching, exact-n slicing, encode/stats ops,
//! protocol errors, and concurrent clients (CPU backend; the HLO path is
//! covered by runtime_integration + examples/serve_quantized).

use std::sync::Arc;
use std::time::Duration;

use fmq::coordinator::registry::Registry;
use fmq::coordinator::server::{serve, Client, RetryPolicy, ServerConfig};
use fmq::flow::sampler::{self, CpuQStep, CpuStep};
use fmq::model::spec::{Layer, ModelSpec};
use fmq::quant::{quantize_model, QuantMethod};
use fmq::util::json::Json;
use fmq::util::rng::Pcg64;

/// Steps every test server integrates with (fast; part of the
/// determinism tuple `(model, n, seed, steps)`).
const STEPS: usize = 2;

fn test_theta(spec: &ModelSpec) -> fmq::model::params::ParamStore {
    spec.init_theta(&mut Pcg64::seed(5))
}

/// A tiny architecture with the full layer table shape, so the serving
/// tests that push many rows (slicing, determinism under load) stay fast
/// in debug builds — `cargo test -q` runs unoptimized.
fn small_spec() -> ModelSpec {
    let (d, hidden, temb_freqs, blocks) = (24usize, 32usize, 4usize, 2usize);
    let mut layers = Vec::new();
    let mut off = 0usize;
    let mut add = |layers: &mut Vec<Layer>, name: &str, shape: Vec<usize>| {
        let l = Layer {
            name: name.to_string(),
            shape,
            offset: off,
        };
        off += l.size();
        layers.push(l);
    };
    add(&mut layers, "w_in", vec![d, hidden]);
    add(&mut layers, "b_in", vec![hidden]);
    add(&mut layers, "w_t", vec![2 * temb_freqs, hidden]);
    add(&mut layers, "b_t", vec![hidden]);
    for i in 0..blocks {
        add(&mut layers, &format!("w1_{i}"), vec![hidden, hidden]);
        add(&mut layers, &format!("b1_{i}"), vec![hidden]);
        add(&mut layers, &format!("w2_{i}"), vec![hidden, hidden]);
        add(&mut layers, &format!("b2_{i}"), vec![hidden]);
    }
    add(&mut layers, "w_out", vec![hidden, d]);
    add(&mut layers, "b_out", vec![d]);
    ModelSpec {
        layers,
        d,
        hidden,
        blocks,
        temb_freqs,
        k_max: 256,
        freq_max: 1000.0,
    }
}

fn test_config(engine: Option<fmq::engine::EngineKind>) -> ServerConfig {
    ServerConfig {
        addr: "127.0.0.1:0".to_string(), // ephemeral port
        steps: STEPS,
        linger: Duration::from_millis(3),
        engine,
        ..Default::default()
    }
}

fn start_server_with_engine(
    engine: Option<fmq::engine::EngineKind>,
) -> (fmq::coordinator::server::Server, String) {
    let spec = ModelSpec::default_spec();
    let theta = test_theta(&spec);
    let registry = Arc::new(Registry::build_fleet(
        &spec,
        &theta,
        &[QuantMethod::Ot],
        &[2, 8],
    ));
    let server = serve(registry, None, test_config(engine)).expect("server start");
    let addr = server.addr.to_string();
    (server, addr)
}

fn start_server() -> (fmq::coordinator::server::Server, String) {
    start_server_with_engine(None)
}

/// Like `start_server`, on the small spec — for the row-heavy tests.
fn start_small_server() -> (fmq::coordinator::server::Server, String) {
    let spec = small_spec();
    let theta = test_theta(&spec);
    let registry = Arc::new(Registry::build_fleet(
        &spec,
        &theta,
        &[QuantMethod::Ot],
        &[2, 8],
    ));
    let server = serve(registry, None, test_config(None)).expect("server start");
    let addr = server.addr.to_string();
    (server, addr)
}

/// The serving determinism contract, computed offline: a `generate`
/// reply for `(model, n, seed)` must equal `sampler::generate` run
/// locally with the request's seed (the server's auto engines — `lut`
/// for quantized, `cpu-ref` for fp32 — are bit-exact vs these backends).
fn expected_images(spec: &ModelSpec, model: &str, n: usize, seed: u64) -> Vec<f32> {
    let theta = test_theta(spec);
    let mut rng = Pcg64::seed(seed);
    if model == "fp32" {
        let mut be = CpuStep { spec, theta: &theta };
        sampler::generate(&mut be, &mut rng, n, STEPS).unwrap()
    } else {
        let bits: u8 = model.strip_prefix("ot").unwrap().parse().unwrap();
        let qm = quantize_model(spec, &theta, QuantMethod::Ot, bits);
        let mut be = CpuQStep { qm: &qm };
        sampler::generate(&mut be, &mut rng, n, STEPS).unwrap()
    }
}

/// The LUT engine is bit-exact against the dequantize-then-GEMM reference,
/// so two servers differing only in `--engine` must serve identical images
/// for the same model + seed.
#[test]
fn explicit_engines_agree_over_tcp() {
    use fmq::engine::EngineKind;
    let (s_lut, addr_lut) = start_server_with_engine(Some(EngineKind::Lut));
    let (s_ref, addr_ref) = start_server_with_engine(Some(EngineKind::CpuRef));
    let a = Client::connect(&addr_lut)
        .unwrap()
        .generate("ot2", 2, 1234)
        .unwrap();
    let b = Client::connect(&addr_ref)
        .unwrap()
        .generate("ot2", 2, 1234)
        .unwrap();
    assert_eq!(a, b, "lut and cpu-ref engines must serve identical images");
    // fp32 under the lut choice falls back to the reference and still works
    let f = Client::connect(&addr_lut)
        .unwrap()
        .generate("fp32", 1, 7)
        .unwrap();
    assert_eq!(f.len(), ModelSpec::default_spec().d);
    s_lut.stop();
    s_ref.stop();
}

/// The v2 blocked engine re-associates sums through its fused tables,
/// so it serves *equivalent* (not bit-identical) images: per-pixel drift
/// vs the reference stays tiny after the Euler loop.
#[test]
fn v2_engine_serves_equivalent_images_over_tcp() {
    use fmq::engine::EngineKind;
    let (s_v2, addr_v2) = start_server_with_engine(Some(EngineKind::Lut2));
    let (s_ref, addr_ref) = start_server_with_engine(Some(EngineKind::CpuRef));
    let a = Client::connect(&addr_v2)
        .unwrap()
        .generate("ot2", 2, 77)
        .unwrap();
    let b = Client::connect(&addr_ref)
        .unwrap()
        .generate("ot2", 2, 77)
        .unwrap();
    assert_eq!(a.len(), b.len());
    let max = a
        .iter()
        .zip(b.iter())
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max);
    assert!(max < 1e-3, "lut2 vs cpu-ref drift over TCP: {max}");
    s_v2.stop();
    s_ref.stop();
}

#[test]
fn ping_models_and_generate() {
    let (server, addr) = start_server();
    let mut c = Client::connect(&addr).unwrap();

    let pong = c.call(&Json::obj(vec![("op", Json::Str("ping".into()))])).unwrap();
    assert_eq!(pong.get("ok").unwrap().as_bool(), Some(true));

    let models = c
        .call(&Json::obj(vec![("op", Json::Str("models".into()))]))
        .unwrap();
    let names: Vec<String> = models
        .get("models")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|j| j.as_str().unwrap().to_string())
        .collect();
    assert!(names.contains(&"fp32".to_string()));
    assert!(names.contains(&"ot2".to_string()));
    assert!(names.contains(&"ot8".to_string()));

    let imgs = c.generate("ot2", 2, 42).unwrap();
    let d = ModelSpec::default_spec().d;
    assert_eq!(imgs.len(), 2 * d);
    assert!(imgs.iter().all(|&p| (-1.0..=1.0).contains(&p)));

    server.stop();
}

#[test]
fn unknown_model_and_bad_json_are_reported() {
    let (server, addr) = start_server();
    let mut c = Client::connect(&addr).unwrap();

    let resp = c
        .call(&Json::obj(vec![
            ("op", Json::Str("generate".into())),
            ("model", Json::Str("nope9".into())),
            ("n", Json::Num(1.0)),
        ]))
        .unwrap();
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false));
    assert!(resp.req_str("error").unwrap().contains("unknown model"));

    // raw garbage line
    use std::io::{BufRead, BufReader, Write};
    let stream = std::net::TcpStream::connect(&addr).unwrap();
    let mut r = BufReader::new(stream.try_clone().unwrap());
    let mut w = stream;
    w.write_all(b"this is not json\n").unwrap();
    w.flush().unwrap();
    let mut line = String::new();
    r.read_line(&mut line).unwrap();
    assert!(line.contains("\"ok\":false"));

    server.stop();
}

#[test]
fn concurrent_clients_are_batched() {
    let (server, addr) = start_server();
    let mut handles = Vec::new();
    for i in 0..6 {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || {
            let mut c = Client::connect(&addr).unwrap();
            c.generate("ot8", 2, i).unwrap().len()
        }));
    }
    let d = ModelSpec::default_spec().d;
    for h in handles {
        assert_eq!(h.join().unwrap(), 2 * d);
    }
    let reqs = server.stats.requests.get();
    let batches = server.stats.batches.get();
    assert_eq!(reqs, 6);
    assert!(batches >= 1, "no batches recorded");
    // dynamic batching must have merged at least some requests
    assert!(
        batches <= reqs,
        "batches {batches} should not exceed requests {reqs}"
    );
    server.stop();
}

#[test]
fn same_seed_same_images() {
    let (server, addr) = start_server();
    let mut c = Client::connect(&addr).unwrap();
    let a = c.generate("fp32", 1, 99).unwrap();
    let b = c.generate("fp32", 1, 99).unwrap();
    assert_eq!(a, b, "generation must be deterministic per seed");
    server.stop();
}

/// The tentpole contract: a generate reply is a pure function of
/// `(model, n, seed, steps)` — bit-identical to running the sampler
/// locally with the request's seed, for fp32 and quantized variants.
#[test]
fn generate_is_pure_function_of_model_n_seed() {
    let (server, addr) = start_small_server();
    let spec = small_spec();
    let mut c = Client::connect(&addr).unwrap();
    for (model, n, seed) in [("fp32", 2, 7u64), ("ot2", 3, 41), ("ot8", 1, 0)] {
        let got = c.generate(model, n, seed).unwrap();
        assert_eq!(
            got,
            expected_images(&spec, model, n, seed),
            "{model} n={n} seed={seed} must equal the offline sampler"
        );
    }
    server.stop();
}

/// n larger than the model batch (16) is sliced across super-batches and
/// reassembled: exactly n rows come back, still bit-identical to the
/// offline sampler (slicing is invisible in the result).
#[test]
fn exact_n_delivery_across_super_batches() {
    let (server, addr) = start_small_server();
    let spec = small_spec();
    let d = spec.d;
    let mut c = Client::connect(&addr).unwrap();
    for n in [1usize, 16, 17, 40] {
        let imgs = c.generate("ot2", n, 1234).unwrap();
        assert_eq!(imgs.len(), n * d, "exactly n rows for n={n}");
    }
    let big = c.generate("ot2", 40, 4321).unwrap();
    assert_eq!(big, expected_images(&spec, "ot2", 40, 4321));
    // prefix property of one noise stream: the first rows of a larger
    // request equal a smaller request with the same seed
    let small = c.generate("ot2", 3, 4321).unwrap();
    assert_eq!(&big[..3 * d], &small[..]);
    server.stop();
}

/// Determinism under load: the same `(model, n, seed)` returns identical
/// bits whether the request runs alone or co-batched with arbitrary
/// concurrent traffic — including another request with the *same* seed
/// (the old xor-fold cancelled equal seeds to the base seed).
#[test]
fn cobatching_and_concurrency_do_not_change_samples() {
    let (server, addr) = start_small_server();
    let solo = Client::connect(&addr).unwrap().generate("ot2", 3, 123).unwrap();
    let mut handles = Vec::new();
    for i in 0..8u64 {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || {
            let mut c = Client::connect(&addr).unwrap();
            if i % 2 == 0 {
                // the probe request, racing varied background traffic
                ("probe", c.generate("ot2", 3, 123).unwrap())
            } else if i % 4 == 1 {
                // same-variant noise: co-batches with the probe on the
                // ot2 batcher under a different seed
                ("noise", c.generate("ot2", 2, 1000 + i).unwrap())
            } else {
                // cross-variant noise: concurrent load on another worker
                ("noise", c.generate("ot8", 2, 1000 + i).unwrap())
            }
        }));
    }
    for h in handles {
        let (kind, imgs) = h.join().unwrap();
        if kind == "probe" {
            assert_eq!(imgs, solo, "co-batching changed a deterministic reply");
        }
    }
    server.stop();
}

/// The encode op runs the reverse ODE over client rows and matches the
/// offline `sampler::encode` bit-for-bit (lut engine is bit-exact).
#[test]
fn encode_op_round_trips_over_tcp() {
    let (server, addr) = start_small_server();
    let spec = small_spec();
    let mut c = Client::connect(&addr).unwrap();
    let imgs = c.generate("ot8", 2, 11).unwrap();
    let latents = c.encode("ot8", &imgs).unwrap();
    assert_eq!(latents.len(), imgs.len());
    let qm = quantize_model(&spec, &test_theta(&spec), QuantMethod::Ot, 8);
    let mut be = CpuQStep { qm: &qm };
    let want = sampler::encode(&mut be, &imgs, STEPS).unwrap();
    assert_eq!(latents, want, "server encode must equal the offline sampler");
    // malformed rows are rejected with a protocol error
    let err = c.encode("ot8", &imgs[..spec.d + 1]).unwrap_err();
    assert!(err.to_string().contains("flat [n, d]"), "got: {err}");
    server.stop();
}

/// The stats op exposes the counters plus queue depth for the bench
/// harness.
#[test]
fn stats_op_reports_counters() {
    let (server, addr) = start_small_server();
    let mut c = Client::connect(&addr).unwrap();
    let imgs = c.generate("ot2", 2, 3).unwrap();
    c.encode("ot2", &imgs).unwrap();
    let s = c.stats().unwrap();
    let get = |k: &str| s.req(k).unwrap().as_f64().unwrap();
    assert!(get("requests") >= 2.0);
    assert!(get("batches") >= 2.0);
    assert!(get("samples") >= 2.0);
    assert!(get("encodes") >= 2.0);
    assert!(get("queue_depth") >= 0.0, "gauge must be present");
    // memory gauges: every native-engine worker reports its packed
    // resident footprint at startup, and the reusable scratch arenas
    // report a positive high-water once a batch has run
    assert!(
        get("resident_bytes") > 0.0,
        "native engines must report resident model bytes"
    );
    assert!(
        get("workspace_bytes") > 0.0,
        "warm worker arenas must report high-water scratch bytes"
    );
    server.stop();
}

/// The metrics op serves the full registry as Prometheus text format:
/// at least 12 families, including the request-latency and per-ODE-step
/// histograms with quantile estimate lines.
#[test]
fn metrics_op_serves_prometheus_families() {
    let (server, addr) = start_small_server();
    let mut c = Client::connect(&addr).unwrap();
    c.generate("ot2", 2, 3).unwrap();
    let resp = c.metrics("prometheus").unwrap();
    assert_eq!(
        resp.req_str("content_type").unwrap(),
        "text/plain; version=0.0.4"
    );
    let body = resp.req_str("body").unwrap().to_string();
    let families = body
        .lines()
        .filter(|l| l.starts_with("# TYPE "))
        .count();
    assert!(families >= 12, "expected >= 12 families, got {families}:\n{body}");
    for name in [
        "fmq_server_requests_total",
        "fmq_server_errors_total",
        "fmq_server_queue_depth",
        "fmq_server_request_latency_ns",
        "fmq_server_queue_wait_ns",
        "fmq_server_batch_assemble_ns",
        "fmq_server_batch_run_ns",
        "fmq_server_batch_rows",
        "fmq_server_reply_serialize_ns",
        "fmq_engine_ode_step_ns",
        "fmq_engine_layer_sweep_ns",
        "fmq_engine_shard_jobs_total",
    ] {
        assert!(body.contains(name), "missing family {name}:\n{body}");
    }
    // quantile estimate lines on the latency histograms
    for q in ["quantile=\"0.5\"", "quantile=\"0.95\"", "quantile=\"0.99\""] {
        assert!(body.contains(q), "missing {q} lines:\n{body}");
    }
    // the generate above integrated STEPS ODE steps through the engine
    // adapter; nothing in this binary disables timing, so the per-step
    // histogram must have filled
    let count_line = body
        .lines()
        .find(|l| l.starts_with("fmq_engine_ode_step_ns_count"))
        .expect("ode step count line");
    let count: u64 = count_line.split_whitespace().next_back().unwrap().parse().unwrap();
    assert!(count > 0, "ODE steps must be timed: {count_line}");
    // json format carries the same registry, integer-exact
    let js = c.metrics("json").unwrap();
    let m = js.req("metrics").unwrap();
    let srv = m.req("server").unwrap();
    assert!(srv.req("requests").unwrap().as_u64().unwrap() >= 1);
    assert!(
        m.req("engine").unwrap().req("ode_step_ns").unwrap().req("count").is_ok(),
        "engine histograms must be present in json form"
    );
    // unknown formats are rejected
    let err = c.metrics("xml").unwrap_err();
    assert!(err.to_string().contains("unknown metrics format"), "{err}");
    server.stop();
}

/// `ServerConfig::metrics_dump` (the `--metrics-dump` flag) writes a
/// parseable Prometheus snapshot when the server stops.
#[test]
fn metrics_dump_writes_snapshot_on_stop() {
    let path = std::env::temp_dir().join(format!("fmq_metrics_dump_{}.prom", std::process::id()));
    let _ = std::fs::remove_file(&path);
    let spec = small_spec();
    let theta = test_theta(&spec);
    let registry = Arc::new(Registry::build_fleet(&spec, &theta, &[QuantMethod::Ot], &[2]));
    let cfg = ServerConfig {
        metrics_dump: Some(path.clone()),
        ..test_config(None)
    };
    let server = serve(registry, None, cfg).expect("server start");
    let addr = server.addr.to_string();
    Client::connect(&addr).unwrap().generate("ot2", 1, 5).unwrap();
    server.stop();
    let body = std::fs::read_to_string(&path).expect("dump written on stop");
    assert!(body.contains("# TYPE fmq_server_requests_total counter"));
    assert!(body.contains("fmq_server_requests_total 1"));
    assert!(body.contains("fmq_server_request_latency_ns_bucket"));
    let _ = std::fs::remove_file(&path);
}

/// Satellite regression: hammer `stats` from a reader thread while load
/// runs — the queue-depth gauge must stay consistent (never negative,
/// and exactly zero once the queues drain). The old u64 wrapping-delta
/// export could transiently read as 2^64-ish garbage.
#[test]
fn queue_depth_gauge_is_consistent_under_load() {
    let (server, addr) = start_small_server();
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let reader = {
        let addr = addr.clone();
        let stop = stop.clone();
        std::thread::spawn(move || {
            let mut c = Client::connect(&addr).unwrap();
            let mut polls = 0u32;
            while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                let s = c.stats().unwrap();
                let depth = s.req("queue_depth").unwrap().as_i64().unwrap();
                assert!(depth >= 0, "queue_depth went negative: {depth}");
                polls += 1;
            }
            polls
        })
    };
    let mut writers = Vec::new();
    for i in 0..4u64 {
        let addr = addr.clone();
        writers.push(std::thread::spawn(move || {
            let mut c = Client::connect(&addr).unwrap();
            for j in 0..5 {
                // > model batch: forces slicing, so depth moves up + down
                c.generate("ot2", 20, i * 100 + j).unwrap();
            }
        }));
    }
    for w in writers {
        w.join().unwrap();
    }
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    let polls = reader.join().unwrap();
    assert!(polls > 0, "reader must have observed the gauge");
    assert_eq!(
        server.stats.queue_depth.get(),
        0,
        "drained queues must read exactly zero"
    );
    server.stop();
}

/// The stats op is integer-exact above 2^53: a byte gauge poked past the
/// f64 precision cliff round-trips the wire without rounding.
#[test]
fn stats_op_is_integer_exact_above_2_53() {
    let (server, addr) = start_small_server();
    let mut c = Client::connect(&addr).unwrap();
    // touch every variant so each worker has finished startup (workers
    // add their resident bytes once, at init) before we poke the gauge
    for model in ["fp32", "ot2", "ot8"] {
        c.generate(model, 1, 1).unwrap();
    }
    let big = (1i64 << 53) + 1;
    server.stats.resident_bytes.set(big);
    let s = c.stats().unwrap();
    assert_eq!(
        s.req("resident_bytes").unwrap().as_i64(),
        Some(big),
        "2^53+1 must survive the wire exactly"
    );
    // the old f64 wire format sat exactly on the precision cliff here:
    // the nearest representable double is 2^53, one byte short
    assert_eq!(s.req("resident_bytes").unwrap().as_f64().unwrap() as i64, big - 1);
    server.stop();
}

/// Out-of-range n is rejected explicitly (no silent clamping — the
/// exact-n contract).
#[test]
fn out_of_range_n_is_rejected() {
    let (server, addr) = start_small_server();
    let mut c = Client::connect(&addr).unwrap();
    for n in [0usize, 257] {
        let resp = c
            .call(&Json::obj(vec![
                ("op", Json::Str("generate".into())),
                ("model", Json::Str("ot2".into())),
                ("n", Json::Num(n as f64)),
            ]))
            .unwrap();
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false));
        assert!(
            resp.req_str("error").unwrap().contains("1..=256"),
            "n={n}: {resp:?}"
        );
    }
    // seeds that cannot round-trip the f64 wire format are rejected, not
    // silently aliased onto another noise stream
    for bad in [-1.0f64, 1.5, 9_007_199_254_740_992.0] {
        let resp = c
            .call(&Json::obj(vec![
                ("op", Json::Str("generate".into())),
                ("model", Json::Str("ot2".into())),
                ("n", Json::Num(1.0)),
                ("seed", Json::Num(bad)),
            ]))
            .unwrap();
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false));
        assert!(
            resp.req_str("error").unwrap().contains("seed"),
            "seed={bad}: {resp:?}"
        );
    }
    server.stop();
}

/// A request line longer than the protocol cap gets an error reply and a
/// closed connection instead of unbounded server-side buffering.
#[test]
fn oversized_request_line_is_rejected() {
    use std::io::{BufRead, BufReader, Read, Write};
    let (server, addr) = start_small_server();
    let stream = std::net::TcpStream::connect(&addr).unwrap();
    let mut r = BufReader::new(stream.try_clone().unwrap());
    let mut w = stream;
    // past the cap, no newline anywhere: the server stops reading at
    // MAX_LINE, replies, and drains the excess so the reply survives
    // (an un-drained close would RST the connection and destroy it)
    let max = fmq::coordinator::server::MAX_LINE as usize;
    let blob = vec![b'x'; max + 10_000];
    w.write_all(&blob).unwrap();
    w.flush().unwrap();
    let mut line = String::new();
    r.read_line(&mut line).unwrap();
    assert!(line.contains("\"ok\":false"), "got: {line}");
    assert!(line.contains("exceeds"), "got: {line}");
    // the server closed the connection after replying
    let mut rest = Vec::new();
    let _ = r.read_to_end(&mut rest);
    assert!(rest.is_empty());
    server.stop();
}

/// EOF from the server surfaces as a clear client error, not a JSON
/// parse failure on an empty string. Uses a scripted peer that reads the
/// request fully and then hangs up, so the client sees a clean FIN.
#[test]
fn client_reports_server_closed_connection() {
    use std::io::BufRead;
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let peer = std::thread::spawn(move || {
        let (stream, _) = listener.accept().unwrap();
        let mut r = std::io::BufReader::new(stream);
        let mut line = String::new();
        r.read_line(&mut line).unwrap(); // drain the request, reply nothing
    });
    let mut c = Client::connect(&addr).unwrap();
    let err = c
        .call(&Json::obj(vec![("op", Json::Str("ping".into()))]))
        .unwrap_err();
    assert!(
        err.to_string().contains("server closed connection"),
        "got: {err}"
    );
    peer.join().unwrap();
}

/// An explicit `--engine lut` the model cannot satisfy (9-bit codes are
/// beyond the packed-LUT range) errors per request instead of silently
/// serving through cpu-ref; `auto` on the same fleet serves correctly
/// via the reference fallback.
#[test]
fn explicit_engine_failure_surfaces_to_client() {
    let spec = small_spec();
    let theta = test_theta(&spec);
    let mk_registry = || {
        Arc::new(Registry::build_fleet(
            &spec,
            &theta,
            &[QuantMethod::Uniform],
            &[9],
        ))
    };
    let strict = serve(
        mk_registry(),
        None,
        test_config(Some(fmq::engine::EngineKind::Lut)),
    )
    .unwrap();
    let err = Client::connect(&strict.addr.to_string())
        .unwrap()
        .generate("uniform9", 1, 1)
        .unwrap_err();
    assert!(
        err.to_string().contains("engine init failed"),
        "got: {err}"
    );
    strict.stop();
    let auto = serve(mk_registry(), None, test_config(None)).unwrap();
    let imgs = Client::connect(&auto.addr.to_string())
        .unwrap()
        .generate("uniform9", 1, 1)
        .unwrap();
    assert_eq!(imgs.len(), spec.d);
    auto.stop();
}

/// Per-request deadlines over the wire: `deadline_ms: 0` is legal,
/// expires deterministically, and comes back as the typed non-retryable
/// `deadline_exceeded` reply (never a hang, never a generic timeout); a
/// generous deadline changes nothing about the bits.
#[test]
fn deadline_zero_sheds_typed_and_generous_deadline_serves_exact_bits() {
    let (server, addr) = start_small_server();
    let spec = small_spec();
    let mut c = Client::connect(&addr).unwrap();
    let resp = c
        .call(&Json::obj(vec![
            ("op", Json::Str("generate".into())),
            ("model", Json::Str("ot2".into())),
            ("n", Json::Num(1.0)),
            ("seed", Json::Num(1.0)),
            ("deadline_ms", Json::Num(0.0)),
        ]))
        .unwrap();
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false), "{resp:?}");
    assert_eq!(resp.req_str("code").unwrap(), "deadline_exceeded");
    assert_eq!(resp.get("retryable").unwrap().as_bool(), Some(false));
    assert_eq!(server.stats.error_class("deadline_exceeded").get(), 1);
    // a malformed deadline is a bad request, not a silent default
    let resp = c
        .call(&Json::obj(vec![
            ("op", Json::Str("generate".into())),
            ("model", Json::Str("ot2".into())),
            ("n", Json::Num(1.0)),
            ("seed", Json::Num(1.0)),
            ("deadline_ms", Json::Num(-5.0)),
        ]))
        .unwrap();
    assert_eq!(resp.req_str("code").unwrap(), "bad_request");
    assert!(resp.req_str("error").unwrap().contains("deadline_ms"));
    // a generous budget is invisible in the result: same bits as the
    // deadline-free determinism contract
    let got = c.generate_with_deadline("ot2", 2, 42, 60_000).unwrap();
    assert_eq!(got, expected_images(&spec, "ot2", 2, 42));
    server.stop();
}

/// Load shedding + client retry, end to end: a `queue_cap = 1` server
/// flooded by concurrent max-size requests must shed some of them with
/// the retryable `overloaded` error — and retrying clients ride out the
/// congestion, every reply still bit-identical to the offline sampler.
#[test]
fn overload_flood_sheds_and_retrying_clients_all_complete() {
    let spec = small_spec();
    let theta = test_theta(&spec);
    let registry = Arc::new(Registry::build_fleet(
        &spec,
        &theta,
        &[QuantMethod::Ot],
        &[2],
    ));
    let cfg = ServerConfig {
        queue_cap: 1,
        ..test_config(None)
    };
    let server = serve(registry, None, cfg).expect("server start");
    let addr = server.addr.to_string();
    // max-size requests keep the single ot2 worker busy long enough that
    // the cap-1 queue must turn try_send away (the flood is concurrent)
    let (n, seed) = (256usize, 7u64);
    let want = expected_images(&spec, "ot2", n, seed);
    let mut handles = Vec::new();
    for _ in 0..6 {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || {
            // generous retry budget: a debug-build flood can keep the
            // cap-1 queue congested for whole seconds on slow CI hosts
            let policy = RetryPolicy {
                max_retries: 16,
                base: Duration::from_millis(20),
                cap: Duration::from_millis(250),
                seed: 11,
            };
            Client::connect(&addr)
                .unwrap()
                .generate_with_retry("ot2", n, seed, policy)
                .unwrap()
        }));
    }
    for h in handles {
        assert_eq!(
            h.join().unwrap(),
            want,
            "a retried request must return the same bits as an unshed one"
        );
    }
    assert!(
        server.stats.shed.get() >= 1,
        "a cap-1 queue under a 6-way flood must shed at least once"
    );
    server.stop();
}

/// The `shutdown` op begins a graceful drain: new generation is refused
/// with the terminal `shutting_down` error, but observability (`ping`,
/// `stats`) stays reachable for the whole drain window, and `stop()`
/// completes cleanly via the drain-idle worker exit.
#[test]
fn drain_refuses_new_work_but_keeps_ops_reachable() {
    let (server, addr) = start_small_server();
    let mut c = Client::connect(&addr).unwrap();
    c.generate("ot2", 1, 3).unwrap();
    let resp = c
        .call(&Json::obj(vec![("op", Json::Str("shutdown".into()))]))
        .unwrap();
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
    // admission is now gated, with the non-retryable terminal class
    let resp = c
        .call(&Json::obj(vec![
            ("op", Json::Str("generate".into())),
            ("model", Json::Str("ot2".into())),
            ("n", Json::Num(1.0)),
            ("seed", Json::Num(4.0)),
        ]))
        .unwrap();
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false));
    assert_eq!(resp.req_str("code").unwrap(), "shutting_down");
    assert_eq!(resp.get("retryable").unwrap().as_bool(), Some(false));
    assert!(resp.req_str("error").unwrap().contains("draining"));
    // a second drain request is an idempotent no-op
    let again = c
        .call(&Json::obj(vec![("op", Json::Str("shutdown".into()))]))
        .unwrap();
    assert_eq!(again.get("ok").unwrap().as_bool(), Some(true));
    // operators can still watch the drain: ping + stats keep serving
    let pong = c.call(&Json::obj(vec![("op", Json::Str("ping".into()))])).unwrap();
    assert_eq!(pong.get("ok").unwrap().as_bool(), Some(true));
    let s = c.stats().unwrap();
    assert!(s.req("requests").unwrap().as_u64().unwrap() >= 1);
    assert_eq!(s.req("errors").unwrap().as_u64(), Some(1));
    // workers exit through the drain-idle path; stop() must not hang
    server.stop();
}
