//! Artifact-free pipeline integration: quantize → sample → metrics across
//! modules, checkpoint I/O through real files, and the Fig. 3/4 orderings
//! the paper reports, all on the CPU reference backend.

use fmq::coordinator::experiment::{pseudo_trained_theta, EvalContext};
use fmq::data::Dataset;
use fmq::metrics::features::FeatureNet;
use fmq::metrics::fid::fid_images;
use fmq::model::checkpoint;
use fmq::model::spec::ModelSpec;
use fmq::quant::{quantize_model, QuantMethod};

fn ctx(spec: &ModelSpec) -> EvalContext<'static> {
    EvalContext {
        spec: spec.clone(),
        art: None,
        steps: 6,
        n: 8,
        seed: 3,
        engine: None,
    }
}

/// Fig. 3 ordering on one dataset: SSIM and PSNR rise with bit-width for
/// every method, and OT dominates the baselines at 2–3 bits.
#[test]
fn fig3_orderings_cpu() {
    let spec = ModelSpec::default_spec();
    let c = ctx(&spec);
    let theta = pseudo_trained_theta(&spec, Dataset::SynthCeleba);
    let x0 = c.start_noise();
    let reference = c.generate_fp32(&theta, &x0).unwrap();

    let mut ssim_at = |m: QuantMethod, b: u8| {
        let p = c
            .fidelity_point(Dataset::SynthCeleba, &theta, &reference, &x0, m, b)
            .unwrap();
        (p.ssim, p.psnr)
    };

    // bit-monotonicity per method (2 vs 8)
    for m in QuantMethod::PAPER {
        let (s2, p2) = ssim_at(m, 2);
        let (s8, p8) = ssim_at(m, 8);
        assert!(s8 >= s2 - 1e-6, "{m:?}: ssim8 {s8} < ssim2 {s2}");
        assert!(p8 >= p2 - 1e-6, "{m:?}: psnr8 {p8} < psnr2 {p2}");
    }
    // the paper's headline: OT >= the baselines at 2 and 3 bits. On these
    // *untrained* pseudo weights PWL (quantile-cored) is the closest
    // competitor and can land within noise of OT, matching the paper's
    // "modest but consistent" framing — so PWL gets a wider slack; the
    // decisive margins vs uniform/log2 are asserted tightly. The trained-
    // model margins are measured in examples/e2e_pipeline.rs.
    for b in [2u8, 3] {
        let (s_ot, p_ot) = ssim_at(QuantMethod::Ot, b);
        for m in [QuantMethod::Uniform, QuantMethod::Log2] {
            let (s_m, p_m) = ssim_at(m, b);
            assert!(
                s_ot >= s_m - 0.02,
                "b={b}: OT ssim {s_ot} << {m:?} {s_m}"
            );
            assert!(p_ot >= p_m - 1.0, "b={b}: OT psnr {p_ot} << {m:?} {p_m}");
        }
        let (s_pwl, _) = ssim_at(QuantMethod::Pwl, b);
        assert!(
            s_ot >= s_pwl - 0.06,
            "b={b}: OT ssim {s_ot} far below PWL {s_pwl}"
        );
    }
}

/// Fig. 4 ordering: OT latent var-std at 2 bits stays no worse than log2
/// (the "variance explosion" direction), and 8-bit OT tracks the baseline.
#[test]
fn fig4_latent_stability_cpu() {
    let spec = ModelSpec::default_spec();
    let c = ctx(&spec);
    let theta = pseudo_trained_theta(&spec, Dataset::SynthCifar);
    let ot = c
        .latent_point(Dataset::SynthCifar, &theta, QuantMethod::Ot, 2)
        .unwrap();
    let lg = c
        .latent_point(Dataset::SynthCifar, &theta, QuantMethod::Log2, 2)
        .unwrap();
    // untrained pseudo weights keep both dispersions small; assert OT is
    // not materially worse (the decisive trained-model gap is measured in
    // the e2e example and the fig4 bench).
    assert!(
        ot.stats.var_std <= lg.stats.var_std + 0.05,
        "OT var_std {} should be <= log2 {} (+slack)",
        ot.stats.var_std,
        lg.stats.var_std
    );
    let ot8 = c
        .latent_point(Dataset::SynthCifar, &theta, QuantMethod::Ot, 8)
        .unwrap();
    let drift = (ot8.stats.var_std - ot8.baseline_var_std).abs();
    assert!(
        drift <= 0.1 * (1.0 + ot8.baseline_var_std),
        "8-bit OT latent drift {drift}"
    );
}

/// FID of quantized samples vs fp32 samples falls as bits rise (the
/// Theorem 3/6 direction, measured with our Lipschitz feature net).
#[test]
fn fid_decreases_with_bits() {
    let spec = ModelSpec::default_spec();
    let mut c = ctx(&spec);
    c.n = 16;
    let theta = pseudo_trained_theta(&spec, Dataset::SynthImagenet);
    let x0 = c.start_noise();
    let reference = c.generate_fp32(&theta, &x0).unwrap();
    let net = FeatureNet::standard(spec.d);
    let fid_at = |b: u8| {
        let qm = quantize_model(&spec, &theta, QuantMethod::Ot, b);
        let imgs = c.generate_quant(&qm, &x0).unwrap();
        fid_images(&net, &reference, &imgs)
    };
    let f2 = fid_at(2);
    let f8 = fid_at(8);
    assert!(f8 < f2, "fid8 {f8} !< fid2 {f2}");
}

/// End-to-end checkpoint round trip: quantize -> save -> load -> identical
/// generation.
#[test]
fn checkpoint_roundtrip_preserves_generation() {
    let spec = ModelSpec::default_spec();
    let c = ctx(&spec);
    let theta = pseudo_trained_theta(&spec, Dataset::SynthMnist);
    let dir = std::env::temp_dir().join("fmq-pipeline-test");
    std::fs::create_dir_all(&dir).unwrap();

    let qm = quantize_model(&spec, &theta, QuantMethod::Ot, 3);
    let qpath = dir.join("m.ot3");
    checkpoint::save_quantized(&qpath, &qm).unwrap();
    let qm2 = checkpoint::load_quantized(&qpath, &spec).unwrap();

    let x0 = c.start_noise();
    let a = c.generate_quant(&qm, &x0).unwrap();
    let b = c.generate_quant(&qm2, &x0).unwrap();
    assert_eq!(a, b, "generation changed across checkpoint roundtrip");
}

/// W₂ weight error tracks generation error across methods at fixed bits —
/// the causal chain the paper's theory formalizes.
#[test]
fn weight_error_predicts_generation_error() {
    let spec = ModelSpec::default_spec();
    let c = ctx(&spec);
    let theta = pseudo_trained_theta(&spec, Dataset::SynthFashion);
    let x0 = c.start_noise();
    let reference = c.generate_fp32(&theta, &x0).unwrap();
    let mut pairs = Vec::new();
    for m in QuantMethod::PAPER {
        let p = c
            .fidelity_point(Dataset::SynthFashion, &theta, &reference, &x0, m, 3)
            .unwrap();
        pairs.push((p.w2_sq, p.psnr));
    }
    pairs.sort_by(|a, b| a.0.total_cmp(&b.0));
    let best = pairs.first().unwrap();
    let worst = pairs.last().unwrap();
    assert!(
        best.1 >= worst.1 - 0.5,
        "lowest-W2 method should not have materially worse PSNR: {pairs:?}"
    );
}

/// Latent encode of the fp32 model approximately inverts generation (ODE
/// consistency through the whole EvalContext plumbing; the [-1,1] clamp at
/// the end of generation makes this approximate).
#[test]
fn encode_inverts_generate_cpu() {
    let spec = ModelSpec::default_spec();
    let mut c = ctx(&spec);
    c.steps = 48;
    c.n = 2;
    let theta = pseudo_trained_theta(&spec, Dataset::SynthMnist);
    let x0 = c.start_noise();
    let imgs = c.generate_fp32(&theta, &x0).unwrap();
    let lat = c.encode_fp32(&theta, &imgs).unwrap();
    let mut err = 0.0f64;
    for (a, b) in x0.iter().zip(lat.iter()) {
        err += ((a - b) as f64).powi(2);
    }
    let rmse = (err / x0.len() as f64).sqrt();
    // error budget: Euler discretization + the [-1,1] clamp between passes
    assert!(rmse < 0.5, "encode(generate(x0)) rmse {rmse}");
}
