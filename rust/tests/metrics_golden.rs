//! Golden-value tests for the `metrics/` stack: closed-form answers the
//! implementations must reproduce exactly (up to float roundoff), so a
//! refactor of any metric shows up as a hard diff rather than a drifting
//! benchmark number.

use fmq::data::{Dataset, IMG_D};
use fmq::metrics::coverage::{coverage, Templates};
use fmq::metrics::features::FeatureNet;
use fmq::metrics::fid::fid_images;
use fmq::metrics::psnr::{batch_psnr, psnr};
use fmq::metrics::ssim::{batch_ssim, ssim};
use fmq::util::rng::Pcg64;

fn sample_batch(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg64::seed(seed);
    Dataset::SynthCifar.batch(&mut rng, n)
}

#[test]
fn psnr_identical_images_is_infinite_and_batch_clamps_to_99() {
    let imgs = sample_batch(3, 11);
    assert!(psnr(&imgs[..IMG_D], &imgs[..IMG_D]).is_infinite());
    // batch mean caps per-image infinities at 99 dB so means stay finite
    let b = batch_psnr(&imgs, &imgs, IMG_D);
    assert!((b - 99.0).abs() < 1e-12, "batch psnr {b}");
}

#[test]
fn psnr_uniform_shift_matches_closed_form() {
    // constant shift s: mse = s^2, peak 2 -> psnr = 10 log10(4 / s^2)
    let a = vec![0.1f32; IMG_D];
    for s in [0.2f64, 0.05, 0.5] {
        let b: Vec<f32> = a.iter().map(|&x| x + s as f32).collect();
        let expected = 10.0 * (4.0 / (s * s)).log10();
        let got = psnr(&a, &b);
        assert!(
            (got - expected).abs() < 1e-3,
            "shift {s}: psnr {got} vs closed form {expected}"
        );
    }
    // the textbook value: s = 0.2 -> 20 dB
    let b: Vec<f32> = a.iter().map(|&x| x + 0.2).collect();
    assert!((psnr(&a, &b) - 20.0).abs() < 1e-3);
}

#[test]
fn ssim_identical_images_is_one() {
    let imgs = sample_batch(2, 17);
    let s = ssim(&imgs[..IMG_D], &imgs[..IMG_D]);
    assert!((s - 1.0).abs() < 1e-9, "ssim(a, a) = {s}");
    let bs = batch_ssim(&imgs, &imgs, IMG_D);
    assert!((bs - 1.0).abs() < 1e-9, "batch ssim {bs}");
}

#[test]
fn ssim_degrades_under_noise_but_stays_in_range() {
    let imgs = sample_batch(1, 23);
    let mut rng = Pcg64::seed(29);
    let noisy: Vec<f32> = imgs.iter().map(|&x| x + rng.normal_f32(0.0, 0.3)).collect();
    let s = ssim(&imgs, &noisy);
    assert!(s < 0.999, "noise must cost similarity: {s}");
    assert!((-1.0..=1.0).contains(&s), "ssim out of range: {s}");
}

#[test]
fn fid_of_a_distribution_with_itself_is_zero() {
    let net = FeatureNet::standard(IMG_D);
    let imgs = sample_batch(16, 31);
    let d = fid_images(&net, &imgs, &imgs);
    assert!(d.abs() < 1e-6, "fid(a, a) = {d}");
    // and strictly positive between different datasets
    let mut rng = Pcg64::seed(37);
    let other = Dataset::SynthMnist.batch(&mut rng, 16);
    let d2 = fid_images(&net, &imgs, &other);
    assert!(d2 > d + 1e-6, "fid must separate distributions: {d2}");
}

#[test]
fn coverage_of_the_template_set_itself_is_total() {
    let mut rng = Pcg64::seed(41);
    let templates = Templates::build(Dataset::SynthMnist, &mut rng, 64, 4);
    // the templates, offered as a batch, each hit their own mode
    let cov = coverage(&templates, &templates.means);
    assert!((cov.covered - 1.0).abs() < 1e-12, "covered = {}", cov.covered);
    assert!(cov.entropy > 0.99, "uniform histogram entropy = {}", cov.entropy);
    // a collapsed batch (one template repeated) covers exactly 1/k
    let one: Vec<f32> = templates.means[..IMG_D].repeat(8);
    let collapsed = coverage(&templates, &one);
    let expect = 1.0 / templates.k as f64;
    assert!(
        (collapsed.covered - expect).abs() < 1e-12,
        "collapsed covered {} vs {expect}",
        collapsed.covered
    );
}
