//! Paper-grid conformance: run a trimmed smoke grid end to end through
//! the sweep runner and assert the four invariant families the paper's
//! figures encode (see `sweep::conformance`):
//!
//!  1. degradation is monotone in fewer bits for every method,
//!  2. OT is no worse than uniform/log2 (5%) at 2–3 bits on every rung,
//!     with an order-of-magnitude guard against the quantile-cored pwl,
//!  3. measured errors sit under their theory bounds (closed-form Δ_U
//!     for uniform; measured-constant Grönwall for the trajectories),
//!  4. the primary (lut2) and check (cpu-ref) engines agree per cell.
//!
//! The grid here is the CI smoke tier with the per-cell sample counts
//! cut further so the debug-profile test run stays in budget; the CI
//! release binary runs the full [`GridSpec::smoke`] tier and the
//! offline `figgrid` run covers [`GridSpec::full`].

use fmq::data::Dataset;
use fmq::flow::ode::Solver;
use fmq::quant::QuantMethod;
use fmq::sweep::{cell_key, conformance, run_grid, GridSpec};

fn test_spec() -> GridSpec {
    GridSpec {
        n: 2,
        batch: 2,
        steps: 3,
        coverage_samples: 32,
        coverage_iters: 2,
        lipschitz_probes: 2,
        ..GridSpec::smoke()
    }
}

#[test]
fn smoke_grid_satisfies_all_conformance_invariants() {
    let spec = test_spec();
    let res = run_grid(&spec).expect("sweep runs");

    // every cell the spec names is present, exactly once
    assert_eq!(res.cells.len(), spec.cells());
    let mut keys: Vec<String> = res.cells.iter().map(|c| c.key()).collect();
    keys.sort();
    keys.dedup();
    assert_eq!(keys.len(), spec.cells(), "duplicate cell keys");

    // the four invariant families
    let violations = conformance::check(&res);
    assert!(
        violations.is_empty(),
        "conformance violations:\n{}",
        violations.join("\n")
    );

    // spot-check the families directly (belt to conformance's braces)
    for d in &res.datasets {
        assert!(d.l_x_hat.is_finite() && d.l_x_hat > 0.0);
    }
    for &ds in &spec.datasets {
        for &method in &spec.methods {
            for &solver in &spec.solvers {
                let lo = res.cell(ds, method, 2, solver).expect("b2 cell");
                let hi = res.cell(ds, method, 8, solver).expect("b8 cell");
                // (1) monotone degradation, both in weight space and
                // end-to-end
                assert!(
                    hi.w2_sq <= lo.w2_sq * 1.01 + 1e-12,
                    "{}: w2 {} !<= {}",
                    hi.key(),
                    hi.w2_sq,
                    lo.w2_sq
                );
                assert!(
                    hi.ssim + 0.02 >= lo.ssim,
                    "{}: ssim {} < b2 {}",
                    hi.key(),
                    hi.ssim,
                    lo.ssim
                );
            }
        }
        // (2) OT no worse than the baselines at the low bit-widths.
        // Strict (5%) against uniform/log2; the quantile-cored pwl is
        // MSE-competitive with equal-mass OT (which optimizes the W₂
        // coupling, not MSE), so only an order-of-magnitude guard holds
        // there — mirroring `sweep::conformance`.
        for bits in [2u8, 3] {
            let ot = res
                .cell(ds, QuantMethod::Ot, bits, Solver::Euler)
                .expect("ot cell");
            for (base, slack) in [
                (QuantMethod::Uniform, 1.05),
                (QuantMethod::Pwl, 2.5),
                (QuantMethod::Log2, 1.05),
            ] {
                let bc = res.cell(ds, base, bits, Solver::Euler).expect("base cell");
                assert!(
                    ot.w2_sq <= bc.w2_sq * slack,
                    "{}: OT w2 {} above {} w2 {}",
                    ot.key(),
                    ot.w2_sq,
                    bc.key(),
                    bc.w2_sq
                );
            }
        }
    }
    for c in &res.cells {
        // (3) theory bounds
        if c.method == QuantMethod::Uniform {
            assert!(c.w2_sq <= c.w2_uniform_bound * 1.05 + 1e-12, "{}", c.key());
            assert!(c.sup_err <= c.sup_uniform_bound * 1.05 + 1e-12, "{}", c.key());
        }
        if c.solver == Solver::Euler && c.traj_dev.is_finite() && c.traj_bound.is_finite() {
            assert!(
                c.traj_dev <= c.traj_bound * 1.05 + 1e-6,
                "{}: traj {} above bound {}",
                c.key(),
                c.traj_dev,
                c.traj_bound
            );
        }
        // (4) engine equivalence (fixed-step solvers; dopri5's adaptive
        // control flow may fork on sub-tolerance velocity differences)
        assert!(c.engine_dev.is_finite(), "{}", c.key());
        if c.solver != Solver::Dopri5 {
            assert!(c.engine_dev <= 5e-3, "{}: engine_dev {}", c.key(), c.engine_dev);
        }
        // cost fields populated
        assert!(c.evals > 0 && c.gen_seconds > 0.0 && c.per_eval_us > 0.0, "{}", c.key());
    }

    // heun costs two evaluations per step, euler one — recorded per cell
    let e = res
        .cell(Dataset::SynthMnist, QuantMethod::Ot, 8, Solver::Euler)
        .expect("euler cell");
    let h = res
        .cell(Dataset::SynthMnist, QuantMethod::Ot, 8, Solver::Heun)
        .expect("heun cell");
    assert_eq!(h.evals, 2 * e.evals, "heun evals vs euler");

    // JSON lands with the expected cell keys and fields
    let path = std::env::temp_dir().join(format!("fmq_figgrid_{}.json", std::process::id()));
    let text = res.write_json(&path).expect("json writes");
    for (ds, m, b, s) in [
        (Dataset::SynthMnist, QuantMethod::Ot, 2, Solver::Euler),
        (Dataset::SynthImagenet, QuantMethod::Log2, 8, Solver::Dopri5),
    ] {
        let key = cell_key(ds, m, b, s);
        assert!(text.contains(&format!("\"{key}\"")), "missing {key} in JSON");
    }
    for field in ["traj_bound", "ssim", "psnr", "fid", "per_step_us", "engine_dev"] {
        assert!(text.contains(&format!("\"{field}\"")), "missing field {field}");
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn sweep_is_deterministic_for_a_fixed_spec() {
    // one rung, fixed-step solvers: the whole pipeline is seeded, so a
    // re-run must reproduce every measurement bit for bit
    let spec = GridSpec {
        datasets: vec![Dataset::SynthCifar],
        methods: vec![QuantMethod::Ot, QuantMethod::Uniform],
        bits: vec![2, 8],
        solvers: vec![Solver::Euler, Solver::Heun],
        ..test_spec()
    };
    let a = run_grid(&spec).expect("first run");
    let b = run_grid(&spec).expect("second run");
    assert_eq!(a.cells.len(), b.cells.len());
    for (ca, cb) in a.cells.iter().zip(b.cells.iter()) {
        assert_eq!(ca.key(), cb.key());
        assert_eq!(ca.ssim.to_bits(), cb.ssim.to_bits(), "{}", ca.key());
        assert_eq!(ca.psnr.to_bits(), cb.psnr.to_bits(), "{}", ca.key());
        assert_eq!(ca.w2_sq.to_bits(), cb.w2_sq.to_bits(), "{}", ca.key());
        assert_eq!(ca.traj_dev.to_bits(), cb.traj_dev.to_bits(), "{}", ca.key());
        assert_eq!(ca.engine_dev.to_bits(), cb.engine_dev.to_bits(), "{}", ca.key());
        assert_eq!(ca.evals, cb.evals, "{}", ca.key());
    }
}
