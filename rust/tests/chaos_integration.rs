//! Chaos harness: seeded fault schedules against a live server.
//!
//! Only meaningful with the `faults` cargo feature (the CI `chaos` job
//! runs `cargo test --features faults`); without it the plan compiles to
//! an inert ZST and these tests vanish.
//!
//! The contract under test is the robustness tentpole: with a plan that
//! panics one worker, slows another, and drops one connection,
//! *unaffected* requests still return bit-identical replies, the
//! panicked model serves again after the supervisor respawns it, and
//! every failure is a typed wire error — never a hang, never changed
//! bits. See `docs/ROBUSTNESS.md` for the fault matrix.

#![cfg(feature = "faults")]

use std::sync::Arc;
use std::time::Duration;

use fmq::coordinator::registry::Registry;
use fmq::coordinator::server::{serve, Client, RetryPolicy, Server, ServerConfig};
use fmq::faults::FaultPlan;
use fmq::model::spec::{Layer, ModelSpec};
use fmq::quant::QuantMethod;
use fmq::util::json::Json;
use fmq::util::rng::Pcg64;

const STEPS: usize = 2;

/// Same tiny architecture as server_integration: full layer-table shape,
/// fast in debug builds.
fn small_spec() -> ModelSpec {
    let (d, hidden, temb_freqs, blocks) = (24usize, 32usize, 4usize, 2usize);
    let mut layers = Vec::new();
    let mut off = 0usize;
    let mut add = |layers: &mut Vec<Layer>, name: &str, shape: Vec<usize>| {
        let l = Layer {
            name: name.to_string(),
            shape,
            offset: off,
        };
        off += l.size();
        layers.push(l);
    };
    add(&mut layers, "w_in", vec![d, hidden]);
    add(&mut layers, "b_in", vec![hidden]);
    add(&mut layers, "w_t", vec![2 * temb_freqs, hidden]);
    add(&mut layers, "b_t", vec![hidden]);
    for i in 0..blocks {
        add(&mut layers, &format!("w1_{i}"), vec![hidden, hidden]);
        add(&mut layers, &format!("b1_{i}"), vec![hidden]);
        add(&mut layers, &format!("w2_{i}"), vec![hidden, hidden]);
        add(&mut layers, &format!("b2_{i}"), vec![hidden]);
    }
    add(&mut layers, "w_out", vec![hidden, d]);
    add(&mut layers, "b_out", vec![d]);
    ModelSpec {
        layers,
        d,
        hidden,
        blocks,
        temb_freqs,
        k_max: 256,
        freq_max: 1000.0,
    }
}

fn start_server(plan: &str, queue_cap: usize) -> (Server, String) {
    let spec = small_spec();
    let theta = spec.init_theta(&mut Pcg64::seed(5));
    let registry = Arc::new(Registry::build_fleet(
        &spec,
        &theta,
        &[QuantMethod::Ot],
        &[2, 8],
    ));
    let cfg = ServerConfig {
        addr: "127.0.0.1:0".to_string(),
        steps: STEPS,
        linger: Duration::from_millis(3),
        queue_cap,
        faults: Arc::new(FaultPlan::parse(plan).expect("valid plan")),
        ..Default::default()
    };
    let server = serve(registry, None, cfg).expect("server start");
    let addr = server.addr.to_string();
    (server, addr)
}

/// Fast retry schedule so chaos tests do not sleep for real-world spans.
fn quick_retry() -> RetryPolicy {
    RetryPolicy {
        max_retries: 6,
        base: Duration::from_millis(20),
        cap: Duration::from_millis(200),
        seed: 7,
    }
}

/// The headline chaos run: one seeded plan panics the second ot2 batch,
/// slows the first ot8 batch, and the schedule is fixed — yet every
/// reply, on every model, is bit-identical to the same requests against
/// a fault-free server. The panicked model keeps serving (respawn), the
/// panic surfaces only as a retryable typed error, and nothing hangs.
#[test]
fn seeded_fault_schedule_preserves_reply_bits() {
    // baseline bits from an undisturbed server
    let requests: &[(&str, usize, u64)] = &[
        ("ot2", 3, 11),
        ("ot2", 2, 12),
        ("ot2", 1, 13),
        ("ot8", 2, 21),
        ("fp32", 1, 31),
    ];
    let (clean, clean_addr) = start_server("", 64);
    let mut c = Client::connect(&clean_addr).unwrap();
    let baseline: Vec<Vec<f32>> = requests
        .iter()
        .map(|&(m, n, s)| c.generate(m, n, s).unwrap())
        .collect();
    clean.stop();

    let (server, addr) = start_server("panic@batch/ot2:2,slow@batch/ot8:1:25ms,seed=7", 64);
    let mut c = Client::connect(&addr).unwrap();
    for (i, &(m, n, s)) in requests.iter().enumerate() {
        // sequential requests, one batch each: the 2nd ot2 batch panics;
        // the retry goes through the respawned worker
        let got = c.generate_with_retry(m, n, s, quick_retry()).unwrap();
        assert_eq!(
            got, baseline[i],
            "{m} n={n} seed={s}: bits changed under the fault schedule"
        );
    }
    assert_eq!(
        server.stats.worker_respawns.get(),
        1,
        "exactly one injected panic -> exactly one respawn"
    );
    assert!(
        server.stats.errors.get() >= 1,
        "the panicked batch must surface as a typed error"
    );
    // the panicked model serves post-respawn without retries needed
    let again = c.generate("ot2", 3, 11).unwrap();
    assert_eq!(again, baseline[0]);
    server.stop();
}

/// An injected panic fails only the in-flight batch with the retryable
/// `worker_panic` class; a plain (no-retry) client sees the typed error,
/// and the per-class counter moves with it.
#[test]
fn worker_panic_is_typed_and_retryable_on_the_wire() {
    let (server, addr) = start_server("panic@batch/ot2:1", 64);
    let mut c = Client::connect(&addr).unwrap();
    let resp = c
        .call(&Json::obj(vec![
            ("op", Json::Str("generate".into())),
            ("model", Json::Str("ot2".into())),
            ("n", Json::Num(1.0)),
            ("seed", Json::Num(1.0)),
        ]))
        .unwrap();
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false));
    assert_eq!(resp.req_str("code").unwrap(), "worker_panic");
    assert_eq!(resp.get("retryable").unwrap().as_bool(), Some(true));
    assert!(resp.req_str("error").unwrap().contains("panicked"));
    // same connection, plain retry: the respawned worker serves it
    let imgs = c.generate("ot2", 1, 1).unwrap();
    assert_eq!(imgs.len(), small_spec().d);
    assert_eq!(server.stats.worker_respawns.get(), 1);
    assert_eq!(server.stats.error_class("worker_panic").get(), 1);
    server.stop();
}

/// A dropped connection (injected before the reply write) kills exactly
/// one client; the server counts one conn drop + one error, and other
/// connections are untouched.
#[test]
fn injected_connection_drop_counts_once_and_isolates() {
    let (server, addr) = start_server("drop@reply:2", 64);
    // reply 1: fine
    let a = Client::connect(&addr).unwrap().generate("ot8", 1, 5).unwrap();
    // reply 2: the server severs the socket before writing
    let err = Client::connect(&addr)
        .unwrap()
        .generate("ot8", 1, 6)
        .unwrap_err();
    assert!(
        err.to_string().contains("server closed connection")
            || err.to_string().contains("Connection reset")
            || err.to_string().contains("os error"),
        "got: {err}"
    );
    // reply 3 on a fresh connection: unaffected, and deterministic
    let b = Client::connect(&addr).unwrap().generate("ot8", 1, 5).unwrap();
    assert_eq!(a, b, "a dropped sibling connection must not change bits");
    assert_eq!(server.stats.conn_drops.get(), 1, "one injected drop");
    assert_eq!(
        server.stats.errors.get(),
        1,
        "the undeliverable success counts exactly one error"
    );
    assert_eq!(server.stats.error_class("internal").get(), 1);
    server.stop();
}

/// Load shedding under a slowed worker: with a queue bound of 1 and the
/// first ot2 batch sleeping, a burst overfills the queue and the
/// overflow is shed with the typed `overloaded` reply + retry hint —
/// and a retrying client still completes every request.
#[test]
fn slowed_worker_sheds_overflow_with_typed_overloaded() {
    let (server, addr) = start_server("slow@batch/ot2:1:300ms", 1);
    // occupy the worker: this request's batch sleeps 300ms
    let first = {
        let addr = addr.clone();
        std::thread::spawn(move || Client::connect(&addr).unwrap().generate("ot2", 1, 1).unwrap())
    };
    std::thread::sleep(Duration::from_millis(80));
    // worker is inside the slow batch; this one parks in the queue (cap 1)
    let second = {
        let addr = addr.clone();
        std::thread::spawn(move || Client::connect(&addr).unwrap().generate("ot2", 1, 2).unwrap())
    };
    std::thread::sleep(Duration::from_millis(80));
    // queue is full now: a plain call is shed with the typed reply
    let resp = Client::connect(&addr)
        .unwrap()
        .call(&Json::obj(vec![
            ("op", Json::Str("generate".into())),
            ("model", Json::Str("ot2".into())),
            ("n", Json::Num(1.0)),
            ("seed", Json::Num(3.0)),
        ]))
        .unwrap();
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false), "{resp:?}");
    assert_eq!(resp.req_str("code").unwrap(), "overloaded");
    assert_eq!(resp.get("retryable").unwrap().as_bool(), Some(true));
    assert!(resp.get("retry_after_ms").unwrap().as_u64().unwrap() >= 1);
    // a retrying client rides out the congestion
    let imgs = Client::connect(&addr)
        .unwrap()
        .generate_with_retry("ot2", 1, 4, quick_retry())
        .unwrap();
    assert_eq!(imgs.len(), small_spec().d);
    first.join().unwrap();
    second.join().unwrap();
    assert!(server.stats.shed.get() >= 1, "at least one shed");
    server.stop();
}

/// Graceful drain with work in flight: `stop()` lets a request admitted
/// just before the drain finish (reply delivered, not `shutting_down`),
/// while admission after the drain begins is refused with the typed
/// terminal error.
#[test]
fn drain_flushes_inflight_and_refuses_new_work() {
    let (server, addr) = start_server("slow@batch/ot2:1:200ms", 64);
    // in-flight: its batch sleeps 200ms, so it straddles the drain
    let inflight = {
        let addr = addr.clone();
        std::thread::spawn(move || Client::connect(&addr).unwrap().generate("ot2", 1, 9))
    };
    std::thread::sleep(Duration::from_millis(60));
    let mut late = Client::connect(&addr).unwrap();
    // begin the drain via the wire op (what operators use)
    late.call(&Json::obj(vec![("op", Json::Str("shutdown".into()))]))
        .unwrap();
    let resp = late
        .call(&Json::obj(vec![
            ("op", Json::Str("generate".into())),
            ("model", Json::Str("ot2".into())),
            ("n", Json::Num(1.0)),
            ("seed", Json::Num(1.0)),
        ]))
        .unwrap();
    assert_eq!(resp.req_str("code").unwrap(), "shutting_down");
    assert_eq!(resp.get("retryable").unwrap().as_bool(), Some(false));
    // the in-flight request drains to a real reply
    let imgs = inflight.join().unwrap().expect("in-flight must flush");
    assert_eq!(imgs.len(), small_spec().d);
    server.stop();
}
