//! Quantized model container: per-tensor codebooks + code indices + fp32
//! biases — exactly the inputs the `qsample_step` artifact takes.

use anyhow::Result;

use crate::model::params::ParamStore;
use crate::model::spec::ModelSpec;
use crate::quant::codebook::Codebook;
use crate::quant::error::{aggregate, tensor_error, QuantError};
use crate::quant::packing::PackedCodes;
use crate::quant::QuantMethod;

#[derive(Clone, Debug)]
pub struct QuantizedModel {
    pub spec: ModelSpec,
    pub method: QuantMethod,
    pub bits: u8,
    /// One codebook per weight layer, ordered as `spec.weight_layers()`.
    pub codebooks: Vec<Codebook>,
    /// Codes for all weight layers, packed contiguously (len = spec.pw()).
    pub codes: Vec<u32>,
    /// Biases packed contiguously (len = spec.pb()), full precision.
    pub biases: Vec<f32>,
}

impl QuantizedModel {
    pub fn new(
        spec: ModelSpec,
        method: QuantMethod,
        bits: u8,
        codebooks: Vec<Codebook>,
        codes: Vec<u32>,
        biases: Vec<f32>,
    ) -> Self {
        assert_eq!(codes.len(), spec.pw());
        assert_eq!(biases.len(), spec.pb());
        assert_eq!(codebooks.len(), spec.weight_layers().len());
        Self {
            spec,
            method,
            bits,
            codebooks,
            codes,
            biases,
        }
    }

    pub fn from_packed(
        spec: ModelSpec,
        method: QuantMethod,
        bits: u8,
        codebooks: Vec<Codebook>,
        packed: PackedCodes,
        biases: Vec<f32>,
    ) -> Result<Self> {
        Ok(Self::new(spec, method, bits, codebooks, packed.unpack(), biases))
    }

    /// Pack codes at the native bit-width for storage.
    pub fn pack_codes(&self) -> Result<PackedCodes> {
        // codes may exceed 2^bits only if a codebook deduped below K; the
        // index space is still within 2^bits by construction.
        PackedCodes::pack(&self.codes, self.bits.max(1))
    }

    /// Dequantize back to a full flat theta (biases verbatim).
    pub fn dequantize(&self) -> ParamStore {
        let mut theta = vec![0f32; self.spec.p()];
        for (row, l) in self.spec.weight_layers().iter().enumerate() {
            let cb = &self.codebooks[row];
            let woff = self.spec.weight_offset(&l.name);
            for i in 0..l.size() {
                theta[l.offset + i] = cb.levels[self.codes[woff + i] as usize];
            }
        }
        for l in self.spec.bias_layers() {
            let boff = self.spec.bias_offset(&l.name);
            theta[l.offset..l.offset + l.size()]
                .copy_from_slice(&self.biases[boff..boff + l.size()]);
        }
        ParamStore::new(theta)
    }

    /// Shared execution-adapter setup: every backend that outlives this
    /// container (the packed `LutModel`, the HLO step backends) starts
    /// from a private copy of the architecture and the fp32 biases.
    /// One helper so the copies cannot drift apart per adapter.
    pub fn adapter_base(&self) -> (ModelSpec, Vec<f32>) {
        (self.spec.clone(), self.biases.clone())
    }

    /// Codes as i32 for the artifact input.
    pub fn codes_i32(&self) -> Vec<i32> {
        self.codes.iter().map(|&c| c as i32).collect()
    }

    /// Codebooks padded to [n_weights, k_max] row-major for the artifact.
    pub fn codebooks_padded(&self) -> Vec<f32> {
        let k = self.spec.k_max;
        let mut out = Vec::with_capacity(self.codebooks.len() * k);
        for cb in &self.codebooks {
            out.extend_from_slice(&cb.padded_levels(k));
        }
        out
    }

    /// Per-layer W₂ errors against the original theta.
    pub fn layer_errors(&self, theta: &ParamStore) -> Vec<(String, QuantError)> {
        self.spec
            .weight_layers()
            .iter()
            .enumerate()
            .map(|(row, l)| {
                let w = theta.layer(&self.spec, &l.name);
                (l.name.clone(), tensor_error(w, &self.codebooks[row]))
            })
            .collect()
    }

    /// Size-weighted total W₂² against the original theta.
    pub fn w2_error(&self, theta: &ParamStore) -> QuantError {
        let errs: Vec<QuantError> = self
            .layer_errors(theta)
            .into_iter()
            .map(|(_, e)| e)
            .collect();
        aggregate(&errs)
    }

    /// Total W₂² of the stored reconstruction (vs its own dequantization —
    /// zero by construction; kept for the doc example's API shape).
    pub fn total_w2_error(&self) -> f64 {
        0.0
    }

    /// Compressed size in bytes (packed codes + codebooks + biases).
    pub fn compressed_bytes(&self) -> usize {
        let codes = (self.codes.len() * self.bits as usize).div_ceil(8);
        let cbs: usize = self.codebooks.iter().map(|c| c.levels.len() * 4).sum();
        codes + cbs + self.biases.len() * 4
    }

    /// Compression ratio vs fp32 storage of the full theta.
    pub fn compression_ratio(&self) -> f64 {
        (self.spec.p() * 4) as f64 / self.compressed_bytes() as f64
    }

    /// Mean codebook utilization across layers (future-work analysis).
    pub fn mean_utilization(&self) -> f64 {
        let mut total = 0.0;
        for (row, l) in self.spec.weight_layers().iter().enumerate() {
            let woff = self.spec.weight_offset(&l.name);
            let codes = &self.codes[woff..woff + l.size()];
            total += self.codebooks[row].utilization(codes);
        }
        total / self.codebooks.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::quantize_model;
    use crate::util::rng::Pcg64;

    fn setup(bits: u8, method: QuantMethod) -> (ModelSpec, ParamStore, QuantizedModel) {
        let spec = ModelSpec::default_spec();
        let mut rng = Pcg64::seed(3);
        let theta = spec.init_theta(&mut rng);
        let qm = quantize_model(&spec, &theta, method, bits);
        (spec, theta, qm)
    }

    #[test]
    fn dequantize_biases_exact_weights_close() {
        let (spec, theta, qm) = setup(8, QuantMethod::Ot);
        let deq = qm.dequantize();
        // biases pass through exactly
        for l in spec.bias_layers() {
            assert_eq!(deq.layer(&spec, &l.name), theta.layer(&spec, &l.name));
        }
        // weights close at 8 bits (w_t has fan-in 64 -> sigma ~0.125, so
        // the size-weighted W2 lands around 1e-6)
        let err = qm.w2_error(&theta);
        assert!(err.w2_sq < 5e-6, "w2={}", err.w2_sq);
        // sup error is dominated by the widest (tail) cell of the largest-
        // sigma layer (w_t, fan-in 64); equal-mass keeps it ~a tail width
        assert!(deq.max_abs_diff(&theta) < 0.25, "{}", deq.max_abs_diff(&theta));
    }

    #[test]
    fn compression_ratio_scales_with_bits() {
        let (_, _, q2) = setup(2, QuantMethod::Ot);
        let (_, _, q8) = setup(8, QuantMethod::Ot);
        assert!(q2.compression_ratio() > 12.0, "{}", q2.compression_ratio());
        assert!(q8.compression_ratio() > 3.5 && q8.compression_ratio() < 4.5);
        assert!(q2.compression_ratio() > q8.compression_ratio());
    }

    #[test]
    fn artifact_inputs_have_right_shapes() {
        let (spec, _, qm) = setup(4, QuantMethod::Uniform);
        assert_eq!(qm.codes_i32().len(), spec.pw());
        assert_eq!(
            qm.codebooks_padded().len(),
            spec.weight_layers().len() * spec.k_max
        );
        // padded slots are huge sentinels
        let padded = qm.codebooks_padded();
        let k = spec.k_max;
        let first_cb = &qm.codebooks[0];
        assert_eq!(&padded[..first_cb.levels.len()], &first_cb.levels[..]);
        assert!(padded[k - 1] > 1e29 || first_cb.levels.len() == k);
    }

    #[test]
    fn ot_utilization_near_one_log2_lower() {
        let (_, _, q_ot) = setup(4, QuantMethod::Ot);
        let (_, _, q_log) = setup(4, QuantMethod::Log2);
        // equal-mass fills every level by construction
        assert!(q_ot.mean_utilization() > 0.95, "{}", q_ot.mean_utilization());
        assert!(q_ot.mean_utilization() >= q_log.mean_utilization());
    }

    #[test]
    fn pack_roundtrip() {
        let (_, _, qm) = setup(3, QuantMethod::Pwl);
        let packed = qm.pack_codes().unwrap();
        assert_eq!(packed.unpack(), qm.codes);
    }
}
