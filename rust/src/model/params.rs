//! Flat parameter store: the single theta[P] vector every artifact takes.

use crate::model::spec::ModelSpec;

/// Owns the flat f32 parameter vector.
#[derive(Clone, Debug, PartialEq)]
pub struct ParamStore {
    data: Vec<f32>,
}

impl ParamStore {
    pub fn new(data: Vec<f32>) -> Self {
        Self { data }
    }

    pub fn zeros(p: usize) -> Self {
        Self { data: vec![0.0; p] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Slice of one named layer.
    pub fn layer<'a>(&'a self, spec: &ModelSpec, name: &str) -> &'a [f32] {
        let l = spec.layer(name).unwrap_or_else(|| panic!("no layer {name}")); // fmq-analyze: allow(panic_cone) -- spec-table lookup with static layer names; offsets were sized by the same spec (covers next line)
        &self.data[l.offset..l.offset + l.size()]
    }

    pub fn layer_mut<'a>(&'a mut self, spec: &ModelSpec, name: &str) -> &'a mut [f32] {
        let l = spec.layer(name).unwrap_or_else(|| panic!("no layer {name}"));
        &mut self.data[l.offset..l.offset + l.size()]
    }

    /// L2 norm of the whole parameter vector.
    pub fn norm(&self) -> f64 {
        self.data
            .iter()
            .map(|&x| (x as f64) * (x as f64))
            .sum::<f64>()
            .sqrt()
    }

    /// Max |theta_i - other_i| — used to compare HLO vs CPU training paths.
    pub fn max_abs_diff(&self, other: &ParamStore) -> f32 {
        assert_eq!(self.len(), other.len());
        self.data
            .iter()
            .zip(other.data.iter())
            .fold(0.0f32, |m, (a, b)| m.max((a - b).abs()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::spec::ModelSpec;

    #[test]
    fn layer_slicing() {
        let spec = ModelSpec::default_spec();
        let mut theta = ParamStore::zeros(spec.p());
        theta.layer_mut(&spec, "w_t")[0] = 7.0;
        let off = spec.layer("w_t").unwrap().offset;
        assert_eq!(theta.as_slice()[off], 7.0);
        assert_eq!(theta.layer(&spec, "w_t").len(), 64 * 512);
    }

    #[test]
    fn norm_and_diff() {
        let a = ParamStore::new(vec![3.0, 4.0]);
        let b = ParamStore::new(vec![3.0, 5.0]);
        assert!((a.norm() - 5.0).abs() < 1e-12);
        assert_eq!(a.max_abs_diff(&b), 1.0);
    }
}
