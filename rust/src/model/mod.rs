//! Model container: architecture spec, flat parameter store, checkpoint
//! I/O and the quantized-model format.
//!
//! The spec regenerates the exact layer table that `python/compile/arch.py`
//! defines; `runtime::artifacts` cross-checks it against the AOT
//! `manifest.json` at load time so the flat-theta layout can never drift.

pub mod checkpoint;
pub mod params;
pub mod quantized;
pub mod spec;
