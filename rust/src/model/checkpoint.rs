//! Binary checkpoint format for trained and quantized models.
//!
//! Layout (little-endian):
//!   magic "FMQ1" | kind u32 | json header len u32 | json header bytes |
//!   payload sections (raw f32/u64 arrays, lengths declared in header)
//!
//! kind 1 = full-precision theta; kind 2 = quantized model. The JSON header
//! makes the format self-describing and versionable without a schema
//! compiler.
//!
//! Integrity: the header carries an FNV-1a 64 fingerprint of the payload
//! (`"fp"`), written on every save and verified on every load (files
//! from before the field are still accepted). Any structural damage —
//! torn/truncated write, bit flip, header/payload length mismatch —
//! surfaces as a typed [`CorruptCheckpoint`] error (downcastable through
//! `anyhow`), never as a panic or silently-garbage parameters. The fault
//! harness's torn-write schedule (`crate::faults::torn_points`) drives
//! the round-trip tests below through every structural boundary.

use std::fmt;
use std::fs;
use std::io::Write;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::model::params::ParamStore;
use crate::model::quantized::QuantizedModel;
use crate::model::spec::ModelSpec;
use crate::quant::codebook::Codebook;
use crate::quant::packing::PackedCodes;
use crate::quant::QuantMethod;
use crate::util::json::{parse, Json};

const MAGIC: &[u8; 4] = b"FMQ1";

/// A checkpoint failed its structural or integrity checks: bad magic,
/// truncated header/payload, undecodable header, declared-vs-actual
/// length mismatch, or payload fingerprint mismatch. Typed (rather than
/// a bare `anyhow!`) so the serving layer can map it onto the
/// `corrupt_artifact` wire class: `err.downcast_ref::<CorruptCheckpoint>()`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CorruptCheckpoint(pub String);

impl fmt::Display for CorruptCheckpoint {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "corrupt checkpoint: {}", self.0)
    }
}

impl std::error::Error for CorruptCheckpoint {}

fn corrupt(msg: impl Into<String>) -> anyhow::Error {
    anyhow::Error::new(CorruptCheckpoint(msg.into()))
}

/// FNV-1a 64 over the payload bytes: tiny, dependency-free, and plenty
/// to catch torn writes and bit flips (this is an integrity check
/// against accidents, not an authenticity check against adversaries).
pub fn fingerprint(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01B3);
    }
    h
}

fn f32s_to_bytes(xs: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 4);
    for &x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

fn bytes_to_f32s(b: &[u8]) -> Result<Vec<f32>> {
    if b.len() % 4 != 0 {
        return Err(corrupt("f32 payload not a multiple of 4 bytes"));
    }
    Ok(b.chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

fn u64s_to_bytes(xs: &[u64]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 8);
    for &x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

fn bytes_to_u64s(b: &[u8]) -> Result<Vec<u64>> {
    if b.len() % 8 != 0 {
        return Err(corrupt("u64 payload not a multiple of 8 bytes"));
    }
    Ok(b.chunks_exact(8)
        .map(|c| u64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]))
        .collect())
}

/// `header_pairs` is extended with the payload fingerprint before
/// serialization, so every saved file is integrity-checkable.
fn write_file(
    path: &Path,
    kind: u32,
    mut header_pairs: Vec<(&str, Json)>,
    payload: &[u8],
) -> Result<()> {
    header_pairs.push(("fp", Json::Int(fingerprint(payload) as i128)));
    let hdr = Json::obj(header_pairs).to_string().into_bytes();
    let mut f = fs::File::create(path).with_context(|| format!("create {path:?}"))?;
    f.write_all(MAGIC)?;
    f.write_all(&kind.to_le_bytes())?;
    f.write_all(&(hdr.len() as u32).to_le_bytes())?;
    f.write_all(&hdr)?;
    f.write_all(payload)?;
    Ok(())
}

fn read_file(path: &Path) -> Result<(u32, Json, Vec<u8>)> {
    let raw = fs::read(path).with_context(|| format!("read {path:?}"))?;
    if raw.len() < 12 || &raw[..4] != MAGIC {
        return Err(corrupt(format!("{path:?}: not an FMQ1 checkpoint")));
    }
    let kind = u32::from_le_bytes([raw[4], raw[5], raw[6], raw[7]]);
    let hlen = u32::from_le_bytes([raw[8], raw[9], raw[10], raw[11]]) as usize;
    // checked: a torn length word could otherwise wrap 12 + hlen
    let end = 12usize
        .checked_add(hlen)
        .ok_or_else(|| corrupt(format!("{path:?}: header length overflows")))?;
    if raw.len() < end {
        return Err(corrupt(format!(
            "{path:?}: truncated header (declared {hlen} bytes, {} present)",
            raw.len().saturating_sub(12)
        )));
    }
    let text = std::str::from_utf8(&raw[12..end])
        .map_err(|e| corrupt(format!("{path:?}: header is not UTF-8: {e}")))?;
    let header =
        parse(text).map_err(|e| corrupt(format!("{path:?}: header does not parse: {e}")))?;
    let payload = raw[end..].to_vec();
    // fingerprint verification; files from before the field have no
    // "fp" and are accepted on the structural checks alone
    if let Some(j) = header.get("fp") {
        let want = j
            .as_u64()
            .ok_or_else(|| corrupt(format!("{path:?}: fp field is not an integer")))?;
        let got = fingerprint(&payload);
        if got != want {
            return Err(corrupt(format!(
                "{path:?}: payload fingerprint mismatch \
                 (stored {want:#018x}, computed {got:#018x}) — torn write or bit rot"
            )));
        }
    }
    Ok((kind, header, payload))
}

/// Save a full-precision theta.
pub fn save_theta(path: &Path, theta: &ParamStore, meta: Vec<(&str, Json)>) -> Result<()> {
    let mut pairs = vec![("p", Json::Num(theta.len() as f64))];
    pairs.extend(meta);
    write_file(path, 1, pairs, &f32s_to_bytes(theta.as_slice()))
}

/// Load a full-precision theta (checks length against spec).
pub fn load_theta(path: &Path, spec: &ModelSpec) -> Result<ParamStore> {
    let (kind, header, payload) = read_file(path)?;
    if kind != 1 {
        bail!("{path:?}: kind {kind}, expected full-precision (1)");
    }
    let p = header
        .req_usize("p")
        .map_err(|e| corrupt(format!("{path:?}: {e}")))?;
    if p != spec.p() {
        bail!("checkpoint P={p}, spec P={}", spec.p());
    }
    let data = bytes_to_f32s(&payload)?;
    if data.len() != p {
        return Err(corrupt(format!(
            "{path:?}: payload has {} f32s, header says {p}",
            data.len()
        )));
    }
    Ok(ParamStore::new(data))
}

/// Save a quantized model: packed codes + codebooks + biases.
pub fn save_quantized(path: &Path, qm: &QuantizedModel) -> Result<()> {
    let packed = qm.pack_codes()?;
    let levels: Vec<Json> = qm
        .codebooks
        .iter()
        .map(|cb| Json::from_f32s(&cb.levels))
        .collect();
    let header = vec![
        ("method", Json::Str(qm.method.name().to_string())),
        ("bits", Json::Num(qm.bits as f64)),
        ("n_codes", Json::Num(packed.n as f64)),
        ("n_words", Json::Num(packed.words.len() as f64)),
        ("n_biases", Json::Num(qm.biases.len() as f64)),
        ("codebooks", Json::Arr(levels)),
    ];
    let mut payload = u64s_to_bytes(&packed.words);
    payload.extend_from_slice(&f32s_to_bytes(&qm.biases));
    write_file(path, 2, header, &payload)
}

/// Load a quantized model.
pub fn load_quantized(path: &Path, spec: &ModelSpec) -> Result<QuantizedModel> {
    let (kind, header, payload) = read_file(path)?;
    if kind != 2 {
        bail!("{path:?}: kind {kind}, expected quantized (2)");
    }
    let hdr_err = |e: anyhow::Error| corrupt(format!("{path:?}: {e}"));
    let method = QuantMethod::parse(header.req_str("method").map_err(hdr_err)?)
        .context("unknown quant method in checkpoint")?;
    let bits = header.req_usize("bits").map_err(hdr_err)? as u8;
    let n_codes = header.req_usize("n_codes").map_err(hdr_err)?;
    let n_words = header.req_usize("n_words").map_err(hdr_err)?;
    let n_biases = header.req_usize("n_biases").map_err(hdr_err)?;
    // checked arithmetic: a corrupted header must not be able to
    // overflow the expected-size computation into a bogus match
    let words_bytes = n_words
        .checked_mul(8)
        .ok_or_else(|| corrupt(format!("{path:?}: n_words={n_words} overflows")))?;
    let expect = n_biases
        .checked_mul(4)
        .and_then(|b| words_bytes.checked_add(b))
        .ok_or_else(|| corrupt(format!("{path:?}: declared sizes overflow")))?;
    if payload.len() != expect {
        return Err(corrupt(format!(
            "{path:?}: payload is {} bytes, header declares {expect}",
            payload.len()
        )));
    }
    let packed = PackedCodes {
        bits,
        n: n_codes,
        words: bytes_to_u64s(&payload[..words_bytes])?,
    };
    let biases = bytes_to_f32s(&payload[words_bytes..])?;
    let codebooks: Vec<Codebook> = header
        .req("codebooks")
        .map_err(hdr_err)?
        .as_arr()
        .context("codebooks not an array")?
        .iter()
        .map(|j| Ok(Codebook::new(j.to_f32s()?, bits)))
        .collect::<Result<_>>()?;
    QuantizedModel::from_packed(spec.clone(), method, bits, codebooks, packed, biases)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::faults::torn_points;
    use crate::quant::{quantize_model, QuantMethod};
    use crate::util::rng::Pcg64;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("fmq-ckpt-tests");
        fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn theta_roundtrip() {
        let spec = ModelSpec::default_spec();
        let mut rng = Pcg64::seed(1);
        let theta = spec.init_theta(&mut rng);
        let p = tmp("theta.fmq");
        save_theta(&p, &theta, vec![("note", Json::Str("test".into()))]).unwrap();
        let back = load_theta(&p, &spec).unwrap();
        assert_eq!(theta, back);
    }

    #[test]
    fn quantized_roundtrip_preserves_dequant() {
        let spec = ModelSpec::default_spec();
        let mut rng = Pcg64::seed(2);
        let theta = spec.init_theta(&mut rng);
        let qm = quantize_model(&spec, &theta, QuantMethod::Ot, 3);
        let p = tmp("q3.fmq");
        save_quantized(&p, &qm).unwrap();
        let back = load_quantized(&p, &spec).unwrap();
        assert_eq!(back.method, QuantMethod::Ot);
        assert_eq!(back.bits, 3);
        assert_eq!(back.codes, qm.codes);
        assert_eq!(back.biases, qm.biases);
        for (a, b) in back.codebooks.iter().zip(qm.codebooks.iter()) {
            assert_eq!(a.levels, b.levels);
        }
    }

    #[test]
    fn rejects_wrong_kind_and_garbage() {
        let spec = ModelSpec::default_spec();
        let p = tmp("garbage.fmq");
        fs::write(&p, b"not a checkpoint").unwrap();
        assert!(load_theta(&p, &spec).is_err());
        // theta file loaded as quantized
        let theta = ParamStore::zeros(spec.p());
        let p2 = tmp("theta2.fmq");
        save_theta(&p2, &theta, vec![]).unwrap();
        assert!(load_quantized(&p2, &spec).is_err());
    }

    #[test]
    fn rejects_wrong_size() {
        let spec = ModelSpec::default_spec();
        let p = tmp("short.fmq");
        save_theta(&p, &ParamStore::zeros(100), vec![]).unwrap();
        assert!(load_theta(&p, &spec).is_err());
    }

    /// Every torn prefix of a saved theta — the fault plan's seeded cut
    /// schedule plus all structural boundaries — must load as a typed
    /// [`CorruptCheckpoint`] error: never a panic, never garbage params.
    #[test]
    fn torn_theta_writes_are_typed_corruption() {
        let spec = ModelSpec::default_spec();
        let mut rng = Pcg64::seed(3);
        let theta = spec.init_theta(&mut rng);
        let p = tmp("torn-theta.fmq");
        save_theta(&p, &theta, vec![]).unwrap();
        let full = fs::read(&p).unwrap();
        for cut in torn_points(0xBAD5EED, full.len()) {
            assert!(cut < full.len());
            let tp = tmp(&format!("torn-theta-{cut}.fmq"));
            fs::write(&tp, &full[..cut]).unwrap();
            let err = load_theta(&tp, &spec).expect_err("torn prefix must not load");
            assert!(
                err.downcast_ref::<CorruptCheckpoint>().is_some(),
                "cut at {cut}/{}: untyped error: {err:#}",
                full.len()
            );
        }
    }

    /// Same torn-write sweep for the quantized format (two payload
    /// sections, so the boundaries differ), plus a single-bit payload
    /// flip that only the fingerprint can catch (lengths all still
    /// match).
    #[test]
    fn torn_and_bitflipped_quantized_writes_are_typed_corruption() {
        let spec = ModelSpec::default_spec();
        let mut rng = Pcg64::seed(4);
        let theta = spec.init_theta(&mut rng);
        let qm = quantize_model(&spec, &theta, QuantMethod::Uniform, 2);
        let p = tmp("torn-q.fmq");
        save_quantized(&p, &qm).unwrap();
        let full = fs::read(&p).unwrap();
        for cut in torn_points(0x7EA2, full.len()) {
            let tp = tmp(&format!("torn-q-{cut}.fmq"));
            fs::write(&tp, &full[..cut]).unwrap();
            let err = load_quantized(&tp, &spec).expect_err("torn prefix must not load");
            assert!(
                err.downcast_ref::<CorruptCheckpoint>().is_some(),
                "cut at {cut}/{}: untyped error: {err:#}",
                full.len()
            );
        }
        // bit rot in the last payload byte: sizes line up, only fp trips
        let mut rotted = full.clone();
        *rotted.last_mut().unwrap() ^= 0x40;
        let rp = tmp("rot-q.fmq");
        fs::write(&rp, &rotted).unwrap();
        let err = load_quantized(&rp, &spec).expect_err("bit rot must not load");
        let c = err
            .downcast_ref::<CorruptCheckpoint>()
            .expect("bit rot must be the typed corruption error");
        assert!(c.0.contains("fingerprint"), "unexpected: {c}");
    }

    /// Files written before the `fp` header field (simulated by
    /// stripping it) still load: integrity is additive, not a format
    /// break.
    #[test]
    fn pre_fingerprint_files_still_load() {
        let spec = ModelSpec::default_spec();
        let theta = ParamStore::zeros(spec.p());
        let payload = f32s_to_bytes(theta.as_slice());
        let p = tmp("legacy.fmq");
        // hand-write the v0 layout: header without "fp"
        let hdr = Json::obj(vec![("p", Json::Num(spec.p() as f64))])
            .to_string()
            .into_bytes();
        let mut raw = Vec::new();
        raw.extend_from_slice(MAGIC);
        raw.extend_from_slice(&1u32.to_le_bytes());
        raw.extend_from_slice(&(hdr.len() as u32).to_le_bytes());
        raw.extend_from_slice(&hdr);
        raw.extend_from_slice(&payload);
        fs::write(&p, &raw).unwrap();
        let back = load_theta(&p, &spec).unwrap();
        assert_eq!(back, theta);
    }

    #[test]
    fn fingerprint_is_fnv1a64() {
        // reference values for the standard FNV-1a 64 test vectors
        assert_eq!(fingerprint(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fingerprint(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fingerprint(b"foobar"), 0x85944171f73967e8);
    }
}
