//! Binary checkpoint format for trained and quantized models.
//!
//! Layout (little-endian):
//!   magic "FMQ1" | kind u32 | json header len u32 | json header bytes |
//!   payload sections (raw f32/u64 arrays, lengths declared in header)
//!
//! kind 1 = full-precision theta; kind 2 = quantized model. The JSON header
//! makes the format self-describing and versionable without a schema
//! compiler.

use std::fs;
use std::io::Write;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::model::params::ParamStore;
use crate::model::quantized::QuantizedModel;
use crate::model::spec::ModelSpec;
use crate::quant::codebook::Codebook;
use crate::quant::packing::PackedCodes;
use crate::quant::QuantMethod;
use crate::util::json::{parse, Json};

const MAGIC: &[u8; 4] = b"FMQ1";

fn f32s_to_bytes(xs: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 4);
    for &x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

fn bytes_to_f32s(b: &[u8]) -> Result<Vec<f32>> {
    if b.len() % 4 != 0 {
        bail!("f32 payload not multiple of 4");
    }
    Ok(b.chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

fn u64s_to_bytes(xs: &[u64]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 8);
    for &x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

fn bytes_to_u64s(b: &[u8]) -> Result<Vec<u64>> {
    if b.len() % 8 != 0 {
        bail!("u64 payload not multiple of 8");
    }
    Ok(b.chunks_exact(8)
        .map(|c| u64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]))
        .collect())
}

fn write_file(path: &Path, kind: u32, header: &Json, payload: &[u8]) -> Result<()> {
    let hdr = header.to_string().into_bytes();
    let mut f = fs::File::create(path).with_context(|| format!("create {path:?}"))?;
    f.write_all(MAGIC)?;
    f.write_all(&kind.to_le_bytes())?;
    f.write_all(&(hdr.len() as u32).to_le_bytes())?;
    f.write_all(&hdr)?;
    f.write_all(payload)?;
    Ok(())
}

fn read_file(path: &Path) -> Result<(u32, Json, Vec<u8>)> {
    let raw = fs::read(path).with_context(|| format!("read {path:?}"))?;
    if raw.len() < 12 || &raw[..4] != MAGIC {
        bail!("{path:?}: not an FMQ1 checkpoint");
    }
    let kind = u32::from_le_bytes([raw[4], raw[5], raw[6], raw[7]]);
    let hlen = u32::from_le_bytes([raw[8], raw[9], raw[10], raw[11]]) as usize;
    if raw.len() < 12 + hlen {
        bail!("truncated header");
    }
    let header = parse(std::str::from_utf8(&raw[12..12 + hlen])?)?;
    Ok((kind, header, raw[12 + hlen..].to_vec()))
}

/// Save a full-precision theta.
pub fn save_theta(path: &Path, theta: &ParamStore, meta: Vec<(&str, Json)>) -> Result<()> {
    let mut pairs = vec![("p", Json::Num(theta.len() as f64))];
    pairs.extend(meta);
    write_file(path, 1, &Json::obj(pairs), &f32s_to_bytes(theta.as_slice()))
}

/// Load a full-precision theta (checks length against spec).
pub fn load_theta(path: &Path, spec: &ModelSpec) -> Result<ParamStore> {
    let (kind, header, payload) = read_file(path)?;
    if kind != 1 {
        bail!("{path:?}: kind {kind}, expected full-precision (1)");
    }
    let p = header.req_usize("p")?;
    if p != spec.p() {
        bail!("checkpoint P={p}, spec P={}", spec.p());
    }
    let data = bytes_to_f32s(&payload)?;
    if data.len() != p {
        bail!("payload has {} f32s, header says {p}", data.len());
    }
    Ok(ParamStore::new(data))
}

/// Save a quantized model: packed codes + codebooks + biases.
pub fn save_quantized(path: &Path, qm: &QuantizedModel) -> Result<()> {
    let packed = qm.pack_codes()?;
    let levels: Vec<Json> = qm
        .codebooks
        .iter()
        .map(|cb| Json::from_f32s(&cb.levels))
        .collect();
    let header = Json::obj(vec![
        ("method", Json::Str(qm.method.name().to_string())),
        ("bits", Json::Num(qm.bits as f64)),
        ("n_codes", Json::Num(packed.n as f64)),
        ("n_words", Json::Num(packed.words.len() as f64)),
        ("n_biases", Json::Num(qm.biases.len() as f64)),
        ("codebooks", Json::Arr(levels)),
    ]);
    let mut payload = u64s_to_bytes(&packed.words);
    payload.extend_from_slice(&f32s_to_bytes(&qm.biases));
    write_file(path, 2, &header, &payload)
}

/// Load a quantized model.
pub fn load_quantized(path: &Path, spec: &ModelSpec) -> Result<QuantizedModel> {
    let (kind, header, payload) = read_file(path)?;
    if kind != 2 {
        bail!("{path:?}: kind {kind}, expected quantized (2)");
    }
    let method = QuantMethod::parse(header.req_str("method")?)
        .context("unknown quant method in checkpoint")?;
    let bits = header.req_usize("bits")? as u8;
    let n_codes = header.req_usize("n_codes")?;
    let n_words = header.req_usize("n_words")?;
    let n_biases = header.req_usize("n_biases")?;
    let words_bytes = n_words * 8;
    if payload.len() != words_bytes + n_biases * 4 {
        bail!("payload size mismatch");
    }
    let packed = PackedCodes {
        bits,
        n: n_codes,
        words: bytes_to_u64s(&payload[..words_bytes])?,
    };
    let biases = bytes_to_f32s(&payload[words_bytes..])?;
    let codebooks: Vec<Codebook> = header
        .req("codebooks")?
        .as_arr()
        .context("codebooks not an array")?
        .iter()
        .map(|j| Ok(Codebook::new(j.to_f32s()?, bits)))
        .collect::<Result<_>>()?;
    QuantizedModel::from_packed(spec.clone(), method, bits, codebooks, packed, biases)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{quantize_model, QuantMethod};
    use crate::util::rng::Pcg64;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("fmq-ckpt-tests");
        fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn theta_roundtrip() {
        let spec = ModelSpec::default_spec();
        let mut rng = Pcg64::seed(1);
        let theta = spec.init_theta(&mut rng);
        let p = tmp("theta.fmq");
        save_theta(&p, &theta, vec![("note", Json::Str("test".into()))]).unwrap();
        let back = load_theta(&p, &spec).unwrap();
        assert_eq!(theta, back);
    }

    #[test]
    fn quantized_roundtrip_preserves_dequant() {
        let spec = ModelSpec::default_spec();
        let mut rng = Pcg64::seed(2);
        let theta = spec.init_theta(&mut rng);
        let qm = quantize_model(&spec, &theta, QuantMethod::Ot, 3);
        let p = tmp("q3.fmq");
        save_quantized(&p, &qm).unwrap();
        let back = load_quantized(&p, &spec).unwrap();
        assert_eq!(back.method, QuantMethod::Ot);
        assert_eq!(back.bits, 3);
        assert_eq!(back.codes, qm.codes);
        assert_eq!(back.biases, qm.biases);
        for (a, b) in back.codebooks.iter().zip(qm.codebooks.iter()) {
            assert_eq!(a.levels, b.levels);
        }
    }

    #[test]
    fn rejects_wrong_kind_and_garbage() {
        let spec = ModelSpec::default_spec();
        let p = tmp("garbage.fmq");
        fs::write(&p, b"not a checkpoint").unwrap();
        assert!(load_theta(&p, &spec).is_err());
        // theta file loaded as quantized
        let theta = ParamStore::zeros(spec.p());
        let p2 = tmp("theta2.fmq");
        save_theta(&p2, &theta, vec![]).unwrap();
        assert!(load_quantized(&p2, &spec).is_err());
    }

    #[test]
    fn rejects_wrong_size() {
        let spec = ModelSpec::default_spec();
        let p = tmp("short.fmq");
        save_theta(&p, &ParamStore::zeros(100), vec![]).unwrap();
        assert!(load_theta(&p, &spec).is_err());
    }
}
