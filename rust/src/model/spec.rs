//! Architecture spec — the rust-side twin of `python/compile/arch.py`.
//!
//! Defines the velocity network's layer table (names, shapes, flat-theta
//! offsets) and the He-style initialization the training driver starts
//! from. An integration test asserts this table equals the one in
//! `artifacts/manifest.json` byte-for-byte.

use crate::util::json::Json;
use crate::util::rng::Pcg64;

/// Canonical hyperparameters (single artifact set — see DESIGN.md §2).
pub const D: usize = 768; // 16*16*3
pub const IMG_HW: usize = 16;
pub const IMG_C: usize = 3;
pub const HIDDEN: usize = 512;
pub const TEMB_FREQS: usize = 32;
pub const TEMB: usize = 2 * TEMB_FREQS;
pub const BLOCKS: usize = 3;
pub const B_TRAIN: usize = 64;
pub const B_SAMPLE: usize = 16;
pub const K_MAX: usize = 256;
pub const FREQ_MAX: f32 = 1000.0;

/// One entry of the layer table.
#[derive(Clone, Debug, PartialEq)]
pub struct Layer {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
}

impl Layer {
    pub fn size(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_weight(&self) -> bool {
        self.shape.len() == 2
    }
}

/// The full architecture description.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelSpec {
    pub layers: Vec<Layer>,
    pub d: usize,
    pub hidden: usize,
    pub blocks: usize,
    pub temb_freqs: usize,
    pub k_max: usize,
    pub freq_max: f32,
}

impl ModelSpec {
    /// The default (and only AOT-compiled) architecture.
    pub fn default_spec() -> Self {
        let mut layers = Vec::new();
        let mut off = 0usize;
        let mut add = |name: &str, shape: Vec<usize>, off: &mut usize| {
            let l = Layer {
                name: name.to_string(),
                shape: shape.clone(),
                offset: *off,
            };
            *off += l.size();
            layers_push(&mut layers, l);
        };
        add("w_in", vec![D, HIDDEN], &mut off);
        add("b_in", vec![HIDDEN], &mut off);
        add("w_t", vec![TEMB, HIDDEN], &mut off);
        add("b_t", vec![HIDDEN], &mut off);
        for i in 0..BLOCKS {
            add(&format!("w1_{i}"), vec![HIDDEN, HIDDEN], &mut off);
            add(&format!("b1_{i}"), vec![HIDDEN], &mut off);
            add(&format!("w2_{i}"), vec![HIDDEN, HIDDEN], &mut off);
            add(&format!("b2_{i}"), vec![HIDDEN], &mut off);
        }
        add("w_out", vec![HIDDEN, D], &mut off);
        add("b_out", vec![D], &mut off);
        ModelSpec {
            layers,
            d: D,
            hidden: HIDDEN,
            blocks: BLOCKS,
            temb_freqs: TEMB_FREQS,
            k_max: K_MAX,
            freq_max: FREQ_MAX,
        }
    }

    /// Total parameter count P.
    pub fn p(&self) -> usize {
        self.layers.iter().map(|l| l.size()).sum()
    }

    /// Quantized (weight-matrix) parameter count PW.
    pub fn pw(&self) -> usize {
        self.weight_layers().iter().map(|l| l.size()).sum()
    }

    /// Bias parameter count PB.
    pub fn pb(&self) -> usize {
        self.bias_layers().iter().map(|l| l.size()).sum()
    }

    pub fn weight_layers(&self) -> Vec<Layer> {
        self.layers.iter().filter(|l| l.is_weight()).cloned().collect()
    }

    pub fn bias_layers(&self) -> Vec<Layer> {
        self.layers.iter().filter(|l| !l.is_weight()).cloned().collect()
    }

    pub fn layer(&self, name: &str) -> Option<&Layer> {
        self.layers.iter().find(|l| l.name == name)
    }

    /// Offset of a weight tensor inside the packed codes vector codes[PW].
    pub fn weight_offset(&self, name: &str) -> usize {
        let mut off = 0;
        for l in self.weight_layers() {
            if l.name == name {
                return off;
            }
            off += l.size();
        }
        panic!("unknown weight layer {name}"); // fmq-analyze: allow(panic_cone) -- callers pass names from this spec's own layer table; load/pack-time only
    }

    /// Offset of a bias inside the packed bias vector biases[PB].
    pub fn bias_offset(&self, name: &str) -> usize {
        let mut off = 0;
        for l in self.bias_layers() {
            if l.name == name {
                return off;
            }
            off += l.size();
        }
        panic!("unknown bias layer {name}"); // fmq-analyze: allow(panic_cone) -- same spec-table contract as weight_offset
    }

    /// He-style init: W ~ N(0, 1/sqrt(fan_in)), biases 0, output layer
    /// scaled down for ODE stability (matches the training recipe in
    /// EXPERIMENTS.md).
    pub fn init_theta(&self, rng: &mut Pcg64) -> crate::model::params::ParamStore {
        let mut data = vec![0f32; self.p()];
        for l in &self.layers {
            if l.is_weight() {
                let fan_in = l.shape[0] as f32;
                let mut std = 1.0 / fan_in.sqrt();
                if l.name == "w_out" {
                    std *= 0.1;
                }
                for v in data[l.offset..l.offset + l.size()].iter_mut() {
                    *v = rng.normal_f32(0.0, std);
                }
            }
        }
        crate::model::params::ParamStore::new(data)
    }

    /// Cross-check against the AOT manifest layer table.
    pub fn matches_manifest(&self, manifest: &Json) -> anyhow::Result<()> {
        use anyhow::{bail, Context};
        let p = manifest.req_usize("p")?;
        if p != self.p() {
            bail!("manifest P={p}, spec P={}", self.p());
        }
        let layers = manifest
            .req("layers")?
            .as_arr()
            .context("layers not an array")?;
        if layers.len() != self.layers.len() {
            bail!("layer count {} vs {}", layers.len(), self.layers.len());
        }
        for (m, l) in layers.iter().zip(self.layers.iter()) {
            let name = m.req_str("name")?;
            let offset = m.req_usize("offset")?;
            let shape: Vec<usize> = m
                .req("shape")?
                .as_arr()
                .context("shape not an array")?
                .iter()
                .map(|v| v.as_usize().unwrap_or(0))
                .collect();
            if name != l.name || offset != l.offset || shape != l.shape {
                bail!(
                    "layer mismatch: manifest ({name}, {offset}, {shape:?}) vs spec ({}, {}, {:?})",
                    l.name, l.offset, l.shape
                );
            }
        }
        Ok(())
    }
}

fn layers_push(layers: &mut Vec<Layer>, l: Layer) {
    layers.push(l);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_matches_python_arch() {
        // the exact numbers printed by `python -m compile.arch`
        let s = ModelSpec::default_spec();
        assert_eq!(s.p(), 2_396_928);
        assert_eq!(s.weight_layers().len(), 9);
        assert_eq!(s.layer("w_in").unwrap().offset, 0);
        assert_eq!(s.layer("b_in").unwrap().offset, 393_216);
        assert_eq!(s.layer("w_t").unwrap().offset, 393_728);
        assert_eq!(s.layer("b_out").unwrap().offset, s.p() - D);
        assert_eq!(s.pw() + s.pb(), s.p());
    }

    #[test]
    fn weight_and_bias_offsets_are_contiguous() {
        let s = ModelSpec::default_spec();
        let mut off = 0;
        for l in s.weight_layers() {
            assert_eq!(s.weight_offset(&l.name), off);
            off += l.size();
        }
        assert_eq!(off, s.pw());
        let mut off = 0;
        for l in s.bias_layers() {
            assert_eq!(s.bias_offset(&l.name), off);
            off += l.size();
        }
        assert_eq!(off, s.pb());
    }

    #[test]
    fn init_statistics() {
        let s = ModelSpec::default_spec();
        let mut rng = Pcg64::seed(1);
        let theta = s.init_theta(&mut rng);
        // w_in std ~ 1/sqrt(768)
        let w_in = theta.layer(&s, "w_in");
        let sd = crate::stats::std_dev(w_in);
        assert!((sd - 1.0 / (768f64).sqrt()).abs() < 2e-3, "sd={sd}");
        // biases zero
        let b = theta.layer(&s, "b_in");
        assert!(b.iter().all(|&x| x == 0.0));
        // w_out scaled down
        let w_out = theta.layer(&s, "w_out");
        let sd_out = crate::stats::std_dev(w_out);
        assert!(sd_out < 0.2 / (512f64).sqrt(), "sd_out={sd_out}");
    }

    #[test]
    fn manifest_cross_check_detects_drift() {
        let s = ModelSpec::default_spec();
        let good = format!(
            r#"{{"p": {}, "layers": [{}]}}"#,
            s.p(),
            s.layers
                .iter()
                .map(|l| format!(
                    r#"{{"name": "{}", "offset": {}, "shape": [{}]}}"#,
                    l.name,
                    l.offset,
                    l.shape.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(",")
                ))
                .collect::<Vec<_>>()
                .join(",")
        );
        let j = crate::util::json::parse(&good).unwrap();
        s.matches_manifest(&j).unwrap();
        // corrupt one offset
        let bad = good.replacen("\"offset\": 0", "\"offset\": 4", 1);
        let j = crate::util::json::parse(&bad).unwrap();
        assert!(s.matches_manifest(&j).is_err());
    }
}
