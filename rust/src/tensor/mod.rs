//! Minimal shaped f32 tensor used across the coordinator.
//!
//! Deliberately small: the heavy compute runs in the AOT-compiled XLA
//! artifacts; this type exists for host-side glue (datasets, metrics,
//! quantizer I/O, the CPU reference forward). Row-major, f32-only.

use anyhow::{bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: &[usize], data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} wants {} elements, got {}", shape, n, data.len());
        }
        Ok(Self {
            shape: shape.to_vec(),
            data,
        })
    }

    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Self {
            shape: shape.to_vec(),
            data: vec![0.0; n],
        }
    }

    pub fn full(shape: &[usize], v: f32) -> Self {
        let n = shape.iter().product();
        Self {
            shape: shape.to_vec(),
            data: vec![v; n],
        }
    }

    pub fn from_vec(data: Vec<f32>) -> Self {
        Self {
            shape: vec![data.len()],
            data,
        }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    pub fn reshape(mut self, shape: &[usize]) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != self.data.len() {
            bail!("cannot reshape {} elements to {:?}", self.data.len(), shape);
        }
        self.shape = shape.to_vec();
        Ok(self)
    }

    /// 2-D accessor.
    #[inline]
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.shape.len(), 2);
        self.data[i * self.shape[1] + j]
    }

    /// Row view of a 2-D tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        debug_assert_eq!(self.shape.len(), 2);
        let w = self.shape[1];
        &self.data[i * w..(i + 1) * w]
    }

    /// `self` [m,k] @ `rhs` [k,n] -> [m,n]. Blocked i-k-j loop order so the
    /// inner loop is a contiguous axpy (vectorizes well; see §Perf).
    pub fn matmul(&self, rhs: &Tensor) -> Result<Tensor> {
        if self.shape.len() != 2 || rhs.shape.len() != 2 || self.shape[1] != rhs.shape[0] {
            bail!("matmul shapes {:?} x {:?}", self.shape, rhs.shape);
        }
        let (m, k, n) = (self.shape[0], self.shape[1], rhs.shape[1]);
        let mut out = vec![0.0f32; m * n];
        matmul_into(&self.data, &rhs.data, &mut out, m, k, n);
        Tensor::new(&[m, n], out)
    }

    pub fn add_assign(&mut self, rhs: &Tensor) -> Result<()> {
        if self.shape != rhs.shape {
            bail!("add shapes {:?} vs {:?}", self.shape, rhs.shape);
        }
        for (a, b) in self.data.iter_mut().zip(rhs.data.iter()) {
            *a += b;
        }
        Ok(())
    }

    /// Broadcast-add a row vector to every row of a 2-D tensor.
    pub fn add_row(&mut self, row: &[f32]) -> Result<()> {
        if self.shape.len() != 2 || self.shape[1] != row.len() {
            bail!("add_row shapes {:?} vs [{}]", self.shape, row.len());
        }
        for r in self.data.chunks_mut(row.len()) {
            for (a, b) in r.iter_mut().zip(row.iter()) {
                *a += b;
            }
        }
        Ok(())
    }

    pub fn scale(&mut self, s: f32) {
        for a in self.data.iter_mut() {
            *a *= s;
        }
    }

    pub fn map(mut self, f: impl Fn(f32) -> f32) -> Self {
        for a in self.data.iter_mut() {
            *a = f(*a);
        }
        self
    }

    pub fn sum(&self) -> f64 {
        self.data.iter().map(|&x| x as f64).sum()
    }

    pub fn mean(&self) -> f64 {
        if self.data.is_empty() {
            0.0
        } else {
            self.sum() / self.data.len() as f64
        }
    }

    pub fn sq_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum()
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }
}

/// Core GEMM used by both `Tensor::matmul` and the CPU reference forward:
/// C[m,n] += A[m,k] @ B[k,n], accumulating into `out` (caller zeroes it).
/// i-k-j order keeps the inner loop contiguous over both B and C rows.
pub fn matmul_into(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut out[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &b[kk * n..(kk + 1) * n];
            for (c, &bv) in crow.iter_mut().zip(brow.iter()) {
                *c += av * bv;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_checks_shape() {
        assert!(Tensor::new(&[2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(&[2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn matmul_known_values() {
        let a = Tensor::new(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let b = Tensor::new(&[2, 2], vec![1.0, 1.0, 1.0, 1.0]).unwrap();
        let c = a.matmul(&b).unwrap();
        assert_eq!(c.data(), &[3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_rectangular() {
        // [1,3] @ [3,2]
        let a = Tensor::new(&[1, 3], vec![1.0, 2.0, 3.0]).unwrap();
        let b = Tensor::new(&[3, 2], vec![1.0, 0.0, 0.0, 1.0, 1.0, 1.0]).unwrap();
        let c = a.matmul(&b).unwrap();
        assert_eq!(c.shape(), &[1, 2]);
        assert_eq!(c.data(), &[4.0, 5.0]);
    }

    #[test]
    fn matmul_shape_mismatch() {
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[2, 3]);
        assert!(a.matmul(&b).is_err());
    }

    #[test]
    fn add_row_broadcasts() {
        let mut t = Tensor::zeros(&[2, 3]);
        t.add_row(&[1.0, 2.0, 3.0]).unwrap();
        assert_eq!(t.data(), &[1.0, 2.0, 3.0, 1.0, 2.0, 3.0]);
    }

    #[test]
    fn reductions() {
        let t = Tensor::from_vec(vec![1.0, -2.0, 3.0]);
        assert_eq!(t.sum(), 2.0);
        assert_eq!(t.max_abs(), 3.0);
        assert!((t.sq_norm() - 14.0).abs() < 1e-9);
        assert!((t.mean() - 2.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn reshape_and_row() {
        let t = Tensor::from_vec((0..6).map(|i| i as f32).collect())
            .reshape(&[2, 3])
            .unwrap();
        assert_eq!(t.row(1), &[3.0, 4.0, 5.0]);
        assert_eq!(t.at2(1, 2), 5.0);
    }
}
