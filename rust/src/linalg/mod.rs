//! Dense symmetric linear algebra for the FID metric.
//!
//! FID needs tr((Σ₁ + Σ₂ − 2(Σ₁^{1/2} Σ₂ Σ₁^{1/2})^{1/2})) — i.e. PSD
//! matrix square roots. We implement a cyclic Jacobi eigensolver (robust
//! for the small symmetric covariance matrices our feature dimension
//! produces) and build sqrtm from the eigendecomposition.

/// Column-major-free simple square matrix: row-major `n x n` f64.
#[derive(Clone, Debug)]
pub struct SymMat {
    pub n: usize,
    pub a: Vec<f64>,
}

impl SymMat {
    pub fn zeros(n: usize) -> Self {
        Self {
            n,
            a: vec![0.0; n * n],
        }
    }

    pub fn from_rows(n: usize, a: Vec<f64>) -> Self {
        assert_eq!(a.len(), n * n);
        Self { n, a }
    }

    pub fn identity(n: usize) -> Self {
        let mut m = Self::zeros(n);
        for i in 0..n {
            m.a[i * n + i] = 1.0;
        }
        m
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.a[i * self.n + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.a[i * self.n + j] = v;
    }

    pub fn trace(&self) -> f64 {
        (0..self.n).map(|i| self.get(i, i)).sum()
    }

    pub fn matmul(&self, rhs: &SymMat) -> SymMat {
        assert_eq!(self.n, rhs.n);
        let n = self.n;
        let mut out = SymMat::zeros(n);
        for i in 0..n {
            for k in 0..n {
                let aik = self.get(i, k);
                if aik == 0.0 {
                    continue;
                }
                for j in 0..n {
                    out.a[i * n + j] += aik * rhs.get(k, j);
                }
            }
        }
        out
    }

    pub fn add(&self, rhs: &SymMat) -> SymMat {
        assert_eq!(self.n, rhs.n); // fmq-analyze: allow(panic_cone) -- OT quantizer builds both operands with one n; a mismatch is a programmer error, not data
        SymMat {
            n: self.n,
            a: self
                .a
                .iter()
                .zip(rhs.a.iter())
                .map(|(x, y)| x + y)
                .collect(),
        }
    }

    pub fn scaled(&self, s: f64) -> SymMat {
        SymMat {
            n: self.n,
            a: self.a.iter().map(|x| x * s).collect(),
        }
    }

    /// Max |A - Aᵀ| — symmetry defect.
    pub fn asymmetry(&self) -> f64 {
        let mut d = 0.0f64;
        for i in 0..self.n {
            for j in (i + 1)..self.n {
                d = d.max((self.get(i, j) - self.get(j, i)).abs());
            }
        }
        d
    }

    /// Force exact symmetry: A ← (A + Aᵀ)/2.
    pub fn symmetrize(&mut self) {
        for i in 0..self.n {
            for j in (i + 1)..self.n {
                let v = 0.5 * (self.get(i, j) + self.get(j, i));
                self.set(i, j, v);
                self.set(j, i, v);
            }
        }
    }
}

/// Cyclic Jacobi eigendecomposition of a symmetric matrix.
/// Returns (eigenvalues, eigenvectors as columns of V): A = V diag(λ) Vᵀ.
pub fn jacobi_eigen(m: &SymMat, max_sweeps: usize) -> (Vec<f64>, SymMat) {
    let n = m.n;
    let mut a = m.clone();
    a.symmetrize();
    let mut v = SymMat::identity(n);
    for _sweep in 0..max_sweeps {
        // off-diagonal Frobenius norm
        let mut off = 0.0f64;
        for i in 0..n {
            for j in (i + 1)..n {
                off += a.get(i, j) * a.get(i, j);
            }
        }
        if off.sqrt() < 1e-12 * (1.0 + a.trace().abs()) {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = a.get(p, q);
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = a.get(p, p);
                let aqq = a.get(q, q);
                let tau = (aqq - app) / (2.0 * apq);
                let t = if tau >= 0.0 {
                    1.0 / (tau + (1.0 + tau * tau).sqrt())
                } else {
                    -1.0 / (-tau + (1.0 + tau * tau).sqrt())
                };
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = t * c;
                // rotate rows/cols p,q of A
                for k in 0..n {
                    let akp = a.get(k, p);
                    let akq = a.get(k, q);
                    a.set(k, p, c * akp - s * akq);
                    a.set(k, q, s * akp + c * akq);
                }
                for k in 0..n {
                    let apk = a.get(p, k);
                    let aqk = a.get(q, k);
                    a.set(p, k, c * apk - s * aqk);
                    a.set(q, k, s * apk + c * aqk);
                }
                // accumulate eigenvectors
                for k in 0..n {
                    let vkp = v.get(k, p);
                    let vkq = v.get(k, q);
                    v.set(k, p, c * vkp - s * vkq);
                    v.set(k, q, s * vkp + c * vkq);
                }
            }
        }
    }
    let eig = (0..n).map(|i| a.get(i, i)).collect();
    (eig, v)
}

/// PSD matrix square root via eigendecomposition; negative eigenvalues
/// (numerical noise) clamp to zero.
pub fn sqrtm_psd(m: &SymMat) -> SymMat {
    let n = m.n;
    let (eig, v) = jacobi_eigen(m, 64);
    let mut out = SymMat::zeros(n);
    for k in 0..n {
        let s = eig[k].max(0.0).sqrt();
        if s == 0.0 {
            continue;
        }
        for i in 0..n {
            let vik = v.get(i, k);
            if vik == 0.0 {
                continue;
            }
            for j in 0..n {
                out.a[i * n + j] += s * vik * v.get(j, k);
            }
        }
    }
    out
}

/// Covariance matrix (population) of rows: xs is a flat [m, d] matrix.
pub fn covariance(xs: &[f32], m: usize, d: usize) -> (Vec<f64>, SymMat) {
    assert_eq!(xs.len(), m * d);
    let mut mean = vec![0.0f64; d];
    for r in 0..m {
        for c in 0..d {
            mean[c] += xs[r * d + c] as f64;
        }
    }
    for v in mean.iter_mut() {
        *v /= m as f64;
    }
    let mut cov = SymMat::zeros(d);
    for r in 0..m {
        for i in 0..d {
            let di = xs[r * d + i] as f64 - mean[i];
            if di == 0.0 {
                continue;
            }
            for j in i..d {
                let dj = xs[r * d + j] as f64 - mean[j];
                cov.a[i * d + j] += di * dj;
            }
        }
    }
    for i in 0..d {
        for j in i..d {
            let v = cov.a[i * d + j] / m as f64;
            cov.a[i * d + j] = v;
            cov.a[j * d + i] = v;
        }
    }
    (mean, cov)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn reconstruct(eig: &[f64], v: &SymMat) -> SymMat {
        let n = v.n;
        let mut out = SymMat::zeros(n);
        for k in 0..n {
            for i in 0..n {
                for j in 0..n {
                    out.a[i * n + j] += eig[k] * v.get(i, k) * v.get(j, k);
                }
            }
        }
        out
    }

    #[test]
    fn eigen_diagonal_matrix() {
        let mut m = SymMat::zeros(3);
        m.set(0, 0, 3.0);
        m.set(1, 1, 1.0);
        m.set(2, 2, 2.0);
        let (mut eig, _) = jacobi_eigen(&m, 32);
        eig.sort_by(f64::total_cmp);
        assert!((eig[0] - 1.0).abs() < 1e-10);
        assert!((eig[1] - 2.0).abs() < 1e-10);
        assert!((eig[2] - 3.0).abs() < 1e-10);
    }

    #[test]
    fn eigen_reconstructs_random_symmetric() {
        let mut rng = Pcg64::seed(21);
        let n = 12;
        let mut m = SymMat::zeros(n);
        for i in 0..n {
            for j in i..n {
                let v = rng.normal();
                m.set(i, j, v);
                m.set(j, i, v);
            }
        }
        let (eig, v) = jacobi_eigen(&m, 64);
        let rec = reconstruct(&eig, &v);
        for (a, b) in m.a.iter().zip(rec.a.iter()) {
            assert!((a - b).abs() < 1e-8, "{a} vs {b}");
        }
    }

    #[test]
    fn sqrtm_squares_back() {
        let mut rng = Pcg64::seed(22);
        let n = 10;
        // build PSD: B Bᵀ
        let b: Vec<f64> = (0..n * n).map(|_| rng.normal()).collect();
        let mut m = SymMat::zeros(n);
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += b[i * n + k] * b[j * n + k];
                }
                m.set(i, j, s);
            }
        }
        let r = sqrtm_psd(&m);
        let r2 = r.matmul(&r);
        for (a, b) in m.a.iter().zip(r2.a.iter()) {
            assert!((a - b).abs() < 1e-7, "{a} vs {b}");
        }
    }

    #[test]
    fn sqrtm_identity() {
        let i4 = SymMat::identity(4);
        let r = sqrtm_psd(&i4);
        for (a, b) in r.a.iter().zip(i4.a.iter()) {
            assert!((a - b).abs() < 1e-10);
        }
    }

    #[test]
    fn covariance_of_isotropic_gaussian() {
        let mut rng = Pcg64::seed(23);
        let (m, d) = (20_000, 4);
        let xs: Vec<f32> = (0..m * d).map(|_| rng.normal() as f32).collect();
        let (mean, cov) = covariance(&xs, m, d);
        for mu in mean {
            assert!(mu.abs() < 0.05);
        }
        for i in 0..d {
            for j in 0..d {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((cov.get(i, j) - want).abs() < 0.05);
            }
        }
    }

    #[test]
    fn trace_and_add() {
        let mut a = SymMat::identity(3);
        let b = SymMat::identity(3);
        a = a.add(&b);
        assert!((a.trace() - 6.0).abs() < 1e-12);
        assert!((a.scaled(0.5).trace() - 3.0).abs() < 1e-12);
    }
}
