//! Evaluation metrics: PSNR, windowed SSIM, Fréchet distance over a
//! Lipschitz feature net, and latent-space variance statistics — the
//! quantities behind the paper's Figures 3 and 4.

pub mod coverage;
pub mod features;
pub mod fid;
pub mod latent;
pub mod psnr;
pub mod ssim;
