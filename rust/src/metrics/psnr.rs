//! Peak Signal-to-Noise Ratio — the paper's pixel-level fidelity metric
//! (Fig. 3B). Images live in [-1, 1], so the peak-to-peak range is 2.

use crate::stats::mse;

/// PSNR in dB between a reference image and a test image (both [-1, 1]).
pub fn psnr(reference: &[f32], test: &[f32]) -> f64 {
    let m = mse(reference, test);
    if m == 0.0 {
        return f64::INFINITY;
    }
    let peak = 2.0f64; // dynamic range of [-1, 1]
    10.0 * (peak * peak / m).log10()
}

/// Mean PSNR over a batch of flattened images.
pub fn batch_psnr(reference: &[f32], test: &[f32], img_len: usize) -> f64 {
    assert_eq!(reference.len(), test.len());
    assert_eq!(reference.len() % img_len, 0);
    let n = reference.len() / img_len;
    let mut acc = 0.0;
    for i in 0..n {
        let a = &reference[i * img_len..(i + 1) * img_len];
        let b = &test[i * img_len..(i + 1) * img_len];
        // cap infinities (identical images) at a high but finite value so
        // batch means stay informative; non-finite inputs score worst-case
        let p = psnr(a, b);
        acc += if p.is_nan() { 0.0 } else { p.min(99.0) };
    }
    acc / n as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn identical_images_infinite_psnr() {
        let img = vec![0.3f32; 256];
        assert!(psnr(&img, &img).is_infinite());
    }

    #[test]
    fn known_value() {
        // mse = 0.04 -> psnr = 10 log10(4/0.04) = 20 dB
        let a = vec![0.0f32; 100];
        let b = vec![0.2f32; 100];
        // f32 representation of 0.2 puts us ~1e-7 off the exact 20 dB
        assert!((psnr(&a, &b) - 20.0).abs() < 1e-5);
    }

    #[test]
    fn monotone_in_noise() {
        let mut rng = Pcg64::seed(1);
        let a: Vec<f32> = (0..768).map(|_| rng.uniform_in(-1.0, 1.0)).collect();
        let noisy = |amp: f32, rng: &mut Pcg64| -> Vec<f32> {
            a.iter().map(|&x| x + rng.normal_f32(0.0, amp)).collect()
        };
        let p1 = psnr(&a, &noisy(0.01, &mut rng));
        let p2 = psnr(&a, &noisy(0.1, &mut rng));
        let p3 = psnr(&a, &noisy(0.5, &mut rng));
        assert!(p1 > p2 && p2 > p3, "{p1} {p2} {p3}");
    }

    #[test]
    fn batch_psnr_averages() {
        let a = vec![0.0f32; 200];
        let mut b = vec![0.0f32; 200];
        for v in b[100..].iter_mut() {
            *v = 0.2;
        }
        // first image identical (capped 99), second 20 dB
        let got = batch_psnr(&a, &b, 100);
        assert!((got - (99.0 + 20.0) / 2.0).abs() < 1e-5);
    }
}
