//! Inception-v3 stand-in: a fixed random-projection feature network.
//!
//! Assumptions 1-D/1-E only require an L_φ-Lipschitz feature extractor
//! whose embeddings are ~Gaussian. We use a frozen 2-layer random net
//! φ(x) = W₂ tanh(W₁ x): tanh is 1-Lipschitz, so
//! L_φ ≤ ‖W₂‖₂ ‖W₁‖₂ — and unlike Inception we can *compute* that bound,
//! making the Theorem 3/6 bound checks in EXPERIMENTS.md concrete.

use crate::tensor::matmul_into;
use crate::util::rng::Pcg64;

/// Frozen feature extractor.
pub struct FeatureNet {
    pub in_dim: usize,
    pub hidden: usize,
    pub out_dim: usize,
    w1: Vec<f32>, // [in, hidden]
    w2: Vec<f32>, // [hidden, out]
}

pub const FEAT_DIM: usize = 64;
pub const FEAT_HIDDEN: usize = 256;

impl FeatureNet {
    /// Deterministic net (fixed seed) — every experiment shares it.
    pub fn standard(in_dim: usize) -> Self {
        Self::new(in_dim, FEAT_HIDDEN, FEAT_DIM, 0x0F_EA_70)
    }

    pub fn new(in_dim: usize, hidden: usize, out_dim: usize, seed: u64) -> Self {
        let mut rng = Pcg64::seed(seed);
        let s1 = 1.0 / (in_dim as f32).sqrt();
        let s2 = 1.0 / (hidden as f32).sqrt();
        let w1 = (0..in_dim * hidden)
            .map(|_| rng.normal_f32(0.0, s1))
            .collect();
        let w2 = (0..hidden * out_dim)
            .map(|_| rng.normal_f32(0.0, s2))
            .collect();
        Self {
            in_dim,
            hidden,
            out_dim,
            w1,
            w2,
        }
    }

    /// Embed a batch: xs flat [n, in_dim] -> [n, out_dim].
    pub fn embed(&self, xs: &[f32]) -> Vec<f32> {
        assert_eq!(xs.len() % self.in_dim, 0);
        let n = xs.len() / self.in_dim;
        let mut h = vec![0f32; n * self.hidden];
        matmul_into(xs, &self.w1, &mut h, n, self.in_dim, self.hidden);
        for v in h.iter_mut() {
            *v = v.tanh();
        }
        let mut out = vec![0f32; n * self.out_dim];
        matmul_into(&h, &self.w2, &mut out, n, self.hidden, self.out_dim);
        out
    }

    /// Upper bound on L_φ via power iteration on W₁ᵀW₁ and W₂ᵀW₂:
    /// L_φ ≤ σ_max(W₁) σ_max(W₂) (tanh is 1-Lipschitz).
    pub fn lipschitz_bound(&self) -> f64 {
        spectral_norm(&self.w1, self.in_dim, self.hidden)
            * spectral_norm(&self.w2, self.hidden, self.out_dim)
    }
}

/// Largest singular value of a [m, n] matrix by power iteration.
pub fn spectral_norm(a: &[f32], m: usize, n: usize) -> f64 {
    let mut rng = Pcg64::seed(0x5EC7);
    let mut v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let mut sigma = 0.0f64;
    for _ in 0..60 {
        // u = A v
        let mut u = vec![0f64; m];
        for i in 0..m {
            let mut s = 0.0;
            for j in 0..n {
                s += a[i * n + j] as f64 * v[j];
            }
            u[i] = s;
        }
        let un = u.iter().map(|x| x * x).sum::<f64>().sqrt().max(1e-30);
        for x in u.iter_mut() {
            *x /= un;
        }
        // v = Aᵀ u
        let mut v2 = vec![0f64; n];
        for i in 0..m {
            let ui = u[i];
            for j in 0..n {
                v2[j] += a[i * n + j] as f64 * ui;
            }
        }
        sigma = v2.iter().map(|x| x * x).sum::<f64>().sqrt();
        for x in v2.iter_mut() {
            *x /= sigma.max(1e-30);
        }
        v = v2;
    }
    sigma
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn embed_shapes() {
        let net = FeatureNet::new(32, 64, 16, 1);
        let xs = vec![0.1f32; 5 * 32];
        let e = net.embed(&xs);
        assert_eq!(e.len(), 5 * 16);
    }

    #[test]
    fn deterministic_standard_net() {
        let a = FeatureNet::standard(768).embed(&vec![0.5f32; 768]);
        let b = FeatureNet::standard(768).embed(&vec![0.5f32; 768]);
        assert_eq!(a, b);
    }

    #[test]
    fn spectral_norm_of_diagonal() {
        // diag(1, 2, 7) embedded in 3x3
        let a = vec![1.0, 0.0, 0.0, 0.0, 2.0, 0.0, 0.0, 0.0, 7.0];
        let s = spectral_norm(&a, 3, 3);
        assert!((s - 7.0).abs() < 1e-6, "s={s}");
    }

    /// The Lipschitz bound must actually hold on random probes — this is
    /// Assumption 1-D, verified by construction.
    #[test]
    fn lipschitz_bound_holds_empirically() {
        let net = FeatureNet::new(48, 96, 24, 2);
        let bound = net.lipschitz_bound();
        let mut rng = Pcg64::seed(3);
        for _ in 0..50 {
            let x: Vec<f32> = (0..48).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let mut y = x.clone();
            let i = rng.below(48);
            y[i] += 0.01;
            let ex = net.embed(&x);
            let ey = net.embed(&y);
            let num: f64 = ex
                .iter()
                .zip(ey.iter())
                .map(|(&a, &b)| ((a - b) as f64).powi(2))
                .sum::<f64>()
                .sqrt();
            let den = 0.01f64;
            assert!(num / den <= bound * 1.001, "ratio {} > bound {bound}", num / den);
        }
    }

    /// Assumption 1-E: embeddings of image batches are near-Gaussian per
    /// coordinate (loose normality check via standardized moments).
    #[test]
    fn embeddings_roughly_gaussian() {
        use crate::data::Dataset;
        let net = FeatureNet::standard(crate::data::IMG_D);
        let mut rng = Pcg64::seed(4);
        let batch = Dataset::SynthImagenet.batch(&mut rng, 256);
        let e = net.embed(&batch);
        // per-dim skewness should be small on average
        let d = net.out_dim;
        let n = e.len() / d;
        let mut mean_abs_skew = 0.0f64;
        for j in 0..d {
            let col: Vec<f32> = (0..n).map(|i| e[i * d + j]).collect();
            let (m, v) = crate::stats::mean_var(&col);
            let sd = v.sqrt().max(1e-9);
            let skew: f64 = col
                .iter()
                .map(|&x| ((x as f64 - m) / sd).powi(3))
                .sum::<f64>()
                / n as f64;
            mean_abs_skew += skew.abs();
        }
        mean_abs_skew /= d as f64;
        assert!(mean_abs_skew < 1.0, "skew={mean_abs_skew}");
    }
}
