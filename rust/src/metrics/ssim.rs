//! Structural Similarity Index (SSIM) — the paper's perceptual metric
//! (Fig. 3A). Full windowed implementation: 8×8 gaussian-weighted windows
//! slid over each channel, per-window luminance/contrast/structure terms,
//! averaged. Constants follow Wang et al. 2004 with L = 2 ([-1, 1] range).

use crate::data::{IMG_C, IMG_HW};

const WIN: usize = 8;
const SIGMA: f64 = 1.5;
const K1: f64 = 0.01;
const K2: f64 = 0.03;
const L: f64 = 2.0; // dynamic range of [-1, 1]

/// Precomputed normalized gaussian window weights.
fn gaussian_window() -> [f64; WIN * WIN] {
    let mut w = [0f64; WIN * WIN];
    let c = (WIN as f64 - 1.0) / 2.0;
    let mut sum = 0.0;
    for y in 0..WIN {
        for x in 0..WIN {
            let dx = x as f64 - c;
            let dy = y as f64 - c;
            let g = (-(dx * dx + dy * dy) / (2.0 * SIGMA * SIGMA)).exp();
            w[y * WIN + x] = g;
            sum += g;
        }
    }
    for v in w.iter_mut() {
        *v /= sum;
    }
    w
}

/// SSIM between two flattened [IMG_HW, IMG_HW, IMG_C] images in [-1, 1].
pub fn ssim(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), IMG_HW * IMG_HW * IMG_C);
    assert_eq!(a.len(), b.len());
    let w = gaussian_window();
    let c1 = (K1 * L) * (K1 * L);
    let c2 = (K2 * L) * (K2 * L);

    let mut total = 0.0f64;
    let mut count = 0usize;
    // stride-2 window placement: dense enough for 16x16, 5x5 windows/chan
    for ch in 0..IMG_C {
        let px = |img: &[f32], x: usize, y: usize| img[(y * IMG_HW + x) * IMG_C + ch] as f64;
        let mut wy = 0;
        while wy + WIN <= IMG_HW {
            let mut wx = 0;
            while wx + WIN <= IMG_HW {
                // weighted moments inside the window
                let (mut ma, mut mb) = (0.0f64, 0.0f64);
                for y in 0..WIN {
                    for x in 0..WIN {
                        let g = w[y * WIN + x];
                        ma += g * px(a, wx + x, wy + y);
                        mb += g * px(b, wx + x, wy + y);
                    }
                }
                let (mut va, mut vb, mut cov) = (0.0f64, 0.0f64, 0.0f64);
                for y in 0..WIN {
                    for x in 0..WIN {
                        let g = w[y * WIN + x];
                        let da = px(a, wx + x, wy + y) - ma;
                        let db = px(b, wx + x, wy + y) - mb;
                        va += g * da * da;
                        vb += g * db * db;
                        cov += g * da * db;
                    }
                }
                let s = ((2.0 * ma * mb + c1) * (2.0 * cov + c2))
                    / ((ma * ma + mb * mb + c1) * (va + vb + c2));
                total += s;
                count += 1;
                wx += 2;
            }
            wy += 2;
        }
    }
    total / count as f64
}

/// Mean SSIM over a batch of flattened images.
pub fn batch_ssim(reference: &[f32], test: &[f32], img_len: usize) -> f64 {
    assert_eq!(reference.len(), test.len());
    let n = reference.len() / img_len;
    let mut acc = 0.0;
    for i in 0..n {
        acc += ssim(
            &reference[i * img_len..(i + 1) * img_len],
            &test[i * img_len..(i + 1) * img_len],
        );
    }
    acc / n as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Dataset, IMG_D};
    use crate::util::rng::Pcg64;

    #[test]
    fn identical_images_score_one() {
        let mut rng = Pcg64::seed(1);
        let img = Dataset::SynthCifar.sample(&mut rng);
        assert!((ssim(&img, &img) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn independent_images_score_low() {
        let mut rng = Pcg64::seed(2);
        let a = Dataset::SynthCifar.sample(&mut rng);
        let b = Dataset::SynthCifar.sample(&mut rng);
        let s = ssim(&a, &b);
        assert!(s < 0.6, "s={s}");
    }

    #[test]
    fn monotone_in_noise_amplitude() {
        let mut rng = Pcg64::seed(3);
        let img = Dataset::SynthCeleba.sample(&mut rng);
        let noisy = |amp: f32, rng: &mut Pcg64| -> Vec<f32> {
            img.iter()
                .map(|&x| (x + rng.normal_f32(0.0, amp)).clamp(-1.0, 1.0))
                .collect()
        };
        let s1 = ssim(&img, &noisy(0.02, &mut rng));
        let s2 = ssim(&img, &noisy(0.1, &mut rng));
        let s3 = ssim(&img, &noisy(0.4, &mut rng));
        assert!(s1 > s2 && s2 > s3, "{s1} {s2} {s3}");
        assert!(s1 > 0.8);
    }

    #[test]
    fn symmetric() {
        let mut rng = Pcg64::seed(4);
        let a = Dataset::SynthMnist.sample(&mut rng);
        let b = Dataset::SynthMnist.sample(&mut rng);
        assert!((ssim(&a, &b) - ssim(&b, &a)).abs() < 1e-12);
    }

    #[test]
    fn bounded_above_by_one() {
        let mut rng = Pcg64::seed(5);
        for _ in 0..5 {
            let a = Dataset::SynthImagenet.sample(&mut rng);
            let b = Dataset::SynthImagenet.sample(&mut rng);
            assert!(ssim(&a, &b) <= 1.0 + 1e-9);
        }
    }

    #[test]
    fn batch_matches_singles() {
        let mut rng = Pcg64::seed(6);
        let a1 = Dataset::SynthCifar.sample(&mut rng);
        let a2 = Dataset::SynthCifar.sample(&mut rng);
        let b1 = Dataset::SynthCifar.sample(&mut rng);
        let b2 = Dataset::SynthCifar.sample(&mut rng);
        let mut ra = a1.clone();
        ra.extend_from_slice(&a2);
        let mut rb = b1.clone();
        rb.extend_from_slice(&b2);
        let got = batch_ssim(&ra, &rb, IMG_D);
        let want = (ssim(&a1, &b1) + ssim(&a2, &b2)) / 2.0;
        assert!((got - want).abs() < 1e-12);
    }
}
