//! Mode coverage — the paper's future-work "sample diversity / mode
//! coverage" item, made measurable on the class-structured synthetic
//! datasets.
//!
//! Each generated image is matched to its nearest class template (mean
//! image over many samples of that class-conditioned generator), and the
//! class histogram is summarized by (a) covered-mode fraction and (b)
//! normalized entropy. A collapsed generator maps everything to one
//! template; a healthy one spreads mass across all of them.

use crate::data::{Dataset, IMG_D};
use crate::util::rng::Pcg64;

/// Mean-image templates per latent class of a dataset, estimated by
/// sampling the generator and clustering by the generator's own class
/// (re-derived by seeding: we draw many samples and k-means-initialize
/// from dataset structure). For the stroke-based datasets the class is the
/// dominant mode, so template extraction via k-means on samples works.
pub struct Templates {
    pub k: usize,
    pub means: Vec<f32>, // flat [k, IMG_D]
}

impl Templates {
    /// Build templates by k-means over dataset samples (k = class count).
    pub fn build(dataset: Dataset, rng: &mut Pcg64, n_samples: usize, iters: usize) -> Self {
        let k = dataset.classes().max(2).min(16);
        let data = dataset.batch(rng, n_samples);
        let n = n_samples;
        // k-means++ style init: pick spread-out samples
        let mut means = Vec::with_capacity(k * IMG_D);
        means.extend_from_slice(&data[..IMG_D]);
        while means.len() < k * IMG_D {
            // farthest-point heuristic
            let mut best = (0usize, -1.0f64);
            for i in 0..n {
                let xi = &data[i * IMG_D..(i + 1) * IMG_D];
                let mut dmin = f64::INFINITY;
                for c in 0..means.len() / IMG_D {
                    let m = &means[c * IMG_D..(c + 1) * IMG_D];
                    let d: f64 = xi
                        .iter()
                        .zip(m.iter())
                        .map(|(&a, &b)| ((a - b) as f64).powi(2))
                        .sum();
                    dmin = dmin.min(d);
                }
                if dmin > best.1 {
                    best = (i, dmin);
                }
            }
            means.extend_from_slice(&data[best.0 * IMG_D..(best.0 + 1) * IMG_D]);
        }
        // Lloyd iterations
        let mut assign = vec![0usize; n];
        for _ in 0..iters {
            for i in 0..n {
                assign[i] = nearest(&data[i * IMG_D..(i + 1) * IMG_D], &means, k);
            }
            let mut sums = vec![0f64; k * IMG_D];
            let mut counts = vec![0usize; k];
            for i in 0..n {
                let c = assign[i];
                counts[c] += 1;
                for j in 0..IMG_D {
                    sums[c * IMG_D + j] += data[i * IMG_D + j] as f64;
                }
            }
            for c in 0..k {
                if counts[c] > 0 {
                    for j in 0..IMG_D {
                        means[c * IMG_D + j] = (sums[c * IMG_D + j] / counts[c] as f64) as f32;
                    }
                }
            }
        }
        Self { k, means }
    }

    /// Assign each image in a flat batch to its nearest template.
    pub fn classify(&self, imgs: &[f32]) -> Vec<usize> {
        imgs.chunks(IMG_D)
            .map(|img| nearest(img, &self.means, self.k))
            .collect()
    }
}

fn nearest(img: &[f32], means: &[f32], k: usize) -> usize {
    let mut best = (0usize, f64::INFINITY);
    for c in 0..k {
        let m = &means[c * IMG_D..(c + 1) * IMG_D];
        let d: f64 = img
            .iter()
            .zip(m.iter())
            .map(|(&a, &b)| ((a - b) as f64).powi(2))
            .sum();
        if d < best.1 {
            best = (c, d);
        }
    }
    best.0
}

/// Coverage summary of a generated batch.
#[derive(Clone, Copy, Debug)]
pub struct Coverage {
    /// fraction of templates hit at least once
    pub covered: f64,
    /// Shannon entropy of the class histogram, normalized to [0, 1]
    pub entropy: f64,
}

pub fn coverage(templates: &Templates, imgs: &[f32]) -> Coverage {
    let assign = templates.classify(imgs);
    let mut counts = vec![0usize; templates.k];
    for &a in &assign {
        counts[a] += 1;
    }
    let n = assign.len() as f64;
    let covered = counts.iter().filter(|&&c| c > 0).count() as f64 / templates.k as f64;
    let entropy: f64 = counts
        .iter()
        .filter(|&&c| c > 0)
        .map(|&c| {
            let p = c as f64 / n;
            -p * p.log2()
        })
        .sum::<f64>()
        / (templates.k as f64).log2();
    Coverage { covered, entropy }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dataset_samples_cover_their_own_modes() {
        let mut rng = Pcg64::seed(1);
        let t = Templates::build(Dataset::SynthMnist, &mut rng, 200, 8);
        let fresh = Dataset::SynthMnist.batch(&mut rng, 200);
        let cov = coverage(&t, &fresh);
        assert!(cov.covered > 0.7, "covered={}", cov.covered);
        assert!(cov.entropy > 0.6, "entropy={}", cov.entropy);
    }

    #[test]
    fn collapsed_batch_scores_low() {
        let mut rng = Pcg64::seed(2);
        let t = Templates::build(Dataset::SynthMnist, &mut rng, 150, 6);
        // one image repeated = total mode collapse
        let one = Dataset::SynthMnist.sample(&mut rng);
        let collapsed: Vec<f32> = (0..50).flat_map(|_| one.clone()).collect();
        let cov = coverage(&t, &collapsed);
        assert!(cov.covered <= 0.2, "covered={}", cov.covered);
        assert!(cov.entropy < 0.05, "entropy={}", cov.entropy);
    }

    #[test]
    fn classify_matches_template_count() {
        let mut rng = Pcg64::seed(3);
        let t = Templates::build(Dataset::SynthFashion, &mut rng, 100, 4);
        let imgs = Dataset::SynthFashion.batch(&mut rng, 10);
        let a = t.classify(&imgs);
        assert_eq!(a.len(), 10);
        assert!(a.iter().all(|&c| c < t.k));
    }
}
