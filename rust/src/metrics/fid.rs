//! Fréchet distance between embedded sample batches (the FID construction
//! of Assumption 1-E / Lemma 2):
//!
//!   FID = ‖m₁ − m₂‖² + tr(Σ₁ + Σ₂ − 2 (Σ₁^{1/2} Σ₂ Σ₁^{1/2})^{1/2})
//!
//! computed over [`features::FeatureNet`] embeddings with the Jacobi
//! eigensolver from [`crate::linalg`].

use crate::linalg::{covariance, sqrtm_psd, SymMat};
use crate::metrics::features::FeatureNet;

/// Fréchet distance between two embedded batches (flat [n, d] each).
pub fn frechet_distance(ea: &[f32], eb: &[f32], d: usize) -> f64 {
    assert_eq!(ea.len() % d, 0);
    assert_eq!(eb.len() % d, 0);
    let (ma, ca) = covariance(ea, ea.len() / d, d);
    let (mb, cb) = covariance(eb, eb.len() / d, d);
    frechet_gaussians(&ma, &ca, &mb, &cb)
}

/// Fréchet distance between two Gaussians given moments.
pub fn frechet_gaussians(ma: &[f64], ca: &SymMat, mb: &[f64], cb: &SymMat) -> f64 {
    let d2: f64 = ma
        .iter()
        .zip(mb.iter())
        .map(|(a, b)| (a - b) * (a - b))
        .sum();
    let sa = sqrtm_psd(ca);
    let inner = sa.matmul(cb).matmul(&sa);
    let mut inner_sym = inner;
    inner_sym.symmetrize(); // numerical asymmetry cleanup
    let cross = sqrtm_psd(&inner_sym);
    let tr = ca.trace() + cb.trace() - 2.0 * cross.trace();
    (d2 + tr).max(0.0)
}

/// FID between two image batches using the standard feature net.
pub fn fid_images(net: &FeatureNet, imgs_a: &[f32], imgs_b: &[f32]) -> f64 {
    let ea = net.embed(imgs_a);
    let eb = net.embed(imgs_b);
    frechet_distance(&ea, &eb, net.out_dim)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Dataset;
    use crate::util::rng::Pcg64;

    #[test]
    fn zero_for_identical_batches() {
        let mut rng = Pcg64::seed(1);
        let e: Vec<f32> = (0..200 * 8).map(|_| rng.normal() as f32).collect();
        let f = frechet_distance(&e, &e, 8);
        assert!(f.abs() < 1e-6, "f={f}");
    }

    #[test]
    fn mean_shift_gives_squared_distance() {
        // identical covariance, mean shift u: FID = ||u||^2
        let mut rng = Pcg64::seed(2);
        let n = 60_000;
        let d = 4;
        let a: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let shift = [0.5f32, -0.25, 0.0, 1.0];
        let b: Vec<f32> = a
            .iter()
            .enumerate()
            .map(|(i, &x)| x + shift[i % d])
            .collect();
        let want: f64 = shift.iter().map(|&s| (s as f64) * (s as f64)).sum();
        let got = frechet_distance(&a, &b, d);
        assert!((got - want).abs() < 0.05, "got={got} want={want}");
    }

    #[test]
    fn scale_change_known_value() {
        // N(0, I) vs N(0, 4I) in d dims: FID = d(1 + 4 - 2*2) = d
        let mut rng = Pcg64::seed(3);
        let n = 120_000;
        let d = 3;
        let a: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..n * d).map(|_| 2.0 * rng.normal() as f32).collect();
        let got = frechet_distance(&a, &b, d);
        assert!((got - d as f64).abs() < 0.1, "got={got}");
    }

    #[test]
    fn fid_separates_datasets() {
        let net = FeatureNet::standard(crate::data::IMG_D);
        let mut rng = Pcg64::seed(4);
        let a1 = Dataset::SynthMnist.batch(&mut rng, 128);
        let a2 = Dataset::SynthMnist.batch(&mut rng, 128);
        let b = Dataset::SynthImagenet.batch(&mut rng, 128);
        let same = fid_images(&net, &a1, &a2);
        let diff = fid_images(&net, &a1, &b);
        assert!(diff > 5.0 * same, "same={same} diff={diff}");
    }

    #[test]
    fn symmetric_metric() {
        let mut rng = Pcg64::seed(5);
        let a: Vec<f32> = (0..500 * 4).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..500 * 4).map(|_| rng.normal() as f32 * 1.3 + 0.2).collect();
        let ab = frechet_distance(&a, &b, 4);
        let ba = frechet_distance(&b, &a, 4);
        assert!((ab - ba).abs() < 1e-6 * (1.0 + ab), "{ab} vs {ba}");
    }
}
