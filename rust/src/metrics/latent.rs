//! Latent-space stability statistics (the paper's Fig. 4).
//!
//! The FM "latent" of an image is the base-distribution point reached by
//! integrating the probability-flow ODE *backwards* (data → noise). For a
//! healthy model the latents are ~N(0, I), so the per-dimension variances
//! cluster tightly around 1. Quantization noise destabilizes the reverse
//! flow; the paper measures that as the *standard deviation of the
//! per-dimension latent variances* — flat for OT, exploding for
//! uniform/log2 at low bits.

/// Summary of a latent batch (flat [n, d]).
#[derive(Clone, Copy, Debug)]
pub struct LatentStats {
    /// mean of per-dimension variances (≈1 for a healthy model)
    pub var_mean: f64,
    /// std of per-dimension variances — Fig. 4's y-axis
    pub var_std: f64,
    /// mean |latent| magnitude (sanity: should stay O(1))
    pub mean_abs: f64,
    /// max |latent| (explosion detector)
    pub max_abs: f64,
}

pub fn latent_stats(latents: &[f32], d: usize) -> LatentStats {
    assert!(d > 0 && latents.len() % d == 0);
    let n = latents.len() / d;
    assert!(n > 1, "need at least 2 latents");
    let mut var_per_dim = Vec::with_capacity(d);
    for j in 0..d {
        let mut mean = 0.0f64;
        for i in 0..n {
            mean += latents[i * d + j] as f64;
        }
        mean /= n as f64;
        let mut var = 0.0f64;
        for i in 0..n {
            let dlt = latents[i * d + j] as f64 - mean;
            var += dlt * dlt;
        }
        var_per_dim.push(var / n as f64);
    }
    let vm = var_per_dim.iter().sum::<f64>() / d as f64;
    let vs = (var_per_dim.iter().map(|v| (v - vm) * (v - vm)).sum::<f64>() / d as f64).sqrt();
    let mean_abs = latents.iter().map(|&x| x.abs() as f64).sum::<f64>() / latents.len() as f64;
    let max_abs = latents.iter().fold(0.0f64, |m, &x| m.max(x.abs() as f64));
    LatentStats {
        var_mean: vm,
        var_std: vs,
        mean_abs,
        max_abs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn standard_normal_latents() {
        let mut rng = Pcg64::seed(1);
        let (n, d) = (2000, 32);
        let l: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let s = latent_stats(&l, d);
        assert!((s.var_mean - 1.0).abs() < 0.05, "{}", s.var_mean);
        assert!(s.var_std < 0.1, "{}", s.var_std);
        assert!((s.mean_abs - 0.7979).abs() < 0.05); // E|N(0,1)| = sqrt(2/pi)
    }

    #[test]
    fn heteroscedastic_latents_have_high_var_std() {
        let mut rng = Pcg64::seed(2);
        let (n, d) = (2000, 16);
        // half the dims exploded to std 5
        let l: Vec<f32> = (0..n * d)
            .map(|i| {
                let j = i % d;
                let s = if j < d / 2 { 1.0 } else { 5.0 };
                rng.normal_f32(0.0, s)
            })
            .collect();
        let s = latent_stats(&l, d);
        assert!(s.var_std > 5.0, "{}", s.var_std);
    }

    #[test]
    fn detects_explosion() {
        let mut l = vec![0.1f32; 100 * 4];
        l[13] = 1e4;
        let s = latent_stats(&l, 4);
        assert!(s.max_abs >= 1e4);
    }
}
