//! Deterministic, seeded fault injection for the serving stack.
//!
//! A [`FaultPlan`] is a small schedule of injected failures — "panic on
//! the Nth super-batch", "sleep 25 ms before the 2nd batch for model X",
//! "sever the connection before the 3rd reply write", "exercise torn
//! artifact writes" — parsed from a compact spec string (usually the
//! `FMQ_FAULTS` environment variable) and threaded through
//! [`crate::coordinator::server`]. Because every rule fires on a fixed
//! ordinal of a deterministic event stream, a failing chaos run
//! reproduces byte-for-byte from the spec alone; there is no randomness
//! at the injection sites themselves (the seed only drives *test-side*
//! derivations such as [`torn_points`]).
//!
//! ## Spec grammar
//!
//! Comma-separated rules, order-irrelevant, plus an optional seed:
//!
//! ```text
//! panic@batch:3            worker panics on its 3rd super-batch (any model)
//! panic@batch/ot2:1        ...only the worker serving model "ot2"
//! slow@batch/ot8:2:25ms    sleep 25 ms before ot8's 2nd super-batch
//! drop@reply:2             sever the socket before the 2nd reply write
//! torn@write:1             request torn-write coverage (drives torn_points)
//! seed=42                  seed for derived schedules (default 0)
//! ```
//!
//! ## Feature gating
//!
//! The real implementation only exists under `--features faults`. The
//! default build gets the zero-sized twin at the bottom of this file
//! (mirroring the `no-obs` treatment of [`crate::obs::span::Span`]):
//! every query inlines to "no fault", `parse` accepts anything and
//! returns the inert plan, and the serving hot path carries no branch
//! cost and no allocations for a subsystem it cannot observe.

use crate::util::rng::Pcg64;

/// Outcome of asking the plan about the next super-batch for a model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BatchFault {
    /// Run the batch normally.
    None,
    /// Panic inside the batch run (contained by the supervisor's
    /// `catch_unwind`; exercises respawn).
    Panic,
    /// Sleep this long before running the batch (exercises deadlines,
    /// queue buildup and load shedding). Milliseconds.
    Slow(u64),
}

/// Outcome of asking the plan about the next reply write.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReplyFault {
    /// Write the reply normally.
    None,
    /// Sever the client socket before the write (exercises the
    /// disconnect-mid-reply accounting in `handle_conn`).
    Drop,
}

/// Deterministic truncation points for torn-write tests: structural
/// boundaries of the FMQ1 container (mid-magic, mid-kind, mid-header-len,
/// start of header) plus seeded interior cuts. Sorted, deduplicated, and
/// strictly less than `len`, so every point yields a genuinely truncated
/// file. Available in all builds — checkpoint corruption tests run in
/// tier-1, not just under `--features faults`.
pub fn torn_points(seed: u64, len: usize) -> Vec<usize> {
    let mut pts: Vec<usize> = vec![0, 2, 4, 6, 8, 11, 12];
    if len > 0 {
        let mut rng = Pcg64::seed(seed ^ 0x7042_5f70_6f69_6e74); // "tB_point"
        for _ in 0..8 {
            pts.push(rng.below(len));
        }
        if len >= 2 {
            pts.push(len - 1);
            pts.push(len / 2);
        }
    }
    pts.retain(|&p| p < len);
    pts.sort_unstable();
    pts.dedup();
    pts
}

#[cfg(feature = "faults")]
mod real {
    use std::sync::atomic::{AtomicU64, Ordering};

    use anyhow::{bail, Context, Result};

    use super::{BatchFault, ReplyFault};

    #[derive(Debug, Clone, Copy, PartialEq, Eq)]
    enum Action {
        Panic,
        Slow(u64),
        Drop,
        Torn,
    }

    #[derive(Debug)]
    struct Rule {
        action: Action,
        /// `None` matches every model (batch-site rules only).
        model: Option<String>,
        /// 1-based ordinal of the matching event this rule fires on.
        nth: u64,
        /// Matching events seen so far; the rule fires exactly once,
        /// when this count reaches `nth`.
        hits: AtomicU64,
    }

    impl Rule {
        /// Count one matching event; true exactly when it is the nth.
        fn fire(&self) -> bool {
            self.hits.fetch_add(1, Ordering::Relaxed) + 1 == self.nth
        }
    }

    /// A parsed, seeded fault schedule. Interior counters make the plan
    /// shareable (`Arc<FaultPlan>`) across worker and connection threads
    /// while each rule still fires exactly once.
    #[derive(Debug)]
    pub struct FaultPlan {
        seed: u64,
        rules: Vec<Rule>,
    }

    impl FaultPlan {
        /// The empty plan: injects nothing.
        pub fn none() -> Self {
            Self {
                seed: 0,
                rules: Vec::new(),
            }
        }

        /// Parse a spec string (see the module docs for the grammar).
        pub fn parse(spec: &str) -> Result<Self> {
            let mut seed = 0u64;
            let mut rules = Vec::new();
            for part in spec.split(',') {
                let part = part.trim();
                if part.is_empty() {
                    continue;
                }
                if let Some(v) = part.strip_prefix("seed=") {
                    seed = v
                        .parse()
                        .with_context(|| format!("bad seed in fault rule '{part}'"))?;
                    continue;
                }
                rules.push(parse_rule(part)?);
            }
            Ok(Self { seed, rules })
        }

        /// Parse the `FMQ_FAULTS` environment variable (empty/unset →
        /// the empty plan).
        pub fn from_env() -> Result<Self> {
            match std::env::var("FMQ_FAULTS") {
                Ok(spec) => Self::parse(&spec),
                Err(_) => Ok(Self::none()),
            }
        }

        pub fn is_empty(&self) -> bool {
            self.rules.is_empty()
        }

        /// Number of parsed rules (0 in inert builds).
        pub fn rules_len(&self) -> usize {
            self.rules.len()
        }

        /// Seed for derived schedules such as [`super::torn_points`].
        pub fn seed(&self) -> u64 {
            self.seed
        }

        /// True if the plan requests torn-write coverage (`torn@write:N`).
        pub fn wants_torn_writes(&self) -> bool {
            self.rules.iter().any(|r| r.action == Action::Torn)
        }

        /// Called by the worker once per non-empty super-batch, before
        /// running it. Counts the event against every batch-site rule
        /// whose model filter matches; the first rule reaching its
        /// ordinal decides the outcome.
        pub fn on_batch(&self, model: &str) -> BatchFault {
            let mut out = BatchFault::None;
            for r in &self.rules {
                let matches = match r.action {
                    Action::Panic | Action::Slow(_) => match r.model.as_deref() {
                        Some(m) => m == model,
                        None => true,
                    },
                    _ => false,
                };
                if matches && r.fire() && out == BatchFault::None {
                    out = match r.action {
                        Action::Panic => BatchFault::Panic,
                        Action::Slow(ms) => BatchFault::Slow(ms),
                        _ => BatchFault::None,
                    };
                }
            }
            out
        }

        /// Called by a connection handler once per reply, before the
        /// write. Replies are counted across all connections in arrival
        /// order, which is deterministic for sequential test clients.
        pub fn on_reply(&self) -> ReplyFault {
            let mut out = ReplyFault::None;
            for r in &self.rules {
                if r.action == Action::Drop && r.fire() && out == ReplyFault::None {
                    out = ReplyFault::Drop;
                }
            }
            out
        }
    }

    fn parse_rule(part: &str) -> Result<Rule> {
        let (action, rest) = part
            .split_once('@')
            .with_context(|| format!("fault rule '{part}' missing '@site'"))?;
        let mut fields = rest.split(':');
        let site = fields.next().unwrap_or("");
        let (site, model) = match site.split_once('/') {
            Some((s, m)) => (s, Some(m.to_string())),
            None => (site, None),
        };
        let nth: u64 = fields
            .next()
            .with_context(|| format!("fault rule '{part}' missing ':N' ordinal"))?
            .parse()
            .with_context(|| format!("bad ordinal in fault rule '{part}'"))?;
        if nth == 0 {
            bail!("fault rule '{part}': ordinals are 1-based");
        }
        let extra = fields.next();
        if fields.next().is_some() {
            bail!("fault rule '{part}' has trailing fields");
        }
        let action = match (action, site) {
            ("panic", "batch") => Action::Panic,
            ("slow", "batch") => {
                let ms = extra
                    .with_context(|| format!("slow rule '{part}' missing ':<ms>' duration"))?;
                let ms = ms.strip_suffix("ms").unwrap_or(ms);
                Action::Slow(
                    ms.parse()
                        .with_context(|| format!("bad duration in fault rule '{part}'"))?,
                )
            }
            ("drop", "reply") => Action::Drop,
            ("torn", "write") => Action::Torn,
            _ => bail!("unknown fault rule '{part}' (want action@site)"),
        };
        if !matches!(action, Action::Slow(_)) && extra.is_some() {
            bail!("fault rule '{part}' has a trailing duration field");
        }
        if model.is_some() && !matches!(action, Action::Panic | Action::Slow(_)) {
            bail!("fault rule '{part}': only batch-site rules take a /model filter");
        }
        Ok(Rule {
            action,
            model,
            nth,
            hits: AtomicU64::new(0),
        })
    }
}

#[cfg(feature = "faults")]
pub use real::FaultPlan;

/// Inert zero-sized twin: the default build's `FaultPlan`. Every query
/// answers "no fault" and `parse`/`from_env` accept any spec without
/// acting on it (the CLI prints a notice when `FMQ_FAULTS` is set on a
/// build that cannot honor it).
#[cfg(not(feature = "faults"))]
#[derive(Debug, Clone, Copy, Default)]
pub struct FaultPlan;

#[cfg(not(feature = "faults"))]
impl FaultPlan {
    #[inline]
    pub fn none() -> Self {
        Self
    }

    #[inline]
    pub fn parse(_spec: &str) -> anyhow::Result<Self> {
        Ok(Self)
    }

    #[inline]
    pub fn from_env() -> anyhow::Result<Self> {
        Ok(Self)
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        true
    }

    #[inline]
    pub fn rules_len(&self) -> usize {
        0
    }

    #[inline]
    pub fn seed(&self) -> u64 {
        0
    }

    #[inline]
    pub fn wants_torn_writes(&self) -> bool {
        false
    }

    #[inline]
    pub fn on_batch(&self, _model: &str) -> BatchFault {
        BatchFault::None
    }

    #[inline]
    pub fn on_reply(&self) -> ReplyFault {
        ReplyFault::None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn torn_points_are_deterministic_sorted_and_in_range() {
        let a = torn_points(9, 1000);
        let b = torn_points(9, 1000);
        assert_eq!(a, b, "same seed+len must give the same cuts");
        assert!(!a.is_empty());
        assert!(a.windows(2).all(|w| w[0] < w[1]), "sorted + deduped");
        assert!(a.iter().all(|&p| p < 1000), "every cut truncates");
        // structural boundaries of the FMQ1 container are always covered
        for p in [0usize, 4, 8, 12] {
            assert!(a.contains(&p), "missing structural cut {p}");
        }
        let c = torn_points(10, 1000);
        assert_ne!(a, c, "different seeds explore different interiors");
    }

    #[test]
    fn torn_points_handle_degenerate_lengths() {
        assert!(torn_points(1, 0).is_empty());
        assert_eq!(torn_points(1, 1), vec![0]);
    }

    #[test]
    fn inert_or_empty_plan_injects_nothing() {
        let plan = FaultPlan::none();
        assert!(plan.is_empty());
        assert_eq!(plan.rules_len(), 0);
        assert_eq!(plan.on_batch("ot2"), BatchFault::None);
        assert_eq!(plan.on_reply(), ReplyFault::None);
        assert!(!plan.wants_torn_writes());
    }

    #[cfg(feature = "faults")]
    #[test]
    fn rules_fire_exactly_once_on_their_ordinal() {
        let plan = FaultPlan::parse("panic@batch/ot2:2,slow@batch:3:25ms,seed=7").unwrap();
        assert_eq!(plan.seed(), 7);
        assert_eq!(plan.rules_len(), 2);
        // ot8 never matches the panic rule but counts toward the
        // unfiltered slow rule.
        assert_eq!(plan.on_batch("ot8"), BatchFault::None); // slow hit 1
        assert_eq!(plan.on_batch("ot2"), BatchFault::None); // panic hit 1, slow hit 2
        assert_eq!(plan.on_batch("ot2"), BatchFault::Panic); // panic hit 2 fires (slow hit 3 also fires; panic wins by rule order)
        assert_eq!(plan.on_batch("ot2"), BatchFault::None); // both spent
    }

    #[cfg(feature = "faults")]
    #[test]
    fn slow_fires_alone_on_its_ordinal() {
        let plan = FaultPlan::parse("slow@batch/ot8:2:40").unwrap();
        assert_eq!(plan.on_batch("ot8"), BatchFault::None);
        assert_eq!(plan.on_batch("ot8"), BatchFault::Slow(40));
        assert_eq!(plan.on_batch("ot8"), BatchFault::None);
    }

    #[cfg(feature = "faults")]
    #[test]
    fn reply_drops_count_globally() {
        let plan = FaultPlan::parse("drop@reply:2").unwrap();
        assert_eq!(plan.on_reply(), ReplyFault::None);
        assert_eq!(plan.on_reply(), ReplyFault::Drop);
        assert_eq!(plan.on_reply(), ReplyFault::None);
    }

    #[cfg(feature = "faults")]
    #[test]
    fn torn_rule_sets_coverage_flag() {
        let plan = FaultPlan::parse("torn@write:1,seed=9").unwrap();
        assert!(plan.wants_torn_writes());
        assert_eq!(plan.on_batch("x"), BatchFault::None);
    }

    #[cfg(feature = "faults")]
    #[test]
    fn bad_specs_are_rejected_with_context() {
        for bad in [
            "panic:3",            // missing @site
            "panic@batch",        // missing ordinal
            "panic@batch:0",      // ordinals are 1-based
            "panic@batch:x",      // non-numeric ordinal
            "slow@batch:1",       // missing duration
            "drop@reply/ot2:1",   // model filter on a non-batch site
            "explode@batch:1",    // unknown action
            "panic@batch:1:2:3",  // trailing fields
            "seed=banana",        // bad seed
            "drop@reply:1:10ms",  // trailing duration on a non-slow rule
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "spec '{bad}' should fail");
        }
        // empty / whitespace-only specs are the empty plan
        assert!(FaultPlan::parse("").unwrap().is_empty());
        assert!(FaultPlan::parse(" , ").unwrap().is_empty());
    }
}
