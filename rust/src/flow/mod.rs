//! Flow-matching drivers: the CPU reference forward (mirrors the L2 jax
//! model exactly), the Euler ODE sampler (forward generation and reverse
//! latent encoding), and the training-loop driver over the AOT
//! `train_step` artifact.

pub mod cpu_ref;
pub mod ode;
pub mod sampler;
pub mod train;
