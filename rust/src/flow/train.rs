//! Training driver: rust owns the loop, batches and RNG; the compiled
//! `train_step` artifact owns fwd/bwd/Adam. Loss curve is recorded for
//! EXPERIMENTS.md.

use anyhow::Result;

use crate::data::Dataset;
use crate::model::params::ParamStore;
use crate::runtime::ArtifactSet;
use crate::util::rng::Pcg64;

#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub steps: usize,
    pub lr: f32,
    pub seed: u64,
    /// print every N steps (0 = silent)
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            steps: 400,
            lr: 1e-3,
            seed: 42,
            log_every: 50,
        }
    }
}

#[derive(Clone, Debug)]
pub struct TrainResult {
    pub theta: ParamStore,
    /// (step, loss) curve
    pub losses: Vec<(usize, f32)>,
    pub wall_s: f64,
}

/// Train a velocity network on one dataset through the AOT train_step.
pub fn train(art: &ArtifactSet, dataset: Dataset, cfg: &TrainConfig) -> Result<TrainResult> {
    let spec = &art.spec;
    let mut rng = Pcg64::seed(cfg.seed);
    let mut theta = spec.init_theta(&mut rng);
    let p = spec.p();
    let mut m = vec![0f32; p];
    let mut v = vec![0f32; p];
    let b = art.b_train;
    let d = spec.d;
    let mut losses = Vec::new();
    let t0 = std::time::Instant::now();
    for step in 1..=cfg.steps {
        let x1 = dataset.batch(&mut rng, b);
        let x0: Vec<f32> = (0..b * d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let t: Vec<f32> = (0..b).map(|_| rng.uniform() as f32).collect();
        let (th2, m2, v2, loss) =
            art.train_step(&theta, &m, &v, step as f32, &x1, &x0, &t, cfg.lr)?;
        theta = ParamStore::new(th2);
        m = m2;
        v = v2;
        losses.push((step, loss));
        if cfg.log_every > 0 && step % cfg.log_every == 0 {
            let recent: f32 = losses[losses.len().saturating_sub(cfg.log_every)..]
                .iter()
                .map(|&(_, l)| l)
                .sum::<f32>()
                / cfg.log_every.min(losses.len()) as f32;
            println!(
                "  [train {}] step {step}/{} loss {loss:.3} (avg {recent:.3})",
                dataset.name(),
                cfg.steps
            );
        }
    }
    Ok(TrainResult {
        theta,
        losses,
        wall_s: t0.elapsed().as_secs_f64(),
    })
}

/// Smoothed early/late loss ratio — the "did it learn" check used by the
/// e2e example and EXPERIMENTS.md.
pub fn loss_improvement(losses: &[(usize, f32)]) -> f64 {
    if losses.len() < 20 {
        return 1.0;
    }
    let k = losses.len() / 10;
    let head: f64 = losses[..k].iter().map(|&(_, l)| l as f64).sum::<f64>() / k as f64;
    let tail: f64 = losses[losses.len() - k..]
        .iter()
        .map(|&(_, l)| l as f64)
        .sum::<f64>()
        / k as f64;
    head / tail.max(1e-9)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loss_improvement_ratio() {
        let losses: Vec<(usize, f32)> = (0..100).map(|i| (i, 100.0 / (i + 1) as f32)).collect();
        assert!(loss_improvement(&losses) > 5.0);
        let flat: Vec<(usize, f32)> = (0..100).map(|i| (i, 1.0)).collect();
        assert!((loss_improvement(&flat) - 1.0).abs() < 1e-6);
        assert_eq!(loss_improvement(&[(0, 1.0)]), 1.0);
    }

    #[test]
    fn default_config_sane() {
        let c = TrainConfig::default();
        assert!(c.steps >= 100);
        assert!(c.lr > 0.0);
    }
}
