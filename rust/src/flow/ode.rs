//! Higher-order ODE solvers over a velocity oracle.
//!
//! The paper samples with deterministic integration of the learned field;
//! it does not pin the solver. Euler (the default throughout) is O(dt);
//! Heun (explicit trapezoid) is O(dt²) at twice the velocity evaluations
//! per step — the classic accuracy/VFE trade-off for FM samplers. This
//! module provides both over any velocity closure and the step-count
//! ablation the bench uses to show where the quantization error (not the
//! discretization error) becomes the binding constraint.

use anyhow::Result;

/// The fixed t-grid every fixed-step integrator in this crate visits:
/// `t₀, t₀+dt, t₀+2dt, …` for `steps` points, produced by **additive
/// accumulation** (`t += dt`) — the sequence the sampler's step loop
/// has always computed, which the serving determinism contract pins.
/// ([`integrate`] below previously used the multiplicative
/// `t0 + s·dt` grid and now adopts this shared contract; for dt values
/// exactly representable in f32 — every dt its tests use — the two are
/// bit-identical, otherwise the solver-level grids may differ by an ulp
/// from pre-unification runs. Nothing pins integrate's bits.)
///
/// Centralizing the grid matters beyond deduplication: the engine
/// workspace caches the per-step time-embedding row by the exact f32
/// bit pattern of `t` (see `engine/workspace.rs`), so every integrator
/// must visit bit-identical t values for a given `(t0, t1, steps)` —
/// this iterator is that contract. Do not "simplify" it to
/// `t0 + s as f32 * dt`: the bits differ and both determinism pins and
/// cache hit rates depend on the accumulated sequence.
#[derive(Clone, Copy, Debug)]
pub struct StepGrid {
    t: f32,
    dt: f32,
    left: usize,
}

impl StepGrid {
    /// Grid from `t0` to `t1` in `steps` fixed steps (dt is signed).
    pub fn new(t0: f32, t1: f32, steps: usize) -> Self {
        assert!(steps > 0);
        Self {
            t: t0,
            dt: (t1 - t0) / steps as f32,
            left: steps,
        }
    }

    /// The signed step size paired with the yielded t values.
    pub fn dt(&self) -> f32 {
        self.dt
    }
}

impl Iterator for StepGrid {
    type Item = f32;
    fn next(&mut self) -> Option<f32> {
        if self.left == 0 {
            return None;
        }
        self.left -= 1;
        let t = self.t;
        self.t += self.dt;
        Some(t)
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        (self.left, Some(self.left))
    }
}

/// Velocity oracle: v = f(x, t) for a flat [n, d] batch with shared t.
pub trait BatchVelocity {
    fn velocity(&mut self, x: &[f32], t: f32) -> Result<Vec<f32>>;
}

impl<F> BatchVelocity for F
where
    F: FnMut(&[f32], f32) -> Result<Vec<f32>>,
{
    fn velocity(&mut self, x: &[f32], t: f32) -> Result<Vec<f32>> {
        self(x, t)
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Solver {
    Euler,
    Heun,
}

impl Solver {
    pub fn parse(s: &str) -> Option<Solver> {
        match s {
            "euler" => Some(Solver::Euler),
            "heun" => Some(Solver::Heun),
            _ => None,
        }
    }

    /// Velocity evaluations per step.
    pub fn evals_per_step(&self) -> usize {
        match self {
            Solver::Euler => 1,
            Solver::Heun => 2,
        }
    }
}

/// Integrate dx/dt = f(x, t) from t0 to t1 in `steps` fixed steps.
pub fn integrate(
    solver: Solver,
    f: &mut dyn BatchVelocity,
    mut x: Vec<f32>,
    t0: f32,
    t1: f32,
    steps: usize,
) -> Result<Vec<f32>> {
    let grid = StepGrid::new(t0, t1, steps);
    let dt = grid.dt();
    for t in grid {
        match solver {
            Solver::Euler => {
                let v = f.velocity(&x, t)?;
                for (xi, vi) in x.iter_mut().zip(v.iter()) {
                    *xi += dt * vi;
                }
            }
            Solver::Heun => {
                let v0 = f.velocity(&x, t)?;
                let pred: Vec<f32> = x
                    .iter()
                    .zip(v0.iter())
                    .map(|(&xi, &vi)| xi + dt * vi)
                    .collect();
                let v1 = f.velocity(&pred, t + dt)?;
                for ((xi, &a), &b) in x.iter_mut().zip(v0.iter()).zip(v1.iter()) {
                    *xi += dt * 0.5 * (a + b);
                }
            }
        }
    }
    Ok(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// dx/dt = -x, solution x(t) = x0 e^{-t}: Heun converges at O(dt²),
    /// Euler at O(dt).
    #[test]
    fn convergence_orders_on_linear_ode() {
        let mut f = |x: &[f32], _t: f32| -> Result<Vec<f32>> {
            Ok(x.iter().map(|&v| -v).collect())
        };
        let x0 = vec![1.0f32];
        let exact = (-1.0f32).exp();
        let mut err = |solver, steps| -> f32 {
            let out = integrate(solver, &mut f, x0.clone(), 0.0, 1.0, steps).unwrap();
            (out[0] - exact).abs()
        };
        // halving dt: Euler error halves, Heun error quarters
        let e1 = err(Solver::Euler, 16);
        let e2 = err(Solver::Euler, 32);
        assert!((e1 / e2 - 2.0).abs() < 0.3, "euler ratio {}", e1 / e2);
        let h1 = err(Solver::Heun, 16);
        let h2 = err(Solver::Heun, 32);
        assert!((h1 / h2 - 4.0).abs() < 0.6, "heun ratio {}", h1 / h2);
        // Heun strictly more accurate at equal steps
        assert!(h1 < e1 / 5.0, "heun {h1} vs euler {e1}");
    }

    /// Time-dependent field dx/dt = t: x(1) = x0 + 1/2. Heun is exact for
    /// fields linear in t.
    #[test]
    fn heun_exact_for_linear_in_time() {
        let mut f =
            |x: &[f32], t: f32| -> Result<Vec<f32>> { Ok(x.iter().map(|_| t).collect()) };
        let out = integrate(Solver::Heun, &mut f, vec![0.0], 0.0, 1.0, 4).unwrap();
        assert!((out[0] - 0.5).abs() < 1e-6, "{}", out[0]);
        // Euler underestimates (left endpoint rule)
        let out_e = integrate(Solver::Euler, &mut f, vec![0.0], 0.0, 1.0, 4).unwrap();
        assert!(out_e[0] < 0.5 - 0.05);
    }

    /// The grid must reproduce `t += dt` accumulation bit-for-bit — the
    /// contract the workspace's time-embedding cache keys on.
    #[test]
    fn step_grid_is_the_accumulated_sequence() {
        let steps = 6usize; // dt = 1/6 is not exactly representable
        let grid: Vec<f32> = StepGrid::new(0.0, 1.0, steps).collect();
        assert_eq!(grid.len(), steps);
        let dt = StepGrid::new(0.0, 1.0, steps).dt();
        let mut t = 0.0f32;
        for (s, &g) in grid.iter().enumerate() {
            assert_eq!(g.to_bits(), t.to_bits(), "step {s}");
            t += dt;
        }
        // reverse (encode) grid descends with signed dt
        let rev: Vec<f32> = StepGrid::new(1.0, 0.0, 4).collect();
        assert_eq!(rev, vec![1.0, 0.75, 0.5, 0.25]);
        assert_eq!(StepGrid::new(1.0, 0.0, 4).dt(), -0.25);
    }

    #[test]
    fn solver_parse_and_evals() {
        assert_eq!(Solver::parse("euler"), Some(Solver::Euler));
        assert_eq!(Solver::parse("heun"), Some(Solver::Heun));
        assert_eq!(Solver::parse("rk4"), None);
        assert_eq!(Solver::Heun.evals_per_step(), 2);
    }

    /// Heun over the actual velocity network (CPU) reduces discretization
    /// error vs Euler at equal step counts, measured against a 256-step
    /// Euler reference.
    #[test]
    fn heun_beats_euler_on_velocity_net() {
        use crate::model::spec::ModelSpec;
        use crate::util::rng::Pcg64;
        let spec = ModelSpec::default_spec();
        let mut rng = Pcg64::seed(5);
        let theta = spec.init_theta(&mut rng);
        let x0: Vec<f32> = (0..spec.d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut f = |x: &[f32], t: f32| -> Result<Vec<f32>> {
            Ok(crate::flow::cpu_ref::velocity(&spec, &theta, x, &[t]))
        };
        let reference = integrate(Solver::Euler, &mut f, x0.clone(), 0.0, 1.0, 256).unwrap();
        let dist = |a: &[f32]| -> f64 {
            a.iter()
                .zip(reference.iter())
                .map(|(&x, &y)| ((x - y) as f64).powi(2))
                .sum::<f64>()
                .sqrt()
        };
        let e_euler = dist(&integrate(Solver::Euler, &mut f, x0.clone(), 0.0, 1.0, 8).unwrap());
        let e_heun = dist(&integrate(Solver::Heun, &mut f, x0.clone(), 0.0, 1.0, 8).unwrap());
        assert!(e_heun < e_euler, "heun {e_heun} vs euler {e_euler}");
    }
}
