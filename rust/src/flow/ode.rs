//! Higher-order ODE solvers over a velocity oracle.
//!
//! The paper samples with deterministic integration of the learned field;
//! it does not pin the solver. Euler (the default throughout) is O(dt);
//! Heun (explicit trapezoid) is O(dt²) at twice the velocity evaluations
//! per step — the classic accuracy/VFE trade-off for FM samplers; dopri5
//! (Dormand–Prince 5(4), [`dopri5_into`]) adds an adaptive embedded pair
//! with explicit atol/rtol for the sweep's solver axis. This module
//! provides all three over any velocity closure and the step-count
//! ablation the bench uses to show where the quantization error (not the
//! discretization error) becomes the binding constraint.
//!
//! The fixed-step solvers come in two shapes that execute *bit-identical*
//! floating-point expressions: the allocating [`integrate`] driver over a
//! [`BatchVelocity`] oracle, and the in-place `*_into` cores over a
//! fill-a-buffer velocity closure plus a reusable [`SolverScratch`] — the
//! shape the zero-alloc `EngineStep::run_solver` hot path uses. Keeping
//! the update expressions identical between the two is a contract: the
//! sweep's engine-equivalence checks compare trajectories produced
//! through both shapes.

use anyhow::Result;

use crate::engine::workspace::take_zeroed;

/// The fixed t-grid every fixed-step integrator in this crate visits:
/// `t₀, t₀+dt, t₀+2dt, …` for `steps` points, produced by **additive
/// accumulation** (`t += dt`) — the sequence the sampler's step loop
/// has always computed, which the serving determinism contract pins.
/// ([`integrate`] below previously used the multiplicative
/// `t0 + s·dt` grid and now adopts this shared contract; for dt values
/// exactly representable in f32 — every dt its tests use — the two are
/// bit-identical, otherwise the solver-level grids may differ by an ulp
/// from pre-unification runs. Nothing pins integrate's bits.)
///
/// Centralizing the grid matters beyond deduplication: the engine
/// workspace caches the per-step time-embedding row by the exact f32
/// bit pattern of `t` (see `engine/workspace.rs`), so every integrator
/// must visit bit-identical t values for a given `(t0, t1, steps)` —
/// this iterator is that contract. Do not "simplify" it to
/// `t0 + s as f32 * dt`: the bits differ and both determinism pins and
/// cache hit rates depend on the accumulated sequence.
#[derive(Clone, Copy, Debug)]
pub struct StepGrid {
    t: f32,
    dt: f32,
    left: usize,
}

impl StepGrid {
    /// Grid from `t0` to `t1` in `steps` fixed steps (dt is signed).
    /// `steps == 0` yields an empty grid; for `steps >= 1` the dt bits
    /// are unchanged from the plain division (`max(1)` is identity), so
    /// the accumulated-t contract above is preserved exactly.
    pub fn new(t0: f32, t1: f32, steps: usize) -> Self {
        Self {
            t: t0,
            dt: (t1 - t0) / steps.max(1) as f32,
            left: steps,
        }
    }

    /// The signed step size paired with the yielded t values.
    pub fn dt(&self) -> f32 {
        self.dt
    }
}

impl Iterator for StepGrid {
    type Item = f32;
    fn next(&mut self) -> Option<f32> {
        if self.left == 0 {
            return None;
        }
        self.left -= 1;
        let t = self.t;
        self.t += self.dt;
        Some(t)
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        (self.left, Some(self.left))
    }
}

/// Velocity oracle: v = f(x, t) for a flat [n, d] batch with shared t.
pub trait BatchVelocity {
    fn velocity(&mut self, x: &[f32], t: f32) -> Result<Vec<f32>>;
}

impl<F> BatchVelocity for F
where
    F: FnMut(&[f32], f32) -> Result<Vec<f32>>,
{
    fn velocity(&mut self, x: &[f32], t: f32) -> Result<Vec<f32>> {
        self(x, t)
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Solver {
    Euler,
    Heun,
    /// Dormand–Prince 5(4): adaptive step size against an embedded 4th-
    /// order error estimate, controlled by (atol, rtol). The `steps`
    /// argument of the drivers becomes the *initial* step hint.
    Dopri5,
}

impl Solver {
    pub fn parse(s: &str) -> Option<Solver> {
        match s {
            "euler" => Some(Solver::Euler),
            "heun" => Some(Solver::Heun),
            "dopri5" => Some(Solver::Dopri5),
            _ => None,
        }
    }

    /// The `--solver` flag value for this integrator.
    pub fn name(&self) -> &'static str {
        match self {
            Solver::Euler => "euler",
            Solver::Heun => "heun",
            Solver::Dopri5 => "dopri5",
        }
    }

    /// Velocity evaluations per step (nominal — dopri5 is adaptive and
    /// FSAL, so 6 is its per-accepted-step cost, not a fixed total).
    pub fn evals_per_step(&self) -> usize {
        match self {
            Solver::Euler => 1,
            Solver::Heun => 2,
            Solver::Dopri5 => 6,
        }
    }
}

/// Default absolute tolerance for [`Solver::Dopri5`] when a caller does
/// not pass one explicitly (state components are O(1) pixels/latents, so
/// the floor sits well under the quantization error the sweep measures).
pub const DOPRI5_ATOL: f32 = 1e-5;
/// Default relative tolerance for [`Solver::Dopri5`].
pub const DOPRI5_RTOL: f32 = 1e-4;

/// Reusable scratch for the in-place solver cores: seven stage buffers,
/// one state-proposal buffer, and the velocity-evaluation counter the
/// sweep's per-step latency accounting reads. Construct once per worker
/// (`SolverScratch::default()`) and reuse — after the first step at a
/// given batch shape the cores perform zero heap allocations.
#[derive(Default)]
pub struct SolverScratch {
    k1: Vec<f32>,
    k2: Vec<f32>,
    k3: Vec<f32>,
    k4: Vec<f32>,
    k5: Vec<f32>,
    k6: Vec<f32>,
    k7: Vec<f32>,
    ytmp: Vec<f32>,
    /// Velocity evaluations performed by the most recent core run.
    pub evals: usize,
}

impl SolverScratch {
    /// Resize (and zero) every stage buffer for an n-element state.
    fn prepare(&mut self, n: usize) {
        take_zeroed(&mut self.k1, n);
        take_zeroed(&mut self.k2, n);
        take_zeroed(&mut self.k3, n);
        take_zeroed(&mut self.k4, n);
        take_zeroed(&mut self.k5, n);
        take_zeroed(&mut self.k6, n);
        take_zeroed(&mut self.k7, n);
        take_zeroed(&mut self.ytmp, n);
    }

    /// Capacity held by the stage buffers (workspace accounting).
    pub fn bytes(&self) -> usize {
        [
            &self.k1, &self.k2, &self.k3, &self.k4, &self.k5, &self.k6, &self.k7, &self.ytmp,
        ]
        .iter()
        .map(|v| v.capacity())
        .sum::<usize>()
            * 4
    }
}

/// Fill-a-buffer velocity closure: `vel(x, t, out)` writes v(x, t) into
/// `out` (same length as `x`). The in-place cores take this shape so the
/// engine adapter can route evaluations through `Engine::velocity_into`
/// without allocating.
pub type VelocityInto<'c> = dyn FnMut(&[f32], f32, &mut [f32]) -> Result<()> + 'c;

/// In-place Heun over the shared [`StepGrid`]. The per-step expressions
/// (`pred_i = x_i + dt·v0_i`, then `x_i += dt·0.5·(v0_i + v1_i)`) are
/// the exact ones [`integrate`]'s Heun arm computes, so both paths
/// produce bit-identical trajectories for the same velocity values —
/// pinned by `flow::sampler`'s cross-path regression test.
pub fn heun_into(
    vel: &mut VelocityInto<'_>,
    x: &mut [f32],
    t0: f32,
    t1: f32,
    steps: usize,
    scr: &mut SolverScratch,
) -> Result<()> {
    let n = x.len();
    scr.evals = 0;
    let grid = StepGrid::new(t0, t1, steps);
    let dt = grid.dt();
    for t in grid {
        take_zeroed(&mut scr.k1, n);
        vel(x, t, &mut scr.k1)?;
        take_zeroed(&mut scr.ytmp, n);
        for i in 0..n {
            scr.ytmp[i] = x[i] + dt * scr.k1[i];
        }
        take_zeroed(&mut scr.k2, n);
        vel(&scr.ytmp, t + dt, &mut scr.k2)?;
        for i in 0..n {
            x[i] += dt * 0.5 * (scr.k1[i] + scr.k2[i]);
        }
        scr.evals += 2;
    }
    Ok(())
}

// Dormand–Prince 5(4) Butcher tableau (c: stage times, a: stage weights,
// b: 5th-order solution, e = b − b*: embedded error weights).
const DP_C2: f32 = 1.0 / 5.0;
const DP_C3: f32 = 3.0 / 10.0;
const DP_C4: f32 = 4.0 / 5.0;
const DP_C5: f32 = 8.0 / 9.0;
const DP_A21: f32 = 1.0 / 5.0;
const DP_A31: f32 = 3.0 / 40.0;
const DP_A32: f32 = 9.0 / 40.0;
const DP_A41: f32 = 44.0 / 45.0;
const DP_A42: f32 = -56.0 / 15.0;
const DP_A43: f32 = 32.0 / 9.0;
const DP_A51: f32 = 19372.0 / 6561.0;
const DP_A52: f32 = -25360.0 / 2187.0;
const DP_A53: f32 = 64448.0 / 6561.0;
const DP_A54: f32 = -212.0 / 729.0;
const DP_A61: f32 = 9017.0 / 3168.0;
const DP_A62: f32 = -355.0 / 33.0;
const DP_A63: f32 = 46732.0 / 5247.0;
const DP_A64: f32 = 49.0 / 176.0;
const DP_A65: f32 = -5103.0 / 18656.0;
const DP_B1: f32 = 35.0 / 384.0;
const DP_B3: f32 = 500.0 / 1113.0;
const DP_B4: f32 = 125.0 / 192.0;
const DP_B5: f32 = -2187.0 / 6784.0;
const DP_B6: f32 = 11.0 / 84.0;
const DP_E1: f32 = 71.0 / 57600.0;
const DP_E3: f32 = -71.0 / 16695.0;
const DP_E4: f32 = 71.0 / 1920.0;
const DP_E5: f32 = -17253.0 / 339200.0;
const DP_E6: f32 = 22.0 / 525.0;
const DP_E7: f32 = -1.0 / 40.0;

/// In-place adaptive Dormand–Prince 5(4) from t0 to t1 (signed — the
/// reverse/encode direction integrates with negative steps).
///
/// Step control: the embedded error is reduced to a scaled RMS norm
/// (`scale_i = atol + rtol·max(|x_i|, |x'_i|)`, accumulated with an
/// explicit f64 loop — no float `.sum()` in flow/, per the determinism
/// lint) and a step is accepted when that norm is ≤ 1. The next step is
/// `h · clamp(0.9·err^(-1/5), 0.2, 5)`. `steps_hint` seeds the initial
/// step at `(t1-t0)/steps_hint`.
///
/// Termination is guaranteed on *any* field, including the exploded
/// low-bit models Fig. 4 documents (non-finite velocities): a non-finite
/// error norm rejects and shrinks the step hard; once the step reaches
/// the floor (1e-6 of the span) it is force-accepted; and an overall
/// iteration cap finishes the remaining interval with a single Euler
/// step so the sweep can score the failure instead of hanging.
#[allow(clippy::too_many_arguments)]
pub fn dopri5_into(
    vel: &mut VelocityInto<'_>,
    x: &mut [f32],
    t0: f32,
    t1: f32,
    atol: f32,
    rtol: f32,
    steps_hint: usize,
    scr: &mut SolverScratch,
) -> Result<()> {
    let n = x.len();
    scr.evals = 0;
    if n == 0 || t0 == t1 {
        return Ok(());
    }
    scr.prepare(n);
    let span = t1 - t0;
    let hint = steps_hint.max(1);
    let mut dt = span / hint as f32;
    let dt_min = span.abs() * 1e-6;
    let max_iters = 64 * hint + 256;
    let mut t = t0;
    // FSAL: k1 holds v(x, t) at the top of every iteration; after an
    // accepted step the 7th stage *is* the next step's first stage.
    vel(x, t, &mut scr.k1)?;
    scr.evals += 1;
    let mut iters = 0usize;
    while t != t1 {
        iters += 1;
        if iters > max_iters {
            // pathological field: finish deterministically with one
            // Euler step over the remainder (downstream clamps score it)
            let rem = t1 - t;
            for i in 0..n {
                x[i] += rem * scr.k1[i];
            }
            t = t1;
            break;
        }
        let mut h = dt;
        let rem = t1 - t;
        let last = if span > 0.0 { h >= rem } else { h <= rem };
        if last {
            h = rem;
        }
        for i in 0..n {
            scr.ytmp[i] = x[i] + h * (DP_A21 * scr.k1[i]);
        }
        vel(&scr.ytmp, t + DP_C2 * h, &mut scr.k2)?;
        for i in 0..n {
            scr.ytmp[i] = x[i] + h * (DP_A31 * scr.k1[i] + DP_A32 * scr.k2[i]);
        }
        vel(&scr.ytmp, t + DP_C3 * h, &mut scr.k3)?;
        for i in 0..n {
            scr.ytmp[i] = x[i] + h * (DP_A41 * scr.k1[i] + DP_A42 * scr.k2[i] + DP_A43 * scr.k3[i]);
        }
        vel(&scr.ytmp, t + DP_C4 * h, &mut scr.k4)?;
        for i in 0..n {
            scr.ytmp[i] = x[i]
                + h * (DP_A51 * scr.k1[i]
                    + DP_A52 * scr.k2[i]
                    + DP_A53 * scr.k3[i]
                    + DP_A54 * scr.k4[i]);
        }
        vel(&scr.ytmp, t + DP_C5 * h, &mut scr.k5)?;
        for i in 0..n {
            scr.ytmp[i] = x[i]
                + h * (DP_A61 * scr.k1[i]
                    + DP_A62 * scr.k2[i]
                    + DP_A63 * scr.k3[i]
                    + DP_A64 * scr.k4[i]
                    + DP_A65 * scr.k5[i]);
        }
        vel(&scr.ytmp, t + h, &mut scr.k6)?;
        // 5th-order proposal x' (into ytmp) and its trailing stage k7
        for i in 0..n {
            scr.ytmp[i] = x[i]
                + h * (DP_B1 * scr.k1[i]
                    + DP_B3 * scr.k3[i]
                    + DP_B4 * scr.k4[i]
                    + DP_B5 * scr.k5[i]
                    + DP_B6 * scr.k6[i]);
        }
        vel(&scr.ytmp, t + h, &mut scr.k7)?;
        scr.evals += 6;
        let mut acc = 0.0f64;
        for i in 0..n {
            let e = h
                * (DP_E1 * scr.k1[i]
                    + DP_E3 * scr.k3[i]
                    + DP_E4 * scr.k4[i]
                    + DP_E5 * scr.k5[i]
                    + DP_E6 * scr.k6[i]
                    + DP_E7 * scr.k7[i]);
            let sc = atol + rtol * x[i].abs().max(scr.ytmp[i].abs());
            let r = e as f64 / sc as f64;
            acc += r * r;
        }
        let err = (acc / n as f64).sqrt();
        if err <= 1.0 || h.abs() <= dt_min {
            x.copy_from_slice(&scr.ytmp);
            std::mem::swap(&mut scr.k1, &mut scr.k7);
            t = if last { t1 } else { t + h };
        }
        let fac = if err.is_finite() && err > 0.0 {
            (0.9 * err.powf(-0.2)).clamp(0.2, 5.0) as f32
        } else if err == 0.0 {
            5.0
        } else {
            0.2
        };
        dt = h * fac;
        if dt.abs() < dt_min {
            dt = dt_min * span.signum();
        }
    }
    Ok(())
}

/// Integrate dx/dt = f(x, t) from t0 to t1 in `steps` fixed steps
/// (for [`Solver::Dopri5`], `steps` is the initial-step hint and the
/// default [`DOPRI5_ATOL`]/[`DOPRI5_RTOL`] tolerances apply).
pub fn integrate(
    solver: Solver,
    f: &mut dyn BatchVelocity,
    mut x: Vec<f32>,
    t0: f32,
    t1: f32,
    steps: usize,
) -> Result<Vec<f32>> {
    match solver {
        Solver::Euler => {
            let grid = StepGrid::new(t0, t1, steps);
            let dt = grid.dt();
            for t in grid {
                let v = f.velocity(&x, t)?;
                for (xi, vi) in x.iter_mut().zip(v.iter()) {
                    *xi += dt * vi;
                }
            }
        }
        Solver::Heun => {
            let grid = StepGrid::new(t0, t1, steps);
            let dt = grid.dt();
            for t in grid {
                let v0 = f.velocity(&x, t)?;
                let pred: Vec<f32> = x
                    .iter()
                    .zip(v0.iter())
                    .map(|(&xi, &vi)| xi + dt * vi)
                    .collect();
                let v1 = f.velocity(&pred, t + dt)?;
                for ((xi, &a), &b) in x.iter_mut().zip(v0.iter()).zip(v1.iter()) {
                    *xi += dt * 0.5 * (a + b);
                }
            }
        }
        Solver::Dopri5 => {
            let mut scr = SolverScratch::default();
            let mut vel = |xs: &[f32], t: f32, out: &mut [f32]| -> Result<()> {
                let v = f.velocity(xs, t)?;
                out.copy_from_slice(&v);
                Ok(())
            };
            dopri5_into(
                &mut vel,
                &mut x,
                t0,
                t1,
                DOPRI5_ATOL,
                DOPRI5_RTOL,
                steps,
                &mut scr,
            )?;
        }
    }
    Ok(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// dx/dt = -x, solution x(t) = x0 e^{-t}: Heun converges at O(dt²),
    /// Euler at O(dt).
    #[test]
    fn convergence_orders_on_linear_ode() {
        let mut f = |x: &[f32], _t: f32| -> Result<Vec<f32>> {
            Ok(x.iter().map(|&v| -v).collect())
        };
        let x0 = vec![1.0f32];
        let exact = (-1.0f32).exp();
        let mut err = |solver, steps| -> f32 {
            let out = integrate(solver, &mut f, x0.clone(), 0.0, 1.0, steps).unwrap();
            (out[0] - exact).abs()
        };
        // halving dt: Euler error halves, Heun error quarters
        let e1 = err(Solver::Euler, 16);
        let e2 = err(Solver::Euler, 32);
        assert!((e1 / e2 - 2.0).abs() < 0.3, "euler ratio {}", e1 / e2);
        let h1 = err(Solver::Heun, 16);
        let h2 = err(Solver::Heun, 32);
        assert!((h1 / h2 - 4.0).abs() < 0.6, "heun ratio {}", h1 / h2);
        // empirical order p from error(dt) ∝ dt^p: p = log2(e(dt)/e(dt/2))
        let p_euler = (e1 / e2).log2();
        assert!((p_euler - 1.0).abs() < 0.25, "euler order {p_euler}");
        let p_heun = (h1 / h2).log2();
        assert!((p_heun - 2.0).abs() < 0.35, "heun order {p_heun}");
        // Heun strictly more accurate at equal steps
        assert!(h1 < e1 / 5.0, "heun {h1} vs euler {e1}");
    }

    /// dopri5 on the same closed-form field: the global error must land
    /// within a small multiple of the (atol, rtol) tolerance band, and
    /// the adaptive controller must not burn more evaluations than a
    /// fine fixed grid would.
    #[test]
    fn dopri5_meets_tolerances_on_linear_ode() {
        let mut vel = |x: &[f32], _t: f32, out: &mut [f32]| -> Result<()> {
            for (o, &v) in out.iter_mut().zip(x.iter()) {
                *o = -v;
            }
            Ok(())
        };
        let mut x = vec![1.0f32];
        let mut scr = SolverScratch::default();
        dopri5_into(&mut vel, &mut x, 0.0, 1.0, DOPRI5_ATOL, DOPRI5_RTOL, 4, &mut scr).unwrap();
        let exact = (-1.0f32).exp();
        let tol_scale = DOPRI5_ATOL + DOPRI5_RTOL * exact;
        let err = (x[0] - exact).abs();
        // global error within ~10x the per-step tolerance scale (the
        // controller bounds local error; global error accumulates)
        assert!(err < 10.0 * tol_scale, "err {err} vs scale {tol_scale}");
        assert!(scr.evals > 0, "evals must be recorded");
        // far fewer evals than a 256-step fixed grid at this accuracy
        assert!(scr.evals < 256, "evals {}", scr.evals);
        // the integrate() driver routes Dopri5 to the same core
        let mut f = |x: &[f32], _t: f32| -> Result<Vec<f32>> {
            Ok(x.iter().map(|&v| -v).collect())
        };
        let out = integrate(Solver::Dopri5, &mut f, vec![1.0], 0.0, 1.0, 4).unwrap();
        assert_eq!(out[0].to_bits(), x[0].to_bits(), "driver and core must agree");
    }

    /// dopri5 must terminate (and return Ok) even when the field goes
    /// non-finite — the exploded low-bit models the sweep scores.
    #[test]
    fn dopri5_terminates_on_pathological_field() {
        let mut vel = |_x: &[f32], _t: f32, out: &mut [f32]| -> Result<()> {
            for o in out.iter_mut() {
                *o = f32::NAN;
            }
            Ok(())
        };
        let mut x = vec![0.5f32, -0.5];
        let mut scr = SolverScratch::default();
        dopri5_into(&mut vel, &mut x, 0.0, 1.0, DOPRI5_ATOL, DOPRI5_RTOL, 8, &mut scr).unwrap();
        // reverse direction terminates too
        let mut x2 = vec![0.5f32];
        dopri5_into(&mut vel, &mut x2, 1.0, 0.0, DOPRI5_ATOL, DOPRI5_RTOL, 8, &mut scr).unwrap();
    }

    /// The in-place Heun core's stage-1 evaluation times are exactly the
    /// shared [`StepGrid`] sequence — the temb-cache keying contract.
    #[test]
    fn heun_into_visits_the_euler_step_grid() {
        let steps = 6usize; // dt = 1/6: not exactly representable
        let mut seen: Vec<f32> = Vec::new();
        let mut stage = 0usize;
        let mut vel = |x: &[f32], t: f32, out: &mut [f32]| -> Result<()> {
            if stage % 2 == 0 {
                seen.push(t);
            }
            stage += 1;
            for (o, &v) in out.iter_mut().zip(x.iter()) {
                *o = -v;
            }
            Ok(())
        };
        let mut x = vec![1.0f32];
        let mut scr = SolverScratch::default();
        heun_into(&mut vel, &mut x, 0.0, 1.0, steps, &mut scr).unwrap();
        let grid: Vec<f32> = StepGrid::new(0.0, 1.0, steps).collect();
        assert_eq!(seen.len(), grid.len());
        for (s, (&a, &b)) in seen.iter().zip(grid.iter()).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "stage-1 t at step {s}");
        }
        assert_eq!(scr.evals, 2 * steps);
    }

    /// Cross-shape contract: the allocating [`integrate`] Heun arm and
    /// the in-place [`heun_into`] core produce bit-identical states.
    #[test]
    fn heun_core_matches_integrate_bitwise() {
        let field = |x: &[f32], t: f32| -> Vec<f32> {
            x.iter().map(|&v| (t - v) * 0.7).collect()
        };
        let x0 = vec![0.3f32, -1.2, 0.9];
        let mut f = |x: &[f32], t: f32| -> Result<Vec<f32>> { Ok(field(x, t)) };
        let want = integrate(Solver::Heun, &mut f, x0.clone(), 0.0, 1.0, 7).unwrap();
        let mut vel = |x: &[f32], t: f32, out: &mut [f32]| -> Result<()> {
            out.copy_from_slice(&field(x, t));
            Ok(())
        };
        let mut got = x0.clone();
        let mut scr = SolverScratch::default();
        heun_into(&mut vel, &mut got, 0.0, 1.0, 7, &mut scr).unwrap();
        for (i, (a, b)) in got.iter().zip(want.iter()).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "component {i}");
        }
    }

    /// Time-dependent field dx/dt = t: x(1) = x0 + 1/2. Heun is exact for
    /// fields linear in t.
    #[test]
    fn heun_exact_for_linear_in_time() {
        let mut f =
            |x: &[f32], t: f32| -> Result<Vec<f32>> { Ok(x.iter().map(|_| t).collect()) };
        let out = integrate(Solver::Heun, &mut f, vec![0.0], 0.0, 1.0, 4).unwrap();
        assert!((out[0] - 0.5).abs() < 1e-6, "{}", out[0]);
        // Euler underestimates (left endpoint rule)
        let out_e = integrate(Solver::Euler, &mut f, vec![0.0], 0.0, 1.0, 4).unwrap();
        assert!(out_e[0] < 0.5 - 0.05);
    }

    /// The grid must reproduce `t += dt` accumulation bit-for-bit — the
    /// contract the workspace's time-embedding cache keys on.
    #[test]
    fn step_grid_is_the_accumulated_sequence() {
        let steps = 6usize; // dt = 1/6 is not exactly representable
        let grid: Vec<f32> = StepGrid::new(0.0, 1.0, steps).collect();
        assert_eq!(grid.len(), steps);
        let dt = StepGrid::new(0.0, 1.0, steps).dt();
        let mut t = 0.0f32;
        for (s, &g) in grid.iter().enumerate() {
            assert_eq!(g.to_bits(), t.to_bits(), "step {s}");
            t += dt;
        }
        // reverse (encode) grid descends with signed dt
        let rev: Vec<f32> = StepGrid::new(1.0, 0.0, 4).collect();
        assert_eq!(rev, vec![1.0, 0.75, 0.5, 0.25]);
        assert_eq!(StepGrid::new(1.0, 0.0, 4).dt(), -0.25);
    }

    #[test]
    fn solver_parse_and_evals() {
        assert_eq!(Solver::parse("euler"), Some(Solver::Euler));
        assert_eq!(Solver::parse("heun"), Some(Solver::Heun));
        assert_eq!(Solver::parse("dopri5"), Some(Solver::Dopri5));
        assert_eq!(Solver::parse("rk4"), None);
        assert_eq!(Solver::Heun.evals_per_step(), 2);
        assert_eq!(Solver::Dopri5.evals_per_step(), 6);
        for s in [Solver::Euler, Solver::Heun, Solver::Dopri5] {
            assert_eq!(Solver::parse(s.name()), Some(s), "name round-trip");
        }
    }

    /// Heun over the actual velocity network (CPU) reduces discretization
    /// error vs Euler at equal step counts, measured against a 256-step
    /// Euler reference.
    #[test]
    fn heun_beats_euler_on_velocity_net() {
        use crate::model::spec::ModelSpec;
        use crate::util::rng::Pcg64;
        let spec = ModelSpec::default_spec();
        let mut rng = Pcg64::seed(5);
        let theta = spec.init_theta(&mut rng);
        let x0: Vec<f32> = (0..spec.d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut f = |x: &[f32], t: f32| -> Result<Vec<f32>> {
            Ok(crate::flow::cpu_ref::velocity(&spec, &theta, x, &[t]))
        };
        let reference = integrate(Solver::Euler, &mut f, x0.clone(), 0.0, 1.0, 256).unwrap();
        let dist = |a: &[f32]| -> f64 {
            a.iter()
                .zip(reference.iter())
                .map(|(&x, &y)| ((x - y) as f64).powi(2))
                .sum::<f64>()
                .sqrt()
        };
        let e_euler = dist(&integrate(Solver::Euler, &mut f, x0.clone(), 0.0, 1.0, 8).unwrap());
        let e_heun = dist(&integrate(Solver::Heun, &mut f, x0.clone(), 0.0, 1.0, 8).unwrap());
        assert!(e_heun < e_euler, "heun {e_heun} vs euler {e_euler}");
    }
}
