//! Pure-rust velocity network forward — the exact mirror of
//! `python/compile/model.py::velocity` (and `qvelocity`).
//!
//! Three implementations of one model now exist: this, the jnp reference,
//! and the Pallas kernels inside the lowered HLO. Integration tests pin
//! them together (|rust − HLO| < 1e-4), which lets the entire pipeline run
//! and be tested without artifacts, and catches layout drift instantly.

use crate::model::params::ParamStore;
use crate::model::quantized::QuantizedModel;
use crate::model::spec::ModelSpec;
use crate::tensor::matmul_into;

#[inline]
fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// Sinusoidal time features, matching `model.time_features`:
/// freqs geometric in [1, FREQ_MAX], feats = [sin(t·f) ‖ cos(t·f)].
/// A single-frequency embedding (`temb_freqs == 1`) degenerates to
/// freq = 1 — the geometric ladder's start — instead of the 0/0 → NaN the
/// naive `i / (f - 1)` interpolation would produce.
pub fn time_features(spec: &ModelSpec, t: &[f32]) -> Vec<f32> {
    let mut out = vec![0f32; t.len() * 2 * spec.temb_freqs];
    time_features_into(spec, t, &mut out);
    out
}

/// Allocation-free [`time_features`]: fills a caller-provided
/// `[t.len(), 2 * temb_freqs]` buffer (the engine workspace's `temb`
/// arena). Each frequency is derived once and applied column-wise, so
/// no scratch is needed; the values written are bit-identical to the
/// allocating version (same `freq`, same `sin`/`cos` arguments).
pub fn time_features_into(spec: &ModelSpec, t: &[f32], out: &mut [f32]) {
    let f = spec.temb_freqs;
    assert_eq!(out.len(), t.len() * 2 * f, "out must be [B, 2 * temb_freqs]"); // fmq-analyze: allow(panic_cone) -- shape contract with the workspace temb arena: the caller sizes `out` from the same spec (pinned by the bit-exactness tests)
    // denominator (f-1) is only meaningful for f >= 2; clamping to 1 makes
    // the f == 1 exponent exactly 0 (freq = e^0 = 1) and changes nothing
    // for f >= 2
    let denom = (f as f32 - 1.0).max(1.0);
    for i in 0..f {
        let freq = ((i as f32 / denom) * spec.freq_max.ln()).exp();
        for (b, &tb) in t.iter().enumerate() {
            let ang = tb * freq;
            out[b * 2 * f + i] = ang.sin();
            out[b * 2 * f + f + i] = ang.cos();
        }
    }
}

/// Weight accessor abstraction so the fp32 and quantized paths share one
/// forward implementation.
trait Weights {
    /// Materialize weight matrix `name` into `buf` (row-major [rows, cols]).
    fn weight(&self, spec: &ModelSpec, name: &str, buf: &mut Vec<f32>);
    fn bias<'a>(&'a self, spec: &ModelSpec, name: &str) -> Vec<f32>;
}

struct FullPrecision<'a>(&'a ParamStore);

impl Weights for FullPrecision<'_> {
    fn weight(&self, spec: &ModelSpec, name: &str, buf: &mut Vec<f32>) {
        buf.clear();
        buf.extend_from_slice(self.0.layer(spec, name));
    }
    fn bias(&self, spec: &ModelSpec, name: &str) -> Vec<f32> {
        self.0.layer(spec, name).to_vec()
    }
}

struct Quantized<'a>(&'a QuantizedModel);

impl Weights for Quantized<'_> {
    fn weight(&self, spec: &ModelSpec, name: &str, buf: &mut Vec<f32>) {
        let qm = self.0;
        let row = spec
            .weight_layers()
            .iter()
            .position(|l| l.name == name)
            .unwrap(); // fmq-analyze: allow(panic_cone) -- reference-oracle path: layer names come from the spec's own tables; a miss is a construction bug caught by any test run, not request-reachable (covers next line too)
        let l = spec.layer(name).unwrap();
        let woff = spec.weight_offset(name);
        let cb = &qm.codebooks[row];
        buf.clear();
        buf.extend(
            qm.codes[woff..woff + l.size()]
                .iter()
                .map(|&c| cb.levels[c as usize]),
        );
    }
    fn bias(&self, spec: &ModelSpec, name: &str) -> Vec<f32> {
        let l = spec.layer(name).unwrap(); // fmq-analyze: allow(panic_cone) -- same spec-table lookup as `weight` above: a miss is a construction bug, not request data
        let boff = spec.bias_offset(name);
        self.0.biases[boff..boff + l.size()].to_vec()
    }
}

fn forward(spec: &ModelSpec, w: &dyn Weights, x: &[f32], t: &[f32]) -> Vec<f32> {
    let b = t.len();
    let (d, h_dim, temb_dim) = (spec.d, spec.hidden, 2 * spec.temb_freqs);
    assert_eq!(x.len(), b * d); // fmq-analyze: allow(panic_cone) -- oracle shape contract: callers build x/t from the same spec
    let mut wbuf: Vec<f32> = Vec::new();

    // ht = silu(temb @ w_t + b_t)
    let temb = time_features(spec, t);
    let mut ht = vec![0f32; b * h_dim];
    w.weight(spec, "w_t", &mut wbuf);
    matmul_into(&temb, &wbuf, &mut ht, b, temb_dim, h_dim);
    let b_t = w.bias(spec, "b_t");
    for r in ht.chunks_mut(h_dim) {
        for (v, &bb) in r.iter_mut().zip(b_t.iter()) {
            *v = silu(*v + bb);
        }
    }

    // h = x @ w_in + b_in + ht
    let mut h = vec![0f32; b * h_dim];
    w.weight(spec, "w_in", &mut wbuf);
    matmul_into(x, &wbuf, &mut h, b, d, h_dim);
    let b_in = w.bias(spec, "b_in");
    for (r, rt) in h.chunks_mut(h_dim).zip(ht.chunks(h_dim)) {
        for ((v, &bb), &tv) in r.iter_mut().zip(b_in.iter()).zip(rt.iter()) {
            *v += bb + tv;
        }
    }

    // residual blocks: h += silu(h @ w1 + b1) @ w2 + b2
    let mut u = vec![0f32; b * h_dim];
    let mut r2 = vec![0f32; b * h_dim];
    for i in 0..spec.blocks {
        u.iter_mut().for_each(|v| *v = 0.0);
        w.weight(spec, &format!("w1_{i}"), &mut wbuf);
        matmul_into(&h, &wbuf, &mut u, b, h_dim, h_dim);
        let b1 = w.bias(spec, &format!("b1_{i}"));
        for r in u.chunks_mut(h_dim) {
            for (v, &bb) in r.iter_mut().zip(b1.iter()) {
                *v = silu(*v + bb);
            }
        }
        r2.iter_mut().for_each(|v| *v = 0.0);
        w.weight(spec, &format!("w2_{i}"), &mut wbuf);
        matmul_into(&u, &wbuf, &mut r2, b, h_dim, h_dim);
        let b2 = w.bias(spec, &format!("b2_{i}"));
        for (hr, rr) in h.chunks_mut(h_dim).zip(r2.chunks(h_dim)) {
            for ((v, &rv), &bb) in hr.iter_mut().zip(rr.iter()).zip(b2.iter()) {
                *v += rv + bb;
            }
        }
    }

    // v = h @ w_out + b_out
    let mut out = vec![0f32; b * d];
    w.weight(spec, "w_out", &mut wbuf);
    matmul_into(&h, &wbuf, &mut out, b, h_dim, d);
    let b_out = w.bias(spec, "b_out");
    for r in out.chunks_mut(d) {
        for (v, &bb) in r.iter_mut().zip(b_out.iter()) {
            *v += bb;
        }
    }
    out
}

/// Full-precision velocity: x flat [B, D], t [B] -> v flat [B, D].
pub fn velocity(spec: &ModelSpec, theta: &ParamStore, x: &[f32], t: &[f32]) -> Vec<f32> {
    forward(spec, &FullPrecision(theta), x, t)
}

/// Quantized velocity (dequantize-on-the-fly, mirroring `qvelocity`).
pub fn qvelocity(qm: &QuantizedModel, x: &[f32], t: &[f32]) -> Vec<f32> {
    forward(&qm.spec.clone(), &Quantized(qm), x, t)
}

/// One Euler step (signed dt), shared t across the batch.
pub fn sample_step(
    spec: &ModelSpec,
    theta: &ParamStore,
    x: &[f32],
    t: f32,
    dt: f32,
) -> Vec<f32> {
    let b = x.len() / spec.d;
    let tb = vec![t; b];
    let v = velocity(spec, theta, x, &tb);
    x.iter().zip(v.iter()).map(|(&xi, &vi)| xi + dt * vi).collect()
}

/// One quantized Euler step.
pub fn qsample_step(qm: &QuantizedModel, x: &[f32], t: f32, dt: f32) -> Vec<f32> {
    let b = x.len() / qm.spec.d;
    let tb = vec![t; b];
    let v = qvelocity(qm, x, &tb);
    x.iter().zip(v.iter()).map(|(&xi, &vi)| xi + dt * vi).collect()
}

// ------------------------------------------------ Lipschitz oracle glue

/// VelocityOracle over the CPU forward (for `theory::lipschitz`).
pub struct CpuOracle<'a> {
    pub spec: &'a ModelSpec,
    pub theta: &'a ParamStore,
}

impl crate::theory::lipschitz::VelocityOracle for CpuOracle<'_> {
    fn velocity(&mut self, x: &[f32], t: f32) -> Vec<f32> {
        velocity(self.spec, self.theta, x, &[t])
    }
    fn dim(&self) -> usize {
        self.spec.d
    }
}

impl crate::theory::lipschitz::ParamOracle for CpuOracle<'_> {
    fn velocity_with(&mut self, delta: &[f32], x: &[f32], t: f32) -> Vec<f32> {
        let mut th = self.theta.clone();
        for (a, &b) in th.as_mut_slice().iter_mut().zip(delta.iter()) {
            *a += b;
        }
        velocity(self.spec, &th, x, &[t])
    }
    fn dim(&self) -> usize {
        self.spec.d
    }
    fn p(&self) -> usize {
        self.spec.p()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{quantize_model, QuantMethod};
    use crate::util::rng::Pcg64;

    fn setup() -> (ModelSpec, ParamStore) {
        let spec = ModelSpec::default_spec();
        let mut rng = Pcg64::seed(7);
        let theta = spec.init_theta(&mut rng);
        (spec, theta)
    }

    #[test]
    fn time_features_match_python_semantics() {
        let spec = ModelSpec::default_spec();
        let f = time_features(&spec, &[0.0, 1.0]);
        let tf = spec.temb_freqs;
        // t = 0: sin block 0, cos block 1
        for i in 0..tf {
            assert!((f[i]).abs() < 1e-7);
            assert!((f[tf + i] - 1.0).abs() < 1e-7);
        }
        // t = 1, freq 0 = 1.0: sin(1), cos(1)
        assert!((f[2 * tf] - 1f32.sin()).abs() < 1e-6);
        assert!((f[3 * tf] - 1f32.cos()).abs() < 1e-6);
        // last freq = FREQ_MAX
        let last = ((tf - 1) as f32 / (tf as f32 - 1.0) * spec.freq_max.ln()).exp();
        assert!((last - spec.freq_max).abs() < 1e-2);
    }

    #[test]
    fn time_features_single_frequency_is_finite() {
        // regression: temb_freqs == 1 used to hit (f-1) == 0 -> 0/0 -> NaN
        // frequencies that poisoned the whole forward
        let mut spec = ModelSpec::default_spec();
        spec.temb_freqs = 1;
        let f = time_features(&spec, &[0.0, 0.3, 1.0]);
        assert_eq!(f.len(), 3 * 2);
        assert!(f.iter().all(|v| v.is_finite()), "{f:?}");
        // the lone frequency degenerates to 1.0: feats = [sin(t), cos(t)]
        assert!((f[2] - 0.3f32.sin()).abs() < 1e-6);
        assert!((f[3] - 0.3f32.cos()).abs() < 1e-6);
    }

    #[test]
    fn velocity_shape_and_determinism() {
        let (spec, theta) = setup();
        let mut rng = Pcg64::seed(1);
        let x: Vec<f32> = (0..2 * spec.d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let v1 = velocity(&spec, &theta, &x, &[0.3, 0.8]);
        let v2 = velocity(&spec, &theta, &x, &[0.3, 0.8]);
        assert_eq!(v1.len(), 2 * spec.d);
        assert_eq!(v1, v2);
        assert!(v1.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn batch_independence() {
        // each row's output depends only on its own input
        let (spec, theta) = setup();
        let mut rng = Pcg64::seed(2);
        let x1: Vec<f32> = (0..spec.d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let x2: Vec<f32> = (0..spec.d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut both = x1.clone();
        both.extend_from_slice(&x2);
        let vb = velocity(&spec, &theta, &both, &[0.4, 0.9]);
        let v1 = velocity(&spec, &theta, &x1, &[0.4]);
        let v2 = velocity(&spec, &theta, &x2, &[0.9]);
        crate::util::check::assert_close(&vb[..spec.d], &v1, 1e-6, 1e-6);
        crate::util::check::assert_close(&vb[spec.d..], &v2, 1e-6, 1e-6);
    }

    #[test]
    fn qvelocity_at_8_bits_tracks_fp32() {
        let (spec, theta) = setup();
        let qm = quantize_model(&spec, &theta, QuantMethod::Ot, 8);
        let mut rng = Pcg64::seed(3);
        let x: Vec<f32> = (0..2 * spec.d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let t = [0.25, 0.75];
        let v = velocity(&spec, &theta, &x, &t);
        let vq = qvelocity(&qm, &x, &t);
        let rel = {
            let num: f64 = v
                .iter()
                .zip(vq.iter())
                .map(|(&a, &b)| ((a - b) as f64).powi(2))
                .sum::<f64>()
                .sqrt();
            let den: f64 = v.iter().map(|&a| (a as f64).powi(2)).sum::<f64>().sqrt();
            num / den.max(1e-12)
        };
        assert!(rel < 0.2, "rel={rel}");
    }

    #[test]
    fn qvelocity_equals_dequantized_velocity() {
        // the quantized path must equal running fp32 forward on dequantized
        // weights — they are the same function by construction.
        let (spec, theta) = setup();
        let qm = quantize_model(&spec, &theta, QuantMethod::Uniform, 4);
        let deq = qm.dequantize();
        let mut rng = Pcg64::seed(4);
        let x: Vec<f32> = (0..spec.d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let vq = qvelocity(&qm, &x, &[0.5]);
        let vd = velocity(&spec, &deq, &x, &[0.5]);
        crate::util::check::assert_close(&vq, &vd, 1e-6, 1e-6);
    }

    #[test]
    fn sample_step_euler_identity() {
        let (spec, theta) = setup();
        let mut rng = Pcg64::seed(5);
        let x: Vec<f32> = (0..spec.d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let y = sample_step(&spec, &theta, &x, 0.2, 0.1);
        let v = velocity(&spec, &theta, &x, &[0.2]);
        for i in 0..spec.d {
            assert!((y[i] - (x[i] + 0.1 * v[i])).abs() < 1e-6);
        }
    }
}
