//! ODE sampler: forward generation (noise → data) and reverse encoding
//! (data → noise, the Fig. 4 latent extraction), over any step backend
//! (compiled HLO or the CPU reference). The default integration is Euler
//! over the shared [`StepGrid`]; the engine adapter additionally exposes
//! the full solver axis (euler/heun/dopri5) through
//! [`EngineStep::run_solver`] for the paper-grid sweep.

use anyhow::{ensure, Context, Result};

use crate::engine::workspace::{take_zeroed, Workspace};
use crate::flow::ode::{
    dopri5_into, heun_into, Solver, SolverScratch, StepGrid, DOPRI5_ATOL, DOPRI5_RTOL,
};
use crate::model::params::ParamStore;
use crate::model::quantized::QuantizedModel;
use crate::model::spec::ModelSpec;
use crate::runtime::ArtifactSet;
use crate::util::rng::Pcg64;

/// A step backend: x, t, dt -> x'.
pub trait StepBackend {
    fn step(&mut self, x: &[f32], t: f32, dt: f32) -> Result<Vec<f32>>;
    fn spec(&self) -> &ModelSpec;

    /// Multi-step integration hook over the shared [`StepGrid`] (the
    /// accumulated t sequence every integrator visits). The default
    /// loops [`StepBackend::step`] (one host round trip per step); the
    /// HLO backends override it with device-resident sessions where the
    /// state chains on device and the weights/codes are staged once
    /// (§Perf optimization 1), and [`EngineStep`] overrides it with an
    /// in-place, workspace-backed loop that performs zero heap
    /// allocations per step.
    fn run(&mut self, x: Vec<f32>, t0: f32, t1: f32, steps: usize) -> Result<Vec<f32>> {
        let grid = StepGrid::new(t0, t1, steps);
        let dt = grid.dt();
        let mut x = x;
        for t in grid {
            x = self.step(&x, t, dt)?;
        }
        Ok(x)
    }
}

/// CPU reference, full precision.
pub struct CpuStep<'a> {
    pub spec: &'a ModelSpec,
    pub theta: &'a ParamStore,
}

impl StepBackend for CpuStep<'_> {
    fn step(&mut self, x: &[f32], t: f32, dt: f32) -> Result<Vec<f32>> {
        Ok(crate::flow::cpu_ref::sample_step(self.spec, self.theta, x, t, dt))
    }
    fn spec(&self) -> &ModelSpec {
        self.spec
    }
}

/// CPU reference, quantized weights.
pub struct CpuQStep<'a> {
    pub qm: &'a QuantizedModel,
}

impl StepBackend for CpuQStep<'_> {
    fn step(&mut self, x: &[f32], t: f32, dt: f32) -> Result<Vec<f32>> {
        Ok(crate::flow::cpu_ref::qsample_step(self.qm, x, t, dt))
    }
    fn spec(&self) -> &ModelSpec {
        &self.qm.spec
    }
}

/// Any [`crate::engine::Engine`] adapted to the step-backend seam, so the
/// generation/encoding drivers below (and everything layered on them —
/// the batcher workers, the sweep runner) are engine-agnostic: the native
/// LUT engines (v1 `lut` and the blocked autotuned `lut2`), the
/// dequantize-then-GEMM reference and future backends all integrate
/// through this one adapter.
///
/// The adapter owns the serving worker's scratch arena: one
/// [`Workspace`] plus the velocity/t buffers its integration loop
/// reuses. Construct it **once per worker** and reuse it across batches
/// — the per-step time-embedding cache and the autotuned engine scratch
/// then persist across every super-batch of the same step grid, and the
/// steady-state `run` loop performs zero heap allocations (pinned by
/// the `bench_engine` allocation counter).
pub struct EngineStep<'a> {
    engine: &'a dyn crate::engine::Engine,
    ws: Workspace,
    /// Velocity output of the current step, flat `[B, D]`.
    v: Vec<f32>,
    /// Shared per-step t broadcast to `[B]`.
    tb: Vec<f32>,
    /// Stage buffers for the non-Euler solver cores ([`run_solver`][Self::run_solver]).
    scr: SolverScratch,
}

impl<'a> EngineStep<'a> {
    /// Wrap an engine. Allocation-free until the first step runs.
    pub fn new(engine: &'a dyn crate::engine::Engine) -> Self {
        Self {
            engine,
            ws: Workspace::new(),
            v: Vec::new(),
            tb: Vec::new(),
            scr: SolverScratch::default(),
        }
    }

    /// The wrapped engine.
    pub fn engine(&self) -> &dyn crate::engine::Engine {
        self.engine
    }

    /// High-water bytes of the adapter-owned scratch (its workspace plus
    /// the step loop's velocity/t buffers and solver stage buffers). The
    /// engine's own pool arenas are reported separately by
    /// `Engine::workspace_bytes`.
    pub fn workspace_bytes(&self) -> usize {
        self.ws.high_water_bytes()
            + (self.v.capacity() + self.tb.capacity()) * 4
            + self.scr.bytes()
    }

    /// Velocity evaluations performed by the most recent
    /// [`run_solver`][Self::run_solver] call — the sweep's per-eval
    /// latency accounting (for dopri5 this is the *actual* adaptive
    /// count, not the nominal 6·steps).
    pub fn last_evals(&self) -> usize {
        self.scr.evals
    }

    /// Multi-step integration with an explicit [`Solver`] — the sweep's
    /// solver axis. Euler delegates to the serving [`StepBackend::run`]
    /// loop (bit-identical to every other euler path in the crate); Heun
    /// and dopri5 route through the in-place `flow::ode` cores with the
    /// adapter's reusable [`SolverScratch`], so steady-state runs stay
    /// allocation-free. Heun visits the exact euler [`StepGrid`] at its
    /// first stage, so interleaving solvers never disturbs the engine
    /// workspace's temb-cache keying (pinned by this module's tests).
    pub fn run_solver(
        &mut self,
        x: Vec<f32>,
        t0: f32,
        t1: f32,
        steps: usize,
        solver: Solver,
    ) -> Result<Vec<f32>> {
        if solver == Solver::Euler {
            let out = self.run(x, t0, t1, steps)?;
            self.scr.evals = steps;
            return Ok(out);
        }
        let d = self.engine.spec().d.max(1);
        ensure!(x.len() % d == 0, "x must be flat [B, D] with d={d}");
        let b = x.len() / d;
        let mut x = x;
        let Self {
            engine, ws, tb, scr, ..
        } = self;
        let mut vel = |xs: &[f32], t: f32, out: &mut [f32]| -> Result<()> {
            tb.clear();
            tb.resize(b, t);
            engine.velocity_into(xs, tb, out, ws)
        };
        match solver {
            // handled above: the serving euler loop is the pinned path
            Solver::Euler => {}
            Solver::Heun => heun_into(&mut vel, &mut x, t0, t1, steps, scr)?,
            Solver::Dopri5 => dopri5_into(
                &mut vel,
                &mut x,
                t0,
                t1,
                DOPRI5_ATOL,
                DOPRI5_RTOL,
                steps,
                scr,
            )?,
        }
        Ok(x)
    }
}

impl StepBackend for EngineStep<'_> {
    fn step(&mut self, x: &[f32], t: f32, dt: f32) -> Result<Vec<f32>> {
        let d = self.engine.spec().d.max(1);
        ensure!(x.len() % d == 0, "x must be flat [B, D] with d={d}");
        let b = x.len() / d;
        self.tb.clear();
        self.tb.resize(b, t);
        take_zeroed(&mut self.v, b * d);
        self.engine.velocity_into(x, &self.tb, &mut self.v, &mut self.ws)?;
        Ok(x.iter()
            .zip(self.v.iter())
            .map(|(&xi, &vi)| xi + dt * vi)
            .collect())
    }

    fn spec(&self) -> &ModelSpec {
        self.engine.spec()
    }

    fn run(&mut self, x: Vec<f32>, t0: f32, t1: f32, steps: usize) -> Result<Vec<f32>> {
        let d = self.engine.spec().d.max(1);
        ensure!(x.len() % d == 0, "x must be flat [B, D] with d={d}");
        let b = x.len() / d;
        let grid = StepGrid::new(t0, t1, steps);
        let dt = grid.dt();
        let mut x = x;
        for t in grid {
            // span + atomics only — never touches x/v, so the result is
            // bit-identical with timing on, off, or compiled out
            let span = crate::obs::Span::begin();
            self.tb.clear();
            self.tb.resize(b, t);
            take_zeroed(&mut self.v, b * d);
            self.engine
                .velocity_into(&x, &self.tb, &mut self.v, &mut self.ws)?;
            // in-place Euler update: same expression as the one-shot
            // step path, so the result is bit-identical to it
            for (xi, &vi) in x.iter_mut().zip(self.v.iter()) {
                *xi += dt * vi;
            }
            span.end(&crate::obs::ENGINE.ode_step_ns);
        }
        Ok(x)
    }
}

/// Compiled HLO, full precision. Theta is staged on device lazily (first
/// `run`), so constructing the backend stays cheap.
pub struct HloStep<'a> {
    pub art: &'a ArtifactSet,
    pub theta: &'a ParamStore,
}

impl StepBackend for HloStep<'_> {
    fn step(&mut self, x: &[f32], t: f32, dt: f32) -> Result<Vec<f32>> {
        self.art.sample_step(self.theta, x, t, dt)
    }
    fn spec(&self) -> &ModelSpec {
        &self.art.spec
    }
    fn run(&mut self, x: Vec<f32>, t0: f32, t1: f32, steps: usize) -> Result<Vec<f32>> {
        self.art.sample_session(self.theta)?.integrate(&x, t0, t1, steps)
    }
}

/// Compiled HLO, quantized. Two serving modes (numerically identical —
/// both reconstruct weights from the same codebooks):
/// * **dequantize-on-load** (default): the `dequant_theta` artifact
///   reconstructs fp32 theta on device once per session, then fp32 steps
///   run gather-free — §Perf optimization 2.
/// * **dequantize-on-the-fly**: every step routes through the Pallas qmm
///   gather (the paper-faithful TPU/VMEM mode) — used by `step()` and the
///   `new_on_the_fly` constructor; benchmarked in bench_sample_step.
pub struct HloQStep<'a> {
    mode: QMode<'a>,
    spec: ModelSpec,
    // host copies for the one-shot step() path (always on-the-fly)
    art: &'a ArtifactSet,
    codes: Vec<i32>,
    biases: Vec<f32>,
    cbs: Vec<f32>,
}

enum QMode<'a> {
    DequantOnLoad(crate::runtime::artifacts::SampleSession<'a>),
    OnTheFly(crate::runtime::artifacts::QSampleSession<'a>),
}

impl<'a> HloQStep<'a> {
    pub fn new(art: &'a ArtifactSet, qm: &QuantizedModel) -> Result<Self> {
        let session = art
            .qsample_session_dequant(qm)
            .context("dequantize quantized model on device")?;
        Ok(Self::build(art, qm, QMode::DequantOnLoad(session)))
    }

    /// Per-step Pallas-qmm dequantization (the TPU-faithful mode).
    pub fn new_on_the_fly(art: &'a ArtifactSet, qm: &QuantizedModel) -> Result<Self> {
        let session = art
            .qsample_session(qm)
            .context("stage quantized model on device")?;
        Ok(Self::build(art, qm, QMode::OnTheFly(session)))
    }

    fn build(art: &'a ArtifactSet, qm: &QuantizedModel, mode: QMode<'a>) -> Self {
        // shared adapter setup (same base the packed LutModel starts
        // from): private spec + fp32 biases, see QuantizedModel::adapter_base
        let (spec, biases) = qm.adapter_base();
        Self {
            mode,
            spec,
            art,
            codes: qm.codes_i32(),
            biases,
            cbs: qm.codebooks_padded(),
        }
    }
}

impl StepBackend for HloQStep<'_> {
    fn step(&mut self, x: &[f32], t: f32, dt: f32) -> Result<Vec<f32>> {
        self.art
            .qsample_step(&self.codes, &self.biases, &self.cbs, x, t, dt)
    }
    fn spec(&self) -> &ModelSpec {
        &self.spec
    }
    fn run(&mut self, x: Vec<f32>, t0: f32, t1: f32, steps: usize) -> Result<Vec<f32>> {
        match &self.mode {
            QMode::DequantOnLoad(s) => s.integrate(&x, t0, t1, steps),
            QMode::OnTheFly(s) => s.integrate(&x, t0, t1, steps),
        }
    }
}

/// Integrate the probability-flow ODE forward: x₀ ~ N(0, I) → x₁ (images).
/// Returns the generated batch (flat [n, D], clamped to [-1, 1] at the end).
/// Clamp to image range; non-finite states (an exploded low-bit model —
/// the failure mode Fig. 4 documents) map to mid-gray so downstream
/// metrics stay well-defined and score the failure as what it is.
pub(crate) fn to_pixel(v: f32) -> f32 {
    if v.is_finite() {
        v.clamp(-1.0, 1.0)
    } else {
        0.0
    }
}

/// Bound latents; explosions register as a huge-but-finite sentinel so
/// variance statistics quantify the blow-up instead of becoming NaN.
pub(crate) fn to_latent(v: f32) -> f32 {
    if v.is_finite() {
        v.clamp(-1e3, 1e3)
    } else {
        1e3
    }
}

pub fn generate(
    backend: &mut dyn StepBackend,
    rng: &mut Pcg64,
    n: usize,
    steps: usize,
) -> Result<Vec<f32>> {
    let d = backend.spec().d;
    let x0: Vec<f32> = (0..n * d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let out = integrate(backend, x0, 0.0, 1.0, steps)?;
    Ok(out.into_iter().map(to_pixel).collect())
}

/// Same start noise, explicit (for paired fp32-vs-quantized comparisons).
pub fn generate_from(
    backend: &mut dyn StepBackend,
    x0: &[f32],
    steps: usize,
) -> Result<Vec<f32>> {
    let out = integrate(backend, x0.to_vec(), 0.0, 1.0, steps)?;
    Ok(out.into_iter().map(to_pixel).collect())
}

/// Reverse encoding: images → latents (integrate t: 1 → 0, dt < 0).
pub fn encode(backend: &mut dyn StepBackend, imgs: &[f32], steps: usize) -> Result<Vec<f32>> {
    let out = integrate(backend, imgs.to_vec(), 1.0, 0.0, steps)?;
    Ok(out.into_iter().map(to_latent).collect())
}

/// [`generate_from`] with an explicit solver through the engine adapter
/// (same start noise, same pixel clamp) — the sweep's forward path.
pub fn generate_from_solver(
    be: &mut EngineStep<'_>,
    x0: &[f32],
    steps: usize,
    solver: Solver,
) -> Result<Vec<f32>> {
    let out = be.run_solver(x0.to_vec(), 0.0, 1.0, steps, solver)?;
    Ok(out.into_iter().map(to_pixel).collect())
}

/// [`encode`] with an explicit solver through the engine adapter (same
/// latent sentinel bound) — the sweep's Fig. 4 latent path.
pub fn encode_solver(
    be: &mut EngineStep<'_>,
    imgs: &[f32],
    steps: usize,
    solver: Solver,
) -> Result<Vec<f32>> {
    let out = be.run_solver(imgs.to_vec(), 1.0, 0.0, steps, solver)?;
    Ok(out.into_iter().map(to_latent).collect())
}

/// Which way a batch integrates the probability-flow ODE. The serving
/// layer schedules homogeneous super-batches by direction: `Forward` is
/// the `generate` op ([`generate_from`], noise → images), `Reverse` is
/// the `encode` op ([`encode`], images → latents, the paper's Fig. 4
/// latent-extraction path).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Direction {
    /// t: 0 → 1 (generation; output clamped to pixel range).
    Forward,
    /// t: 1 → 0 (encoding; output bounded by the latent sentinel).
    Reverse,
}

/// Run a flat `[n, d]` batch through the ODE in the given direction —
/// the single entry point the serving worker uses for both ops.
pub fn run_direction(
    backend: &mut dyn StepBackend,
    rows: &[f32],
    dir: Direction,
    steps: usize,
) -> Result<Vec<f32>> {
    match dir {
        Direction::Forward => generate_from(backend, rows, steps),
        Direction::Reverse => encode(backend, rows, steps),
    }
}

/// Fixed-step explicit Euler from t0 to t1 (delegates to the backend's
/// `run`, which HLO backends override with device-resident sessions).
pub fn integrate(
    backend: &mut dyn StepBackend,
    x: Vec<f32>,
    t0: f32,
    t1: f32,
    steps: usize,
) -> Result<Vec<f32>> {
    backend.run(x, t0, t1, steps)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::spec::ModelSpec;

    fn setup() -> (ModelSpec, ParamStore) {
        let spec = ModelSpec::default_spec();
        let mut rng = Pcg64::seed(9);
        (spec.clone(), spec.init_theta(&mut rng))
    }

    #[test]
    fn generate_shape_and_bounds() {
        let (spec, theta) = setup();
        let mut be = CpuStep {
            spec: &spec,
            theta: &theta,
        };
        let mut rng = Pcg64::seed(1);
        let imgs = generate(&mut be, &mut rng, 3, 8).unwrap();
        assert_eq!(imgs.len(), 3 * spec.d);
        assert!(imgs.iter().all(|&p| (-1.0..=1.0).contains(&p)));
    }

    #[test]
    fn forward_then_reverse_roundtrips_near_identity() {
        // an untrained (small-weight) field is near-linear: encode(generate)
        // with many steps should approximately recover the noise.
        let (spec, theta) = setup();
        let mut be = CpuStep {
            spec: &spec,
            theta: &theta,
        };
        let mut rng = Pcg64::seed(2);
        let d = spec.d;
        let x0: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let x1 = integrate(&mut be, x0.clone(), 0.0, 1.0, 64).unwrap();
        let back = integrate(&mut be, x1, 1.0, 0.0, 64).unwrap();
        let err: f32 = x0
            .iter()
            .zip(back.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        assert!(err < 0.05, "roundtrip err={err}");
    }

    #[test]
    fn integrate_dt_sign_matches_direction() {
        let (spec, theta) = setup();
        let mut be = CpuStep {
            spec: &spec,
            theta: &theta,
        };
        let x = vec![0.5f32; spec.d];
        let fwd = integrate(&mut be, x.clone(), 0.0, 1.0, 4).unwrap();
        let bwd = integrate(&mut be, x.clone(), 1.0, 0.0, 4).unwrap();
        assert_ne!(fwd, bwd);
    }

    #[test]
    fn engine_step_matches_cpu_backend() {
        use crate::engine::{CpuRefEngine, LutEngine, LutV2Engine};
        use crate::quant::{quantize_model, QuantMethod};
        let (spec, theta) = setup();
        let qm = quantize_model(&spec, &theta, QuantMethod::Ot, 3);
        let x0 = vec![0.25f32; 2 * spec.d];
        let mut direct = CpuQStep { qm: &qm };
        let want = generate_from(&mut direct, &x0, 6).unwrap();
        // the same model through the Engine impls and the adapter
        let cref = CpuRefEngine::quantized(&qm);
        let mut be = EngineStep::new(&cref);
        assert_eq!(generate_from(&mut be, &x0, 6).unwrap(), want);
        let lut = LutEngine::new(&qm).unwrap();
        let mut be = EngineStep::new(&lut);
        assert_eq!(generate_from(&mut be, &x0, 6).unwrap(), want);
        // the adapter's reused workspace is warm now; a second run must
        // be bit-identical to the first (dirty-arena invisibility)
        assert_eq!(generate_from(&mut be, &x0, 6).unwrap(), want);
        assert!(be.workspace_bytes() > 0);
        // the v2 blocked kernel re-associates sums: equal within the
        // integration harness tolerance, not bit-for-bit
        let lut2 = LutV2Engine::new(&qm).unwrap();
        let mut be = EngineStep::new(&lut2);
        let got = generate_from(&mut be, &x0, 6).unwrap();
        crate::util::check::assert_close(&got, &want, 1e-4, 1e-5);
    }

    #[test]
    fn run_direction_dispatches_generate_and_encode() {
        let (spec, theta) = setup();
        let mut be = CpuStep {
            spec: &spec,
            theta: &theta,
        };
        let x = vec![0.4f32; 2 * spec.d];
        let fwd = run_direction(&mut be, &x, Direction::Forward, 4).unwrap();
        assert_eq!(fwd, generate_from(&mut be, &x, 4).unwrap());
        let rev = run_direction(&mut be, &x, Direction::Reverse, 4).unwrap();
        assert_eq!(rev, encode(&mut be, &x, 4).unwrap());
        assert_ne!(fwd, rev);
    }

    /// Instrumentation must be an observer: sampling through the engine
    /// adapter is bit-identical with span timing on and off (the spans
    /// only read the clock and bump atomics), and the per-ODE-step
    /// histogram actually fills while timing is on.
    #[test]
    fn engine_step_is_bit_identical_with_timing_on_and_off() {
        use crate::engine::LutEngine;
        use crate::quant::{quantize_model, QuantMethod};
        let _g = crate::obs::span::TEST_TIMING_LOCK
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        let (spec, theta) = setup();
        let qm = quantize_model(&spec, &theta, QuantMethod::Ot, 4);
        let lut = LutEngine::new(&qm).unwrap();
        let x0 = vec![0.2f32; 3 * spec.d];

        crate::obs::set_timing_enabled(true);
        let before = crate::obs::ENGINE.ode_step_ns.snapshot().count;
        let mut be = EngineStep::new(&lut);
        let on = generate_from(&mut be, &x0, 6).unwrap();
        let after = crate::obs::ENGINE.ode_step_ns.snapshot().count;
        if !cfg!(feature = "no-obs") {
            assert_eq!(after - before, 6, "one record per ODE step");
        }

        crate::obs::set_timing_enabled(false);
        let mut be = EngineStep::new(&lut);
        let off = generate_from(&mut be, &x0, 6).unwrap();
        crate::obs::set_timing_enabled(true);

        assert_eq!(on, off, "timing must never change sampling results");
    }

    /// Cross-path regression (referenced by `flow::ode`'s module doc):
    /// the zero-alloc `run_solver` Heun path and the allocating
    /// `ode::integrate` Heun driver produce bit-identical trajectories
    /// through the same (bit-exact) engine.
    #[test]
    fn run_solver_heun_matches_integrate_bitwise() {
        use crate::engine::{Engine, LutEngine};
        use crate::quant::{quantize_model, QuantMethod};
        let (spec, theta) = setup();
        let qm = quantize_model(&spec, &theta, QuantMethod::Ot, 4);
        let lut = LutEngine::new(&qm).unwrap();
        let x0 = vec![0.3f32; 2 * spec.d];
        let mut be = EngineStep::new(&lut);
        let got = be.run_solver(x0.clone(), 0.0, 1.0, 5, Solver::Heun).unwrap();
        assert_eq!(be.last_evals(), 10, "2 evals per heun step");
        let d = spec.d;
        let mut f = |x: &[f32], t: f32| -> Result<Vec<f32>> {
            let ts = vec![t; x.len() / d];
            lut.velocity(x, &ts)
        };
        let want = crate::flow::ode::integrate(Solver::Heun, &mut f, x0, 0.0, 1.0, 5).unwrap();
        assert_eq!(got, want, "heun cross-path bit-identity");
    }

    /// StepGrid bit-contract regression: interleaving heun/dopri5 runs on
    /// the same adapter must not disturb the euler path's temb-cache
    /// keying — an euler run after heun+dopri5 is bit-identical to the
    /// euler run on the fresh (cold-cache) adapter.
    #[test]
    fn solver_runs_do_not_disturb_euler_temb_cache() {
        use crate::engine::LutEngine;
        use crate::quant::{quantize_model, QuantMethod};
        let (spec, theta) = setup();
        let qm = quantize_model(&spec, &theta, QuantMethod::Uniform, 4);
        let lut = LutEngine::new(&qm).unwrap();
        let x0 = vec![0.25f32; 2 * spec.d];
        let mut be = EngineStep::new(&lut);
        let first = be.run_solver(x0.clone(), 0.0, 1.0, 6, Solver::Euler).unwrap();
        let _ = be.run_solver(x0.clone(), 0.0, 1.0, 6, Solver::Heun).unwrap();
        let _ = be
            .run_solver(x0.clone(), 0.0, 1.0, 6, Solver::Dopri5)
            .unwrap();
        let again = be.run_solver(x0, 0.0, 1.0, 6, Solver::Euler).unwrap();
        assert_eq!(first, again, "heun/dopri5 disturbed the euler path");
        assert_eq!(be.last_evals(), 6, "euler records one eval per step");
    }

    /// dopri5 through the engine adapter: closer to the fine-grid euler
    /// reference than coarse euler at the same step hint, with its
    /// adaptive evaluation count recorded.
    #[test]
    fn run_solver_dopri5_tracks_fine_euler_reference() {
        use crate::engine::LutEngine;
        use crate::quant::{quantize_model, QuantMethod};
        let (spec, theta) = setup();
        let qm = quantize_model(&spec, &theta, QuantMethod::Ot, 8);
        let lut = LutEngine::new(&qm).unwrap();
        let x0 = vec![0.2f32; spec.d];
        let mut be = EngineStep::new(&lut);
        let reference = be
            .run_solver(x0.clone(), 0.0, 1.0, 256, Solver::Euler)
            .unwrap();
        let coarse = be.run_solver(x0.clone(), 0.0, 1.0, 8, Solver::Euler).unwrap();
        let adaptive = be.run_solver(x0, 0.0, 1.0, 8, Solver::Dopri5).unwrap();
        assert!(be.last_evals() >= 7, "fsal start + at least one step");
        let dist = |a: &[f32]| -> f64 {
            let mut acc = 0.0f64;
            for (&x, &y) in a.iter().zip(reference.iter()) {
                acc += f64::from(x - y) * f64::from(x - y);
            }
            acc.sqrt()
        };
        let (e_coarse, e_adaptive) = (dist(&coarse), dist(&adaptive));
        assert!(adaptive.iter().all(|v| v.is_finite()));
        assert!(
            e_adaptive < e_coarse,
            "dopri5 {e_adaptive} vs euler-8 {e_coarse}"
        );
    }

    #[test]
    fn generate_from_is_deterministic() {
        let (spec, theta) = setup();
        let mut be = CpuStep {
            spec: &spec,
            theta: &theta,
        };
        let x0 = vec![0.3f32; 2 * spec.d];
        let a = generate_from(&mut be, &x0, 8).unwrap();
        let b = generate_from(&mut be, &x0, 8).unwrap();
        assert_eq!(a, b);
    }
}
