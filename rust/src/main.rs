//! fmq — CLI for the OT-quantization flow-matching system.
//!
//! Subcommands map one-to-one onto the paper's experiments (pipeline
//! walkthrough in docs/ARCHITECTURE.md):
//!   train     train a velocity net on a synthetic dataset (AOT train_step)
//!   quantize  post-training-quantize a checkpoint at (method, bits)
//!   generate  sample images from a checkpoint / quantized model
//!   sweep     Fig. 3 fidelity grid -> results/fig3_*.csv
//!   latent    Fig. 4 latent-stability grid -> results/fig4_latent.csv
//!   grid      Figs. 2 & 5–8 sample grids -> results/*.ppm
//!   theory    ρ(b), bound curves, bit budgets -> results/theory_*.csv
//!   figgrid   paper-grid conformance sweep -> BENCH_figgrid.json
//!   serve     TCP serving with dynamic batching
//!   info      artifact/manifest status

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{bail, Result};

use fmq::coordinator::experiment::{pseudo_trained_theta, EvalContext};
use fmq::coordinator::registry::Registry;
use fmq::coordinator::report;
use fmq::coordinator::server::{serve, ServerConfig};
use fmq::data::Dataset;
use fmq::engine::EngineKind;
use fmq::flow::train::{train, TrainConfig};
use fmq::model::checkpoint;
use fmq::model::params::ParamStore;
use fmq::model::spec::ModelSpec;
use fmq::quant::{quantize_model, QuantMethod};
use fmq::runtime::{artifacts, ArtifactSet};
use fmq::theory::alpha::{alpha_spacing, spacing_for};
use fmq::theory::bounds::BoundInputs;
use fmq::util::cli::Command;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(sub) = argv.first() else {
        print_help();
        return Ok(());
    };
    let rest = argv.get(1..).unwrap_or(&[]);
    match sub.as_str() {
        "train" => cmd_train(rest),
        "quantize" => cmd_quantize(rest),
        "generate" => cmd_generate(rest),
        "sweep" => cmd_sweep(rest),
        "latent" => cmd_latent(rest),
        "grid" => cmd_grid(rest),
        "theory" => cmd_theory(rest),
        "figgrid" => cmd_figgrid(rest),
        "serve" => cmd_serve(rest),
        "info" => cmd_info(rest),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown subcommand '{other}' — run `fmq help`"),
    }
}

fn print_help() {
    println!(
        "fmq — Low-Bit, High-Fidelity: OT Quantization for Flow Matching\n\
         \n\
         subcommands:\n\
           train     train a velocity net (needs artifacts)\n\
           quantize  PTQ a checkpoint at --method/--bits\n\
           generate  sample images from a model\n\
           sweep     Fig. 3 fidelity grid (SSIM/PSNR csv)\n\
           latent    Fig. 4 latent-stability grid (csv)\n\
           grid      Figs. 2 & 5-8 sample grids (ppm)\n\
           theory    rho(b), FID bounds, bit budgets (csv)\n\
           figgrid   paper-grid conformance sweep (BENCH_figgrid.json)\n\
           serve     TCP serving with dynamic batching\n\
           info      artifact/manifest status\n\
         run `fmq <sub> --help` for flags"
    )
}

// ------------------------------------------------------------- helpers

fn load_art(required: bool) -> Result<Option<ArtifactSet>> {
    let dir = artifacts::default_dir();
    if artifacts::available(&dir) {
        println!("loading artifacts from {dir:?} ...");
        Ok(Some(ArtifactSet::load(&dir)?))
    } else if required {
        bail!("artifacts missing at {dir:?} — run `make artifacts`")
    } else {
        println!("(no artifacts at {dir:?} — using CPU reference backend)");
        Ok(None)
    }
}

/// Load theta from --ckpt, else pseudo-trained weights for the dataset.
fn theta_for(
    spec: &ModelSpec,
    ckpt: &str,
    dataset: Dataset,
) -> Result<ParamStore> {
    if ckpt.is_empty() {
        Ok(pseudo_trained_theta(spec, dataset))
    } else {
        checkpoint::load_theta(Path::new(ckpt), spec)
    }
}

/// Parse `--engine`: `auto` (None — let the layer pick) or a concrete kind.
fn parse_engine(args: &fmq::util::cli::Args) -> Result<Option<EngineKind>> {
    if args.get("engine") == "auto" {
        return Ok(None);
    }
    Ok(Some(args.get_parse::<EngineKind>("engine")?))
}

fn parse_bits(args: &fmq::util::cli::Args) -> Result<Vec<u8>> {
    args.get_list("bits")
        .iter()
        .map(|s| Ok(s.parse::<u8>()?))
        .collect()
}

fn parse_methods(args: &fmq::util::cli::Args) -> Result<Vec<QuantMethod>> {
    args.get_list("methods")
        .iter()
        .map(|s| QuantMethod::parse(s).ok_or_else(|| anyhow::anyhow!("unknown method '{s}'")))
        .collect()
}

fn parse_datasets(args: &fmq::util::cli::Args) -> Result<Vec<Dataset>> {
    let list = args.get_list("datasets");
    if list.len() == 1 && list.first().is_some_and(|s| *s == "all") {
        return Ok(Dataset::ALL.to_vec());
    }
    list.iter()
        .map(|s| Dataset::parse(s).ok_or_else(|| anyhow::anyhow!("unknown dataset '{s}'")))
        .collect()
}

// ------------------------------------------------------------ commands

fn cmd_train(argv: &[String]) -> Result<()> {
    let cmd = Command::new("train", "train a velocity net via the AOT train_step")
        .flag("dataset", "synth-mnist", "dataset name")
        .flag("steps", "400", "training steps")
        .flag("lr", "0.001", "learning rate")
        .flag("seed", "42", "rng seed")
        .flag("out", "checkpoints/model.fmq", "output checkpoint");
    let a = cmd.parse(argv)?;
    let dataset = Dataset::parse(a.get("dataset"))
        .ok_or_else(|| anyhow::anyhow!("unknown dataset"))?;
    let art = load_art(true)?
        .ok_or_else(|| anyhow::anyhow!("AOT artifacts required for training (build them first)"))?;
    let cfg = TrainConfig {
        steps: a.get_usize("steps")?,
        lr: a.get_f32("lr")?,
        seed: a.get_u64("seed")?,
        log_every: 50,
    };
    println!("training on {} for {} steps ...", dataset.name(), cfg.steps);
    let res = train(&art, dataset, &cfg)?;
    println!(
        "done in {:.1}s; loss {:.3} -> {:.3} (improvement x{:.2})",
        res.wall_s,
        res.losses.first().map(|&(_, l)| l).unwrap_or(0.0),
        res.losses.last().map(|&(_, l)| l).unwrap_or(0.0),
        fmq::flow::train::loss_improvement(&res.losses)
    );
    let out = PathBuf::from(a.get("out"));
    if let Some(p) = out.parent() {
        std::fs::create_dir_all(p)?;
    }
    // fmq-analyze: allow(det_taint) -- train's clock feeds only the wall_s progress line; theta bytes are a pure function of (seed, dataset, spec)
    checkpoint::save_theta(
        &out,
        &res.theta,
        vec![(
            "dataset",
            fmq::util::json::Json::Str(dataset.name().to_string()),
        )],
    )?;
    println!("checkpoint -> {out:?}");
    Ok(())
}

fn cmd_quantize(argv: &[String]) -> Result<()> {
    let cmd = Command::new("quantize", "post-training quantization of a checkpoint")
        .flag("ckpt", "checkpoints/model.fmq", "input checkpoint")
        .flag("method", "ot", "ot|uniform|pwl|log2")
        .flag("bits", "4", "bit-width")
        .flag("out", "", "output path (default <ckpt>.<method><bits>)");
    let a = cmd.parse(argv)?;
    let spec = ModelSpec::default_spec();
    let theta = checkpoint::load_theta(Path::new(a.get("ckpt")), &spec)?;
    let method = QuantMethod::parse(a.get("method"))
        .ok_or_else(|| anyhow::anyhow!("unknown method"))?;
    let bits: u8 = a.get("bits").parse()?;
    let qm = quantize_model(&spec, &theta, method, bits);
    let err = qm.w2_error(&theta);
    println!(
        "{} @ {} bits: W2^2 = {:.3e}, sup = {:.3e}, compression x{:.2}, utilization {:.1}%",
        method.name(),
        bits,
        err.w2_sq,
        err.sup,
        qm.compression_ratio(),
        100.0 * qm.mean_utilization()
    );
    let out = if a.get("out").is_empty() {
        format!("{}.{}{}", a.get("ckpt"), method.name(), bits)
    } else {
        a.get("out").to_string()
    };
    checkpoint::save_quantized(Path::new(&out), &qm)?;
    println!("quantized model -> {out}");
    Ok(())
}

fn cmd_generate(argv: &[String]) -> Result<()> {
    let cmd = Command::new("generate", "sample images")
        .flag("ckpt", "", "fp32 checkpoint (empty = pseudo-trained)")
        .flag("qckpt", "", "quantized checkpoint (overrides --ckpt)")
        .flag("dataset", "synth-mnist", "dataset (for pseudo weights)")
        .flag("n", "16", "number of samples")
        .flag("steps", "32", "euler steps")
        .flag("seed", "7", "rng seed")
        .flag("engine", "auto", "execution backend: auto|cpu-ref|lut|lut2|runtime")
        .flag("out", "results/samples.ppm", "output grid");
    let a = cmd.parse(argv)?;
    let spec = ModelSpec::default_spec();
    let art = load_art(false)?;
    let dataset = Dataset::parse(a.get("dataset"))
        .ok_or_else(|| anyhow::anyhow!("unknown dataset"))?;
    let ctx = EvalContext {
        spec: spec.clone(),
        art: art.as_ref(),
        steps: a.get_usize("steps")?,
        n: a.get_usize("n")?,
        seed: a.get_u64("seed")?,
        engine: parse_engine(&a)?,
    };
    let x0 = ctx.start_noise();
    let imgs = if !a.get("qckpt").is_empty() {
        let qm = checkpoint::load_quantized(Path::new(a.get("qckpt")), &spec)?;
        ctx.generate_quant(&qm, &x0)?
    } else {
        let theta = theta_for(&spec, a.get("ckpt"), dataset)?;
        ctx.generate_fp32(&theta, &x0)?
    };
    let out = PathBuf::from(a.get("out"));
    let keep = ctx.n.min(imgs.len() / spec.d) * spec.d;
    report::write_image_grid(&out, imgs.get(..keep).unwrap_or(&[]), 8)?;
    println!("{} samples -> {out:?}", ctx.n);
    Ok(())
}

fn cmd_sweep(argv: &[String]) -> Result<()> {
    let cmd = Command::new("sweep", "Fig. 3: SSIM/PSNR over (dataset x method x bits)")
        .flag("datasets", "all", "comma list or 'all'")
        .flag("methods", "ot,uniform,pwl,log2", "quantizers")
        .flag("bits", "2,3,4,5,6,8", "bit-widths")
        .flag("steps", "16", "euler steps")
        .flag("n", "32", "samples per point")
        .flag("seed", "7", "rng seed")
        .flag("engine", "auto", "quantized-path backend: auto|cpu-ref|lut|lut2|runtime")
        .flag("ckpt-dir", "checkpoints", "per-dataset checkpoints (model-<ds>.fmq)")
        .flag("out", "results", "output directory");
    let a = cmd.parse(argv)?;
    let spec = ModelSpec::default_spec();
    let art = load_art(false)?;
    let ctx = EvalContext {
        spec: spec.clone(),
        art: art.as_ref(),
        steps: a.get_usize("steps")?,
        n: a.get_usize("n")?,
        seed: a.get_u64("seed")?,
        engine: parse_engine(&a)?,
    };
    let methods = parse_methods(&a)?;
    let bits = parse_bits(&a)?;
    let mut all = Vec::new();
    for ds in parse_datasets(&a)? {
        let ckpt = PathBuf::from(a.get("ckpt-dir")).join(format!("model-{}.fmq", ds.name()));
        let theta = if ckpt.exists() {
            println!("[{}] using trained checkpoint {ckpt:?}", ds.name());
            checkpoint::load_theta(&ckpt, &spec)?
        } else {
            println!("[{}] no checkpoint — pseudo-trained weights", ds.name());
            pseudo_trained_theta(&spec, ds)
        };
        let points = ctx.fidelity_sweep(ds, &theta, &methods, &bits)?;
        for p in &points {
            println!(
                "  {} {} b={}: ssim {:.4} psnr {:.2} w2 {:.2e}",
                p.dataset,
                p.method.name(),
                p.bits,
                p.ssim,
                p.psnr,
                p.w2_sq
            );
        }
        all.extend(points);
    }
    let out = PathBuf::from(a.get("out"));
    report::fidelity_csv(&out.join("fig3_fidelity.csv"), &all)?;
    println!("-> {:?}", out.join("fig3_fidelity.csv"));
    Ok(())
}

fn cmd_latent(argv: &[String]) -> Result<()> {
    let cmd = Command::new("latent", "Fig. 4: latent variance stability grid")
        .flag("datasets", "all", "comma list or 'all'")
        .flag("methods", "ot,uniform,pwl,log2", "quantizers")
        .flag("bits", "2,3,4,5,6,8", "bit-widths")
        .flag("steps", "16", "euler steps")
        .flag("n", "32", "images per point")
        .flag("seed", "7", "rng seed")
        .flag("engine", "auto", "quantized-path backend: auto|cpu-ref|lut|lut2|runtime")
        .flag("ckpt-dir", "checkpoints", "per-dataset checkpoints")
        .flag("out", "results", "output directory");
    let a = cmd.parse(argv)?;
    let spec = ModelSpec::default_spec();
    let art = load_art(false)?;
    let ctx = EvalContext {
        spec: spec.clone(),
        art: art.as_ref(),
        steps: a.get_usize("steps")?,
        n: a.get_usize("n")?,
        seed: a.get_u64("seed")?,
        engine: parse_engine(&a)?,
    };
    let methods = parse_methods(&a)?;
    let bits = parse_bits(&a)?;
    let mut all = Vec::new();
    for ds in parse_datasets(&a)? {
        let ckpt = PathBuf::from(a.get("ckpt-dir")).join(format!("model-{}.fmq", ds.name()));
        let theta = if ckpt.exists() {
            checkpoint::load_theta(&ckpt, &spec)?
        } else {
            pseudo_trained_theta(&spec, ds)
        };
        let points = ctx.latent_sweep(ds, &theta, &methods, &bits)?;
        for p in &points {
            println!(
                "  {} {} b={}: var_std {:.4} (fp32 {:.4}) max|z| {:.2}",
                p.dataset,
                p.method.name(),
                p.bits,
                p.stats.var_std,
                p.baseline_var_std,
                p.stats.max_abs
            );
        }
        all.extend(points);
    }
    let out = PathBuf::from(a.get("out"));
    report::latent_csv(&out.join("fig4_latent.csv"), &all)?;
    println!("-> {:?}", out.join("fig4_latent.csv"));
    Ok(())
}

fn cmd_grid(argv: &[String]) -> Result<()> {
    let cmd = Command::new("grid", "Figs. 2 & 5-8: qualitative sample grids")
        .flag("datasets", "synth-celeba", "comma list or 'all'")
        .flag("methods", "ot,uniform,pwl,log2", "quantizers")
        .flag("bits", "2,3,4,6,8", "bit-widths")
        .flag("steps", "32", "euler steps")
        .flag("n", "16", "samples per grid")
        .flag("seed", "7", "rng seed")
        .flag("engine", "auto", "quantized-path backend: auto|cpu-ref|lut|lut2|runtime")
        .flag("ckpt-dir", "checkpoints", "per-dataset checkpoints")
        .flag("out", "results", "output directory");
    let a = cmd.parse(argv)?;
    let spec = ModelSpec::default_spec();
    let art = load_art(false)?;
    let ctx = EvalContext {
        spec: spec.clone(),
        art: art.as_ref(),
        steps: a.get_usize("steps")?,
        n: a.get_usize("n")?,
        seed: a.get_u64("seed")?,
        engine: parse_engine(&a)?,
    };
    let out = PathBuf::from(a.get("out"));
    let bits = parse_bits(&a)?;
    let methods = parse_methods(&a)?;
    for ds in parse_datasets(&a)? {
        let ckpt = PathBuf::from(a.get("ckpt-dir")).join(format!("model-{}.fmq", ds.name()));
        let theta = if ckpt.exists() {
            checkpoint::load_theta(&ckpt, &spec)?
        } else {
            pseudo_trained_theta(&spec, ds)
        };
        let x0 = ctx.start_noise();
        let dir = out.join("grids").join(ds.name());
        let reference = ctx.generate_fp32(&theta, &x0)?;
        report::write_image_grid(&dir.join("fp32.ppm"), &reference, 8)?;
        for &m in &methods {
            for &b in &bits {
                let qm = quantize_model(&spec, &theta, m, b);
                let imgs = ctx.generate_quant(&qm, &x0)?;
                let name = format!("{}{}.ppm", m.name(), b);
                report::write_image_grid(&dir.join(&name), &imgs, 8)?;
            }
        }
        println!("[{}] grids -> {dir:?}", ds.name());
    }
    Ok(())
}

fn cmd_theory(argv: &[String]) -> Result<()> {
    let cmd = Command::new("theory", "rho(b), FID bound curves, bit budgets")
        .flag("ckpt", "", "checkpoint for empirical alpha (else Gaussian)")
        .flag("sigma", "0.05", "weight std for analytic tables")
        .flag("k-sigma", "10", "uniform clipping range in sigmas")
        .flag("out", "results", "output directory");
    let a = cmd.parse(argv)?;
    let out = PathBuf::from(a.get("out"));
    std::fs::create_dir_all(&out)?;
    let sigma = a.get_f64("sigma")?;
    let k = a.get_f64("k-sigma")?;

    // analytic table (paper's "Provable Advantages" numbers)
    let b_gauss = BoundInputs::paper_defaults(sigma, k);
    let alpha_l =
        fmq::stats::dist::alpha_laplace(sigma / std::f64::consts::SQRT_2);
    println!("analytic (sigma={sigma}, R={k}sigma):");
    println!(
        "  gaussian: alpha^3/R^2 = {:.4} (paper: 0.33), rho = {:.4}",
        b_gauss.alpha.powi(3) / (b_gauss.r * b_gauss.r),
        b_gauss.rho()
    );
    println!(
        "  laplace:  alpha^3/R^2 = {:.4} (paper: 0.54)",
        alpha_l.powi(3) / (b_gauss.r * b_gauss.r)
    );

    // empirical alpha from a real checkpoint, per layer
    let mut rows = vec![];
    if !a.get("ckpt").is_empty() {
        let spec = ModelSpec::default_spec();
        let theta = checkpoint::load_theta(Path::new(a.get("ckpt")), &spec)?;
        println!("per-layer empirical alpha (trained weights):");
        for l in spec.weight_layers() {
            let w = theta.layer(&spec, &l.name);
            let alpha = alpha_spacing(w, spacing_for(w.len()));
            let r = fmq::quant::uniform::symmetric_range(w) as f64;
            let ratio = alpha.powi(3) / (r * r);
            println!("  {:8} alpha={alpha:.4} R={r:.4} alpha^3/R^2={ratio:.4}", l.name);
            rows.push(format!("{},{alpha:.6},{r:.6},{ratio:.6}", l.name));
        }
        report::write_csv(
            &out.join("theory_alpha_layers.csv"),
            "layer,alpha,r,alpha3_over_r2",
            &rows,
        )?;
    }

    // bound curves + bit budgets
    let mut curve = vec![];
    for bits in 2..=8u8 {
        curve.push(format!(
            "{bits},{:.6e},{:.6e}",
            b_gauss.fid_bound_uniform(bits),
            b_gauss.fid_bound_ot(bits)
        ));
    }
    report::write_csv(
        &out.join("theory_bounds.csv"),
        "bits,fid_bound_uniform,fid_bound_ot",
        &curve,
    )?;
    let mut budget = vec![];
    for delta_exp in 1..=6 {
        let delta = 10f64.powi(-delta_exp);
        budget.push(format!(
            "{delta:.0e},{},{}",
            b_gauss.bit_budget(delta, false),
            b_gauss.bit_budget(delta, true)
        ));
    }
    report::write_csv(
        &out.join("theory_budget.csv"),
        "delta_max,bits_uniform,bits_ot",
        &budget,
    )?;
    println!("-> {:?}, theory_bounds.csv, theory_budget.csv", out);
    Ok(())
}

fn parse_solvers(args: &fmq::util::cli::Args) -> Result<Vec<fmq::flow::ode::Solver>> {
    args.get_list("solvers")
        .iter()
        .map(|s| {
            fmq::flow::ode::Solver::parse(s)
                .ok_or_else(|| anyhow::anyhow!("unknown solver '{s}'"))
        })
        .collect()
}

fn cmd_figgrid(argv: &[String]) -> Result<()> {
    let cmd = Command::new(
        "figgrid",
        "paper-grid conformance sweep: datasets x methods x bits x solvers -> BENCH_figgrid.json",
    )
    .flag("datasets", "all", "comma list or 'all'")
    .flag("methods", "ot,uniform,pwl,log2", "quantizers")
    .flag("bits", "2,3,4,8", "bit-widths")
    .flag("solvers", "euler,heun,dopri5", "ODE solvers")
    .flag("steps", "16", "steps per trajectory (dopri5: initial-step hint)")
    .flag("n", "64", "samples per cell")
    .flag("batch", "16", "samples per engine super-batch")
    .flag("seed", "7", "rng seed")
    .flag("engine", "lut2", "primary backend: cpu-ref|lut|lut2")
    .flag("check-engine", "cpu-ref", "cross-check backend")
    .flag("out", "BENCH_figgrid.json", "output JSON path");
    let a = cmd.parse(argv)?;
    let mut spec = fmq::sweep::GridSpec {
        datasets: parse_datasets(&a)?,
        methods: parse_methods(&a)?,
        bits: parse_bits(&a)?,
        solvers: parse_solvers(&a)?,
        steps: a.get_usize("steps")?,
        n: a.get_usize("n")?,
        batch: a.get_usize("batch")?.max(1),
        seed: a.get_u64("seed")?,
        engine: a.get_parse::<EngineKind>("engine")?,
        check_engine: a.get_parse::<EngineKind>("check-engine")?,
        ..fmq::sweep::GridSpec::full()
    };
    if std::env::var("FMQ_BENCH_FAST").is_ok_and(|v| v == "1") {
        // CI smoke tier: keep the axes/engines chosen above, shrink the
        // per-cell work to the smoke sizes (and drop the 4-bit column).
        spec = fmq::sweep::GridSpec {
            datasets: spec.datasets,
            methods: spec.methods,
            solvers: spec.solvers,
            seed: spec.seed,
            engine: spec.engine,
            check_engine: spec.check_engine,
            ..fmq::sweep::GridSpec::smoke()
        };
    }
    println!(
        "figgrid: {} cells ({} datasets x {} methods x {:?} bits x {} solvers), \
         n={} steps={} engine={} check={}{}",
        spec.cells(),
        spec.datasets.len(),
        spec.methods.len(),
        spec.bits,
        spec.solvers.len(),
        spec.n,
        spec.steps,
        spec.engine.name(),
        spec.check_engine.name(),
        if spec.fast { " [FMQ_BENCH_FAST smoke tier]" } else { "" }
    );
    let start = std::time::Instant::now();
    let res = fmq::sweep::run_grid(&spec)?;
    for d in &res.datasets {
        println!("  [{}] L_x_hat = {:.3}", d.dataset.name(), d.l_x_hat);
    }
    for c in &res.cells {
        println!(
            "  {}: ssim {:.4} psnr {:.2} fid {:.3} w2 {:.2e} traj {:.2e}<={:.2e} \
             engine_dev {:.1e} ({} evals, {:.1} us/step)",
            c.key(),
            c.ssim,
            c.psnr,
            c.fid,
            c.w2_sq,
            c.traj_dev,
            c.traj_bound,
            c.engine_dev,
            c.evals,
            c.per_step_us
        );
    }
    let out = PathBuf::from(a.get("out"));
    // fmq-analyze: allow(det_taint) -- the per_step_us fields in BENCH_figgrid.json are informational bench metadata; golden conformance compares only the deterministic metric fields
    res.write_json(&out)?;
    println!(
        "{} cells in {:.1}s -> {out:?}",
        res.cells.len(),
        start.elapsed().as_secs_f64()
    );
    // conformance AFTER the JSON lands, so a failing grid is inspectable
    let violations = fmq::sweep::conformance::check(&res);
    if !violations.is_empty() {
        for v in &violations {
            eprintln!("CONFORMANCE VIOLATION: {v}");
        }
        bail!("{} conformance violation(s) — see {out:?}", violations.len());
    }
    println!("conformance: all invariants hold");
    Ok(())
}

fn cmd_serve(argv: &[String]) -> Result<()> {
    let cmd = Command::new("serve", "TCP serving with dynamic batching")
        .flag("addr", "127.0.0.1:7878", "listen address")
        .flag("ckpt", "", "fp32 checkpoint (empty = pseudo-trained)")
        .flag("dataset", "synth-celeba", "dataset for pseudo weights")
        .flag("methods", "ot,uniform", "variants to build")
        .flag("bits", "2,4,8", "bit-widths to build")
        .flag("steps", "16", "euler steps per sample")
        .flag("engine", "auto", "execution backend: auto|cpu-ref|lut|lut2|runtime")
        .flag("queue", "256", "per-variant request queue bound (backpressure)")
        .flag(
            "metrics-dump",
            "",
            "write a Prometheus text metrics snapshot here on shutdown",
        )
        .flag(
            "drain-ms",
            "5000",
            "graceful-stop drain window before stragglers are failed",
        );
    let a = cmd.parse(argv)?;
    let spec = ModelSpec::default_spec();
    let dataset = Dataset::parse(a.get("dataset"))
        .ok_or_else(|| anyhow::anyhow!("unknown dataset"))?;
    let theta = theta_for(&spec, a.get("ckpt"), dataset)?;
    let methods = parse_methods(&a)?;
    let bits = parse_bits(&a)?;
    println!("building variant fleet ({} methods x {} bits + fp32) ...", methods.len(), bits.len());
    let registry = Arc::new(Registry::build_fleet(&spec, &theta, &methods, &bits));
    let art = load_art(false)?.map(|a| Arc::new(fmq::runtime::SharedArtifacts::new(a)));
    let engine = parse_engine(&a)?;
    let metrics_dump = match a.get("metrics-dump") {
        "" => None,
        p => Some(std::path::PathBuf::from(p)),
    };
    // the fault plan is read from FMQ_FAULTS here — the CLI entrypoint —
    // and nowhere else, so library users and unrelated tests never pick
    // up a fault schedule from the ambient environment
    let faults = fmq::faults::FaultPlan::from_env()?;
    if !faults.is_empty() {
        println!(
            "fault injection ACTIVE: {} rule(s) from FMQ_FAULTS (seed {})",
            faults.rules_len(),
            faults.seed()
        );
    } else if std::env::var_os("FMQ_FAULTS").is_some() {
        // built without the `faults` feature the plan is an inert ZST:
        // say so instead of silently ignoring the operator's schedule
        println!("FMQ_FAULTS set but this build has no `faults` feature; plan is inert");
    }
    let cfg = ServerConfig {
        addr: a.get("addr").to_string(),
        steps: a.get_usize("steps")?,
        engine,
        queue_cap: a.get_usize("queue")?.max(1),
        metrics_dump,
        drain: std::time::Duration::from_millis(a.get_usize("drain-ms")? as u64),
        faults: Arc::new(faults),
        ..Default::default()
    };
    let server = serve(registry.clone(), art, cfg)?;
    println!(
        "serving {} variants on {} (engine: {}) — ops: \
         generate/encode/stats/metrics/models/ping/shutdown \
         (deterministic per (model, n, seed); n up to 256 sliced to exact count)",
        registry.len(),
        server.addr,
        engine.map(|k| k.name()).unwrap_or("auto")
    );
    // block until the shutdown op flips the flag, then join workers and
    // write the --metrics-dump snapshot (Server::stop)
    loop {
        std::thread::sleep(std::time::Duration::from_millis(200));
        if server.shutdown_requested() {
            break;
        }
        if server.stats.requests.get() > 0 && server.stats.samples.get() % 1000 == 999 {
            // periodic stats line (cheap, approximate; also served as
            // the `stats` op)
            println!(
                "requests={} batches={} samples={} encodes={} errors={} queue_depth={}",
                server.stats.requests.get(),
                server.stats.batches.get(),
                server.stats.samples.get(),
                server.stats.encodes.get(),
                server.stats.errors.get(),
                server.stats.queue_depth.get()
            );
        }
    }
    server.stop();
    Ok(())
}

fn cmd_info(argv: &[String]) -> Result<()> {
    let cmd = Command::new("info", "artifact/manifest status");
    let _a = cmd.parse(argv)?;
    let spec = ModelSpec::default_spec();
    println!(
        "model: d={} hidden={} blocks={} P={} PW={} ({} weight tensors)",
        spec.d,
        spec.hidden,
        spec.blocks,
        spec.p(),
        spec.pw(),
        spec.weight_layers().len()
    );
    let dir = artifacts::default_dir();
    if artifacts::available(&dir) {
        println!("artifacts: complete at {dir:?}");
        let art = ArtifactSet::load(&dir)?;
        println!(
            "  b_train={} b_sample={} assign_chunk={} (manifest cross-check OK)",
            art.b_train, art.b_sample, art.assign_chunk
        );
    } else {
        println!("artifacts: MISSING at {dir:?} — run `make artifacts`");
    }
    Ok(())
}
