//! Histograms and histogram-based density estimation.
//!
//! Used by `theory::alpha` to estimate α(f_W) = ∫ f^{1/3} dw from *trained*
//! weights (the paper evaluates α analytically for Gaussian/Laplace and
//! empirically from layer histograms).

#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<u64>,
    pub n: u64,
}

impl Histogram {
    /// Build with `bins` uniform bins spanning [min, max] of the data.
    pub fn build(xs: &[f32], bins: usize) -> Self {
        assert!(bins > 0);
        let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
        for &x in xs {
            lo = lo.min(x as f64);
            hi = hi.max(x as f64);
        }
        if !lo.is_finite() || lo == hi {
            lo -= 0.5;
            hi += 0.5;
        }
        let mut counts = vec![0u64; bins];
        let w = (hi - lo) / bins as f64;
        for &x in xs {
            let mut b = ((x as f64 - lo) / w) as usize;
            if b >= bins {
                b = bins - 1;
            }
            counts[b] += 1;
        }
        Self {
            lo,
            hi,
            counts,
            n: xs.len() as u64,
        }
    }

    pub fn bin_width(&self) -> f64 {
        (self.hi - self.lo) / self.counts.len() as f64
    }

    /// Density estimate at bin centers: f̂_i = c_i / (n·Δ).
    pub fn density(&self) -> Vec<(f64, f64)> {
        let w = self.bin_width();
        self.counts
            .iter()
            .enumerate()
            .map(|(i, &c)| {
                let center = self.lo + (i as f64 + 0.5) * w;
                (center, c as f64 / (self.n as f64 * w))
            })
            .collect()
    }

    /// Riemann estimate of ∫ f̂(w)^{1/3} dw — the paper's α(f_W).
    pub fn alpha_integral(&self) -> f64 {
        let w = self.bin_width();
        self.density()
            .iter()
            .map(|&(_, f)| f.powf(1.0 / 3.0) * w)
            .sum()
    }

    /// Fraction of total mass in the given bin range.
    pub fn mass(&self, lo_bin: usize, hi_bin: usize) -> f64 {
        let c: u64 = self.counts[lo_bin..hi_bin].iter().sum();
        c as f64 / self.n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::dist::alpha_gaussian;
    use crate::util::rng::Pcg64;

    #[test]
    fn counts_sum_to_n() {
        let xs: Vec<f32> = (0..1000).map(|i| (i % 10) as f32).collect();
        let h = Histogram::build(&xs, 10);
        assert_eq!(h.counts.iter().sum::<u64>(), 1000);
    }

    #[test]
    fn density_integrates_to_one() {
        let mut rng = Pcg64::seed(11);
        let xs: Vec<f32> = (0..50_000).map(|_| rng.normal() as f32).collect();
        let h = Histogram::build(&xs, 128);
        let total: f64 = h.density().iter().map(|&(_, f)| f * h.bin_width()).sum();
        assert!((total - 1.0).abs() < 1e-9, "total={total}");
    }

    /// The empirical α estimate on Gaussian draws must land near the paper's
    /// closed form 3.197·σ^{2/3}. This is the key calibration the theory
    /// module relies on.
    #[test]
    fn alpha_integral_matches_gaussian_closed_form() {
        let mut rng = Pcg64::seed(12);
        let sigma = 0.05f64;
        let xs: Vec<f32> = (0..200_000)
            .map(|_| (rng.normal() * sigma) as f32)
            .collect();
        let h = Histogram::build(&xs, 256);
        let a = h.alpha_integral();
        let closed = alpha_gaussian(sigma);
        let rel = (a - closed).abs() / closed;
        assert!(rel < 0.05, "a={a} closed={closed} rel={rel}");
    }

    #[test]
    fn degenerate_constant_data() {
        let xs = vec![1.0f32; 100];
        let h = Histogram::build(&xs, 8);
        assert_eq!(h.counts.iter().sum::<u64>(), 100);
        assert!(h.bin_width() > 0.0);
    }

    #[test]
    fn mass_fractions() {
        let xs: Vec<f32> = (0..100).map(|i| i as f32).collect();
        let h = Histogram::build(&xs, 4);
        assert!((h.mass(0, 4) - 1.0).abs() < 1e-12);
        assert!((h.mass(0, 2) - 0.5).abs() < 0.03);
    }
}
