//! Distribution functions: erf, Gaussian and Laplace pdf/cdf/quantile.
//!
//! The paper's theory section evaluates α(f_W) = ∫ f^{1/3} analytically for
//! Gaussian and Laplace weight densities; these closed forms live here so
//! the theory module and its tests share one implementation.

use std::f64::consts::PI;

/// Abramowitz–Stegun 7.1.26 rational approximation of erf (|err| < 1.5e-7).
pub fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.327_591_1 * x);
    let y = 1.0
        - (((((1.061_405_429 * t - 1.453_152_027) * t) + 1.421_413_741) * t - 0.284_496_736)
            * t
            + 0.254_829_592)
            * t
            * (-x * x).exp();
    sign * y
}

/// Standard normal pdf.
pub fn normal_pdf(x: f64) -> f64 {
    (-0.5 * x * x).exp() / (2.0 * PI).sqrt()
}

/// Standard normal cdf.
pub fn normal_cdf(x: f64) -> f64 {
    0.5 * (1.0 + erf(x / std::f64::consts::SQRT_2))
}

/// Standard normal quantile (Acklam's algorithm, |rel err| < 1.2e-9).
pub fn normal_quantile(p: f64) -> f64 {
    assert!(p > 0.0 && p < 1.0, "quantile needs p in (0,1), got {p}");
    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    let plow = 0.02425;
    if p < plow {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - plow {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -(((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    }
}

/// Laplace(0, beta) pdf.
pub fn laplace_pdf(x: f64, beta: f64) -> f64 {
    (-x.abs() / beta).exp() / (2.0 * beta)
}

/// Laplace(0, beta) cdf.
pub fn laplace_cdf(x: f64, beta: f64) -> f64 {
    if x < 0.0 {
        0.5 * (x / beta).exp()
    } else {
        1.0 - 0.5 * (-x / beta).exp()
    }
}

/// Laplace(0, beta) quantile.
pub fn laplace_quantile(p: f64, beta: f64) -> f64 {
    assert!(p > 0.0 && p < 1.0);
    if p < 0.5 {
        beta * (2.0 * p).ln()
    } else {
        -beta * (2.0 * (1.0 - p)).ln()
    }
}

/// α(f) = ∫ f^{1/3} for N(0, σ²). Closed form (paper Eq. 18):
/// α = (√(2π) σ)^{-1/3} · √(6π) σ = (2π)^{-1/6} √(6π) σ^{2/3} ≈ 3.1967 σ^{2/3}
/// so α³ ≈ 32.67 σ² (the paper rounds to 32.8).
pub fn alpha_gaussian(sigma: f64) -> f64 {
    (2.0 * PI).powf(-1.0 / 6.0) * (6.0 * PI).sqrt() * sigma.powf(2.0 / 3.0)
}

/// α(f) for Laplace(0, β): ∫ ( e^{-|w|/β} / 2β )^{1/3} dw
/// = (2β)^{-1/3} · 2 ∫₀^∞ e^{-w/(3β)} dw = (2β)^{-1/3} · 6β = 6 β^{2/3} 2^{-1/3}·...
/// Simplifies to α = 6 β / (2β)^{1/3} = 6 · 2^{-1/3} β^{2/3}, so
/// α³ = 216/2 · β² = 108 β² = 54 σ² (σ² = 2β², paper's value).
pub fn alpha_laplace(beta: f64) -> f64 {
    6.0 * beta / (2.0 * beta).powf(1.0 / 3.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn erf_known_values() {
        // A&S 7.1.26 has |err| < 1.5e-7 (including at 0)
        assert!((erf(0.0)).abs() < 1e-7);
        assert!((erf(1.0) - 0.842_700_79).abs() < 1e-6);
        assert!((erf(-1.0) + 0.842_700_79).abs() < 1e-6);
        assert!((erf(3.0) - 0.999_977_91).abs() < 1e-6);
    }

    #[test]
    fn normal_cdf_symmetry() {
        for &x in &[0.1, 0.7, 1.5, 2.5] {
            assert!((normal_cdf(x) + normal_cdf(-x) - 1.0).abs() < 1e-7);
        }
        assert!((normal_cdf(0.0) - 0.5).abs() < 1e-7);
        assert!((normal_cdf(1.96) - 0.975).abs() < 1e-3);
    }

    #[test]
    fn quantile_inverts_cdf() {
        for &p in &[0.001, 0.01, 0.1, 0.25, 0.5, 0.9, 0.999] {
            let x = normal_quantile(p);
            assert!((normal_cdf(x) - p).abs() < 1e-6, "p={p}");
        }
    }

    #[test]
    fn laplace_cdf_quantile_roundtrip() {
        let beta = 0.8;
        for &p in &[0.05, 0.3, 0.5, 0.7, 0.95] {
            let x = laplace_quantile(p, beta);
            assert!((laplace_cdf(x, beta) - p).abs() < 1e-12);
        }
    }

    #[test]
    fn laplace_pdf_integrates_to_one() {
        let beta = 0.5;
        let mut sum = 0.0;
        let dx = 0.001;
        let mut x = -20.0;
        while x < 20.0 {
            sum += laplace_pdf(x, beta) * dx;
            x += dx;
        }
        assert!((sum - 1.0).abs() < 1e-3, "sum={sum}");
    }

    /// α values match the paper's constants: α³ ≈ 32.8 σ² (Gaussian, they
    /// round 32.67 up) and α³ = 108 β² = 54 σ² (Laplace).
    #[test]
    fn alpha_closed_forms_match_paper() {
        let a1 = alpha_gaussian(1.0);
        assert!((a1.powi(3) - 32.67).abs() < 0.05, "{}", a1.powi(3));
        // sigma scaling: alpha ~ sigma^{2/3}
        let a2 = alpha_gaussian(2.0);
        assert!((a2 / a1 - 2.0f64.powf(2.0 / 3.0)).abs() < 1e-9);

        let beta = 0.7;
        let al = alpha_laplace(beta);
        assert!((al.powi(3) - 108.0 * beta * beta).abs() < 1e-6);
        let sigma2 = 2.0 * beta * beta;
        assert!((al.powi(3) - 54.0 * sigma2).abs() < 1e-6);
    }

    /// numerically integrate f^{1/3} and compare with the closed forms.
    #[test]
    fn alpha_matches_numeric_integral() {
        let sigma = 0.05; // realistic weight std
        let mut num = 0.0;
        let dx = sigma / 500.0;
        let mut x = -30.0 * sigma;
        while x < 30.0 * sigma {
            let f = normal_pdf(x / sigma) / sigma;
            num += f.powf(1.0 / 3.0) * dx;
            x += dx;
        }
        let closed = alpha_gaussian(sigma);
        assert!((num - closed).abs() / closed < 1e-3, "num={num} closed={closed}");
    }
}
