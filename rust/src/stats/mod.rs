//! Statistics substrate: moments, quantiles, histograms, distribution
//! functions and goodness-of-fit tests.
//!
//! Used by the quantizers (quantile splits), the theory module (α(f_W)
//! estimation needs a density estimate), and the test suite (verifying the
//! synthetic weight draws actually follow Gaussian/Laplace laws).

pub mod dist;
pub mod hist;

/// Mean and (population) variance in one pass (Welford).
pub fn mean_var(xs: &[f32]) -> (f64, f64) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let mut mean = 0.0f64;
    let mut m2 = 0.0f64;
    for (i, &x) in xs.iter().enumerate() {
        let x = x as f64;
        let d = x - mean;
        mean += d / (i + 1) as f64;
        m2 += d * (x - mean);
    }
    (mean, m2 / xs.len() as f64)
}

pub fn std_dev(xs: &[f32]) -> f64 {
    mean_var(xs).1.sqrt()
}

/// q-th quantile (0..=1) of *sorted* data, linear interpolation.
pub fn quantile_sorted(sorted: &[f32], q: f64) -> f32 {
    assert!(!sorted.is_empty());
    let q = q.clamp(0.0, 1.0);
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = (pos - lo as f64) as f32;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Sort a copy with total order (NaNs last).
pub fn sorted_copy(xs: &[f32]) -> Vec<f32> {
    let mut v = xs.to_vec();
    v.sort_by(f32::total_cmp);
    v
}

/// Mean squared error between two equal-length slices.
pub fn mse(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    a.iter()
        .zip(b.iter())
        .map(|(&x, &y)| {
            let d = (x - y) as f64;
            d * d
        })
        .sum::<f64>()
        / a.len() as f64
}

/// Two-sample Kolmogorov–Smirnov statistic (max CDF gap). Inputs unsorted.
pub fn ks_statistic(a: &[f32], b: &[f32]) -> f64 {
    let sa = sorted_copy(a);
    let sb = sorted_copy(b);
    let (mut i, mut j) = (0usize, 0usize);
    let mut d = 0.0f64;
    while i < sa.len() && j < sb.len() {
        let (fa, fb) = (i as f64 / sa.len() as f64, j as f64 / sb.len() as f64);
        d = d.max((fa - fb).abs());
        if sa[i] <= sb[j] {
            i += 1;
        } else {
            j += 1;
        }
    }
    d.max((1.0 - j as f64 / sb.len() as f64).abs())
        .max((1.0 - i as f64 / sa.len() as f64).abs())
}

/// One-sample KS statistic against a CDF.
pub fn ks_one_sample(xs: &[f32], cdf: impl Fn(f64) -> f64) -> f64 {
    let s = sorted_copy(xs);
    let n = s.len() as f64;
    let mut d = 0.0f64;
    for (i, &x) in s.iter().enumerate() {
        let f = cdf(x as f64);
        d = d.max((f - i as f64 / n).abs());
        d = d.max(((i + 1) as f64 / n - f).abs());
    }
    d
}

/// Pearson correlation of two equal-length series.
pub fn pearson(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let n = a.len() as f64;
    let ma = a.iter().sum::<f64>() / n;
    let mb = b.iter().sum::<f64>() / n;
    let mut num = 0.0;
    let mut da = 0.0;
    let mut db = 0.0;
    for (x, y) in a.iter().zip(b.iter()) {
        num += (x - ma) * (y - mb);
        da += (x - ma) * (x - ma);
        db += (y - mb) * (y - mb);
    }
    num / (da.sqrt() * db.sqrt() + 1e-300)
}

/// Least-squares slope of y on x.
pub fn ols_slope(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    let n = x.len() as f64;
    let mx = x.iter().sum::<f64>() / n;
    let my = y.iter().sum::<f64>() / n;
    let mut num = 0.0;
    let mut den = 0.0;
    for (xi, yi) in x.iter().zip(y.iter()) {
        num += (xi - mx) * (yi - my);
        den += (xi - mx) * (xi - mx);
    }
    num / (den + 1e-300)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn mean_var_known() {
        let (m, v) = mean_var(&[1.0, 2.0, 3.0, 4.0]);
        assert!((m - 2.5).abs() < 1e-12);
        assert!((v - 1.25).abs() < 1e-12);
    }

    #[test]
    fn quantiles_interpolate() {
        let s = [0.0f32, 1.0, 2.0, 3.0];
        assert_eq!(quantile_sorted(&s, 0.0), 0.0);
        assert_eq!(quantile_sorted(&s, 1.0), 3.0);
        assert!((quantile_sorted(&s, 0.5) - 1.5).abs() < 1e-6);
    }

    #[test]
    fn mse_zero_for_identical() {
        let a = [1.0f32, 2.0, 3.0];
        assert_eq!(mse(&a, &a), 0.0);
        assert!((mse(&[0.0], &[2.0]) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn ks_same_distribution_small() {
        let mut rng = Pcg64::seed(1);
        let a: Vec<f32> = (0..4000).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..4000).map(|_| rng.normal() as f32).collect();
        assert!(ks_statistic(&a, &b) < 0.05);
    }

    #[test]
    fn ks_different_distributions_large() {
        let mut rng = Pcg64::seed(2);
        let a: Vec<f32> = (0..2000).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..2000).map(|_| rng.normal() as f32 + 2.0).collect();
        assert!(ks_statistic(&a, &b) > 0.5);
    }

    #[test]
    fn ks_one_sample_gaussian_fits() {
        let mut rng = Pcg64::seed(3);
        let xs: Vec<f32> = (0..5000).map(|_| rng.normal() as f32).collect();
        let d = ks_one_sample(&xs, dist::normal_cdf);
        assert!(d < 0.03, "d={d}");
    }

    #[test]
    fn pearson_perfect_correlation() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let y = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&x, &y) - 1.0).abs() < 1e-12);
        let yneg = [8.0, 6.0, 4.0, 2.0];
        assert!((pearson(&x, &yneg) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn ols_slope_known() {
        let x = [0.0, 1.0, 2.0, 3.0];
        let y = [1.0, 3.0, 5.0, 7.0];
        assert!((ols_slope(&x, &y) - 2.0).abs() < 1e-12);
    }
}
