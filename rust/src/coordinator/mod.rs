//! The L3 coordinator: experiment sweeps (Figs. 2–4 + theory tables),
//! report/figure writers, the model-variant registry and the serving layer
//! (TCP JSON protocol with a dynamic batcher).

pub mod batcher;
pub mod experiment;
pub mod registry;
pub mod report;
pub mod server;
