//! The L3 coordinator: experiment sweeps (Figs. 2–4 + theory tables),
//! report/figure writers, the model-variant registry and the serving layer
//! (TCP JSON protocol over a slot-accounted dynamic batcher with
//! per-request seeded noise — deterministic, exact-n replies).

pub mod batcher;
pub mod errors;
pub mod experiment;
pub mod registry;
pub mod report;
pub mod server;
