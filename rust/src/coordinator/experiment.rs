//! Experiment sweep runner — regenerates the paper's evaluation grids.
//!
//! Fig. 3: (dataset × method × bits) → SSIM/PSNR of quantized-model samples
//! against the full-precision model's samples *from the same start noise*
//! (the paper's "reference outputs").
//! Fig. 4: (dataset × method × bits) → latent-variance statistics from the
//! reverse ODE.
//! Fig. 2/5–8: sample grids per method/bits.

use anyhow::{anyhow, Result};

use crate::data::Dataset;
use crate::engine::EngineKind;
use crate::flow::sampler::{self, CpuQStep, CpuStep, EngineStep, HloQStep, HloStep, StepBackend};
use crate::metrics::latent::{latent_stats, LatentStats};
use crate::metrics::psnr::batch_psnr;
use crate::metrics::ssim::batch_ssim;
use crate::model::params::ParamStore;
use crate::model::quantized::QuantizedModel;
use crate::model::spec::ModelSpec;
use crate::quant::{quantize_model, QuantMethod};
use crate::runtime::ArtifactSet;
use crate::util::rng::Pcg64;

/// Shared sweep configuration.
pub struct EvalContext<'a> {
    pub spec: ModelSpec,
    /// When present, sampling runs through the compiled HLO (Pallas qmm on
    /// the quantized path); otherwise the CPU reference backend.
    pub art: Option<&'a ArtifactSet>,
    /// Euler integration steps.
    pub steps: usize,
    /// Number of evaluation samples (rounded up to the artifact batch).
    pub n: usize,
    pub seed: u64,
    /// Execution backend for the *quantized* sampling paths (where the
    /// engines actually differ): `None` = legacy auto (HLO when `art` is
    /// set, else the CPU reference), `Some(Lut)` = the native LUT-GEMM
    /// engine, etc. The fp32 reference always runs HLO-if-available else
    /// the CPU reference, independent of this knob.
    pub engine: Option<EngineKind>,
}

/// One Fig. 3 grid point.
#[derive(Clone, Debug)]
pub struct FidelityPoint {
    pub dataset: String,
    pub method: QuantMethod,
    pub bits: u8,
    pub ssim: f64,
    pub psnr: f64,
    /// size-weighted W₂² weight error
    pub w2_sq: f64,
    pub compression: f64,
}

/// One Fig. 4 grid point.
#[derive(Clone, Debug)]
pub struct LatentPoint {
    pub dataset: String,
    pub method: QuantMethod,
    pub bits: u8,
    pub stats: LatentStats,
    /// fp32 baseline var_std for the same inputs
    pub baseline_var_std: f64,
}

impl<'a> EvalContext<'a> {
    /// Effective batch size for generation.
    fn batch(&self) -> usize {
        self.art.map(|a| a.b_sample).unwrap_or(16)
    }

    fn n_padded(&self) -> usize {
        let b = self.batch();
        self.n.div_ceil(b) * b
    }

    /// Shared start noise for paired comparisons.
    pub fn start_noise(&self) -> Vec<f32> {
        let mut rng = Pcg64::seed(self.seed ^ 0x5eed);
        let d = self.spec.d;
        (0..self.n_padded() * d)
            .map(|_| rng.normal_f32(0.0, 1.0))
            .collect()
    }

    fn run_batched(
        &self,
        backend: &mut dyn StepBackend,
        x0: &[f32],
        reverse: bool,
    ) -> Result<Vec<f32>> {
        let d = self.spec.d;
        let b = self.batch();
        let mut out = Vec::with_capacity(x0.len());
        for chunk in x0.chunks(b * d) {
            let res = if reverse {
                sampler::encode(backend, chunk, self.steps)?
            } else {
                sampler::generate_from(backend, chunk, self.steps)?
            };
            out.extend(res);
        }
        Ok(out)
    }

    /// Generate with full-precision weights from given noise.
    pub fn generate_fp32(&self, theta: &ParamStore, x0: &[f32]) -> Result<Vec<f32>> {
        match self.art {
            Some(art) => {
                let mut be = HloStep { art, theta };
                self.run_batched(&mut be, x0, false)
            }
            None => {
                let mut be = CpuStep {
                    spec: &self.spec,
                    theta,
                };
                self.run_batched(&mut be, x0, false)
            }
        }
    }

    /// Quantized sampling through the selected [`EngineKind`].
    fn run_quant(&self, qm: &QuantizedModel, x: &[f32], reverse: bool) -> Result<Vec<f32>> {
        match self.engine {
            None => match self.art {
                Some(art) => {
                    let mut be = HloQStep::new(art, qm)?;
                    self.run_batched(&mut be, x, reverse)
                }
                None => {
                    let mut be = CpuQStep { qm };
                    self.run_batched(&mut be, x, reverse)
                }
            },
            Some(EngineKind::Runtime) => {
                let art = self
                    .art
                    .ok_or_else(|| anyhow!("--engine runtime needs compiled artifacts"))?;
                let mut be = HloQStep::new(art, qm)?;
                self.run_batched(&mut be, x, reverse)
            }
            Some(kind) => {
                let engine = crate::engine::build_quantized(kind, qm)?;
                let mut be = EngineStep::new(engine.as_ref());
                self.run_batched(&mut be, x, reverse)
            }
        }
    }

    /// Generate with a quantized model from given noise.
    pub fn generate_quant(&self, qm: &QuantizedModel, x0: &[f32]) -> Result<Vec<f32>> {
        self.run_quant(qm, x0, false)
    }

    /// Reverse-encode images to latents.
    pub fn encode_fp32(&self, theta: &ParamStore, imgs: &[f32]) -> Result<Vec<f32>> {
        match self.art {
            Some(art) => {
                let mut be = HloStep { art, theta };
                self.run_batched(&mut be, imgs, true)
            }
            None => {
                let mut be = CpuStep {
                    spec: &self.spec,
                    theta,
                };
                self.run_batched(&mut be, imgs, true)
            }
        }
    }

    pub fn encode_quant(&self, qm: &QuantizedModel, imgs: &[f32]) -> Result<Vec<f32>> {
        self.run_quant(qm, imgs, true)
    }

    /// One Fig. 3 point: quantize, generate from the *same* noise as the
    /// fp32 reference, score SSIM/PSNR.
    pub fn fidelity_point(
        &self,
        dataset: Dataset,
        theta: &ParamStore,
        reference: &[f32],
        x0: &[f32],
        method: QuantMethod,
        bits: u8,
    ) -> Result<FidelityPoint> {
        let qm = quantize_model(&self.spec, theta, method, bits);
        let imgs = self.generate_quant(&qm, x0)?;
        let d = self.spec.d;
        Ok(FidelityPoint {
            dataset: dataset.name().to_string(),
            method,
            bits,
            ssim: batch_ssim(reference, &imgs, d),
            psnr: batch_psnr(reference, &imgs, d),
            w2_sq: qm.w2_error(theta).w2_sq,
            compression: qm.compression_ratio(),
        })
    }

    /// One Fig. 4 point: reverse-encode a dataset batch through the
    /// quantized model and summarize latent variances.
    pub fn latent_point(
        &self,
        dataset: Dataset,
        theta: &ParamStore,
        method: QuantMethod,
        bits: u8,
    ) -> Result<LatentPoint> {
        let mut rng = Pcg64::seed(self.seed ^ 0x1a7e);
        let imgs = dataset.batch(&mut rng, self.n_padded());
        let qm = quantize_model(&self.spec, theta, method, bits);
        let lat_q = self.encode_quant(&qm, &imgs)?;
        let lat_f = self.encode_fp32(theta, &imgs)?;
        let d = self.spec.d;
        Ok(LatentPoint {
            dataset: dataset.name().to_string(),
            method,
            bits,
            stats: latent_stats(&lat_q, d),
            baseline_var_std: latent_stats(&lat_f, d).var_std,
        })
    }

    /// Full Fig. 3 sweep for one dataset/theta.
    pub fn fidelity_sweep(
        &self,
        dataset: Dataset,
        theta: &ParamStore,
        methods: &[QuantMethod],
        bits: &[u8],
    ) -> Result<Vec<FidelityPoint>> {
        let x0 = self.start_noise();
        let reference = self.generate_fp32(theta, &x0)?;
        let mut out = Vec::new();
        for &m in methods {
            for &b in bits {
                out.push(self.fidelity_point(dataset, theta, &reference, &x0, m, b)?);
            }
        }
        Ok(out)
    }

    /// Full Fig. 4 sweep for one dataset/theta.
    pub fn latent_sweep(
        &self,
        dataset: Dataset,
        theta: &ParamStore,
        methods: &[QuantMethod],
        bits: &[u8],
    ) -> Result<Vec<LatentPoint>> {
        let mut out = Vec::new();
        for &m in methods {
            for &b in bits {
                out.push(self.latent_point(dataset, theta, m, b)?);
            }
        }
        Ok(out)
    }
}

/// Obtain a model for a dataset without artifacts: a deterministic
/// "pseudo-trained" theta — initialized weights plus a dataset-dependent
/// perturbation so each dataset has a distinct model with realistic weight
/// histograms. Real training (examples/e2e_pipeline) replaces this when
/// artifacts are available.
pub fn pseudo_trained_theta(spec: &ModelSpec, dataset: Dataset) -> ParamStore {
    let seed = 0xA110C ^ (dataset.name().len() as u64).wrapping_mul(0x9E3779B97F4A7C15);
    let mut rng = Pcg64::seed(seed);
    let mut theta = spec.init_theta(&mut rng);
    // mild heavy-tail mixture: a few larger weights, as trained nets have
    let sl = theta.as_mut_slice();
    for v in sl.iter_mut() {
        if rng.uniform() < 0.01 {
            *v *= 4.0;
        }
    }
    theta
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx(spec: &ModelSpec) -> EvalContext<'_> {
        EvalContext {
            spec: spec.clone(),
            art: None,
            steps: 4,
            n: 4,
            seed: 11,
            engine: None,
        }
    }

    #[test]
    fn lut_engine_sweep_path_matches_legacy_cpu_path() {
        let spec = ModelSpec::default_spec();
        let legacy = ctx(&spec);
        let lut = EvalContext {
            engine: Some(EngineKind::Lut),
            ..ctx(&spec)
        };
        let theta = pseudo_trained_theta(&spec, Dataset::SynthMnist);
        let qm = crate::quant::quantize_model(&spec, &theta, QuantMethod::Ot, 3);
        let x0 = legacy.start_noise();
        // the LUT engine is bit-exact vs the dequantize-then-GEMM path, so
        // the whole sweep plumbing must produce identical images
        let imgs_legacy = legacy.generate_quant(&qm, &x0).unwrap();
        let imgs_lut = lut.generate_quant(&qm, &x0).unwrap();
        assert_eq!(imgs_lut, imgs_legacy);
        assert!(imgs_legacy.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn runtime_engine_without_artifacts_errors() {
        let spec = ModelSpec::default_spec();
        let c = EvalContext {
            engine: Some(EngineKind::Runtime),
            ..ctx(&spec)
        };
        let theta = pseudo_trained_theta(&spec, Dataset::SynthMnist);
        let qm = crate::quant::quantize_model(&spec, &theta, QuantMethod::Ot, 4);
        let x0 = c.start_noise();
        assert!(c.generate_quant(&qm, &x0).is_err());
    }

    #[test]
    fn fidelity_point_ordering_by_bits() {
        let spec = ModelSpec::default_spec();
        let c = ctx(&spec);
        let theta = pseudo_trained_theta(&spec, Dataset::SynthMnist);
        let x0 = c.start_noise();
        let reference = c.generate_fp32(&theta, &x0).unwrap();
        let p2 = c
            .fidelity_point(Dataset::SynthMnist, &theta, &reference, &x0, QuantMethod::Ot, 2)
            .unwrap();
        let p8 = c
            .fidelity_point(Dataset::SynthMnist, &theta, &reference, &x0, QuantMethod::Ot, 8)
            .unwrap();
        assert!(p8.ssim >= p2.ssim, "ssim {} vs {}", p8.ssim, p2.ssim);
        assert!(p8.psnr >= p2.psnr);
        assert!(p8.w2_sq < p2.w2_sq);
        assert!(p2.compression > p8.compression);
    }

    #[test]
    fn latent_point_has_baseline() {
        let spec = ModelSpec::default_spec();
        let c = ctx(&spec);
        let theta = pseudo_trained_theta(&spec, Dataset::SynthCifar);
        let lp = c
            .latent_point(Dataset::SynthCifar, &theta, QuantMethod::Ot, 8)
            .unwrap();
        assert!(lp.stats.var_std.is_finite());
        assert!(lp.baseline_var_std.is_finite());
        // 8-bit OT should stay near the fp32 baseline
        assert!(lp.stats.var_std < lp.baseline_var_std * 2.0 + 0.5);
    }

    #[test]
    fn pseudo_theta_differs_per_dataset() {
        let spec = ModelSpec::default_spec();
        let a = pseudo_trained_theta(&spec, Dataset::SynthMnist);
        let b = pseudo_trained_theta(&spec, Dataset::SynthImagenet);
        assert!(a.max_abs_diff(&b) > 0.0);
    }
}
