//! Report writers: CSV series for the figures, markdown tables for
//! EXPERIMENTS.md, and plain-PPM image grids (dependency-free viewable
//! output for Figs. 2 and 5–8).

use std::fs;
use std::path::Path;

use anyhow::{Context, Result};

use crate::coordinator::experiment::{FidelityPoint, LatentPoint};
use crate::data::{IMG_C, IMG_HW};

/// Write rows as CSV with a header.
pub fn write_csv(path: &Path, header: &str, rows: &[String]) -> Result<()> {
    if let Some(parent) = path.parent() {
        fs::create_dir_all(parent)?;
    }
    let mut s = String::from(header);
    s.push('\n');
    for r in rows {
        s.push_str(r);
        s.push('\n');
    }
    fs::write(path, s).with_context(|| format!("write {path:?}"))
}

pub fn fidelity_csv(path: &Path, points: &[FidelityPoint]) -> Result<()> {
    let rows: Vec<String> = points
        .iter()
        .map(|p| {
            format!(
                "{},{},{},{:.6},{:.4},{:.6e},{:.3}",
                p.dataset,
                p.method.name(),
                p.bits,
                p.ssim,
                p.psnr,
                p.w2_sq,
                p.compression
            )
        })
        .collect();
    write_csv(path, "dataset,method,bits,ssim,psnr,w2_sq,compression", &rows)
}

pub fn latent_csv(path: &Path, points: &[LatentPoint]) -> Result<()> {
    let rows: Vec<String> = points
        .iter()
        .map(|p| {
            format!(
                "{},{},{},{:.6},{:.6},{:.6},{:.6},{:.6}",
                p.dataset,
                p.method.name(),
                p.bits,
                p.stats.var_mean,
                p.stats.var_std,
                p.stats.mean_abs,
                p.stats.max_abs,
                p.baseline_var_std
            )
        })
        .collect();
    write_csv(
        path,
        "dataset,method,bits,var_mean,var_std,mean_abs,max_abs,baseline_var_std",
        &rows,
    )
}

/// Markdown table from header cells + rows of cells.
pub fn markdown_table(header: &[&str], rows: &[Vec<String>]) -> String {
    let mut s = String::new();
    s.push_str(&format!("| {} |\n", header.join(" | ")));
    s.push_str(&format!("|{}\n", "---|".repeat(header.len())));
    for r in rows {
        s.push_str(&format!("| {} |\n", r.join(" | ")));
    }
    s
}

/// Write a grid of flattened [-1,1] images as one plain-PPM (P3) file.
/// `cols` images per row, 1px separator lines.
pub fn write_image_grid(path: &Path, imgs: &[f32], cols: usize) -> Result<()> {
    let d = IMG_HW * IMG_HW * IMG_C;
    assert_eq!(imgs.len() % d, 0);
    let n = imgs.len() / d;
    let rows = n.div_ceil(cols);
    let gw = cols * (IMG_HW + 1) + 1;
    let gh = rows * (IMG_HW + 1) + 1;
    // start mid-gray
    let mut canvas = vec![128u8; gw * gh * 3];
    for i in 0..n {
        let (gr, gc) = (i / cols, i % cols);
        let oy = gr * (IMG_HW + 1) + 1;
        let ox = gc * (IMG_HW + 1) + 1;
        for y in 0..IMG_HW {
            for x in 0..IMG_HW {
                for c in 0..IMG_C {
                    let v = imgs[i * d + (y * IMG_HW + x) * IMG_C + c];
                    let b = (((v + 1.0) * 0.5).clamp(0.0, 1.0) * 255.0) as u8;
                    canvas[((oy + y) * gw + ox + x) * 3 + c] = b;
                }
            }
        }
    }
    if let Some(parent) = path.parent() {
        fs::create_dir_all(parent)?;
    }
    let mut s = format!("P3\n{gw} {gh}\n255\n");
    for px in canvas.chunks(3) {
        s.push_str(&format!("{} {} {}\n", px[0], px[1], px[2]));
    }
    fs::write(path, s).with_context(|| format!("write {path:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::latent::LatentStats;
    use crate::quant::QuantMethod;

    fn tmpdir() -> std::path::PathBuf {
        let d = std::env::temp_dir().join("fmq-report-tests");
        fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn csv_roundtrip_readable() {
        let p = tmpdir().join("fid.csv");
        let pt = FidelityPoint {
            dataset: "synth-mnist".into(),
            method: QuantMethod::Ot,
            bits: 4,
            ssim: 0.91,
            psnr: 28.5,
            w2_sq: 1.2e-6,
            compression: 7.9,
        };
        fidelity_csv(&p, &[pt]).unwrap();
        let text = fs::read_to_string(&p).unwrap();
        assert!(text.starts_with("dataset,method,bits"));
        assert!(text.contains("synth-mnist,ot,4,0.91"));
    }

    #[test]
    fn latent_csv_written() {
        let p = tmpdir().join("lat.csv");
        let lp = LatentPoint {
            dataset: "synth-cifar".into(),
            method: QuantMethod::Log2,
            bits: 2,
            stats: LatentStats {
                var_mean: 1.5,
                var_std: 3.2,
                mean_abs: 0.9,
                max_abs: 12.0,
            },
            baseline_var_std: 0.1,
        };
        latent_csv(&p, &[lp]).unwrap();
        assert!(fs::read_to_string(&p).unwrap().contains("log2,2,1.5"));
    }

    #[test]
    fn markdown_table_shape() {
        let t = markdown_table(&["a", "b"], &[vec!["1".into(), "2".into()]]);
        assert_eq!(t.lines().count(), 3);
        assert!(t.contains("| 1 | 2 |"));
    }

    #[test]
    fn ppm_grid_valid_header_and_size() {
        let p = tmpdir().join("grid.ppm");
        let d = IMG_HW * IMG_HW * IMG_C;
        let imgs = vec![0.0f32; 3 * d];
        write_image_grid(&p, &imgs, 2).unwrap();
        let text = fs::read_to_string(&p).unwrap();
        let mut lines = text.lines();
        assert_eq!(lines.next().unwrap(), "P3");
        let dims: Vec<usize> = lines
            .next()
            .unwrap()
            .split(' ')
            .map(|v| v.parse().unwrap())
            .collect();
        assert_eq!(dims, vec![2 * 17 + 1, 2 * 17 + 1]);
        // 0.0 maps to 127/128 gray
        assert!(text.contains("127 127 127") || text.contains("128 128 128"));
    }
}
