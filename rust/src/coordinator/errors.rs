//! Typed serving errors: the wire-visible error taxonomy.
//!
//! Every error reply the server produces carries, besides the
//! human-readable `error` message, a stable machine-readable `code` and
//! a `retryable` flag so clients can decide between backing off and
//! giving up without parsing prose. The full taxonomy, including which
//! classes are produced where, is documented in `docs/ROBUSTNESS.md`.
//!
//! Design notes:
//! - `Display` renders the message *only* (no code prefix), so existing
//!   substring assertions and log lines keep their shape; the class
//!   travels in the dedicated `code` wire field.
//! - The class list is index-aligned with
//!   [`crate::obs::ERROR_CLASSES`] so per-class counters stay a fixed
//!   array of atomics with no allocation at count time.

use std::fmt;

use crate::util::json::Json;

/// Stable error classes. `code()` strings are wire API — never rename.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrClass {
    /// Malformed or out-of-range request (client bug). Terminal.
    BadRequest,
    /// Model name not in this server's registry. Terminal.
    UnknownModel,
    /// The worker serving this request panicked (or is gone) and the
    /// request was failed while the worker respawns. Retryable: the
    /// respawned worker serves the identical request deterministically.
    WorkerPanic,
    /// The request's `deadline_ms` elapsed before a reply was ready.
    /// Terminal: the client's budget is spent by definition.
    DeadlineExceeded,
    /// Admission control shed the request because the model's queue was
    /// full. Retryable after the `retry_after_ms` hint.
    Overloaded,
    /// The server is draining or stopped and no longer admits work.
    /// Terminal against this server (another replica may retry it).
    ShuttingDown,
    /// A checkpoint/artifact failed its integrity check while loading.
    /// Terminal until the on-disk artifact is repaired.
    CorruptArtifact,
    /// Engine failure or other server-side invariant violation. Terminal.
    Internal,
}

impl ErrClass {
    /// Wire `code` string; index-aligned with [`crate::obs::ERROR_CLASSES`].
    pub fn code(self) -> &'static str {
        match self {
            ErrClass::BadRequest => "bad_request",
            ErrClass::UnknownModel => "unknown_model",
            ErrClass::WorkerPanic => "worker_panic",
            ErrClass::DeadlineExceeded => "deadline_exceeded",
            ErrClass::Overloaded => "overloaded",
            ErrClass::ShuttingDown => "shutting_down",
            ErrClass::CorruptArtifact => "corrupt_artifact",
            ErrClass::Internal => "internal",
        }
    }

    /// Whether a client retry against the *same* server can succeed.
    /// `worker_panic` clears once the supervisor respawns the worker;
    /// `overloaded` clears once the queue drains. Everything else is
    /// terminal here (see `docs/ROBUSTNESS.md` for the replica nuance
    /// around `shutting_down`).
    pub fn retryable(self) -> bool {
        matches!(self, ErrClass::WorkerPanic | ErrClass::Overloaded)
    }
}

/// A typed serving error: class + message + optional backoff hint.
#[derive(Clone, Debug)]
pub struct ServeError {
    pub class: ErrClass,
    pub msg: String,
    /// Server-suggested minimum backoff before retrying (only set for
    /// `Overloaded`).
    pub retry_after_ms: Option<u64>,
}

impl ServeError {
    pub fn new(class: ErrClass, msg: impl Into<String>) -> Self {
        Self {
            class,
            msg: msg.into(),
            retry_after_ms: None,
        }
    }

    pub fn bad_request(msg: impl Into<String>) -> Self {
        Self::new(ErrClass::BadRequest, msg)
    }

    pub fn unknown_model(msg: impl Into<String>) -> Self {
        Self::new(ErrClass::UnknownModel, msg)
    }

    pub fn worker_panic(msg: impl Into<String>) -> Self {
        Self::new(ErrClass::WorkerPanic, msg)
    }

    pub fn deadline_exceeded(msg: impl Into<String>) -> Self {
        Self::new(ErrClass::DeadlineExceeded, msg)
    }

    pub fn overloaded(msg: impl Into<String>, retry_after_ms: u64) -> Self {
        Self {
            class: ErrClass::Overloaded,
            msg: msg.into(),
            retry_after_ms: Some(retry_after_ms),
        }
    }

    pub fn shutting_down(msg: impl Into<String>) -> Self {
        Self::new(ErrClass::ShuttingDown, msg)
    }

    pub fn internal(msg: impl Into<String>) -> Self {
        Self::new(ErrClass::Internal, msg)
    }

    /// Build the wire error reply. Shape:
    /// `{"ok":false,"error":msg,"code":...,"retryable":...[,"retry_after_ms":n]}`.
    pub fn to_reply(&self) -> Json {
        let mut pairs = vec![
            ("ok", Json::Bool(false)),
            ("error", Json::Str(self.msg.clone())),
            ("code", Json::Str(self.class.code().to_string())),
            ("retryable", Json::Bool(self.class.retryable())),
        ];
        if let Some(ms) = self.retry_after_ms {
            pairs.push(("retry_after_ms", Json::Int(ms as i128)));
        }
        Json::obj(pairs)
    }
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for ServeError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codes_align_with_obs_error_class_labels() {
        // The per-class counters in obs index by position in
        // ERROR_CLASSES; every class this module can produce must have a
        // slot there, in the same spelling.
        for class in [
            ErrClass::BadRequest,
            ErrClass::UnknownModel,
            ErrClass::WorkerPanic,
            ErrClass::DeadlineExceeded,
            ErrClass::Overloaded,
            ErrClass::ShuttingDown,
            ErrClass::CorruptArtifact,
            ErrClass::Internal,
        ] {
            assert!(
                crate::obs::ERROR_CLASSES.contains(&class.code()),
                "obs::ERROR_CLASSES missing '{}'",
                class.code()
            );
        }
        assert_eq!(crate::obs::ERROR_CLASSES.len(), 8);
    }

    #[test]
    fn only_panic_and_overload_are_retryable() {
        assert!(ErrClass::WorkerPanic.retryable());
        assert!(ErrClass::Overloaded.retryable());
        for terminal in [
            ErrClass::BadRequest,
            ErrClass::UnknownModel,
            ErrClass::DeadlineExceeded,
            ErrClass::ShuttingDown,
            ErrClass::CorruptArtifact,
            ErrClass::Internal,
        ] {
            assert!(!terminal.retryable(), "{:?} must be terminal", terminal);
        }
    }

    #[test]
    fn reply_shape_carries_code_and_hint() {
        let e = ServeError::overloaded("queue for 'ot2' is full", 100);
        let j = e.to_reply();
        assert_eq!(j.get("ok").and_then(Json::as_bool), Some(false));
        assert_eq!(j.get("code").and_then(Json::as_str), Some("overloaded"));
        assert_eq!(j.get("retryable").and_then(Json::as_bool), Some(true));
        assert_eq!(j.get("retry_after_ms").and_then(Json::as_u64), Some(100));
        assert_eq!(e.to_string(), "queue for 'ot2' is full");

        let t = ServeError::deadline_exceeded("deadline exceeded");
        let j = t.to_reply();
        assert_eq!(j.get("retryable").and_then(Json::as_bool), Some(false));
        assert!(j.get("retry_after_ms").is_none());
    }
}
