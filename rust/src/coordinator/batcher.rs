//! Slot-accounted dynamic batcher: aggregates concurrent generation and
//! encoding requests into fixed-size model batches (the artifact's
//! B_SAMPLE), trading a small queue delay for full batch occupancy — the
//! standard serving pattern (vLLM-style), implemented with std threads +
//! channels.
//!
//! Two properties the serving layer's determinism contract rests on:
//!
//! * **Per-request noise streams.** Every `generate` request draws its
//!   noise rows from its own `Pcg64::seed(request seed)` — never from a
//!   batch-level stream — so the rows a request integrates are the first
//!   `n × d` normals of its seed regardless of which other requests share
//!   the super-batch, where in the batch they landed, or how the request
//!   was sliced. Combined with the row-independent forward (pinned by
//!   `cpu_ref::tests::batch_independence`), results are a pure function
//!   of `(model, n, seed, steps)`.
//! * **Exact-n slicing.** A request larger than the model batch is not
//!   clamped; it is sliced across consecutive super-batches by slot
//!   accounting ([`Batcher::next_batch`] issues rows, [`Batcher::complete`]
//!   reassembles them in order) and replied to only when all `n` rows are
//!   done.
//!
//! Backpressure: submissions go through a bounded [`mpsc::sync_channel`];
//! the server's `submit` uses `try_send` and sheds with a typed
//! `overloaded` reply once `queue_cap` requests are queued (instead of
//! the queue growing without bound), and the batcher admits at most
//! `queue_cap` requests into its active set at a time.
//!
//! Deadlines: a request may carry an absolute deadline. Expired requests
//! are failed with a typed `deadline_exceeded` error at admission and
//! again before each assembly ([`Batcher::next_batch`] sheds queued
//! requests whose deadline passed while they waited), so a stale request
//! never burns sampler compute. Rows already issued into a super-batch
//! are finished rather than cancelled — slicing keeps batches small, so
//! the win from mid-batch cancellation would not pay for the complexity.

use std::collections::VecDeque;
use std::sync::mpsc::{self, Receiver, Sender, SyncSender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coordinator::errors::ServeError;
use crate::flow::sampler::Direction;
use crate::obs::{self, Metrics, Span};
use crate::util::rng::Pcg64;

/// What one request wants integrated.
pub enum Work {
    /// Forward ODE over exactly `n` rows of per-request seeded noise.
    Generate {
        /// Number of samples to generate.
        n: usize,
        /// Seed of the request's private noise stream.
        seed: u64,
    },
    /// Reverse ODE over client-provided rows (flat `[n, d]`).
    Encode {
        /// Input rows, `rows.len() = n * d`.
        rows: Vec<f32>,
    },
}

/// Reply payload: the exact-n output rows, or a typed error the protocol
/// layer forwards to the client (class + message + retry hint — see
/// [`crate::coordinator::errors`]).
pub type Reply = Result<Vec<f32>, ServeError>;

/// One queued request: the work plus its reply channel.
pub struct GenRequest {
    /// What to integrate.
    pub work: Work,
    /// Absolute completion deadline, if the client set `deadline_ms`.
    /// Expired requests are shed (`deadline_exceeded`) instead of run.
    pub deadline: Option<Instant>,
    /// Where the reassembled result (or error) goes.
    pub reply: Sender<Reply>,
}

/// An admitted request being served across one or more super-batches.
struct Active {
    id: u64,
    dir: Direction,
    n: usize,
    /// Rows handed to super-batches so far (slot accounting).
    issued: usize,
    /// Rows reassembled into `out` so far.
    done: usize,
    /// When the request entered the active set (feeds `queue_wait_ns` on
    /// the request's first issuance into a super-batch).
    admitted: Instant,
    /// Absolute deadline; checked at admission and before each assembly.
    deadline: Option<Instant>,
    src: Source,
    out: Vec<f32>,
    reply: Sender<Reply>,
}

enum Source {
    /// Lazy per-request noise: rows `[issued..]` continue this stream, so
    /// the noise is independent of slicing boundaries.
    Noise(Pcg64),
    /// Encode input rows, consumed by the `issued` cursor.
    Rows(Vec<f32>),
}

/// One slice of a request scheduled into the current super-batch.
struct Slice {
    id: u64,
    /// Row offset within the request this slice starts at.
    at: usize,
    /// Row offset within the super-batch.
    batch_row: usize,
    take: usize,
}

/// A homogeneous (single-direction) super-batch assembled by
/// [`Batcher::next_batch`]: up to `max_batch` rows sliced from the oldest
/// compatible requests, FIFO. Hand the integrated rows (same order) back
/// via [`Batcher::complete`].
pub struct SuperBatch {
    /// Integration direction shared by every slice in this batch.
    pub dir: Direction,
    /// Input rows, flat `[rows, d]`, in slice order (no padding — the
    /// worker pads only where the backend needs fixed shapes).
    pub x0: Vec<f32>,
    /// Number of real rows in `x0`.
    pub rows: usize,
    slices: Vec<Slice>,
}

impl SuperBatch {
    fn empty() -> Self {
        Self {
            dir: Direction::Forward,
            x0: Vec::new(),
            rows: 0,
            slices: Vec::new(),
        }
    }

    /// True for the idle-timeout batch (no work; re-check shutdown).
    pub fn is_empty(&self) -> bool {
        self.rows == 0
    }

    /// Number of requests contributing rows to this batch.
    pub fn requests(&self) -> usize {
        self.slices.len()
    }
}

/// Batching queue with a linger window, slot accounting and in-order
/// reply reassembly. Owned by exactly one serving worker.
pub struct Batcher {
    tx: SyncSender<GenRequest>,
    rx: Receiver<GenRequest>,
    /// Super-batch row capacity (the model batch size).
    pub max_batch: usize,
    /// How long to wait for co-batchable requests before dispatching.
    pub linger: Duration,
    d: usize,
    queue_cap: usize,
    active: VecDeque<Active>,
    next_id: u64,
    metrics: Arc<Metrics>,
}

impl Batcher {
    /// `max_batch` rows per super-batch, `linger` accumulation window,
    /// `d` row width. `queue_cap` bounds the channel and the admitted
    /// active set each (so at most `2 * queue_cap` requests are held per
    /// variant before submitters block). `metrics` is the owning server's
    /// registry (queue-wait / assembly histograms land there).
    pub fn new(
        max_batch: usize,
        linger: Duration,
        d: usize,
        queue_cap: usize,
        metrics: Arc<Metrics>,
    ) -> Self {
        let cap = queue_cap.max(1);
        let (tx, rx) = mpsc::sync_channel(cap);
        Self {
            tx,
            rx,
            max_batch: max_batch.max(1),
            linger,
            d: d.max(1),
            queue_cap: cap,
            active: VecDeque::new(),
            next_id: 0,
            metrics,
        }
    }

    /// A bounded submission handle; `send` blocks once `queue_cap`
    /// requests are queued (backpressure on connection handlers).
    pub fn submitter(&self) -> SyncSender<GenRequest> {
        self.tx.clone()
    }

    /// Rows admitted but not yet completed — the worker exports this as
    /// the `queue_depth` stat.
    pub fn backlog_rows(&self) -> usize {
        self.active.iter().map(|a| a.n - a.done).sum()
    }

    /// Validate and admit one request into the active set; invalid or
    /// already-expired requests are failed immediately instead of being
    /// admitted.
    fn admit(&mut self, req: GenRequest) {
        if let Some(dl) = req.deadline {
            if Instant::now() >= dl {
                let _ = req.reply.send(Err(ServeError::deadline_exceeded(
                    "deadline expired before the request was admitted",
                )));
                return;
            }
        }
        let (dir, n, src) = match req.work {
            Work::Generate { n, seed } => {
                if n == 0 {
                    let _ = req
                        .reply
                        .send(Err(ServeError::bad_request("n must be at least 1")));
                    return;
                }
                (Direction::Forward, n, Source::Noise(Pcg64::seed(seed)))
            }
            Work::Encode { rows } => {
                let d = self.d.max(1);
                if rows.is_empty() || rows.len() % d != 0 {
                    let _ = req.reply.send(Err(ServeError::bad_request(format!(
                        "encode rows must be flat [n, d] with d={} (got {} values)",
                        self.d,
                        rows.len()
                    ))));
                    return;
                }
                let n = rows.len() / d;
                (Direction::Reverse, n, Source::Rows(rows))
            }
        };
        self.next_id += 1;
        self.active.push_back(Active {
            id: self.next_id,
            dir,
            n,
            issued: 0,
            done: 0,
            admitted: Instant::now(),
            deadline: req.deadline,
            src,
            out: vec![0.0; n * self.d],
            reply: req.reply,
        });
    }

    /// Fail every queued request whose deadline has passed before it got
    /// any rows issued. Partially-issued requests are left to finish:
    /// their compute is already committed, and `complete` tolerates the
    /// finished slices either way.
    fn shed_expired(&mut self) {
        let now = Instant::now();
        let mut i = 0;
        while i < self.active.len() {
            let expired = self
                .active
                .get(i)
                .is_some_and(|a| a.issued == 0 && a.deadline.is_some_and(|dl| now >= dl));
            if expired {
                if let Some(a) = self.active.remove(i) {
                    let _ = a.reply.send(Err(ServeError::deadline_exceeded(
                        "deadline exceeded while the request was queued",
                    )));
                }
            } else {
                i += 1;
            }
        }
    }

    /// Fail every admitted request and drain the submission channel,
    /// replying `err` to each. Called by workers on hard stop (drain
    /// window expired) so no client is left waiting on a reply that will
    /// never come.
    pub fn abort_all(&mut self, err: &ServeError) {
        while let Some(a) = self.active.pop_front() {
            let _ = a.reply.send(Err(err.clone()));
        }
        while let Ok(req) = self.rx.try_recv() {
            let _ = req.reply.send(Err(err.clone()));
        }
    }

    fn pending_rows(&self) -> usize {
        self.active.iter().map(|a| a.n - a.issued).sum()
    }

    /// Pull the next super-batch. With no backlog, waits (up to 200 ms)
    /// for one request; then lingers up to `linger` (or until `max_batch`
    /// rows are pending) to accumulate more. Returns `Some(empty batch)`
    /// on the wait timeout so worker loops can re-check their shutdown
    /// flag (the Batcher keeps a live submitter internally, so a plain
    /// blocking recv would never disconnect and `Server::stop` would
    /// deadlock on join); returns `None` only when every submitter is
    /// gone and no admitted work remains.
    pub fn next_batch(&mut self) -> Option<SuperBatch> {
        if self.pending_rows() == 0 {
            match self.rx.recv_timeout(Duration::from_millis(200)) {
                Ok(req) => self.admit(req),
                Err(mpsc::RecvTimeoutError::Timeout) => return Some(SuperBatch::empty()),
                Err(mpsc::RecvTimeoutError::Disconnected) => return None,
            }
        }
        // linger: admit co-batchable requests until the batch is full,
        // the admission cap is reached, or the window closes. A backlog
        // of >= max_batch rows dispatches immediately, and so does the
        // tail of a partially-issued (sliced) request — it already
        // waited its linger when admitted; waiting again would add pure
        // latency to every large request.
        let mid_request = self.active.iter().any(|a| 0 < a.issued && a.issued < a.n);
        let deadline = Instant::now() + self.linger;
        while !mid_request
            && self.pending_rows() < self.max_batch
            && self.active.len() < self.queue_cap
        {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match self.rx.recv_timeout(deadline - now) {
                Ok(req) => self.admit(req),
                Err(_) => break,
            }
        }
        // already-queued requests ride along for free (no waiting) —
        // this is what fills the slots next to a sliced request's tail
        while self.pending_rows() < self.max_batch && self.active.len() < self.queue_cap {
            match self.rx.try_recv() {
                Ok(req) => self.admit(req),
                Err(_) => break,
            }
        }
        // shed anything whose deadline lapsed while it waited — after
        // linger/drain so a request expiring inside the linger window is
        // still caught, before assemble so it never costs sampler time
        self.shed_expired();
        let span = Span::begin();
        let batch = self.assemble();
        span.end(&self.metrics.batch_assemble_ns);
        if !batch.is_empty() {
            self.metrics.batch_rows.record(batch.rows as u64);
        }
        Some(batch)
    }

    /// Slice up to `max_batch` rows from the oldest unfinished requests
    /// (FIFO, restricted to the oldest request's direction so every
    /// super-batch integrates one way). Consecutive super-batches of the
    /// same step count replay the same ODE t-grid, so they ride the
    /// worker's warm time-embedding cache (see `engine/workspace.rs`) —
    /// the batcher never needs to know about it, it only has to keep
    /// handing batches to the same persistent worker adapter.
    fn assemble(&mut self) -> SuperBatch {
        let Some(dir) = self.active.iter().find(|a| a.issued < a.n).map(|a| a.dir) else {
            return SuperBatch::empty();
        };
        let d = self.d;
        // size the buffers up front: one growth instead of log2(rows*d)
        // doubling reallocations per super-batch on the noise-push path
        let cap = self.max_batch.min(self.pending_rows());
        let mut x0 = Vec::with_capacity(cap * d);
        let mut slices = Vec::with_capacity(self.active.len().min(cap));
        let mut batch_row = 0usize;
        for a in self.active.iter_mut() {
            if batch_row == self.max_batch {
                break;
            }
            if a.dir != dir || a.issued >= a.n {
                continue;
            }
            let take = (a.n - a.issued).min(self.max_batch - batch_row);
            if a.issued == 0 && obs::timing_enabled() {
                // first issuance: the request's whole queue wait is over
                obs::record_since(&self.metrics.queue_wait_ns, a.admitted);
            }
            match &mut a.src {
                Source::Noise(rng) => {
                    for _ in 0..take * d {
                        x0.push(rng.normal_f32(0.0, 1.0));
                    }
                }
                Source::Rows(rows) => {
                    // fmq-lint: allow(panic_safety) -- admit() pins rows.len() == n*d and issued+take <= n
                    x0.extend_from_slice(&rows[a.issued * d..(a.issued + take) * d]);
                }
            }
            slices.push(Slice {
                id: a.id,
                at: a.issued,
                batch_row,
                take,
            });
            a.issued += take;
            batch_row += take;
        }
        SuperBatch {
            dir,
            x0,
            rows: batch_row,
            slices,
        }
    }

    /// Reassemble one integrated super-batch back into its requests (or
    /// fail them): rows land at each request's recorded offset, and a
    /// request replies the moment its last row arrives. On `Ok`, the
    /// slice must hold at least `batch.rows * d` values in `x0` order;
    /// on `Err`, every request sliced into the batch fails with the
    /// typed error (this is how the supervisor fails exactly the
    /// in-flight super-batch's requests with `worker_panic`).
    pub fn complete(&mut self, batch: SuperBatch, result: Result<&[f32], &ServeError>) {
        let d = self.d;
        for s in batch.slices {
            let Some(pos) = self.active.iter().position(|a| a.id == s.id) else {
                continue;
            };
            match result {
                Ok(rows) => {
                    // re-slice defensively: a worker handing back fewer
                    // rows than the super-batch asked for must fail the
                    // request, never panic the batcher thread (a panic
                    // here would strand every queued client)
                    let Some(a) = self.active.get_mut(pos) else {
                        continue;
                    };
                    let src = rows.get(s.batch_row * d..(s.batch_row + s.take) * d);
                    let dst = a.out.get_mut(s.at * d..(s.at + s.take) * d);
                    let copied = match (src, dst) {
                        (Some(src), Some(dst)) => {
                            dst.copy_from_slice(src);
                            true
                        }
                        _ => false,
                    };
                    if copied {
                        a.done += s.take;
                        let finished = a.done == a.n;
                        if finished {
                            if let Some(a) = self.active.remove(pos) {
                                // receiver may have hung up; fine
                                let _ = a.reply.send(Ok(a.out));
                            }
                        }
                    } else if let Some(a) = self.active.remove(pos) {
                        let _ = a.reply.send(Err(ServeError::internal(
                            "worker result shorter than super-batch",
                        )));
                    }
                }
                Err(err) => {
                    if let Some(a) = self.active.remove(pos) {
                        let _ = a.reply.send(Err(err.clone()));
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    /// Test batcher with its own throwaway metrics registry.
    fn mk(max_batch: usize, linger: Duration, d: usize, queue_cap: usize) -> Batcher {
        Batcher::new(max_batch, linger, d, queue_cap, Arc::new(Metrics::new()))
    }

    fn gen_req(n: usize, seed: u64) -> (GenRequest, mpsc::Receiver<Reply>) {
        let (rtx, rrx) = mpsc::channel();
        (
            GenRequest {
                work: Work::Generate { n, seed },
                deadline: None,
                reply: rtx,
            },
            rrx,
        )
    }

    /// The first n*d normals of the request's own seed — the noise
    /// contract the server's determinism guarantee is built on.
    fn expected_noise(seed: u64, n: usize, d: usize) -> Vec<f32> {
        let mut rng = Pcg64::seed(seed);
        (0..n * d).map(|_| rng.normal_f32(0.0, 1.0)).collect()
    }

    #[test]
    fn batches_accumulate_within_linger() {
        let d = 4;
        let mut b = mk(8, Duration::from_millis(50), d, 64);
        let tx = b.submitter();
        let mut rxs = Vec::new();
        for i in 0..3 {
            let (req, rrx) = gen_req(2, i);
            tx.send(req).unwrap();
            rxs.push(rrx);
        }
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.requests(), 3);
        assert_eq!(batch.rows, 6);
        assert_eq!(batch.x0.len(), 6 * d);
        assert_eq!(batch.dir, Direction::Forward);
    }

    #[test]
    fn full_batch_returns_immediately() {
        let mut b = mk(4, Duration::from_secs(10), 4, 64); // long linger
        let tx = b.submitter();
        let (req, _rrx) = gen_req(4, 0);
        tx.send(req).unwrap();
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert!(t0.elapsed() < Duration::from_secs(1)); // didn't linger
        assert_eq!(batch.rows, 4);
    }

    #[test]
    fn noise_is_per_request_and_independent_of_cobatching() {
        let d = 3;
        // alone
        let mut b = mk(8, Duration::from_millis(5), d, 64);
        let (req, _r) = gen_req(2, 42);
        b.submitter().send(req).unwrap();
        let alone = b.next_batch().unwrap();
        // co-batched behind another request with a different seed
        let mut b2 = mk(8, Duration::from_millis(5), d, 64);
        let (other, _r2) = gen_req(3, 7);
        let (req, _r3) = gen_req(2, 42);
        b2.submitter().send(other).unwrap();
        b2.submitter().send(req).unwrap();
        let shared = b2.next_batch().unwrap();
        assert_eq!(shared.rows, 5);
        // rows 3.. of the shared batch are request 42's rows — identical
        // to its solo noise, and equal to the seed's own stream
        assert_eq!(&shared.x0[3 * d..], &alone.x0[..]);
        assert_eq!(alone.x0, expected_noise(42, 2, d));
        // two co-batched requests with the SAME seed get the same noise
        // (the old xor-fold cancelled them to the base seed instead)
        let mut b3 = mk(8, Duration::from_millis(5), d, 64);
        let (ra, _ka) = gen_req(1, 9);
        let (rb, _kb) = gen_req(1, 9);
        b3.submitter().send(ra).unwrap();
        b3.submitter().send(rb).unwrap();
        let twin = b3.next_batch().unwrap();
        assert_eq!(twin.rows, 2);
        assert_eq!(twin.x0[..d], twin.x0[d..2 * d]);
    }

    #[test]
    fn large_request_slices_across_batches_and_reassembles_exact_n() {
        let d = 2;
        let (n, max_batch) = (10usize, 4usize);
        let mut b = mk(max_batch, Duration::from_millis(1), d, 64);
        let (req, rrx) = gen_req(n, 5);
        b.submitter().send(req).unwrap();
        let mut sizes = Vec::new();
        let mut noise = Vec::new();
        for _ in 0..3 {
            let batch = b.next_batch().unwrap();
            sizes.push(batch.rows);
            noise.extend_from_slice(&batch.x0);
            // identity "integration": reply rows = input rows
            let rows = batch.x0.clone();
            b.complete(batch, Ok(&rows));
        }
        assert_eq!(sizes, vec![4, 4, 2]);
        assert_eq!(b.backlog_rows(), 0);
        let out = rrx.recv().unwrap().unwrap();
        assert_eq!(out.len(), n * d, "exactly n rows delivered");
        // in order, and slicing-invariant: the request's own noise stream
        assert_eq!(out, noise);
        assert_eq!(out, expected_noise(5, n, d));
    }

    #[test]
    fn directions_are_not_mixed_in_one_batch() {
        let d = 2;
        let mut b = mk(8, Duration::from_millis(5), d, 64);
        let (gtx, grx) = mpsc::channel();
        let (etx, erx) = mpsc::channel();
        b.submitter()
            .send(GenRequest {
                work: Work::Generate { n: 2, seed: 1 },
                deadline: None,
                reply: gtx,
            })
            .unwrap();
        b.submitter()
            .send(GenRequest {
                work: Work::Encode {
                    rows: vec![0.5; 3 * d],
                },
                deadline: None,
                reply: etx,
            })
            .unwrap();
        let first = b.next_batch().unwrap();
        assert_eq!(first.dir, Direction::Forward);
        assert_eq!(first.rows, 2);
        let rows = first.x0.clone();
        b.complete(first, Ok(&rows));
        assert!(grx.try_recv().unwrap().is_ok());
        let second = b.next_batch().unwrap();
        assert_eq!(second.dir, Direction::Reverse);
        assert_eq!(second.rows, 3);
        assert_eq!(second.x0, vec![0.5; 3 * d]);
        let rows = second.x0.clone();
        b.complete(second, Ok(&rows));
        assert_eq!(erx.recv().unwrap().unwrap(), vec![0.5; 3 * d]);
    }

    #[test]
    fn failed_batch_fails_only_its_requests() {
        let d = 2;
        let mut b = mk(2, Duration::from_millis(1), d, 64);
        let (req, rrx) = gen_req(2, 3);
        b.submitter().send(req).unwrap();
        let batch = b.next_batch().unwrap();
        b.complete(batch, Err(&ServeError::internal("engine exploded")));
        let got = rrx.recv().unwrap();
        let err = got.unwrap_err();
        assert_eq!(err.to_string(), "engine exploded");
        assert_eq!(err.class, crate::coordinator::errors::ErrClass::Internal);
        assert_eq!(b.backlog_rows(), 0);
    }

    #[test]
    fn invalid_requests_fail_fast_without_admission() {
        let d = 4;
        let mut b = mk(4, Duration::from_millis(1), d, 64);
        let (ztx, zrx) = mpsc::channel();
        b.submitter()
            .send(GenRequest {
                work: Work::Generate { n: 0, seed: 1 },
                deadline: None,
                reply: ztx,
            })
            .unwrap();
        let (etx, erx) = mpsc::channel();
        b.submitter()
            .send(GenRequest {
                work: Work::Encode {
                    rows: vec![0.0; d + 1], // not a whole number of rows
                },
                deadline: None,
                reply: etx,
            })
            .unwrap();
        let batch = b.next_batch().unwrap();
        assert!(batch.is_empty());
        assert!(zrx.recv().unwrap().is_err());
        let err = erx.recv().unwrap().unwrap_err();
        assert!(err.to_string().contains("flat [n, d]"));
        assert_eq!(err.class, crate::coordinator::errors::ErrClass::BadRequest);
    }

    /// The batcher feeds the owning server's registry: every non-empty
    /// batch records its row count, and (with timing on) the first
    /// issuance of a request records its queue wait.
    #[test]
    fn metrics_record_assembly_and_queue_wait() {
        let _g = crate::obs::span::TEST_TIMING_LOCK
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        crate::obs::set_timing_enabled(true);
        let d = 2;
        let m = Arc::new(Metrics::new());
        let mut b = Batcher::new(4, Duration::from_millis(1), d, 64, m.clone());
        let (req, _r) = gen_req(2, 1);
        b.submitter().send(req).unwrap();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.rows, 2);
        assert_eq!(m.batch_rows.snapshot().count, 1, "rows histogram fed");
        if !cfg!(feature = "no-obs") {
            assert_eq!(m.queue_wait_ns.snapshot().count, 1, "queue wait fed once");
            assert!(m.batch_assemble_ns.snapshot().count >= 1, "assembly timed");
        }
        // the sliced tail must NOT record queue wait again
        let rows = batch.x0.clone();
        b.complete(batch, Ok(&rows));
        if !cfg!(feature = "no-obs") {
            assert_eq!(m.queue_wait_ns.snapshot().count, 1);
        }
    }

    #[test]
    fn expired_deadline_is_shed_at_admission() {
        use crate::coordinator::errors::ErrClass;
        let mut b = mk(4, Duration::from_millis(1), 2, 64);
        let (rtx, rrx) = mpsc::channel();
        b.submitter()
            .send(GenRequest {
                work: Work::Generate { n: 2, seed: 1 },
                deadline: Some(Instant::now() - Duration::from_millis(1)),
                reply: rtx,
            })
            .unwrap();
        let batch = b.next_batch().unwrap();
        assert!(batch.is_empty(), "expired request must not produce rows");
        let err = rrx.recv().unwrap().unwrap_err();
        assert_eq!(err.class, ErrClass::DeadlineExceeded);
        assert_eq!(b.backlog_rows(), 0, "nothing admitted");
    }

    #[test]
    fn queued_request_expiring_behind_backlog_is_shed_before_assembly() {
        use crate::coordinator::errors::ErrClass;
        let d = 2;
        // max_batch 2: the first request (n=4) needs two batches, so the
        // second request waits in the active set across a dispatch
        let mut b = mk(2, Duration::from_millis(1), d, 64);
        let (big, big_rx) = gen_req(4, 1);
        b.submitter().send(big).unwrap();
        let (rtx, rrx) = mpsc::channel();
        b.submitter()
            .send(GenRequest {
                work: Work::Generate { n: 1, seed: 2 },
                deadline: Some(Instant::now() + Duration::from_millis(20)),
                reply: rtx,
            })
            .unwrap();
        let first = b.next_batch().unwrap();
        assert_eq!(first.rows, 2);
        std::thread::sleep(Duration::from_millis(30)); // deadline lapses in queue
        let rows = first.x0.clone();
        b.complete(first, Ok(&rows));
        let second = b.next_batch().unwrap(); // sheds, then assembles big's tail
        assert_eq!(second.rows, 2, "big request's tail still runs");
        let err = rrx.recv().unwrap().unwrap_err();
        assert_eq!(err.class, ErrClass::DeadlineExceeded);
        let rows = second.x0.clone();
        b.complete(second, Ok(&rows));
        assert!(big_rx.recv().unwrap().is_ok(), "unexpired request unharmed");
    }

    #[test]
    fn abort_all_fails_active_and_channel_queued_requests() {
        use crate::coordinator::errors::ErrClass;
        let mut b = mk(2, Duration::from_millis(1), 2, 64);
        let (admitted, admitted_rx) = gen_req(4, 1);
        b.submitter().send(admitted).unwrap();
        let batch = b.next_batch().unwrap(); // admits + issues first slice
        assert_eq!(batch.rows, 2);
        let (queued, queued_rx) = gen_req(1, 2);
        b.submitter().send(queued).unwrap(); // still in the channel
        b.abort_all(&ServeError::shutting_down("server stopped"));
        for rx in [admitted_rx, queued_rx] {
            let err = rx.recv().unwrap().unwrap_err();
            assert_eq!(err.class, ErrClass::ShuttingDown);
        }
        assert_eq!(b.backlog_rows(), 0);
    }

    #[test]
    fn next_batch_times_out_empty_when_idle() {
        let mut b = mk(4, Duration::from_millis(1), 2, 64);
        let batch = b.next_batch().unwrap();
        assert!(batch.is_empty());
        // a request sent from another thread still arrives
        let tx = b.submitter();
        let h = thread::spawn(move || {
            let (rtx, _r) = mpsc::channel();
            tx.send(GenRequest {
                work: Work::Generate { n: 1, seed: 0 },
                deadline: None,
                reply: rtx,
            })
            .unwrap();
        });
        h.join().unwrap();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.rows, 1);
    }
}
