//! Dynamic batcher: aggregates concurrent generation requests into
//! fixed-size model batches (the artifact's B_SAMPLE), trading a small
//! queue delay for full batch occupancy — the standard serving pattern
//! (vLLM-style), implemented with std threads + channels.

use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// One queued request: n samples wanted, seed, and a reply channel.
pub struct GenRequest {
    pub n: usize,
    pub seed: u64,
    pub reply: Sender<Vec<f32>>,
}

/// Batch assembled by the batcher: requests to fill one model batch.
pub struct Batch {
    pub requests: Vec<GenRequest>,
    pub total: usize,
}

impl Batch {
    /// Sample count padded up to a whole number of model batches — the
    /// size every execution engine is handed, regardless of backend
    /// (fixed-shape HLO artifacts need exact batches; the CPU engines
    /// just amortize better on full ones).
    pub fn padded_total(&self, batch_size: usize) -> usize {
        self.total.max(1).div_ceil(batch_size.max(1)) * batch_size.max(1)
    }
}

/// Batching queue with a linger window.
pub struct Batcher {
    tx: Sender<GenRequest>,
    rx: Arc<Mutex<Receiver<GenRequest>>>,
    pub max_batch: usize,
    pub linger: Duration,
}

impl Batcher {
    pub fn new(max_batch: usize, linger: Duration) -> Self {
        let (tx, rx) = mpsc::channel();
        Self {
            tx,
            rx: Arc::new(Mutex::new(rx)),
            max_batch,
            linger,
        }
    }

    pub fn submitter(&self) -> Sender<GenRequest> {
        self.tx.clone()
    }

    /// Pull the next batch: waits (up to 200 ms) for one request, then
    /// lingers up to `linger` (or until `max_batch` samples) to accumulate
    /// more. Returns `Some(empty batch)` on the wait timeout so worker
    /// loops can re-check their shutdown flag (the Batcher keeps a live
    /// submitter internally, so a plain blocking recv would never
    /// disconnect and `Server::stop` would deadlock on join); returns
    /// None only when every submitter is gone.
    pub fn next_batch(&self) -> Option<Batch> {
        let rx = self.rx.lock().unwrap();
        let first = match rx.recv_timeout(Duration::from_millis(200)) {
            Ok(req) => req,
            Err(mpsc::RecvTimeoutError::Timeout) => {
                return Some(Batch {
                    requests: Vec::new(),
                    total: 0,
                })
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => return None,
        };
        let mut total = first.n.min(self.max_batch);
        let mut requests = vec![first];
        let deadline = Instant::now() + self.linger;
        while total < self.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(req) => {
                    total += req.n;
                    requests.push(req);
                    if total >= self.max_batch {
                        break;
                    }
                }
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        Some(Batch { requests, total })
    }
}

/// Split one generated super-batch back to the per-request repliers.
/// `imgs` is flat [n_total_padded, d]; requests consume their n in order.
pub fn distribute(batch: Batch, imgs: &[f32], d: usize) {
    let mut off = 0usize;
    for req in batch.requests {
        let take = req.n.min((imgs.len() / d).saturating_sub(off));
        let slice = imgs[off * d..(off + take) * d].to_vec();
        off += take;
        let _ = req.reply.send(slice); // receiver may have hung up; fine
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn batches_accumulate_within_linger() {
        let b = Batcher::new(8, Duration::from_millis(50));
        let tx = b.submitter();
        for i in 0..3 {
            let (rtx, _rrx) = mpsc::channel();
            tx.send(GenRequest {
                n: 2,
                seed: i,
                reply: rtx,
            })
            .unwrap();
        }
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.requests.len(), 3);
        assert_eq!(batch.total, 6);
    }

    #[test]
    fn full_batch_returns_immediately() {
        let b = Batcher::new(4, Duration::from_secs(10)); // long linger
        let tx = b.submitter();
        let (rtx, _rrx) = mpsc::channel();
        tx.send(GenRequest {
            n: 4,
            seed: 0,
            reply: rtx,
        })
        .unwrap();
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert!(t0.elapsed() < Duration::from_secs(1)); // didn't linger
        assert_eq!(batch.total, 4);
    }

    #[test]
    fn padded_total_rounds_to_model_batches() {
        let mk = |total| Batch {
            requests: Vec::new(),
            total,
        };
        assert_eq!(mk(1).padded_total(16), 16);
        assert_eq!(mk(16).padded_total(16), 16);
        assert_eq!(mk(17).padded_total(16), 32);
        assert_eq!(mk(0).padded_total(16), 16); // empty batch still 1 slot
    }

    #[test]
    fn distribute_splits_in_order() {
        let (tx1, rx1) = mpsc::channel();
        let (tx2, rx2) = mpsc::channel();
        let batch = Batch {
            requests: vec![
                GenRequest {
                    n: 1,
                    seed: 0,
                    reply: tx1,
                },
                GenRequest {
                    n: 2,
                    seed: 0,
                    reply: tx2,
                },
            ],
            total: 3,
        };
        let d = 4;
        let imgs: Vec<f32> = (0..4 * d).map(|i| i as f32).collect(); // padded to 4
        distribute(batch, &imgs, d);
        assert_eq!(rx1.recv().unwrap(), vec![0.0, 1.0, 2.0, 3.0]);
        assert_eq!(rx2.recv().unwrap().len(), 2 * d);
    }

    #[test]
    fn next_batch_none_when_senders_dropped() {
        let b = Batcher::new(4, Duration::from_millis(1));
        let tx = b.submitter();
        drop(tx);
        // also drop the internal tx by moving b into a thread? the Batcher
        // holds its own tx clone, so spawn a thread that sends one request
        // then hang up — ensure we still get that batch.
        let b = Batcher::new(4, Duration::from_millis(1));
        let tx = b.submitter();
        let h = thread::spawn(move || {
            let (rtx, _r) = mpsc::channel();
            tx.send(GenRequest {
                n: 1,
                seed: 0,
                reply: rtx,
            })
            .unwrap();
        });
        h.join().unwrap();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.total, 1);
    }
}
