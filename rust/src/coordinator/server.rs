//! Serving layer: TCP, JSON-lines protocol, dynamic batching per model
//! variant, supervised workers. Python never runs here — quantized
//! sampling executes through the compiled HLO (or the CPU reference when
//! artifacts are absent).
//!
//! Protocol (one JSON object per line; request lines are capped at
//! [`MAX_LINE`] bytes, sized to the largest legal `encode` payload;
//! `seed` is a JSON number, so it must stay below 2^53 — the f64
//! integer-precision limit — to round-trip exactly):
//!   -> {"op": "generate", "model": "ot4", "n": 2, "seed": 7}
//!   <- {"ok": true, "model": "ot4", "n": 2, "d": 768, "images": [...]}
//!   -> {"op": "generate", "model": "ot4", "n": 2, "seed": 7,
//!       "deadline_ms": 250}              (optional per-request budget)
//!   -> {"op": "encode", "model": "ot4", "images": [... n*d floats ...]}
//!   <- {"ok": true, "model": "ot4", "n": 2, "d": 768, "latents": [...]}
//!   -> {"op": "stats"}
//!   <- {"ok": true, "requests": 9, "batches": 3, "samples": 18,
//!       "encodes": 2, "errors": 0, "shed": 0, "worker_respawns": 0,
//!       "conn_drops": 0, "queue_depth": 0,
//!       "resident_bytes": 5443584, "workspace_bytes": 1245184}
//!   -> {"op": "metrics"}                     (or "format": "json")
//!   <- {"ok": true, "content_type": "text/plain; version=0.0.4",
//!       "body": "# HELP fmq_server_requests_total ...\n..."}
//!   -> {"op": "models"}
//!   <- {"ok": true, "models": ["fp32", "ot2", ...]}
//!   -> {"op": "ping"} / {"op": "shutdown"}   (shutdown begins a drain)
//!
//! Error replies are typed: `{"ok": false, "error": <message>, "code":
//! <class>, "retryable": <bool>[, "retry_after_ms": <hint>]}` with the
//! class taxonomy of [`crate::coordinator::errors`] (full matrix:
//! `docs/ROBUSTNESS.md`). Counter/gauge values in `stats` replies are
//! integer-exact ([`Json::Int`] — no f64 2^53 precision cliff for byte
//! gauges). The richer `metrics` op exposes the full [`crate::obs`]
//! registry — request-latency / queue-wait / per-ODE-step histograms
//! with p50/p95/p99 estimates — as Prometheus text-format or JSON; the
//! catalogue is documented in `docs/OBSERVABILITY.md`.
//!
//! Serving contracts:
//!
//! * **Determinism.** A `generate` reply is a pure function of
//!   `(model, n, seed, steps)`: the request's noise comes from its own
//!   `Pcg64::seed(seed)` stream (see `coordinator/batcher.rs`), and the
//!   native engines are row-independent and bit-stable across batch
//!   shapes, so co-batched traffic, request slicing and queue position
//!   never change a single bit of the result. Under the `cpu-ref`/`lut`
//!   engines (the no-artifact auto default) the reply is additionally
//!   bit-identical to running `flow::sampler::generate` locally with
//!   the same seed; `lut2`/`runtime` replies are equally deterministic
//!   but match the reference sampler only within the 1e-5
//!   engine-equivalence harness (v2 re-associates sums). Worker panics
//!   and respawns do not weaken this: a respawned worker repacks the
//!   same variant, so a retried request returns the identical bits.
//! * **Exact n.** Requests up to [`MAX_N`] samples are sliced across as
//!   many super-batches as needed (slot accounting in the batcher) and
//!   reassembled in order — never truncated to the model batch.
//! * **Backpressure.** Each variant's queue is a bounded channel
//!   (`ServerConfig::queue_cap`); once it fills, submits are *shed* with
//!   a typed `overloaded` error carrying a `retry_after_ms` hint instead
//!   of blocking connection handlers (load never grows server memory,
//!   and a client can tell "busy" from "broken").
//! * **Supervision.** Each variant worker runs its batches under
//!   `catch_unwind`; a panic fails only the in-flight super-batch's
//!   requests with a retryable `worker_panic` error, then the supervisor
//!   respawns the worker (fresh engine + [`EngineStep`]) under capped
//!   exponential backoff. Queued requests survive respawn untouched.
//! * **Deadlines.** A request's optional `deadline_ms` is enforced at
//!   admission, before each batch assembly (queued-but-expired requests
//!   are shed with `deadline_exceeded`), and on the reply wait.
//! * **Drain.** [`Server::stop`] (and the `shutdown` op) moves the
//!   lifecycle to *draining*: no new work is admitted, in-flight and
//!   queued requests are flushed, and only stragglers past the drain
//!   deadline are failed with `shutting_down`.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU8, AtomicUsize, Ordering};
use std::sync::mpsc::{self, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::coordinator::batcher::{Batcher, GenRequest, Work};
use crate::coordinator::errors::ServeError;
use crate::coordinator::registry::{Registry, Variant};
use crate::engine::{CpuRefEngine, Engine, EngineKind, LutEngine, LutV2Engine, Tuner};
use crate::faults::{BatchFault, FaultPlan, ReplyFault};
use crate::flow::sampler::{self, Direction, EngineStep, HloQStep, HloStep};
use crate::model::spec::ModelSpec;
use crate::obs::{self, Metrics, Span};
use crate::runtime::SharedArtifacts;
use crate::util::json::{parse, Json};
use crate::util::rng::Pcg64;

/// Protocol cap on samples per request (`generate` n, `encode` rows).
pub const MAX_N: usize = 256;

/// Request-line byte cap: a runaway (or malicious) client cannot grow
/// server memory past this per connection. Sized so the largest legal
/// `encode` request (MAX_N × d floats in decimal) still fits.
pub const MAX_LINE: u64 = 16 * 1024 * 1024;

/// `retry_after_ms` hint attached to `overloaded` shed replies: one
/// model batch is typically integrated well within this, so a polite
/// client retrying after it usually finds a free queue slot.
pub const SHED_RETRY_MS: u64 = 100;

/// Reply wait when the request carries no deadline — the historical
/// server-wide generation timeout.
const DEFAULT_SUBMIT_TIMEOUT: Duration = Duration::from_secs(600);

/// Cap on client-supplied `deadline_ms` (24h): keeps `Instant + Duration`
/// arithmetic far from overflow while remaining far beyond any real
/// request budget.
const MAX_DEADLINE_MS: u64 = 86_400_000;

/// First respawn backoff is `BACKOFF_BASE_MS << 1`, doubling per
/// consecutive respawn up to `BACKOFF_BASE_MS << BACKOFF_MAX_SHIFT`
/// (640ms) — long enough to stop a crash-looping engine from spinning a
/// core, short enough that a one-off panic barely dents latency.
const BACKOFF_BASE_MS: u64 = 10;
const BACKOFF_MAX_SHIFT: u32 = 6;

/// Server configuration.
pub struct ServerConfig {
    pub addr: String,
    pub steps: usize,
    pub linger: Duration,
    /// Execution backend; `None` = auto (compiled HLO when artifacts are
    /// loaded, else the native LUT engine for quantized variants and the
    /// CPU reference for fp32).
    pub engine: Option<EngineKind>,
    /// Bound on queued requests per model variant. Submits against a
    /// full queue are shed with a typed `overloaded` error (plus
    /// `retry_after_ms` hint) instead of blocking the connection.
    pub queue_cap: usize,
    /// Write a Prometheus text-format metrics snapshot to this path when
    /// the server stops (the `--metrics-dump` flag), so benches and CI
    /// capture latency trajectories as artifacts.
    pub metrics_dump: Option<PathBuf>,
    /// How long [`Server::stop`] lets in-flight + queued work flush
    /// before hard-failing stragglers with `shutting_down`.
    pub drain: Duration,
    /// Deterministic fault-injection plan (chaos harness). Inert unless
    /// built with the `faults` cargo feature *and* rules are configured
    /// (`FMQ_FAULTS` is read by the CLI, never ambiently here).
    pub faults: Arc<FaultPlan>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:7878".to_string(),
            steps: 16,
            linger: Duration::from_millis(5),
            engine: None,
            queue_cap: 256,
            metrics_dump: None,
            drain: Duration::from_secs(5),
            faults: Arc::new(FaultPlan::none()),
        }
    }
}

/// Lifecycle phase of a serving process. Transitions are one-way:
/// `Running -> Draining -> Stopped` (stop can skip the drain).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LifeState {
    /// Admitting and serving work.
    Running,
    /// No longer admitting; flushing in-flight + queued requests.
    Draining,
    /// Hard-stopped; workers abort whatever remains with `shutting_down`.
    Stopped,
}

/// Shared lifecycle switchboard: the phase plus a live-worker count the
/// drain loop polls. Replaces the old single `AtomicBool` shutdown flag
/// so "stop admitting" and "abandon in-flight work" are distinct steps.
pub struct Lifecycle {
    state: AtomicU8,
    live_workers: AtomicUsize,
}

impl Lifecycle {
    const RUNNING: u8 = 0;
    const DRAINING: u8 = 1;
    const STOPPED: u8 = 2;

    pub fn new(workers: usize) -> Self {
        Self {
            state: AtomicU8::new(Self::RUNNING),
            live_workers: AtomicUsize::new(workers),
        }
    }

    pub fn state(&self) -> LifeState {
        match self.state.load(Ordering::SeqCst) {
            Self::RUNNING => LifeState::Running,
            Self::DRAINING => LifeState::Draining,
            _ => LifeState::Stopped,
        }
    }

    /// Move `Running -> Draining`. No-op from any later phase (never
    /// regresses a `Stopped` server back to draining).
    pub fn begin_drain(&self) {
        let _ = self.state.compare_exchange(
            Self::RUNNING,
            Self::DRAINING,
            Ordering::SeqCst,
            Ordering::SeqCst,
        );
    }

    fn stop_hard(&self) {
        self.state.store(Self::STOPPED, Ordering::SeqCst);
    }

    fn worker_exited(&self) {
        self.live_workers.fetch_sub(1, Ordering::SeqCst);
    }

    /// Variant workers that have not yet exited their serve loop.
    pub fn workers_live(&self) -> usize {
        self.live_workers.load(Ordering::SeqCst)
    }
}

/// Resolve the configured engine for one variant. `None` means "run the
/// batch through the compiled-HLO artifact sessions" (the `Runtime`
/// kind); `Some(engine)` is a native in-process backend. Built once per
/// serving worker (and again on each supervisor respawn), so LUT packing
/// happens at startup, never per request.
///
/// An *explicit* `--engine lut`/`lut2` choice that fails to pack is an
/// error (the operator asked for a specific backend; silently serving
/// through `cpu-ref` would misreport every benchmark run against it).
/// Only `auto` (no choice) falls back to the reference on packing
/// failure, because there it is a selection default, not an override.
fn resolve_engine<'a>(
    choice: Option<EngineKind>,
    has_art: bool,
    variant: &'a Variant,
    spec: &'a ModelSpec,
    pool: crate::engine::Pool,
) -> Result<Option<Box<dyn Engine + 'a>>> {
    let explicit = choice.is_some();
    let kind = choice.unwrap_or(if has_art {
        EngineKind::Runtime
    } else if matches!(variant, Variant::Quantized(_)) {
        EngineKind::Lut
    } else {
        EngineKind::CpuRef
    });
    match (kind, variant) {
        (EngineKind::Runtime, _) if has_art => Ok(None),
        // runtime resolved by auto without artifacts cannot happen (auto
        // never picks it then); an *explicit* runtime choice without
        // artifacts is rejected up front in `serve`. Defensive fallback:
        (EngineKind::Runtime, _) => resolve_engine(None, false, variant, spec, pool),
        (EngineKind::Lut, Variant::Quantized(qm)) => match LutEngine::with_pool(qm, pool) {
            Ok(e) => Ok(Some(Box::new(e))),
            Err(e) if explicit => Err(e.context("--engine lut")),
            // auto-picked on an unpackable model (e.g. >8 bits): serve
            // correct, just slower
            Err(_) => Ok(Some(Box::new(CpuRefEngine::quantized(qm)))),
        },
        // v2: measured autotuning warms up on the first batches per GEMM
        // shape, then dispatches cached tile plans
        (EngineKind::Lut2, Variant::Quantized(qm)) => {
            match LutV2Engine::with_config(qm, pool, Tuner::measured()) {
                Ok(e) => Ok(Some(Box::new(e))),
                Err(e) if explicit => Err(e.context("--engine lut2")),
                Err(_) => Ok(Some(Box::new(CpuRefEngine::quantized(qm)))),
            }
        }
        // the LUT engines are quantized-only; fp32 serves via the reference
        (EngineKind::Lut | EngineKind::Lut2, Variant::FullPrecision(theta)) => {
            Ok(Some(Box::new(CpuRefEngine::fp32(spec, theta))))
        }
        (EngineKind::CpuRef, Variant::FullPrecision(theta)) => {
            Ok(Some(Box::new(CpuRefEngine::fp32(spec, theta))))
        }
        (EngineKind::CpuRef, Variant::Quantized(qm)) => {
            Ok(Some(Box::new(CpuRefEngine::quantized(qm))))
        }
    }
}

/// The running server handle. `stats` is the per-server
/// [`crate::obs::Metrics`] registry (the old ad-hoc `ServerStats`
/// counters live there now, plus the lifecycle histograms).
pub struct Server {
    pub addr: std::net::SocketAddr,
    pub stats: Arc<Metrics>,
    lifecycle: Arc<Lifecycle>,
    threads: Vec<thread::JoinHandle<()>>,
    metrics_dump: Option<PathBuf>,
    drain: Duration,
}

impl Server {
    /// Graceful stop with the configured drain window
    /// (`ServerConfig::drain`).
    pub fn stop(self) {
        let drain = self.drain;
        self.stop_within(drain);
    }

    /// Graceful stop: begin draining (no new admissions), give in-flight
    /// and queued work up to `drain` to flush, then hard-stop — workers
    /// fail any stragglers with a typed `shutting_down` error — join
    /// every thread and write the metrics dump.
    pub fn stop_within(mut self, drain: Duration) {
        self.lifecycle.begin_drain();
        let deadline = Instant::now() + drain;
        while self.lifecycle.workers_live() > 0 && Instant::now() < deadline {
            thread::sleep(Duration::from_millis(5));
        }
        self.lifecycle.stop_hard();
        // nudge the acceptor with a dummy connection
        let _ = TcpStream::connect(self.addr);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
        // final snapshot after every worker has drained: the artifact CI
        // and benches pick up (`--metrics-dump`)
        if let Some(path) = &self.metrics_dump {
            if let Err(e) = std::fs::write(path, obs::render_prometheus(&self.stats)) {
                eprintln!("metrics dump to {} failed: {e}", path.display());
            }
        }
    }

    /// Whether a client issued the `shutdown` op (or `stop` began): the
    /// lifecycle has left `Running`. The CLI's serve loop polls this to
    /// exit and write the metrics dump.
    pub fn shutdown_requested(&self) -> bool {
        self.lifecycle.state() != LifeState::Running
    }

    /// The lifecycle switchboard (tests observe drain transitions here).
    pub fn lifecycle(&self) -> &Lifecycle {
        &self.lifecycle
    }
}

/// Launch the server: one acceptor thread, one supervised batching
/// worker per model variant. `registry` and the optional artifact set
/// are shared read-only.
pub fn serve(
    registry: Arc<Registry>,
    art: Option<Arc<SharedArtifacts>>,
    cfg: ServerConfig,
) -> Result<Server> {
    if cfg.engine == Some(EngineKind::Runtime) && art.is_none() {
        bail!(
            "--engine runtime needs compiled artifacts \
             (build with --features pjrt and run `make artifacts`)"
        );
    }
    let listener = TcpListener::bind(&cfg.addr)?;
    let addr = listener.local_addr()?;
    let stats = Arc::new(Metrics::new());
    let names = registry.names();
    let lifecycle = Arc::new(Lifecycle::new(names.len()));
    let mut threads = Vec::new();

    // one batcher + supervised worker per variant
    let batch_size = art
        .as_ref()
        .map(|a| a.with(|art| art.b_sample))
        .unwrap_or(16);
    let d = registry.spec.d;
    let mut submitters = std::collections::BTreeMap::new();
    for name in names {
        let batcher = Batcher::new(batch_size, cfg.linger, d, cfg.queue_cap, stats.clone());
        submitters.insert(name.clone(), batcher.submitter());
        let reg = registry.clone();
        let art = art.clone();
        let stats = stats.clone();
        let lc = lifecycle.clone();
        let fp = cfg.faults.clone();
        let steps = cfg.steps;
        let engine = cfg.engine;
        threads.push(thread::spawn(move || {
            worker_loop(&name, reg, art, batcher, stats, lc, fp, steps, batch_size, engine)
        }));
    }
    let submitters = Arc::new(submitters);

    // acceptor
    {
        let lc = lifecycle.clone();
        let fp = cfg.faults.clone();
        let stats = stats.clone();
        let reg = registry.clone();
        let subs = submitters.clone();
        threads.push(thread::spawn(move || {
            for stream in listener.incoming() {
                if lc.state() == LifeState::Stopped {
                    break;
                }
                let Ok(stream) = stream else { continue };
                let stats = stats.clone();
                let reg = reg.clone();
                let subs = subs.clone();
                let lc2 = lc.clone();
                let fp2 = fp.clone();
                thread::spawn(move || {
                    let _ = handle_conn(stream, &reg, &subs, &stats, &lc2, &fp2);
                });
            }
        }));
    }

    Ok(Server {
        addr,
        stats,
        lifecycle,
        threads,
        metrics_dump: cfg.metrics_dump,
        drain: cfg.drain,
    })
}

/// This worker's last exported contribution to the shared gauges, so
/// each iteration exports ONE signed delta (see [`export_gauges`]).
#[derive(Default)]
struct WorkerGauges {
    queue: i64,
    ws: i64,
}

/// How one supervised serve pass ended.
enum WorkerExit {
    /// Clean exit: drained idle, channel closed, or hard stop.
    Finished,
    /// A batch panicked; the supervisor should respawn the engine.
    Panicked,
}

/// Supervisor: build the engine, serve batches, and on a batch panic
/// respawn the whole execution stack (pool, engine, [`EngineStep`])
/// under capped exponential backoff. The batcher — and with it every
/// queued request — survives respawns untouched; only the super-batch
/// that was in flight during the panic is failed (typed `worker_panic`,
/// retryable).
#[allow(clippy::too_many_arguments)]
fn worker_loop(
    name: &str,
    registry: Arc<Registry>,
    art: Option<Arc<SharedArtifacts>>,
    mut batcher: Batcher,
    stats: Arc<Metrics>,
    lifecycle: Arc<Lifecycle>,
    faults: Arc<FaultPlan>,
    steps: usize,
    batch_size: usize,
    engine_choice: Option<EngineKind>,
) {
    let variant = match registry.get(name) {
        Ok(v) => v,
        Err(_) => {
            lifecycle.worker_exited();
            return;
        }
    };
    let d = registry.spec.d;
    let mut gauges = WorkerGauges::default();
    let mut respawns = 0u32;
    loop {
        // resolve + build the execution engine once per (re)spawn: for
        // the LUT engine this packs the codes up front, so the request
        // path only ever touches the packed representation. Each
        // worker's pool spans all cores — a lone hot variant should
        // saturate the machine, and when several variants batch at once
        // the scoped worker threads simply time-share.
        let pool = crate::engine::Pool::new(0);
        let resolved =
            resolve_engine(engine_choice, art.is_some(), variant, &registry.spec, pool);
        let engine = match resolved {
            Ok(e) => e,
            Err(err) => {
                // an explicit engine choice this variant cannot satisfy:
                // deterministic init failure, so never respawn — stay up
                // and fail each request with the build error instead of
                // silently serving through a different backend
                let serr =
                    ServeError::internal(format!("engine init failed for '{name}': {err:#}"));
                while lifecycle.state() == LifeState::Running {
                    let Some(batch) = batcher.next_batch() else { break };
                    batcher.complete(batch, Err(&serr));
                }
                batcher.abort_all(&ServeError::shutting_down(
                    "server stopped before the request completed",
                ));
                break;
            }
        };
        let res_bytes = engine
            .as_deref()
            .map(|e| e.resident_bytes() as i64)
            .unwrap_or(0);
        stats.resident_bytes.add(res_bytes);
        // one step adapter per spawn, reused across every super-batch:
        // its workspace arena (and the per-step time-embedding cache
        // inside it) persists, so after the first batch of a given step
        // grid the velocity hot path performs zero heap allocations
        let mut native = engine.as_deref().map(EngineStep::new);
        let exit = run_batches(
            name,
            variant,
            art.as_deref(),
            &mut batcher,
            &stats,
            &lifecycle,
            &faults,
            &mut native,
            steps,
            batch_size,
            d,
            &mut gauges,
        );
        match exit {
            WorkerExit::Finished => break,
            WorkerExit::Panicked => {
                // the panicked spawn's engine is dropped here; retract
                // its residency before the respawn re-adds its own
                stats.resident_bytes.add(-res_bytes);
                stats.worker_respawns.inc();
                respawns += 1;
                let shift = respawns.min(BACKOFF_MAX_SHIFT);
                thread::sleep(Duration::from_millis(BACKOFF_BASE_MS << shift));
            }
        }
    }
    stats.queue_depth.add(-gauges.queue);
    lifecycle.worker_exited();
}

/// One supervised serve pass: batch, integrate (under `catch_unwind`),
/// reply — until the lifecycle says stop, the queue drains idle, or a
/// batch panics.
#[allow(clippy::too_many_arguments)]
fn run_batches(
    name: &str,
    variant: &Variant,
    art: Option<&SharedArtifacts>,
    batcher: &mut Batcher,
    stats: &Metrics,
    lifecycle: &Lifecycle,
    faults: &FaultPlan,
    native: &mut Option<EngineStep<'_>>,
    steps: usize,
    batch_size: usize,
    d: usize,
    gauges: &mut WorkerGauges,
) -> WorkerExit {
    loop {
        if lifecycle.state() == LifeState::Stopped {
            // hard stop: whatever is still queued/active is a straggler
            // past the drain deadline
            batcher.abort_all(&ServeError::shutting_down(
                "server stopped before the request completed",
            ));
            export_gauges(batcher, native.as_ref(), stats, gauges);
            return WorkerExit::Finished;
        }
        let Some(batch) = batcher.next_batch() else {
            // all submitters dropped -> server handle is gone
            return WorkerExit::Finished;
        };
        if batch.is_empty() {
            // idle tick: during a drain, idle + empty backlog means this
            // worker has flushed everything it will ever get
            if lifecycle.state() != LifeState::Running && batcher.backlog_rows() == 0 {
                export_gauges(batcher, native.as_ref(), stats, gauges);
                return WorkerExit::Finished;
            }
            continue;
        }
        let run_span = Span::begin();
        let res = catch_unwind(AssertUnwindSafe(|| {
            match faults.on_batch(name) {
                BatchFault::Slow(ms) => thread::sleep(Duration::from_millis(ms)),
                BatchFault::Panic => {
                    // fmq-analyze: allow(panic_cone) -- injected chaos
                    // fault; fires only under the `faults` feature with a
                    // matching FMQ_FAULTS rule, and exists to exercise
                    // the supervisor's catch_unwind + respawn path
                    panic!("injected fault: panic@batch for '{name}'")
                }
                BatchFault::None => {}
            }
            run_rows(
                native.as_mut(),
                variant,
                art,
                &batch.x0,
                batch.dir,
                steps,
                batch_size,
                d,
            )
        }));
        run_span.end(&stats.batch_run_ns);
        match res {
            Ok(Ok(rows)) => {
                stats.batches.inc();
                let counter = match batch.dir {
                    Direction::Forward => &stats.samples,
                    Direction::Reverse => &stats.encodes,
                };
                counter.add(batch.rows as u64);
                batcher.complete(batch, Ok(&rows));
            }
            Ok(Err(e)) => {
                batcher.complete(batch, Err(&ServeError::internal(e.to_string())));
            }
            Err(payload) => {
                // fail ONLY the in-flight super-batch's requests; queued
                // work survives for the respawned worker
                let what = panic_message(payload.as_ref());
                batcher.complete(
                    batch,
                    Err(&ServeError::worker_panic(format!(
                        "worker for '{name}' panicked while serving this batch: {what}"
                    ))),
                );
                export_gauges(batcher, native.as_ref(), stats, gauges);
                return WorkerExit::Panicked;
            }
        }
        export_gauges(batcher, native.as_ref(), stats, gauges);
    }
}

/// Export backlog + workspace as ONE signed delta per call so the gauges
/// sum correctly over concurrent workers and can never wrap: a reader
/// observes depth transitions atomically (no fetch_sub/fetch_add window
/// where another worker's export interleaves).
fn export_gauges(
    batcher: &Batcher,
    native: Option<&EngineStep<'_>>,
    stats: &Metrics,
    g: &mut WorkerGauges,
) {
    let depth = batcher.backlog_rows() as i64;
    stats.queue_depth.add(depth - g.queue);
    g.queue = depth;
    // arena high-water, same delta scheme (monotone per spawn)
    let hw = native
        .map(|be| be.workspace_bytes() + be.engine().workspace_bytes())
        .unwrap_or(0) as i64;
    stats.workspace_bytes.add(hw - g.ws);
    g.ws = hw;
}

/// Best-effort human-readable panic payload (the `&str`/`String` cases
/// cover `panic!` and `assert!` — everything the serve path can raise).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = payload.downcast_ref::<&'static str>() {
        s
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.as_str()
    } else {
        "non-string panic payload"
    }
}

/// Integrate one super-batch in the given direction. `native = Some(..)`
/// runs the worker's persistent [`EngineStep`] adapter (warm workspace +
/// temb cache) on the exact rows; `native = None` is the `Runtime` kind
/// and drives the compiled-HLO sessions, which are fixed-shape — rows
/// are padded with zeros up to whole model batches and the padding is
/// cut before the batcher reassembles replies (rows are independent
/// through the forward, so padding never changes a real row).
#[allow(clippy::too_many_arguments)]
fn run_rows(
    native: Option<&mut EngineStep>,
    variant: &Variant,
    art: Option<&SharedArtifacts>,
    x0: &[f32],
    dir: Direction,
    steps: usize,
    batch_size: usize,
    d: usize,
) -> Result<Vec<f32>> {
    match native {
        Some(be) => sampler::run_direction(be, x0, dir, steps),
        None => {
            let sa = art.ok_or_else(|| anyhow::anyhow!("runtime engine requires artifacts"))?;
            let rows = x0.len() / d.max(1);
            let padded = rows.max(1).div_ceil(batch_size.max(1)) * batch_size.max(1);
            let mut xp = x0.to_vec();
            xp.resize(padded * d, 0.0);
            let mut out = Vec::with_capacity(padded * d);
            for chunk in xp.chunks(batch_size.max(1) * d) {
                let imgs = match variant {
                    Variant::FullPrecision(theta) => sa.with(|a| {
                        let mut be = HloStep { art: a, theta };
                        sampler::run_direction(&mut be, chunk, dir, steps)
                    })?,
                    Variant::Quantized(qm) => sa.with(|a| {
                        let mut be = HloQStep::new(a, qm)?;
                        sampler::run_direction(&mut be, chunk, dir, steps)
                    })?,
                };
                out.extend(imgs);
            }
            out.truncate(rows * d);
            Ok(out)
        }
    }
}

/// Serialize + write one reply line. Split out so `handle_conn` can
/// observe the io error exactly once (accounting) before propagating.
fn write_reply(writer: &mut TcpStream, reply: &Json) -> std::io::Result<()> {
    writer.write_all(reply.to_string().as_bytes())?;
    writer.write_all(b"\n")?;
    writer.flush()
}

fn handle_conn(
    stream: TcpStream,
    registry: &Registry,
    submitters: &std::collections::BTreeMap<String, SyncSender<GenRequest>>,
    stats: &Metrics,
    lifecycle: &Lifecycle,
    faults: &FaultPlan,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut buf = Vec::new();
    loop {
        buf.clear();
        // cap the request line so a client that never sends '\n' cannot
        // grow server memory without bound; bytes (not read_line) so the
        // limit cannot split a multi-byte character into an io error
        if (&mut reader).take(MAX_LINE).read_until(b'\n', &mut buf)? == 0 {
            return Ok(());
        }
        if buf.len() as u64 >= MAX_LINE && buf.last() != Some(&b'\n') {
            // overlong line: report, then close (the stream cannot be
            // resynchronized mid-line)
            let err = ServeError::bad_request(format!("request line exceeds {MAX_LINE} bytes"));
            stats.errors.inc();
            stats.error_class(err.class.code()).inc();
            if write_reply(&mut writer, &err.to_reply()).is_err() {
                stats.conn_drops.inc();
                return Ok(());
            }
            // best-effort drain of what the client already sent before
            // closing: dropping the socket with unread bytes queued makes
            // the kernel RST the connection, which would destroy the
            // error reply before the client can read it
            let _ = writer.shutdown(std::net::Shutdown::Write);
            let _ = writer.set_read_timeout(Some(Duration::from_millis(500)));
            let mut sink = [0u8; 8192];
            let mut drained = 0usize;
            while drained < 4 * MAX_LINE as usize {
                match reader.read(&mut sink) {
                    Ok(0) | Err(_) => break,
                    Ok(k) => drained += k,
                }
            }
            return Ok(());
        }
        // lossy conversion: invalid UTF-8 becomes a JSON parse error
        // reply below instead of dropping the connection
        let line = String::from_utf8_lossy(&buf);
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        // error accounting happens HERE and only here: `errors` and the
        // matching per-class counter move together, exactly once per
        // error reply, whatever happens to the socket afterwards
        let (reply, was_error) =
            match handle_request(trimmed, registry, submitters, stats, lifecycle) {
                Ok(j) => (j, false),
                Err(e) => {
                    stats.errors.inc();
                    stats.error_class(e.class.code()).inc();
                    (e.to_reply(), true)
                }
            };
        // injected connection-drop fault: sever before the reply write
        // so the client observes a mid-reply disconnect
        if matches!(faults.on_reply(), ReplyFault::Drop) {
            let _ = writer.shutdown(std::net::Shutdown::Both);
        }
        let ser_span = Span::begin();
        let wrote = write_reply(&mut writer, &reply);
        ser_span.end(&stats.reply_serialize_ns);
        if let Err(e) = wrote {
            // client went away mid-reply: count the dropped connection,
            // and if the reply was a success count ONE error for the
            // undeliverable result (an error reply was already counted
            // above — never double-count it)
            stats.conn_drops.inc();
            if !was_error {
                stats.errors.inc();
                stats.error_class("internal").inc();
            }
            return Err(e.into());
        }
        if lifecycle.state() == LifeState::Stopped {
            return Ok(());
        }
    }
}

/// A `worker is gone` disconnect: retryable `worker_panic` while the
/// supervisor is respawning, terminal `shutting_down` once the lifecycle
/// has left `Running` (the worker exited on purpose and is not coming
/// back).
fn worker_gone(model: &str, lifecycle: &Lifecycle) -> ServeError {
    if lifecycle.state() == LifeState::Running {
        ServeError::worker_panic(format!("worker for '{model}' is gone"))
    } else {
        ServeError::shutting_down(format!("worker for '{model}' is gone"))
    }
}

/// Submit one unit of work to a variant's batcher and wait for the
/// reassembled exact-n reply. Admission control lives here: drain gate,
/// queue-full shedding, and the deadline-derived reply wait.
fn submit(
    submitters: &std::collections::BTreeMap<String, SyncSender<GenRequest>>,
    lifecycle: &Lifecycle,
    stats: &Metrics,
    model: &str,
    work: Work,
    deadline: Option<Instant>,
) -> Result<Vec<f32>, ServeError> {
    let tx = submitters
        .get(model)
        .ok_or_else(|| ServeError::unknown_model(format!("unknown model '{model}'")))?;
    if lifecycle.state() != LifeState::Running {
        return Err(ServeError::shutting_down(format!(
            "server is draining; not admitting new '{model}' work"
        )));
    }
    let (rtx, rrx) = mpsc::channel();
    match tx.try_send(GenRequest {
        work,
        deadline,
        reply: rtx,
    }) {
        Ok(()) => {}
        Err(TrySendError::Full(_)) => {
            stats.shed.inc();
            return Err(ServeError::overloaded(
                format!("queue for '{model}' is full"),
                SHED_RETRY_MS,
            ));
        }
        Err(TrySendError::Disconnected(_)) => return Err(worker_gone(model, lifecycle)),
    }
    let wait = deadline
        .map(|dl| dl.saturating_duration_since(Instant::now()))
        .unwrap_or(DEFAULT_SUBMIT_TIMEOUT);
    match rrx.recv_timeout(wait) {
        Ok(reply) => reply,
        Err(mpsc::RecvTimeoutError::Timeout) => Err(if deadline.is_some() {
            ServeError::deadline_exceeded("deadline exceeded awaiting generation")
        } else {
            ServeError::deadline_exceeded("generation timed out")
        }),
        // worker died (panic / shutdown race): report that, not a timeout
        Err(mpsc::RecvTimeoutError::Disconnected) => Err(worker_gone(model, lifecycle)),
    }
}

/// Map a request-shape error (JSON parse, missing/mistyped field) onto
/// the `bad_request` class with the message unchanged.
fn bad(e: anyhow::Error) -> ServeError {
    ServeError::bad_request(e.to_string())
}

/// Parse the optional `deadline_ms` field into an absolute [`Instant`].
/// `0` is legal and expires immediately (deterministic in tests);
/// values are capped at 24h.
fn parse_deadline(req: &Json) -> Result<Option<Instant>, ServeError> {
    match req.get("deadline_ms") {
        None => Ok(None),
        Some(j) => {
            let ms = j.as_u64().ok_or_else(|| {
                ServeError::bad_request("deadline_ms must be a non-negative integer")
            })?;
            Ok(Some(
                Instant::now() + Duration::from_millis(ms.min(MAX_DEADLINE_MS)),
            ))
        }
    }
}

fn handle_request(
    line: &str,
    registry: &Registry,
    submitters: &std::collections::BTreeMap<String, SyncSender<GenRequest>>,
    stats: &Metrics,
    lifecycle: &Lifecycle,
) -> Result<Json, ServeError> {
    let req = parse(line).map_err(bad)?;
    stats.requests.inc();
    match req.req_str("op").map_err(bad)? {
        "ping" => Ok(Json::obj(vec![("ok", Json::Bool(true))])),
        "models" => Ok(Json::obj(vec![
            ("ok", Json::Bool(true)),
            (
                "models",
                Json::Arr(registry.names().into_iter().map(Json::Str).collect()),
            ),
        ])),
        // integer-exact ([`Json::Int`]): byte gauges can legitimately
        // exceed 2^53, where an f64 wire value silently rounds
        "stats" => Ok(Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("requests", Json::Int(stats.requests.get() as i128)),
            ("batches", Json::Int(stats.batches.get() as i128)),
            ("samples", Json::Int(stats.samples.get() as i128)),
            ("encodes", Json::Int(stats.encodes.get() as i128)),
            ("errors", Json::Int(stats.errors.get() as i128)),
            ("shed", Json::Int(stats.shed.get() as i128)),
            (
                "worker_respawns",
                Json::Int(stats.worker_respawns.get() as i128),
            ),
            ("conn_drops", Json::Int(stats.conn_drops.get() as i128)),
            ("queue_depth", Json::Int(stats.queue_depth.get() as i128)),
            ("resident_bytes", Json::Int(stats.resident_bytes.get() as i128)),
            ("workspace_bytes", Json::Int(stats.workspace_bytes.get() as i128)),
        ])),
        "metrics" => {
            let format = match req.get("format") {
                None => "prometheus",
                Some(j) => j
                    .as_str()
                    .ok_or_else(|| ServeError::bad_request("format must be a string"))?,
            };
            match format {
                "prometheus" => Ok(Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    (
                        "content_type",
                        Json::Str("text/plain; version=0.0.4".to_string()),
                    ),
                    ("body", Json::Str(obs::render_prometheus(stats))),
                ])),
                "json" => Ok(Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    ("metrics", obs::render_json(stats)),
                ])),
                other => Err(ServeError::bad_request(format!(
                    "unknown metrics format '{other}' (expected 'prometheus' or 'json')"
                ))),
            }
        }
        "shutdown" => {
            // begin a graceful drain; the CLI (or embedding test) sees
            // `shutdown_requested` and completes the stop with its
            // configured drain window
            lifecycle.begin_drain();
            Ok(Json::obj(vec![("ok", Json::Bool(true))]))
        }
        "generate" => {
            let model = req.req_str("model").map_err(bad)?;
            let n = req.req_usize("n").map_err(bad)?;
            if n == 0 || n > MAX_N {
                return Err(ServeError::bad_request(format!(
                    "n must be in 1..={MAX_N} (got {n})"
                )));
            }
            // strict like n: a coerced seed would silently alias two
            // distinct wire seeds onto one noise stream
            let seed = match req.get("seed") {
                None => 0u64,
                Some(j) => {
                    let s = j.as_u64().ok_or_else(|| {
                        ServeError::bad_request("seed must be an integer in 0..2^53")
                    })?;
                    if s >= 9_007_199_254_740_992 {
                        return Err(ServeError::bad_request(format!(
                            "seed must be an integer in 0..2^53 (got {s})"
                        )));
                    }
                    s
                }
            };
            let deadline = parse_deadline(&req)?;
            let latency = Span::begin();
            let imgs = submit(
                submitters,
                lifecycle,
                stats,
                model,
                Work::Generate { n, seed },
                deadline,
            )?;
            latency.end(&stats.request_latency_ns);
            let d = registry.spec.d.max(1);
            Ok(Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("model", Json::Str(model.to_string())),
                ("n", Json::Num((imgs.len() / d) as f64)),
                ("d", Json::Num(d as f64)),
                ("images", Json::from_f32s(&imgs)),
            ]))
        }
        "encode" => {
            let model = req.req_str("model").map_err(bad)?;
            let rows = req.req("images").map_err(bad)?.to_f32s().map_err(bad)?;
            let d = registry.spec.d.max(1);
            if rows.is_empty() || rows.len() % d != 0 {
                return Err(ServeError::bad_request(format!(
                    "images must be flat [n, d] with d={d} (got {} values)",
                    rows.len()
                )));
            }
            let n = rows.len() / d;
            if n > MAX_N {
                return Err(ServeError::bad_request(format!(
                    "encode rows must be in 1..={MAX_N} (got {n})"
                )));
            }
            let deadline = parse_deadline(&req)?;
            let latency = Span::begin();
            let latents = submit(
                submitters,
                lifecycle,
                stats,
                model,
                Work::Encode { rows },
                deadline,
            )?;
            latency.end(&stats.request_latency_ns);
            Ok(Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("model", Json::Str(model.to_string())),
                ("n", Json::Num(n as f64)),
                ("d", Json::Num(d as f64)),
                ("latents", Json::from_f32s(&latents)),
            ]))
        }
        other => Err(ServeError::bad_request(format!("unknown op '{other}'"))),
    }
}

/// Client-side retry schedule for *retryable* typed errors
/// (`worker_panic`, `overloaded`): jittered exponential backoff, floored
/// by the server's `retry_after_ms` hint when one is present. Terminal
/// errors and transport failures are never retried here — a dropped
/// connection needs a reconnect, which is the caller's policy call.
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// Retries after the first attempt (so `max_retries + 1` calls max).
    pub max_retries: u32,
    /// Backoff before the first retry; doubles per subsequent retry.
    pub base: Duration,
    /// Ceiling on the exponential term.
    pub cap: Duration,
    /// Jitter stream seed (deterministic schedules in tests).
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self {
            max_retries: 4,
            base: Duration::from_millis(50),
            cap: Duration::from_secs(2),
            seed: 0x5eed,
        }
    }
}

/// Minimal blocking client (used by examples, benches and tests).
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        Ok(Self {
            reader: BufReader::new(stream.try_clone()?),
            writer: stream,
        })
    }

    pub fn call(&mut self, req: &Json) -> Result<Json> {
        self.writer.write_all(req.to_string().as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut line = String::new();
        if self.reader.read_line(&mut line)? == 0 {
            return Err(anyhow::anyhow!("server closed connection"));
        }
        parse(line.trim())
    }

    /// `call`, retrying replies whose typed error is marked `retryable`
    /// (worker respawning, queue full) under `policy`'s jittered
    /// exponential backoff. The sleep never undercuts the server's
    /// `retry_after_ms` hint. Returns the first success, or the last
    /// error reply once retries are exhausted (as `server error: ...`,
    /// the same shape `checked` produces).
    pub fn call_with_retry(&mut self, req: &Json, policy: RetryPolicy) -> Result<Json> {
        let mut rng = Pcg64::seed(policy.seed);
        let mut attempt = 0u32;
        loop {
            let resp = self.call(req)?;
            if resp.get("ok").and_then(|j| j.as_bool()) == Some(true) {
                return Ok(resp);
            }
            let retryable = resp.get("retryable").and_then(|j| j.as_bool()) == Some(true);
            if !retryable || attempt >= policy.max_retries {
                return Err(anyhow::anyhow!(
                    "server error: {}",
                    resp.req_str("error").unwrap_or("unknown")
                ));
            }
            let exp = policy
                .base
                .saturating_mul(1u32 << attempt.min(16))
                .min(policy.cap);
            let hint = resp
                .get("retry_after_ms")
                .and_then(|j| j.as_u64())
                .map(Duration::from_millis)
                .unwrap_or(Duration::ZERO);
            // full backoff at most, half at least: jitter de-synchronizes
            // a thundering herd of shed clients without starving any
            thread::sleep(exp.max(hint).mul_f64(0.5 + 0.5 * rng.uniform()));
            attempt += 1;
        }
    }

    fn checked(&mut self, req: &Json) -> Result<Json> {
        let resp = self.call(req)?;
        if resp.get("ok").and_then(|j| j.as_bool()) != Some(true) {
            return Err(anyhow::anyhow!(
                "server error: {}",
                resp.req_str("error").unwrap_or("unknown")
            ));
        }
        Ok(resp)
    }

    /// Generate exactly `n` samples; deterministic in `(model, n, seed)`.
    /// `seed` must be < 2^53 (it crosses the wire as a JSON number).
    pub fn generate(&mut self, model: &str, n: usize, seed: u64) -> Result<Vec<f32>> {
        let req = Json::obj(vec![
            ("op", Json::Str("generate".into())),
            ("model", Json::Str(model.into())),
            ("n", Json::Num(n as f64)),
            ("seed", Json::Num(seed as f64)),
        ]);
        self.checked(&req)?.req("images")?.to_f32s()
    }

    /// `generate` with a per-request budget: the server sheds the
    /// request with `deadline_exceeded` if `deadline_ms` elapses before
    /// its rows are ready.
    pub fn generate_with_deadline(
        &mut self,
        model: &str,
        n: usize,
        seed: u64,
        deadline_ms: u64,
    ) -> Result<Vec<f32>> {
        let req = Json::obj(vec![
            ("op", Json::Str("generate".into())),
            ("model", Json::Str(model.into())),
            ("n", Json::Num(n as f64)),
            ("seed", Json::Num(seed as f64)),
            ("deadline_ms", Json::Int(deadline_ms as i128)),
        ]);
        self.checked(&req)?.req("images")?.to_f32s()
    }

    /// `generate`, retrying retryable typed errors under `policy`.
    /// Determinism makes this safe: a retried request returns bits
    /// identical to what the first attempt would have.
    pub fn generate_with_retry(
        &mut self,
        model: &str,
        n: usize,
        seed: u64,
        policy: RetryPolicy,
    ) -> Result<Vec<f32>> {
        let req = Json::obj(vec![
            ("op", Json::Str("generate".into())),
            ("model", Json::Str(model.into())),
            ("n", Json::Num(n as f64)),
            ("seed", Json::Num(seed as f64)),
        ]);
        self.call_with_retry(&req, policy)?.req("images")?.to_f32s()
    }

    /// Reverse-ODE encode: images (flat `[n, d]`) → latents.
    pub fn encode(&mut self, model: &str, imgs: &[f32]) -> Result<Vec<f32>> {
        let req = Json::obj(vec![
            ("op", Json::Str("encode".into())),
            ("model", Json::Str(model.into())),
            ("images", Json::from_f32s(imgs)),
        ]);
        self.checked(&req)?.req("latents")?.to_f32s()
    }

    /// Server counters (`requests`/`batches`/`samples`/`encodes`/
    /// `errors`/`shed`/`worker_respawns`/`conn_drops`/`queue_depth`)
    /// plus the memory gauges: `resident_bytes` (packed model bytes held
    /// by the native engines) and `workspace_bytes` (high-water scratch
    /// across every worker's reusable arenas). Values are integer-exact
    /// ([`Json::Int`]).
    pub fn stats(&mut self) -> Result<Json> {
        self.checked(&Json::obj(vec![("op", Json::Str("stats".into()))]))
    }

    /// Full metrics snapshot. `format` is `"prometheus"` (reply carries
    /// `content_type` + text-format `body`) or `"json"` (reply carries a
    /// structured `metrics` object).
    pub fn metrics(&mut self, format: &str) -> Result<Json> {
        self.checked(&Json::obj(vec![
            ("op", Json::Str("metrics".into())),
            ("format", Json::Str(format.into())),
        ]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::errors::ErrClass;
    use crate::quant::QuantMethod;
    use std::collections::BTreeMap;

    /// An explicit `--engine lut`/`lut2` on an unpackable model must
    /// surface the packing error; `auto` falls back to the reference.
    #[test]
    fn explicit_lut_choice_errors_on_unpackable_model() {
        let spec = ModelSpec::default_spec();
        let theta = spec.init_theta(&mut Pcg64::seed(11));
        // 9-bit codes exceed the LUT engines' 1..=8 packing range
        let qm = crate::quant::quantize_model(&spec, &theta, QuantMethod::Uniform, 9);
        let v = Variant::Quantized(qm);
        for kind in [EngineKind::Lut, EngineKind::Lut2] {
            let got = resolve_engine(Some(kind), false, &v, &spec, crate::engine::Pool::serial());
            let err = got.err().expect("explicit unpackable choice must error");
            assert!(format!("{err:#}").contains("1..=8"), "unexpected: {err:#}");
        }
        // auto keeps the serve-correct fallback
        let auto = resolve_engine(None, false, &v, &spec, crate::engine::Pool::serial())
            .unwrap()
            .expect("auto resolves a native engine");
        assert_eq!(auto.name(), "cpu-ref");
    }

    /// A dead worker (dropped queue receiver) must report the retryable
    /// `worker_panic` class — never masquerade as a deadline timeout —
    /// and a deadline on a silent worker must cut the reply wait from
    /// the historical 600s to the request's own budget.
    #[test]
    fn submit_distinguishes_dead_worker_from_deadline_timeout() {
        let stats = Metrics::new();
        let lifecycle = Lifecycle::new(1);
        let mut submitters = BTreeMap::new();
        let (dead_tx, dead_rx) = mpsc::sync_channel::<GenRequest>(1);
        drop(dead_rx);
        submitters.insert("dead".to_string(), dead_tx);
        let (mute_tx, _mute_rx) = mpsc::sync_channel::<GenRequest>(1);
        submitters.insert("mute".to_string(), mute_tx);

        let err = submit(
            &submitters,
            &lifecycle,
            &stats,
            "dead",
            Work::Generate { n: 1, seed: 0 },
            None,
        )
        .unwrap_err();
        assert_eq!(err.class, ErrClass::WorkerPanic, "dead worker: {err}");
        assert!(err.to_string().contains("is gone"));

        let t0 = Instant::now();
        let err = submit(
            &submitters,
            &lifecycle,
            &stats,
            "mute",
            Work::Generate { n: 1, seed: 0 },
            Some(Instant::now() + Duration::from_millis(30)),
        )
        .unwrap_err();
        assert_eq!(err.class, ErrClass::DeadlineExceeded, "mute worker: {err}");
        assert!(
            t0.elapsed() < Duration::from_secs(30),
            "deadline must bound the wait (waited {:?})",
            t0.elapsed()
        );

        let err = submit(
            &submitters,
            &lifecycle,
            &stats,
            "nope",
            Work::Generate { n: 1, seed: 0 },
            None,
        )
        .unwrap_err();
        assert_eq!(err.class, ErrClass::UnknownModel);
    }

    /// A full variant queue sheds with the typed `overloaded` error (and
    /// its retry hint) instead of blocking the submitter; a draining
    /// lifecycle refuses admission with `shutting_down`.
    #[test]
    fn full_queue_sheds_and_drain_gates_admission() {
        let stats = Metrics::new();
        let lifecycle = Lifecycle::new(1);
        let mut submitters = BTreeMap::new();
        let (mute_tx, _mute_rx) = mpsc::sync_channel::<GenRequest>(1);
        submitters.insert("mute".to_string(), mute_tx);

        // occupy the single queue slot (nobody ever receives it); the
        // short deadline bounds this call's own reply wait
        let err = submit(
            &submitters,
            &lifecycle,
            &stats,
            "mute",
            Work::Generate { n: 1, seed: 0 },
            Some(Instant::now() + Duration::from_millis(10)),
        )
        .unwrap_err();
        assert_eq!(err.class, ErrClass::DeadlineExceeded);

        // the slot is still held by the unreceived request -> shed
        let err = submit(
            &submitters,
            &lifecycle,
            &stats,
            "mute",
            Work::Generate { n: 1, seed: 1 },
            None,
        )
        .unwrap_err();
        assert_eq!(err.class, ErrClass::Overloaded);
        assert_eq!(err.retry_after_ms, Some(SHED_RETRY_MS));
        assert!(err.class.retryable());
        assert_eq!(stats.shed.get(), 1);

        // draining: admission is refused before touching the queue
        lifecycle.begin_drain();
        let err = submit(
            &submitters,
            &lifecycle,
            &stats,
            "mute",
            Work::Generate { n: 1, seed: 2 },
            None,
        )
        .unwrap_err();
        assert_eq!(err.class, ErrClass::ShuttingDown);
        assert_eq!(stats.shed.get(), 1, "drain refusal is not a shed");
    }

    /// Lifecycle transitions are one-way and `begin_drain` never
    /// regresses a stopped server.
    #[test]
    fn lifecycle_transitions_are_one_way() {
        let lc = Lifecycle::new(2);
        assert_eq!(lc.state(), LifeState::Running);
        assert_eq!(lc.workers_live(), 2);
        lc.begin_drain();
        assert_eq!(lc.state(), LifeState::Draining);
        lc.stop_hard();
        assert_eq!(lc.state(), LifeState::Stopped);
        lc.begin_drain();
        assert_eq!(lc.state(), LifeState::Stopped, "drain must not regress");
        lc.worker_exited();
        lc.worker_exited();
        assert_eq!(lc.workers_live(), 0);
    }
}
