//! Serving layer: TCP, JSON-lines protocol, dynamic batching per model
//! variant. Python never runs here — quantized sampling executes through
//! the compiled HLO (or the CPU reference when artifacts are absent).
//!
//! Protocol (one JSON object per line):
//!   -> {"op": "generate", "model": "ot4", "n": 2, "seed": 7, "steps": 16}
//!   <- {"ok": true, "model": "ot4", "n": 2, "d": 768, "images": [...]}
//!   -> {"op": "models"}
//!   <- {"ok": true, "models": ["fp32", "ot2", ...]}
//!   -> {"op": "ping"} / {"op": "shutdown"}

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread;
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use crate::coordinator::batcher::{distribute, Batcher, GenRequest};
use crate::coordinator::registry::{Registry, Variant};
use crate::engine::{CpuRefEngine, Engine, EngineKind, LutEngine, LutV2Engine, Tuner};
use crate::flow::sampler::{self, EngineStep, HloQStep, HloStep};
use crate::model::spec::ModelSpec;
use crate::runtime::SharedArtifacts;
use crate::util::json::{parse, Json};
use crate::util::rng::Pcg64;

/// Server configuration.
pub struct ServerConfig {
    pub addr: String,
    pub steps: usize,
    pub linger: Duration,
    /// Execution backend; `None` = auto (compiled HLO when artifacts are
    /// loaded, else the native LUT engine for quantized variants and the
    /// CPU reference for fp32).
    pub engine: Option<EngineKind>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:7878".to_string(),
            steps: 16,
            linger: Duration::from_millis(5),
            engine: None,
        }
    }
}

/// Resolve the configured engine for one variant. `None` means "run the
/// batch through the compiled-HLO artifact sessions" (the `Runtime`
/// kind); `Some(engine)` is a native in-process backend. Built once per
/// serving worker, so LUT packing happens at startup, never per request.
fn resolve_engine<'a>(
    choice: Option<EngineKind>,
    has_art: bool,
    variant: &'a Variant,
    spec: &'a ModelSpec,
    pool: crate::engine::Pool,
) -> Option<Box<dyn Engine + 'a>> {
    let kind = choice.unwrap_or(if has_art {
        EngineKind::Runtime
    } else if matches!(variant, Variant::Quantized(_)) {
        EngineKind::Lut
    } else {
        EngineKind::CpuRef
    });
    match (kind, variant) {
        (EngineKind::Runtime, _) if has_art => None,
        // runtime resolved by auto without artifacts cannot happen (auto
        // never picks it then); an *explicit* runtime choice without
        // artifacts is rejected up front in `serve`. Defensive fallback:
        (EngineKind::Runtime, _) => resolve_engine(None, false, variant, spec, pool),
        (EngineKind::Lut, Variant::Quantized(qm)) => match LutEngine::with_pool(qm, pool) {
            Ok(e) => Some(Box::new(e)),
            // unpackable model (e.g. >8 bits): serve correct, just slower
            Err(_) => Some(Box::new(CpuRefEngine::quantized(qm))),
        },
        // v2: measured autotuning warms up on the first batches per GEMM
        // shape, then dispatches cached tile plans
        (EngineKind::Lut2, Variant::Quantized(qm)) => {
            match LutV2Engine::with_config(qm, pool, Tuner::measured()) {
                Ok(e) => Some(Box::new(e)),
                Err(_) => Some(Box::new(CpuRefEngine::quantized(qm))),
            }
        }
        // the LUT engines are quantized-only; fp32 serves via the reference
        (EngineKind::Lut | EngineKind::Lut2, Variant::FullPrecision(theta)) => {
            Some(Box::new(CpuRefEngine::fp32(spec, theta)))
        }
        (EngineKind::CpuRef, Variant::FullPrecision(theta)) => {
            Some(Box::new(CpuRefEngine::fp32(spec, theta)))
        }
        (EngineKind::CpuRef, Variant::Quantized(qm)) => {
            Some(Box::new(CpuRefEngine::quantized(qm)))
        }
    }
}

/// Metrics counters exposed for the bench harness.
#[derive(Default)]
pub struct ServerStats {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    pub samples: AtomicU64,
}

/// The running server handle.
pub struct Server {
    pub addr: std::net::SocketAddr,
    pub stats: Arc<ServerStats>,
    shutdown: Arc<AtomicBool>,
    threads: Vec<thread::JoinHandle<()>>,
}

impl Server {
    pub fn stop(mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // nudge the acceptor with a dummy connection
        let _ = TcpStream::connect(self.addr);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// Launch the server: one acceptor thread, one batching worker per model
/// variant. `registry` and the optional artifact set are shared read-only.
pub fn serve(
    registry: Arc<Registry>,
    art: Option<Arc<SharedArtifacts>>,
    cfg: ServerConfig,
) -> Result<Server> {
    if cfg.engine == Some(EngineKind::Runtime) && art.is_none() {
        bail!(
            "--engine runtime needs compiled artifacts \
             (build with --features pjrt and run `make artifacts`)"
        );
    }
    let listener = TcpListener::bind(&cfg.addr)?;
    let addr = listener.local_addr()?;
    let shutdown = Arc::new(AtomicBool::new(false));
    let stats = Arc::new(ServerStats::default());
    let mut threads = Vec::new();

    // one batcher + worker per variant
    let batch_size = art
        .as_ref()
        .map(|a| a.with(|art| art.b_sample))
        .unwrap_or(16);
    let mut submitters = std::collections::BTreeMap::new();
    for name in registry.names() {
        let batcher = Batcher::new(batch_size, cfg.linger);
        submitters.insert(name.clone(), batcher.submitter());
        let reg = registry.clone();
        let art = art.clone();
        let stats = stats.clone();
        let sd = shutdown.clone();
        let steps = cfg.steps;
        let engine = cfg.engine;
        threads.push(thread::spawn(move || {
            worker_loop(&name, reg, art, batcher, stats, sd, steps, batch_size, engine)
        }));
    }
    let submitters = Arc::new(submitters);

    // acceptor
    {
        let sd = shutdown.clone();
        let stats = stats.clone();
        let reg = registry.clone();
        let subs = submitters.clone();
        threads.push(thread::spawn(move || {
            for stream in listener.incoming() {
                if sd.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = stream else { continue };
                let stats = stats.clone();
                let reg = reg.clone();
                let subs = subs.clone();
                let sd2 = sd.clone();
                thread::spawn(move || {
                    let _ = handle_conn(stream, &reg, &subs, &stats, &sd2);
                });
            }
        }));
    }

    Ok(Server {
        addr,
        stats,
        shutdown,
        threads,
    })
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    name: &str,
    registry: Arc<Registry>,
    art: Option<Arc<SharedArtifacts>>,
    batcher: Batcher,
    stats: Arc<ServerStats>,
    shutdown: Arc<AtomicBool>,
    steps: usize,
    batch_size: usize,
    engine_choice: Option<EngineKind>,
) {
    let variant = match registry.get(name) {
        Ok(v) => v,
        Err(_) => return,
    };
    // resolve + build the execution engine once per worker: for the LUT
    // engine this packs the codes at startup, so the request path only
    // ever touches the packed representation. Each worker's pool spans
    // all cores — a lone hot variant should saturate the machine, and
    // when several variants batch at once the scoped worker threads
    // simply time-share.
    let pool = crate::engine::Pool::new(0);
    let engine = resolve_engine(engine_choice, art.is_some(), variant, &registry.spec, pool);
    let d = registry.spec.d;
    while !shutdown.load(Ordering::SeqCst) {
        let Some(batch) = batcher.next_batch() else {
            // all submitters dropped -> server is shutting down
            return;
        };
        if batch.requests.is_empty() {
            continue; // wait timeout: loop to re-check the shutdown flag
        }
        let total = batch.total.max(1);
        let padded = batch.padded_total(batch_size);
        // mix per-request seeds into the noise
        let seed = batch
            .requests
            .iter()
            .fold(0x5eed_u64, |acc, r| acc ^ r.seed.wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Pcg64::seed(seed);
        let x0: Vec<f32> = (0..padded * d).map(|_| rng.normal_f32(0.0, 1.0)).collect();

        let imgs = run_generate(
            engine.as_deref(),
            variant,
            art.as_deref(),
            &x0,
            steps,
            batch_size,
            d,
        );
        match imgs {
            Ok(imgs) => {
                stats.batches.fetch_add(1, Ordering::Relaxed);
                stats
                    .samples
                    .fetch_add(total as u64, Ordering::Relaxed);
                distribute(batch, &imgs, d);
            }
            Err(_) => {
                // reply with empty payloads so clients don't hang
                distribute(batch, &[], d);
            }
        }
    }
}

/// Generate one padded super-batch. `engine = Some(..)` runs the native
/// in-process backend through the [`EngineStep`] adapter; `engine = None`
/// is the `Runtime` kind and drives the compiled-HLO sessions.
#[allow(clippy::too_many_arguments)]
fn run_generate(
    engine: Option<&dyn Engine>,
    variant: &Variant,
    art: Option<&SharedArtifacts>,
    x0: &[f32],
    steps: usize,
    batch_size: usize,
    d: usize,
) -> Result<Vec<f32>> {
    let mut out = Vec::with_capacity(x0.len());
    for chunk in x0.chunks(batch_size * d) {
        let imgs = match engine {
            Some(eng) => {
                let mut be = EngineStep { engine: eng };
                sampler::generate_from(&mut be, chunk, steps)?
            }
            None => {
                let sa = art.ok_or_else(|| anyhow!("runtime engine requires artifacts"))?;
                match variant {
                    Variant::FullPrecision(theta) => sa.with(|a| {
                        let mut be = HloStep { art: a, theta };
                        sampler::generate_from(&mut be, chunk, steps)
                    })?,
                    Variant::Quantized(qm) => sa.with(|a| {
                        let mut be = HloQStep::new(a, qm);
                        sampler::generate_from(&mut be, chunk, steps)
                    })?,
                }
            }
        };
        out.extend(imgs);
    }
    Ok(out)
}

fn handle_conn(
    stream: TcpStream,
    registry: &Registry,
    submitters: &std::collections::BTreeMap<String, mpsc::Sender<GenRequest>>,
    stats: &ServerStats,
    shutdown: &AtomicBool,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(());
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let reply = match handle_request(trimmed, registry, submitters, stats, shutdown) {
            Ok(j) => j,
            Err(e) => Json::obj(vec![
                ("ok", Json::Bool(false)),
                ("error", Json::Str(e.to_string())),
            ]),
        };
        writer.write_all(reply.to_string().as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
        if shutdown.load(Ordering::SeqCst) {
            return Ok(());
        }
    }
}

fn handle_request(
    line: &str,
    registry: &Registry,
    submitters: &std::collections::BTreeMap<String, mpsc::Sender<GenRequest>>,
    stats: &ServerStats,
    shutdown: &AtomicBool,
) -> Result<Json> {
    let req = parse(line)?;
    stats.requests.fetch_add(1, Ordering::Relaxed);
    match req.req_str("op")? {
        "ping" => Ok(Json::obj(vec![("ok", Json::Bool(true))])),
        "models" => Ok(Json::obj(vec![
            ("ok", Json::Bool(true)),
            (
                "models",
                Json::Arr(registry.names().into_iter().map(Json::Str).collect()),
            ),
        ])),
        "shutdown" => {
            shutdown.store(true, Ordering::SeqCst);
            Ok(Json::obj(vec![("ok", Json::Bool(true))]))
        }
        "generate" => {
            let model = req.req_str("model")?;
            let n = req.req_usize("n")?.clamp(1, 256);
            let seed = req.get("seed").and_then(|j| j.as_f64()).unwrap_or(0.0) as u64;
            let tx = submitters
                .get(model)
                .ok_or_else(|| anyhow!("unknown model '{model}'"))?;
            let (rtx, rrx) = mpsc::channel();
            tx.send(GenRequest {
                n,
                seed,
                reply: rtx,
            })
            .map_err(|_| anyhow!("worker for '{model}' is gone"))?;
            let imgs = rrx
                .recv_timeout(Duration::from_secs(600))
                .map_err(|_| anyhow!("generation timed out"))?;
            if imgs.is_empty() {
                return Err(anyhow!("generation failed"));
            }
            let d = registry.spec.d;
            Ok(Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("model", Json::Str(model.to_string())),
                ("n", Json::Num((imgs.len() / d) as f64)),
                ("d", Json::Num(d as f64)),
                ("images", Json::from_f32s(&imgs)),
            ]))
        }
        other => Err(anyhow!("unknown op '{other}'")),
    }
}

/// Minimal blocking client (used by examples, benches and tests).
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        Ok(Self {
            reader: BufReader::new(stream.try_clone()?),
            writer: stream,
        })
    }

    pub fn call(&mut self, req: &Json) -> Result<Json> {
        self.writer.write_all(req.to_string().as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        parse(line.trim())
    }

    pub fn generate(&mut self, model: &str, n: usize, seed: u64) -> Result<Vec<f32>> {
        let req = Json::obj(vec![
            ("op", Json::Str("generate".into())),
            ("model", Json::Str(model.into())),
            ("n", Json::Num(n as f64)),
            ("seed", Json::Num(seed as f64)),
        ]);
        let resp = self.call(&req)?;
        if resp.get("ok").and_then(|j| j.as_bool()) != Some(true) {
            return Err(anyhow!(
                "server error: {}",
                resp.req_str("error").unwrap_or("unknown")
            ));
        }
        resp.req("images")?.to_f32s()
    }
}
