//! Serving layer: TCP, JSON-lines protocol, dynamic batching per model
//! variant. Python never runs here — quantized sampling executes through
//! the compiled HLO (or the CPU reference when artifacts are absent).
//!
//! Protocol (one JSON object per line; request lines are capped at
//! [`MAX_LINE`] bytes, sized to the largest legal `encode` payload;
//! `seed` is a JSON number, so it must stay below 2^53 — the f64
//! integer-precision limit — to round-trip exactly):
//!   -> {"op": "generate", "model": "ot4", "n": 2, "seed": 7}
//!   <- {"ok": true, "model": "ot4", "n": 2, "d": 768, "images": [...]}
//!   -> {"op": "encode", "model": "ot4", "images": [... n*d floats ...]}
//!   <- {"ok": true, "model": "ot4", "n": 2, "d": 768, "latents": [...]}
//!   -> {"op": "stats"}
//!   <- {"ok": true, "requests": 9, "batches": 3, "samples": 18,
//!       "encodes": 2, "errors": 0, "queue_depth": 0,
//!       "resident_bytes": 5443584, "workspace_bytes": 1245184}
//!   -> {"op": "metrics"}                     (or "format": "json")
//!   <- {"ok": true, "content_type": "text/plain; version=0.0.4",
//!       "body": "# HELP fmq_server_requests_total ...\n..."}
//!   -> {"op": "models"}
//!   <- {"ok": true, "models": ["fp32", "ot2", ...]}
//!   -> {"op": "ping"} / {"op": "shutdown"}
//!
//! Counter/gauge values in `stats` replies are integer-exact
//! ([`Json::Int`] — no f64 2^53 precision cliff for byte gauges). The
//! richer `metrics` op exposes the full [`crate::obs`] registry —
//! request-latency / queue-wait / per-ODE-step histograms with
//! p50/p95/p99 estimates — as Prometheus text-format or JSON; the
//! catalogue is documented in `docs/OBSERVABILITY.md`.
//!
//! Serving contracts:
//!
//! * **Determinism.** A `generate` reply is a pure function of
//!   `(model, n, seed, steps)`: the request's noise comes from its own
//!   `Pcg64::seed(seed)` stream (see `coordinator/batcher.rs`), and the
//!   native engines are row-independent and bit-stable across batch
//!   shapes, so co-batched traffic, request slicing and queue position
//!   never change a single bit of the result. Under the `cpu-ref`/`lut`
//!   engines (the no-artifact auto default) the reply is additionally
//!   bit-identical to running `flow::sampler::generate` locally with
//!   the same seed; `lut2`/`runtime` replies are equally deterministic
//!   but match the reference sampler only within the 1e-5
//!   engine-equivalence harness (v2 re-associates sums).
//! * **Exact n.** Requests up to [`MAX_N`] samples are sliced across as
//!   many super-batches as needed (slot accounting in the batcher) and
//!   reassembled in order — never truncated to the model batch.
//! * **Backpressure.** Each variant's queue is a bounded channel
//!   (`ServerConfig::queue_cap`); connection handlers block on submit
//!   once it fills instead of growing server memory.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, SyncSender};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use crate::coordinator::batcher::{Batcher, GenRequest, Work};
use crate::coordinator::registry::{Registry, Variant};
use crate::engine::{CpuRefEngine, Engine, EngineKind, LutEngine, LutV2Engine, Tuner};
use crate::flow::sampler::{self, Direction, EngineStep, HloQStep, HloStep};
use crate::model::spec::ModelSpec;
use crate::obs::{self, Metrics, Span};
use crate::runtime::SharedArtifacts;
use crate::util::json::{parse, Json};

/// Protocol cap on samples per request (`generate` n, `encode` rows).
pub const MAX_N: usize = 256;

/// Request-line byte cap: a runaway (or malicious) client cannot grow
/// server memory past this per connection. Sized so the largest legal
/// `encode` request (MAX_N × d floats in decimal) still fits.
pub const MAX_LINE: u64 = 16 * 1024 * 1024;

/// Server configuration.
pub struct ServerConfig {
    pub addr: String,
    pub steps: usize,
    pub linger: Duration,
    /// Execution backend; `None` = auto (compiled HLO when artifacts are
    /// loaded, else the native LUT engine for quantized variants and the
    /// CPU reference for fp32).
    pub engine: Option<EngineKind>,
    /// Bound on queued requests per model variant (backpressure: submits
    /// block once the queue is full).
    pub queue_cap: usize,
    /// Write a Prometheus text-format metrics snapshot to this path when
    /// the server stops (the `--metrics-dump` flag), so benches and CI
    /// capture latency trajectories as artifacts.
    pub metrics_dump: Option<PathBuf>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:7878".to_string(),
            steps: 16,
            linger: Duration::from_millis(5),
            engine: None,
            queue_cap: 256,
            metrics_dump: None,
        }
    }
}

/// Resolve the configured engine for one variant. `None` means "run the
/// batch through the compiled-HLO artifact sessions" (the `Runtime`
/// kind); `Some(engine)` is a native in-process backend. Built once per
/// serving worker, so LUT packing happens at startup, never per request.
///
/// An *explicit* `--engine lut`/`lut2` choice that fails to pack is an
/// error (the operator asked for a specific backend; silently serving
/// through `cpu-ref` would misreport every benchmark run against it).
/// Only `auto` (no choice) falls back to the reference on packing
/// failure, because there it is a selection default, not an override.
fn resolve_engine<'a>(
    choice: Option<EngineKind>,
    has_art: bool,
    variant: &'a Variant,
    spec: &'a ModelSpec,
    pool: crate::engine::Pool,
) -> Result<Option<Box<dyn Engine + 'a>>> {
    let explicit = choice.is_some();
    let kind = choice.unwrap_or(if has_art {
        EngineKind::Runtime
    } else if matches!(variant, Variant::Quantized(_)) {
        EngineKind::Lut
    } else {
        EngineKind::CpuRef
    });
    match (kind, variant) {
        (EngineKind::Runtime, _) if has_art => Ok(None),
        // runtime resolved by auto without artifacts cannot happen (auto
        // never picks it then); an *explicit* runtime choice without
        // artifacts is rejected up front in `serve`. Defensive fallback:
        (EngineKind::Runtime, _) => resolve_engine(None, false, variant, spec, pool),
        (EngineKind::Lut, Variant::Quantized(qm)) => match LutEngine::with_pool(qm, pool) {
            Ok(e) => Ok(Some(Box::new(e))),
            Err(e) if explicit => Err(e.context("--engine lut")),
            // auto-picked on an unpackable model (e.g. >8 bits): serve
            // correct, just slower
            Err(_) => Ok(Some(Box::new(CpuRefEngine::quantized(qm)))),
        },
        // v2: measured autotuning warms up on the first batches per GEMM
        // shape, then dispatches cached tile plans
        (EngineKind::Lut2, Variant::Quantized(qm)) => {
            match LutV2Engine::with_config(qm, pool, Tuner::measured()) {
                Ok(e) => Ok(Some(Box::new(e))),
                Err(e) if explicit => Err(e.context("--engine lut2")),
                Err(_) => Ok(Some(Box::new(CpuRefEngine::quantized(qm)))),
            }
        }
        // the LUT engines are quantized-only; fp32 serves via the reference
        (EngineKind::Lut | EngineKind::Lut2, Variant::FullPrecision(theta)) => {
            Ok(Some(Box::new(CpuRefEngine::fp32(spec, theta))))
        }
        (EngineKind::CpuRef, Variant::FullPrecision(theta)) => {
            Ok(Some(Box::new(CpuRefEngine::fp32(spec, theta))))
        }
        (EngineKind::CpuRef, Variant::Quantized(qm)) => {
            Ok(Some(Box::new(CpuRefEngine::quantized(qm))))
        }
    }
}

/// The running server handle. `stats` is the per-server
/// [`crate::obs::Metrics`] registry (the old ad-hoc `ServerStats`
/// counters live there now, plus the lifecycle histograms).
pub struct Server {
    pub addr: std::net::SocketAddr,
    pub stats: Arc<Metrics>,
    shutdown: Arc<AtomicBool>,
    threads: Vec<thread::JoinHandle<()>>,
    metrics_dump: Option<PathBuf>,
}

impl Server {
    pub fn stop(mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // nudge the acceptor with a dummy connection
        let _ = TcpStream::connect(self.addr);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
        // final snapshot after every worker has drained: the artifact CI
        // and benches pick up (`--metrics-dump`)
        if let Some(path) = &self.metrics_dump {
            if let Err(e) = std::fs::write(path, obs::render_prometheus(&self.stats)) {
                eprintln!("metrics dump to {} failed: {e}", path.display());
            }
        }
    }

    /// Whether a client issued the `shutdown` op (or `stop` began). The
    /// CLI's serve loop polls this to exit and write the metrics dump.
    pub fn shutdown_requested(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }
}

/// Launch the server: one acceptor thread, one batching worker per model
/// variant. `registry` and the optional artifact set are shared read-only.
pub fn serve(
    registry: Arc<Registry>,
    art: Option<Arc<SharedArtifacts>>,
    cfg: ServerConfig,
) -> Result<Server> {
    if cfg.engine == Some(EngineKind::Runtime) && art.is_none() {
        bail!(
            "--engine runtime needs compiled artifacts \
             (build with --features pjrt and run `make artifacts`)"
        );
    }
    let listener = TcpListener::bind(&cfg.addr)?;
    let addr = listener.local_addr()?;
    let shutdown = Arc::new(AtomicBool::new(false));
    let stats = Arc::new(Metrics::new());
    let mut threads = Vec::new();

    // one batcher + worker per variant
    let batch_size = art
        .as_ref()
        .map(|a| a.with(|art| art.b_sample))
        .unwrap_or(16);
    let d = registry.spec.d;
    let mut submitters = std::collections::BTreeMap::new();
    for name in registry.names() {
        let batcher = Batcher::new(batch_size, cfg.linger, d, cfg.queue_cap, stats.clone());
        submitters.insert(name.clone(), batcher.submitter());
        let reg = registry.clone();
        let art = art.clone();
        let stats = stats.clone();
        let sd = shutdown.clone();
        let steps = cfg.steps;
        let engine = cfg.engine;
        threads.push(thread::spawn(move || {
            worker_loop(&name, reg, art, batcher, stats, sd, steps, batch_size, engine)
        }));
    }
    let submitters = Arc::new(submitters);

    // acceptor
    {
        let sd = shutdown.clone();
        let stats = stats.clone();
        let reg = registry.clone();
        let subs = submitters.clone();
        threads.push(thread::spawn(move || {
            for stream in listener.incoming() {
                if sd.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = stream else { continue };
                let stats = stats.clone();
                let reg = reg.clone();
                let subs = subs.clone();
                let sd2 = sd.clone();
                thread::spawn(move || {
                    let _ = handle_conn(stream, &reg, &subs, &stats, &sd2);
                });
            }
        }));
    }

    Ok(Server {
        addr,
        stats,
        shutdown,
        threads,
        metrics_dump: cfg.metrics_dump,
    })
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    name: &str,
    registry: Arc<Registry>,
    art: Option<Arc<SharedArtifacts>>,
    mut batcher: Batcher,
    stats: Arc<Metrics>,
    shutdown: Arc<AtomicBool>,
    steps: usize,
    batch_size: usize,
    engine_choice: Option<EngineKind>,
) {
    let variant = match registry.get(name) {
        Ok(v) => v,
        Err(_) => return,
    };
    // resolve + build the execution engine once per worker: for the LUT
    // engine this packs the codes at startup, so the request path only
    // ever touches the packed representation. Each worker's pool spans
    // all cores — a lone hot variant should saturate the machine, and
    // when several variants batch at once the scoped worker threads
    // simply time-share.
    let pool = crate::engine::Pool::new(0);
    let resolved = resolve_engine(engine_choice, art.is_some(), variant, &registry.spec, pool);
    let engine = match resolved {
        Ok(e) => e,
        Err(err) => {
            // an explicit engine choice this variant cannot satisfy:
            // stay up and fail each request with the build error instead
            // of silently serving through a different backend
            let msg = format!("engine init failed for '{name}': {err:#}");
            while !shutdown.load(Ordering::SeqCst) {
                let Some(batch) = batcher.next_batch() else { return };
                batcher.complete(batch, Err(&msg));
            }
            return;
        }
    };
    let d = registry.spec.d;
    // one step adapter per worker, built once and reused across every
    // super-batch: its workspace arena (and the per-step time-embedding
    // cache inside it) persists, so after the first batch of a given
    // step grid the velocity hot path performs zero heap allocations
    let mut native = engine.as_deref().map(EngineStep::new);
    if let Some(e) = engine.as_deref() {
        stats.resident_bytes.add(e.resident_bytes() as i64);
    }
    let mut gauge = 0i64; // this worker's last contribution to queue_depth
    let mut ws_gauge = 0i64; // last contribution to workspace_bytes
    while !shutdown.load(Ordering::SeqCst) {
        let Some(batch) = batcher.next_batch() else {
            // all submitters dropped -> server is shutting down
            break;
        };
        if batch.is_empty() {
            continue; // wait timeout: loop to re-check the shutdown flag
        }
        let run_span = Span::begin();
        let res = run_rows(
            native.as_mut(),
            variant,
            art.as_deref(),
            &batch.x0,
            batch.dir,
            steps,
            batch_size,
            d,
        );
        run_span.end(&stats.batch_run_ns);
        match res {
            Ok(rows) => {
                stats.batches.inc();
                let counter = match batch.dir {
                    Direction::Forward => &stats.samples,
                    Direction::Reverse => &stats.encodes,
                };
                counter.add(batch.rows as u64);
                batcher.complete(batch, Ok(&rows));
            }
            Err(e) => batcher.complete(batch, Err(&e.to_string())),
        }
        // export backlog as ONE signed delta per iteration so the gauge
        // sums correctly over concurrent workers and can never wrap: a
        // reader observes depth transitions atomically (no fetch_sub/
        // fetch_add window where another worker's export interleaves)
        let depth = batcher.backlog_rows() as i64;
        stats.queue_depth.add(depth - gauge);
        gauge = depth;
        // arena high-water, same delta scheme (monotone per worker)
        let hw = native
            .as_ref()
            .map(|be| be.workspace_bytes() + be.engine().workspace_bytes())
            .unwrap_or(0) as i64;
        stats.workspace_bytes.add(hw - ws_gauge);
        ws_gauge = hw;
    }
    stats.queue_depth.add(-gauge);
}

/// Integrate one super-batch in the given direction. `native = Some(..)`
/// runs the worker's persistent [`EngineStep`] adapter (warm workspace +
/// temb cache) on the exact rows; `native = None` is the `Runtime` kind
/// and drives the compiled-HLO sessions, which are fixed-shape — rows
/// are padded with zeros up to whole model batches and the padding is
/// cut before the batcher reassembles replies (rows are independent
/// through the forward, so padding never changes a real row).
#[allow(clippy::too_many_arguments)]
fn run_rows(
    native: Option<&mut EngineStep>,
    variant: &Variant,
    art: Option<&SharedArtifacts>,
    x0: &[f32],
    dir: Direction,
    steps: usize,
    batch_size: usize,
    d: usize,
) -> Result<Vec<f32>> {
    match native {
        Some(be) => sampler::run_direction(be, x0, dir, steps),
        None => {
            let sa = art.ok_or_else(|| anyhow!("runtime engine requires artifacts"))?;
            let rows = x0.len() / d.max(1);
            let padded = rows.max(1).div_ceil(batch_size.max(1)) * batch_size.max(1);
            let mut xp = x0.to_vec();
            xp.resize(padded * d, 0.0);
            let mut out = Vec::with_capacity(padded * d);
            for chunk in xp.chunks(batch_size.max(1) * d) {
                let imgs = match variant {
                    Variant::FullPrecision(theta) => sa.with(|a| {
                        let mut be = HloStep { art: a, theta };
                        sampler::run_direction(&mut be, chunk, dir, steps)
                    })?,
                    Variant::Quantized(qm) => sa.with(|a| {
                        let mut be = HloQStep::new(a, qm)?;
                        sampler::run_direction(&mut be, chunk, dir, steps)
                    })?,
                };
                out.extend(imgs);
            }
            out.truncate(rows * d);
            Ok(out)
        }
    }
}

fn handle_conn(
    stream: TcpStream,
    registry: &Registry,
    submitters: &std::collections::BTreeMap<String, SyncSender<GenRequest>>,
    stats: &Metrics,
    shutdown: &AtomicBool,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut buf = Vec::new();
    loop {
        buf.clear();
        // cap the request line so a client that never sends '\n' cannot
        // grow server memory without bound; bytes (not read_line) so the
        // limit cannot split a multi-byte character into an io error
        if (&mut reader).take(MAX_LINE).read_until(b'\n', &mut buf)? == 0 {
            return Ok(());
        }
        if buf.len() as u64 >= MAX_LINE && buf.last() != Some(&b'\n') {
            // overlong line: report, then close (the stream cannot be
            // resynchronized mid-line)
            let reply = Json::obj(vec![
                ("ok", Json::Bool(false)),
                (
                    "error",
                    Json::Str(format!("request line exceeds {MAX_LINE} bytes")),
                ),
            ]);
            writer.write_all(reply.to_string().as_bytes())?;
            writer.write_all(b"\n")?;
            writer.flush()?;
            // best-effort drain of what the client already sent before
            // closing: dropping the socket with unread bytes queued makes
            // the kernel RST the connection, which would destroy the
            // error reply before the client can read it
            let _ = writer.shutdown(std::net::Shutdown::Write);
            let _ = writer.set_read_timeout(Some(Duration::from_millis(500)));
            let mut sink = [0u8; 8192];
            let mut drained = 0usize;
            while drained < 4 * MAX_LINE as usize {
                match reader.read(&mut sink) {
                    Ok(0) | Err(_) => break,
                    Ok(k) => drained += k,
                }
            }
            return Ok(());
        }
        // lossy conversion: invalid UTF-8 becomes a JSON parse error
        // reply below instead of dropping the connection
        let line = String::from_utf8_lossy(&buf);
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let reply = match handle_request(trimmed, registry, submitters, stats, shutdown) {
            Ok(j) => j,
            Err(e) => {
                stats.errors.inc();
                Json::obj(vec![
                    ("ok", Json::Bool(false)),
                    ("error", Json::Str(e.to_string())),
                ])
            }
        };
        let ser_span = Span::begin();
        writer.write_all(reply.to_string().as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
        ser_span.end(&stats.reply_serialize_ns);
        if shutdown.load(Ordering::SeqCst) {
            return Ok(());
        }
    }
}

/// Submit one unit of work to a variant's batcher and wait for the
/// reassembled exact-n reply.
fn submit(
    submitters: &std::collections::BTreeMap<String, SyncSender<GenRequest>>,
    model: &str,
    work: Work,
) -> Result<Vec<f32>> {
    let tx = submitters
        .get(model)
        .ok_or_else(|| anyhow!("unknown model '{model}'"))?;
    let (rtx, rrx) = mpsc::channel();
    tx.send(GenRequest { work, reply: rtx })
        .map_err(|_| anyhow!("worker for '{model}' is gone"))?;
    match rrx.recv_timeout(Duration::from_secs(600)) {
        Ok(reply) => reply.map_err(|e| anyhow!(e)),
        Err(mpsc::RecvTimeoutError::Timeout) => Err(anyhow!("generation timed out")),
        // worker died (panic / shutdown race): report that, not a timeout
        Err(mpsc::RecvTimeoutError::Disconnected) => {
            Err(anyhow!("worker for '{model}' is gone"))
        }
    }
}

fn handle_request(
    line: &str,
    registry: &Registry,
    submitters: &std::collections::BTreeMap<String, SyncSender<GenRequest>>,
    stats: &Metrics,
    shutdown: &AtomicBool,
) -> Result<Json> {
    let req = parse(line)?;
    stats.requests.inc();
    match req.req_str("op")? {
        "ping" => Ok(Json::obj(vec![("ok", Json::Bool(true))])),
        "models" => Ok(Json::obj(vec![
            ("ok", Json::Bool(true)),
            (
                "models",
                Json::Arr(registry.names().into_iter().map(Json::Str).collect()),
            ),
        ])),
        // integer-exact ([`Json::Int`]): byte gauges can legitimately
        // exceed 2^53, where an f64 wire value silently rounds
        "stats" => Ok(Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("requests", Json::Int(stats.requests.get() as i128)),
            ("batches", Json::Int(stats.batches.get() as i128)),
            ("samples", Json::Int(stats.samples.get() as i128)),
            ("encodes", Json::Int(stats.encodes.get() as i128)),
            ("errors", Json::Int(stats.errors.get() as i128)),
            ("queue_depth", Json::Int(stats.queue_depth.get() as i128)),
            ("resident_bytes", Json::Int(stats.resident_bytes.get() as i128)),
            ("workspace_bytes", Json::Int(stats.workspace_bytes.get() as i128)),
        ])),
        "metrics" => {
            let format = match req.get("format") {
                None => "prometheus",
                Some(j) => j
                    .as_str()
                    .ok_or_else(|| anyhow!("format must be a string"))?,
            };
            match format {
                "prometheus" => Ok(Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    (
                        "content_type",
                        Json::Str("text/plain; version=0.0.4".to_string()),
                    ),
                    ("body", Json::Str(obs::render_prometheus(stats))),
                ])),
                "json" => Ok(Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    ("metrics", obs::render_json(stats)),
                ])),
                other => Err(anyhow!(
                    "unknown metrics format '{other}' (expected 'prometheus' or 'json')"
                )),
            }
        }
        "shutdown" => {
            shutdown.store(true, Ordering::SeqCst);
            Ok(Json::obj(vec![("ok", Json::Bool(true))]))
        }
        "generate" => {
            let model = req.req_str("model")?;
            let n = req.req_usize("n")?;
            if n == 0 || n > MAX_N {
                bail!("n must be in 1..={MAX_N} (got {n})");
            }
            // strict like n: a coerced seed would silently alias two
            // distinct wire seeds onto one noise stream
            let seed = match req.get("seed") {
                None => 0u64,
                Some(j) => {
                    let s = j
                        .as_u64()
                        .ok_or_else(|| anyhow!("seed must be an integer in 0..2^53"))?;
                    if s >= 9_007_199_254_740_992 {
                        bail!("seed must be an integer in 0..2^53 (got {s})");
                    }
                    s
                }
            };
            let latency = Span::begin();
            let imgs = submit(submitters, model, Work::Generate { n, seed })?;
            latency.end(&stats.request_latency_ns);
            let d = registry.spec.d.max(1);
            Ok(Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("model", Json::Str(model.to_string())),
                ("n", Json::Num((imgs.len() / d) as f64)),
                ("d", Json::Num(d as f64)),
                ("images", Json::from_f32s(&imgs)),
            ]))
        }
        "encode" => {
            let model = req.req_str("model")?;
            let rows = req.req("images")?.to_f32s()?;
            let d = registry.spec.d.max(1);
            if rows.is_empty() || rows.len() % d != 0 {
                bail!(
                    "images must be flat [n, d] with d={d} (got {} values)",
                    rows.len()
                );
            }
            let n = rows.len() / d;
            if n > MAX_N {
                bail!("encode rows must be in 1..={MAX_N} (got {n})");
            }
            let latency = Span::begin();
            let latents = submit(submitters, model, Work::Encode { rows })?;
            latency.end(&stats.request_latency_ns);
            Ok(Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("model", Json::Str(model.to_string())),
                ("n", Json::Num(n as f64)),
                ("d", Json::Num(d as f64)),
                ("latents", Json::from_f32s(&latents)),
            ]))
        }
        other => Err(anyhow!("unknown op '{other}'")),
    }
}

/// Minimal blocking client (used by examples, benches and tests).
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        Ok(Self {
            reader: BufReader::new(stream.try_clone()?),
            writer: stream,
        })
    }

    pub fn call(&mut self, req: &Json) -> Result<Json> {
        self.writer.write_all(req.to_string().as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut line = String::new();
        if self.reader.read_line(&mut line)? == 0 {
            return Err(anyhow!("server closed connection"));
        }
        parse(line.trim())
    }

    fn checked(&mut self, req: &Json) -> Result<Json> {
        let resp = self.call(req)?;
        if resp.get("ok").and_then(|j| j.as_bool()) != Some(true) {
            return Err(anyhow!(
                "server error: {}",
                resp.req_str("error").unwrap_or("unknown")
            ));
        }
        Ok(resp)
    }

    /// Generate exactly `n` samples; deterministic in `(model, n, seed)`.
    /// `seed` must be < 2^53 (it crosses the wire as a JSON number).
    pub fn generate(&mut self, model: &str, n: usize, seed: u64) -> Result<Vec<f32>> {
        let req = Json::obj(vec![
            ("op", Json::Str("generate".into())),
            ("model", Json::Str(model.into())),
            ("n", Json::Num(n as f64)),
            ("seed", Json::Num(seed as f64)),
        ]);
        self.checked(&req)?.req("images")?.to_f32s()
    }

    /// Reverse-ODE encode: images (flat `[n, d]`) → latents.
    pub fn encode(&mut self, model: &str, imgs: &[f32]) -> Result<Vec<f32>> {
        let req = Json::obj(vec![
            ("op", Json::Str("encode".into())),
            ("model", Json::Str(model.into())),
            ("images", Json::from_f32s(imgs)),
        ]);
        self.checked(&req)?.req("latents")?.to_f32s()
    }

    /// Server counters (`requests`/`batches`/`samples`/`encodes`/
    /// `errors`/`queue_depth`) plus the memory gauges: `resident_bytes`
    /// (packed model bytes held by the native engines) and
    /// `workspace_bytes` (high-water scratch across every worker's
    /// reusable arenas). Values are integer-exact ([`Json::Int`]).
    pub fn stats(&mut self) -> Result<Json> {
        self.checked(&Json::obj(vec![("op", Json::Str("stats".into()))]))
    }

    /// Full metrics snapshot. `format` is `"prometheus"` (reply carries
    /// `content_type` + text-format `body`) or `"json"` (reply carries a
    /// structured `metrics` object).
    pub fn metrics(&mut self, format: &str) -> Result<Json> {
        self.checked(&Json::obj(vec![
            ("op", Json::Str("metrics".into())),
            ("format", Json::Str(format.into())),
        ]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::QuantMethod;
    use crate::util::rng::Pcg64;

    /// An explicit `--engine lut`/`lut2` on an unpackable model must
    /// surface the packing error; `auto` falls back to the reference.
    #[test]
    fn explicit_lut_choice_errors_on_unpackable_model() {
        let spec = ModelSpec::default_spec();
        let theta = spec.init_theta(&mut Pcg64::seed(11));
        // 9-bit codes exceed the LUT engines' 1..=8 packing range
        let qm = crate::quant::quantize_model(&spec, &theta, QuantMethod::Uniform, 9);
        let v = Variant::Quantized(qm);
        for kind in [EngineKind::Lut, EngineKind::Lut2] {
            let got = resolve_engine(Some(kind), false, &v, &spec, crate::engine::Pool::serial());
            let err = got.err().expect("explicit unpackable choice must error");
            assert!(format!("{err:#}").contains("1..=8"), "unexpected: {err:#}");
        }
        // auto keeps the serve-correct fallback
        let auto = resolve_engine(None, false, &v, &spec, crate::engine::Pool::serial())
            .unwrap()
            .expect("auto resolves a native engine");
        assert_eq!(auto.name(), "cpu-ref");
    }
}
