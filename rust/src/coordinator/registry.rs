//! Model-variant registry: named quantized variants ("ot4", "uniform8",
//! "fp32") built from one trained theta, resolvable by serving requests.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use crate::model::params::ParamStore;
use crate::model::quantized::QuantizedModel;
use crate::model::spec::ModelSpec;
use crate::quant::{quantize_model, QuantMethod};

/// A servable model variant.
pub enum Variant {
    FullPrecision(ParamStore),
    Quantized(QuantizedModel),
}

impl Variant {
    pub fn describe(&self) -> String {
        match self {
            Variant::FullPrecision(_) => "fp32".to_string(),
            Variant::Quantized(q) => format!("{}{}", q.method.name(), q.bits),
        }
    }
}

/// Registry of variants, keyed by name.
pub struct Registry {
    pub spec: ModelSpec,
    variants: BTreeMap<String, Variant>,
}

impl Registry {
    pub fn new(spec: ModelSpec) -> Self {
        Self {
            spec,
            variants: BTreeMap::new(),
        }
    }

    /// Build a standard fleet from one theta: fp32 + each (method, bits).
    pub fn build_fleet(
        spec: &ModelSpec,
        theta: &ParamStore,
        methods: &[QuantMethod],
        bits: &[u8],
    ) -> Self {
        let mut reg = Self::new(spec.clone());
        reg.insert("fp32", Variant::FullPrecision(theta.clone()));
        for &m in methods {
            for &b in bits {
                let qm = quantize_model(spec, theta, m, b);
                reg.insert(&format!("{}{}", m.name(), b), Variant::Quantized(qm));
            }
        }
        reg
    }

    pub fn insert(&mut self, name: &str, v: Variant) {
        self.variants.insert(name.to_string(), v);
    }

    pub fn get(&self, name: &str) -> Result<&Variant> {
        self.variants
            .get(name)
            .ok_or_else(|| anyhow!("unknown model '{name}'; have: {:?}", self.names()))
    }

    pub fn names(&self) -> Vec<String> {
        self.variants.keys().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.variants.len()
    }

    pub fn is_empty(&self) -> bool {
        self.variants.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn fleet_contains_expected_names() {
        let spec = ModelSpec::default_spec();
        let theta = spec.init_theta(&mut Pcg64::seed(1));
        let reg = Registry::build_fleet(
            &spec,
            &theta,
            &[QuantMethod::Ot, QuantMethod::Uniform],
            &[2, 8],
        );
        assert_eq!(reg.len(), 5);
        assert!(reg.get("fp32").is_ok());
        assert!(reg.get("ot2").is_ok());
        assert!(reg.get("uniform8").is_ok());
        assert!(reg.get("log2_4").is_err());
        assert_eq!(reg.get("ot8").unwrap().describe(), "ot8");
    }
}
