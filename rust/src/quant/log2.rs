//! Logarithmic (base-2) quantization baseline — the paper's "LogBase2".
//!
//! Levels are sign × power-of-two magnitudes: ±2^e for e on an integer
//! grid chosen from the weight range, plus an explicit zero level.
//! Hardware-friendly (multiplies become shifts) but allocates resolution
//! geometrically — far too coarse near the distribution mode, which is
//! exactly where FM weight mass concentrates; the paper shows it collapses
//! first at low bits.

use super::codebook::Codebook;

/// Build the logarithmic codebook: an explicit zero plus ±2^e pairs on
/// a descending exponent grid from the weight range's ceiling.
pub fn log2_codebook(w: &[f32], bits: u8) -> Codebook {
    let k = 1usize << bits;
    let max_abs = w.iter().fold(0.0f32, |m, &x| m.max(x.abs())).max(1e-12);
    // largest exponent that covers max |w|
    let e_hi = max_abs.log2().ceil() as i32;
    // budget: 1 level for zero, the rest split into ± pairs
    let pairs = (k - 1) / 2;
    let mut levels = Vec::with_capacity(k);
    levels.push(0.0);
    for i in 0..pairs {
        let mag = 2.0f32.powi(e_hi - i as i32);
        levels.push(mag);
        levels.push(-mag);
    }
    // odd leftover slot: one more positive magnitude
    if levels.len() < k {
        levels.push(2.0f32.powi(e_hi - pairs as i32));
    }
    Codebook::new(levels, bits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::otq::equal_mass_codebook;
    use crate::stats::mse;
    use crate::util::rng::Pcg64;

    #[test]
    fn levels_are_signed_powers_of_two_plus_zero() {
        let w = [-0.8f32, 0.3, 0.05, -0.01];
        let cb = log2_codebook(&w, 3);
        assert!(cb.levels.contains(&0.0));
        for &l in &cb.levels {
            if l != 0.0 {
                let e = l.abs().log2();
                assert!((e - e.round()).abs() < 1e-6, "level {l} not power of two");
            }
        }
    }

    #[test]
    fn covers_max_weight() {
        let mut rng = Pcg64::seed(1);
        let w: Vec<f32> = (0..1024).map(|_| rng.normal_f32(0.0, 0.1)).collect();
        let cb = log2_codebook(&w, 5);
        let max_abs = w.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        let top = cb.levels.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        assert!(top >= max_abs);
    }

    #[test]
    fn ot_beats_log2_on_gaussian() {
        // the paper's Fig. 3 ordering at any bit-width
        let mut rng = Pcg64::seed(2);
        let w: Vec<f32> = (0..32768).map(|_| rng.normal_f32(0.0, 0.05)).collect();
        for bits in 2..=6u8 {
            let e_log = mse(&w, &log2_codebook(&w, bits).reconstruct(&w));
            let e_ot = mse(&w, &equal_mass_codebook(&w, bits).reconstruct(&w));
            assert!(e_ot < e_log, "bits={bits} ot={e_ot} log2={e_log}");
        }
    }

    #[test]
    fn respects_level_budget() {
        let mut rng = Pcg64::seed(3);
        let w: Vec<f32> = (0..512).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        for bits in 2..=8u8 {
            assert!(log2_codebook(&w, bits).k() <= 1usize << bits);
        }
    }

    #[test]
    fn zero_heavy_weights_quantize_to_zero() {
        let w = vec![0.0f32; 100];
        let cb = log2_codebook(&w, 4);
        assert_eq!(cb.reconstruct(&[0.0])[0], 0.0);
    }
}
