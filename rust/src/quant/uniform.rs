//! Uniform symmetric PTQ — the paper's primary baseline.
//!
//! A single symmetric range [-R, R] wide enough to cover every weight in
//! the layer (Definition 1), with 2^b equally spaced reconstruction levels
//! at cell centers. Step Δ = 2R/2^b, worst-case per-weight error
//! δ_U ≤ Δ/2 = R·2^{-(b-1)} (Definition 2) — the quantity Theorem 3's
//! FID bound is built from. Because R must cover the single largest
//! weight, outliers inflate every bin (the paper's "Intuition" paragraph).

use super::codebook::Codebook;

/// Symmetric clipping range R = max |w| (full coverage, as in Def. 1).
pub fn symmetric_range(w: &[f32]) -> f32 {
    w.iter().fold(0.0f32, |m, &x| m.max(x.abs())).max(1e-12)
}

/// Uniform codebook: 2^b cell centers of [-R, R].
pub fn uniform_codebook(w: &[f32], bits: u8) -> Codebook {
    let r = symmetric_range(w);
    let k = 1usize << bits;
    let delta = 2.0 * r / k as f32;
    let levels = (0..k)
        .map(|i| -r + delta * (i as f32 + 0.5))
        .collect::<Vec<_>>();
    Codebook::new(levels, bits)
}

/// Worst-case per-weight error δ_U = R / 2^{b-1} (Definition 2).
pub fn delta_u(r: f64, bits: u8) -> f64 {
    r / 2.0f64.powi(bits as i32 - 1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;
    use crate::util::rng::Pcg64;

    #[test]
    fn levels_are_cell_centers() {
        let w = [-1.0f32, 1.0];
        let cb = uniform_codebook(&w, 2); // R=1, K=4, delta=0.5
        assert_eq!(cb.levels, vec![-0.75, -0.25, 0.25, 0.75]);
    }

    #[test]
    fn worst_case_error_bound_holds() {
        forall("uniform |w - q(w)| <= delta_u", 100, |g| {
            let w = g.nasty_weights(8..=1024);
            let bits = g.usize_in(2..=8) as u8;
            let cb = uniform_codebook(&w, bits);
            let r = symmetric_range(&w) as f64;
            let bound = delta_u(r, bits) + 1e-6;
            let rec = cb.reconstruct(&w);
            w.iter()
                .zip(rec.iter())
                .all(|(&x, &y)| ((x - y).abs() as f64) <= bound)
        });
    }

    #[test]
    fn delta_u_halves_per_bit() {
        let r = 3.0;
        for b in 2..8u8 {
            assert!((delta_u(r, b) / delta_u(r, b + 1) - 2.0).abs() < 1e-12);
        }
    }

    #[test]
    fn outlier_inflates_every_bin() {
        // the paper's intuition: one huge weight degrades everyone's error
        let mut rng = Pcg64::seed(1);
        let mut w: Vec<f32> = (0..4096).map(|_| rng.normal_f32(0.0, 0.05)).collect();
        let cb_clean = uniform_codebook(&w, 4);
        let e_clean = crate::stats::mse(&w, &cb_clean.reconstruct(&w));
        w.push(5.0); // outlier
        let cb_out = uniform_codebook(&w, 4);
        let e_out = crate::stats::mse(&w[..4096], &cb_out.reconstruct(&w[..4096]));
        assert!(e_out > 10.0 * e_clean, "clean={e_clean} out={e_out}");
    }

    #[test]
    fn range_never_zero() {
        assert!(symmetric_range(&[0.0, 0.0]) > 0.0);
        let cb = uniform_codebook(&[0.0; 16], 3);
        assert!(cb.k() >= 1);
    }
}
