//! On-device quantization driver — the paper's "efficient on-chip
//! quantization routines" future-work item, built on the L1 Pallas
//! `assign` kernel.
//!
//! The coordinator computes codebooks host-side (sort + segment means,
//! cheap) and dispatches the O(N·K) nearest-centroid assignment to the
//! compiled `assign` artifact in fixed 65536-value chunks (padding the
//! tail with the first centroid value, which maps to a valid code).

use anyhow::Result;

use crate::model::params::ParamStore;
use crate::model::quantized::QuantizedModel;
use crate::model::spec::ModelSpec;
use crate::quant::codebook::Codebook;
use crate::quant::QuantMethod;
use crate::runtime::ArtifactSet;

/// Assign codes for one value slice through the device kernel.
pub fn assign_on_device(art: &ArtifactSet, vals: &[f32], cb: &Codebook) -> Result<Vec<u32>> {
    let chunk = art.assign_chunk;
    let padded_cb = cb.padded_levels(art.spec.k_max);
    let mut out = Vec::with_capacity(vals.len());
    let mut buf = vec![0f32; chunk];
    for piece in vals.chunks(chunk) {
        let codes = if piece.len() == chunk {
            art.assign_chunk_exec(piece, &padded_cb)?
        } else {
            // pad the tail with a real level so every lane stays valid
            buf[..piece.len()].copy_from_slice(piece);
            for v in buf[piece.len()..].iter_mut() {
                *v = cb.levels[0];
            }
            art.assign_chunk_exec(&buf, &padded_cb)?
        };
        out.extend(codes[..piece.len()].iter().map(|&c| c as u32));
    }
    Ok(out)
}

/// Quantize a whole model with device-side assignment (host-side codebook
/// construction). Mirrors `quant::quantize_model` exactly — an integration
/// test pins the two against each other.
pub fn quantize_model_on_device(
    art: &ArtifactSet,
    spec: &ModelSpec,
    theta: &ParamStore,
    method: QuantMethod,
    bits: u8,
) -> Result<QuantizedModel> {
    let mut codebooks = Vec::new();
    let mut codes: Vec<u32> = Vec::with_capacity(spec.pw());
    for layer in spec.weight_layers() {
        let w = theta.layer(spec, &layer.name);
        let cb = method.build_codebook(w, bits);
        codes.extend(assign_on_device(art, w, &cb)?);
        codebooks.push(cb);
    }
    let mut biases: Vec<f32> = Vec::with_capacity(spec.pb());
    for layer in spec.bias_layers() {
        biases.extend_from_slice(theta.layer(spec, &layer.name));
    }
    Ok(QuantizedModel::new(
        spec.clone(),
        method,
        bits,
        codebooks,
        codes,
        biases,
    ))
}
