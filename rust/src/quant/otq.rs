//! Optimal-transport (equal-mass) quantization — the paper's Algorithm 1.
//!
//! The trained weights of a layer are an empirical distribution
//! P_w = (1/N) Σ δ_{w_i}. The K-point distribution Q minimizing W₂(P_w, Q)
//! on ℝ is found by the monotone (quantile) coupling: split the *sorted*
//! weights into K contiguous groups of equal mass and take each group's
//! mean as its codeword (Lloyd–Max optimality in 1-D). Equal-mass binning
//! automatically spends resolution where the density is high and lets the
//! tail bins be wide — the mechanism behind the C_E < C_U front-constant
//! advantage of Theorem 6.
//!
//! `lloyd_refine` optionally runs classic Lloyd iterations afterwards;
//! for heavy-tailed layers this can strictly reduce MSE versus the plain
//! equal-mass split (the paper's "ensuring effective representation"
//! future-work item — we measure this in the ablation bench).

use super::codebook::Codebook;
use crate::stats::sorted_copy;

/// Equal-mass split of *sorted* values into K groups: group j spans
/// `sorted[floor(jN/K) .. floor((j+1)N/K)]`. Returns group means.
/// Mirrors `python/tests/test_model.py::_equal_mass_codebook` exactly.
pub fn equal_mass_levels(sorted: &[f32], k: usize) -> Vec<f32> {
    assert!(k >= 1);
    let n = sorted.len();
    let mut levels = Vec::with_capacity(k);
    for j in 0..k {
        let a = j * n / k;
        let b = (j + 1) * n / k;
        if b > a {
            let sum: f64 = sorted[a..b].iter().map(|&x| x as f64).sum();
            levels.push((sum / (b - a) as f64) as f32);
        }
        // empty group (N < K): skip — dedup in Codebook handles collisions
    }
    if levels.is_empty() {
        levels.push(0.0);
    }
    levels
}

/// Algorithm 1 (per-tensor): equal-mass codebook for one flattened layer.
pub fn equal_mass_codebook(w: &[f32], bits: u8) -> Codebook {
    let k = 1usize << bits;
    let sorted = sorted_copy(w);
    Codebook::new(equal_mass_levels(&sorted, k), bits)
}

/// Classic Lloyd refinement on the 1-D codebook: alternate
/// (nearest-level partition) <-> (partition means) until the MSE stops
/// improving. Keeps W₂ optimality's fixed point; strictly non-increasing
/// in MSE each iteration.
pub fn lloyd_refine(w: &[f32], cb: &Codebook, max_iters: usize) -> Codebook {
    let sorted = sorted_copy(w);
    let mut levels = cb.levels.clone();
    for _ in 0..max_iters {
        // partition boundaries are midpoints between adjacent levels; on
        // sorted data each cell is a contiguous range -> one linear pass.
        let mut sums = vec![0f64; levels.len()];
        let mut counts = vec![0usize; levels.len()];
        let mut cell = 0usize;
        for &x in &sorted {
            while cell + 1 < levels.len()
                && (x - levels[cell]).abs() > (x - levels[cell + 1]).abs()
            {
                cell += 1;
            }
            sums[cell] += x as f64;
            counts[cell] += 1;
        }
        let mut changed = false;
        for i in 0..levels.len() {
            if counts[i] > 0 {
                let new = (sums[i] / counts[i] as f64) as f32;
                if (new - levels[i]).abs() > 1e-12 {
                    changed = true;
                }
                levels[i] = new;
            }
        }
        levels.sort_by(f32::total_cmp);
        if !changed {
            break;
        }
    }
    Codebook::new(levels, cb.bits)
}

/// Convenience: equal-mass + Lloyd refinement.
pub fn otq_refined_codebook(w: &[f32], bits: u8, lloyd_iters: usize) -> Codebook {
    let cb = equal_mass_codebook(w, bits);
    if lloyd_iters == 0 {
        cb
    } else {
        lloyd_refine(w, &cb, lloyd_iters)
    }
}

/// The W₂² distance between the empirical weight distribution and its
/// quantization — for the monotone 1-D coupling this is exactly the mean
/// squared quantization error (paper Eq. 9 discussion).
pub fn w2_sq(w: &[f32], cb: &Codebook) -> f64 {
    let rec = cb.reconstruct(w);
    crate::stats::mse(w, &rec)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::uniform::uniform_codebook;
    use crate::stats::mse;
    use crate::util::check::{forall, Gen};
    use crate::util::rng::Pcg64;

    fn gaussian_weights(n: usize, sigma: f32, seed: u64) -> Vec<f32> {
        let mut rng = Pcg64::seed(seed);
        (0..n).map(|_| rng.normal_f32(0.0, sigma)).collect()
    }

    #[test]
    fn equal_mass_bins_have_equal_mass() {
        let w = gaussian_weights(16384, 1.0, 1);
        let cb = equal_mass_codebook(&w, 4); // K = 16
        let codes = cb.assign(&w);
        let mut counts = vec![0usize; cb.k()];
        for &c in &codes {
            counts[c as usize] += 1;
        }
        let expect = w.len() / cb.k();
        for (i, &c) in counts.iter().enumerate() {
            // nearest-assignment can shift boundary elements slightly from
            // the pure quantile split; mass stays within a few percent.
            assert!(
                (c as f64 - expect as f64).abs() < 0.25 * expect as f64,
                "bin {i}: {c} vs {expect}"
            );
        }
    }

    #[test]
    fn centroid_is_group_mean_exact_small_case() {
        // N=8, K=4: groups of 2, centroids are pair means
        let sorted = [1.0f32, 2.0, 3.0, 5.0, 8.0, 9.0, 10.0, 20.0];
        let lv = equal_mass_levels(&sorted, 4);
        assert_eq!(lv, vec![1.5, 4.0, 8.5, 15.0]);
    }

    #[test]
    fn k_greater_than_n_degenerates_gracefully() {
        let lv = equal_mass_levels(&[1.0, 2.0], 8);
        assert!(!lv.is_empty());
        let cb = Codebook::new(lv, 3);
        // both values representable exactly
        assert_eq!(cb.reconstruct(&[1.0, 2.0]), vec![1.0, 2.0]);
    }

    /// 1-D W₂ optimality (spot check): no small perturbation of the
    /// codebook may lower the MSE.
    #[test]
    fn local_optimality_after_lloyd() {
        let w = gaussian_weights(8192, 0.05, 2);
        let cb = otq_refined_codebook(&w, 3, 50);
        let base = w2_sq(&w, &cb);
        let mut rng = Pcg64::seed(3);
        for _ in 0..20 {
            let mut lv = cb.levels.clone();
            let i = rng.below(lv.len());
            lv[i] += rng.normal_f32(0.0, 0.002);
            lv.sort_by(f32::total_cmp);
            let pert = Codebook { levels: lv, bits: 3 };
            assert!(
                w2_sq(&w, &pert) >= base * (1.0 - 1e-4),
                "perturbation lowered W2"
            );
        }
    }

    #[test]
    fn lloyd_never_increases_mse() {
        forall("lloyd monotone", 40, |g: &mut Gen| {
            let w = g.nasty_weights(64..=2048);
            let bits = g.usize_in(2..=6) as u8;
            let cb0 = equal_mass_codebook(&w, bits);
            let cb1 = lloyd_refine(&w, &cb0, 25);
            w2_sq(&w, &cb1) <= w2_sq(&w, &cb0) * (1.0 + 1e-6)
        });
    }

    /// Theorem-6 mechanism check, with the honest caveat the paper glosses
    /// over: Bennett's D_E = α³/12 · 2^{-2b} is the *optimal-point-density*
    /// (λ ∝ f^{1/3}) error, while equal-mass binning uses λ ∝ f — so the
    /// plain Algorithm-1 quantizer sits a constant factor (~2–4×) above the
    /// Bennett value on Gaussians, and Lloyd refinement closes most of the
    /// gap. Both scale as 2^{-2b}, which is what Theorem 6 needs.
    #[test]
    fn de_matches_bennett_integral_gaussian() {
        let sigma = 0.05f64;
        let w = gaussian_weights(1 << 18, sigma as f32, 4);
        let alpha3 = crate::stats::dist::alpha_gaussian(sigma).powi(3);
        for bits in 4..=6u8 {
            let de = alpha3 / 12.0 * 2.0f64.powi(-2 * bits as i32);
            let d_em = w2_sq(&w, &equal_mass_codebook(&w, bits));
            let ratio_em = d_em / de;
            // equal-mass drifts further above Bennett as b grows (its tail
            // cells keep a fixed mass, not a fixed width)
            assert!(
                (1.0..12.0).contains(&ratio_em),
                "bits={bits} equal-mass={d_em:.3e} bennett={de:.3e} ratio={ratio_em:.2}"
            );
            // Lloyd-refined OT approaches the Bennett optimum
            let d_ll = w2_sq(&w, &otq_refined_codebook(&w, bits, 300));
            let ratio_ll = d_ll / de;
            assert!(d_ll <= d_em * 1.0001);
            assert!(
                (0.5..2.0).contains(&ratio_ll),
                "bits={bits} lloyd={d_ll:.3e} bennett={de:.3e} ratio={ratio_ll:.2}"
            );
        }
        // the 2^{-2b} slope itself (16x per 2 bits) on the refined codebook
        let d4 = w2_sq(&w, &otq_refined_codebook(&w, 4, 300));
        let d6 = w2_sq(&w, &otq_refined_codebook(&w, 6, 300));
        let per_two_bits = d4 / d6;
        assert!(
            (8.0..32.0).contains(&per_two_bits),
            "slope off 2^-2b: {per_two_bits}"
        );
    }

    #[test]
    fn ot_beats_uniform_more_on_heavy_tails() {
        // Laplace weights: the OT advantage should be larger than on Gaussian
        let mut rng = Pcg64::seed(5);
        let lap: Vec<f32> = (0..65536).map(|_| rng.laplace(0.05) as f32).collect();
        let gau = gaussian_weights(65536, 0.05 * std::f64::consts::SQRT_2 as f32, 6);
        let adv = |w: &[f32]| {
            let o = w2_sq(w, &equal_mass_codebook(w, 3));
            let u = mse(w, &uniform_codebook(w, 3).reconstruct(w));
            u / o
        };
        let adv_lap = adv(&lap);
        let adv_gau = adv(&gau);
        assert!(adv_lap > adv_gau, "lap={adv_lap} gau={adv_gau}");
        assert!(adv_gau > 1.0);
    }

    #[test]
    fn handles_constant_and_tiny_inputs() {
        let cb = equal_mass_codebook(&[0.5; 100], 4);
        assert_eq!(cb.levels, vec![0.5]);
        let cb = equal_mass_codebook(&[1.0], 8);
        assert_eq!(cb.reconstruct(&[1.0]), vec![1.0]);
    }
}
