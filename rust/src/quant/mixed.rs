//! Mixed-precision bit allocation — Corollary 13.1 made practical.
//!
//! The paper assigns one bit-width to every layer. Layers differ in size
//! and sensitivity, so for a fixed *average* bit budget it is better to
//! solve
//!
//! ```text
//! min Σ_l  s_l · D_l(b_l)    s.t.   Σ_l n_l b_l ≤ B_total
//! ```
//!
//! where D_l(b) is the measured per-weight distortion of layer l at b bits
//! and s_l a sensitivity weight. With D_l convex-decreasing in b, the
//! greedy marginal-gain allocator below is optimal (discrete
//! water-filling): repeatedly give one more bit to the layer with the
//! best distortion-reduction per parameter-bit spent.

use crate::model::params::ParamStore;
use crate::model::quantized::QuantizedModel;
use crate::model::spec::ModelSpec;
use crate::quant::codebook::Codebook;
use crate::quant::QuantMethod;

/// Smallest per-layer bit-width the allocator may assign.
pub const MIN_BITS: u8 = 2;
/// Largest per-layer bit-width the allocator may assign.
pub const MAX_BITS: u8 = 8;

/// Per-layer distortion table D_l(b) (mean squared error per weight).
pub struct DistortionTable {
    /// [layer][bits - MIN_BITS]
    pub d: Vec<Vec<f64>>,
    /// Parameter count per layer (the allocator's cost weights).
    pub sizes: Vec<usize>,
}

/// Build the distortion table by quantizing every layer at every
/// candidate bit-width and measuring the resulting W₂²/MSE.
pub fn measure_distortions(
    spec: &ModelSpec,
    theta: &ParamStore,
    method: QuantMethod,
) -> DistortionTable {
    let mut d = Vec::new();
    let mut sizes = Vec::new();
    for l in spec.weight_layers() {
        let w = theta.layer(spec, &l.name);
        let mut row = Vec::new();
        for bits in MIN_BITS..=MAX_BITS {
            let cb = method.build_codebook(w, bits);
            row.push(crate::quant::otq::w2_sq(w, &cb));
        }
        d.push(row);
        sizes.push(l.size());
    }
    DistortionTable { d, sizes }
}

/// Greedy optimal allocation under a total-bit budget expressed as an
/// average bits/weight target. Returns per-layer bit-widths.
pub fn allocate(table: &DistortionTable, avg_bits: f64) -> Vec<u8> {
    let n_layers = table.sizes.len();
    let total_params: usize = table.sizes.iter().sum();
    let budget = (avg_bits * total_params as f64) as i64;
    let mut bits = vec![MIN_BITS; n_layers];
    let mut spent: i64 = table
        .sizes
        .iter()
        .map(|&n| n as i64 * MIN_BITS as i64)
        .sum();
    loop {
        // best marginal gain per parameter-bit
        let mut best: Option<(usize, f64)> = None;
        for l in 0..n_layers {
            if bits[l] >= MAX_BITS {
                continue;
            }
            let cost = table.sizes[l] as i64;
            if spent + cost > budget {
                continue;
            }
            let cur = table.d[l][(bits[l] - MIN_BITS) as usize] * table.sizes[l] as f64;
            let nxt = table.d[l][(bits[l] + 1 - MIN_BITS) as usize] * table.sizes[l] as f64;
            let gain = (cur - nxt) / cost as f64;
            if best.map_or(true, |(_, g)| gain > g) {
                best = Some((l, gain));
            }
        }
        match best {
            Some((l, gain)) if gain > 0.0 => {
                bits[l] += 1;
                spent += table.sizes[l] as i64;
            }
            _ => break,
        }
    }
    bits
}

/// Quantize with per-layer bit-widths (codebooks padded to K_MAX as usual,
/// so the serving artifact is unchanged — mixed precision is free at
/// inference time).
pub fn quantize_mixed(
    spec: &ModelSpec,
    theta: &ParamStore,
    method: QuantMethod,
    bits_per_layer: &[u8],
) -> QuantizedModel {
    let wl = spec.weight_layers();
    assert_eq!(bits_per_layer.len(), wl.len());
    let mut codebooks: Vec<Codebook> = Vec::new();
    let mut codes: Vec<u32> = Vec::with_capacity(spec.pw());
    for (l, &b) in wl.iter().zip(bits_per_layer.iter()) {
        let w = theta.layer(spec, &l.name);
        let cb = method.build_codebook(w, b);
        codes.extend(cb.assign(w));
        codebooks.push(cb);
    }
    let mut biases: Vec<f32> = Vec::with_capacity(spec.pb());
    for l in spec.bias_layers() {
        biases.extend_from_slice(theta.layer(spec, &l.name));
    }
    // stored bit-width = max over layers (packing granularity); effective
    // average is what the allocator controlled
    let max_bits = *bits_per_layer.iter().max().unwrap();
    QuantizedModel::new(spec.clone(), method, max_bits, codebooks, codes, biases)
}

/// Bit-tight payload accounting for a mixed allocation: the bytes the
/// per-layer packed code streams occupy, Σ_l ⌈n_l·b_l / 8⌉. With a
/// homogeneous allocation this is exactly the packed-codes term of
/// [`QuantizedModel::compressed_bytes`] whenever every layer's bit count
/// is byte-aligned (true for the default spec — all layer sizes are
/// multiples of 8); the property tests pin both facts.
pub fn packed_bytes(sizes: &[usize], bits: &[u8]) -> usize {
    assert_eq!(sizes.len(), bits.len());
    let mut total = 0usize;
    for (&n, &b) in sizes.iter().zip(bits.iter()) {
        total += (n * b as usize).div_ceil(8);
    }
    total
}

/// Size-weighted total distortion of an allocation (for tests/benches).
pub fn total_distortion(table: &DistortionTable, bits: &[u8]) -> f64 {
    let total: usize = table.sizes.iter().sum();
    bits.iter()
        .enumerate()
        .map(|(l, &b)| table.d[l][(b - MIN_BITS) as usize] * table.sizes[l] as f64)
        .sum::<f64>()
        / total as f64
}

/// Average bits/weight of an allocation.
pub fn avg_bits(table: &DistortionTable, bits: &[u8]) -> f64 {
    let total: usize = table.sizes.iter().sum();
    bits.iter()
        .enumerate()
        .map(|(l, &b)| b as f64 * table.sizes[l] as f64)
        .sum::<f64>()
        / total as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn setup() -> (ModelSpec, ParamStore, DistortionTable) {
        let spec = ModelSpec::default_spec();
        let mut rng = Pcg64::seed(5);
        let theta = spec.init_theta(&mut rng);
        let table = measure_distortions(&spec, &theta, QuantMethod::Ot);
        (spec, theta, table)
    }

    #[test]
    fn distortion_table_monotone() {
        let (_, _, table) = setup();
        for row in &table.d {
            for w in row.windows(2) {
                assert!(w[1] <= w[0] * 1.01, "distortion rose with bits: {row:?}");
            }
        }
    }

    #[test]
    fn allocation_respects_budget_and_bounds() {
        let (_, _, table) = setup();
        for target in [2.5f64, 4.0, 6.0] {
            let bits = allocate(&table, target);
            assert!(bits.iter().all(|&b| (MIN_BITS..=MAX_BITS).contains(&b)));
            assert!(
                avg_bits(&table, &bits) <= target + 1e-9,
                "target {target} exceeded: {}",
                avg_bits(&table, &bits)
            );
        }
    }

    /// The point of the exercise: at equal average bits, the mixed
    /// allocation's total distortion never exceeds the uniform assignment.
    #[test]
    fn mixed_beats_or_ties_uniform_assignment() {
        let (_, _, table) = setup();
        for b in [3u8, 4, 6] {
            let uniform = vec![b; table.sizes.len()];
            let mixed = allocate(&table, b as f64);
            let du = total_distortion(&table, &uniform);
            let dm = total_distortion(&table, &mixed);
            assert!(dm <= du * 1.001, "b={b}: mixed {dm} vs uniform {du}");
        }
    }

    /// Sensitivity shows up: the high-variance w_t layer (fan-in 64) should
    /// receive at least as many bits as the big homogeneous blocks at tight
    /// budgets.
    #[test]
    fn high_sigma_layer_gets_more_bits() {
        let (spec, _, table) = setup();
        let bits = allocate(&table, 3.0);
        let wl = spec.weight_layers();
        let idx_wt = wl.iter().position(|l| l.name == "w_t").unwrap();
        let idx_w1 = wl.iter().position(|l| l.name == "w1_0").unwrap();
        assert!(
            bits[idx_wt] >= bits[idx_w1],
            "w_t got {} bits, w1_0 got {}",
            bits[idx_wt],
            bits[idx_w1]
        );
    }

    /// Per-layer bit assignments survive pack/unpack for ragged layer
    /// shapes at every bit-width the allocator can emit (and below its
    /// floor, down to 1 bit — the packing layer must not care).
    #[test]
    fn mixed_allocation_codes_roundtrip_packing() {
        use crate::quant::packing::PackedCodes;
        use crate::util::check::forall;
        forall("mixed ragged pack/unpack", 60, |g| {
            let n_layers = g.usize_in(1..=6);
            let mut sizes = Vec::new();
            let mut bits = Vec::new();
            let mut layers: Vec<Vec<u32>> = Vec::new();
            for _ in 0..n_layers {
                // ragged: odd sizes, sizes below one packing word, empty
                let n = g.usize_in(0..=67);
                let b = g.usize_in(1..=8) as u8;
                let limit = (1u32 << b) - 1;
                let codes: Vec<u32> =
                    (0..n).map(|_| g.rng().next_u64() as u32 & limit).collect();
                sizes.push(n);
                bits.push(b);
                layers.push(codes);
            }
            let mut packed_total = 0usize;
            for (codes, &b) in layers.iter().zip(bits.iter()) {
                let p = PackedCodes::pack(codes, b).expect("codes fit");
                if p.unpack() != *codes {
                    return false;
                }
                packed_total += p.byte_len();
            }
            // the bit-tight account never exceeds the stored (64-bit
            // word padded) payload, and the padding is under one word
            // per layer
            let tight = packed_bytes(&sizes, &bits);
            tight <= packed_total && packed_total < tight + 8 * n_layers + 8
        });
    }

    /// The model's reported size is exactly the per-layer packed-byte
    /// sum plus codebooks and biases — no hidden accounting.
    #[test]
    fn model_size_accounting_matches_packed_bytes() {
        let (spec, theta, table) = setup();
        for b in [2u8, 3, 5, 8] {
            let bits = vec![b; table.sizes.len()];
            let qm = quantize_mixed(&spec, &theta, QuantMethod::Ot, &bits);
            assert_eq!(qm.bits, b);
            let code_bytes = packed_bytes(&table.sizes, &bits);
            let cb_bytes: usize = qm.codebooks.iter().map(|c| c.levels.len() * 4).sum();
            let bias_bytes = qm.biases.len() * 4;
            assert_eq!(
                qm.compressed_bytes(),
                code_bytes + cb_bytes + bias_bytes,
                "b={b}: accounting drift"
            );
            // and the stored packing agrees with the tight account
            // (every default-spec layer is a multiple of 8 params, so
            // per-layer and contiguous packing coincide)
            let packed = qm.pack_codes().expect("packs");
            assert!(packed.byte_len() >= code_bytes);
            assert!(packed.byte_len() < code_bytes + 8);
        }
    }

    #[test]
    fn quantize_mixed_roundtrip() {
        let (spec, theta, table) = setup();
        let bits = allocate(&table, 3.5);
        let qm = quantize_mixed(&spec, &theta, QuantMethod::Ot, &bits);
        assert_eq!(qm.codes.len(), spec.pw());
        // reconstruction error close to the table's prediction
        let err = qm.w2_error(&theta);
        let predicted = total_distortion(&table, &bits);
        assert!(
            (err.w2_sq - predicted).abs() / predicted < 0.05,
            "measured {} vs predicted {predicted}",
            err.w2_sq
        );
    }
}
