//! Dense bit-packing of code indices — the storage format.
//!
//! Codes at b bits each pack little-endian into a `u64` stream (codes may
//! straddle word boundaries). This is what makes the compression ratio
//! real: a 2.4M-parameter model at 3 bits is ~0.9 MB of codes plus a few
//! KB of codebooks, vs 9.6 MB of f32.

use anyhow::{bail, Result};

/// Packed code stream.
#[derive(Clone, Debug, PartialEq)]
pub struct PackedCodes {
    /// Bits per code (1..=32).
    pub bits: u8,
    /// Number of packed codes.
    pub n: usize,
    /// Little-endian bit stream, `n * bits` bits used.
    pub words: Vec<u64>,
}

impl PackedCodes {
    /// Pack `codes` (each < 2^bits) at `bits` per entry.
    pub fn pack(codes: &[u32], bits: u8) -> Result<Self> {
        if bits == 0 || bits > 32 {
            bail!("bits must be in 1..=32, got {bits}");
        }
        let limit = if bits == 32 { u32::MAX } else { (1u32 << bits) - 1 };
        let total_bits = codes.len() * bits as usize;
        let mut words = vec![0u64; total_bits.div_ceil(64)];
        let mut bitpos = 0usize;
        for &c in codes {
            if c > limit {
                bail!("code {c} does not fit in {bits} bits");
            }
            let word = bitpos / 64;
            let off = bitpos % 64;
            words[word] |= (c as u64) << off;
            let spill = off + bits as usize;
            if spill > 64 {
                words[word + 1] |= (c as u64) >> (64 - off);
            }
            bitpos += bits as usize;
        }
        Ok(Self {
            bits,
            n: codes.len(),
            words,
        })
    }

    /// Unpack all codes.
    pub fn unpack(&self) -> Vec<u32> {
        (0..self.n).map(|i| self.get(i)).collect()
    }

    /// Random access to code i.
    #[inline]
    pub fn get(&self, i: usize) -> u32 {
        debug_assert!(i < self.n);
        let bits = self.bits as usize;
        let bitpos = i * bits;
        let word = bitpos / 64;
        let off = bitpos % 64;
        let mask = if bits == 64 { u64::MAX } else { (1u64 << bits) - 1 };
        let mut v = self.words[word] >> off;
        if off + bits > 64 {
            v |= self.words[word + 1] << (64 - off);
        }
        (v & mask) as u32
    }

    /// Sequential unpack of `out.len()` codes starting at element `start`
    /// into a `u8` buffer — the engine's LUT-GEMM feed. Requires
    /// `bits <= 8` (codes fit a byte) and `start + out.len() <= n`.
    /// Decodes with one running bit cursor instead of per-element division
    /// by recomputing `get(i)`, which is what makes tile-wise streaming of
    /// the codes cheap enough to sit inside a GEMM.
    pub fn unpack_range_u8(&self, start: usize, out: &mut [u8]) {
        assert!(self.bits <= 8, "unpack_range_u8 needs bits <= 8, got {}", self.bits); // fmq-analyze: allow(panic_cone) -- these asserts ARE the documented bounds contract; offsets derive from the spec's layer table and the property tests cover every bit-width (covers next line)
        assert!(
            start + out.len() <= self.n,
            "unpack_range_u8 range {}..{} out of {} codes",
            start,
            start + out.len(),
            self.n
        );
        let bits = self.bits as usize;
        let mask: u64 = (1u64 << bits) - 1;
        let mut bitpos = start * bits;
        for slot in out.iter_mut() {
            let word = bitpos / 64;
            let off = bitpos % 64;
            let mut v = self.words[word] >> off;
            if off + bits > 64 {
                v |= self.words[word + 1] << (64 - off);
            }
            *slot = (v & mask) as u8;
            bitpos += bits;
        }
    }

    /// Bulk sequential decode of `out.len()` codes starting at element
    /// `start` — the v2 engine's tile feed. Same contract as
    /// [`PackedCodes::unpack_range_u8`] (`bits <= 8`, range in bounds),
    /// same output, different cost model: instead of recomputing the
    /// word/offset split per element, a 64-bit buffer is refilled once
    /// per word and codes are shifted out of it, so the per-code cost
    /// drops to a shift + mask for the ~`64/b − 1` codes that do not
    /// straddle a word boundary. A property test pins this against the
    /// element-wise decoder for every bit-width and ragged range.
    pub fn unpack_bulk_u8(&self, start: usize, out: &mut [u8]) {
        assert!(self.bits <= 8, "unpack_bulk_u8 needs bits <= 8, got {}", self.bits); // fmq-analyze: allow(panic_cone) -- same documented bounds contract as unpack_range_u8 (covers next line)
        assert!(
            start + out.len() <= self.n,
            "unpack_bulk_u8 range {}..{} out of {} codes",
            start,
            start + out.len(),
            self.n
        );
        if out.is_empty() {
            return;
        }
        let bits = self.bits as usize;
        let mask: u64 = (1u64 << bits) - 1;
        let bitpos = start * bits;
        let mut wi = bitpos / 64;
        let off = bitpos % 64;
        // `buf` holds the unread suffix of word `wi`, low-aligned;
        // `avail` counts its valid low bits.
        let mut buf = self.words[wi] >> off;
        let mut avail = 64 - off;
        for slot in out.iter_mut() {
            if avail >= bits {
                *slot = (buf & mask) as u8;
                buf >>= bits;
                avail -= bits;
            } else {
                // code straddles into the next word (or the buffer is
                // exactly drained): splice `avail` low bits with the
                // next word's low bits
                wi += 1;
                let next = self.words[wi];
                *slot = ((buf | (next << avail)) & mask) as u8;
                buf = next >> (bits - avail);
                avail = 64 - (bits - avail);
            }
        }
    }

    /// Payload size in bytes.
    pub fn byte_len(&self) -> usize {
        self.words.len() * 8
    }

    /// Compression ratio vs f32 storage for the same element count.
    pub fn compression_ratio(&self) -> f64 {
        (self.n * 4) as f64 / self.byte_len().max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;

    #[test]
    fn roundtrip_all_bit_widths() {
        forall("pack/unpack roundtrip", 100, |g| {
            let bits = g.usize_in(1..=16) as u8;
            let n = g.len(0..=500);
            let max = (1u64 << bits) as u32;
            let codes: Vec<u32> = (0..n).map(|_| g.rng().below(max as usize) as u32).collect();
            let p = PackedCodes::pack(&codes, bits).unwrap();
            p.unpack() == codes
        });
    }

    #[test]
    fn straddles_word_boundaries() {
        // 3-bit codes: element 21 spans bits 63..66
        let codes: Vec<u32> = (0..64).map(|i| (i % 8) as u32).collect();
        let p = PackedCodes::pack(&codes, 3).unwrap();
        assert_eq!(p.unpack(), codes);
        assert_eq!(p.get(21), codes[21]);
    }

    #[test]
    fn rejects_out_of_range_codes() {
        assert!(PackedCodes::pack(&[8], 3).is_err());
        assert!(PackedCodes::pack(&[7], 3).is_ok());
        assert!(PackedCodes::pack(&[0], 0).is_err());
    }

    #[test]
    fn compression_ratio_expected() {
        let codes = vec![1u32; 64_000];
        let p3 = PackedCodes::pack(&codes, 3).unwrap();
        // 32/3 ≈ 10.7x, minus word-rounding slack
        assert!(p3.compression_ratio() > 10.0, "{}", p3.compression_ratio());
        let p8 = PackedCodes::pack(&codes, 8).unwrap();
        assert!((p8.compression_ratio() - 4.0).abs() < 0.1);
    }

    #[test]
    fn unpack_range_u8_matches_get_at_every_bit_width() {
        forall("unpack_range_u8 == get", 100, |g| {
            let bits = g.usize_in(1..=8) as u8;
            // deliberately non-multiples of the 64-bit word so ranges
            // start and end mid-word
            let n = g.len(1..=300);
            let max = 1u32 << bits;
            let codes: Vec<u32> = (0..n).map(|_| g.rng().below(max as usize) as u32).collect();
            let p = PackedCodes::pack(&codes, bits).unwrap();
            let start = g.rng().below(n);
            let len = g.rng().below(n - start + 1);
            let mut out = vec![0u8; len];
            p.unpack_range_u8(start, &mut out);
            out.iter()
                .enumerate()
                .all(|(i, &c)| c as u32 == codes[start + i])
        });
    }

    /// Satellite pin: the word-buffered bulk decoder must agree with the
    /// element-wise decoder for every serving bit-width on ragged ranges
    /// (starts/ends mid-word, lengths not multiples of anything).
    #[test]
    fn unpack_bulk_u8_matches_elementwise_at_every_bit_width() {
        forall("unpack_bulk_u8 == unpack_range_u8", 200, |g| {
            let bits = g.usize_in(1..=8) as u8;
            let n = g.len(1..=400);
            let max = 1u32 << bits;
            let codes: Vec<u32> = (0..n).map(|_| g.rng().below(max as usize) as u32).collect();
            let p = PackedCodes::pack(&codes, bits).unwrap();
            let start = g.rng().below(n);
            let len = g.rng().below(n - start + 1);
            let mut bulk = vec![0u8; len];
            p.unpack_bulk_u8(start, &mut bulk);
            let mut elem = vec![0u8; len];
            p.unpack_range_u8(start, &mut elem);
            bulk == elem
        });
    }

    #[test]
    fn unpack_bulk_u8_word_boundary_cases() {
        // 3-bit codes straddle a word every 64/3 elements; 8-bit codes
        // drain the buffer to exactly zero bits before each refill.
        for bits in [3u8, 8] {
            let n = 129usize;
            let codes: Vec<u32> = (0..n).map(|i| (i as u32) % (1 << bits)).collect();
            let p = PackedCodes::pack(&codes, bits).unwrap();
            let mut out = vec![0u8; n];
            p.unpack_bulk_u8(0, &mut out);
            assert!(out.iter().enumerate().all(|(i, &c)| c as u32 == codes[i]));
            // a range that starts exactly at a word boundary
            let start = 64 / bits as usize + 1;
            let mut tail = vec![0u8; n - start];
            p.unpack_bulk_u8(start, &mut tail);
            assert!(tail.iter().enumerate().all(|(i, &c)| c as u32 == codes[start + i]));
        }
    }

    #[test]
    fn empty_stream() {
        let p = PackedCodes::pack(&[], 4).unwrap();
        assert_eq!(p.unpack(), Vec::<u32>::new());
        assert_eq!(p.byte_len(), 0);
    }
}
