//! Post-training quantization — the paper's contribution.
//!
//! Four schemes over flattened per-layer (or per-channel) weight
//! distributions, all emitting a [`codebook::Codebook`] + code indices:
//!
//! * [`otq`] — **optimal-transport / equal-mass** quantization
//!   (Algorithm 1): sort, split into K = 2^b equal-mass bins, codeword =
//!   bin mean. W₂-optimal in 1-D (Lloyd–Max); optional Lloyd refinement.
//! * [`uniform`] — symmetric uniform PTQ over [-R, R].
//! * [`pwl`] — piecewise-linear: dense levels inside ±σ-quantile core,
//!   sparse in the tails (the paper's "PWL" baseline).
//! * [`log2`] — logarithmic (sign × power-of-two magnitudes).
//!
//! [`packing`] stores codes at b bits each in a dense bitstream, giving the
//! real compression ratio; [`error`] computes the W₂²/MSE error the theory
//! section bounds.
#![warn(missing_docs)]

pub mod bias_correct;
pub mod codebook;
pub mod device;
pub mod error;
pub mod huffman;
pub mod log2;
pub mod mixed;
pub mod otq;
pub mod packing;
pub mod pwl;
pub mod uniform;

use crate::model::params::ParamStore;
use crate::model::quantized::QuantizedModel;
use crate::model::spec::ModelSpec;
use codebook::Codebook;

/// The quantization schemes compared in the paper (Figs. 2–4), plus
/// `OtLloyd` — the Lloyd-refined OT codebook (the paper's future-work
/// "codebook efficiency" item; the true 1-D W₂ optimum).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum QuantMethod {
    /// Equal-mass optimal-transport quantization (Algorithm 1).
    Ot,
    /// Equal-mass OT followed by Lloyd refinement (1-D W₂ optimum).
    OtLloyd,
    /// Symmetric uniform PTQ over [-R, R].
    Uniform,
    /// Piecewise-linear: dense core grid, sparse tails.
    Pwl,
    /// Logarithmic: sign × power-of-two magnitudes.
    Log2,
}

impl QuantMethod {
    /// Every implemented method, in `--methods` help order.
    pub const ALL: [QuantMethod; 5] = [
        QuantMethod::Ot,
        QuantMethod::OtLloyd,
        QuantMethod::Uniform,
        QuantMethod::Pwl,
        QuantMethod::Log2,
    ];

    /// The four methods the paper's figures compare.
    pub const PAPER: [QuantMethod; 4] = [
        QuantMethod::Ot,
        QuantMethod::Uniform,
        QuantMethod::Pwl,
        QuantMethod::Log2,
    ];

    /// The `--method` flag value for this scheme.
    pub fn name(&self) -> &'static str {
        match self {
            QuantMethod::Ot => "ot",
            QuantMethod::OtLloyd => "ot-lloyd",
            QuantMethod::Uniform => "uniform",
            QuantMethod::Pwl => "pwl",
            QuantMethod::Log2 => "log2",
        }
    }

    /// Inverse of [`QuantMethod::name`]; `None` for unknown strings.
    pub fn parse(s: &str) -> Option<Self> {
        Self::ALL.iter().copied().find(|m| m.name() == s)
    }

    /// Build the codebook for one flattened weight tensor at `bits`.
    pub fn build_codebook(&self, w: &[f32], bits: u8) -> Codebook {
        match self {
            QuantMethod::Ot => otq::equal_mass_codebook(w, bits),
            QuantMethod::OtLloyd => otq::otq_refined_codebook(w, bits, 60),
            QuantMethod::Uniform => uniform::uniform_codebook(w, bits),
            QuantMethod::Pwl => pwl::pwl_codebook(w, bits),
            QuantMethod::Log2 => log2::log2_codebook(w, bits),
        }
    }
}

/// Quantize one tensor: codebook + per-element codes.
pub fn quantize_tensor(method: QuantMethod, w: &[f32], bits: u8) -> (Codebook, Vec<u32>) {
    let cb = method.build_codebook(w, bits);
    let codes = cb.assign(w);
    (cb, codes)
}

/// Quantize every weight matrix of a model (per-tensor codebooks; biases
/// stay fp32, standard PTQ practice — also what the serving artifact
/// expects). Returns the full quantized-model container, ready for any
/// execution engine:
///
/// ```
/// use fmq::model::spec::ModelSpec;
/// use fmq::quant::{quantize_model, QuantMethod};
/// use fmq::util::rng::Pcg64;
///
/// let spec = ModelSpec::default_spec();
/// let theta = spec.init_theta(&mut Pcg64::seed(1));
/// let qm = quantize_model(&spec, &theta, QuantMethod::Uniform, 3);
/// assert_eq!(qm.codes.len(), spec.pw());        // one code per weight
/// assert_eq!(qm.biases.len(), spec.pb());       // biases stay fp32
/// assert!(qm.compression_ratio() > 8.0);        // 3-bit codes vs f32
/// ```
pub fn quantize_model(
    spec: &ModelSpec,
    theta: &ParamStore,
    method: QuantMethod,
    bits: u8,
) -> QuantizedModel {
    let mut codebooks = Vec::new();
    let mut codes: Vec<u32> = Vec::with_capacity(spec.pw());
    for layer in spec.weight_layers() {
        let w = theta.layer(spec, &layer.name);
        let (cb, c) = quantize_tensor(method, w, bits);
        codebooks.push(cb);
        codes.extend_from_slice(&c);
    }
    let mut biases: Vec<f32> = Vec::with_capacity(spec.pb());
    for layer in spec.bias_layers() {
        biases.extend_from_slice(theta.layer(spec, &layer.name));
    }
    QuantizedModel::new(spec.clone(), method, bits, codebooks, codes, biases)
}

/// Per-channel variant of Algorithm 1 (the paper's `for c = 1..C` loop):
/// each output channel (column block of the row-major [in, out] matrix —
/// we use rows of the transposed view, i.e. per-output-column) gets its own
/// codebook. Used by the ablation bench; the serving artifact uses
/// per-tensor codebooks.
pub fn quantize_per_channel(
    method: QuantMethod,
    w: &[f32],
    rows: usize,
    cols: usize,
    bits: u8,
) -> (Vec<Codebook>, Vec<u32>) {
    assert_eq!(w.len(), rows * cols);
    // gather each output channel (column) contiguously
    let mut cbs = Vec::with_capacity(cols);
    let mut codes = vec![0u32; w.len()];
    let mut chan = vec![0f32; rows];
    for c in 0..cols {
        for r in 0..rows {
            chan[r] = w[r * cols + c];
        }
        let (cb, ch_codes) = quantize_tensor(method, &chan, bits);
        for r in 0..rows {
            codes[r * cols + c] = ch_codes[r];
        }
        cbs.push(cb);
    }
    (cbs, codes)
}

/// Dequantize per-channel codes back to a dense matrix.
pub fn dequant_per_channel(
    cbs: &[Codebook],
    codes: &[u32],
    rows: usize,
    cols: usize,
) -> Vec<f32> {
    let mut out = vec![0f32; rows * cols];
    for r in 0..rows {
        for c in 0..cols {
            out[r * cols + c] = cbs[c].levels[codes[r * cols + c] as usize];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::mse;
    use crate::util::rng::Pcg64;

    #[test]
    fn method_names_roundtrip() {
        for m in QuantMethod::ALL {
            assert_eq!(QuantMethod::parse(m.name()), Some(m));
        }
        assert_eq!(QuantMethod::parse("float"), None);
    }

    #[test]
    fn quantize_tensor_all_methods_all_bits() {
        let mut rng = Pcg64::seed(1);
        let w: Vec<f32> = (0..4096).map(|_| rng.normal_f32(0.0, 0.05)).collect();
        for m in QuantMethod::ALL {
            for bits in 2..=8u8 {
                let (cb, codes) = quantize_tensor(m, &w, bits);
                assert!(cb.levels.len() <= 1usize << bits);
                assert_eq!(codes.len(), w.len());
                let deq = cb.dequant(&codes);
                // error must be bounded by the weight range
                let range = 2.0 * w.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
                for (x, y) in w.iter().zip(deq.iter()) {
                    assert!((x - y).abs() <= range, "{m:?} b={bits}");
                }
            }
        }
    }

    /// The paper's premise (Fig. 3), measured honestly: equal-mass OT wins
    /// decisively in the low-bit regime (2–4 bits — the paper's headline
    /// territory); at ≥5 bits on *clean Gaussians with a tight empirical
    /// R* plain equal-mass can trail uniform slightly (its tail cells are
    /// wide), but the Lloyd-refined OT codebook — the true 1-D W₂ optimum —
    /// dominates uniform at every bit-width, as optimality requires.
    #[test]
    fn ot_beats_uniform_on_gaussian_weights() {
        let mut rng = Pcg64::seed(2);
        let w: Vec<f32> = (0..65536).map(|_| rng.normal_f32(0.0, 0.05)).collect();
        for bits in 2..=8u8 {
            let (cbu, cu) = quantize_tensor(QuantMethod::Uniform, &w, bits);
            let e_un = mse(&w, &cbu.dequant(&cu));
            if bits <= 4 {
                let (cbo, co) = quantize_tensor(QuantMethod::Ot, &w, bits);
                let e_ot = mse(&w, &cbo.dequant(&co));
                assert!(e_ot <= e_un * 1.02, "bits={bits} ot={e_ot} uniform={e_un}");
            }
            // the W2-optimal (Lloyd-refined) codebook dominates uniform up
            // to Lloyd's slow high-K convergence; allow near-parity at 7-8
            // bits where both are ~1e-6 and convergence is the binder
            let iters = 100 * (1usize << bits).max(64) / 16; // more iters for larger K
            let cbr = crate::quant::otq::otq_refined_codebook(&w, bits, iters.min(1200));
            let e_ref = mse(&w, &cbr.reconstruct(&w));
            let slack = if bits <= 6 { 1.02 } else { 1.5 };
            assert!(
                e_ref <= e_un * slack,
                "bits={bits} lloyd-ot={e_ref} uniform={e_un}"
            );
        }
    }

    #[test]
    fn mse_monotone_in_bits() {
        let mut rng = Pcg64::seed(3);
        let w: Vec<f32> = (0..16384).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        for m in QuantMethod::ALL {
            let mut prev = f64::INFINITY;
            for bits in 2..=8u8 {
                let (cb, codes) = quantize_tensor(m, &w, bits);
                let e = mse(&w, &cb.dequant(&codes));
                assert!(
                    e <= prev * 1.05,
                    "{m:?}: error rose from {prev} to {e} at {bits} bits"
                );
                prev = e;
            }
        }
    }

    #[test]
    fn per_channel_beats_or_ties_per_tensor() {
        // heterogeneous channels: per-channel codebooks must win
        let mut rng = Pcg64::seed(4);
        let (rows, cols) = (256, 8);
        let mut w = vec![0f32; rows * cols];
        for c in 0..cols {
            let scale = 0.01 * (c + 1) as f32 * (c + 1) as f32;
            for r in 0..rows {
                w[r * cols + c] = rng.normal_f32(0.0, scale);
            }
        }
        let (cb, codes) = quantize_tensor(QuantMethod::Ot, &w, 3);
        let e_tensor = mse(&w, &cb.dequant(&codes));
        let (cbs, ccodes) = quantize_per_channel(QuantMethod::Ot, &w, rows, cols, 3);
        let e_chan = mse(&w, &dequant_per_channel(&cbs, &ccodes, rows, cols));
        assert!(e_chan < e_tensor, "chan={e_chan} tensor={e_tensor}");
    }
}
