//! Codebook: the K ≤ 2^b representative levels plus assignment logic.
//!
//! Levels are kept sorted, so nearest-level assignment is a binary search
//! (O(log K) per weight) instead of the naive O(K) scan — the same
//! monotone-coupling fact that makes the 1-D OT solution analytic.

/// Padding value for unused slots when a codebook is shipped to the fixed
/// K_MAX=256 artifact input (mirrors `arch.CODEBOOK_PAD` on the python side).
pub const CODEBOOK_PAD: f32 = 1.0e30;

/// A quantizer's representative levels plus assignment logic.
#[derive(Clone, Debug, PartialEq)]
pub struct Codebook {
    /// Sorted representative levels (deduplicated).
    pub levels: Vec<f32>,
    /// Bit-width this codebook was built for.
    pub bits: u8,
}

impl Codebook {
    /// Build from possibly-unsorted, possibly-duplicated levels.
    pub fn new(mut levels: Vec<f32>, bits: u8) -> Self {
        assert!(!levels.is_empty(), "empty codebook");
        assert!(levels.len() <= 1usize << bits, "too many levels for bits");
        levels.sort_by(f32::total_cmp);
        levels.dedup();
        Self { levels, bits }
    }

    /// Number of distinct levels (K ≤ 2^bits after deduplication).
    pub fn k(&self) -> usize {
        self.levels.len()
    }

    /// Index of the nearest level (ties -> lower index, matching the
    /// python `argmin` tie-break on first occurrence).
    #[inline]
    pub fn nearest(&self, x: f32) -> u32 {
        let ls = &self.levels;
        match ls.binary_search_by(|l| l.total_cmp(&x)) {
            Ok(i) => i as u32,
            Err(i) => {
                if i == 0 {
                    0
                } else if i == ls.len() {
                    (ls.len() - 1) as u32
                } else {
                    let lo = ls[i - 1];
                    let hi = ls[i];
                    // strict '<' keeps argmin's first-occurrence tie-break
                    if (x - lo) <= (hi - x) {
                        (i - 1) as u32
                    } else {
                        i as u32
                    }
                }
            }
        }
    }

    /// Assign every value to its nearest level.
    pub fn assign(&self, xs: &[f32]) -> Vec<u32> {
        xs.iter().map(|&x| self.nearest(x)).collect()
    }

    /// Reconstruct values from codes.
    pub fn dequant(&self, codes: &[u32]) -> Vec<f32> {
        codes.iter().map(|&c| self.levels[c as usize]).collect()
    }

    /// Quantize in one shot (assign + dequant), returning reconstruction.
    pub fn reconstruct(&self, xs: &[f32]) -> Vec<f32> {
        xs.iter()
            .map(|&x| self.levels[self.nearest(x) as usize])
            .collect()
    }

    /// Pad levels to `k_max` with CODEBOOK_PAD for the fixed-size artifact
    /// input.
    pub fn padded_levels(&self, k_max: usize) -> Vec<f32> {
        assert!(self.levels.len() <= k_max); // fmq-analyze: allow(panic_cone) -- k_max is the spec-wide max level count computed over these same codebooks
        let mut v = self.levels.clone();
        v.resize(k_max, CODEBOOK_PAD);
        v
    }

    /// Codebook-utilization: fraction of levels actually used by `codes`
    /// (the paper's future-work §codebook-utilization analysis).
    pub fn utilization(&self, codes: &[u32]) -> f64 {
        let mut used = vec![false; self.levels.len()];
        for &c in codes {
            used[c as usize] = true;
        }
        used.iter().filter(|&&u| u).count() as f64 / self.levels.len() as f64
    }

    /// Shannon entropy (bits) of the code distribution — effective bits
    /// actually spent vs the nominal b.
    pub fn code_entropy(&self, codes: &[u32]) -> f64 {
        if codes.is_empty() {
            return 0.0;
        }
        let mut counts = vec![0usize; self.levels.len()];
        for &c in codes {
            counts[c as usize] += 1;
        }
        let n = codes.len() as f64;
        counts
            .iter()
            .filter(|&&c| c > 0)
            .map(|&c| {
                let p = c as f64 / n;
                -p * p.log2()
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;

    #[test]
    fn nearest_basic() {
        let cb = Codebook::new(vec![-1.0, 0.0, 1.0], 2);
        assert_eq!(cb.nearest(-0.9), 0);
        assert_eq!(cb.nearest(-0.4), 1); // closer to 0
        assert_eq!(cb.nearest(0.6), 2);
        assert_eq!(cb.nearest(100.0), 2); // clamps
        assert_eq!(cb.nearest(-100.0), 0);
    }

    #[test]
    fn nearest_tie_breaks_low() {
        let cb = Codebook::new(vec![0.0, 1.0], 1);
        assert_eq!(cb.nearest(0.5), 0); // equidistant -> lower index
    }

    #[test]
    fn dedup_and_sort() {
        let cb = Codebook::new(vec![1.0, -1.0, 1.0, 0.0], 2);
        assert_eq!(cb.levels, vec![-1.0, 0.0, 1.0]);
    }

    #[test]
    fn assign_dequant_roundtrip_on_levels() {
        let cb = Codebook::new(vec![-0.5, 0.1, 0.7], 2);
        let codes = cb.assign(&cb.levels.clone());
        assert_eq!(cb.dequant(&codes), cb.levels);
    }

    #[test]
    fn padded_levels_layout() {
        let cb = Codebook::new(vec![0.0, 1.0], 3);
        let p = cb.padded_levels(8);
        assert_eq!(p.len(), 8);
        assert_eq!(&p[..2], &[0.0, 1.0]);
        assert!(p[2..].iter().all(|&v| v == CODEBOOK_PAD));
    }

    #[test]
    fn binary_search_matches_linear_scan() {
        forall("nearest == argmin scan", 200, |g| {
            let mut levels = g.f32_vec(1..=32, -2.0..=2.0);
            levels.sort_by(f32::total_cmp);
            levels.dedup();
            let cb = Codebook {
                levels: levels.clone(),
                bits: 8,
            };
            let xs = g.f32_vec(1..=64, -3.0..=3.0);
            xs.iter().all(|&x| {
                let fast = cb.nearest(x) as usize;
                // linear argmin with first-occurrence tie-break
                let mut best = 0usize;
                let mut bd = f32::INFINITY;
                for (i, &l) in levels.iter().enumerate() {
                    let d = (x - l).abs();
                    if d < bd {
                        bd = d;
                        best = i;
                    }
                }
                (cb.levels[fast] - x).abs() == (cb.levels[best] - x).abs()
            })
        });
    }

    #[test]
    fn utilization_and_entropy() {
        let cb = Codebook::new(vec![0.0, 1.0, 2.0, 3.0], 2);
        let codes = vec![0, 0, 1, 1];
        assert!((cb.utilization(&codes) - 0.5).abs() < 1e-12);
        assert!((cb.code_entropy(&codes) - 1.0).abs() < 1e-12); // two equi-likely codes
        let uniform = vec![0, 1, 2, 3];
        assert!((cb.code_entropy(&uniform) - 2.0).abs() < 1e-12);
    }
}
