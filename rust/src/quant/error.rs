//! Quantization error accounting: per-layer and whole-model W₂²/MSE plus
//! the sup-norm error that Theorem 3's worst-case analysis uses.

use super::codebook::Codebook;

/// Error summary for one quantized tensor.
#[derive(Clone, Debug)]
pub struct QuantError {
    /// Mean squared error == W₂²(P_w, Q) under the monotone coupling.
    pub w2_sq: f64,
    /// sup-norm error max |w - q(w)| (the δ of Assumption 1-B analyses).
    pub sup: f64,
    /// signed mean error (bias) — should be ~0 for centroid codebooks.
    pub bias: f64,
    /// Number of weights measured.
    pub n: usize,
}

/// Measure one tensor's quantization error against a codebook.
pub fn tensor_error(w: &[f32], cb: &Codebook) -> QuantError {
    let mut sq = 0.0f64;
    let mut sup = 0.0f64;
    let mut bias = 0.0f64;
    for &x in w {
        let q = cb.levels[cb.nearest(x) as usize];
        let d = (x - q) as f64;
        sq += d * d;
        bias += d;
        sup = sup.max(d.abs());
    }
    let n = w.len().max(1);
    QuantError {
        w2_sq: sq / n as f64,
        sup,
        bias: bias / n as f64,
        n: w.len(),
    }
}

/// Aggregate per-layer errors into model totals (size-weighted).
pub fn aggregate(errors: &[QuantError]) -> QuantError {
    let total_n: usize = errors.iter().map(|e| e.n).sum();
    let mut agg = QuantError {
        w2_sq: 0.0,
        sup: 0.0,
        bias: 0.0,
        n: total_n,
    };
    for e in errors {
        let w = e.n as f64 / total_n.max(1) as f64;
        agg.w2_sq += e.w2_sq * w;
        agg.bias += e.bias * w;
        agg.sup = agg.sup.max(e.sup);
    }
    agg
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::otq::equal_mass_codebook;
    use crate::util::rng::Pcg64;

    #[test]
    fn zero_error_when_codebook_exact() {
        let cb = Codebook::new(vec![-1.0, 0.0, 1.0], 2);
        let e = tensor_error(&[-1.0, 0.0, 1.0, 1.0], &cb);
        assert_eq!(e.w2_sq, 0.0);
        assert_eq!(e.sup, 0.0);
        assert_eq!(e.bias, 0.0);
    }

    #[test]
    fn centroid_codebooks_are_nearly_unbiased() {
        let mut rng = Pcg64::seed(1);
        let w: Vec<f32> = (0..32768).map(|_| rng.normal_f32(0.0, 0.05)).collect();
        let cb = equal_mass_codebook(&w, 4);
        let e = tensor_error(&w, &cb);
        assert!(e.bias.abs() < 2e-4, "bias={}", e.bias);
        assert!(e.w2_sq > 0.0);
        assert!(e.sup >= e.w2_sq.sqrt());
    }

    #[test]
    fn aggregate_weights_by_size() {
        let a = QuantError { w2_sq: 1.0, sup: 0.5, bias: 0.1, n: 100 };
        let b = QuantError { w2_sq: 3.0, sup: 2.0, bias: -0.1, n: 300 };
        let agg = aggregate(&[a, b]);
        assert!((agg.w2_sq - 2.5).abs() < 1e-12);
        assert_eq!(agg.sup, 2.0);
        assert!((agg.bias - (-0.05)).abs() < 1e-12);
        assert_eq!(agg.n, 400);
    }
}
