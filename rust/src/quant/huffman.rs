//! Canonical Huffman coding of quantization codes — storage beyond plain
//! bit-packing.
//!
//! Equal-mass OT codes are uniform by construction (entropy ≈ b bits →
//! incompressible; the quantizer already spent its budget optimally).
//! Uniform/log2 codes are heavily skewed (most weights fall in the few
//! central levels), so entropy coding claws back real bytes — this module
//! quantifies that trade-off (see `bench_ablations`), connecting the
//! paper's codebook-utilization future-work item to actual storage.

use std::collections::BinaryHeap;

use anyhow::{bail, Result};

/// Canonical Huffman code table: code lengths per symbol.
#[derive(Clone, Debug)]
pub struct HuffmanTable {
    /// bit length per symbol (0 = symbol absent)
    pub lengths: Vec<u8>,
    /// canonical codes, aligned with `lengths`
    codes: Vec<u32>,
}

const MAX_LEN: u8 = 32;

impl HuffmanTable {
    /// Build from symbol frequencies.
    pub fn build(freqs: &[u64]) -> Result<Self> {
        let n = freqs.len();
        if n == 0 {
            bail!("empty alphabet");
        }
        let present: Vec<usize> = (0..n).filter(|&i| freqs[i] > 0).collect();
        if present.is_empty() {
            bail!("no symbols present");
        }
        let mut lengths = vec![0u8; n];
        if present.len() == 1 {
            lengths[present[0]] = 1; // degenerate: one symbol, 1-bit code
            return Ok(Self::canonicalize(lengths));
        }
        // standard Huffman over a min-heap of (weight, node)
        #[derive(PartialEq, Eq)]
        struct Node {
            w: u64,
            id: usize,
        }
        impl Ord for Node {
            fn cmp(&self, o: &Self) -> std::cmp::Ordering {
                o.w.cmp(&self.w).then(o.id.cmp(&self.id)) // min-heap
            }
        }
        impl PartialOrd for Node {
            fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
                Some(self.cmp(o))
            }
        }
        let mut heap = BinaryHeap::new();
        // tree arena: leaves 0..n, internal nodes after
        let mut parent = vec![usize::MAX; n];
        for &i in &present {
            heap.push(Node { w: freqs[i], id: i });
        }
        while heap.len() > 1 {
            let a = heap.pop().unwrap();
            let b = heap.pop().unwrap();
            let id = parent.len();
            parent.push(usize::MAX);
            // record parents
            set_parent(&mut parent, a.id, id);
            set_parent(&mut parent, b.id, id);
            heap.push(Node {
                w: a.w + b.w,
                id,
            });
        }
        let root = heap.pop().unwrap().id;
        for &i in &present {
            let mut len = 0u8;
            let mut cur = i;
            while cur != root {
                cur = parent[cur];
                len += 1;
            }
            lengths[i] = len.max(1).min(MAX_LEN);
        }
        Ok(Self::canonicalize(lengths))
    }

    /// Assign canonical codes from lengths (shorter lengths first, then
    /// symbol order) — decodable from lengths alone.
    fn canonicalize(lengths: Vec<u8>) -> Self {
        let mut symbols: Vec<usize> = (0..lengths.len()).filter(|&i| lengths[i] > 0).collect();
        symbols.sort_by_key(|&i| (lengths[i], i));
        let mut codes = vec![0u32; lengths.len()];
        let mut code = 0u32;
        let mut prev_len = 0u8;
        for &s in &symbols {
            code <<= lengths[s] - prev_len;
            codes[s] = code;
            code += 1;
            prev_len = lengths[s];
        }
        Self { lengths, codes }
    }

    /// Encode a code stream; returns (bits, total bit count).
    pub fn encode(&self, codes: &[u32]) -> Result<(Vec<u64>, usize)> {
        let mut words = Vec::new();
        let mut acc = 0u64;
        let mut fill = 0usize;
        let mut total = 0usize;
        for &c in codes {
            let c = c as usize;
            if c >= self.lengths.len() || self.lengths[c] == 0 {
                bail!("symbol {c} not in table");
            }
            let len = self.lengths[c] as usize;
            let code = self.codes[c] as u64;
            // write MSB-first into the accumulator
            for k in (0..len).rev() {
                let bit = (code >> k) & 1;
                acc |= bit << (63 - fill);
                fill += 1;
                if fill == 64 {
                    words.push(acc);
                    acc = 0;
                    fill = 0;
                }
            }
            total += len;
        }
        if fill > 0 {
            words.push(acc);
        }
        Ok((words, total))
    }

    /// Decode `n` symbols from a bit stream.
    pub fn decode(&self, words: &[u64], total_bits: usize, n: usize) -> Result<Vec<u32>> {
        // build (length, code) -> symbol lookup
        let mut by_len: Vec<Vec<(u32, u32)>> = vec![Vec::new(); (MAX_LEN + 1) as usize];
        for (s, (&len, &code)) in self.lengths.iter().zip(self.codes.iter()).enumerate() {
            if len > 0 {
                by_len[len as usize].push((code, s as u32));
            }
        }
        for v in by_len.iter_mut() {
            v.sort_unstable();
        }
        let mut out = Vec::with_capacity(n);
        let mut pos = 0usize;
        let read_bit = |p: usize| -> u64 { (words[p / 64] >> (63 - (p % 64))) & 1 };
        while out.len() < n {
            let mut code = 0u32;
            let mut len = 0usize;
            loop {
                if pos >= total_bits {
                    bail!("bit stream exhausted after {} symbols", out.len());
                }
                code = (code << 1) | read_bit(pos) as u32;
                pos += 1;
                len += 1;
                if len > MAX_LEN as usize {
                    bail!("code longer than MAX_LEN — corrupt stream");
                }
                if let Ok(i) = by_len[len].binary_search_by_key(&code, |&(c, _)| c) {
                    out.push(by_len[len][i].1);
                    break;
                }
            }
        }
        Ok(out)
    }

    /// Expected bits/symbol under the given frequency distribution.
    pub fn expected_bits(&self, freqs: &[u64]) -> f64 {
        let total: u64 = freqs.iter().sum();
        if total == 0 {
            return 0.0;
        }
        freqs
            .iter()
            .zip(self.lengths.iter())
            .map(|(&f, &l)| f as f64 * l as f64)
            .sum::<f64>()
            / total as f64
    }
}

fn set_parent(parent: &mut [usize], child: usize, p: usize) {
    parent[child] = p;
}

/// Frequencies of a code stream over alphabet size k.
pub fn frequencies(codes: &[u32], k: usize) -> Vec<u64> {
    let mut f = vec![0u64; k];
    for &c in codes {
        f[c as usize] += 1;
    }
    f
}

/// Compressed size (bytes) of a code stream under Huffman vs plain b-bit
/// packing. Returns (huffman_bytes, packed_bytes).
pub fn compare_storage(codes: &[u32], bits: u8, k: usize) -> Result<(usize, usize)> {
    let freqs = frequencies(codes, k);
    let table = HuffmanTable::build(&freqs)?;
    let (_, total_bits) = table.encode(codes)?;
    // + table overhead: one length byte per symbol
    let huff = total_bits.div_ceil(8) + k;
    let packed = (codes.len() * bits as usize).div_ceil(8);
    Ok((huff, packed))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;
    use crate::util::rng::Pcg64;

    #[test]
    fn roundtrip_skewed_distribution() {
        let mut rng = Pcg64::seed(1);
        // zipf-ish: symbol i with weight 1/(i+1)^2
        let w: Vec<f32> = (0..16).map(|i| 1.0 / ((i + 1) as f32).powi(2)).collect();
        let codes: Vec<u32> = (0..10_000).map(|_| rng.pick_weighted(&w) as u32).collect();
        let freqs = frequencies(&codes, 16);
        let t = HuffmanTable::build(&freqs).unwrap();
        let (words, bits) = t.encode(&codes).unwrap();
        let back = t.decode(&words, bits, codes.len()).unwrap();
        assert_eq!(back, codes);
        // skewed -> fewer than 4 bits/symbol on average
        assert!(
            (bits as f64 / codes.len() as f64) < 3.0,
            "{} bits/sym",
            bits as f64 / codes.len() as f64
        );
    }

    #[test]
    fn uniform_codes_near_b_bits() {
        let mut rng = Pcg64::seed(2);
        let codes: Vec<u32> = (0..20_000).map(|_| rng.below(16) as u32).collect();
        let t = HuffmanTable::build(&frequencies(&codes, 16)).unwrap();
        let (_, bits) = t.encode(&codes).unwrap();
        let per = bits as f64 / codes.len() as f64;
        assert!((3.9..=4.3).contains(&per), "{per} bits/sym");
    }

    #[test]
    fn near_entropy_optimal() {
        let mut rng = Pcg64::seed(3);
        let w = [8.0f32, 4.0, 2.0, 1.0, 1.0];
        let codes: Vec<u32> = (0..50_000).map(|_| rng.pick_weighted(&w) as u32).collect();
        let freqs = frequencies(&codes, 5);
        let t = HuffmanTable::build(&freqs).unwrap();
        let total: u64 = freqs.iter().sum();
        let entropy: f64 = freqs
            .iter()
            .filter(|&&f| f > 0)
            .map(|&f| {
                let p = f as f64 / total as f64;
                -p * p.log2()
            })
            .sum();
        let avg = t.expected_bits(&freqs);
        assert!(avg >= entropy - 1e-9);
        assert!(avg <= entropy + 1.0, "avg {avg} vs entropy {entropy}"); // Huffman <= H+1
    }

    #[test]
    fn degenerate_single_symbol() {
        let codes = vec![3u32; 100];
        let t = HuffmanTable::build(&frequencies(&codes, 8)).unwrap();
        let (words, bits) = t.encode(&codes).unwrap();
        assert_eq!(bits, 100); // 1 bit each
        assert_eq!(t.decode(&words, bits, 100).unwrap(), codes);
    }

    #[test]
    fn rejects_unknown_symbol() {
        let t = HuffmanTable::build(&[10, 10, 0, 0]).unwrap();
        assert!(t.encode(&[2]).is_err());
    }

    #[test]
    fn prop_roundtrip_random_alphabets() {
        forall("huffman roundtrip", 60, |g| {
            let k = g.usize_in(1..=64);
            let n = g.len(1..=400);
            let codes: Vec<u32> = (0..n).map(|_| g.usize_in(0..=k - 1) as u32).collect();
            let t = match HuffmanTable::build(&frequencies(&codes, k)) {
                Ok(t) => t,
                Err(_) => return false,
            };
            let (words, bits) = t.encode(&codes).unwrap();
            t.decode(&words, bits, codes.len()).unwrap() == codes
        });
    }

    /// The storage story: OT codes are ~incompressible (already optimal),
    /// uniform codes compress well — the information-theoretic echo of the
    /// equal-mass construction.
    #[test]
    fn ot_codes_incompressible_uniform_codes_compress() {
        use crate::quant::{quantize_tensor, QuantMethod};
        let mut rng = Pcg64::seed(4);
        let w: Vec<f32> = (0..32768).map(|_| rng.normal_f32(0.0, 0.05)).collect();
        let (_, ot_codes) = quantize_tensor(QuantMethod::Ot, &w, 4);
        let (_, un_codes) = quantize_tensor(QuantMethod::Uniform, &w, 4);
        let (ot_h, ot_p) = compare_storage(&ot_codes, 4, 16).unwrap();
        let (un_h, un_p) = compare_storage(&un_codes, 4, 16).unwrap();
        assert_eq!(ot_p, un_p);
        // OT: huffman within ~5% of packed; uniform: >= 15% smaller
        assert!(ot_h as f64 >= 0.95 * ot_p as f64, "ot {ot_h} vs {ot_p}");
        assert!(un_h as f64 <= 0.85 * un_p as f64, "uniform {un_h} vs {un_p}");
    }
}
