//! Piecewise-linear (PWL) quantization baseline.
//!
//! Two uniform grids: a dense one over the central "core" of the
//! distribution (between the 2.5% and 97.5% quantiles) holding 3/4 of the
//! levels, and a sparse one over the tails holding the rest. This is the
//! standard piecewise-linear PTQ construction the paper benchmarks as
//! "PWL": better than plain uniform on peaked distributions, but not
//! mass-adaptive like OT.

use super::codebook::Codebook;
use crate::stats::{quantile_sorted, sorted_copy};

/// Build the piecewise-linear codebook: 3/4 of the levels on a dense
/// grid over the 2.5%–97.5% quantile core, the rest over the tails.
pub fn pwl_codebook(w: &[f32], bits: u8) -> Codebook {
    let k = 1usize << bits;
    let s = sorted_copy(w);
    let lo = s[0];
    let hi = s[s.len() - 1];
    let core_lo = quantile_sorted(&s, 0.025);
    let core_hi = quantile_sorted(&s, 0.975);

    // degenerate core -> plain uniform over [lo, hi]
    if core_hi <= core_lo || k < 4 {
        let span = (hi - lo).max(1e-12);
        let levels = (0..k)
            .map(|i| lo + span * (i as f32 + 0.5) / k as f32)
            .collect();
        return Codebook::new(levels, bits);
    }

    let k_core = (3 * k) / 4;
    let k_tail = k - k_core;
    let mut levels = Vec::with_capacity(k);
    // dense core grid (cell centers)
    let core_span = core_hi - core_lo;
    for i in 0..k_core {
        levels.push(core_lo + core_span * (i as f32 + 0.5) / k_core as f32);
    }
    // sparse tails: split remaining levels between the two tails by span
    let left_span = (core_lo - lo).max(0.0);
    let right_span = (hi - core_hi).max(0.0);
    let total = (left_span + right_span).max(1e-12);
    let k_left = ((k_tail as f32 * left_span / total).round() as usize).min(k_tail);
    let k_right = k_tail - k_left;
    for i in 0..k_left {
        levels.push(lo + left_span * (i as f32 + 0.5) / k_left as f32);
    }
    for i in 0..k_right {
        levels.push(core_hi + right_span * (i as f32 + 0.5) / k_right as f32);
    }
    Codebook::new(levels, bits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::uniform::uniform_codebook;
    use crate::stats::mse;
    use crate::util::rng::Pcg64;

    #[test]
    fn level_count_within_budget() {
        let mut rng = Pcg64::seed(1);
        let w: Vec<f32> = (0..4096).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        for bits in 2..=8u8 {
            let cb = pwl_codebook(&w, bits);
            assert!(cb.k() <= 1usize << bits);
            assert!(cb.k() >= (1usize << bits) / 2);
        }
    }

    #[test]
    fn beats_uniform_on_outlier_heavy_weights() {
        // with outliers, PWL's dense core should beat plain uniform
        let mut rng = Pcg64::seed(2);
        let mut w: Vec<f32> = (0..8192).map(|_| rng.normal_f32(0.0, 0.05)).collect();
        for _ in 0..16 {
            w.push(rng.normal_f32(0.0, 2.0)); // heavy outliers
        }
        let e_pwl = mse(&w, &pwl_codebook(&w, 4).reconstruct(&w));
        let e_uni = mse(&w, &uniform_codebook(&w, 4).reconstruct(&w));
        assert!(e_pwl < e_uni, "pwl={e_pwl} uniform={e_uni}");
    }

    #[test]
    fn covers_full_range() {
        let mut rng = Pcg64::seed(3);
        let w: Vec<f32> = (0..2048).map(|_| rng.normal_f32(0.0, 0.3)).collect();
        let cb = pwl_codebook(&w, 5);
        let min_w = w.iter().fold(f32::INFINITY, |a, &b| a.min(b));
        let max_w = w.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        // extreme values reconstruct within one tail-cell width
        let rec = cb.reconstruct(&[min_w, max_w]);
        assert!((rec[0] - min_w).abs() < (max_w - min_w) * 0.2);
        assert!((rec[1] - max_w).abs() < (max_w - min_w) * 0.2);
    }

    #[test]
    fn degenerate_inputs() {
        let cb = pwl_codebook(&[1.0; 64], 4);
        assert!(cb.k() >= 1);
        let rec = cb.reconstruct(&[1.0]);
        assert!((rec[0] - 1.0).abs() < 0.51);
        let cb2 = pwl_codebook(&[0.0, 1.0], 2);
        assert!(cb2.k() <= 4);
    }
}
