//! Post-quantization bias correction — standard PTQ practice (cf. the
//! calibrated-rounding GAN work the paper cites) adapted to the FM
//! velocity network.
//!
//! Quantizing W perturbs each linear layer's output by x·ΔW; over a
//! calibration batch the *mean* of that perturbation is a constant vector
//! that can be folded into the (fp32) bias for free:
//!
//! ```text
//! b' = b − E_x[ x·ΔW ] = b − E[x]·(W_q − W)
//! ```
//!
//! Equal-mass OT codebooks are already nearly unbiased per weight, but
//! the *output* bias after the matmul is not zero for finite calibration
//! distributions; the correction helps every method and is largest for
//! the skewed baselines. Measured in `bench_ablations`-style tests below.

use crate::model::params::ParamStore;
use crate::model::quantized::QuantizedModel;
use crate::model::spec::ModelSpec;
use crate::tensor::matmul_into;
use crate::util::rng::Pcg64;

/// Mean activations feeding each weight layer, collected by running the
/// fp32 CPU forward on a calibration batch and recording layer inputs.
/// The forward here mirrors `flow::cpu_ref` (kept in lockstep by the
/// equivalence test below).
pub struct Calibration {
    /// mean input vector per weight layer, keyed by layer order
    pub mean_inputs: Vec<Vec<f32>>,
}

/// Collect per-layer mean inputs by running the fp32 forward on
/// `n_samples` draws from the sampling distribution (x ~ N(0,I),
/// t ~ U\[0,1\]).
pub fn calibrate(
    spec: &ModelSpec,
    theta: &ParamStore,
    rng: &mut Pcg64,
    n_samples: usize,
) -> Calibration {
    let d = spec.d;
    let h = spec.hidden;
    let temb_dim = 2 * spec.temb_freqs;
    let b = n_samples;
    // calibration inputs: the sampling distribution x ~ N(0, I), t ~ U[0,1]
    let x: Vec<f32> = (0..b * d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let t: Vec<f32> = (0..b).map(|_| rng.uniform() as f32).collect();

    let mean_of = |m: &[f32], cols: usize| -> Vec<f32> {
        let rows = m.len() / cols;
        let mut out = vec![0f32; cols];
        for r in 0..rows {
            for c in 0..cols {
                out[c] += m[r * cols + c];
            }
        }
        for v in out.iter_mut() {
            *v /= rows as f32;
        }
        out
    };

    // replicate the forward, capturing inputs in weight-layer order:
    // w_in, w_t, (w1_i, w2_i)*, w_out — note spec.weight_layers() order is
    // w_in, w_t, w1_0, w2_0, ..., w_out
    let temb = crate::flow::cpu_ref::time_features(spec, &t);
    let mut inputs: Vec<Vec<f32>> = Vec::new();

    // ht = silu(temb @ w_t + b_t)
    let mut ht = vec![0f32; b * h];
    matmul_into(&temb, theta.layer(spec, "w_t"), &mut ht, b, temb_dim, h);
    let b_t = theta.layer(spec, "b_t");
    for r in ht.chunks_mut(h) {
        for (v, &bb) in r.iter_mut().zip(b_t.iter()) {
            let z = *v + bb;
            *v = z / (1.0 + (-z).exp());
        }
    }
    // h = x @ w_in + b_in + ht
    let mut hh = vec![0f32; b * h];
    matmul_into(&x, theta.layer(spec, "w_in"), &mut hh, b, d, h);
    let b_in = theta.layer(spec, "b_in");
    for (r, rt) in hh.chunks_mut(h).zip(ht.chunks(h)) {
        for ((v, &bb), &tv) in r.iter_mut().zip(b_in.iter()).zip(rt.iter()) {
            *v += bb + tv;
        }
    }
    let w_in_mean = mean_of(&x, d);
    let w_t_mean = mean_of(&temb, temb_dim);

    let mut block_means = Vec::new();
    let mut u = vec![0f32; b * h];
    let mut r2 = vec![0f32; b * h];
    for i in 0..spec.blocks {
        let in1 = mean_of(&hh, h);
        u.iter_mut().for_each(|v| *v = 0.0);
        matmul_into(&hh, theta.layer(spec, &format!("w1_{i}")), &mut u, b, h, h);
        let b1 = theta.layer(spec, &format!("b1_{i}"));
        for r in u.chunks_mut(h) {
            for (v, &bb) in r.iter_mut().zip(b1.iter()) {
                let z = *v + bb;
                *v = z / (1.0 + (-z).exp());
            }
        }
        let in2 = mean_of(&u, h);
        r2.iter_mut().for_each(|v| *v = 0.0);
        matmul_into(&u, theta.layer(spec, &format!("w2_{i}")), &mut r2, b, h, h);
        let b2 = theta.layer(spec, &format!("b2_{i}"));
        for (hr, rr) in hh.chunks_mut(h).zip(r2.chunks(h)) {
            for ((v, &rv), &bb) in hr.iter_mut().zip(rr.iter()).zip(b2.iter()) {
                *v += rv + bb;
            }
        }
        block_means.push((in1, in2));
    }
    let w_out_mean = mean_of(&hh, h);

    inputs.push(w_in_mean);
    inputs.push(w_t_mean);
    for (in1, in2) in block_means {
        inputs.push(in1);
        inputs.push(in2);
    }
    inputs.push(w_out_mean);
    Calibration {
        mean_inputs: inputs,
    }
}

/// Bias layer fed by each weight layer, in `spec.weight_layers()` order.
fn bias_for(weight_name: &str) -> String {
    match weight_name {
        "w_in" => "b_in".to_string(),
        "w_t" => "b_t".to_string(),
        "w_out" => "b_out".to_string(),
        other => {
            // w1_i -> b1_i, w2_i -> b2_i
            other.replacen('w', "b", 1)
        }
    }
}

/// Apply bias correction in place: b ← b − E[x]·(W_q − W).
pub fn correct_biases(qm: &mut QuantizedModel, theta: &ParamStore, calib: &Calibration) {
    let spec = qm.spec.clone();
    for (row, l) in spec.weight_layers().iter().enumerate() {
        let (rows, cols) = (l.shape[0], l.shape[1]);
        let mean_in = &calib.mean_inputs[row];
        assert_eq!(mean_in.len(), rows, "calibration shape for {}", l.name);
        let w = theta.layer(&spec, &l.name);
        let woff = spec.weight_offset(&l.name);
        let cb = &qm.codebooks[row];
        // delta_out[c] = sum_r mean_in[r] * (Wq[r,c] - W[r,c])
        let mut delta = vec![0f32; cols];
        for r in 0..rows {
            let mi = mean_in[r];
            if mi == 0.0 {
                continue;
            }
            for c in 0..cols {
                let idx = r * cols + c;
                let wq = cb.levels[qm.codes[woff + idx] as usize];
                delta[c] += mi * (wq - w[idx]);
            }
        }
        let bname = bias_for(&l.name);
        let boff = spec.bias_offset(&bname);
        for c in 0..cols {
            qm.biases[boff + c] -= delta[c];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flow::cpu_ref;
    use crate::quant::{quantize_model, QuantMethod};

    #[test]
    fn bias_for_names() {
        assert_eq!(bias_for("w_in"), "b_in");
        assert_eq!(bias_for("w1_2"), "b1_2");
        assert_eq!(bias_for("w2_0"), "b2_0");
        assert_eq!(bias_for("w_out"), "b_out");
    }

    #[test]
    fn calibration_shapes_match_weight_layers() {
        let spec = ModelSpec::default_spec();
        let mut rng = Pcg64::seed(1);
        let theta = spec.init_theta(&mut rng);
        let calib = calibrate(&spec, &theta, &mut rng, 8);
        let wl = spec.weight_layers();
        assert_eq!(calib.mean_inputs.len(), wl.len());
        for (m, l) in calib.mean_inputs.iter().zip(wl.iter()) {
            assert_eq!(m.len(), l.shape[0], "layer {}", l.name);
        }
    }

    /// The headline: on the calibration distribution, bias correction
    /// reduces the quantized velocity's error vs fp32 (mean-zero residual)
    /// at low bit-widths.
    #[test]
    fn correction_reduces_velocity_error_at_low_bits() {
        let spec = ModelSpec::default_spec();
        let mut rng = Pcg64::seed(2);
        let theta = spec.init_theta(&mut rng);
        let calib = calibrate(&spec, &theta, &mut rng, 64);
        for method in [QuantMethod::Uniform, QuantMethod::Log2, QuantMethod::Ot] {
            let qm_raw = quantize_model(&spec, &theta, method, 2);
            let mut qm_fix = qm_raw.clone();
            correct_biases(&mut qm_fix, &theta, &calib);
            // evaluate on fresh draws from the same distribution
            let b = 16;
            let x: Vec<f32> = (0..b * spec.d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let t: Vec<f32> = (0..b).map(|_| rng.uniform() as f32).collect();
            let v = cpu_ref::velocity(&spec, &theta, &x, &t);
            let err = |qm: &QuantizedModel| -> f64 {
                let vq = cpu_ref::qvelocity(qm, &x, &t);
                v.iter()
                    .zip(vq.iter())
                    .map(|(&a, &b)| ((a - b) as f64).powi(2))
                    .sum::<f64>()
            };
            let e_raw = err(&qm_raw);
            let e_fix = err(&qm_fix);
            assert!(
                e_fix <= e_raw * 1.02,
                "{method:?}: corrected {e_fix} vs raw {e_raw}"
            );
        }
    }

    /// Correction must not touch codes or codebooks — only biases.
    #[test]
    fn correction_only_changes_biases() {
        let spec = ModelSpec::default_spec();
        let mut rng = Pcg64::seed(3);
        let theta = spec.init_theta(&mut rng);
        let calib = calibrate(&spec, &theta, &mut rng, 16);
        let qm0 = quantize_model(&spec, &theta, QuantMethod::Uniform, 3);
        let mut qm1 = qm0.clone();
        correct_biases(&mut qm1, &theta, &calib);
        assert_eq!(qm0.codes, qm1.codes);
        for (a, b) in qm0.codebooks.iter().zip(qm1.codebooks.iter()) {
            assert_eq!(a.levels, b.levels);
        }
        assert_ne!(qm0.biases, qm1.biases);
    }
}
