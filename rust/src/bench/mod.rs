//! Micro/meso benchmark harness (criterion stand-in).
//!
//! Adaptive: calibrates iterations to a target measurement window, then
//! reports mean / p50 / p95 / min plus derived throughput. All `cargo
//! bench` targets (`benches/*.rs`, `harness = false`) use this; see
//! docs/BENCHMARKS.md for how to run them and read the output.

use std::time::Instant;

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
}

impl BenchResult {
    pub fn per_sec(&self) -> f64 {
        1.0 / self.mean_s
    }

    /// Throughput given a per-iteration element count.
    pub fn throughput(&self, elems_per_iter: f64) -> f64 {
        elems_per_iter / self.mean_s
    }

    pub fn report_line(&self) -> String {
        format!(
            "{:<44} {:>10} {:>12} {:>12} {:>12}",
            self.name,
            fmt_time(self.mean_s),
            fmt_time(self.p50_s),
            fmt_time(self.p95_s),
            format!("x{}", self.iters),
        )
    }
}

pub fn fmt_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.3}ms", s * 1e3)
    } else {
        format!("{:.3}s", s)
    }
}

/// Benchmark runner with a wall-clock budget per case.
pub struct Bencher {
    /// target total measurement time per case (seconds)
    pub budget_s: f64,
    /// minimum timed iterations
    pub min_iters: usize,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new(0.6)
    }
}

impl Bencher {
    pub fn new(budget_s: f64) -> Self {
        // honor FMQ_BENCH_FAST=1 for CI smoke runs
        let budget_s = if std::env::var("FMQ_BENCH_FAST").is_ok() {
            budget_s.min(0.05)
        } else {
            budget_s
        };
        println!(
            "{:<44} {:>10} {:>12} {:>12} {:>12}",
            "benchmark", "mean", "p50", "p95", "iters"
        );
        Self {
            budget_s,
            min_iters: 5,
            results: Vec::new(),
        }
    }

    /// Time `f`, which must do one unit of work per call. The closure's
    /// return value is black-boxed so the work is not optimized away.
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchResult {
        // warmup + calibration
        let t0 = Instant::now();
        std::hint::black_box(f());
        let once = t0.elapsed().as_secs_f64().max(1e-9);
        let iters = ((self.budget_s / once) as usize).clamp(self.min_iters, 100_000);

        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t = Instant::now();
            std::hint::black_box(f());
            samples.push(t.elapsed().as_secs_f64());
        }
        samples.sort_by(f64::total_cmp);
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let r = BenchResult {
            name: name.to_string(),
            iters,
            mean_s: mean,
            p50_s: samples[samples.len() / 2],
            p95_s: samples[((samples.len() as f64 * 0.95) as usize).min(samples.len() - 1)],
            min_s: samples[0],
        };
        println!("{}", r.report_line());
        self.results.push(r);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Print a throughput footnote for the last benchmark.
    pub fn note_throughput(&self, elems: f64, unit: &str) {
        if let Some(r) = self.results.last() {
            println!(
                "{:<44}   -> {:.3e} {unit}/s",
                format!("  ({})", r.name),
                r.throughput(elems)
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_and_records() {
        std::env::set_var("FMQ_BENCH_FAST", "1");
        let mut b = Bencher::new(0.02);
        let r = b.bench("noop-sum", || (0..100u64).sum::<u64>()).clone();
        assert!(r.mean_s > 0.0);
        assert!(r.p50_s <= r.p95_s + 1e-12);
        assert_eq!(b.results().len(), 1);
        assert!(r.per_sec() > 0.0);
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(2e-9).ends_with("ns"));
        assert!(fmt_time(2e-6).ends_with("us"));
        assert!(fmt_time(2e-3).ends_with("ms"));
        assert!(fmt_time(2.0).ends_with('s'));
    }
}
