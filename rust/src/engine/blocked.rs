//! Blocked LUT-GEMM v2: fused multi-code lookup tables over packed codes.
//!
//! The v1 kernel ([`crate::engine::lut::LutLayer::matmul_into`]) pays one
//! byte load + one table load + one read-modify-write of the output row
//! *per weight*. This kernel restructures the same math around three
//! ideas:
//!
//! * **Bulk tile decode** — each tile's code rows stream out of the
//!   packed words through [`PackedCodes::unpack_bulk_u8`] (one 64-bit
//!   buffer refill per word instead of per-element bit arithmetic) into
//!   a scratch that is reused across tiles, layers and calls.
//! * **Fused code groups** — `group = ⌊8 / bits⌋` adjacent weight rows
//!   combine into a *single* u8 index (`c₀ | c₁≪b | …`), precomputed
//!   once per tile and shared by every batch row. A 256-slot product
//!   table per group (`tab[idx] = Σⱼ aⱼ·levels[cⱼ]`, built by iterative
//!   expansion in O(table size)) then turns the inner loop into **one
//!   byte load + one table load + one add per `group` weights** — at 2
//!   bits, a 4× cut in inner-loop memory traffic over v1.
//! * **Register-paired sweeps** — consecutive group tables are applied
//!   in pairs (`out[j] += tabA[iA] + tabB[iB]`), halving output-row
//!   read-modify-writes again and giving the scalar pipeline two
//!   independent gathers per iteration.
//!
//! Accumulation order per output element is: ascending fused groups,
//! paired — fixed by `group` (a pure function of bits) and *independent
//! of `k_tile`, batch split and column split*, so results are
//! bit-identical across tile plans, thread counts and shardings. Versus
//! the reference dequantize-then-GEMM order the association differs
//! (groups sum before touching the accumulator), which stays well inside
//! the `|engine − cpu_ref| < 1e-5` equivalence harness.

use crate::engine::lut::LutLayer;
use crate::engine::tune::{TilePlan, Tuner};
use crate::engine::workspace::{take_zeroed, Kernel};
use crate::quant::packing::PackedCodes;

/// Slice → fixed-size array for the sweep blocks below. Callers slice
/// exactly `N` elements (`chunks_exact(N)` chunks, `q * N..(q + 1) * N`
/// table windows), so the conversion is infallible by construction.
#[inline]
fn arr<T, const N: usize>(s: &[T]) -> &[T; N] {
    // fmq-analyze: allow(panic_cone) -- every caller slices exactly N elements (chunks_exact / N-wide windows), so try_into cannot fail
    s.try_into().unwrap()
}

/// Mutable twin of [`arr`], for the unrolled output blocks.
#[inline]
fn arr_mut<T, const N: usize>(s: &mut [T]) -> &mut [T; N] {
    // fmq-analyze: allow(panic_cone) -- same contract as `arr`: callers pass exactly N elements
    s.try_into().unwrap()
}

/// Output elements per unrolled sweep block. Eight f32 lanes = one AVX2
/// register width; the fixed-size-array block below removes every bounds
/// check so the compiler is free to vectorize the adds and interleave
/// the (inherently scalar) table gathers.
const LANES: usize = 8;

/// Reusable scratch for the blocked kernel: decoded tile codes, fused
/// group indices, and the per-batch-row product tables. Lives inside a
/// [`crate::engine::workspace::Workspace`] (one per worker thread);
/// `resize` keeps capacity across calls so the hot path never allocates
/// after warm-up.
#[derive(Default)]
pub struct Scratch {
    /// Decoded tile codes, row-major `[k_tile, width]`.
    codes: Vec<u8>,
    /// Fused group indices, row-major `[k_tile / group, width]`.
    fused: Vec<u8>,
    /// Product tables, 256 slots per group (`[k_tile / group, 256]`).
    tabs: Vec<f32>,
}

impl Scratch {
    /// Empty scratch (buffers grow on first use).
    pub fn new() -> Self {
        Self::default()
    }

    /// Bytes currently held (== the high-water mark; buffers never
    /// shrink). Part of the workspace accounting the `stats` op reports.
    pub fn bytes(&self) -> usize {
        self.codes.capacity() + self.fused.capacity() + self.tabs.capacity() * 4
    }
}

/// `out[m, c1-c0] += x[m, rows] @ W[:, c0..c1]` with W gathered from the
/// packed codes via fused group tables. `out` is row-major with row
/// stride `c1 - c0`; the caller zeroes (or pre-loads) it. The full-width
/// case is `c0 = 0, c1 = layer.cols`.
#[allow(clippy::too_many_arguments)]
#[fmq_macros::no_alloc]
pub fn matmul_stripe(
    layer: &LutLayer,
    x: &[f32],
    out: &mut [f32],
    m: usize,
    c0: usize,
    c1: usize,
    plan: TilePlan,
    scratch: &mut Scratch,
) {
    let (kd, n) = (layer.rows, layer.cols);
    debug_assert!(c0 <= c1 && c1 <= n);
    let w = c1 - c0;
    debug_assert_eq!(x.len(), m * kd);
    debug_assert_eq!(out.len(), m * w);
    if w == 0 || m == 0 || kd == 0 {
        return;
    }
    let span = crate::obs::Span::begin();
    // max(1) is identity after the clamps; it pins the nonzero divisors
    // for the panic-cone pass
    let bits = (layer.packed.bits.clamp(1, 8) as usize).max(1);
    let levels: &[f32] = &layer.levels;
    let klen = levels.len();
    // group is capped by the 8-bit fused index; k_tile aligns to pair
    // boundaries so the accumulation order is plan-invariant
    let g = plan.group.clamp(1, 8 / bits).max(1);
    let align = 2 * g;
    let k_tile = plan.k_tile.max(align).div_ceil(align) * align;
    let quads_max = k_tile / g;
    scratch.codes.resize(k_tile * w, 0);
    scratch.fused.resize(quads_max * w, 0);
    scratch.tabs.resize(quads_max * 256, 0.0);

    let mut k0 = 0usize;
    while k0 < kd {
        let kt = k_tile.min(kd - k0);
        let nq = kt.div_ceil(g);
        // 1) decode this tile's code rows for the column stripe
        for r in 0..kt {
            let dst = &mut scratch.codes[r * w..(r + 1) * w];
            layer.packed.unpack_bulk_u8((k0 + r) * n + c0, dst);
        }
        // 2) fuse each group of g code rows into one u8 index per column
        //    (shared by every batch row)
        {
            let (codes, fused) = (&scratch.codes, &mut scratch.fused);
            for q in 0..nq {
                let r0 = q * g;
                let gl = g.min(kt - r0);
                let frow = &mut fused[q * w..(q + 1) * w];
                frow.copy_from_slice(&codes[r0 * w..(r0 + 1) * w]);
                for j in 1..gl {
                    let crow = &codes[(r0 + j) * w..(r0 + j + 1) * w];
                    let sh = (j * bits) as u32;
                    for (fv, &cv) in frow.iter_mut().zip(crow.iter()) {
                        *fv |= cv << sh;
                    }
                }
            }
        }
        // 3) per batch row: build the fused product tables, then sweep
        for i in 0..m {
            let xrow = &x[i * kd + k0..i * kd + k0 + kt];
            for q in 0..nq {
                let r0 = q * g;
                let gl = g.min(kt - r0);
                let tab = &mut scratch.tabs[q * 256..(q + 1) * 256];
                let a0 = xrow[r0];
                for (t, &lev) in tab[..klen].iter_mut().zip(levels.iter()) {
                    *t = a0 * lev;
                }
                // iterative expansion: row j adds its products to every
                // prefix combination; descending c keeps it in place
                let mut width = 1usize << bits;
                for j in 1..gl {
                    let aj = xrow[r0 + j];
                    let sh = j * bits;
                    for c in (0..klen).rev() {
                        let p = aj * levels[c];
                        let dst0 = c << sh;
                        for idx in 0..width {
                            tab[dst0 + idx] = tab[idx] + p;
                        }
                    }
                    width <<= bits;
                }
            }
            let orow = &mut out[i * w..(i + 1) * w];
            let tabs = &scratch.tabs;
            let fused = &scratch.fused;
            // paired sweep: two group tables per pass over the output
            // row, in 8-lane unrolled blocks. Converting each chunk to a
            // fixed-size array (slice-pattern bounds-check elimination)
            // gives the compiler a known trip count, so the adds
            // vectorize and the two gather chains per lane overlap.
            // Per-element accumulation order is unchanged vs the scalar
            // loop — the blocking is numerically invisible.
            let mut q = 0usize;
            while q + 1 < nq {
                let ta: &[f32; 256] = arr(&tabs[q * 256..(q + 1) * 256]);
                let tb: &[f32; 256] = arr(&tabs[(q + 1) * 256..(q + 2) * 256]);
                let fa = &fused[q * w..(q + 1) * w];
                let fb = &fused[(q + 1) * w..(q + 2) * w];
                let mut oc = orow.chunks_exact_mut(LANES);
                let mut ac = fa.chunks_exact(LANES);
                let mut bc = fb.chunks_exact(LANES);
                for ((o, ca), cb) in (&mut oc).zip(&mut ac).zip(&mut bc) {
                    let o: &mut [f32; LANES] = arr_mut(o);
                    let ca: &[u8; LANES] = arr(ca);
                    let cb: &[u8; LANES] = arr(cb);
                    for ((ov, &a), &b) in o.iter_mut().zip(ca.iter()).zip(cb.iter()) {
                        *ov += ta[a as usize] + tb[b as usize];
                    }
                }
                for ((o, &ca), &cb) in oc
                    .into_remainder()
                    .iter_mut()
                    .zip(ac.remainder().iter())
                    .zip(bc.remainder().iter())
                {
                    *o += ta[ca as usize] + tb[cb as usize];
                }
                q += 2;
            }
            if q < nq {
                let ta: &[f32; 256] = arr(&tabs[q * 256..(q + 1) * 256]);
                let fa = &fused[q * w..(q + 1) * w];
                let mut oc = orow.chunks_exact_mut(LANES);
                let mut ac = fa.chunks_exact(LANES);
                for (o, ca) in (&mut oc).zip(&mut ac) {
                    let o: &mut [f32; LANES] = arr_mut(o);
                    let ca: &[u8; LANES] = arr(ca);
                    for (ov, &a) in o.iter_mut().zip(ca.iter()) {
                        *ov += ta[a as usize];
                    }
                }
                for (o, &ca) in oc.into_remainder().iter_mut().zip(ac.remainder().iter()) {
                    *o += ta[ca as usize];
                }
            }
        }
        k0 += kt;
    }
    span.end(&crate::obs::ENGINE.v2_kernel_ns);
}

/// Full-width blocked matmul: `out[m, cols] += x[m, rows] @ W`.
#[fmq_macros::no_alloc]
pub fn matmul_blocked(
    layer: &LutLayer,
    x: &[f32],
    out: &mut [f32],
    m: usize,
    plan: TilePlan,
    scratch: &mut Scratch,
) {
    matmul_stripe(layer, x, out, m, 0, layer.cols, plan, scratch)
}

/// Resolve the tile plan for a stripe through the [`Tuner`]. The measured
/// policy times candidates on the live inputs into the workspace's
/// throwaway `tune_tmp` buffer (one warm-up-sized run each) — results
/// are unaffected because every plan is numerically identical, and the
/// cache-hit path (every call after warm-up) touches no scratch at all.
pub fn plan_stripe(
    layer: &LutLayer,
    tuner: &Tuner,
    x: &[f32],
    m: usize,
    c0: usize,
    c1: usize,
    kern: &mut Kernel,
) -> TilePlan {
    let Kernel {
        scratch, tune_tmp, ..
    } = kern;
    tuner.plan(layer.packed.bits, m, c1 - c0, layer.rows, |p| {
        let tmp = take_zeroed(tune_tmp, m * (c1 - c0));
        let t0 = std::time::Instant::now();
        matmul_stripe(layer, x, tmp, m, c0, c1, p, scratch);
        t0.elapsed().as_secs_f64()
    })
}

/// Self-check helper used in docs/tests: true when `bits` admits more
/// than one code per fused index (i.e. the v2 kernel's headline regime).
pub fn fuses_multiple_codes(packed: &PackedCodes) -> bool {
    crate::engine::tune::max_group(packed.bits) > 1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::lut::LutLayer;
    use crate::tensor::matmul_into;
    use crate::util::check::assert_close;
    use crate::util::rng::Pcg64;

    fn random_layer(rng: &mut Pcg64, rows: usize, cols: usize, bits: u8, klen: usize) -> LutLayer {
        assert!(klen <= 1 << bits);
        let levels: Vec<f32> = (0..klen)
            .map(|i| -0.4 + 0.8 * i as f32 / (klen - 1).max(1) as f32)
            .collect();
        let codes: Vec<u32> = (0..rows * cols).map(|_| rng.below(klen) as u32).collect();
        LutLayer::new("w_test", rows, cols, &codes, levels, bits).unwrap()
    }

    fn reference(layer: &LutLayer, x: &[f32], m: usize) -> Vec<f32> {
        let dense = layer.dequantize_dense();
        let mut out = vec![0f32; m * layer.cols];
        matmul_into(x, &dense, &mut out, m, layer.rows, layer.cols);
        out
    }

    #[test]
    fn matches_dense_gemm_all_bit_widths_and_ragged_shapes() {
        let mut rng = Pcg64::seed(71);
        let mut scratch = Scratch::new();
        for bits in 1..=8u8 {
            // rows chosen to exercise partial tiles, partial groups and
            // an odd trailing group for the paired sweep
            for (m, rows, cols) in [(1usize, 37usize, 33usize), (3, 2 * 64 + 5, 48), (5, 19, 7)] {
                let klen = 1usize << bits;
                let layer = random_layer(&mut rng, rows, cols, bits, klen);
                let x: Vec<f32> = (0..m * rows).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                let plan = TilePlan::heuristic(bits, m, cols, rows);
                let mut out = vec![0f32; m * cols];
                matmul_blocked(&layer, &x, &mut out, m, plan, &mut scratch);
                let want = reference(&layer, &x, m);
                assert_close(&out, &want, 1e-5, 1e-6);
            }
        }
    }

    #[test]
    fn handles_partial_codebooks() {
        // deduplicated codebooks can have fewer than 2^bits levels; the
        // fused index space is then sparse and the gaps must never leak
        let mut rng = Pcg64::seed(72);
        let mut scratch = Scratch::new();
        for (bits, klen) in [(2u8, 3usize), (3, 5), (4, 11), (8, 200)] {
            let layer = random_layer(&mut rng, 50, 21, bits, klen);
            let x: Vec<f32> = (0..2 * 50).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let plan = TilePlan::heuristic(bits, 2, 21, 50);
            let mut out = vec![0f32; 2 * 21];
            matmul_blocked(&layer, &x, &mut out, 2, plan, &mut scratch);
            assert_close(&out, &reference(&layer, &x, 2), 1e-5, 1e-6);
        }
    }

    #[test]
    fn bit_identical_across_tile_plans() {
        // the invariant measured autotuning relies on: k_tile moves work
        // between loops but never changes a single output bit
        let mut rng = Pcg64::seed(73);
        for bits in [2u8, 3, 4, 8] {
            let layer = random_layer(&mut rng, 150, 40, bits, 1 << bits);
            let x: Vec<f32> = (0..3 * 150).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let mut base: Option<Vec<f32>> = None;
            for plan in TilePlan::candidates(bits, 150) {
                let mut out = vec![0f32; 3 * 40];
                matmul_blocked(&layer, &x, &mut out, 3, plan, &mut Scratch::new());
                match &base {
                    None => base = Some(out),
                    Some(b) => assert_eq!(&out, b, "bits={bits} plan={plan:?}"),
                }
            }
        }
    }

    #[test]
    fn column_stripes_compose_to_full_width() {
        // stripes must be bit-identical to the full-width kernel — the
        // exactness guarantee behind intra-layer column sharding
        let mut rng = Pcg64::seed(74);
        let (m, rows, cols) = (4usize, 70usize, 50usize);
        let layer = random_layer(&mut rng, rows, cols, 3, 8);
        let x: Vec<f32> = (0..m * rows).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let plan = TilePlan::heuristic(3, m, cols, rows);
        let mut full = vec![0f32; m * cols];
        matmul_blocked(&layer, &x, &mut full, m, plan, &mut Scratch::new());
        for split in [1usize, 13, 25, 49] {
            let mut glued = vec![0f32; m * cols];
            for (c0, c1) in [(0usize, split), (split, cols)] {
                let w = c1 - c0;
                let mut stripe = vec![0f32; m * w];
                matmul_stripe(&layer, &x, &mut stripe, m, c0, c1, plan, &mut Scratch::new());
                for i in 0..m {
                    glued[i * cols + c0..i * cols + c1]
                        .copy_from_slice(&stripe[i * w..(i + 1) * w]);
                }
            }
            assert_eq!(glued, full, "split at {split}");
        }
    }

    #[test]
    fn accumulates_into_preloaded_output() {
        let mut rng = Pcg64::seed(75);
        let layer = random_layer(&mut rng, 12, 6, 2, 4);
        let x = vec![1.0f32; 12];
        let plan = TilePlan::heuristic(2, 1, 6, 12);
        let mut delta = vec![0f32; 6];
        matmul_blocked(&layer, &x, &mut delta, 1, plan, &mut Scratch::new());
        let mut out = vec![5.0f32; 6];
        matmul_blocked(&layer, &x, &mut out, 1, plan, &mut Scratch::new());
        for (o, d) in out.iter().zip(delta.iter()) {
            assert!((o - (5.0 + d)).abs() < 1e-6);
        }
    }

    #[test]
    fn scratch_reuse_across_shapes_is_clean() {
        // a big layer then a small one: stale scratch contents must not
        // bleed into the smaller computation
        let mut rng = Pcg64::seed(76);
        let mut scratch = Scratch::new();
        let big = random_layer(&mut rng, 200, 64, 4, 16);
        let xb: Vec<f32> = (0..200).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut ob = vec![0f32; 64];
        matmul_blocked(&big, &xb, &mut ob, 1, TilePlan::heuristic(4, 1, 64, 200), &mut scratch);
        let small = random_layer(&mut rng, 9, 5, 2, 4);
        let xs: Vec<f32> = (0..9).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut os = vec![0f32; 5];
        matmul_blocked(&small, &xs, &mut os, 1, TilePlan::heuristic(2, 1, 5, 9), &mut scratch);
        assert_close(&os, &reference(&small, &xs, 1), 1e-5, 1e-6);
    }

    #[test]
    fn plan_stripe_measured_is_consistent() {
        let mut rng = Pcg64::seed(77);
        let layer = random_layer(&mut rng, 64, 32, 2, 4);
        let x: Vec<f32> = (0..2 * 64).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let tuner = Tuner::measured();
        let mut kern = Kernel::default();
        let plan = plan_stripe(&layer, &tuner, &x, 2, 0, 32, &mut kern);
        assert_eq!(plan.group, crate::engine::tune::max_group(2));
        // tuned plan produces the same bits as any other plan
        let mut a = vec![0f32; 2 * 32];
        matmul_blocked(&layer, &x, &mut a, 2, plan, &mut kern.scratch);
        let mut b = vec![0f32; 2 * 32];
        let other = TilePlan { k_tile: 16, group: plan.group };
        matmul_blocked(&layer, &x, &mut b, 2, other, &mut Scratch::new());
        assert_eq!(a, b);
        assert!(fuses_multiple_codes(&layer.packed));
    }
}
