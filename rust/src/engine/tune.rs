//! Kernel dispatch / autotune for the v2 blocked LUT-GEMM.
//!
//! The blocked kernel in [`crate::engine::blocked`] is parameterized by a
//! [`TilePlan`]. Two of its knobs behave very differently:
//!
//! * **`group`** — how many adjacent weight rows fuse into one lookup
//!   index — changes floating-point association, so it is a *pure
//!   function of the bit-width* (see [`max_group`]) and is never tuned.
//!   This keeps every plan numerically identical.
//! * **`k_tile`** — how many weight rows decode per tile — only moves
//!   work between loops. Because the kernel aligns tiles to `group`
//!   boundaries, the accumulation order per output element is invariant
//!   in `k_tile`, which makes it safe to pick by *measurement* without
//!   giving up bit-for-bit reproducibility.
//!
//! [`Tuner`] is the dispatch policy: a fixed plan (tests), a shape
//! heuristic (zero-cost startup), or measured autotuning that times the
//! candidate tiles once per (bits, M-bucket, N, K) shape on the real
//! data and caches the winner for the lifetime of the engine.

// BTreeMap (not HashMap): tuning keys feed kernel dispatch, and the
// determinism lint requires ordered containers anywhere iteration order
// could reach observable behavior. See docs/STATIC_ANALYSIS.md.
use std::collections::BTreeMap;
use std::sync::Mutex;

/// Largest number of codes that can fuse into one 8-bit lookup index at
/// `bits` per code: `max_group(2) == 4`, `max_group(3) == 2`,
/// `max_group(4) == 2`, `max_group(b >= 5) == 1`.
pub fn max_group(bits: u8) -> usize {
    let b = (bits.clamp(1, 8) as usize).max(1);
    (8 / b).max(1)
}

/// Tile shape for one blocked LUT-GEMM invocation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TilePlan {
    /// Weight rows decoded per tile. The kernel rounds this up to a
    /// multiple of `2 * group` so quad/pair boundaries land on the same
    /// absolute k positions for every plan (numeric invariance).
    pub k_tile: usize,
    /// Codes fused per lookup index (`group * bits <= 8`). Must equal
    /// [`max_group`] of the layer's bit-width for full fusion; smaller
    /// values are legal but slower and change accumulation order.
    pub group: usize,
}

impl TilePlan {
    /// Deterministic shape heuristic: full fusion, and a tile size that
    /// keeps the decoded tile + fused indices comfortably L1-resident
    /// for small batches while amortizing decode for large ones.
    pub fn heuristic(bits: u8, m: usize, _n: usize, k: usize) -> TilePlan {
        let group = max_group(bits);
        let base = if m >= 16 { 64 } else { 32 };
        let align = 2 * group;
        let k_tile = base.min(k.max(1)).div_ceil(align) * align;
        TilePlan { k_tile, group }
    }

    /// The candidate tile sizes measured autotuning chooses between.
    pub fn candidates(bits: u8, k: usize) -> Vec<TilePlan> {
        let group = max_group(bits);
        let align = 2 * group;
        let mut out: Vec<TilePlan> = Vec::new();
        for kt in [16usize, 32, 64, 128] {
            let kt = kt.min(k.max(1)).div_ceil(align) * align;
            let plan = TilePlan { k_tile: kt, group };
            if !out.contains(&plan) {
                out.push(plan);
            }
        }
        out
    }
}

/// Cache key for measured plans. `m` is bucketed so a serving engine
/// does not re-tune for every batch size the batcher produces.
/// Ordered (`Ord`) so the plan cache can be a `BTreeMap` with
/// reproducible iteration.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct ShapeKey {
    /// Code bit-width.
    pub bits: u8,
    /// Batch-size bucket: 0 for M=1, then doubling ranges.
    pub m_bucket: u8,
    /// Output columns of the GEMM (stripe width under column sharding).
    pub n: u32,
    /// Fan-in rows of the GEMM.
    pub k: u32,
}

impl ShapeKey {
    /// Bucket `m` logarithmically: 1 → 0, 2–3 → 1, 4–7 → 2, ...
    pub fn new(bits: u8, m: usize, n: usize, k: usize) -> Self {
        let m_bucket = usize::BITS - m.max(1).leading_zeros() - 1;
        Self {
            bits,
            m_bucket: m_bucket as u8,
            n: n as u32,
            k: k as u32,
        }
    }
}

/// Plan-selection policy for the v2 kernel. All variants produce
/// numerically identical results (only `k_tile` varies — see the module
/// docs), so the choice is purely a speed/startup-cost trade-off.
pub enum Tuner {
    /// One plan for every shape. Used by tests that pin the numeric
    /// invariance across tile sizes.
    Fixed(TilePlan),
    /// [`TilePlan::heuristic`] per shape; no measurement.
    Heuristic,
    /// Measure each candidate once per [`ShapeKey`] on the live inputs
    /// and cache the fastest. First call per shape pays a few extra
    /// kernel runs; every later call dispatches from the cache.
    Measured(Mutex<BTreeMap<ShapeKey, TilePlan>>),
}

impl Tuner {
    /// A fresh measured autotuner with an empty plan cache.
    pub fn measured() -> Self {
        Tuner::Measured(Mutex::new(BTreeMap::new()))
    }

    /// Resolve the plan for a (bits, m, n, k) GEMM shape. `measure` runs
    /// one kernel invocation with the given plan and returns its wall
    /// time in seconds; it is only called by the `Measured` variant on a
    /// cache miss.
    pub fn plan(
        &self,
        bits: u8,
        m: usize,
        n: usize,
        k: usize,
        mut measure: impl FnMut(TilePlan) -> f64,
    ) -> TilePlan {
        match self {
            Tuner::Fixed(p) => *p,
            Tuner::Heuristic => TilePlan::heuristic(bits, m, n, k),
            Tuner::Measured(cache) => {
                let key = ShapeKey::new(bits, m, n, k);
                if let Some(p) = cache
                    .lock()
                    .unwrap_or_else(|e| e.into_inner())
                    .get(&key)
                {
                    return *p;
                }
                // measure with the lock released so concurrent shards
                // keep computing during warm-up; a racing thread may
                // measure the same shape once more, which is harmless
                // (every plan is numerically identical) — first insert
                // wins so later dispatches stay consistent
                let mut best = TilePlan::heuristic(bits, m, n, k);
                let mut best_t = f64::INFINITY;
                for cand in TilePlan::candidates(bits, k) {
                    let t = measure(cand);
                    if t < best_t {
                        best_t = t;
                        best = cand;
                    }
                }
                // one tick per measured shape (cache misses only): the
                // metrics op shows how much warm-up autotuning cost
                crate::obs::ENGINE.tune_plans_total.inc();
                *cache
                    .lock()
                    .unwrap_or_else(|e| e.into_inner())
                    .entry(key)
                    .or_insert(best)
            }
        }
    }

    /// Short policy name for logs and benches.
    pub fn name(&self) -> &'static str {
        match self {
            Tuner::Fixed(_) => "fixed",
            Tuner::Heuristic => "heuristic",
            Tuner::Measured(_) => "measured",
        }
    }

    /// How many GEMM shapes the measured cache currently holds (0 for
    /// the non-measuring policies). After warm-up this is stable, and
    /// every later dispatch is a pure cache hit — the bench harness
    /// prints it to confirm steady state before counting allocations.
    pub fn cached_plans(&self) -> usize {
        match self {
            Tuner::Measured(cache) => cache.lock().unwrap_or_else(|e| e.into_inner()).len(),
            _ => 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn max_group_respects_index_width() {
        for bits in 1..=8u8 {
            let g = max_group(bits);
            assert!(g >= 1);
            assert!(g * bits as usize <= 8, "bits={bits} group={g}");
            // full fusion: adding one more code would overflow the index
            assert!((g + 1) * bits as usize > 8, "bits={bits} group={g}");
        }
        assert_eq!(max_group(2), 4);
        assert_eq!(max_group(3), 2);
        assert_eq!(max_group(4), 2);
        assert_eq!(max_group(8), 1);
    }

    #[test]
    fn heuristic_and_candidates_are_aligned() {
        for bits in 1..=8u8 {
            for k in [1usize, 5, 16, 100, 512] {
                for m in [1usize, 8, 64] {
                    let p = TilePlan::heuristic(bits, m, 512, k);
                    assert_eq!(p.group, max_group(bits));
                    assert!(p.k_tile >= p.group);
                    assert_eq!(p.k_tile % (2 * p.group), 0, "bits={bits} k={k}");
                }
                for c in TilePlan::candidates(bits, k) {
                    assert_eq!(c.k_tile % (2 * c.group), 0);
                }
            }
        }
    }

    #[test]
    fn shape_key_buckets_batch_sizes() {
        assert_eq!(ShapeKey::new(4, 1, 8, 8).m_bucket, 0);
        assert_eq!(ShapeKey::new(4, 2, 8, 8).m_bucket, 1);
        assert_eq!(ShapeKey::new(4, 3, 8, 8).m_bucket, 1);
        assert_eq!(ShapeKey::new(4, 64, 8, 8).m_bucket, 6);
        assert_eq!(
            ShapeKey::new(4, 65, 8, 8).m_bucket,
            ShapeKey::new(4, 127, 8, 8).m_bucket
        );
    }

    #[test]
    fn measured_tuner_caches_the_winner() {
        let tuner = Tuner::measured();
        let mut calls = 0usize;
        let plan = tuner.plan(2, 4, 64, 512, |p| {
            calls += 1;
            // pretend tile 32 is fastest
            if p.k_tile == 32 {
                1.0
            } else {
                2.0
            }
        });
        assert_eq!(plan.k_tile, 32);
        assert!(calls >= 2, "should have measured multiple candidates");
        // second resolve: served from cache, no measurement
        let plan2 = tuner.plan(2, 4, 64, 512, |_| {
            panic!("cache hit must not re-measure")
        });
        assert_eq!(plan, plan2);
        // different shape -> fresh measurement
        let mut again = 0usize;
        tuner.plan(2, 4, 64, 256, |_| {
            again += 1;
            1.0
        });
        assert!(again >= 1);
        assert_eq!(tuner.cached_plans(), 2, "one entry per tuned shape");
        assert_eq!(Tuner::Heuristic.cached_plans(), 0);
    }

    #[test]
    fn fixed_and_heuristic_never_measure() {
        let fixed = Tuner::Fixed(TilePlan { k_tile: 16, group: 2 });
        let p = fixed.plan(3, 1, 8, 8, |_| panic!("fixed must not measure"));
        assert_eq!(p.k_tile, 16);
        let h = Tuner::Heuristic;
        let p = h.plan(3, 1, 8, 8, |_| panic!("heuristic must not measure"));
        assert_eq!(p.group, max_group(3));
        assert_eq!(h.name(), "heuristic");
        assert_eq!(Tuner::measured().name(), "measured");
    }
}
