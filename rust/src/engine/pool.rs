//! Worker pool with two parallelism axes for the serving hot path.
//!
//! **Batch sharding** ([`Pool::map_rows`]): the velocity network is
//! row-independent (each sample's output depends only on its own input —
//! pinned by `cpu_ref::tests::batch_independence`), so a batch of B
//! samples splits into contiguous row shards that run on std threads with
//! zero synchronization beyond the final join. Scoped threads borrow the
//! input slices directly — no copies in, one ordered concatenation out —
//! so sharding is numerically invisible.
//!
//! **Intra-layer column sharding** ([`Pool::map_shards`]): when the batch
//! is too small to feed every core (the latency-bound B=1 regime), the v2
//! engine splits each layer GEMM's *output columns* across threads
//! instead. Each output column's accumulation is independent of every
//! other column, so this axis is also bit-exact — pinned by
//! `blocked::tests::column_stripes_compose_to_full_width` and the engine
//! integration tests.
//!
//! Threads are scoped *per call* (shard 0 runs on the caller, so an
//! N-way split spawns N−1). A spawn is ~tens of µs; one Euler step on a
//! 16-sample batch is ~tens of ms of GEMM, so the overhead stays well
//! under 1% — persistent workers would buy little at the cost of
//! `'static` plumbing. Each serving variant worker gets an all-cores
//! pool: a lone hot variant saturates the machine, and when several
//! variants batch at once their scoped threads simply time-share under
//! the OS scheduler (see `coordinator/server.rs::worker_loop`).

use anyhow::{anyhow, Result};

/// A fixed-width worker pool (thread count chosen at construction;
/// threads themselves are scoped per call, so the pool is trivially
/// `Send + Sync` and free to share across serving workers).
#[derive(Clone, Copy, Debug)]
pub struct Pool {
    threads: usize,
}

impl Pool {
    /// `threads = 0` means "all available cores".
    pub fn new(threads: usize) -> Self {
        let threads = if threads == 0 {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        } else {
            threads
        };
        Self { threads }
    }

    /// Single-threaded pool (the degenerate case, used for determinism
    /// baselines in tests).
    pub fn serial() -> Self {
        Self { threads: 1 }
    }

    /// Worker thread count this pool shards across.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f` over row shards of `x` (flat `[B, d]`) and `t` (`[B]`),
    /// concatenating the per-shard outputs in row order. `f` must map a
    /// row sub-batch to one output `Vec` row-for-row (any output width).
    /// With one thread or one row this degenerates to a direct call.
    pub fn map_rows<F>(&self, x: &[f32], t: &[f32], d: usize, f: F) -> Result<Vec<f32>>
    where
        F: Fn(&[f32], &[f32]) -> Result<Vec<f32>> + Sync,
    {
        let b = t.len();
        assert_eq!(x.len(), b * d, "x rows must match t length");
        let shards = self.threads.min(b.max(1));
        if shards <= 1 {
            return f(x, t);
        }
        let per = b.div_ceil(shards);
        let mut ranges = Vec::with_capacity(shards);
        let mut r0 = 0usize;
        while r0 < b {
            let r1 = (r0 + per).min(b);
            ranges.push((r0, r1));
            r0 = r1;
        }
        // shard 0 runs on the calling thread while the rest are scoped
        // spawns, so an N-way split costs N-1 spawns (and a 1-way split
        // costs none — handled by the direct-call path above)
        let (first, rest) = ranges.split_first().expect("at least one shard");
        let fref = &f;
        let mut outs: Vec<Result<Vec<f32>>> = Vec::with_capacity(ranges.len());
        std::thread::scope(|s| {
            let handles: Vec<_> = rest
                .iter()
                .map(|&(r0, r1)| {
                    let xs = &x[r0 * d..r1 * d];
                    let ts = &t[r0..r1];
                    s.spawn(move || fref(xs, ts))
                })
                .collect();
            let (r0, r1) = *first;
            outs.push(fref(&x[r0 * d..r1 * d], &t[r0..r1]));
            for h in handles {
                outs.push(
                    h.join()
                        .unwrap_or_else(|_| Err(anyhow!("engine worker panicked"))),
                );
            }
        });
        let mut out = Vec::new();
        for shard in outs {
            out.extend(shard?);
        }
        Ok(out)
    }

    /// Split `0..n` into at most `threads` contiguous ranges of at least
    /// `min_per_shard` items each and run `f(shard_idx, lo, hi)` on every
    /// range — range 0 on the calling thread, the rest on scoped spawns.
    /// Results come back in range order; `shard_idx < threads` is the
    /// range's position, so callers can address per-shard state (e.g.
    /// reusable kernel scratch) without synchronization beyond a slot
    /// lock. This is the second parallelism axis: the v2 engine uses it
    /// to shard a layer's output columns when the batch is too small for
    /// row sharding to help.
    pub fn map_shards<T, F>(&self, n: usize, min_per_shard: usize, f: F) -> Vec<(usize, usize, T)>
    where
        F: Fn(usize, usize, usize) -> T + Sync,
        T: Send,
    {
        if n == 0 {
            return Vec::new();
        }
        let min = min_per_shard.max(1);
        let shards = self.threads.min(n.div_ceil(min)).max(1);
        if shards <= 1 {
            return vec![(0, n, f(0, 0, n))];
        }
        let per = n.div_ceil(shards);
        let mut ranges = Vec::with_capacity(shards);
        let mut lo = 0usize;
        while lo < n {
            let hi = (lo + per).min(n);
            ranges.push((lo, hi));
            lo = hi;
        }
        let fref = &f;
        let mut outs: Vec<(usize, usize, T)> = Vec::with_capacity(ranges.len());
        std::thread::scope(|s| {
            let (first, rest) = ranges.split_first().expect("at least one shard");
            let handles: Vec<_> = rest
                .iter()
                .enumerate()
                .map(|(i, &(lo, hi))| s.spawn(move || (lo, hi, fref(i + 1, lo, hi))))
                .collect();
            let (lo, hi) = *first;
            outs.push((lo, hi, fref(0, lo, hi)));
            for h in handles {
                match h.join() {
                    Ok(v) => outs.push(v),
                    Err(p) => std::panic::resume_unwind(p),
                }
            }
        });
        outs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn double_rows(x: &[f32], t: &[f32]) -> Result<Vec<f32>> {
        // width-2 rows in, width-2 rows out, plus the row's t
        Ok(x.chunks(2)
            .zip(t.iter())
            .flat_map(|(r, &tv)| [r[0] * 2.0 + tv, r[1] * 2.0 + tv])
            .collect())
    }

    #[test]
    fn sharded_equals_serial() {
        let b = 13usize; // deliberately not divisible by the thread count
        let x: Vec<f32> = (0..b * 2).map(|i| i as f32).collect();
        let t: Vec<f32> = (0..b).map(|i| 0.1 * i as f32).collect();
        let serial = Pool::serial().map_rows(&x, &t, 2, double_rows).unwrap();
        for threads in [2, 3, 7, 32] {
            let sharded = Pool::new(threads).map_rows(&x, &t, 2, double_rows).unwrap();
            assert_eq!(sharded, serial, "threads={threads}");
        }
    }

    #[test]
    fn zero_means_all_cores() {
        assert!(Pool::new(0).threads() >= 1);
        assert_eq!(Pool::new(3).threads(), 3);
    }

    #[test]
    fn single_row_batch_works() {
        let out = Pool::new(8)
            .map_rows(&[1.0, 2.0], &[0.5], 2, double_rows)
            .unwrap();
        assert_eq!(out, vec![2.5, 4.5]);
    }

    #[test]
    fn errors_propagate() {
        let r = Pool::new(4).map_rows(&[0.0; 8], &[0.0; 4], 2, |_x, _t| {
            Err(anyhow!("boom"))
        });
        assert!(r.is_err());
    }

    #[test]
    fn empty_batch_is_empty() {
        let out = Pool::new(4).map_rows(&[], &[], 2, double_rows).unwrap();
        assert!(out.is_empty());
    }

    #[test]
    fn map_shards_covers_range_in_order() {
        for (threads, n, min) in [(4usize, 100usize, 1usize), (3, 7, 2), (8, 5, 1), (2, 64, 64)] {
            let shards = Pool::new(threads).map_shards(n, min, |idx, lo, hi| (idx, hi - lo));
            // ordered, contiguous, exhaustive, with positional indices
            let mut expect_lo = 0usize;
            for (pos, &(lo, hi, (idx, w))) in shards.iter().enumerate() {
                assert_eq!(lo, expect_lo);
                assert_eq!(w, hi - lo);
                assert_eq!(idx, pos, "shard index must be its position");
                assert!(hi - lo >= 1);
                expect_lo = hi;
            }
            assert_eq!(expect_lo, n, "threads={threads} n={n}");
            assert!(shards.len() <= threads);
            if min > 1 {
                // every shard except possibly the last meets the minimum
                for &(lo, hi, _) in &shards[..shards.len() - 1] {
                    assert!(hi - lo >= min, "shard {lo}..{hi} under min {min}");
                }
            }
        }
    }

    #[test]
    fn map_shards_single_thread_runs_inline() {
        let shards = Pool::serial().map_shards(10, 1, |idx, lo, hi| (idx, lo, hi));
        assert_eq!(shards.len(), 1);
        assert_eq!(shards[0], (0, 10, (0, 0, 10)));
        assert!(Pool::new(4).map_shards(0, 1, |_, _, _| 0).is_empty());
    }
}
