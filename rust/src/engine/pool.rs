//! Worker pool with two parallelism axes for the serving hot path.
//!
//! **Batch sharding** ([`Pool::map_rows_into`]):
//! the velocity network is row-independent (each sample's output depends
//! only on its own input — pinned by `cpu_ref::tests::batch_independence`),
//! so a batch of B samples splits into contiguous row shards that run on
//! std threads with zero synchronization beyond the final join. Scoped
//! threads borrow the input slices directly and every shard writes
//! straight into its disjoint window of the caller's output — no copies
//! in, no concatenation out — so sharding is numerically invisible
//! *and* allocation-free.
//!
//! **Intra-layer column sharding** ([`Pool::map_shards`]): when the batch
//! is too small to feed every core (the latency-bound B=1 regime), the v2
//! engine splits each layer GEMM's *output columns* across threads
//! instead. Each output column's accumulation is independent of every
//! other column, so this axis is also bit-exact — pinned by
//! `blocked::tests::column_stripes_compose_to_full_width` and the engine
//! integration tests.
//!
//! **Per-worker arenas**: a pool built with [`Pool::new`] owns one
//! [`Workspace`] per worker slot. Shard `idx` leases slot `idx` (an
//! uncontended mutex — shard indices are unique within a call), so both
//! sharding axes reuse kernel scratch, activation buffers and stripe
//! accumulators across every call for the lifetime of the engine.
//! [`Pool::serial`] carries no slots (and allocates nothing): serial
//! execution always runs in the caller's own workspace.
//!
//! Threads are scoped *per call* (shard 0 runs on the caller, so an
//! N-way split spawns N−1). A spawn is ~tens of µs; one Euler step on a
//! 16-sample batch is ~tens of ms of GEMM, so the overhead stays well
//! under 1% — persistent workers would buy little at the cost of
//! `'static` plumbing. Each serving variant worker gets an all-cores
//! pool: a lone hot variant saturates the machine, and when several
//! variants batch at once their scoped threads simply time-share under
//! the OS scheduler (see `coordinator/server.rs::worker_loop`).

use std::sync::{Mutex, MutexGuard};

use anyhow::{anyhow, Result};

use crate::engine::workspace::Workspace;

/// A fixed-width worker pool (thread count chosen at construction;
/// threads themselves are scoped per call, so the pool is `Send + Sync`
/// and free to share across serving workers). Owns one reusable
/// [`Workspace`] arena per worker slot.
pub struct Pool {
    threads: usize,
    /// One arena per worker slot; empty for [`Pool::serial`].
    slots: Vec<Mutex<Workspace>>,
}

impl Pool {
    /// `threads = 0` means "all available cores".
    pub fn new(threads: usize) -> Self {
        let threads = if threads == 0 {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        } else {
            threads
        };
        Self {
            threads,
            slots: (0..threads).map(|_| Mutex::new(Workspace::new())).collect(),
        }
    }

    /// Single-threaded pool (the degenerate case, used for determinism
    /// baselines in tests). Holds no arenas and performs no allocation —
    /// serial callers supply their own workspace.
    pub fn serial() -> Self {
        Self {
            threads: 1,
            slots: Vec::new(),
        }
    }

    /// Worker thread count this pool shards across.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Lease worker slot `idx`'s arena. Shard indices are unique within
    /// a call, so the lock is uncontended; it only serializes against
    /// concurrent *calls* reusing the same engine. Panics for a
    /// [`Pool::serial`] pool (which has no slots) or `idx >= threads()`.
    pub fn workspace(&self, idx: usize) -> MutexGuard<'_, Workspace> {
        self.slots[idx].lock().unwrap_or_else(|e| e.into_inner())
    }

    /// High-water scratch bytes summed across every worker-slot arena —
    /// the pool's contribution to the `stats` op's `workspace_bytes`.
    pub fn workspace_bytes(&self) -> usize {
        self.slots
            .iter()
            .map(|s| s.lock().unwrap_or_else(|e| e.into_inner()).high_water_bytes())
            .sum()
    }

    /// Allocation-free row sharding: run `f(shard_idx, xs, ts, out_shard)`
    /// over contiguous row shards of `x` (flat `[B, d]`) and `t` (`[B]`),
    /// each shard writing directly into its disjoint window of `out`
    /// (same row width `d` in and out — the velocity forward's shape).
    /// Shard 0 runs on the calling thread while the rest are scoped
    /// spawns, so an N-way split costs N−1 spawns. `shard_idx <
    /// threads()` addresses the pool's per-worker arena via
    /// [`Pool::workspace`]. With one thread or one row this degenerates
    /// to a direct call with `shard_idx = 0`.
    pub fn map_rows_into<F>(
        &self,
        x: &[f32],
        t: &[f32],
        d: usize,
        out: &mut [f32],
        f: F,
    ) -> Result<()>
    where
        F: Fn(usize, &[f32], &[f32], &mut [f32]) -> Result<()> + Sync,
    {
        let b = t.len();
        assert_eq!(x.len(), b * d, "x rows must match t length"); // fmq-analyze: allow(panic_cone) -- shard-dispatch shape contract with the engines above; both sides derive sizes from spec.d (covers next line)
        assert_eq!(out.len(), b * d, "out rows must match t length");
        let shards = self.threads.min(b.max(1));
        crate::obs::ENGINE.shard_jobs_total.add(shards.max(1) as u64);
        if shards <= 1 {
            return f(0, x, t, out);
        }
        let per = b.div_ceil(shards);
        let fref = &f;
        let mut results: Vec<Result<()>> = Vec::with_capacity(shards);
        std::thread::scope(|s| {
            let b0 = per.min(b);
            let (out0, mut tail) = out.split_at_mut(b0 * d);
            let mut handles = Vec::with_capacity(shards - 1);
            let mut lo = b0;
            let mut idx = 1usize;
            while lo < b {
                let hi = (lo + per).min(b);
                let (mid, rest) = tail.split_at_mut((hi - lo) * d);
                tail = rest;
                let xs = &x[lo * d..hi * d];
                let ts = &t[lo..hi];
                handles.push(s.spawn(move || fref(idx, xs, ts, mid)));
                lo = hi;
                idx += 1;
            }
            results.push(fref(0, &x[..b0 * d], &t[..b0], out0));
            for h in handles {
                results.push(
                    h.join()
                        .unwrap_or_else(|_| Err(anyhow!("engine worker panicked"))),
                );
            }
        });
        // first error wins, in shard order; no collect — this path is
        // inside the zero-alloc steady-state contract
        for r in results {
            r?;
        }
        Ok(())
    }

    /// Split `0..n` into at most `threads` contiguous ranges of at least
    /// `min_per_shard` items each and run `f(shard_idx, lo, hi)` on every
    /// range — range 0 on the calling thread, the rest on scoped spawns.
    /// Results come back in range order; `shard_idx < threads` is the
    /// range's position, so callers can address per-shard state (the
    /// pool's own arenas via [`Pool::workspace`]) without synchronization
    /// beyond a slot lock. This is the second parallelism axis: the v2
    /// engine uses it to shard a layer's output columns when the batch
    /// is too small for row sharding to help.
    pub fn map_shards<T, F>(&self, n: usize, min_per_shard: usize, f: F) -> Vec<(usize, usize, T)>
    where
        F: Fn(usize, usize, usize) -> T + Sync,
        T: Send,
    {
        if n == 0 {
            return Vec::with_capacity(0);
        }
        let min = min_per_shard.max(1);
        let shards = self.threads.min(n.div_ceil(min)).max(1);
        crate::obs::ENGINE.shard_jobs_total.add(shards as u64);
        if shards <= 1 {
            let mut one = Vec::with_capacity(1);
            one.push((0, n, f(0, 0, n)));
            return one;
        }
        let per = n.div_ceil(shards);
        let mut ranges = Vec::with_capacity(shards);
        let mut lo = 0usize;
        while lo < n {
            let hi = (lo + per).min(n);
            ranges.push((lo, hi));
            lo = hi;
        }
        let fref = &f;
        let mut outs: Vec<(usize, usize, T)> = Vec::with_capacity(ranges.len());
        std::thread::scope(|s| {
            let mut handles = Vec::with_capacity(ranges.len() - 1);
            for (i, &(lo, hi)) in ranges[1..].iter().enumerate() {
                handles.push(s.spawn(move || (lo, hi, fref(i + 1, lo, hi))));
            }
            let (lo, hi) = ranges[0];
            outs.push((lo, hi, fref(0, lo, hi)));
            for h in handles {
                match h.join() {
                    Ok(v) => outs.push(v),
                    Err(p) => std::panic::resume_unwind(p),
                }
            }
        });
        outs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Width-2 rows in, width-2 rows out, plus the row's t — a toy
    /// row-local kernel for exercising the sharding.
    fn double_rows(x: &[f32], t: &[f32], out: &mut [f32]) {
        for ((r, &tv), o) in x.chunks(2).zip(t.iter()).zip(out.chunks_mut(2)) {
            o[0] = r[0] * 2.0 + tv;
            o[1] = r[1] * 2.0 + tv;
        }
    }

    fn run_rows(pool: &Pool, x: &[f32], t: &[f32]) -> (Vec<f32>, Vec<usize>) {
        let mut out = vec![f32::NAN; x.len()]; // dirty output window
        let seen = std::sync::Mutex::new(Vec::new());
        pool.map_rows_into(x, t, 2, &mut out, |idx, xs, ts, o| {
            seen.lock().unwrap().push(idx);
            double_rows(xs, ts, o);
            Ok(())
        })
        .unwrap();
        let mut seen = seen.into_inner().unwrap();
        seen.sort_unstable();
        (out, seen)
    }

    #[test]
    fn sharded_equals_serial() {
        let b = 13usize; // deliberately not divisible by the thread count
        let x: Vec<f32> = (0..b * 2).map(|i| i as f32).collect();
        let t: Vec<f32> = (0..b).map(|i| 0.1 * i as f32).collect();
        let (serial, seen) = run_rows(&Pool::serial(), &x, &t);
        assert_eq!(seen, vec![0], "serial path runs inline as shard 0");
        for threads in [2, 3, 7, 32] {
            let (sharded, seen) = run_rows(&Pool::new(threads), &x, &t);
            assert_eq!(sharded, serial, "threads={threads}");
            assert!(seen.iter().all(|&i| i < threads), "threads={threads}");
            assert!(seen.len() <= threads.min(b), "threads={threads}");
        }
    }

    #[test]
    fn map_rows_into_propagates_errors() {
        let pool = Pool::new(4);
        let mut out = vec![0.0; 8];
        let r = pool.map_rows_into(&[0.0; 8], &[0.0; 4], 2, &mut out, |idx, _x, _t, _o| {
            if idx == 0 {
                Err(anyhow!("boom"))
            } else {
                Ok(())
            }
        });
        assert!(r.is_err());
    }

    #[test]
    fn per_worker_arenas_exist_and_report_bytes() {
        let pool = Pool::new(3);
        assert_eq!(pool.workspace_bytes(), 0);
        pool.workspace(2)
            .split()
            .0
            .fill_temb(&crate::model::spec::ModelSpec::default_spec(), &[0.5]);
        assert!(pool.workspace_bytes() > 0);
        // serial pools carry no arenas at all
        assert_eq!(Pool::serial().workspace_bytes(), 0);
    }

    #[test]
    fn zero_means_all_cores() {
        assert!(Pool::new(0).threads() >= 1);
        assert_eq!(Pool::new(3).threads(), 3);
    }

    #[test]
    fn single_row_batch_works() {
        let (out, seen) = run_rows(&Pool::new(8), &[1.0, 2.0], &[0.5]);
        assert_eq!(out, vec![2.5, 4.5]);
        assert_eq!(seen, vec![0], "one row never spawns");
    }

    #[test]
    fn empty_batch_is_empty() {
        let mut empty: Vec<f32> = Vec::new();
        Pool::new(4)
            .map_rows_into(&[], &[], 2, &mut empty, |_, _, _, _| Ok(()))
            .unwrap();
        assert!(empty.is_empty());
    }

    #[test]
    fn map_shards_covers_range_in_order() {
        for (threads, n, min) in [(4usize, 100usize, 1usize), (3, 7, 2), (8, 5, 1), (2, 64, 64)] {
            let shards = Pool::new(threads).map_shards(n, min, |idx, lo, hi| (idx, hi - lo));
            // ordered, contiguous, exhaustive, with positional indices
            let mut expect_lo = 0usize;
            for (pos, &(lo, hi, (idx, w))) in shards.iter().enumerate() {
                assert_eq!(lo, expect_lo);
                assert_eq!(w, hi - lo);
                assert_eq!(idx, pos, "shard index must be its position");
                assert!(hi - lo >= 1);
                expect_lo = hi;
            }
            assert_eq!(expect_lo, n, "threads={threads} n={n}");
            assert!(shards.len() <= threads);
            if min > 1 {
                // every shard except possibly the last meets the minimum
                for &(lo, hi, _) in &shards[..shards.len() - 1] {
                    assert!(hi - lo >= min, "shard {lo}..{hi} under min {min}");
                }
            }
        }
    }

    #[test]
    fn map_shards_single_thread_runs_inline() {
        let shards = Pool::serial().map_shards(10, 1, |idx, lo, hi| (idx, lo, hi));
        assert_eq!(shards.len(), 1);
        assert_eq!(shards[0], (0, 10, (0, 0, 10)));
        assert!(Pool::new(4).map_shards(0, 1, |_, _, _| 0).is_empty());
    }
}
