//! Batch-sharding worker pool for the sampling loop.
//!
//! The velocity network is row-independent (each sample's output depends
//! only on its own input — pinned by `cpu_ref::tests::batch_independence`),
//! so a batch of B samples splits into contiguous row shards that run on
//! std threads with zero synchronization beyond the final join. Scoped
//! threads borrow the input slices directly — no copies in, one ordered
//! concatenation out — so sharding is numerically invisible.
//!
//! Threads are scoped *per call* (shard 0 runs on the caller, so an
//! N-way split spawns N−1). A spawn is ~tens of µs; one Euler step on a
//! 16-sample batch is ~tens of ms of GEMM, so the overhead stays well
//! under 1% — persistent workers would buy little at the cost of
//! `'static` plumbing. The serving layer additionally divides cores
//! across variant workers so concurrent batches don't oversubscribe.

use anyhow::{anyhow, Result};

/// A fixed-width worker pool (thread count chosen at construction;
/// threads themselves are scoped per call, so the pool is trivially
/// `Send + Sync` and free to share across serving workers).
#[derive(Clone, Copy, Debug)]
pub struct Pool {
    threads: usize,
}

impl Pool {
    /// `threads = 0` means "all available cores".
    pub fn new(threads: usize) -> Self {
        let threads = if threads == 0 {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        } else {
            threads
        };
        Self { threads }
    }

    /// Single-threaded pool (the degenerate case, used for determinism
    /// baselines in tests).
    pub fn serial() -> Self {
        Self { threads: 1 }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f` over row shards of `x` (flat `[B, d]`) and `t` (`[B]`),
    /// concatenating the per-shard outputs in row order. `f` must map a
    /// row sub-batch to one output `Vec` row-for-row (any output width).
    /// With one thread or one row this degenerates to a direct call.
    pub fn map_rows<F>(&self, x: &[f32], t: &[f32], d: usize, f: F) -> Result<Vec<f32>>
    where
        F: Fn(&[f32], &[f32]) -> Result<Vec<f32>> + Sync,
    {
        let b = t.len();
        assert_eq!(x.len(), b * d, "x rows must match t length");
        let shards = self.threads.min(b.max(1));
        if shards <= 1 {
            return f(x, t);
        }
        let per = b.div_ceil(shards);
        let mut ranges = Vec::with_capacity(shards);
        let mut r0 = 0usize;
        while r0 < b {
            let r1 = (r0 + per).min(b);
            ranges.push((r0, r1));
            r0 = r1;
        }
        // shard 0 runs on the calling thread while the rest are scoped
        // spawns, so an N-way split costs N-1 spawns (and a 1-way split
        // costs none — handled by the direct-call path above)
        let (first, rest) = ranges.split_first().expect("at least one shard");
        let fref = &f;
        let mut outs: Vec<Result<Vec<f32>>> = Vec::with_capacity(ranges.len());
        std::thread::scope(|s| {
            let handles: Vec<_> = rest
                .iter()
                .map(|&(r0, r1)| {
                    let xs = &x[r0 * d..r1 * d];
                    let ts = &t[r0..r1];
                    s.spawn(move || fref(xs, ts))
                })
                .collect();
            let (r0, r1) = *first;
            outs.push(fref(&x[r0 * d..r1 * d], &t[r0..r1]));
            for h in handles {
                outs.push(
                    h.join()
                        .unwrap_or_else(|_| Err(anyhow!("engine worker panicked"))),
                );
            }
        });
        let mut out = Vec::new();
        for shard in outs {
            out.extend(shard?);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn double_rows(x: &[f32], t: &[f32]) -> Result<Vec<f32>> {
        // width-2 rows in, width-2 rows out, plus the row's t
        Ok(x.chunks(2)
            .zip(t.iter())
            .flat_map(|(r, &tv)| [r[0] * 2.0 + tv, r[1] * 2.0 + tv])
            .collect())
    }

    #[test]
    fn sharded_equals_serial() {
        let b = 13usize; // deliberately not divisible by the thread count
        let x: Vec<f32> = (0..b * 2).map(|i| i as f32).collect();
        let t: Vec<f32> = (0..b).map(|i| 0.1 * i as f32).collect();
        let serial = Pool::serial().map_rows(&x, &t, 2, double_rows).unwrap();
        for threads in [2, 3, 7, 32] {
            let sharded = Pool::new(threads).map_rows(&x, &t, 2, double_rows).unwrap();
            assert_eq!(sharded, serial, "threads={threads}");
        }
    }

    #[test]
    fn zero_means_all_cores() {
        assert!(Pool::new(0).threads() >= 1);
        assert_eq!(Pool::new(3).threads(), 3);
    }

    #[test]
    fn single_row_batch_works() {
        let out = Pool::new(8)
            .map_rows(&[1.0, 2.0], &[0.5], 2, double_rows)
            .unwrap();
        assert_eq!(out, vec![2.5, 4.5]);
    }

    #[test]
    fn errors_propagate() {
        let r = Pool::new(4).map_rows(&[0.0; 8], &[0.0; 4], 2, |_x, _t| {
            Err(anyhow!("boom"))
        });
        assert!(r.is_err());
    }

    #[test]
    fn empty_batch_is_empty() {
        let out = Pool::new(4).map_rows(&[], &[], 2, double_rows).unwrap();
        assert!(out.is_empty());
    }
}
