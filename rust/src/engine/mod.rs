//! Native low-bit inference engine.
//!
//! Execution backends for the velocity network behind one [`Engine`]
//! interface, so the sampler and the serving layer are engine-agnostic:
//!
//! * [`lut`] — LUT-GEMM kernels that run matmuls **directly over packed
//!   b-bit codes** (no dense weight materialization, ever);
//! * [`forward`] — the fused quantized forward built on those kernels,
//!   bit-exact against `flow/cpu_ref.rs`;
//! * [`pool`] — a std-thread worker pool that shards sample batches
//!   across cores for the Euler/Heun loop;
//! * [`EngineKind`] — the `--engine` selector (`cpu-ref` | `lut` |
//!   `runtime`) dispatched by `flow/sampler.rs`, `coordinator/server.rs`
//!   and `main.rs`.
//!
//! The `runtime` kind routes to the compiled-HLO PJRT path in
//! [`crate::runtime`] (feature-gated); it has no `Engine` impl here
//! because its sessions are batch-shaped and device-resident — the
//! serving layer adapts it through the same `StepBackend` seam instead.

pub mod forward;
pub mod lut;
pub mod pool;

use anyhow::{anyhow, bail, Result};

use crate::model::params::ParamStore;
use crate::model::quantized::QuantizedModel;
use crate::model::spec::ModelSpec;

pub use forward::LutModel;
pub use lut::LutLayer;
pub use pool::Pool;

/// A velocity-network execution backend. Implementations are `Sync` so
/// one engine instance serves concurrent batches.
pub trait Engine: Send + Sync {
    /// Short human-readable backend name (for logs and benches).
    fn name(&self) -> &'static str;

    fn spec(&self) -> &ModelSpec;

    /// v = f(x, t): x flat [B, D], t [B] → v flat [B, D].
    fn velocity(&self, x: &[f32], t: &[f32]) -> Result<Vec<f32>>;

    /// One Euler step (signed dt), shared t across the batch.
    fn step(&self, x: &[f32], t: f32, dt: f32) -> Result<Vec<f32>> {
        let d = self.spec().d;
        assert_eq!(x.len() % d, 0, "x must be flat [B, D]");
        let b = x.len() / d;
        let tb = vec![t; b];
        let v = self.velocity(x, &tb)?;
        Ok(x.iter()
            .zip(v.iter())
            .map(|(&xi, &vi)| xi + dt * vi)
            .collect())
    }
}

/// Which execution backend to use. Parsed from `--engine`; `auto`
/// (absence of a choice) is represented as `None` at call sites and
/// resolved by the serving layer: `runtime` when artifacts are loaded,
/// else `lut` for quantized variants and `cpu-ref` for fp32.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum EngineKind {
    /// Dequantize-then-dense-GEMM reference (`flow/cpu_ref.rs`).
    CpuRef,
    /// Native LUT-GEMM over packed codes (this module).
    Lut,
    /// Compiled-HLO PJRT artifacts (`runtime`, feature-gated).
    Runtime,
}

impl EngineKind {
    pub const ALL: [EngineKind; 3] = [EngineKind::CpuRef, EngineKind::Lut, EngineKind::Runtime];

    pub fn name(&self) -> &'static str {
        match self {
            EngineKind::CpuRef => "cpu-ref",
            EngineKind::Lut => "lut",
            EngineKind::Runtime => "runtime",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        Self::ALL.iter().copied().find(|k| k.name() == s)
    }
}

impl std::str::FromStr for EngineKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        Self::parse(s).ok_or_else(|| anyhow!("unknown engine '{s}' (use cpu-ref|lut|runtime)"))
    }
}

impl std::fmt::Display for EngineKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

enum CpuVariant<'a> {
    Fp32 {
        spec: &'a ModelSpec,
        theta: &'a ParamStore,
    },
    Quantized(&'a QuantizedModel),
}

/// The dequantize-then-dense-GEMM reference path wrapped as an [`Engine`]
/// (numerics identical to calling `cpu_ref` directly).
pub struct CpuRefEngine<'a> {
    inner: CpuVariant<'a>,
}

impl<'a> CpuRefEngine<'a> {
    pub fn fp32(spec: &'a ModelSpec, theta: &'a ParamStore) -> Self {
        Self {
            inner: CpuVariant::Fp32 { spec, theta },
        }
    }

    pub fn quantized(qm: &'a QuantizedModel) -> Self {
        Self {
            inner: CpuVariant::Quantized(qm),
        }
    }
}

impl Engine for CpuRefEngine<'_> {
    fn name(&self) -> &'static str {
        "cpu-ref"
    }

    fn spec(&self) -> &ModelSpec {
        match &self.inner {
            CpuVariant::Fp32 { spec, .. } => spec,
            CpuVariant::Quantized(qm) => &qm.spec,
        }
    }

    fn velocity(&self, x: &[f32], t: &[f32]) -> Result<Vec<f32>> {
        Ok(match &self.inner {
            CpuVariant::Fp32 { spec, theta } => crate::flow::cpu_ref::velocity(spec, theta, x, t),
            CpuVariant::Quantized(qm) => crate::flow::cpu_ref::qvelocity(qm, x, t),
        })
    }
}

/// The native quantized engine: packed-code LUT-GEMM forward, batch
/// shards fanned across a worker pool. Owns its (compressed) weights, so
/// it is `'static` and cheap to keep per serving variant.
pub struct LutEngine {
    model: LutModel,
    pool: Pool,
}

impl LutEngine {
    /// Pack a quantized model for execution, using all available cores.
    pub fn new(qm: &QuantizedModel) -> Result<Self> {
        Self::with_pool(qm, Pool::new(0))
    }

    pub fn with_pool(qm: &QuantizedModel, pool: Pool) -> Result<Self> {
        Ok(Self {
            model: LutModel::new(qm)?,
            pool,
        })
    }

    pub fn model(&self) -> &LutModel {
        &self.model
    }

    pub fn pool(&self) -> &Pool {
        &self.pool
    }
}

impl Engine for LutEngine {
    fn name(&self) -> &'static str {
        "lut"
    }

    fn spec(&self) -> &ModelSpec {
        &self.model.spec
    }

    fn velocity(&self, x: &[f32], t: &[f32]) -> Result<Vec<f32>> {
        let d = self.model.spec.d;
        self.pool
            .map_rows(x, t, d, |xs, ts| Ok(self.model.velocity(xs, ts)))
    }
}

/// Build an engine for a quantized model by kind. `Runtime` is rejected
/// here — its device-resident sessions live behind `StepBackend` in the
/// serving layer, not behind `Engine`.
pub fn build_quantized(kind: EngineKind, qm: &QuantizedModel) -> Result<Box<dyn Engine + '_>> {
    match kind {
        EngineKind::CpuRef => Ok(Box::new(CpuRefEngine::quantized(qm))),
        EngineKind::Lut => Ok(Box::new(LutEngine::new(qm)?)),
        EngineKind::Runtime => {
            bail!("runtime engine is driven through the artifact sessions, not Engine")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{quantize_model, QuantMethod};
    use crate::util::rng::Pcg64;

    #[test]
    fn kind_names_roundtrip() {
        for k in EngineKind::ALL {
            assert_eq!(EngineKind::parse(k.name()), Some(k));
            assert_eq!(k.name().parse::<EngineKind>().unwrap(), k);
        }
        assert_eq!(EngineKind::parse("gpu"), None);
        assert!("nope".parse::<EngineKind>().is_err());
    }

    #[test]
    fn lut_engine_matches_cpu_ref_engine() {
        let spec = crate::model::spec::ModelSpec::default_spec();
        let theta = spec.init_theta(&mut Pcg64::seed(31));
        let qm = quantize_model(&spec, &theta, QuantMethod::Ot, 4);
        let lut = LutEngine::with_pool(&qm, Pool::serial()).unwrap();
        let cref = CpuRefEngine::quantized(&qm);
        let mut rng = Pcg64::seed(32);
        let x: Vec<f32> = (0..3 * spec.d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let t = [0.1, 0.5, 0.9];
        assert_eq!(
            lut.velocity(&x, &t).unwrap(),
            cref.velocity(&x, &t).unwrap()
        );
        assert_eq!(
            lut.step(&x, 0.5, 0.0625).unwrap(),
            cref.step(&x, 0.5, 0.0625).unwrap()
        );
    }

    #[test]
    fn pooled_velocity_is_deterministic_across_thread_counts() {
        let spec = crate::model::spec::ModelSpec::default_spec();
        let theta = spec.init_theta(&mut Pcg64::seed(33));
        let qm = quantize_model(&spec, &theta, QuantMethod::Uniform, 3);
        let serial = LutEngine::with_pool(&qm, Pool::serial()).unwrap();
        let pooled = LutEngine::with_pool(&qm, Pool::new(4)).unwrap();
        let mut rng = Pcg64::seed(34);
        let b = 9usize;
        let x: Vec<f32> = (0..b * spec.d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let t: Vec<f32> = (0..b).map(|i| i as f32 / b as f32).collect();
        assert_eq!(
            serial.velocity(&x, &t).unwrap(),
            pooled.velocity(&x, &t).unwrap()
        );
    }

    #[test]
    fn build_quantized_selector() {
        let spec = crate::model::spec::ModelSpec::default_spec();
        let theta = spec.init_theta(&mut Pcg64::seed(35));
        let qm = quantize_model(&spec, &theta, QuantMethod::Log2, 2);
        assert_eq!(build_quantized(EngineKind::Lut, &qm).unwrap().name(), "lut");
        assert_eq!(
            build_quantized(EngineKind::CpuRef, &qm).unwrap().name(),
            "cpu-ref"
        );
        assert!(build_quantized(EngineKind::Runtime, &qm).is_err());
    }
}
