//! Native low-bit inference engine.
//!
//! Execution backends for the velocity network behind one [`Engine`]
//! interface, so the sampler and the serving layer are engine-agnostic:
//!
//! * [`lut`] — v1 LUT-GEMM kernels that run matmuls **directly over
//!   packed b-bit codes** (no dense weight materialization, ever);
//! * [`blocked`] — the v2 blocked kernel: bulk tile decode, fused
//!   multi-code lookup tables (one table load per `⌊8/b⌋` weights) and
//!   register-paired output sweeps;
//! * [`tune`] — the kernel-dispatch/autotune layer that picks v2 tile
//!   plans per (bits, M, N, K) shape, by heuristic or by measurement;
//! * [`forward`] — the fused quantized forward built on those kernels;
//!   v1 is bit-exact against `flow/cpu_ref.rs`, v2 is equivalent within
//!   the 1e-5 harness;
//! * [`pool`] — a std-thread worker pool with two parallelism axes:
//!   batch (row) sharding for throughput, and intra-layer output-column
//!   sharding for the latency-bound small-batch regime — owning one
//!   reusable scratch arena per worker slot;
//! * [`workspace`] — the per-worker [`Workspace`] arena (named,
//!   size-checked scratch buffers + the per-step time-embedding cache)
//!   that makes the steady-state `velocity_into` path allocation-free;
//! * [`EngineKind`] — the `--engine` selector (`cpu-ref` | `lut` |
//!   `lut2` | `runtime`) dispatched by `flow/sampler.rs`,
//!   `coordinator/server.rs` and `main.rs`.
//!
//! The `runtime` kind routes to the compiled-HLO PJRT path in
//! [`crate::runtime`] (feature-gated); it has no `Engine` impl here
//! because its sessions are batch-shaped and device-resident — the
//! serving layer adapts it through the same `StepBackend` seam instead.
//!
//! See `docs/ARCHITECTURE.md` for the end-to-end pipeline walkthrough
//! and `docs/BENCHMARKS.md` for how the engines are measured.
#![warn(missing_docs)]

pub mod blocked;
pub mod forward;
pub mod lut;
pub mod pool;
pub mod tune;
pub mod workspace;

use anyhow::{anyhow, bail, Result};

use crate::model::params::ParamStore;
use crate::model::quantized::QuantizedModel;
use crate::model::spec::ModelSpec;

pub use forward::LutModel;
pub use lut::LutLayer;
pub use pool::Pool;
pub use tune::{TilePlan, Tuner};
pub use workspace::Workspace;

/// A velocity-network execution backend. Implementations are `Sync` so
/// one engine instance serves concurrent batches.
///
/// The forward contract: `velocity` maps a flat row-major batch
/// `x[B, D]` plus per-row times `t[B]` to the velocity field `v[B, D]`,
/// and every engine for the same model must agree within the 1e-5
/// equivalence harness (`tests/engine_integration.rs`). Example, running
/// the forward through the native v2 engine:
///
/// ```
/// use fmq::engine::{Engine, LutV2Engine};
/// use fmq::model::spec::ModelSpec;
/// use fmq::quant::{quantize_model, QuantMethod};
/// use fmq::util::rng::Pcg64;
///
/// let spec = ModelSpec::default_spec();
/// let theta = spec.init_theta(&mut Pcg64::seed(7));
/// let qm = quantize_model(&spec, &theta, QuantMethod::Uniform, 4);
/// let engine = LutV2Engine::new(&qm)?;
/// let x = vec![0.1f32; 2 * spec.d];        // batch of two samples
/// let v = engine.velocity(&x, &[0.25, 0.75])?;
/// assert_eq!(v.len(), 2 * spec.d);
/// # anyhow::Ok(())
/// ```
pub trait Engine: Send + Sync {
    /// Short human-readable backend name (for logs and benches).
    fn name(&self) -> &'static str;

    /// The architecture this engine executes.
    fn spec(&self) -> &ModelSpec;

    /// v = f(x, t): x flat [B, D], t [B] → v flat [B, D].
    fn velocity(&self, x: &[f32], t: &[f32]) -> Result<Vec<f32>>;

    /// [`Engine::velocity`] into a caller-provided output, with every
    /// intermediate drawn from the reusable `ws` arena — the
    /// allocation-free serving hot path. Bit-identical to `velocity`
    /// regardless of how dirty the reused workspace or `out` are
    /// (pinned by `tests/engine_integration.rs::
    /// velocity_into_reused_workspace_is_bit_identical`). The default
    /// routes through the allocating `velocity`; the native LUT engines
    /// override it (and engines sharding across a [`Pool`] draw
    /// per-worker arenas from the pool, using `ws` for the serial part).
    fn velocity_into(
        &self,
        x: &[f32],
        t: &[f32],
        out: &mut [f32],
        ws: &mut Workspace,
    ) -> Result<()> {
        let _ = ws;
        let v = self.velocity(x, t)?;
        if out.len() != v.len() {
            bail!("velocity_into: out has {} values, need {}", out.len(), v.len());
        }
        out.copy_from_slice(&v);
        Ok(())
    }

    /// One Euler step (signed dt), shared t across the batch.
    fn step(&self, x: &[f32], t: f32, dt: f32) -> Result<Vec<f32>> {
        let d = self.spec().d;
        assert_eq!(x.len() % d, 0, "x must be flat [B, D]");
        let b = x.len() / d;
        let tb = vec![t; b];
        let v = self.velocity(x, &tb)?;
        Ok(x.iter()
            .zip(v.iter())
            .map(|(&xi, &vi)| xi + dt * vi)
            .collect())
    }

    /// Bytes of model data this engine holds resident (packed codes,
    /// codebooks, biases — or the dense working set for the reference).
    fn resident_bytes(&self) -> usize {
        0
    }

    /// High-water scratch bytes across the engine's own per-worker
    /// arenas (its pool slots). The workspace the *caller* threads
    /// through [`Engine::velocity_into`] is accounted by the caller.
    fn workspace_bytes(&self) -> usize {
        0
    }
}

/// Which execution backend to use. Parsed from `--engine`; `auto`
/// (absence of a choice) is represented as `None` at call sites and
/// resolved by the serving layer: `runtime` when artifacts are loaded,
/// else `lut` for quantized variants and `cpu-ref` for fp32.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum EngineKind {
    /// Dequantize-then-dense-GEMM reference (`flow/cpu_ref.rs`).
    CpuRef,
    /// Native v1 LUT-GEMM over packed codes ([`lut`]).
    Lut,
    /// Blocked, autotuned v2 LUT-GEMM ([`blocked`] + [`tune`]).
    Lut2,
    /// Compiled-HLO PJRT artifacts (`runtime`, feature-gated).
    Runtime,
}

impl EngineKind {
    /// Every selectable backend, in `--engine` help order.
    pub const ALL: [EngineKind; 4] = [
        EngineKind::CpuRef,
        EngineKind::Lut,
        EngineKind::Lut2,
        EngineKind::Runtime,
    ];

    /// The `--engine` flag value for this backend.
    pub fn name(&self) -> &'static str {
        match self {
            EngineKind::CpuRef => "cpu-ref",
            EngineKind::Lut => "lut",
            EngineKind::Lut2 => "lut2",
            EngineKind::Runtime => "runtime",
        }
    }

    /// Inverse of [`EngineKind::name`]; `None` for unknown strings.
    pub fn parse(s: &str) -> Option<Self> {
        Self::ALL.iter().copied().find(|k| k.name() == s)
    }
}

impl std::str::FromStr for EngineKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        Self::parse(s)
            .ok_or_else(|| anyhow!("unknown engine '{s}' (use cpu-ref|lut|lut2|runtime)"))
    }
}

impl std::fmt::Display for EngineKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

enum CpuVariant<'a> {
    Fp32 {
        spec: &'a ModelSpec,
        theta: &'a ParamStore,
    },
    Quantized(&'a QuantizedModel),
}

/// The dequantize-then-dense-GEMM reference path wrapped as an [`Engine`]
/// (numerics identical to calling `cpu_ref` directly).
pub struct CpuRefEngine<'a> {
    inner: CpuVariant<'a>,
}

impl<'a> CpuRefEngine<'a> {
    /// Full-precision reference over raw theta.
    pub fn fp32(spec: &'a ModelSpec, theta: &'a ParamStore) -> Self {
        Self {
            inner: CpuVariant::Fp32 { spec, theta },
        }
    }

    /// Dequantize-then-GEMM reference over a quantized model.
    pub fn quantized(qm: &'a QuantizedModel) -> Self {
        Self {
            inner: CpuVariant::Quantized(qm),
        }
    }
}

impl Engine for CpuRefEngine<'_> {
    fn name(&self) -> &'static str {
        "cpu-ref"
    }

    fn spec(&self) -> &ModelSpec {
        match &self.inner {
            CpuVariant::Fp32 { spec, .. } => spec,
            CpuVariant::Quantized(qm) => &qm.spec,
        }
    }

    fn velocity(&self, x: &[f32], t: &[f32]) -> Result<Vec<f32>> {
        Ok(match &self.inner {
            CpuVariant::Fp32 { spec, theta } => crate::flow::cpu_ref::velocity(spec, theta, x, t),
            CpuVariant::Quantized(qm) => crate::flow::cpu_ref::qvelocity(qm, x, t),
        })
    }

    fn resident_bytes(&self) -> usize {
        match &self.inner {
            // dense fp32 theta
            CpuVariant::Fp32 { spec, .. } => spec.p() * 4,
            // u32 codes + fp32 biases + codebook levels (held unpacked)
            CpuVariant::Quantized(qm) => {
                (qm.codes.len() + qm.biases.len()) * 4
                    + qm.codebooks.iter().map(|c| c.levels.len() * 4).sum::<usize>()
            }
        }
    }
}

/// The native quantized engine: packed-code LUT-GEMM forward, batch
/// shards fanned across a worker pool. Owns its (compressed) weights, so
/// it is `'static` and cheap to keep per serving variant.
pub struct LutEngine {
    model: LutModel,
    pool: Pool,
}

impl LutEngine {
    /// Pack a quantized model for execution, using all available cores.
    pub fn new(qm: &QuantizedModel) -> Result<Self> {
        Self::with_pool(qm, Pool::new(0))
    }

    /// Pack a quantized model with an explicit worker pool.
    pub fn with_pool(qm: &QuantizedModel, pool: Pool) -> Result<Self> {
        Ok(Self {
            model: LutModel::new(qm)?,
            pool,
        })
    }

    /// The packed model this engine executes.
    pub fn model(&self) -> &LutModel {
        &self.model
    }

    /// The worker pool batches are sharded across.
    pub fn pool(&self) -> &Pool {
        &self.pool
    }
}

impl Engine for LutEngine {
    fn name(&self) -> &'static str {
        "lut"
    }

    fn spec(&self) -> &ModelSpec {
        &self.model.spec
    }

    fn velocity(&self, x: &[f32], t: &[f32]) -> Result<Vec<f32>> {
        let mut out = vec![0f32; t.len() * self.model.spec.d];
        self.velocity_into(x, t, &mut out, &mut Workspace::new())?;
        Ok(out)
    }

    fn velocity_into(
        &self,
        x: &[f32],
        t: &[f32],
        out: &mut [f32],
        ws: &mut Workspace,
    ) -> Result<()> {
        let d = self.model.spec.d;
        if self.pool.threads() <= 1 || t.len() <= 1 {
            self.model.velocity_into(x, t, out, ws);
            return Ok(());
        }
        // row shards write into disjoint output windows, each computing
        // in its own pool-slot arena
        self.pool.map_rows_into(x, t, d, out, |idx, xs, ts, o| {
            let mut slot = self.pool.workspace(idx);
            // fmq-analyze: allow(lock_order) -- each shard leases its own slot idx (disjoint by construction), and the may-block witnesses are method-name collisions (the atomic `load` in timing_enabled resolving to ArtifactSet::load); the engine under the lease does no channel or file I/O
            self.model.velocity_into(xs, ts, o, &mut slot);
            Ok(())
        })
    }

    fn resident_bytes(&self) -> usize {
        self.model.resident_bytes()
    }

    fn workspace_bytes(&self) -> usize {
        self.pool.workspace_bytes()
    }
}

/// The v2 engine: blocked fused-group LUT-GEMM forward with measured
/// tile autotuning, batch sharding for large batches and intra-layer
/// column sharding for small ones. Selected with `--engine lut2`.
///
/// v2 output is deterministic and bit-identical across thread counts,
/// sharding axes and tile plans (only the bit-width-derived `group`
/// affects accumulation order — see [`tune`]); versus the v1/`cpu-ref`
/// order it re-associates sums, staying within the 1e-5 harness.
pub struct LutV2Engine {
    model: LutModel,
    pool: Pool,
    tuner: Tuner,
}

impl LutV2Engine {
    /// Pack a quantized model for v2 execution: all cores, measured
    /// autotuning (first call per GEMM shape times the candidate tiles).
    pub fn new(qm: &QuantizedModel) -> Result<Self> {
        Self::with_config(qm, Pool::new(0), Tuner::measured())
    }

    /// Full control over the pool and plan policy (tests, benches).
    pub fn with_config(qm: &QuantizedModel, pool: Pool, tuner: Tuner) -> Result<Self> {
        Ok(Self {
            model: LutModel::new(qm)?,
            pool,
            tuner,
        })
    }

    /// The packed model this engine executes.
    pub fn model(&self) -> &LutModel {
        &self.model
    }

    /// The worker pool supplying both parallelism axes.
    pub fn pool(&self) -> &Pool {
        &self.pool
    }

    /// The tile-plan policy in use.
    pub fn tuner(&self) -> &Tuner {
        &self.tuner
    }
}

impl Engine for LutV2Engine {
    fn name(&self) -> &'static str {
        "lut2"
    }

    fn spec(&self) -> &ModelSpec {
        &self.model.spec
    }

    fn velocity(&self, x: &[f32], t: &[f32]) -> Result<Vec<f32>> {
        let mut out = vec![0f32; t.len() * self.model.spec.d];
        self.velocity_into(x, t, &mut out, &mut Workspace::new())?;
        Ok(out)
    }

    fn velocity_into(
        &self,
        x: &[f32],
        t: &[f32],
        out: &mut [f32],
        ws: &mut Workspace,
    ) -> Result<()> {
        let d = self.model.spec.d;
        let b = t.len();
        let threads = self.pool.threads();
        if threads > 1 && b >= threads {
            // throughput regime: row-shard the batch; each shard's
            // forward runs serially in its own pool-slot arena (column
            // sharding would oversubscribe)
            self.pool.map_rows_into(x, t, d, out, |idx, xs, ts, o| {
                let mut slot = self.pool.workspace(idx);
                self.model
                    .velocity_into_v2(xs, ts, o, &self.tuner, None, &mut slot); // fmq-analyze: allow(lock_order) -- same disjoint slot-lease discipline as the v1 shard closure above
                Ok(())
            })
        } else {
            // latency regime: parallelism comes from column sharding
            // inside each layer GEMM; the column shards draw their
            // scratch from the pool's arenas, the serial part from `ws`
            self.model
                .velocity_into_v2(x, t, out, &self.tuner, Some(&self.pool), ws);
            Ok(())
        }
    }

    fn resident_bytes(&self) -> usize {
        self.model.resident_bytes()
    }

    fn workspace_bytes(&self) -> usize {
        self.pool.workspace_bytes()
    }
}

/// Build an engine for a quantized model by kind. `Runtime` is rejected
/// here — its device-resident sessions live behind `StepBackend` in the
/// serving layer, not behind `Engine`.
pub fn build_quantized(kind: EngineKind, qm: &QuantizedModel) -> Result<Box<dyn Engine + '_>> {
    match kind {
        EngineKind::CpuRef => Ok(Box::new(CpuRefEngine::quantized(qm))),
        EngineKind::Lut => Ok(Box::new(LutEngine::new(qm)?)),
        EngineKind::Lut2 => Ok(Box::new(LutV2Engine::new(qm)?)),
        EngineKind::Runtime => {
            bail!("runtime engine is driven through the artifact sessions, not Engine")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{quantize_model, QuantMethod};
    use crate::util::rng::Pcg64;

    #[test]
    fn kind_names_roundtrip() {
        for k in EngineKind::ALL {
            assert_eq!(EngineKind::parse(k.name()), Some(k));
            assert_eq!(k.name().parse::<EngineKind>().unwrap(), k);
        }
        assert_eq!(EngineKind::parse("gpu"), None);
        assert!("nope".parse::<EngineKind>().is_err());
    }

    #[test]
    fn lut_engine_matches_cpu_ref_engine() {
        let spec = crate::model::spec::ModelSpec::default_spec();
        let theta = spec.init_theta(&mut Pcg64::seed(31));
        let qm = quantize_model(&spec, &theta, QuantMethod::Ot, 4);
        let lut = LutEngine::with_pool(&qm, Pool::serial()).unwrap();
        let cref = CpuRefEngine::quantized(&qm);
        let mut rng = Pcg64::seed(32);
        let x: Vec<f32> = (0..3 * spec.d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let t = [0.1, 0.5, 0.9];
        assert_eq!(
            lut.velocity(&x, &t).unwrap(),
            cref.velocity(&x, &t).unwrap()
        );
        assert_eq!(
            lut.step(&x, 0.5, 0.0625).unwrap(),
            cref.step(&x, 0.5, 0.0625).unwrap()
        );
    }

    #[test]
    fn pooled_velocity_is_deterministic_across_thread_counts() {
        let spec = crate::model::spec::ModelSpec::default_spec();
        let theta = spec.init_theta(&mut Pcg64::seed(33));
        let qm = quantize_model(&spec, &theta, QuantMethod::Uniform, 3);
        let serial = LutEngine::with_pool(&qm, Pool::serial()).unwrap();
        let pooled = LutEngine::with_pool(&qm, Pool::new(4)).unwrap();
        let mut rng = Pcg64::seed(34);
        let b = 9usize;
        let x: Vec<f32> = (0..b * spec.d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let t: Vec<f32> = (0..b).map(|i| i as f32 / b as f32).collect();
        assert_eq!(
            serial.velocity(&x, &t).unwrap(),
            pooled.velocity(&x, &t).unwrap()
        );
    }

    #[test]
    fn build_quantized_selector() {
        let spec = crate::model::spec::ModelSpec::default_spec();
        let theta = spec.init_theta(&mut Pcg64::seed(35));
        let qm = quantize_model(&spec, &theta, QuantMethod::Log2, 2);
        assert_eq!(build_quantized(EngineKind::Lut, &qm).unwrap().name(), "lut");
        assert_eq!(
            build_quantized(EngineKind::Lut2, &qm).unwrap().name(),
            "lut2"
        );
        assert_eq!(
            build_quantized(EngineKind::CpuRef, &qm).unwrap().name(),
            "cpu-ref"
        );
        assert!(build_quantized(EngineKind::Runtime, &qm).is_err());
    }

    #[test]
    fn v2_engine_matches_v1_within_harness_tolerance() {
        let spec = crate::model::spec::ModelSpec::default_spec();
        let theta = spec.init_theta(&mut Pcg64::seed(36));
        let qm = quantize_model(&spec, &theta, QuantMethod::Ot, 3);
        let v1 = LutEngine::with_pool(&qm, Pool::serial()).unwrap();
        let v2 = LutV2Engine::with_config(&qm, Pool::serial(), Tuner::Heuristic).unwrap();
        let mut rng = Pcg64::seed(37);
        let x: Vec<f32> = (0..2 * spec.d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let t = [0.3, 0.7];
        let a = v1.velocity(&x, &t).unwrap();
        let b = v2.velocity(&x, &t).unwrap();
        crate::util::check::assert_close(&a, &b, 1e-5, 1e-6);
    }

    #[test]
    fn v2_engine_is_bit_identical_across_thread_counts_and_tuners() {
        let spec = crate::model::spec::ModelSpec::default_spec();
        let theta = spec.init_theta(&mut Pcg64::seed(38));
        let qm = quantize_model(&spec, &theta, QuantMethod::Uniform, 2);
        let mut rng = Pcg64::seed(39);
        // b = 2 exercises column sharding (b < threads); b = 9 row sharding
        for b in [2usize, 9] {
            let x: Vec<f32> = (0..b * spec.d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let t: Vec<f32> = (0..b).map(|i| (i as f32 + 0.5) / b as f32).collect();
            let serial = LutV2Engine::with_config(&qm, Pool::serial(), Tuner::Heuristic)
                .unwrap()
                .velocity(&x, &t)
                .unwrap();
            for threads in [3usize, 8] {
                for tuner in [Tuner::Heuristic, Tuner::measured()] {
                    let eng = LutV2Engine::with_config(&qm, Pool::new(threads), tuner).unwrap();
                    assert_eq!(
                        eng.velocity(&x, &t).unwrap(),
                        serial,
                        "b={b} threads={threads}"
                    );
                }
            }
        }
    }
}
