//! LUT-GEMM: matrix multiply directly over packed b-bit codes.
//!
//! The dequantize-then-GEMM serve path reconstructs every weight matrix to
//! dense f32 (4 bytes/weight) before each matmul, so low-bit compression
//! buys nothing at inference time. This kernel keeps weights as the packed
//! bitstream (b/8 bytes/weight) and a ≤256-entry codebook, and fuses
//! dequantization into the GEMM inner loop:
//!
//! * codes stream tile-by-tile out of the [`PackedCodes`] words into a
//!   small `u8` scratch that stays L1-resident (`TILE_K` weight rows);
//! * for each (batch row, weight row) pair the scalar product
//!   `a · levels[c]` is precomputed once per codebook entry into a 256-slot
//!   lookup table, so the inner loop is one byte load, one L1 table load
//!   and one add per weight — no multiply, no dense W materialization;
//! * accumulation order over k is identical to [`crate::tensor::matmul_into`]
//!   (ascending k within ascending tiles, skipping zero activations), so
//!   the result is bit-exact against the dequantize-then-GEMM reference.

use anyhow::{bail, Result};

use crate::model::quantized::QuantizedModel;
use crate::quant::packing::PackedCodes;

/// Weight rows unpacked per tile. 16 rows × 512 cols = 8 KB of `u8`
/// scratch — comfortably L1-resident alongside the accumulator row.
pub const TILE_K: usize = 16;

/// One weight matrix in executable packed form: `[rows, cols]` row-major
/// codes at `packed.bits` bits each, plus the sorted codebook levels.
#[derive(Clone, Debug)]
pub struct LutLayer {
    /// Weight layer name (matches the model spec's layer table).
    pub name: String,
    /// Fan-in (k dimension of x[m,k] @ W[k,n]).
    pub rows: usize,
    /// Fan-out (n dimension).
    pub cols: usize,
    /// Codebook levels; every packed code indexes into this.
    pub levels: Vec<f32>,
    /// The packed b-bit code stream (row-major `[rows, cols]`).
    pub packed: PackedCodes,
}

impl LutLayer {
    /// Build from raw codes (row-major `[rows, cols]`) and a codebook.
    pub fn new(
        name: &str,
        rows: usize,
        cols: usize,
        codes: &[u32],
        levels: Vec<f32>,
        bits: u8,
    ) -> Result<Self> {
        if codes.len() != rows * cols {
            bail!(
                "layer {name}: {} codes for shape [{rows}, {cols}]",
                codes.len()
            );
        }
        if levels.is_empty() || levels.len() > 256 {
            bail!(
                "layer {name}: codebook size {} outside 1..=256",
                levels.len()
            );
        }
        let bits = bits.clamp(1, 8);
        if let Some(&bad) = codes.iter().find(|&&c| c as usize >= levels.len()) {
            bail!(
                "layer {name}: code {bad} out of range for {} levels",
                levels.len()
            );
        }
        let packed = PackedCodes::pack(codes, bits)?;
        Ok(Self {
            name: name.to_string(),
            rows,
            cols,
            levels,
            packed,
        })
    }

    /// Extract one weight layer of a quantized model into packed form.
    pub fn from_model(qm: &QuantizedModel, layer_name: &str) -> Result<Self> {
        let spec = &qm.spec;
        let Some(l) = spec.layer(layer_name) else {
            bail!("unknown layer {layer_name}");
        };
        if l.shape.len() != 2 {
            bail!("{layer_name} is not a weight matrix");
        }
        let woff = spec.weight_offset(layer_name);
        let row = spec
            .weight_layers()
            .iter()
            .position(|wl| wl.name == layer_name)
            .expect("weight layer position"); // fmq-analyze: allow(panic_cone) -- from_model iterates the spec's own layer table; a miss here is a pack-time bug, found at load, never mid-request
        LutLayer::new(
            layer_name,
            l.shape[0], // fmq-analyze: allow(panic_cone) -- layer shapes are fixed 2-element arrays in the spec table (covers next line)
            l.shape[1],
            &qm.codes[woff..woff + l.size()], // fmq-analyze: allow(panic_cone) -- woff/row come from the same spec table the quantizer packed against; load-time code (covers next line)
            qm.codebooks[row].levels.clone(),
            qm.bits,
        )
    }

    /// Packed payload bytes (codes only).
    pub fn byte_len(&self) -> usize {
        self.packed.byte_len()
    }

    /// `out[m, cols] += x[m, rows] @ W` with W gathered from the packed
    /// codes. The caller zeroes (or pre-loads) `out`; accumulation matches
    /// `tensor::matmul_into` bit-for-bit (same multiply, same k order,
    /// same zero-activation skip). Allocates its own tile scratch — the
    /// hot path uses [`LutLayer::matmul_into_ws`] with a workspace
    /// buffer instead.
    pub fn matmul_into(&self, x: &[f32], out: &mut [f32], m: usize) {
        self.matmul_into_ws(x, out, m, &mut Vec::new())
    }

    /// [`LutLayer::matmul_into`] with the tile scratch drawn from a
    /// reusable workspace buffer (`Kernel::tile`), so steady-state calls
    /// perform zero heap allocations. Numerically identical to the
    /// allocating wrapper.
    pub fn matmul_into_ws(&self, x: &[f32], out: &mut [f32], m: usize, tile: &mut Vec<u8>) {
        let (kd, n) = (self.rows, self.cols);
        debug_assert_eq!(x.len(), m * kd);
        debug_assert_eq!(out.len(), m * n);
        let kmax = self.levels.len();
        // 256-slot table: a u8 code can never index out of it, so the
        // inner-loop gather compiles without a bounds check.
        let mut lut = [0f32; 256];
        tile.clear();
        tile.resize(TILE_K.min(kd.max(1)) * n, 0);
        let mut k0 = 0usize;
        while k0 < kd {
            let kt = TILE_K.min(kd - k0);
            self.packed.unpack_range_u8(k0 * n, &mut tile[..kt * n]);
            for i in 0..m {
                let xrow = &x[i * kd + k0..i * kd + k0 + kt];
                let orow = &mut out[i * n..(i + 1) * n];
                for (kk, &av) in xrow.iter().enumerate() {
                    if av == 0.0 {
                        continue;
                    }
                    for (slot, &lev) in lut[..kmax].iter_mut().zip(self.levels.iter()) {
                        *slot = av * lev;
                    }
                    let crow = &tile[kk * n..(kk + 1) * n];
                    for (o, &c) in orow.iter_mut().zip(crow.iter()) {
                        *o += lut[c as usize];
                    }
                }
            }
            k0 += kt;
        }
    }

    /// Materialize the dense f32 matrix (test/debug reference; the whole
    /// point of the engine is to never call this on the hot path).
    pub fn dequantize_dense(&self) -> Vec<f32> {
        let mut codes = vec![0u8; self.rows * self.cols];
        self.packed.unpack_range_u8(0, &mut codes);
        codes.iter().map(|&c| self.levels[c as usize]).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::matmul_into;
    use crate::util::check::assert_close;
    use crate::util::rng::Pcg64;

    fn random_layer(rng: &mut Pcg64, rows: usize, cols: usize, bits: u8) -> LutLayer {
        let k = 1usize << bits;
        let levels: Vec<f32> = (0..k)
            .map(|i| -0.3 + 0.6 * i as f32 / (k - 1).max(1) as f32)
            .collect();
        let codes: Vec<u32> = (0..rows * cols).map(|_| rng.below(k) as u32).collect();
        LutLayer::new("w_test", rows, cols, &codes, levels, bits).unwrap()
    }

    #[test]
    fn matches_dense_gemm_all_bit_widths() {
        let mut rng = Pcg64::seed(11);
        for bits in 1..=8u8 {
            // rows deliberately not a multiple of TILE_K
            let (m, rows, cols) = (3usize, 2 * TILE_K + 5, 33usize);
            let layer = random_layer(&mut rng, rows, cols, bits);
            let x: Vec<f32> = (0..m * rows).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let mut lut_out = vec![0f32; m * cols];
            layer.matmul_into(&x, &mut lut_out, m);
            let dense = layer.dequantize_dense();
            let mut ref_out = vec![0f32; m * cols];
            matmul_into(&x, &dense, &mut ref_out, m, rows, cols);
            assert_eq!(lut_out, ref_out, "bits={bits}: LUT GEMM must be bit-exact");
        }
    }

    #[test]
    fn accumulates_into_preloaded_output() {
        let mut rng = Pcg64::seed(12);
        let layer = random_layer(&mut rng, 8, 4, 2);
        let x = vec![1.0f32; 8];
        let mut out = vec![10.0f32; 4];
        let mut delta = vec![0f32; 4];
        layer.matmul_into(&x, &mut delta, 1);
        layer.matmul_into(&x, &mut out, 1);
        for (o, d) in out.iter().zip(delta.iter()) {
            assert!((o - (10.0 + d)).abs() < 1e-6);
        }
    }

    #[test]
    fn zero_activations_skip_cleanly() {
        let mut rng = Pcg64::seed(13);
        let layer = random_layer(&mut rng, 20, 6, 3);
        let mut x = vec![0f32; 20];
        x[7] = 0.5;
        let mut out = vec![0f32; 6];
        layer.matmul_into(&x, &mut out, 1);
        let dense = layer.dequantize_dense();
        let expect: Vec<f32> = (0..6).map(|j| 0.5 * dense[7 * 6 + j]).collect();
        assert_close(&out, &expect, 1e-6, 1e-7);
    }

    #[test]
    fn from_model_roundtrips_weights() {
        use crate::model::spec::ModelSpec;
        use crate::quant::{quantize_model, QuantMethod};
        let spec = ModelSpec::default_spec();
        let theta = spec.init_theta(&mut Pcg64::seed(14));
        let qm = quantize_model(&spec, &theta, QuantMethod::Ot, 3);
        let l = LutLayer::from_model(&qm, "w_t").unwrap();
        assert_eq!(l.rows * l.cols, spec.layer("w_t").unwrap().size());
        // dense reconstruction equals the model's own dequantization
        let deq = qm.dequantize();
        let want = deq.layer(&spec, "w_t");
        assert_eq!(l.dequantize_dense(), want);
        // 3-bit payload is ~10x smaller than f32
        assert!(l.byte_len() * 9 < l.rows * l.cols * 4);
    }

    #[test]
    fn rejects_bad_inputs() {
        assert!(LutLayer::new("w", 2, 2, &[0, 1, 2], vec![0.0, 1.0], 1).is_err()); // wrong len
        assert!(LutLayer::new("w", 1, 2, &[0, 1], vec![], 1).is_err()); // empty codebook
        assert!(LutLayer::new("w", 1, 2, &[0, 5], vec![0.0, 1.0], 3).is_err()); // code too big
    }
}
