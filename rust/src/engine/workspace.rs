//! Reusable per-worker scratch arenas for the inference hot path.
//!
//! Every `velocity` call used to heap-allocate its activation buffers
//! (`ht`/`h`/`u`/`r2`/`out`), its kernel decode scratch and — under
//! column sharding — a stripe buffer per shard, multiplied by
//! `steps × super-batches × requests` on the serving path. A
//! [`Workspace`] is the arena that replaces all of those: a set of
//! named, size-checked scratch buffers (f32 activations, u8 code
//! scratch, fused-table storage, stripe/tuning temporaries) that grow
//! to their high-water size once and are then reused for the lifetime
//! of the worker that owns them.
//!
//! Ownership model (see `docs/ARCHITECTURE.md` § Memory model):
//!
//! * the serving worker's `EngineStep` owns one workspace and threads
//!   it through `Engine::velocity_into` — the serial path runs entirely
//!   in that arena;
//! * every [`crate::engine::Pool`] built with `Pool::new` owns one
//!   workspace per worker slot, so row shards and column shards each
//!   reuse a private arena across calls with no cross-thread sharing
//!   beyond an uncontended slot mutex;
//! * [`Workspace::new`] performs **no** heap allocation, so constructing
//!   a throwaway workspace (the allocating `velocity` wrapper, the
//!   serial `Pool`) is free until buffers are actually used.
//!
//! The arena also hosts the per-step time-embedding cache: the ODE
//! integrators visit a fixed, deterministic t-grid
//! ([`crate::flow::ode::StepGrid`]) and share one `t` across the batch,
//! so the `time_features` row for each grid point is computed once,
//! memoized by its exact bit pattern, and broadcast — across batch
//! rows, steps, and super-batches of the same step count.

// BTreeMap (not HashMap): the determinism lint denies unordered
// containers in engine state so no iteration order can leak into
// observable behavior (see docs/STATIC_ANALYSIS.md). Keying by f32 bit
// pattern keeps the ordering total.
use std::collections::BTreeMap;

use crate::engine::blocked::Scratch;
use crate::model::spec::ModelSpec;

/// Rows kept in the time-embedding cache before it is reset. A serving
/// worker sees at most `steps + 1` distinct t values per direction, so
/// this bound only trips under pathological mixed-step traffic.
const MAX_CACHED_TEMB_ROWS: usize = 4096;

/// Resize-and-zero an f32 scratch buffer to exactly `len`, reusing its
/// capacity: after the first growth this never touches the allocator.
/// The returned slice is exactly `len` long, so downstream `zip`s and
/// `chunks` are size-checked against the shape the caller asked for.
#[fmq_macros::no_alloc]
pub fn take_zeroed(buf: &mut Vec<f32>, len: usize) -> &mut [f32] {
    buf.clear();
    buf.resize(len, 0.0);
    &mut buf[..]
}

/// Per-step time-embedding rows, keyed by the exact f32 bit pattern of
/// `t`. Valid for one (temb_freqs, freq_max) fingerprint at a time —
/// reusing the workspace across architectures resets it.
#[derive(Default)]
struct TembCache {
    /// (temb_freqs, freq_max bits) the cached rows were computed for.
    fp: (usize, u32),
    /// t bits → `time_features` row (`[2 * temb_freqs]`).
    rows: BTreeMap<u32, Vec<f32>>,
    /// Peak `rows` bytes ever held, surviving cache resets so the
    /// arena's high-water accounting stays monotone.
    hw_bytes: usize,
}

impl TembCache {
    /// The `time_features` row for scalar `t`: cached after the first
    /// computation, bit-identical to the uncached path (the row is a
    /// pure function of `(spec.temb_freqs, spec.freq_max, t)`).
    fn row(&mut self, spec: &ModelSpec, t: f32) -> &[f32] {
        let fp = (spec.temb_freqs, spec.freq_max.to_bits());
        if self.fp != fp {
            self.reset();
            self.fp = fp;
        }
        if self.rows.len() > MAX_CACHED_TEMB_ROWS {
            self.reset();
        }
        self.rows
            .entry(t.to_bits())
            .or_insert_with(|| crate::flow::cpu_ref::time_features(spec, &[t]))
    }

    /// Clear the rows, folding their footprint into the high-water mark
    /// first (the only place the cache ever shrinks).
    fn reset(&mut self) {
        self.hw_bytes = self.bytes();
        self.rows.clear();
    }

    fn bytes(&self) -> usize {
        self.hw_bytes
            .max(self.rows.values().map(|r| r.capacity() * 4).sum())
    }
}

/// Activation-side scratch for one forward pass: the op sequence's
/// intermediate matrices plus the time-embedding cache. One instance
/// serves any batch size / architecture — buffers are resized (never
/// shrunk) per call.
#[derive(Default)]
pub struct Activations {
    /// Time-feature matrix, flat `[B, 2 * temb_freqs]`.
    pub temb: Vec<f32>,
    /// `silu(temb @ w_t + b_t)`, flat `[B, hidden]`.
    pub ht: Vec<f32>,
    /// Running hidden state, flat `[B, hidden]`.
    pub h: Vec<f32>,
    /// Residual-block inner activation, flat `[B, hidden]`.
    pub u: Vec<f32>,
    /// Residual-block output before the skip add, flat `[B, hidden]`.
    pub r2: Vec<f32>,
    cache: TembCache,
}

impl Activations {
    /// Fill `self.temb` with the `[B, 2f]` time-feature matrix for `t`.
    /// When the batch shares a single `t` (every ODE step does), the row
    /// is served from the per-step cache and broadcast; mixed-t batches
    /// compute all rows directly. Either way the result is bit-identical
    /// to `cpu_ref::time_features(spec, t)`.
    pub fn fill_temb(&mut self, spec: &ModelSpec, t: &[f32]) {
        let td = 2 * spec.temb_freqs;
        let Self { temb, cache, .. } = self;
        temb.clear();
        let Some(&first) = t.first() else {
            return;
        };
        if td == 0 {
            return;
        }
        let t0 = first.to_bits();
        if t.iter().all(|tv| tv.to_bits() == t0) {
            // broadcast by appending: no zero-fill pass — every element
            // is written exactly once (unlike the accumulator buffers,
            // temb is never read before being fully overwritten)
            let row = cache.row(spec, first);
            temb.reserve(t.len() * td);
            for _ in 0..t.len() {
                temb.extend_from_slice(row);
            }
        } else {
            temb.resize(t.len() * td, 0.0);
            crate::flow::cpu_ref::time_features_into(spec, t, temb);
        }
    }

    fn bytes(&self) -> usize {
        (self.temb.capacity()
            + self.ht.capacity()
            + self.h.capacity()
            + self.u.capacity()
            + self.r2.capacity())
            * 4
            + self.cache.bytes()
    }
}

/// Kernel-side scratch: everything the LUT-GEMM kernels need besides
/// their inputs — the v1 tile buffer, the v2 decode/fuse/table
/// [`Scratch`], the column-shard stripe accumulator and the autotuner's
/// throwaway measurement output.
#[derive(Default)]
pub struct Kernel {
    /// v2 blocked-kernel scratch (decoded codes, fused indices, tables).
    pub scratch: Scratch,
    /// v1 kernel's decoded tile rows (`[TILE_K, cols]` u8 codes).
    pub tile: Vec<u8>,
    /// Column-shard stripe accumulator (`[m, c1 - c0]`).
    pub stripe: Vec<f32>,
    /// Throwaway output for autotune measurement runs.
    pub tune_tmp: Vec<f32>,
}

impl Kernel {
    fn bytes(&self) -> usize {
        self.scratch.bytes()
            + self.tile.capacity()
            + (self.stripe.capacity() + self.tune_tmp.capacity()) * 4
    }
}

/// One worker's complete scratch arena: activation buffers + kernel
/// scratch. See the module docs for the ownership model.
#[derive(Default)]
pub struct Workspace {
    act: Activations,
    kern: Kernel,
}

impl Workspace {
    /// An empty workspace. Performs no heap allocation — buffers grow
    /// on first use and then stay at their high-water size.
    pub fn new() -> Self {
        Self::default()
    }

    /// Split into the activation and kernel halves, so a forward pass
    /// can hold the activation buffers while its matmul closure owns the
    /// kernel scratch (disjoint borrows of one arena).
    pub fn split(&mut self) -> (&mut Activations, &mut Kernel) {
        (&mut self.act, &mut self.kern)
    }

    /// The kernel-scratch half alone (column-shard slots).
    pub fn kernel(&mut self) -> &mut Kernel {
        &mut self.kern
    }

    /// High-water bytes across every buffer in the arena — the number
    /// the server's `stats` op aggregates as `workspace_bytes`. Scratch
    /// buffers only ever grow (resize reuses capacity, nothing shrinks)
    /// and the temb cache folds its peak into the mark before its rare
    /// resets, so this is monotone over the workspace's lifetime.
    pub fn high_water_bytes(&self) -> usize {
        self.act.bytes() + self.kern.bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_workspace_holds_no_memory() {
        let ws = Workspace::new();
        assert_eq!(ws.high_water_bytes(), 0);
    }

    #[test]
    fn take_zeroed_reuses_capacity_and_zeroes() {
        let mut buf = vec![1.0f32; 8];
        let s = take_zeroed(&mut buf, 5);
        assert_eq!(s, &[0.0; 5][..]);
        let p0 = buf.as_ptr();
        // shrinking then regrowing within capacity must not reallocate
        take_zeroed(&mut buf, 3);
        take_zeroed(&mut buf, 8);
        assert_eq!(buf.as_ptr(), p0);
        assert!(buf.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn temb_cache_matches_uncached_and_tracks_spec() {
        let spec = ModelSpec::default_spec();
        let mut act = Activations::default();
        let t = [0.3125f32, 0.3125, 0.3125];
        act.fill_temb(&spec, &t);
        let want = crate::flow::cpu_ref::time_features(&spec, &t);
        assert_eq!(act.temb, want, "broadcast cached row must be bit-identical");
        // second fill: served from cache, still identical
        act.fill_temb(&spec, &t);
        assert_eq!(act.temb, want);
        // mixed t falls back to the direct path
        let tm = [0.1f32, 0.9];
        act.fill_temb(&spec, &tm);
        assert_eq!(act.temb, crate::flow::cpu_ref::time_features(&spec, &tm));
        // a different architecture fingerprint invalidates the cache
        let mut small = ModelSpec::default_spec();
        small.temb_freqs = 4;
        act.fill_temb(&small, &[0.3125, 0.3125]);
        assert_eq!(
            act.temb,
            crate::flow::cpu_ref::time_features(&small, &[0.3125, 0.3125])
        );
    }

    #[test]
    fn high_water_is_monotone() {
        let spec = ModelSpec::default_spec();
        let mut ws = Workspace::new();
        ws.split().0.fill_temb(&spec, &[0.5; 4]);
        let after_big = ws.high_water_bytes();
        assert!(after_big > 0);
        ws.split().0.fill_temb(&spec, &[0.5]);
        assert!(ws.high_water_bytes() >= after_big, "arena must never shrink");
    }
}
