//! Fused quantized forward: the full velocity network executed directly
//! from packed codes via [`LutLayer`] — time features → hidden SiLU layers
//! → residual blocks → output, with **no dense weight materialization
//! anywhere**.
//!
//! One op sequence (`LutModel::forward_with`, private) serves two
//! kernel generations, both writing into a caller-provided output with
//! every intermediate drawn from a reusable
//! [`crate::engine::workspace::Workspace`] arena (zero heap allocations
//! in steady state — pinned by the `bench_engine` allocation counter):
//!
//! * [`LutModel::velocity_into`] — the v1 per-activation-LUT kernel,
//!   bit-exact against [`crate::flow::cpu_ref::qvelocity`] (same
//!   multiply, same accumulation order — pinned by
//!   `tests/engine_integration.rs`);
//! * [`LutModel::velocity_into_v2`] — the blocked fused-group kernel
//!   from [`crate::engine::blocked`], dispatched through a
//!   [`crate::engine::tune::Tuner`], with intra-layer column sharding
//!   when the batch is too small to feed the pool. Equivalent to v1
//!   within the 1e-5 harness (group fusion re-associates sums), and
//!   bit-identical to *itself* across tile plans, thread counts and
//!   sharding axes.
//!
//! Layer and bias references are resolved to indices/offsets once at
//! construction, so the per-call path does no name lookups (the old
//! `format!("w1_{i}")` strings were a per-step heap allocation).

use anyhow::{bail, Result};

use crate::engine::blocked;
use crate::engine::lut::LutLayer;
use crate::engine::pool::Pool;
use crate::engine::tune::Tuner;
use crate::engine::workspace::{take_zeroed, Workspace};
use crate::model::quantized::QuantizedModel;
use crate::model::spec::ModelSpec;

/// Minimum output columns per shard before column sharding engages —
/// below this the scoped-spawn overhead outweighs the stripe work.
const COL_SHARD_MIN: usize = 64;

#[inline]
fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// One residual block's resolved parameter references.
struct BlockRefs {
    w1: usize,
    b1: (usize, usize),
    w2: usize,
    b2: (usize, usize),
}

/// Every layer/bias reference the op sequence needs, resolved to
/// indices into `layers` and `(offset, len)` ranges into `biases` at
/// construction time — the hot path never touches a layer name.
struct OpRefs {
    w_t: usize,
    b_t: (usize, usize),
    w_in: usize,
    b_in: (usize, usize),
    blocks: Vec<BlockRefs>,
    w_out: usize,
    b_out: (usize, usize),
}

/// A quantized model compiled to executable packed form: one [`LutLayer`]
/// per weight matrix plus the fp32 biases. Construction packs the codes
/// once (cheap, ~b/32 of the f32 model size); after that the model serves
/// from ~`P·b/8` bytes instead of `P·4`.
pub struct LutModel {
    /// The architecture this model executes.
    pub spec: ModelSpec,
    /// Code bit-width (1..=8).
    pub bits: u8,
    /// Ordered as `spec.weight_layers()`.
    layers: Vec<LutLayer>,
    /// All biases packed contiguously (`spec.pb()`), fp32.
    biases: Vec<f32>,
    refs: OpRefs,
}

impl LutModel {
    /// Pack a quantized model's codes into executable form.
    pub fn new(qm: &QuantizedModel) -> Result<Self> {
        if qm.bits > 8 {
            bail!("LUT engine supports 1..=8 bit codes, got {}", qm.bits);
        }
        let (spec, biases) = qm.adapter_base();
        let layers = spec
            .weight_layers()
            .iter()
            .map(|l| LutLayer::from_model(qm, &l.name))
            .collect::<Result<Vec<_>>>()?;
        let refs = OpRefs::resolve(&spec, &layers);
        Ok(Self {
            spec,
            bits: qm.bits.max(1),
            layers,
            biases,
            refs,
        })
    }

    /// Total packed bytes actually held (codes + codebooks + fp32 biases)
    /// — the engine's resident model footprint.
    pub fn resident_bytes(&self) -> usize {
        let codes: usize = self.layers.iter().map(|l| l.byte_len()).sum();
        let cbs: usize = self.layers.iter().map(|l| l.levels.len() * 4).sum();
        codes + cbs + self.biases.len() * 4
    }

    /// Velocity forward: x flat [B, D], t [B] → v flat [B, D], through
    /// the v1 per-activation-LUT kernel (bit-exact vs `cpu_ref`).
    /// Allocating wrapper over [`LutModel::velocity_into`].
    pub fn velocity(&self, x: &[f32], t: &[f32]) -> Vec<f32> {
        let mut out = vec![0f32; t.len() * self.spec.d];
        self.velocity_into(x, t, &mut out, &mut Workspace::new());
        out
    }

    /// v1 velocity forward into a caller-provided output, with every
    /// intermediate drawn from `ws`. Bit-identical to
    /// [`LutModel::velocity`] regardless of how dirty the reused
    /// workspace (or `out`) is — every buffer is size-set and zeroed
    /// before use.
    pub fn velocity_into(&self, x: &[f32], t: &[f32], out: &mut [f32], ws: &mut Workspace) {
        let (act, kern) = ws.split();
        let tile = &mut kern.tile;
        self.forward_with(
            x,
            t,
            out,
            act,
            &mut |l: &LutLayer, xs: &[f32], o: &mut [f32], m: usize| {
                let span = crate::obs::Span::begin();
                l.matmul_into_ws(xs, o, m, &mut *tile);
                span.end(&crate::obs::ENGINE.layer_sweep_ns);
            },
        );
    }

    /// Velocity forward through the v2 blocked fused-group kernel, into
    /// a caller-provided output. `tuner` picks tile plans (see
    /// [`crate::engine::tune`]); `col_pool = Some(pool)` supplies the
    /// intra-layer column-sharding axis used when the batch is smaller
    /// than the thread count (the caller handles batch sharding — see
    /// `LutV2Engine::velocity_into`), with each shard computing into its
    /// own pool-slot arena; `None` runs every layer full-width in `ws`.
    /// After warm-up (scratch growth + autotune) the path performs no
    /// heap allocations and no per-element unpacking.
    pub fn velocity_into_v2(
        &self,
        x: &[f32],
        t: &[f32],
        out: &mut [f32],
        tuner: &Tuner,
        col_pool: Option<&Pool>,
        ws: &mut Workspace,
    ) {
        let (act, kern) = ws.split();
        let mm = &mut |l: &LutLayer, xs: &[f32], o: &mut [f32], m: usize| {
            let span = crate::obs::Span::begin();
            let n = l.cols;
            let sharded = col_pool
                .filter(|p| p.threads() > 1 && m < p.threads() && n >= 2 * COL_SHARD_MIN);
            if let Some(pool) = sharded {
                // latency-bound regime: shard output columns; stripes are
                // bit-identical to the full-width kernel, so the scatter
                // below reassembles the exact serial result. Each shard
                // leases the stripe buffer out of its slot arena and the
                // scatter hands it back, so capacity is reused across
                // layers and calls.
                let stripes = pool.map_shards(n, COL_SHARD_MIN, |idx, c0, c1| {
                    let mut slot = pool.workspace(idx);
                    let kern = slot.kernel();
                    let mut stripe = std::mem::take(&mut kern.stripe);
                    take_zeroed(&mut stripe, m * (c1 - c0));
                    let plan = blocked::plan_stripe(l, tuner, xs, m, c0, c1, kern); // fmq-analyze: allow(lock_order) -- this shard's slot idx is exclusive (map_shards hands each closure its own), and the may-block witness is the analyzer resolving the atomic `load` in timing_enabled to ArtifactSet::load by method name; covers next line
                    blocked::matmul_stripe(l, xs, &mut stripe, m, c0, c1, plan, &mut kern.scratch);
                    (idx, stripe)
                });
                for (c0, c1, (idx, stripe)) in stripes {
                    let wst = c1 - c0;
                    for i in 0..m {
                        let orow = &mut o[i * n + c0..i * n + c1];
                        for (ov, &v) in orow.iter_mut().zip(stripe[i * wst..(i + 1) * wst].iter()) {
                            *ov += v;
                        }
                    }
                    pool.workspace(idx).kernel().stripe = stripe;
                }
            } else {
                let plan = blocked::plan_stripe(l, tuner, xs, m, 0, n, &mut *kern);
                blocked::matmul_stripe(l, xs, o, m, 0, n, plan, &mut kern.scratch);
            }
            span.end(&crate::obs::ENGINE.layer_sweep_ns);
        };
        self.forward_with(x, t, out, act, mm);
    }

    /// The shared op sequence — time embedding, input projection,
    /// residual blocks, output head — parameterized over the matmul
    /// kernel. Bias handling and op order mirror `flow/cpu_ref.rs::
    /// forward` exactly; `mm` must *accumulate* `x @ W` into its zeroed
    /// output, which both kernel generations do. `out` and every
    /// activation buffer are zeroed here, so dirty reuse is safe.
    fn forward_with(
        &self,
        x: &[f32],
        t: &[f32],
        out: &mut [f32],
        act: &mut crate::engine::workspace::Activations,
        mm: &mut dyn FnMut(&LutLayer, &[f32], &mut [f32], usize),
    ) {
        let spec = &self.spec;
        let b = t.len();
        let (d, h_dim) = (spec.d, spec.hidden);
        assert_eq!(x.len(), b * d); // fmq-analyze: allow(panic_cone) -- shape contract: batcher and engine size x/out from the same spec.d (slice-conformance tests enforce it end-to-end; covers next line)
        assert_eq!(out.len(), b * d);
        let refs = &self.refs;
        let bias = |(off, len): (usize, usize)| &self.biases[off..off + len];

        // temb: one cached row broadcast when the batch shares t (every
        // ODE step does), computed directly otherwise
        act.fill_temb(spec, t);

        // ht = silu(temb @ w_t + b_t)
        take_zeroed(&mut act.ht, b * h_dim);
        mm(&self.layers[refs.w_t], &act.temb, &mut act.ht, b);
        let b_t = bias(refs.b_t);
        for r in act.ht.chunks_mut(h_dim) {
            for (v, &bb) in r.iter_mut().zip(b_t.iter()) {
                *v = silu(*v + bb);
            }
        }

        // h = x @ w_in + b_in + ht
        take_zeroed(&mut act.h, b * h_dim);
        mm(&self.layers[refs.w_in], x, &mut act.h, b);
        let b_in = bias(refs.b_in);
        for (r, rt) in act.h.chunks_mut(h_dim).zip(act.ht.chunks(h_dim)) {
            for ((v, &bb), &tv) in r.iter_mut().zip(b_in.iter()).zip(rt.iter()) {
                *v += bb + tv;
            }
        }

        // residual blocks: h += silu(h @ w1 + b1) @ w2 + b2
        take_zeroed(&mut act.u, b * h_dim);
        take_zeroed(&mut act.r2, b * h_dim);
        for blk in &refs.blocks {
            act.u.iter_mut().for_each(|v| *v = 0.0);
            mm(&self.layers[blk.w1], &act.h, &mut act.u, b);
            let b1 = bias(blk.b1);
            for r in act.u.chunks_mut(h_dim) {
                for (v, &bb) in r.iter_mut().zip(b1.iter()) {
                    *v = silu(*v + bb);
                }
            }
            act.r2.iter_mut().for_each(|v| *v = 0.0);
            mm(&self.layers[blk.w2], &act.u, &mut act.r2, b);
            let b2 = bias(blk.b2);
            for (hr, rr) in act.h.chunks_mut(h_dim).zip(act.r2.chunks(h_dim)) {
                for ((v, &rv), &bb) in hr.iter_mut().zip(rr.iter()).zip(b2.iter()) {
                    *v += rv + bb;
                }
            }
        }

        // v = h @ w_out + b_out
        out.iter_mut().for_each(|v| *v = 0.0);
        mm(&self.layers[refs.w_out], &act.h, out, b);
        let b_out = bias(refs.b_out);
        for r in out.chunks_mut(d) {
            for (v, &bb) in r.iter_mut().zip(b_out.iter()) {
                *v += bb;
            }
        }
    }
}

impl OpRefs {
    /// Resolve every name the op sequence uses against the packed layer
    /// list and the spec's bias table. Panics on a malformed spec (the
    /// same condition the old per-call name lookups panicked on).
    fn resolve(spec: &ModelSpec, layers: &[LutLayer]) -> Self {
        let widx = |name: &str| {
            layers
                .iter()
                .position(|l| l.name == name)
                .unwrap_or_else(|| panic!("unknown weight layer {name}")) // fmq-analyze: allow(panic_cone) -- OpRefs::resolve runs once at model load; a malformed spec fails deployment before any request is accepted
        };
        let bref = |name: &str| {
            let l = spec.layer(name).unwrap_or_else(|| panic!("bias layer {name}")); // fmq-analyze: allow(panic_cone) -- same load-time spec resolution as widx above
            (spec.bias_offset(name), l.size())
        };
        OpRefs {
            w_t: widx("w_t"),
            b_t: bref("b_t"),
            w_in: widx("w_in"),
            b_in: bref("b_in"),
            blocks: (0..spec.blocks)
                .map(|i| BlockRefs {
                    w1: widx(&format!("w1_{i}")),
                    b1: bref(&format!("b1_{i}")),
                    w2: widx(&format!("w2_{i}")),
                    b2: bref(&format!("b2_{i}")),
                })
                .collect(),
            w_out: widx("w_out"),
            b_out: bref("b_out"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flow::cpu_ref;
    use crate::quant::{quantize_model, QuantMethod};
    use crate::util::rng::Pcg64;

    fn setup(method: QuantMethod, bits: u8) -> (ModelSpec, QuantizedModel) {
        let spec = ModelSpec::default_spec();
        let mut rng = Pcg64::seed(21);
        let theta = spec.init_theta(&mut rng);
        (spec.clone(), quantize_model(&spec, &theta, method, bits))
    }

    #[test]
    fn velocity_bit_exact_vs_cpu_ref() {
        let (spec, qm) = setup(QuantMethod::Ot, 3);
        let lm = LutModel::new(&qm).unwrap();
        let mut rng = Pcg64::seed(22);
        let x: Vec<f32> = (0..2 * spec.d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let t = [0.2, 0.9];
        let v_lut = lm.velocity(&x, &t);
        let v_ref = cpu_ref::qvelocity(&qm, &x, &t);
        assert_eq!(v_lut, v_ref, "LUT forward must be bit-exact vs cpu_ref");
    }

    #[test]
    fn velocity_bit_exact_at_two_bits_uniform() {
        let (spec, qm) = setup(QuantMethod::Uniform, 2);
        let lm = LutModel::new(&qm).unwrap();
        let mut rng = Pcg64::seed(23);
        let x: Vec<f32> = (0..spec.d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        assert_eq!(
            lm.velocity(&x, &[0.4]),
            cpu_ref::qvelocity(&qm, &x, &[0.4])
        );
    }

    #[test]
    fn velocity_into_dirty_workspace_and_output_are_invisible() {
        let (spec, qm) = setup(QuantMethod::Ot, 2);
        let lm = LutModel::new(&qm).unwrap();
        let mut rng = Pcg64::seed(24);
        let x: Vec<f32> = (0..3 * spec.d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let t = [0.25f32, 0.25, 0.25];
        let want = lm.velocity(&x, &t);
        // dirty the workspace with a different batch shape first, then a
        // poisoned output buffer: both must be invisible
        let mut ws = Workspace::new();
        let mut junk = vec![0f32; spec.d];
        lm.velocity_into(&x[..spec.d], &t[..1], &mut junk, &mut ws);
        let mut out = vec![f32::NAN; 3 * spec.d];
        lm.velocity_into(&x, &t, &mut out, &mut ws);
        assert_eq!(out, want);
        // v2 through the same dirty workspace, serial full-width
        let mut out2 = vec![f32::INFINITY; 3 * spec.d];
        lm.velocity_into_v2(&x, &t, &mut out2, &Tuner::Heuristic, None, &mut ws);
        crate::util::check::assert_close(&out2, &want, 1e-5, 1e-6);
        assert!(ws.high_water_bytes() > 0);
    }

    #[test]
    fn resident_footprint_tracks_bits() {
        let (spec, q2) = setup(QuantMethod::Ot, 2);
        let (_, q8) = setup(QuantMethod::Ot, 8);
        let m2 = LutModel::new(&q2).unwrap();
        let m8 = LutModel::new(&q8).unwrap();
        assert!(m2.resident_bytes() < m8.resident_bytes());
        // 2-bit resident model is far below the fp32 footprint
        assert!(m2.resident_bytes() * 8 < spec.p() * 4);
    }
}
