//! Fused quantized forward: the full velocity network executed directly
//! from packed codes via [`LutLayer`] — time features → hidden SiLU layers
//! → residual blocks → output, with **no dense weight materialization
//! anywhere**.
//!
//! One op sequence (`LutModel::forward_with`, private) serves two
//! kernel generations:
//!
//! * [`LutModel::velocity`] — the v1 per-activation-LUT kernel, bit-exact
//!   against [`crate::flow::cpu_ref::qvelocity`] (same multiply, same
//!   accumulation order — pinned by `tests/engine_integration.rs`);
//! * [`LutModel::velocity_v2`] — the blocked fused-group kernel from
//!   [`crate::engine::blocked`], dispatched through a
//!   [`crate::engine::tune::Tuner`], with intra-layer column sharding
//!   when the batch is too small to feed the pool. Equivalent to v1
//!   within the 1e-5 harness (group fusion re-associates sums), and
//!   bit-identical to *itself* across tile plans, thread counts and
//!   sharding axes.

use anyhow::{bail, Result};

use crate::engine::blocked::{self, Scratch};
use crate::engine::lut::LutLayer;
use crate::engine::pool::Pool;
use crate::engine::tune::Tuner;
use crate::flow::cpu_ref::time_features;
use crate::model::quantized::QuantizedModel;
use crate::model::spec::ModelSpec;

/// Minimum output columns per shard before column sharding engages —
/// below this the scoped-spawn overhead outweighs the stripe work.
const COL_SHARD_MIN: usize = 64;

#[inline]
fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// A quantized model compiled to executable packed form: one [`LutLayer`]
/// per weight matrix plus the fp32 biases. Construction packs the codes
/// once (cheap, ~b/32 of the f32 model size); after that the model serves
/// from ~`P·b/8` bytes instead of `P·4`.
pub struct LutModel {
    /// The architecture this model executes.
    pub spec: ModelSpec,
    /// Code bit-width (1..=8).
    pub bits: u8,
    /// Ordered as `spec.weight_layers()`.
    layers: Vec<LutLayer>,
    /// All biases packed contiguously (`spec.pb()`), fp32.
    biases: Vec<f32>,
}

impl LutModel {
    /// Pack a quantized model's codes into executable form.
    pub fn new(qm: &QuantizedModel) -> Result<Self> {
        if qm.bits > 8 {
            bail!("LUT engine supports 1..=8 bit codes, got {}", qm.bits);
        }
        let spec = qm.spec.clone();
        let layers = spec
            .weight_layers()
            .iter()
            .map(|l| LutLayer::from_model(qm, &l.name))
            .collect::<Result<Vec<_>>>()?;
        Ok(Self {
            spec,
            bits: qm.bits.max(1),
            layers,
            biases: qm.biases.clone(),
        })
    }

    fn layer(&self, name: &str) -> &LutLayer {
        self.layers
            .iter()
            .find(|l| l.name == name)
            .unwrap_or_else(|| panic!("unknown weight layer {name}"))
    }

    fn bias(&self, name: &str) -> &[f32] {
        let l = self.spec.layer(name).expect("bias layer");
        let boff = self.spec.bias_offset(name);
        &self.biases[boff..boff + l.size()]
    }

    /// Total packed bytes actually held (codes + codebooks + fp32 biases)
    /// — the engine's resident model footprint.
    pub fn resident_bytes(&self) -> usize {
        let codes: usize = self.layers.iter().map(|l| l.byte_len()).sum();
        let cbs: usize = self.layers.iter().map(|l| l.levels.len() * 4).sum();
        codes + cbs + self.biases.len() * 4
    }

    /// Velocity forward: x flat [B, D], t [B] → v flat [B, D], through
    /// the v1 per-activation-LUT kernel (bit-exact vs `cpu_ref`).
    pub fn velocity(&self, x: &[f32], t: &[f32]) -> Vec<f32> {
        self.forward_with(x, t, &mut |l: &LutLayer, xs: &[f32], out: &mut [f32], m: usize| {
            l.matmul_into(xs, out, m)
        })
    }

    /// Velocity forward through the v2 blocked fused-group kernel.
    /// `tuner` picks tile plans (see [`crate::engine::tune`]); `pool`
    /// supplies the intra-layer column-sharding axis used when the batch
    /// is smaller than the thread count (the caller handles batch
    /// sharding — see `LutV2Engine::velocity`). Scratch buffers —
    /// serial and one slot per column shard — are reused across all
    /// layers and tiles of the call, so the hot path performs no
    /// per-element unpacking and no per-tile allocation (only the stripe
    /// result buffers are allocated per sharded GEMM).
    pub fn velocity_v2(&self, x: &[f32], t: &[f32], tuner: &Tuner, pool: &Pool) -> Vec<f32> {
        let threads = pool.threads();
        let mut scratch = Scratch::new();
        // per-shard scratch slots, reused across every sharded layer GEMM
        // of this call; each shard index locks only its own slot, so the
        // mutexes are uncontended
        let shard_scratch: Vec<std::sync::Mutex<Scratch>> =
            (0..threads).map(|_| std::sync::Mutex::new(Scratch::new())).collect();
        self.forward_with(x, t, &mut |l: &LutLayer, xs: &[f32], out: &mut [f32], m: usize| {
            let n = l.cols;
            if threads > 1 && m < threads && n >= 2 * COL_SHARD_MIN {
                // latency-bound regime: shard output columns; stripes are
                // bit-identical to the full-width kernel, so the scatter
                // below reassembles the exact serial result
                let stripes = pool.map_shards(n, COL_SHARD_MIN, |idx, c0, c1| {
                    let mut s = shard_scratch[idx]
                        .lock()
                        .unwrap_or_else(|e| e.into_inner());
                    let mut stripe = vec![0f32; m * (c1 - c0)];
                    let plan = blocked::plan_stripe(l, tuner, xs, m, c0, c1, &mut s);
                    blocked::matmul_stripe(l, xs, &mut stripe, m, c0, c1, plan, &mut s);
                    stripe
                });
                for (c0, c1, stripe) in stripes {
                    let wst = c1 - c0;
                    for i in 0..m {
                        let orow = &mut out[i * n + c0..i * n + c1];
                        for (o, &v) in orow.iter_mut().zip(stripe[i * wst..(i + 1) * wst].iter()) {
                            *o += v;
                        }
                    }
                }
            } else {
                let plan = blocked::plan_stripe(l, tuner, xs, m, 0, n, &mut scratch);
                blocked::matmul_stripe(l, xs, out, m, 0, n, plan, &mut scratch);
            }
        })
    }

    /// The shared op sequence — time embedding, input projection,
    /// residual blocks, output head — parameterized over the matmul
    /// kernel. Bias handling and op order mirror `flow/cpu_ref.rs::
    /// forward` exactly; `mm` must *accumulate* `x @ W` into its zeroed
    /// output, which both kernel generations do.
    fn forward_with(
        &self,
        x: &[f32],
        t: &[f32],
        mm: &mut dyn FnMut(&LutLayer, &[f32], &mut [f32], usize),
    ) -> Vec<f32> {
        let spec = &self.spec;
        let b = t.len();
        let (d, h_dim) = (spec.d, spec.hidden);
        assert_eq!(x.len(), b * d);

        // ht = silu(temb @ w_t + b_t)
        let temb = time_features(spec, t);
        let mut ht = vec![0f32; b * h_dim];
        mm(self.layer("w_t"), &temb, &mut ht, b);
        let b_t = self.bias("b_t");
        for r in ht.chunks_mut(h_dim) {
            for (v, &bb) in r.iter_mut().zip(b_t.iter()) {
                *v = silu(*v + bb);
            }
        }

        // h = x @ w_in + b_in + ht
        let mut h = vec![0f32; b * h_dim];
        mm(self.layer("w_in"), x, &mut h, b);
        let b_in = self.bias("b_in");
        for (r, rt) in h.chunks_mut(h_dim).zip(ht.chunks(h_dim)) {
            for ((v, &bb), &tv) in r.iter_mut().zip(b_in.iter()).zip(rt.iter()) {
                *v += bb + tv;
            }
        }

        // residual blocks: h += silu(h @ w1 + b1) @ w2 + b2
        let mut u = vec![0f32; b * h_dim];
        let mut r2 = vec![0f32; b * h_dim];
        for i in 0..spec.blocks {
            u.iter_mut().for_each(|v| *v = 0.0);
            mm(self.layer(&format!("w1_{i}")), &h, &mut u, b);
            let b1 = self.bias(&format!("b1_{i}"));
            for r in u.chunks_mut(h_dim) {
                for (v, &bb) in r.iter_mut().zip(b1.iter()) {
                    *v = silu(*v + bb);
                }
            }
            r2.iter_mut().for_each(|v| *v = 0.0);
            mm(self.layer(&format!("w2_{i}")), &u, &mut r2, b);
            let b2 = self.bias(&format!("b2_{i}"));
            for (hr, rr) in h.chunks_mut(h_dim).zip(r2.chunks(h_dim)) {
                for ((v, &rv), &bb) in hr.iter_mut().zip(rr.iter()).zip(b2.iter()) {
                    *v += rv + bb;
                }
            }
        }

        // v = h @ w_out + b_out
        let mut out = vec![0f32; b * d];
        mm(self.layer("w_out"), &h, &mut out, b);
        let b_out = self.bias("b_out");
        for r in out.chunks_mut(d) {
            for (v, &bb) in r.iter_mut().zip(b_out.iter()) {
                *v += bb;
            }
        }
        out
    }

}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flow::cpu_ref;
    use crate::quant::{quantize_model, QuantMethod};
    use crate::util::rng::Pcg64;

    fn setup(method: QuantMethod, bits: u8) -> (ModelSpec, QuantizedModel) {
        let spec = ModelSpec::default_spec();
        let mut rng = Pcg64::seed(21);
        let theta = spec.init_theta(&mut rng);
        (spec.clone(), quantize_model(&spec, &theta, method, bits))
    }

    #[test]
    fn velocity_bit_exact_vs_cpu_ref() {
        let (spec, qm) = setup(QuantMethod::Ot, 3);
        let lm = LutModel::new(&qm).unwrap();
        let mut rng = Pcg64::seed(22);
        let x: Vec<f32> = (0..2 * spec.d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let t = [0.2, 0.9];
        let v_lut = lm.velocity(&x, &t);
        let v_ref = cpu_ref::qvelocity(&qm, &x, &t);
        assert_eq!(v_lut, v_ref, "LUT forward must be bit-exact vs cpu_ref");
    }

    #[test]
    fn velocity_bit_exact_at_two_bits_uniform() {
        let (spec, qm) = setup(QuantMethod::Uniform, 2);
        let lm = LutModel::new(&qm).unwrap();
        let mut rng = Pcg64::seed(23);
        let x: Vec<f32> = (0..spec.d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        assert_eq!(
            lm.velocity(&x, &[0.4]),
            cpu_ref::qvelocity(&qm, &x, &[0.4])
        );
    }

    #[test]
    fn resident_footprint_tracks_bits() {
        let (spec, q2) = setup(QuantMethod::Ot, 2);
        let (_, q8) = setup(QuantMethod::Ot, 8);
        let m2 = LutModel::new(&q2).unwrap();
        let m8 = LutModel::new(&q8).unwrap();
        assert!(m2.resident_bytes() < m8.resident_bytes());
        // 2-bit resident model is far below the fp32 footprint
        assert!(m2.resident_bytes() * 8 < spec.p() * 4);
    }
}
