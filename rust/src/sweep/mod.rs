//! Paper-grid conformance sweep (the `figgrid` subcommand).
//!
//! Runs the full figure grid end to end — complexity-ladder datasets ×
//! quantization methods × bit-widths × ODE solvers — through the fast
//! lut2 engine and the zero-alloc `EngineStep` sampler, scoring every
//! cell with the fidelity metrics (SSIM/PSNR/FID/coverage), the Fig. 4
//! latent round-trip stability, the weight-space W₂ error against its
//! closed-form uniform bound, and a measured discrete-Grönwall
//! trajectory bound (Lemma 1 with empirical constants). One machine-
//! readable `BENCH_figgrid.json` lands at the repo root; the
//! [`conformance`] checks assert the paper's qualitative ordering on the
//! result (degradation monotone in bits, OT no worse than the baselines
//! at 2–3 bits on every ladder rung, measured error within the bound,
//! primary engine ≡ reference engine per cell).
//!
//! Two tiers share all of this code: [`GridSpec::smoke`] (the
//! `FMQ_BENCH_FAST=1` CI grid and `tests/figgrid_conformance.rs`) and
//! [`GridSpec::full`] (the offline paper grid). The figure benches
//! (`bench_fig2_grid`/`bench_fig3_fidelity`/`bench_fig4_latent`) are
//! thin wrappers over the same runner.

pub mod conformance;
pub mod grid;

use std::collections::BTreeMap;

use crate::data::Dataset;
use crate::engine::EngineKind;
use crate::flow::ode::Solver;
use crate::quant::QuantMethod;
use crate::util::json::Json;

pub use grid::{run_cell_samples, run_grid};

/// The grid to run: every combination of the four axes, plus the run
/// parameters shared by all cells.
#[derive(Clone, Debug)]
pub struct GridSpec {
    pub datasets: Vec<Dataset>,
    pub methods: Vec<QuantMethod>,
    pub bits: Vec<u8>,
    pub solvers: Vec<Solver>,
    /// ODE steps per trajectory (dopri5: initial-step hint).
    pub steps: usize,
    /// Samples per cell.
    pub n: usize,
    /// Samples per engine super-batch.
    pub batch: usize,
    pub seed: u64,
    /// Primary engine every cell generates through.
    pub engine: EngineKind,
    /// Cross-check engine (per-cell equivalence deviation).
    pub check_engine: EngineKind,
    /// Samples / k-means iterations for the coverage templates.
    pub coverage_samples: usize,
    pub coverage_iters: usize,
    /// Probes for the paper-form Lipschitz estimate L̂_x.
    pub lipschitz_probes: usize,
    /// True for the smoke tier (recorded in the JSON).
    pub fast: bool,
}

impl GridSpec {
    /// The full paper grid (offline; minutes of CPU).
    pub fn full() -> Self {
        GridSpec {
            datasets: Dataset::ALL.to_vec(),
            methods: QuantMethod::PAPER.to_vec(),
            bits: vec![2, 3, 4, 8],
            solvers: vec![Solver::Euler, Solver::Heun, Solver::Dopri5],
            steps: 16,
            n: 64,
            batch: 16,
            seed: 7,
            engine: EngineKind::Lut2,
            check_engine: EngineKind::CpuRef,
            coverage_samples: 256,
            coverage_iters: 8,
            lipschitz_probes: 16,
            fast: false,
        }
    }

    /// The CI / integration-test smoke grid: same axes (minus 4-bit),
    /// tiny sample counts. Seconds of CPU, and every conformance
    /// invariant still has the cells it needs.
    pub fn smoke() -> Self {
        GridSpec {
            bits: vec![2, 3, 8],
            steps: 4,
            n: 4,
            batch: 4,
            coverage_samples: 64,
            coverage_iters: 4,
            lipschitz_probes: 4,
            fast: true,
            ..Self::full()
        }
    }

    /// Total cell count of the configured grid.
    pub fn cells(&self) -> usize {
        self.datasets.len() * self.methods.len() * self.bits.len() * self.solvers.len()
    }
}

/// Stable key of one grid cell inside `BENCH_figgrid.json`'s `cells`
/// object: `"<dataset>/<method>/b<bits>/<solver>"`.
pub fn cell_key(ds: Dataset, method: QuantMethod, bits: u8, solver: Solver) -> String {
    format!("{}/{}/b{}/{}", ds.name(), method.name(), bits, solver.name())
}

/// Everything measured for one (dataset, method, bits, solver) cell.
#[derive(Clone, Debug)]
pub struct CellResult {
    pub dataset: Dataset,
    pub method: QuantMethod,
    pub bits: u8,
    pub solver: Solver,
    // fidelity vs. the fp32 reference of the same solver
    pub ssim: f64,
    pub psnr: f64,
    pub fid: f64,
    pub cov_covered: f64,
    pub cov_entropy: f64,
    // Fig. 4 latent round-trip stability
    pub latent_var_mean: f64,
    pub latent_var_std: f64,
    pub latent_mean_abs: f64,
    pub latent_max_abs: f64,
    pub baseline_var_std: f64,
    // weight-space quantization error + its closed-form uniform bound
    pub w2_sq: f64,
    pub sup_err: f64,
    pub w2_uniform_bound: f64,
    pub sup_uniform_bound: f64,
    pub compression: f64,
    // measured discrete-Grönwall trajectory bound (euler discretization,
    // shared across the solver cells of one (dataset, method, bits))
    pub traj_dev: f64,
    pub dv_max: f64,
    pub l_hat: f64,
    pub traj_bound: f64,
    /// Paper-form Lemma 1 scale: amplification(L̂_x, 1)·dv_max with the
    /// probe-estimated L̂_x (informational — see `conformance`).
    pub eps_paper: f64,
    // engine equivalence + per-step cost
    pub engine_dev: f64,
    pub gen_seconds: f64,
    pub evals: usize,
    pub per_step_us: f64,
    pub per_eval_us: f64,
}

impl CellResult {
    pub fn key(&self) -> String {
        cell_key(self.dataset, self.method, self.bits, self.solver)
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("dataset", Json::Str(self.dataset.name().into())),
            ("ladder_rank", Json::Int(self.dataset.ladder_rank() as i128)),
            ("method", Json::Str(self.method.name().into())),
            ("bits", Json::Int(self.bits as i128)),
            ("solver", Json::Str(self.solver.name().into())),
            ("ssim", num(self.ssim)),
            ("psnr", num(self.psnr)),
            ("fid", num(self.fid)),
            ("cov_covered", num(self.cov_covered)),
            ("cov_entropy", num(self.cov_entropy)),
            ("latent_var_mean", num(self.latent_var_mean)),
            ("latent_var_std", num(self.latent_var_std)),
            ("latent_mean_abs", num(self.latent_mean_abs)),
            ("latent_max_abs", num(self.latent_max_abs)),
            ("baseline_var_std", num(self.baseline_var_std)),
            ("w2_sq", num(self.w2_sq)),
            ("sup_err", num(self.sup_err)),
            ("w2_uniform_bound", num(self.w2_uniform_bound)),
            ("sup_uniform_bound", num(self.sup_uniform_bound)),
            ("compression", num(self.compression)),
            ("traj_dev", num(self.traj_dev)),
            ("dv_max", num(self.dv_max)),
            ("l_hat", num(self.l_hat)),
            ("traj_bound", num(self.traj_bound)),
            ("eps_paper", num(self.eps_paper)),
            ("engine_dev", num(self.engine_dev)),
            ("gen_seconds", num(self.gen_seconds)),
            ("evals", Json::Int(self.evals as i128)),
            ("per_step_us", num(self.per_step_us)),
            ("per_eval_us", num(self.per_eval_us)),
        ])
    }
}

/// Clamp non-finite measurements to a finite sentinel so the JSON stays
/// parseable (exploded low-bit cells are data, not serialization bugs).
fn num(v: f64) -> Json {
    if v.is_finite() {
        Json::Num(v)
    } else if v.is_nan() {
        Json::Num(-1.0)
    } else {
        Json::Num(v.signum() * 1e300)
    }
}

/// Per-dataset context shared by all that dataset's cells.
#[derive(Clone, Debug)]
pub struct DatasetSummary {
    pub dataset: Dataset,
    /// Probe-estimated state-Lipschitz constant of the fp32 field.
    pub l_x_hat: f64,
}

/// The whole sweep result: the spec echo plus every cell.
#[derive(Clone, Debug)]
pub struct GridResult {
    pub spec: GridSpec,
    pub datasets: Vec<DatasetSummary>,
    pub cells: Vec<CellResult>,
}

impl GridResult {
    /// Look up one cell by its axes.
    pub fn cell(
        &self,
        ds: Dataset,
        method: QuantMethod,
        bits: u8,
        solver: Solver,
    ) -> Option<&CellResult> {
        self.cells.iter().find(|c| {
            c.dataset.name() == ds.name()
                && c.method.name() == method.name()
                && c.bits == bits
                && c.solver.name() == solver.name()
        })
    }

    pub fn to_json(&self) -> Json {
        let mut cells = BTreeMap::new();
        for c in &self.cells {
            cells.insert(c.key(), c.to_json());
        }
        let mut datasets = BTreeMap::new();
        for d in &self.datasets {
            datasets.insert(
                d.dataset.name().to_string(),
                Json::obj(vec![
                    ("ladder_rank", Json::Int(d.dataset.ladder_rank() as i128)),
                    ("l_x_hat", num(d.l_x_hat)),
                ]),
            );
        }
        Json::obj(vec![
            ("bench", Json::Str("figgrid".into())),
            ("fast_mode", Json::Bool(self.spec.fast)),
            ("engine", Json::Str(self.spec.engine.name().into())),
            ("check_engine", Json::Str(self.spec.check_engine.name().into())),
            ("steps", Json::Int(self.spec.steps as i128)),
            ("n", Json::Int(self.spec.n as i128)),
            ("seed", Json::Int(self.spec.seed as i128)),
            ("datasets", Json::Obj(datasets)),
            ("cells", Json::Obj(cells)),
        ])
    }

    /// Write `BENCH_figgrid.json` (or any path). Returns the serialized
    /// text so callers can log it.
    pub fn write_json(&self, path: &std::path::Path) -> anyhow::Result<String> {
        let text = self.to_json().to_string();
        std::fs::write(path, &text)?;
        Ok(text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cell_key_format_is_stable() {
        assert_eq!(
            cell_key(Dataset::SynthMnist, QuantMethod::Ot, 2, Solver::Euler),
            "synth-mnist/ot/b2/euler"
        );
        assert_eq!(
            cell_key(Dataset::SynthImagenet, QuantMethod::Log2, 8, Solver::Dopri5),
            "synth-imagenet/log2/b8/dopri5"
        );
    }

    #[test]
    fn smoke_grid_covers_every_axis() {
        let s = GridSpec::smoke();
        assert!(s.fast);
        assert_eq!(s.datasets.len(), Dataset::ALL.len());
        assert_eq!(s.methods.len(), QuantMethod::PAPER.len());
        assert!(s.bits.contains(&2) && s.bits.contains(&3) && s.bits.contains(&8));
        assert_eq!(s.solvers.len(), 3);
        assert_eq!(s.cells(), 5 * 4 * 3 * 3);
        let f = GridSpec::full();
        assert!(!f.fast);
        assert_eq!(f.cells(), 5 * 4 * 4 * 3);
    }

    #[test]
    fn non_finite_measurements_serialize_finite() {
        assert_eq!(num(f64::INFINITY), Json::Num(1e300));
        assert_eq!(num(f64::NEG_INFINITY), Json::Num(-1e300));
        assert_eq!(num(f64::NAN), Json::Num(-1.0));
        assert_eq!(num(0.5), Json::Num(0.5));
    }
}
