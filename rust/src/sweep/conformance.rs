//! Ordering and bound invariants over a finished sweep.
//!
//! [`check`] returns one human-readable violation string per broken
//! invariant (empty = conformant). The families, and why each slack is
//! what it is:
//!
//! 1. **Completeness** — every (dataset, method, bits, solver) cell the
//!    spec names must be present, with positive eval/latency fields.
//! 2. **Monotone degradation** — per (dataset, method): weight-space
//!    `w2_sq` non-increasing as bits increase (1% multiplicative slack
//!    for the seeded Lloyd iterations), and end-to-end SSIM at the
//!    widest bit-width no worse than at the narrowest (0.02 additive
//!    slack for sampling noise on tiny smoke batches).
//! 3. **OT wins at low bits** — on every ladder rung, OT's `w2_sq` is
//!    within 5% of (i.e. at most 1.05×) the uniform and log2 baselines'
//!    at 2 and 3 bits — the paper's Table 1/Fig. 2 ordering. Against
//!    the quantile-cored pwl baseline only an order-of-magnitude guard
//!    applies ([`OT_PWL_SLACK`]): equal-mass OT optimizes the W₂
//!    coupling, not MSE, and pwl is MSE-competitive at 3 bits.
//! 4. **Uniform closed form** — uniform cells must sit under the
//!    Definition-2 Δ_U bounds (`w2_uniform_bound`/`sup_uniform_bound`);
//!    these are theorems, so the slack is float-roundoff only. (OT's
//!    equal-mass `w2_sq` is *not* compared against the Bennett density
//!    form — measured values sit above it by design; see
//!    `theory/bounds.rs`.)
//! 5. **Trajectory bound** — the measured euler-discretization endpoint
//!    deviation must sit under the measured-constant Grönwall bound
//!    (`traj_bound`), a theorem for finite constants; non-finite
//!    constants (exploded low-bit fields) hold vacuously and are
//!    skipped.
//! 6. **Engine equivalence** — the primary (lut2) and check (cpu-ref)
//!    engines must agree per cell: ≤ 5e-3 max pixel deviation for the
//!    fixed-step solvers (the engines' 1e-4/1e-5 velocity tolerance,
//!    amplified along the trajectory). dopri5's accept/reject control
//!    flow may fork on sub-tolerance velocity differences, so its cells
//!    only require a finite deviation (it is recorded for the report).

use super::{CellResult, GridResult};
use crate::flow::ode::Solver;
use crate::quant::QuantMethod;

/// Multiplicative slack for the quantizer-error monotonicity family.
const W2_MONO_SLACK: f64 = 1.01;
/// Additive SSIM slack between the widest and narrowest bit-widths.
const SSIM_SLACK: f64 = 0.02;
/// Multiplicative slack for the OT-vs-uniform/log2 low-bit comparison.
const OT_SLACK: f64 = 1.05;
/// Guard for OT vs the quantile-cored pwl baseline. Equal-mass OT
/// optimizes the W₂ coupling, not MSE, and pwl's dense 2.5–97.5% core
/// is MSE-competitive — measured ~1.0× at 2 bits and 1.2–1.9× at
/// 3 bits on Gaussian-with-outlier layers — so against pwl this family
/// only guards order-of-magnitude regressions (a broken OT sort, an
/// off-by-one mass split), not strict dominance.
const OT_PWL_SLACK: f64 = 2.5;
/// Roundoff-only slack for the closed-form / Grönwall theorems.
const THEOREM_SLACK: f64 = 1.05;
/// Max per-pixel primary-vs-check deviation for fixed-step solvers.
const ENGINE_DEV_MAX: f64 = 5e-3;

/// Run every invariant family over `res`; returns the violations.
pub fn check(res: &GridResult) -> Vec<String> {
    let mut v = Vec::new();
    completeness(res, &mut v);
    monotone_degradation(res, &mut v);
    ot_wins_low_bits(res, &mut v);
    uniform_closed_form(res, &mut v);
    trajectory_bound_holds(res, &mut v);
    engine_equivalence(res, &mut v);
    v
}

fn completeness(res: &GridResult, v: &mut Vec<String>) {
    let spec = &res.spec;
    if res.cells.len() != spec.cells() {
        v.push(format!(
            "completeness: {} cells recorded, spec names {}",
            res.cells.len(),
            spec.cells()
        ));
    }
    for &ds in &spec.datasets {
        for &method in &spec.methods {
            for &bits in &spec.bits {
                for &solver in &spec.solvers {
                    match res.cell(ds, method, bits, solver) {
                        None => v.push(format!(
                            "completeness: missing cell {}",
                            super::cell_key(ds, method, bits, solver)
                        )),
                        Some(c) => {
                            let pos = |x: f64| x.is_finite() && x > 0.0;
                            if c.evals == 0
                                || !pos(c.gen_seconds)
                                || !pos(c.per_step_us)
                                || !pos(c.per_eval_us)
                            {
                                v.push(format!(
                                    "completeness: {} has non-positive cost fields \
                                     (evals={}, gen_seconds={}, per_step_us={}, per_eval_us={})",
                                    c.key(),
                                    c.evals,
                                    c.gen_seconds,
                                    c.per_step_us,
                                    c.per_eval_us
                                ));
                            }
                        }
                    }
                }
            }
        }
    }
}

fn monotone_degradation(res: &GridResult, v: &mut Vec<String>) {
    let spec = &res.spec;
    let mut bits = spec.bits.clone();
    bits.sort_unstable();
    for &ds in &spec.datasets {
        for &method in &spec.methods {
            for &solver in &spec.solvers {
                // w2_sq non-increasing across every adjacent bit pair
                for w in bits.windows(2) {
                    let (lo, hi) = (w[0], w[1]);
                    let (Some(cl), Some(ch)) = (
                        res.cell(ds, method, lo, solver),
                        res.cell(ds, method, hi, solver),
                    ) else {
                        continue;
                    };
                    if ch.w2_sq > cl.w2_sq * W2_MONO_SLACK + 1e-12 {
                        v.push(format!(
                            "monotone: {} w2_sq {} exceeds b{} value {}",
                            ch.key(),
                            ch.w2_sq,
                            lo,
                            cl.w2_sq
                        ));
                    }
                }
                // SSIM at the widest width no worse than at the narrowest
                if let (Some(&lo), Some(&hi)) = (bits.first(), bits.last()) {
                    if lo != hi {
                        let (Some(cl), Some(ch)) = (
                            res.cell(ds, method, lo, solver),
                            res.cell(ds, method, hi, solver),
                        ) else {
                            continue;
                        };
                        if ch.ssim + SSIM_SLACK < cl.ssim {
                            v.push(format!(
                                "monotone: {} ssim {} below b{} value {}",
                                ch.key(),
                                ch.ssim,
                                lo,
                                cl.ssim
                            ));
                        }
                    }
                }
            }
        }
    }
}

fn ot_wins_low_bits(res: &GridResult, v: &mut Vec<String>) {
    let spec = &res.spec;
    if !spec.methods.iter().any(|m| m.name() == QuantMethod::Ot.name()) {
        return;
    }
    let baselines = [QuantMethod::Uniform, QuantMethod::Pwl, QuantMethod::Log2];
    let Some(&solver) = spec.solvers.first() else {
        return;
    };
    for &ds in &spec.datasets {
        for bits in [2u8, 3] {
            if !spec.bits.contains(&bits) {
                continue;
            }
            let Some(ot) = res.cell(ds, QuantMethod::Ot, bits, solver) else {
                continue;
            };
            for base in baselines {
                if !spec.methods.iter().any(|m| m.name() == base.name()) {
                    continue;
                }
                let Some(bc) = res.cell(ds, base, bits, solver) else {
                    continue;
                };
                let slack = if base == QuantMethod::Pwl {
                    OT_PWL_SLACK
                } else {
                    OT_SLACK
                };
                if ot.w2_sq > bc.w2_sq * slack {
                    v.push(format!(
                        "ot-low-bit: {} w2_sq {} exceeds {} w2_sq {}",
                        ot.key(),
                        ot.w2_sq,
                        bc.key(),
                        bc.w2_sq
                    ));
                }
            }
        }
    }
}

fn uniform_closed_form(res: &GridResult, v: &mut Vec<String>) {
    for c in uniform_cells(res) {
        if c.w2_sq > c.w2_uniform_bound * THEOREM_SLACK + 1e-12 {
            v.push(format!(
                "uniform-bound: {} w2_sq {} above closed-form {}",
                c.key(),
                c.w2_sq,
                c.w2_uniform_bound
            ));
        }
        if c.sup_err > c.sup_uniform_bound * THEOREM_SLACK + 1e-12 {
            v.push(format!(
                "uniform-bound: {} sup {} above closed-form {}",
                c.key(),
                c.sup_err,
                c.sup_uniform_bound
            ));
        }
    }
}

fn uniform_cells(res: &GridResult) -> impl Iterator<Item = &CellResult> {
    res.cells
        .iter()
        .filter(|c| c.method.name() == QuantMethod::Uniform.name())
}

fn trajectory_bound_holds(res: &GridResult, v: &mut Vec<String>) {
    for c in &res.cells {
        if c.solver != Solver::Euler {
            continue;
        }
        if !c.traj_dev.is_finite() || !c.traj_bound.is_finite() {
            continue; // exploded field: the bound holds vacuously
        }
        if c.traj_dev > c.traj_bound * THEOREM_SLACK + 1e-6 {
            v.push(format!(
                "traj-bound: {} deviation {} above measured-constant bound {}",
                c.key(),
                c.traj_dev,
                c.traj_bound
            ));
        }
    }
}

fn engine_equivalence(res: &GridResult, v: &mut Vec<String>) {
    for c in &res.cells {
        if !c.engine_dev.is_finite() {
            v.push(format!("engine: {} non-finite deviation", c.key()));
            continue;
        }
        if c.solver != Solver::Dopri5 && c.engine_dev > ENGINE_DEV_MAX {
            v.push(format!(
                "engine: {} primary-vs-check deviation {} exceeds {}",
                c.key(),
                c.engine_dev,
                ENGINE_DEV_MAX
            ));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Dataset;
    use crate::sweep::{GridResult, GridSpec};

    fn cell(bits: u8, solver: Solver, method: QuantMethod) -> CellResult {
        CellResult {
            dataset: Dataset::SynthMnist,
            method,
            bits,
            solver,
            ssim: 1.0 - f64::from(8 - bits.min(8)) * 0.01,
            psnr: 40.0,
            fid: 0.1,
            cov_covered: 1.0,
            cov_entropy: 1.0,
            latent_var_mean: 1.0,
            latent_var_std: 0.1,
            latent_mean_abs: 0.01,
            latent_max_abs: 3.0,
            baseline_var_std: 0.1,
            w2_sq: f64::from(8 - bits.min(8)) * 1e-3 + 1e-6,
            sup_err: 1e-3,
            w2_uniform_bound: 1.0,
            sup_uniform_bound: 1.0,
            compression: 8.0,
            traj_dev: 0.1,
            dv_max: 0.5,
            l_hat: 1.0,
            traj_bound: 1.0,
            eps_paper: 2.0,
            engine_dev: 1e-5,
            gen_seconds: 0.01,
            evals: 8,
            per_step_us: 10.0,
            per_eval_us: 5.0,
        }
    }

    fn tiny_spec() -> GridSpec {
        GridSpec {
            datasets: vec![Dataset::SynthMnist],
            methods: vec![QuantMethod::Uniform],
            bits: vec![2, 8],
            solvers: vec![Solver::Euler],
            ..GridSpec::smoke()
        }
    }

    fn tiny_result() -> GridResult {
        GridResult {
            spec: tiny_spec(),
            datasets: vec![],
            cells: vec![
                cell(2, Solver::Euler, QuantMethod::Uniform),
                cell(8, Solver::Euler, QuantMethod::Uniform),
            ],
        }
    }

    #[test]
    fn conformant_result_passes() {
        let res = tiny_result();
        let v = check(&res);
        assert!(v.is_empty(), "unexpected violations: {v:?}");
    }

    #[test]
    fn missing_cell_is_reported() {
        let mut res = tiny_result();
        res.cells.pop();
        let v = check(&res);
        assert!(v.iter().any(|s| s.contains("missing cell")), "{v:?}");
    }

    #[test]
    fn non_monotone_w2_is_reported() {
        let mut res = tiny_result();
        res.cells[1].w2_sq = res.cells[0].w2_sq * 10.0;
        let v = check(&res);
        assert!(v.iter().any(|s| s.starts_with("monotone:")), "{v:?}");
    }

    #[test]
    fn uniform_bound_violation_is_reported() {
        let mut res = tiny_result();
        res.cells[0].w2_sq = res.cells[0].w2_uniform_bound * 2.0;
        // keep monotonicity intact: the wider cell stays below
        let v = check(&res);
        assert!(v.iter().any(|s| s.starts_with("uniform-bound:")), "{v:?}");
    }

    #[test]
    fn trajectory_bound_violation_is_reported_only_for_finite_euler() {
        let mut res = tiny_result();
        res.cells[0].traj_dev = 10.0; // bound is 1.0
        let v = check(&res);
        assert!(v.iter().any(|s| s.starts_with("traj-bound:")), "{v:?}");
        // non-finite constants hold vacuously
        res.cells[0].traj_bound = f64::INFINITY;
        res.cells[0].traj_dev = f64::INFINITY;
        let v = check(&res);
        assert!(!v.iter().any(|s| s.starts_with("traj-bound:")), "{v:?}");
    }

    #[test]
    fn engine_deviation_violation_is_reported_for_fixed_step_only() {
        let mut res = tiny_result();
        res.cells[0].engine_dev = 0.5;
        let v = check(&res);
        assert!(v.iter().any(|s| s.starts_with("engine:")), "{v:?}");
        res.cells[0].solver = Solver::Dopri5;
        // now the grid is incomplete (euler b2 missing) but the engine
        // family must no longer fire for the adaptive solver
        let v = check(&res);
        assert!(!v.iter().any(|s| s.contains("deviation 0.5")), "{v:?}");
    }

    #[test]
    fn ot_low_bit_regression_is_reported() {
        let mut res = tiny_result();
        res.spec.methods = vec![QuantMethod::Ot, QuantMethod::Uniform];
        res.cells = vec![
            cell(2, Solver::Euler, QuantMethod::Ot),
            cell(8, Solver::Euler, QuantMethod::Ot),
            cell(2, Solver::Euler, QuantMethod::Uniform),
            cell(8, Solver::Euler, QuantMethod::Uniform),
        ];
        res.cells[0].w2_sq = res.cells[2].w2_sq * 3.0; // OT worse than uniform
        let v = check(&res);
        assert!(v.iter().any(|s| s.starts_with("ot-low-bit:")), "{v:?}");
        // monotonicity for OT is now also broken by construction; only
        // assert the family we targeted fired.
    }
}
