//! The sweep runner: per-dataset context, the per-cell hot loop, and the
//! measured-constant trajectory-bound pass.

use std::time::Instant;

use anyhow::Result;

use crate::coordinator::experiment::pseudo_trained_theta;
use crate::data::{synth, Dataset};
use crate::engine::{build_quantized, CpuRefEngine, Engine};
use crate::flow::ode::{Solver, StepGrid};
use crate::flow::sampler::{to_latent, to_pixel, Direction, EngineStep};
use crate::metrics::coverage::{coverage, Templates};
use crate::metrics::features::FeatureNet;
use crate::metrics::fid::fid_images;
use crate::metrics::latent::latent_stats;
use crate::metrics::psnr::batch_psnr;
use crate::metrics::ssim::batch_ssim;
use crate::model::params::ParamStore;
use crate::model::spec::ModelSpec;
use crate::quant::uniform::{delta_u, symmetric_range};
use crate::quant::{quantize_model, QuantMethod};
use crate::theory::bounds::trajectory_bound;
use crate::theory::lipschitz::{estimate_l_x, VelocityOracle};
use crate::util::rng::Pcg64;

use super::{CellResult, DatasetSummary, GridResult, GridSpec};

/// The sweep's per-cell sample generation hot loop: run every chunk of a
/// flat `[n, D]` batch through [`EngineStep::run_solver`], mapping the
/// end states through the direction's clamp into `out`. The chunk buffer
/// and the output are caller-owned and reused across cells, so the
/// steady-state loop performs zero heap allocations — enrolled in the
/// `[no_alloc]` lint roots (`lint.toml`), with the known-bad fixture
/// `xtask/tests/fixtures/bad_no_alloc_sweep_cell.rs` proving an
/// allocating variant is caught. Returns the total velocity evaluations.
pub fn run_cell_samples(
    be: &mut EngineStep<'_>,
    x0: &[f32],
    batch: usize,
    steps: usize,
    solver: Solver,
    dir: Direction,
    xbuf: &mut Vec<f32>,
    out: &mut Vec<f32>,
) -> Result<usize> {
    let d = be.engine().spec().d;
    let (t0, t1) = match dir {
        Direction::Forward => (0.0, 1.0),
        Direction::Reverse => (1.0, 0.0),
    };
    let clamp: fn(f32) -> f32 = match dir {
        Direction::Forward => to_pixel,
        Direction::Reverse => to_latent,
    };
    out.clear();
    let mut evals = 0usize;
    for chunk in x0.chunks(batch.max(1) * d) {
        xbuf.clear();
        xbuf.extend_from_slice(chunk);
        let y = be.run_solver(std::mem::take(xbuf), t0, t1, steps, solver)?;
        evals += be.last_evals();
        for &v in &y {
            out.push(clamp(v));
        }
        *xbuf = y;
    }
    Ok(evals)
}

/// Fp32-field velocity oracle for the paper-form Lipschitz probes.
struct CpuOracle<'a> {
    spec: &'a ModelSpec,
    theta: &'a ParamStore,
}

impl VelocityOracle for CpuOracle<'_> {
    fn velocity(&mut self, x: &[f32], t: f32) -> Vec<f32> {
        crate::flow::cpu_ref::velocity(self.spec, self.theta, x, &[t])
    }
    fn dim(&self) -> usize {
        self.spec.d
    }
}

/// Closed-form weight-space bound for the *uniform* quantizer
/// (Definition 2: per-weight error ≤ Δ_U per layer, pinned by
/// `quant/uniform.rs`'s forall test). Returns the size-weighted mean of
/// the per-layer Δ_U² (dominates `w2_sq`) and the max per-layer Δ_U
/// (dominates `sup`).
fn uniform_w2_bound(spec: &ModelSpec, theta: &ParamStore, bits: u8) -> (f64, f64) {
    let mut acc = 0.0f64;
    let mut sup = 0.0f64;
    let mut total = 0usize;
    for l in spec.weight_layers() {
        let w = theta.layer(spec, &l.name);
        let du = delta_u(symmetric_range(w) as f64, bits);
        acc += du * du * l.size() as f64;
        if du > sup {
            sup = du;
        }
        total += l.size();
    }
    (acc / total.max(1) as f64, sup)
}

/// The measured-constant discrete-Grönwall pass (euler discretization):
/// advance the quantized and reference trajectories side by side,
/// recording the largest per-sample velocity gap `dv_max` at the
/// quantized trajectory's visited states and the largest directional
/// Lipschitz quotient `l_hat` of the reference field between the two
/// trajectories. [`trajectory_bound`]`(l_hat, 1, dv_max)` then dominates
/// the measured endpoint deviation by construction (exact arithmetic) —
/// the sweep's per-cell theory conformance check. Non-finite states
/// (exploded low-bit models) poison the constants to +∞, which the
/// conformance layer treats as "bound holds vacuously".
struct GronwallCell {
    traj_dev: f64,
    dv_max: f64,
    l_hat: f64,
    bound: f64,
}

fn gronwall_euler(
    spec: &ModelSpec,
    theta: &ParamStore,
    qeng: &dyn Engine,
    x0: &[f32],
    steps: usize,
) -> Result<GronwallCell> {
    let d = spec.d.max(1);
    let m = x0.len() / d;
    let mut xq = x0.to_vec();
    let mut yr = x0.to_vec();
    let mut dv_max = 0.0f64;
    let mut l_hat = 0.0f64;
    let mut finite = true;
    let grid = StepGrid::new(0.0, 1.0, steps);
    let dt = grid.dt();
    let l2 = |a: &[f32], b: &[f32]| -> f64 {
        let mut acc = 0.0f64;
        for (&p, &q) in a.iter().zip(b.iter()) {
            let diff = f64::from(p) - f64::from(q);
            acc += diff * diff;
        }
        acc.sqrt()
    };
    for t in grid {
        let tb = vec![t; m];
        let vq = qeng.velocity(&xq, &tb)?;
        let vf_xq = crate::flow::cpu_ref::velocity(spec, theta, &xq, &tb);
        let vf_yr = crate::flow::cpu_ref::velocity(spec, theta, &yr, &tb);
        for (((vq_s, vfx_s), vfy_s), (xq_s, yr_s)) in vq
            .chunks_exact(d)
            .zip(vf_xq.chunks_exact(d))
            .zip(vf_yr.chunks_exact(d))
            .zip(xq.chunks_exact(d).zip(yr.chunks_exact(d)))
        {
            let gap = l2(vq_s, vfx_s);
            let num = l2(vfx_s, vfy_s);
            let den = l2(xq_s, yr_s);
            if !gap.is_finite() || !num.is_finite() {
                finite = false;
            }
            if gap > dv_max {
                dv_max = gap;
            }
            // max(1e-9) is identity under the den > 1e-9 gate; it only
            // keeps the (discarded) ratio finite below it
            let ratio = num / den.max(1e-9);
            if den > 1e-9 && ratio > l_hat {
                l_hat = ratio;
            }
        }
        for i in 0..xq.len() {
            xq[i] += dt * vq[i];
            yr[i] += dt * vf_yr[i];
        }
    }
    let mut traj_dev = 0.0f64;
    for (xq_s, yr_s) in xq.chunks_exact(d).zip(yr.chunks_exact(d)) {
        let dev = l2(xq_s, yr_s);
        if dev > traj_dev || !dev.is_finite() {
            traj_dev = dev;
        }
    }
    if !finite {
        dv_max = f64::INFINITY;
    }
    let bound = trajectory_bound(l_hat, 1.0, dv_max);
    Ok(GronwallCell {
        traj_dev,
        dv_max,
        l_hat,
        bound,
    })
}

/// Per-dataset context shared by every cell of one ladder rung.
struct DsCtx {
    theta: ParamStore,
    /// Shared start noise, flat [n, d].
    x0: Vec<f32>,
    /// Subset of `x0` the Grönwall pass integrates ([m, d], m ≤ 4).
    gron_x0: Vec<f32>,
    /// Real images for the latent round-trip, flat [n, d].
    real: Vec<f32>,
    templates: Templates,
    l_x_hat: f64,
    /// Per-solver fp32 references (parallel to `spec.solvers`).
    refs: Vec<SolverRef>,
}

struct SolverRef {
    imgs: Vec<f32>,
    baseline_var_std: f64,
}

impl DsCtx {
    fn build(spec: &GridSpec, mspec: &ModelSpec, ds: Dataset) -> Result<DsCtx> {
        let d = mspec.d;
        let rank = ds.ladder_rank() as u64;
        let theta = pseudo_trained_theta(mspec, ds);
        let mut noise_rng = Pcg64::seed(spec.seed ^ 0x5EED ^ (rank + 1).wrapping_mul(0xD1CE));
        let x0: Vec<f32> = (0..spec.n * d).map(|_| noise_rng.normal_f32(0.0, 1.0)).collect();
        let gron_x0 = x0[..x0.len().min(4 * d)].to_vec();
        let real = synth::eval_batch(ds, spec.seed ^ 0x1A7E, spec.n);
        let mut tmpl_rng = Pcg64::seed(spec.seed ^ 0xC0F ^ (rank + 1).wrapping_mul(0xFACE));
        let templates = Templates::build(ds, &mut tmpl_rng, spec.coverage_samples, spec.coverage_iters);
        let mut lip_rng = Pcg64::seed(spec.seed ^ 0x11B ^ rank);
        let mut oracle = CpuOracle { spec: mspec, theta: &theta };
        let l_x_hat = estimate_l_x(&mut oracle, &mut lip_rng, spec.lipschitz_probes, 1e-3);
        // fp32 references per solver, through the same engine adapter and
        // hot loop every quantized cell uses
        let feng = CpuRefEngine::fp32(mspec, &theta);
        let mut be = EngineStep::new(&feng);
        let mut xbuf = Vec::with_capacity(spec.batch * d);
        let mut refs = Vec::with_capacity(spec.solvers.len());
        for &solver in &spec.solvers {
            let mut imgs = Vec::with_capacity(spec.n * d);
            run_cell_samples(
                &mut be,
                &x0,
                spec.batch,
                spec.steps,
                solver,
                Direction::Forward,
                &mut xbuf,
                &mut imgs,
            )?;
            let mut lats = Vec::with_capacity(spec.n * d);
            run_cell_samples(
                &mut be,
                &real,
                spec.batch,
                spec.steps,
                solver,
                Direction::Reverse,
                &mut xbuf,
                &mut lats,
            )?;
            let baseline_var_std = latent_stats(&lats, d).var_std;
            refs.push(SolverRef {
                imgs,
                baseline_var_std,
            });
        }
        Ok(DsCtx {
            theta,
            x0,
            gron_x0,
            real,
            templates,
            l_x_hat,
            refs,
        })
    }
}

/// Run the whole configured grid. Deterministic for a given spec.
pub fn run_grid(spec: &GridSpec) -> Result<GridResult> {
    let mspec = ModelSpec::default_spec();
    let net = FeatureNet::standard(mspec.d);
    let mut datasets = Vec::with_capacity(spec.datasets.len());
    let mut cells = Vec::with_capacity(spec.cells());
    for &ds in &spec.datasets {
        let ctx = DsCtx::build(spec, &mspec, ds)?;
        datasets.push(DatasetSummary {
            dataset: ds,
            l_x_hat: ctx.l_x_hat,
        });
        for &method in &spec.methods {
            for &bits in &spec.bits {
                let qm = quantize_model(&mspec, &ctx.theta, method, bits);
                let qerr = qm.w2_error(&ctx.theta);
                let (w2_uniform_bound, sup_uniform_bound) =
                    uniform_w2_bound(&mspec, &ctx.theta, bits);
                let compression = qm.compression_ratio();
                let qeng = build_quantized(spec.engine, &qm)?;
                let ceng = build_quantized(spec.check_engine, &qm)?;
                let gron =
                    gronwall_euler(&mspec, &ctx.theta, qeng.as_ref(), &ctx.gron_x0, spec.steps)?;
                let eps_paper = trajectory_bound(ctx.l_x_hat, 1.0, gron.dv_max);
                for (si, &solver) in spec.solvers.iter().enumerate() {
                    let mut cell = run_cell(
                        spec,
                        &ctx,
                        &net,
                        mspec.d,
                        qeng.as_ref(),
                        ceng.as_ref(),
                        solver,
                        si,
                    )?;
                    cell.dataset = ds;
                    cell.method = method;
                    cell.bits = bits;
                    cell.w2_sq = qerr.w2_sq;
                    cell.sup_err = qerr.sup;
                    cell.w2_uniform_bound = w2_uniform_bound;
                    cell.sup_uniform_bound = sup_uniform_bound;
                    cell.compression = compression;
                    cell.traj_dev = gron.traj_dev;
                    cell.dv_max = gron.dv_max;
                    cell.l_hat = gron.l_hat;
                    cell.traj_bound = gron.bound;
                    cell.eps_paper = eps_paper;
                    cells.push(cell);
                }
            }
        }
    }
    Ok(GridResult {
        spec: spec.clone(),
        datasets,
        cells,
    })
}

/// One (engine, solver) cell: timed generation, latent round-trip,
/// fidelity metrics against the solver's fp32 reference, and the
/// primary-vs-check engine deviation. Quantizer-level fields are filled
/// in by the caller (shared across the solver axis).
#[allow(clippy::too_many_arguments)]
fn run_cell(
    spec: &GridSpec,
    ctx: &DsCtx,
    net: &FeatureNet,
    d: usize,
    qeng: &dyn Engine,
    ceng: &dyn Engine,
    solver: Solver,
    si: usize,
) -> Result<CellResult> {
    let mut be = EngineStep::new(qeng);
    let mut xbuf = Vec::with_capacity(spec.batch * d);
    let mut imgs = Vec::with_capacity(spec.n * d);
    let start = Instant::now();
    let evals = run_cell_samples(
        &mut be,
        &ctx.x0,
        spec.batch,
        spec.steps,
        solver,
        Direction::Forward,
        &mut xbuf,
        &mut imgs,
    )?;
    let gen_seconds = start.elapsed().as_secs_f64();
    let mut lats = Vec::with_capacity(spec.n * d);
    run_cell_samples(
        &mut be,
        &ctx.real,
        spec.batch,
        spec.steps,
        solver,
        Direction::Reverse,
        &mut xbuf,
        &mut lats,
    )?;
    let mut cbe = EngineStep::new(ceng);
    let mut cimgs = Vec::with_capacity(spec.n * d);
    run_cell_samples(
        &mut cbe,
        &ctx.x0,
        spec.batch,
        spec.steps,
        solver,
        Direction::Forward,
        &mut xbuf,
        &mut cimgs,
    )?;
    let mut engine_dev = 0.0f64;
    for (&a, &b) in imgs.iter().zip(cimgs.iter()) {
        let diff = (f64::from(a) - f64::from(b)).abs();
        if diff > engine_dev {
            engine_dev = diff;
        }
    }
    let sref = ctx
        .refs
        .get(si)
        .ok_or_else(|| anyhow::anyhow!("missing solver reference {si}"))?;
    let cov = coverage(&ctx.templates, &imgs);
    let lstats = latent_stats(&lats, d);
    let chunks = spec.n.div_ceil(spec.batch.max(1)).max(1);
    let per_step_us = gen_seconds * 1e6 / (spec.steps.max(1) * chunks) as f64;
    let per_eval_us = gen_seconds * 1e6 / evals.max(1) as f64;
    Ok(CellResult {
        dataset: Dataset::SynthMnist, // caller overwrites the axes
        method: QuantMethod::Ot,
        bits: 0,
        solver,
        ssim: batch_ssim(&sref.imgs, &imgs, d),
        psnr: batch_psnr(&sref.imgs, &imgs, d),
        fid: fid_images(net, &imgs, &sref.imgs),
        cov_covered: cov.covered,
        cov_entropy: cov.entropy,
        latent_var_mean: lstats.var_mean,
        latent_var_std: lstats.var_std,
        latent_mean_abs: lstats.mean_abs,
        latent_max_abs: lstats.max_abs,
        baseline_var_std: sref.baseline_var_std,
        w2_sq: 0.0,
        sup_err: 0.0,
        w2_uniform_bound: 0.0,
        sup_uniform_bound: 0.0,
        compression: 0.0,
        traj_dev: 0.0,
        dv_max: 0.0,
        l_hat: 0.0,
        traj_bound: 0.0,
        eps_paper: 0.0,
        engine_dev,
        gen_seconds,
        evals,
        per_step_us,
        per_eval_us,
    })
}
