//! Cross-thread sharing of the PJRT artifact set.
//!
//! The `xla` crate's client/executable handles hold `Rc`s and raw C
//! pointers, so they are not `Send`/`Sync`. The PJRT C API itself is
//! thread-compatible for serialized use, and the `Rc` refcounts are only
//! touched through methods we call — so guarding ALL access behind one
//! `Mutex` makes cross-thread use sound: every call that could touch the
//! refcount or the C handles happens under the lock.
//!
//! This mirrors what a production serving stack does with a per-device
//! executor thread; a `Mutex` keeps the code obvious. Serving workers take
//! the lock only for the duration of one `execute` dispatch.

use std::sync::Mutex;

use crate::runtime::ArtifactSet;

/// A serialized-access, thread-shareable artifact set.
pub struct SharedArtifacts {
    inner: Mutex<ArtifactSet>,
}

// SAFETY: all access to the non-Send internals goes through `with`, which
// holds the Mutex; the wrapped value never escapes the closure, so no two
// threads can touch the Rc refcounts or PJRT handles concurrently.
// fmq-analyze: safety -- `with` serializes every touch behind the Mutex and the value never escapes the closure, so Rc refcounts / PJRT handles are never reached from two threads
unsafe impl Send for SharedArtifacts {}
// fmq-analyze: safety -- same proof as Send: Mutex-serialized access only
unsafe impl Sync for SharedArtifacts {}

impl SharedArtifacts {
    pub fn new(art: ArtifactSet) -> Self {
        Self {
            inner: Mutex::new(art),
        }
    }

    /// Run `f` with exclusive access to the artifact set.
    pub fn with<T>(&self, f: impl FnOnce(&ArtifactSet) -> T) -> T {
        // a poisoned lock means another worker panicked mid-`with`; the
        // closure only ever gets `&ArtifactSet` (no partial mutation to
        // observe), so serving continues instead of cascading the panic
        let guard = self
            .inner
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        f(&guard)
    }
}
