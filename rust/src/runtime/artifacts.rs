//! Artifact set: manifest-driven loading of every AOT-compiled entry point,
//! with the layer-table cross-check against the rust `ModelSpec`.
//!
//! A manifest that fails to read, parse, or carry its declared shape
//! fields is reported as the typed
//! [`crate::model::checkpoint::CorruptCheckpoint`] error (same taxonomy
//! as torn checkpoint files), so the serving layer can map artifact
//! damage onto the `corrupt_artifact` wire class instead of a generic
//! internal error.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::model::checkpoint::CorruptCheckpoint;
use crate::model::params::ParamStore;
use crate::model::quantized::QuantizedModel;
use crate::model::spec::ModelSpec;
use crate::runtime::exec::{self, cpu_client, Arg, Executable};
use crate::util::json::{parse, Json};

/// All compiled entry points + shape info from the manifest.
pub struct ArtifactSet {
    pub spec: ModelSpec,
    pub manifest: Json,
    pub b_train: usize,
    pub b_sample: usize,
    pub assign_chunk: usize,
    client: xla::PjRtClient,
    velocity_fwd: Executable,
    sample_step: Executable,
    qsample_step: Executable,
    train_step: Executable,
    assign: Executable,
    dequant_theta: Executable,
}

/// Default artifact directory (relative to the repo root / CWD).
pub fn default_dir() -> PathBuf {
    std::env::var("FMQ_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// True if a complete artifact set exists at `dir` (tests gate on this).
pub fn available(dir: &Path) -> bool {
    [
        "manifest.json",
        "velocity_fwd.hlo.txt",
        "sample_step.hlo.txt",
        "qsample_step.hlo.txt",
        "train_step.hlo.txt",
        "assign.hlo.txt",
        "dequant_theta.hlo.txt",
    ]
    .iter()
    .all(|f| dir.join(f).exists())
}

impl ArtifactSet {
    /// Load + compile everything. One-time cost; executables are reused
    /// across the whole run.
    pub fn load(dir: &Path) -> Result<Self> {
        if !available(dir) {
            bail!(
                "artifact set incomplete at {dir:?} — run `make artifacts` first"
            );
        }
        let corrupt = |msg: String| anyhow::Error::new(CorruptCheckpoint(msg));
        let mpath = dir.join("manifest.json");
        let manifest_text = std::fs::read_to_string(&mpath)
            .map_err(|e| corrupt(format!("{mpath:?}: unreadable: {e}")))?;
        let manifest = parse(&manifest_text)
            .map_err(|e| corrupt(format!("{mpath:?}: does not parse: {e}")))?;
        let spec = ModelSpec::default_spec();
        spec.matches_manifest(&manifest)
            .context("manifest/spec layer-table mismatch — rebuild artifacts")?;
        let b_train = manifest
            .req_usize("b_train")
            .map_err(|e| corrupt(format!("{mpath:?}: {e}")))?;
        let b_sample = manifest
            .req_usize("b_sample")
            .map_err(|e| corrupt(format!("{mpath:?}: {e}")))?;
        let assign_chunk = manifest
            .req_usize("assign_chunk")
            .map_err(|e| corrupt(format!("{mpath:?}: {e}")))?;
        let client = cpu_client()?;
        let load = |name: &str| Executable::load(&client, name, &dir.join(format!("{name}.hlo.txt")));
        Ok(Self {
            spec,
            manifest,
            b_train,
            b_sample,
            assign_chunk,
            velocity_fwd: load("velocity_fwd")?,
            sample_step: load("sample_step")?,
            qsample_step: load("qsample_step")?,
            train_step: load("train_step")?,
            assign: load("assign")?,
            dequant_theta: load("dequant_theta")?,
            client,
        })
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// v = f_θ(x, t): x flat [B_SAMPLE, D], t [B_SAMPLE].
    pub fn velocity(&self, theta: &ParamStore, x: &[f32], t: &[f32]) -> Result<Vec<f32>> {
        let d = self.spec.d as i64;
        let b = self.b_sample as i64;
        self.velocity_fwd.run_single_f32(&[
            Arg::F32(theta.as_slice()),
            Arg::F32Shaped(x, &[b, d]),
            Arg::F32(t),
        ])
    }

    /// One fp32 Euler step (signed dt). One-shot path: uploads theta each
    /// call — use [`ArtifactSet::sample_session`] for multi-step sampling.
    pub fn sample_step(&self, theta: &ParamStore, x: &[f32], t: f32, dt: f32) -> Result<Vec<f32>> {
        let d = self.spec.d as i64;
        let b = self.b_sample as i64;
        self.sample_step.run_single_f32(&[
            Arg::F32(theta.as_slice()),
            Arg::F32Shaped(x, &[b, d]),
            Arg::ScalarF32(t),
            Arg::ScalarF32(dt),
        ])
    }

    /// One quantized Euler step (codes + padded codebooks + biases) — the
    /// serving hot path; dequantization happens inside the Pallas qmm tile.
    /// One-shot path: uploads codes each call — use
    /// [`ArtifactSet::qsample_session`] for multi-step sampling.
    pub fn qsample_step(
        &self,
        codes: &[i32],
        biases: &[f32],
        codebooks_padded: &[f32],
        x: &[f32],
        t: f32,
        dt: f32,
    ) -> Result<Vec<f32>> {
        let d = self.spec.d as i64;
        let b = self.b_sample as i64;
        let nw = self.spec.weight_layers().len() as i64;
        let k = self.spec.k_max as i64;
        self.qsample_step.run_single_f32(&[
            Arg::I32(codes),
            Arg::F32(biases),
            Arg::F32Shaped(codebooks_padded, &[nw, k]),
            Arg::F32Shaped(x, &[b, d]),
            Arg::ScalarF32(t),
            Arg::ScalarF32(dt),
        ])
    }

    /// Device-resident fp32 sampling session: theta staged once; per step
    /// only the two scalars move host->device and the state chains on
    /// device (§Perf optimization 1 in EXPERIMENTS.md).
    pub fn sample_session(&self, theta: &ParamStore) -> Result<SampleSession<'_>> {
        let theta_buf = exec::stage_f32(&self.client, theta.as_slice(), &[theta.len()])?;
        Ok(SampleSession {
            art: self,
            theta: theta_buf,
        })
    }

    /// Device-resident quantized sampling session: codes (9.1 MB at i32),
    /// biases and codebooks staged once; each step dequantizes on the fly
    /// through the Pallas qmm gather (the paper-faithful TPU mode).
    pub fn qsample_session(&self, qm: &QuantizedModel) -> Result<QSampleSession<'_>> {
        let nw = self.spec.weight_layers().len();
        let k = self.spec.k_max;
        Ok(QSampleSession {
            art: self,
            codes: exec::stage_i32(&self.client, &qm.codes_i32(), &[self.spec.pw()])?,
            biases: exec::stage_f32(&self.client, &qm.biases, &[self.spec.pb()])?,
            cbs: exec::stage_f32(&self.client, &qm.codebooks_padded(), &[nw, k])?,
        })
    }

    /// Dequantize-on-load session: run the `dequant_theta` artifact once on
    /// device, keep the reconstructed fp32 theta buffer resident, and
    /// sample with the fp32 step. Numerically identical to the on-the-fly
    /// mode (same codebook lookups) but pays the gather once per deployment
    /// instead of once per step — §Perf optimization 2.
    pub fn qsample_session_dequant(&self, qm: &QuantizedModel) -> Result<SampleSession<'_>> {
        let nw = self.spec.weight_layers().len();
        let k = self.spec.k_max;
        let codes = exec::stage_i32(&self.client, &qm.codes_i32(), &[self.spec.pw()])?;
        let biases = exec::stage_f32(&self.client, &qm.biases, &[self.spec.pb()])?;
        let cbs = exec::stage_f32(&self.client, &qm.codebooks_padded(), &[nw, k])?;
        let theta = self
            .dequant_theta
            .execute_buffers(&[&codes, &biases, &cbs])?;
        Ok(SampleSession { art: self, theta })
    }

    /// Host-side dequantization through the artifact (used by tests to pin
    /// the on-device reconstruction against `QuantizedModel::dequantize`).
    pub fn dequantize(&self, qm: &QuantizedModel) -> Result<Vec<f32>> {
        let nw = self.spec.weight_layers().len() as i64;
        let k = self.spec.k_max as i64;
        self.dequant_theta.run_single_f32(&[
            Arg::I32(&qm.codes_i32()),
            Arg::F32(&qm.biases),
            Arg::F32Shaped(&qm.codebooks_padded(), &[nw, k]),
        ])
    }

    /// Convenience wrapper taking a QuantizedModel.
    pub fn qsample_step_model(
        &self,
        qm: &QuantizedModel,
        x: &[f32],
        t: f32,
        dt: f32,
    ) -> Result<Vec<f32>> {
        self.qsample_step(
            &qm.codes_i32(),
            &qm.biases,
            &qm.codebooks_padded(),
            x,
            t,
            dt,
        )
    }

    /// One Adam training step; returns (theta', m', v', loss).
    #[allow(clippy::too_many_arguments)]
    pub fn train_step(
        &self,
        theta: &ParamStore,
        m: &[f32],
        v: &[f32],
        step: f32,
        x1: &[f32],
        x0: &[f32],
        t: &[f32],
        lr: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, f32)> {
        let d = self.spec.d as i64;
        let b = self.b_train as i64;
        let mut out = self.train_step.run_f32(&[
            Arg::F32(theta.as_slice()),
            Arg::F32(m),
            Arg::F32(v),
            Arg::ScalarF32(step),
            Arg::F32Shaped(x1, &[b, d]),
            Arg::F32Shaped(x0, &[b, d]),
            Arg::F32(t),
            Arg::ScalarF32(lr),
        ])?;
        if out.len() != 4 {
            bail!("train_step returned {} outputs, expected 4", out.len());
        }
        let loss_vec = out.pop().unwrap();
        let v2 = out.pop().unwrap();
        let m2 = out.pop().unwrap();
        let th2 = out.pop().unwrap();
        Ok((th2, m2, v2, loss_vec[0]))
    }

    /// On-device nearest-centroid assignment over one chunk.
    pub fn assign_chunk_exec(&self, vals: &[f32], centroids_padded: &[f32]) -> Result<Vec<i32>> {
        if vals.len() != self.assign_chunk {
            bail!(
                "assign expects exactly {} values, got {}",
                self.assign_chunk,
                vals.len()
            );
        }
        self.assign
            .run_single_i32(&[Arg::F32(vals), Arg::F32(centroids_padded)])
    }
}

/// Multi-step fp32 sampler with device-resident theta.
pub struct SampleSession<'a> {
    art: &'a ArtifactSet,
    theta: xla::PjRtBuffer,
}

impl SampleSession<'_> {
    /// Integrate x from t0 to t1 in `steps` Euler steps; the state stays on
    /// device between steps.
    pub fn integrate(&self, x: &[f32], t0: f32, t1: f32, steps: usize) -> Result<Vec<f32>> {
        let art = self.art;
        let b = self.art.b_sample;
        let d = art.spec.d;
        let dt = (t1 - t0) / steps as f32;
        let mut xbuf = exec::stage_f32(&art.client, x, &[b, d])?;
        let dt_buf = exec::stage_f32(&art.client, &[dt], &[])?;
        for s in 0..steps {
            let t = t0 + s as f32 * dt;
            let t_buf = exec::stage_f32(&art.client, &[t], &[])?;
            xbuf = art
                .sample_step
                .execute_buffers(&[&self.theta, &xbuf, &t_buf, &dt_buf])?;
        }
        exec::fetch_f32(&xbuf)
    }
}

/// Multi-step quantized sampler with device-resident codes/codebooks.
pub struct QSampleSession<'a> {
    art: &'a ArtifactSet,
    codes: xla::PjRtBuffer,
    biases: xla::PjRtBuffer,
    cbs: xla::PjRtBuffer,
}

impl QSampleSession<'_> {
    pub fn integrate(&self, x: &[f32], t0: f32, t1: f32, steps: usize) -> Result<Vec<f32>> {
        let art = self.art;
        let b = art.b_sample;
        let d = art.spec.d;
        let dt = (t1 - t0) / steps as f32;
        let mut xbuf = exec::stage_f32(&art.client, x, &[b, d])?;
        let dt_buf = exec::stage_f32(&art.client, &[dt], &[])?;
        for s in 0..steps {
            let t = t0 + s as f32 * dt;
            let t_buf = exec::stage_f32(&art.client, &[t], &[])?;
            xbuf = art.qsample_step.execute_buffers(&[
                &self.codes,
                &self.biases,
                &self.cbs,
                &xbuf,
                &t_buf,
                &dt_buf,
            ])?;
        }
        exec::fetch_f32(&xbuf)
    }
}
