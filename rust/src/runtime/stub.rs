//! API-compatible stand-in for `runtime::artifacts` when the `pjrt`
//! feature is off (the default, pure-Rust build).
//!
//! Every signature mirrors the real module so callers compile unchanged;
//! [`available`] always answers `false` and [`ArtifactSet::load`] always
//! errors, so no `ArtifactSet` value can ever exist in a stub build — the
//! method bodies are unreachable by construction and exist only to
//! satisfy the type checker. All sampling/serving paths therefore fall
//! back to the native CPU engines ([`crate::engine`], [`crate::flow`]).

use std::path::{Path, PathBuf};

use anyhow::{bail, Result};

use crate::model::params::ParamStore;
use crate::model::quantized::QuantizedModel;
use crate::model::spec::ModelSpec;
use crate::util::json::Json;

const NO_PJRT: &str =
    "built without the `pjrt` feature — compiled-HLO execution is unavailable \
     (rebuild with `--features pjrt` and the vendored xla bindings)";

/// Shape info the real manifest would carry; never instantiated here.
pub struct ArtifactSet {
    pub spec: ModelSpec,
    pub manifest: Json,
    pub b_train: usize,
    pub b_sample: usize,
    pub assign_chunk: usize,
}

/// Default artifact directory (same env override as the real module, so
/// `fmq info` prints a truthful path either way).
pub fn default_dir() -> PathBuf {
    std::env::var("FMQ_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// Always `false`: without PJRT the artifacts cannot be executed, so they
/// are reported unavailable even if the HLO files exist on disk. Callers
/// gate on this and fall back to the CPU engines.
pub fn available(_dir: &Path) -> bool {
    false
}

impl ArtifactSet {
    pub fn load(_dir: &Path) -> Result<Self> {
        bail!(NO_PJRT)
    }

    pub fn velocity(&self, _theta: &ParamStore, _x: &[f32], _t: &[f32]) -> Result<Vec<f32>> {
        bail!(NO_PJRT)
    }

    pub fn sample_step(
        &self,
        _theta: &ParamStore,
        _x: &[f32],
        _t: f32,
        _dt: f32,
    ) -> Result<Vec<f32>> {
        bail!(NO_PJRT)
    }

    pub fn qsample_step(
        &self,
        _codes: &[i32],
        _biases: &[f32],
        _codebooks_padded: &[f32],
        _x: &[f32],
        _t: f32,
        _dt: f32,
    ) -> Result<Vec<f32>> {
        bail!(NO_PJRT)
    }

    pub fn sample_session(&self, _theta: &ParamStore) -> Result<SampleSession<'_>> {
        bail!(NO_PJRT)
    }

    pub fn qsample_session(&self, _qm: &QuantizedModel) -> Result<QSampleSession<'_>> {
        bail!(NO_PJRT)
    }

    pub fn qsample_session_dequant(&self, _qm: &QuantizedModel) -> Result<SampleSession<'_>> {
        bail!(NO_PJRT)
    }

    pub fn dequantize(&self, _qm: &QuantizedModel) -> Result<Vec<f32>> {
        bail!(NO_PJRT)
    }

    pub fn qsample_step_model(
        &self,
        _qm: &QuantizedModel,
        _x: &[f32],
        _t: f32,
        _dt: f32,
    ) -> Result<Vec<f32>> {
        bail!(NO_PJRT)
    }

    #[allow(clippy::too_many_arguments)]
    pub fn train_step(
        &self,
        _theta: &ParamStore,
        _m: &[f32],
        _v: &[f32],
        _step: f32,
        _x1: &[f32],
        _x0: &[f32],
        _t: &[f32],
        _lr: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, f32)> {
        bail!(NO_PJRT)
    }

    pub fn assign_chunk_exec(&self, _vals: &[f32], _centroids_padded: &[f32]) -> Result<Vec<i32>> {
        bail!(NO_PJRT)
    }
}

/// Mirrors the real device-resident fp32 session; never instantiated.
pub struct SampleSession<'a> {
    _art: &'a ArtifactSet,
}

impl SampleSession<'_> {
    pub fn integrate(&self, _x: &[f32], _t0: f32, _t1: f32, _steps: usize) -> Result<Vec<f32>> {
        bail!(NO_PJRT)
    }
}

/// Mirrors the real device-resident quantized session; never instantiated.
pub struct QSampleSession<'a> {
    _art: &'a ArtifactSet,
}

impl QSampleSession<'_> {
    pub fn integrate(&self, _x: &[f32], _t0: f32, _t1: f32, _steps: usize) -> Result<Vec<f32>> {
        bail!(NO_PJRT)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable_and_refuses_load() {
        assert!(!available(&default_dir()));
        let err = ArtifactSet::load(&default_dir()).unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
    }

    #[test]
    fn default_dir_honors_env_contract() {
        // matches the real module: bare "artifacts" unless FMQ_ARTIFACTS set
        if std::env::var("FMQ_ARTIFACTS").is_err() {
            assert_eq!(default_dir(), PathBuf::from("artifacts"));
        }
    }
}
