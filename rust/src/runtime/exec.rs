//! Typed execution helpers over the `xla` crate: f32/i32 slices in,
//! f32 vectors out, tuple outputs unpacked.

use anyhow::{anyhow, Context, Result};

/// An input argument for an executable.
pub enum Arg<'a> {
    F32(&'a [f32]),
    /// f32 buffer with an explicit shape (row-major).
    F32Shaped(&'a [f32], &'a [i64]),
    I32(&'a [i32]),
    I32Shaped(&'a [i32], &'a [i64]),
    ScalarF32(f32),
}

impl Arg<'_> {
    fn to_literal(&self) -> Result<xla::Literal> {
        Ok(match self {
            Arg::F32(xs) => xla::Literal::vec1(xs),
            Arg::F32Shaped(xs, dims) => xla::Literal::vec1(xs)
                .reshape(dims)
                .map_err(|e| anyhow!("reshape f32 to {dims:?}: {e:?}"))?,
            Arg::I32(xs) => xla::Literal::vec1(xs),
            Arg::I32Shaped(xs, dims) => xla::Literal::vec1(xs)
                .reshape(dims)
                .map_err(|e| anyhow!("reshape i32 to {dims:?}: {e:?}"))?,
            Arg::ScalarF32(v) => xla::Literal::scalar(*v),
        })
    }
}

/// A compiled artifact ready to execute.
pub struct Executable {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Compile HLO text from a file on the given client.
    pub fn load(client: &xla::PjRtClient, name: &str, path: &std::path::Path) -> Result<Self> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow!("parse HLO text {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
        Ok(Self {
            name: name.to_string(),
            exe,
        })
    }

    /// Execute with typed args; returns the single array output as f32
    /// (for artifacts lowered with `return_tuple=False`).
    pub fn run_single_f32(&self, args: &[Arg]) -> Result<Vec<f32>> {
        let lit = self.run_to_literal(args)?;
        lit.to_vec::<f32>()
            .map_err(|e| anyhow!("{}: output not f32: {e:?}", self.name))
    }

    /// Execute; single i32 array output.
    pub fn run_single_i32(&self, args: &[Arg]) -> Result<Vec<i32>> {
        let lit = self.run_to_literal(args)?;
        lit.to_vec::<i32>()
            .map_err(|e| anyhow!("{}: output not i32: {e:?}", self.name))
    }

    fn run_to_literal(&self, args: &[Arg]) -> Result<xla::Literal> {
        let lits: Vec<xla::Literal> = args
            .iter()
            .map(|a| a.to_literal())
            .collect::<Result<_>>()?;
        let out = self
            .exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow!("execute {}: {e:?}", self.name))?;
        out.first()
            .and_then(|d| d.first())
            .ok_or_else(|| anyhow!("{}: no output buffers", self.name))?
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch {}: {e:?}", self.name))
    }

    /// Execute over pre-staged device buffers; returns the single output
    /// buffer WITHOUT copying back to the host. This is the hot path of
    /// the sampling sessions: weights/codes stay device-resident, and each
    /// step's output chains into the next step's input.
    pub fn execute_buffers(&self, args: &[&xla::PjRtBuffer]) -> Result<xla::PjRtBuffer> {
        let out = self
            .exe
            .execute_b(args)
            .map_err(|e| anyhow!("execute_b {}: {e:?}", self.name))?;
        let dev0 = out
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("{}: no output devices", self.name))?;
        dev0.into_iter()
            .next()
            .ok_or_else(|| anyhow!("{}: no output buffer", self.name))
    }

    /// Execute with typed args; returns the tuple elements as f32 vectors.
    /// (For artifacts lowered with `return_tuple=True`, i.e. train_step.)
    pub fn run_f32(&self, args: &[Arg]) -> Result<Vec<Vec<f32>>> {
        let lits: Vec<xla::Literal> = args
            .iter()
            .map(|a| a.to_literal())
            .collect::<Result<_>>()?;
        let out = self
            .exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow!("execute {}: {e:?}", self.name))?;
        let lit = out
            .first()
            .and_then(|d| d.first())
            .ok_or_else(|| anyhow!("{}: no output buffers", self.name))?
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch {}: {e:?}", self.name))?;
        let parts = lit
            .to_tuple()
            .map_err(|e| anyhow!("untuple {}: {e:?}", self.name))?;
        parts
            .iter()
            .map(|p| {
                p.to_vec::<f32>()
                    .map_err(|e| anyhow!("{}: output not f32: {e:?}", self.name))
            })
            .collect()
    }

}

/// Stage an f32 slice as a device buffer.
pub fn stage_f32(
    client: &xla::PjRtClient,
    data: &[f32],
    dims: &[usize],
) -> Result<xla::PjRtBuffer> {
    client
        .buffer_from_host_buffer(data, dims, None)
        .map_err(|e| anyhow!("stage f32 buffer {dims:?}: {e:?}"))
}

/// Stage an i32 slice as a device buffer.
pub fn stage_i32(
    client: &xla::PjRtClient,
    data: &[i32],
    dims: &[usize],
) -> Result<xla::PjRtBuffer> {
    client
        .buffer_from_host_buffer(data, dims, None)
        .map_err(|e| anyhow!("stage i32 buffer {dims:?}: {e:?}"))
}

/// Read an f32 device buffer back to the host.
pub fn fetch_f32(buf: &xla::PjRtBuffer) -> Result<Vec<f32>> {
    buf.to_literal_sync()
        .map_err(|e| anyhow!("fetch buffer: {e:?}"))?
        .to_vec::<f32>()
        .map_err(|e| anyhow!("buffer not f32: {e:?}"))
}

/// Create the shared CPU PJRT client.
pub fn cpu_client() -> Result<xla::PjRtClient> {
    xla::PjRtClient::cpu()
        .map_err(|e| anyhow!("create PJRT CPU client: {e:?}"))
        .context("is libxla_extension.so reachable? (rpath /opt/xla_extension/lib)")
}
