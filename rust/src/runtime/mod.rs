//! PJRT runtime: load the AOT HLO-text artifacts, compile them once on the
//! CPU PJRT client, and execute them from the coordinator's hot path.
//!
//! Interchange is HLO *text* (see `python/compile/aot.py` — jax ≥ 0.5
//! emits 64-bit instruction-id protos that xla_extension 0.5.1 rejects;
//! the text parser reassigns ids). Python never runs here.

pub mod artifacts;
pub mod exec;
pub mod shared;

pub use artifacts::ArtifactSet;
pub use exec::Executable;
pub use shared::SharedArtifacts;
