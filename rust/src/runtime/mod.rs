//! PJRT runtime: load the AOT HLO-text artifacts, compile them once on the
//! CPU PJRT client, and execute them from the coordinator's hot path.
//!
//! Interchange is HLO *text* (see `python/compile/aot.py` — jax ≥ 0.5
//! emits 64-bit instruction-id protos that xla_extension 0.5.1 rejects;
//! the text parser reassigns ids). Python never runs here.
//!
//! The whole PJRT path sits behind the `pjrt` cargo feature: the default
//! build swaps in [`stub`](stub/index.html) (same public surface, always
//! reports artifacts unavailable), so every caller compiles and falls back
//! to the native CPU engines in [`crate::engine`] / [`crate::flow`].

#[cfg(feature = "pjrt")]
pub mod artifacts;
#[cfg(feature = "pjrt")]
pub mod exec;

#[cfg(not(feature = "pjrt"))]
#[path = "stub.rs"]
pub mod artifacts;

pub mod shared;

pub use artifacts::ArtifactSet;
#[cfg(feature = "pjrt")]
pub use exec::Executable;
pub use shared::SharedArtifacts;
