//! Minimal JSON: a value type, a recursive-descent parser, and a writer.
//!
//! Used for the AOT manifest (`artifacts/manifest.json`), experiment result
//! files, and the serving wire protocol. Supports the full JSON grammar
//! except `\u` surrogate pairs outside the BMP (not needed here — all our
//! payloads are ASCII).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value. Objects use `BTreeMap` so serialisation is deterministic.
///
/// Integer literals (no `.`/`e` in the source text) parse as [`Json::Int`]
/// and serialise back as exact decimal integers, so u64-valued metrics
/// (byte gauges, counters) survive the wire without the 2^53 precision
/// cliff of `f64`. Numeric equality is cross-variant: `Int(2) == Num(2.0)`
/// — required because the writer emits integral `Num`s without a decimal
/// point, so they reparse as `Int`.
#[derive(Clone, Debug)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    /// Integer-exact number (wire-exact for the full `u64`/`i64` range).
    Int(i128),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl PartialEq for Json {
    fn eq(&self, other: &Self) -> bool {
        match (self, other) {
            (Json::Null, Json::Null) => true,
            (Json::Bool(a), Json::Bool(b)) => a == b,
            (Json::Num(a), Json::Num(b)) => a == b,
            (Json::Int(a), Json::Int(b)) => a == b,
            // numerically equal only when the f64 represents the integer
            // exactly (both directions checked so 2^53+1 != 2^53.0)
            (Json::Int(i), Json::Num(f)) | (Json::Num(f), Json::Int(i)) => {
                *f == *i as f64 && *i == *f as i128
            }
            (Json::Str(a), Json::Str(b)) => a == b,
            (Json::Arr(a), Json::Arr(b)) => a == b,
            (Json::Obj(a), Json::Obj(b)) => a == b,
            _ => false,
        }
    }
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Numeric value as `f64`; lossy above 2^53 for [`Json::Int`] — use
    /// [`Json::as_u64`]/[`Json::as_i64`] where exactness matters.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            Json::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Int(i) => usize::try_from(*i).ok(),
            Json::Num(n) => Some(*n as usize),
            _ => None,
        }
    }

    /// Integer-exact `u64`: `Int` in range, or an integral `Num` below
    /// 2^53 (the largest range where `f64` is still exact).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Int(i) => u64::try_from(*i).ok(),
            Json::Num(n) if n.fract() == 0.0 && *n >= 0.0 && *n < 9_007_199_254_740_992.0 => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    /// Integer-exact `i64`: `Int` in range, or an integral `Num` with
    /// |n| < 2^53.
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Int(i) => i64::try_from(*i).ok(),
            Json::Num(n) if n.fract() == 0.0 && n.abs() < 9_007_199_254_740_992.0 => {
                Some(*n as i64)
            }
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Required-field accessors with contextual errors.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing field '{key}'"))
    }

    pub fn req_usize(&self, key: &str) -> Result<usize> {
        self.req(key)?
            .as_usize()
            .ok_or_else(|| anyhow!("field '{key}' is not a number"))
    }

    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| anyhow!("field '{key}' is not a string"))
    }

    pub fn from_f32s(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn to_f32s(&self) -> Result<Vec<f32>> {
        self.as_arr()
            .ok_or_else(|| anyhow!("not an array"))?
            .iter()
            .map(|v| {
                v.as_f64()
                    .map(|n| n as f32)
                    .ok_or_else(|| anyhow!("non-numeric array element"))
            })
            .collect()
    }

    // ------------------------------------------------------------ writing

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Int(i) => {
                let _ = write!(out, "{i}");
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---------------------------------------------------------------- parsing

pub fn parse(input: &str) -> Result<Json> {
    let mut p = Parser {
        b: input.as_bytes(),
        i: 0,
    };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        bail!("trailing characters at byte {}", p.i);
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    // named `eat`, not `expect`: the panic-cone pass denies any method
    // call spelled `expect`, and it cannot see that this one returns
    // Result instead of panicking
    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            bail!("expected '{}' at byte {}", c as char, self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => bail!("unexpected character at byte {}", self.i),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(
            self.peek(),
            Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-')
        ) {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        // integer literals stay integer-exact (counters/byte gauges above
        // 2^53 would silently round through f64); overflow past i128 and
        // anything with '.'/'e' takes the float path
        if !s.bytes().any(|c| matches!(c, b'.' | b'e' | b'E')) {
            if let Ok(i) = s.parse::<i128>() {
                return Ok(Json::Int(i));
            }
        }
        Ok(Json::Num(s.parse::<f64>()?))
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(
                                self.b
                                    .get(self.i + 1..self.i + 5)
                                    .ok_or_else(|| anyhow!("bad \\u escape"))?,
                            )?;
                            let code = u32::from_str_radix(hex, 16)?;
                            s.push(
                                char::from_u32(code)
                                    .ok_or_else(|| anyhow!("bad \\u codepoint"))?,
                            );
                            self.i += 4;
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])?;
                    let Some(c) = rest.chars().next() else {
                        bail!("unterminated string");
                    };
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => bail!("expected ',' or ']' at byte {}", self.i),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => bail!("expected ',' or '}}' at byte {}", self.i),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": 1, "b": [true, null, -2.5e3], "c": "hi\nthere"}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.req_usize("a").unwrap(), 1);
        assert_eq!(v.get("b").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.req_str("c").unwrap(), "hi\nthere");
        // serialize and reparse
        let v2 = parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn parses_manifest_style() {
        let src = r#"{"layers": [{"name": "w_in", "shape": [768, 512], "offset": 0, "is_weight": true}], "p": 2396928}"#;
        let v = parse(src).unwrap();
        let layers = v.get("layers").unwrap().as_arr().unwrap();
        assert_eq!(layers[0].req_str("name").unwrap(), "w_in");
        assert!(layers[0].get("is_weight").unwrap().as_bool().unwrap());
        assert_eq!(v.req_usize("p").unwrap(), 2_396_928);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn float_array_roundtrip() {
        let xs = vec![0.5f32, -1.25, 3.0, 1e-7];
        let j = Json::from_f32s(&xs);
        let back = parse(&j.to_string()).unwrap().to_f32s().unwrap();
        for (a, b) in xs.iter().zip(back.iter()) {
            assert!((a - b).abs() < 1e-10);
        }
    }

    #[test]
    fn integers_roundtrip_exactly_above_2_53() {
        // 2^53 + 1 is the first u64 that f64 cannot represent
        for v in [
            9_007_199_254_740_993u64, // 2^53 + 1
            u64::MAX,
            u64::MAX - 1,
            0,
        ] {
            let j = Json::Int(v as i128);
            let text = j.to_string();
            assert_eq!(text, v.to_string(), "writer must be integer-exact");
            let back = parse(&text).unwrap();
            assert_eq!(back.as_u64(), Some(v), "parse must be integer-exact");
        }
        // negative i64 range survives too
        let back = parse(&Json::Int(i64::MIN as i128).to_string()).unwrap();
        assert_eq!(back.as_i64(), Some(i64::MIN));
    }

    #[test]
    fn cross_variant_numeric_equality() {
        assert_eq!(Json::Int(2), Json::Num(2.0));
        assert_eq!(Json::Num(-2500.0), Json::Int(-2500));
        // 2^53+1 rounds to 2^53 in f64 — must NOT compare equal
        assert_ne!(Json::Int(9_007_199_254_740_993), Json::Num(9_007_199_254_740_992.0));
        assert_ne!(Json::Int(2), Json::Num(2.5));
        // integral Num written without a decimal point reparses as Int,
        // and the whole value still compares equal
        let v = Json::obj(vec![("x", Json::Num(3.0))]);
        let v2 = parse(&v.to_string()).unwrap();
        assert!(matches!(v2.get("x"), Some(Json::Int(3))));
        assert_eq!(v, v2);
    }

    #[test]
    fn as_u64_rejects_lossy_floats() {
        assert_eq!(Json::Num(2.5).as_u64(), None);
        assert_eq!(Json::Num(-1.0).as_u64(), None);
        assert_eq!(Json::Num(9_007_199_254_740_992.0).as_u64(), None);
        assert_eq!(Json::Num(4096.0).as_u64(), Some(4096));
        assert_eq!(Json::Int(-1).as_u64(), None);
        assert_eq!(Json::Int(1 << 60).as_u64(), Some(1u64 << 60));
    }

    #[test]
    fn unicode_escapes() {
        let v = parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "Aé");
    }

    #[test]
    fn deterministic_object_order() {
        let a = parse(r#"{"z": 1, "a": 2}"#).unwrap().to_string();
        let b = parse(r#"{"a": 2, "z": 1}"#).unwrap().to_string();
        assert_eq!(a, b);
    }
}
