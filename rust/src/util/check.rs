//! Mini property-testing harness (proptest stand-in).
//!
//! Runs an invariant over many seeded random cases; on failure it reports
//! the failing seed and then *shrinks* the case by retrying the invariant
//! with progressively smaller size hints, reporting the smallest size that
//! still fails. Deterministic: case seeds derive from a fixed run seed so
//! failures reproduce exactly.
//!
//! ```no_run
//! // (no_run: rustdoc binaries miss the xla rpath in this environment)
//! use fmq::util::check::{forall, Gen};
//! forall("sorted after sort", 64, |g| {
//!     let mut xs = g.f32_vec(1..=100, -1e3..=1e3);
//!     xs.sort_by(f32::total_cmp);
//!     xs.windows(2).all(|w| w[0] <= w[1])
//! });
//! ```

use std::ops::RangeInclusive;

use crate::util::rng::Pcg64;

/// Case generator handed to the property closure.
pub struct Gen {
    rng: Pcg64,
    /// Size multiplier in (0, 1]; shrinking lowers it.
    pub size: f64,
}

impl Gen {
    fn new(seed: u64, size: f64) -> Self {
        Self {
            rng: Pcg64::seed(seed),
            size,
        }
    }

    /// Scaled length draw: the effective max shrinks with `size`.
    pub fn len(&mut self, range: RangeInclusive<usize>) -> usize {
        let (lo, hi) = (*range.start(), *range.end());
        let span = ((hi - lo) as f64 * self.size).ceil() as usize;
        lo + if span == 0 { 0 } else { self.rng.below(span + 1) }
    }

    pub fn usize_in(&mut self, range: RangeInclusive<usize>) -> usize {
        let (lo, hi) = (*range.start(), *range.end());
        lo + self.rng.below(hi - lo + 1)
    }

    pub fn f32_in(&mut self, range: RangeInclusive<f32>) -> f32 {
        self.rng.uniform_in(*range.start(), *range.end())
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// Vector of uniform f32s.
    pub fn f32_vec(&mut self, len: RangeInclusive<usize>, vals: RangeInclusive<f32>) -> Vec<f32> {
        let n = self.len(len);
        (0..n).map(|_| self.f32_in(vals.clone())).collect()
    }

    /// Vector of normals with the given std.
    pub fn normal_vec(&mut self, len: RangeInclusive<usize>, std: f32) -> Vec<f32> {
        let n = self.len(len);
        (0..n).map(|_| self.rng.normal_f32(0.0, std)).collect()
    }

    /// Vector of Laplace draws.
    pub fn laplace_vec(&mut self, len: RangeInclusive<usize>, beta: f64) -> Vec<f32> {
        let n = self.len(len);
        (0..n).map(|_| self.rng.laplace(beta) as f32).collect()
    }

    /// A "nasty" weight vector: mixes scales, ties, zeros and outliers —
    /// the regimes where quantizers break.
    pub fn nasty_weights(&mut self, len: RangeInclusive<usize>) -> Vec<f32> {
        let n = self.len(len).max(1);
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            let r = self.rng.uniform();
            let x = if r < 0.5 {
                self.rng.normal_f32(0.0, 0.05)
            } else if r < 0.7 {
                0.0
            } else if r < 0.85 {
                self.rng.normal_f32(0.0, 1.0)
            } else if r < 0.95 {
                // tied plateau values
                0.125
            } else {
                // outlier
                self.rng.normal_f32(0.0, 50.0)
            };
            v.push(x);
        }
        v
    }

    pub fn rng(&mut self) -> &mut Pcg64 {
        &mut self.rng
    }
}

/// Run `prop` over `cases` random cases; panic (with seed + shrink info) on
/// the first failure. Set `FMQ_CHECK_SEED` to rerun one exact case.
pub fn forall(name: &str, cases: u64, prop: impl Fn(&mut Gen) -> bool) {
    let base = match std::env::var("FMQ_CHECK_SEED") {
        Ok(s) => {
            let seed: u64 = s.parse().expect("FMQ_CHECK_SEED must be u64");
            let mut g = Gen::new(seed, 1.0);
            assert!(prop(&mut g), "property '{name}' failed for seed {seed}");
            return;
        }
        Err(_) => 0xF00D_u64,
    };
    // Under miri (CI's UB-check job) each case runs ~100x slower than
    // native; a thin deterministic slice keeps the job affordable while
    // still exercising every code path of the property.
    let cases = if cfg!(miri) { (cases / 20).max(2) } else { cases };
    for case in 0..cases {
        let seed = base
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(case * 2 + 1);
        let mut g = Gen::new(seed, 1.0);
        if !prop(&mut g) {
            // shrink: find the smallest size multiplier that still fails
            let mut worst = 1.0f64;
            for step in 1..=6 {
                let size = 1.0 / (1 << step) as f64;
                let mut g = Gen::new(seed, size);
                if !prop(&mut g) {
                    worst = size;
                } else {
                    break;
                }
            }
            panic!(
                "property '{name}' failed: case {case}, seed {seed}, \
                 minimal failing size multiplier {worst}. \
                 Rerun with FMQ_CHECK_SEED={seed}"
            );
        }
    }
}

/// Assert two f32 slices are close (abs+rel), with index context on failure.
pub fn assert_close(a: &[f32], b: &[f32], rtol: f32, atol: f32) {
    assert_eq!(a.len(), b.len(), "length mismatch {} vs {}", a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        let tol = atol + rtol * y.abs().max(x.abs());
        assert!(
            (x - y).abs() <= tol || (x.is_nan() && y.is_nan()),
            "index {i}: {x} vs {y} (tol {tol})"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall("abs is nonneg", 32, |g| {
            let v = g.f32_vec(0..=64, -10.0..=10.0);
            v.iter().all(|x| x.abs() >= 0.0)
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn forall_reports_failure() {
        forall("always fails", 4, |_g| false);
    }

    #[test]
    fn nasty_weights_mixes_regimes() {
        let mut g = Gen::new(9, 1.0);
        let v = g.nasty_weights(5000..=5000);
        let zeros = v.iter().filter(|&&x| x == 0.0).count();
        let big = v.iter().filter(|&&x| x.abs() > 10.0).count();
        assert!(zeros > 500, "zeros={zeros}");
        assert!(big > 50, "big={big}");
    }

    #[test]
    fn assert_close_passes_equal() {
        assert_close(&[1.0, 2.0], &[1.0, 2.0 + 1e-7], 1e-5, 1e-5);
    }

    #[test]
    #[should_panic(expected = "index 1")]
    fn assert_close_pinpoints_index() {
        assert_close(&[1.0, 2.0], &[1.0, 3.0], 1e-5, 1e-5);
    }
}
