//! Hand-rolled substrates that would normally be external crates.
//!
//! The build environment is fully offline with only the `xla` + `anyhow`
//! dependency closure available, so the pieces a production repo would take
//! from crates.io (rand, serde_json, clap, proptest) are implemented here
//! from scratch (see DESIGN.md §3 substitution table).

pub mod check;
pub mod cli;
pub mod json;
pub mod rng;

/// Format a float with engineering-friendly precision for report tables.
pub fn fmt_g(v: f64) -> String {
    if v == 0.0 {
        return "0".to_string();
    }
    let a = v.abs();
    if a >= 1e4 || a < 1e-3 {
        format!("{v:.3e}")
    } else {
        format!("{v:.4}")
    }
}

/// Wall-clock seconds of a closure (used by drivers for coarse timing).
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = std::time::Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_g_ranges() {
        assert_eq!(fmt_g(0.0), "0");
        assert!(fmt_g(1234.5).starts_with("1234."));
        assert!(fmt_g(1.2345e7).contains('e'));
        assert!(fmt_g(-3.2e-9).contains('e'));
    }

    #[test]
    fn time_it_returns_value() {
        let (v, dt) = time_it(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(dt >= 0.0);
    }
}
