//! Tiny subcommand + flag parser (clap stand-in).
//!
//! Grammar: `fmq <subcommand> [--flag value]... [--switch]...`
//! Flags are declared up front so typos are hard errors, and `--help`
//! output is generated from the declarations.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// One declared flag.
#[derive(Clone, Debug)]
pub struct FlagSpec {
    pub name: &'static str,
    pub help: &'static str,
    /// None => boolean switch; Some(default) => value flag with default.
    pub default: Option<&'static str>,
}

/// Parsed arguments for one subcommand.
#[derive(Clone, Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    switches: BTreeMap<String, bool>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn get(&self, name: &str) -> &str {
        self.values
            .get(name)
            .map(|s| s.as_str())
            // fmq-analyze: allow(panic_cone) -- fires only when a subcommand reads a flag missing from its own static flag table: a programmer error caught by the first run of that subcommand, not by request data
            .unwrap_or_else(|| panic!("flag --{name} not declared"))
    }

    pub fn get_usize(&self, name: &str) -> Result<usize> {
        Ok(self.get(name).parse()?)
    }

    pub fn get_u64(&self, name: &str) -> Result<u64> {
        Ok(self.get(name).parse()?)
    }

    pub fn get_f64(&self, name: &str) -> Result<f64> {
        Ok(self.get(name).parse()?)
    }

    pub fn get_f32(&self, name: &str) -> Result<f32> {
        Ok(self.get(name).parse()?)
    }

    /// Parse a value flag into any `FromStr` type (enum selectors like
    /// `--engine`, numeric flags, ...), with the flag name in the error.
    pub fn get_parse<T>(&self, name: &str) -> Result<T>
    where
        T: std::str::FromStr,
        T::Err: std::fmt::Display,
    {
        let raw = self.get(name);
        raw.parse()
            .map_err(|e: T::Err| anyhow::anyhow!("--{name} {raw}: {e}"))
    }

    pub fn switch(&self, name: &str) -> bool {
        *self
            .switches
            .get(name)
            .unwrap_or_else(|| panic!("switch --{name} not declared"))
    }

    /// Comma-separated list flag.
    pub fn get_list(&self, name: &str) -> Vec<String> {
        let raw = self.get(name);
        if raw.is_empty() {
            vec![]
        } else {
            raw.split(',').map(|s| s.trim().to_string()).collect()
        }
    }
}

/// Declarative parser for one subcommand.
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    flags: Vec<FlagSpec>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Self {
            name,
            about,
            flags: Vec::new(),
        }
    }

    pub fn flag(mut self, name: &'static str, default: &'static str, help: &'static str) -> Self {
        self.flags.push(FlagSpec {
            name,
            help,
            default: Some(default),
        });
        self
    }

    pub fn switch(mut self, name: &'static str, help: &'static str) -> Self {
        self.flags.push(FlagSpec {
            name,
            help,
            default: None,
        });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("fmq {} — {}\n", self.name, self.about);
        for f in &self.flags {
            match f.default {
                Some(d) => s.push_str(&format!("  --{:<16} {} (default: {})\n", f.name, f.help, d)),
                None => s.push_str(&format!("  --{:<16} {} (switch)\n", f.name, f.help)),
            }
        }
        s
    }

    /// Parse `argv` (after the subcommand word).
    pub fn parse(&self, argv: &[String]) -> Result<Args> {
        let mut args = Args::default();
        for f in &self.flags {
            match f.default {
                Some(d) => {
                    args.values.insert(f.name.to_string(), d.to_string());
                }
                None => {
                    args.switches.insert(f.name.to_string(), false);
                }
            }
        }
        let mut i = 0;
        while let Some(a) = argv.get(i) {
            if let Some(name) = a.strip_prefix("--") {
                if name == "help" {
                    bail!("{}", self.usage());
                }
                let spec = self
                    .flags
                    .iter()
                    .find(|f| f.name == name)
                    .ok_or_else(|| {
                        anyhow::anyhow!("unknown flag --{name} for '{}'\n{}", self.name, self.usage())
                    })?;
                match spec.default {
                    Some(_) => {
                        // value flag: accept "--k v" or "--k=v"
                        if let Some((n, v)) = name.split_once('=') {
                            let _ = n;
                            args.values.insert(spec.name.to_string(), v.to_string());
                        } else {
                            i += 1;
                            let v = argv.get(i).ok_or_else(|| {
                                anyhow::anyhow!("flag --{name} needs a value")
                            })?;
                            args.values.insert(spec.name.to_string(), v.clone());
                        }
                    }
                    None => {
                        args.switches.insert(spec.name.to_string(), true);
                    }
                }
            } else {
                args.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(args)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("sweep", "run the fidelity sweep")
            .flag("bits", "2,3,4,5,6,8", "bit-widths")
            .flag("steps", "32", "euler steps")
            .switch("fast", "use fewer samples")
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let a = cmd().parse(&sv(&[])).unwrap();
        assert_eq!(a.get("steps"), "32");
        assert!(!a.switch("fast"));
        assert_eq!(a.get_list("bits"), vec!["2", "3", "4", "5", "6", "8"]);
    }

    #[test]
    fn values_and_switches() {
        let a = cmd()
            .parse(&sv(&["--steps", "64", "--fast", "pos1"]))
            .unwrap();
        assert_eq!(a.get_usize("steps").unwrap(), 64);
        assert!(a.switch("fast"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn get_parse_typed_flags() {
        let a = cmd().parse(&sv(&["--steps", "48"])).unwrap();
        let n: usize = a.get_parse("steps").unwrap();
        assert_eq!(n, 48);
        let bad = cmd().parse(&sv(&["--steps", "many"])).unwrap();
        let e = bad.get_parse::<usize>("steps").unwrap_err().to_string();
        assert!(e.contains("--steps"), "{e}");
    }

    #[test]
    fn unknown_flag_is_error() {
        assert!(cmd().parse(&sv(&["--nope", "1"])).is_err());
        assert!(cmd().parse(&sv(&["--steps"])).is_err()); // missing value
    }

    #[test]
    fn usage_mentions_flags() {
        let u = cmd().usage();
        assert!(u.contains("--bits"));
        assert!(u.contains("switch"));
    }
}
