//! Deterministic, dependency-free randomness: PCG64 (XSL-RR 128/64) plus
//! SplitMix64 seeding, Box–Muller normals, Laplace/exponential transforms.
//!
//! All randomness in the system flows through this module and is seeded
//! explicitly, so every experiment in EXPERIMENTS.md is bit-reproducible.

/// SplitMix64 — used to expand a single `u64` seed into PCG state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// PCG64 XSL-RR 128/64: 128-bit LCG state, 64-bit xor-shift-rotate output.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ED05_1FC6_5DA4_4385_DF64_9FCC_F645;

impl Pcg64 {
    /// Seed from a single u64 (stream derived from the seed too).
    pub fn seed(seed: u64) -> Self {
        let mut sm = seed;
        let s0 = splitmix64(&mut sm);
        let s1 = splitmix64(&mut sm);
        let i0 = splitmix64(&mut sm);
        let i1 = splitmix64(&mut sm);
        let mut rng = Self {
            state: ((s0 as u128) << 64) | s1 as u128,
            inc: (((i0 as u128) << 64) | i1 as u128) | 1,
        };
        rng.next_u64();
        rng
    }

    /// Derive an independent child stream (for parallel workers).
    pub fn fork(&mut self, tag: u64) -> Self {
        Self::seed(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in [0, 1) with 53-bit precision.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform() as f32
    }

    /// Uniform integer in [0, n) (Lemire-style rejection-free for our use).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.uniform() * n as f64) as usize % n.max(1)
    }

    /// Standard normal via Box–Muller (one value; pairs not cached to keep
    /// the generator stateless w.r.t. call pattern).
    #[inline]
    pub fn normal(&mut self) -> f64 {
        let u1 = (1.0 - self.uniform()).max(1e-300); // avoid log(0)
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with mean/std as f32.
    #[inline]
    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Laplace(0, beta): inverse-CDF transform.
    #[inline]
    pub fn laplace(&mut self, beta: f64) -> f64 {
        let u = self.uniform() - 0.5;
        -beta * u.signum() * (1.0 - 2.0 * u.abs()).max(1e-300).ln()
    }

    /// Fill a slice with standard normals (f32).
    pub fn fill_normal(&mut self, out: &mut [f32], mean: f32, std: f32) {
        for v in out.iter_mut() {
            *v = self.normal_f32(mean, std);
        }
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        let mut v = vec![0.0f32; n];
        self.fill_normal(&mut v, 0.0, 1.0);
        v
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a random element index weighted by `w` (w >= 0, not all zero).
    pub fn pick_weighted(&mut self, w: &[f32]) -> usize {
        let total: f32 = w.iter().sum();
        let mut r = self.uniform() as f32 * total;
        for (i, &wi) in w.iter().enumerate() {
            r -= wi;
            if r <= 0.0 {
                return i;
            }
        }
        w.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Pcg64::seed(42);
        let mut b = Pcg64::seed(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Pcg64::seed(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut rng = Pcg64::seed(1);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::seed(2);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn laplace_moments() {
        let mut rng = Pcg64::seed(3);
        let beta = 0.7;
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.laplace(beta)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        // Laplace variance = 2 beta^2
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 2.0 * beta * beta).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Pcg64::seed(4);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let i = rng.below(10);
            assert!(i < 10);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::seed(5);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_diverge() {
        let mut root = Pcg64::seed(6);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn pick_weighted_respects_weights() {
        let mut rng = Pcg64::seed(7);
        let w = [0.0f32, 1.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[rng.pick_weighted(&w)] += 1;
        }
        assert_eq!(counts[0], 0);
        let ratio = counts[2] as f64 / counts[1] as f64;
        assert!((ratio - 3.0).abs() < 0.4, "ratio={ratio}");
    }
}
