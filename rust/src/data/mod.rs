//! Datasets: the paper's five-benchmark complexity ladder, simulated.
//!
//! The paper evaluates on MNIST → FashionMNIST → CIFAR10 → CelebA →
//! ImageNet purely as a *complexity axis* (class count, visual diversity,
//! intra-class variation). The offline environment has no datasets, so
//! `synth` implements five procedural generators that replicate that axis
//! with controlled knobs (see DESIGN.md §3). All render at 16×16×3 so a
//! single AOT artifact set serves every dataset.

pub mod synth;

use crate::util::rng::Pcg64;

/// Pixel count of one flattened image (matches `arch.D` on the python side).
pub const IMG_HW: usize = 16;
pub const IMG_C: usize = 3;
pub const IMG_D: usize = IMG_HW * IMG_HW * IMG_C;

/// The five benchmark stand-ins, ordered by the paper's complexity ladder.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Dataset {
    /// MNIST stand-in: sparse monochrome stroke digits (10 classes).
    SynthMnist,
    /// FashionMNIST stand-in: textured garment silhouettes (10 classes).
    SynthFashion,
    /// CIFAR10 stand-in: colored geometric objects on noisy backgrounds.
    SynthCifar,
    /// CelebA stand-in: face-like compositions with attribute variation.
    SynthCeleba,
    /// ImageNet stand-in: high-diversity multi-object composite scenes.
    SynthImagenet,
}

impl Dataset {
    pub const ALL: [Dataset; 5] = [
        Dataset::SynthMnist,
        Dataset::SynthFashion,
        Dataset::SynthCifar,
        Dataset::SynthCeleba,
        Dataset::SynthImagenet,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Dataset::SynthMnist => "synth-mnist",
            Dataset::SynthFashion => "synth-fashion",
            Dataset::SynthCifar => "synth-cifar",
            Dataset::SynthCeleba => "synth-celeba",
            Dataset::SynthImagenet => "synth-imagenet",
        }
    }

    pub fn parse(s: &str) -> Option<Dataset> {
        Dataset::ALL.iter().copied().find(|d| d.name() == s)
    }

    /// Position on the paper's complexity ladder (0 = simplest rung,
    /// MNIST stand-in). Identical to the index in [`Dataset::ALL`]; the
    /// sweep's per-rung conformance assertions and `BENCH_figgrid.json`
    /// order cells by this.
    pub fn ladder_rank(&self) -> usize {
        Dataset::ALL
            .iter()
            .position(|d| d.name() == self.name())
            .unwrap_or(0)
    }

    /// Class cardinality — one of the paper's complexity knobs.
    pub fn classes(&self) -> usize {
        match self {
            Dataset::SynthMnist | Dataset::SynthFashion => 10,
            Dataset::SynthCifar => 10,
            Dataset::SynthCeleba => 1, // attribute-continuous, like CelebA
            Dataset::SynthImagenet => 40,
        }
    }

    /// Generate one image (flattened, values in [-1, 1]).
    pub fn sample(&self, rng: &mut Pcg64) -> Vec<f32> {
        match self {
            Dataset::SynthMnist => synth::mnist_like(rng),
            Dataset::SynthFashion => synth::fashion_like(rng),
            Dataset::SynthCifar => synth::cifar_like(rng),
            Dataset::SynthCeleba => synth::celeba_like(rng),
            Dataset::SynthImagenet => synth::imagenet_like(rng),
        }
    }

    /// Generate a batch as a flat [n, IMG_D] matrix.
    pub fn batch(&self, rng: &mut Pcg64, n: usize) -> Vec<f32> {
        let mut out = Vec::with_capacity(n * IMG_D);
        for _ in 0..n {
            out.extend_from_slice(&self.sample(rng));
        }
        out
    }

    /// Empirical "visual diversity" proxy: mean pairwise L2 distance of a
    /// sample batch. The complexity ladder must be monotone in this (tested).
    pub fn diversity(&self, rng: &mut Pcg64, n: usize) -> f64 {
        let b = self.batch(rng, n);
        let mut total = 0.0f64;
        let mut pairs = 0usize;
        for i in 0..n {
            for j in (i + 1)..n {
                let mut d = 0.0f64;
                for k in 0..IMG_D {
                    let diff = (b[i * IMG_D + k] - b[j * IMG_D + k]) as f64;
                    d += diff * diff;
                }
                total += d.sqrt();
                pairs += 1;
            }
        }
        total / pairs as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_roundtrip() {
        for d in Dataset::ALL {
            assert_eq!(Dataset::parse(d.name()), Some(d));
        }
        assert_eq!(Dataset::parse("nope"), None);
    }

    #[test]
    fn samples_are_bounded_and_shaped() {
        let mut rng = Pcg64::seed(1);
        for d in Dataset::ALL {
            for _ in 0..8 {
                let img = d.sample(&mut rng);
                assert_eq!(img.len(), IMG_D);
                for &p in &img {
                    assert!((-1.0..=1.0).contains(&p), "{} out of range: {p}", d.name());
                }
            }
        }
    }

    #[test]
    fn batch_shape() {
        let mut rng = Pcg64::seed(2);
        let b = Dataset::SynthCifar.batch(&mut rng, 5);
        assert_eq!(b.len(), 5 * IMG_D);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = Dataset::SynthCeleba.sample(&mut Pcg64::seed(7));
        let b = Dataset::SynthCeleba.sample(&mut Pcg64::seed(7));
        assert_eq!(a, b);
    }

    /// The complexity ladder: diversity increases from mnist-like to
    /// imagenet-like (the property the paper's dataset choice encodes).
    #[test]
    fn complexity_ladder_is_monotone_at_ends() {
        let mut rng = Pcg64::seed(3);
        let dm = Dataset::SynthMnist.diversity(&mut rng, 32);
        let di = Dataset::SynthImagenet.diversity(&mut rng, 32);
        let dc = Dataset::SynthCifar.diversity(&mut rng, 32);
        assert!(dm < dc, "mnist {dm} !< cifar {dc}");
        assert!(dc < di, "cifar {dc} !< imagenet {di}");
    }
}
