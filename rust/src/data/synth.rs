//! Procedural image generators: the five-benchmark complexity ladder.
//!
//! Shared drawing substrate (a tiny software rasterizer: strokes, ellipses,
//! rectangles, textures) + five generators with increasing class count,
//! color diversity and intra-class variation. Pixels land in [-1, 1].

use super::{IMG_C, IMG_D, IMG_HW};
use crate::util::rng::Pcg64;

/// Mutable canvas over a flattened [H, W, C] image.
pub struct Canvas {
    pub px: Vec<f32>,
}

impl Canvas {
    pub fn new(bg: [f32; 3]) -> Self {
        let mut px = vec![0.0f32; IMG_D];
        for i in 0..IMG_HW * IMG_HW {
            for c in 0..IMG_C {
                px[i * IMG_C + c] = bg[c];
            }
        }
        Self { px }
    }

    #[inline]
    fn idx(x: i32, y: i32) -> Option<usize> {
        if x < 0 || y < 0 || x >= IMG_HW as i32 || y >= IMG_HW as i32 {
            None
        } else {
            Some((y as usize * IMG_HW + x as usize) * IMG_C)
        }
    }

    /// Alpha-blend a pixel.
    pub fn blend(&mut self, x: i32, y: i32, color: [f32; 3], alpha: f32) {
        if let Some(i) = Self::idx(x, y) {
            for c in 0..IMG_C {
                self.px[i + c] = self.px[i + c] * (1.0 - alpha) + color[c] * alpha;
            }
        }
    }

    /// Soft disc (gaussian falloff), the workhorse brush.
    pub fn disc(&mut self, cx: f32, cy: f32, r: f32, color: [f32; 3], alpha: f32) {
        let ir = r.ceil() as i32 + 1;
        let (icx, icy) = (cx.round() as i32, cy.round() as i32);
        for dy in -ir..=ir {
            for dx in -ir..=ir {
                let d2 = (dx as f32 - (cx - icx as f32)).powi(2)
                    + (dy as f32 - (cy - icy as f32)).powi(2);
                let a = alpha * (-d2 / (r * r).max(1e-6)).exp();
                if a > 0.01 {
                    self.blend(icx + dx, icy + dy, color, a.min(1.0));
                }
            }
        }
    }

    /// Stroke from (x0,y0) to (x1,y1) with a soft brush.
    pub fn stroke(&mut self, x0: f32, y0: f32, x1: f32, y1: f32, w: f32, color: [f32; 3]) {
        let steps = (((x1 - x0).abs() + (y1 - y0).abs()) * 2.0).ceil().max(1.0) as usize;
        for s in 0..=steps {
            let t = s as f32 / steps as f32;
            self.disc(x0 + t * (x1 - x0), y0 + t * (y1 - y0), w, color, 0.9);
        }
    }

    /// Axis-aligned filled rectangle.
    pub fn rect(&mut self, x0: i32, y0: i32, x1: i32, y1: i32, color: [f32; 3], alpha: f32) {
        for y in y0..=y1 {
            for x in x0..=x1 {
                self.blend(x, y, color, alpha);
            }
        }
    }

    /// Filled ellipse.
    pub fn ellipse(&mut self, cx: f32, cy: f32, rx: f32, ry: f32, color: [f32; 3], alpha: f32) {
        for y in 0..IMG_HW as i32 {
            for x in 0..IMG_HW as i32 {
                let nx = (x as f32 - cx) / rx.max(0.1);
                let ny = (y as f32 - cy) / ry.max(0.1);
                let d = nx * nx + ny * ny;
                if d <= 1.0 {
                    self.blend(x, y, color, alpha * (1.0 - 0.3 * d));
                }
            }
        }
    }

    /// Add per-pixel noise.
    pub fn noise(&mut self, rng: &mut Pcg64, amp: f32) {
        for p in self.px.iter_mut() {
            *p += rng.normal_f32(0.0, amp);
        }
    }

    /// Horizontal-stripe texture over a region.
    pub fn stripes(&mut self, y0: i32, y1: i32, period: i32, color: [f32; 3], alpha: f32) {
        for y in y0..=y1 {
            if (y / period.max(1)) % 2 == 0 {
                for x in 0..IMG_HW as i32 {
                    self.blend(x, y, color, alpha);
                }
            }
        }
    }

    pub fn finish(mut self) -> Vec<f32> {
        for p in self.px.iter_mut() {
            *p = p.clamp(-1.0, 1.0);
        }
        self.px
    }
}

fn gray(v: f32) -> [f32; 3] {
    [v, v, v]
}

fn random_color(rng: &mut Pcg64) -> [f32; 3] {
    [
        rng.uniform_in(-0.8, 0.9),
        rng.uniform_in(-0.8, 0.9),
        rng.uniform_in(-0.8, 0.9),
    ]
}

// --------------------------------------------------------------- datasets

/// MNIST-like: one white stroke glyph on black; 10 glyph classes with small
/// jitter. Low diversity, grayscale, sparse.
pub fn mnist_like(rng: &mut Pcg64) -> Vec<f32> {
    let class = rng.below(10);
    let mut c = Canvas::new(gray(-1.0));
    let jx = rng.uniform_in(-1.0, 1.0);
    let jy = rng.uniform_in(-1.0, 1.0);
    let ink = gray(rng.uniform_in(0.6, 1.0));
    let w = rng.uniform_in(0.7, 1.1);
    // glyph skeletons: endpoints per class (coarse digit-like shapes)
    let paths: &[&[(f32, f32)]] = &[
        &[(5.0, 4.0), (10.0, 4.0), (10.0, 12.0), (5.0, 12.0), (5.0, 4.0)], // 0
        &[(8.0, 3.0), (8.0, 13.0)],                                        // 1
        &[(5.0, 5.0), (10.0, 5.0), (5.0, 12.0), (10.0, 12.0)],             // 2
        &[(5.0, 4.0), (10.0, 6.0), (6.0, 8.0), (10.0, 10.0), (5.0, 12.0)], // 3
        &[(9.0, 13.0), (9.0, 3.0), (5.0, 9.0), (11.0, 9.0)],               // 4
        &[(10.0, 4.0), (5.0, 4.0), (5.0, 8.0), (10.0, 9.5), (5.0, 12.0)],  // 5
        &[(9.0, 3.0), (5.0, 8.0), (5.0, 12.0), (10.0, 12.0), (9.0, 8.0), (5.0, 9.0)], // 6
        &[(5.0, 4.0), (10.0, 4.0), (6.0, 13.0)],                           // 7
        &[(7.5, 4.0), (5.0, 6.0), (10.0, 10.0), (7.5, 12.0), (5.0, 10.0), (10.0, 6.0), (7.5, 4.0)], // 8
        &[(10.0, 13.0), (10.0, 4.0), (5.0, 4.0), (5.0, 8.0), (10.0, 8.0)], // 9
    ];
    let path = paths[class];
    for seg in path.windows(2) {
        c.stroke(
            seg[0].0 + jx,
            seg[0].1 + jy,
            seg[1].0 + jx,
            seg[1].1 + jy,
            w,
            ink,
        );
    }
    c.finish()
}

/// FashionMNIST-like: textured garment silhouettes (10 classes), grayscale
/// with stripe/noise textures — denser coverage, moderate diversity.
pub fn fashion_like(rng: &mut Pcg64) -> Vec<f32> {
    let class = rng.below(10);
    let mut c = Canvas::new(gray(-1.0));
    let shade = rng.uniform_in(-0.1, 0.7);
    let body = gray(shade);
    match class {
        0..=2 => {
            // shirts: torso + sleeves
            c.rect(5, 4, 10, 12, body, 0.95);
            c.rect(2, 4, 4, 7 + class as i32, body, 0.9);
            c.rect(11, 4, 13, 7 + class as i32, body, 0.9);
        }
        3..=4 => {
            // trousers: two legs
            c.rect(5, 3, 10, 6, body, 0.95);
            c.rect(5, 7, 7, 13, body, 0.95);
            c.rect(9, 7, 10, 13, body, 0.95);
        }
        5..=6 => {
            // dress: triangle-ish
            for y in 3..14 {
                let half = 1 + (y - 3) / 3;
                c.rect(8 - half, y, 8 + half, y, body, 0.95);
            }
        }
        7..=8 => {
            // shoe: low wide form
            c.rect(3, 9, 12, 12, body, 0.95);
            c.rect(9, 6, 12, 9, body, 0.9);
        }
        _ => {
            // bag
            c.rect(4, 7, 11, 13, body, 0.95);
            c.stroke(5.0, 7.0, 8.0, 3.0, 0.6, body);
            c.stroke(8.0, 3.0, 10.0, 7.0, 0.6, body);
        }
    }
    // texture varies within class
    if rng.uniform() < 0.6 {
        c.stripes(3, 13, 1 + rng.below(3) as i32, gray(shade - 0.4), 0.35);
    }
    c.noise(rng, 0.04);
    c.finish()
}

/// CIFAR10-like: a colored object (10 shape classes) on a colored noisy
/// background — full color, background clutter.
pub fn cifar_like(rng: &mut Pcg64) -> Vec<f32> {
    let class = rng.below(10);
    // muted backgrounds: cifar photos cluster closer than imagenet scenes
    let bg = random_color(rng).map(|v| v * 0.8);
    let mut c = Canvas::new(bg);
    c.noise(rng, 0.10);
    let fg = random_color(rng);
    let cx = rng.uniform_in(6.0, 10.0);
    let cy = rng.uniform_in(6.0, 10.0);
    match class % 5 {
        0 => c.ellipse(cx, cy, 4.0, 4.0, fg, 0.95),
        1 => c.rect(cx as i32 - 3, cy as i32 - 3, cx as i32 + 3, cy as i32 + 3, fg, 0.95),
        2 => {
            // triangle via strokes
            c.stroke(cx - 4.0, cy + 3.0, cx + 4.0, cy + 3.0, 1.0, fg);
            c.stroke(cx - 4.0, cy + 3.0, cx, cy - 4.0, 1.0, fg);
            c.stroke(cx + 4.0, cy + 3.0, cx, cy - 4.0, 1.0, fg);
        }
        3 => c.ellipse(cx, cy, 5.0, 2.5, fg, 0.95), // "vehicle" blob
        _ => {
            // cross
            c.rect(cx as i32 - 4, cy as i32 - 1, cx as i32 + 4, cy as i32 + 1, fg, 0.95);
            c.rect(cx as i32 - 1, cy as i32 - 4, cx as i32 + 1, cy as i32 + 4, fg, 0.95);
        }
    }
    // second accent per class parity (adds intra-class variation)
    if class >= 5 {
        let accent = random_color(rng);
        c.disc(
            rng.uniform_in(3.0, 13.0),
            rng.uniform_in(3.0, 13.0),
            1.5,
            accent,
            0.8,
        );
    }
    c.noise(rng, 0.05);
    c.finish()
}

/// CelebA-like: face composition — skin-tone ellipse, eyes, mouth, hair
/// band; continuous attribute variation (tone, hair color, expression).
pub fn celeba_like(rng: &mut Pcg64) -> Vec<f32> {
    let bg = random_color(rng);
    let mut c = Canvas::new(bg);
    // skin tone family
    let tone = rng.uniform_in(-0.2, 0.7);
    let skin = [tone + 0.25, tone, tone - 0.25];
    let fx = rng.uniform_in(7.0, 9.0);
    let fy = rng.uniform_in(7.5, 9.0);
    c.ellipse(fx, fy, 4.5, 5.5, skin, 0.98);
    // hair band
    let hair = [
        rng.uniform_in(-1.0, 0.1),
        rng.uniform_in(-1.0, 0.0),
        rng.uniform_in(-1.0, 0.1),
    ];
    c.ellipse(fx, fy - 4.0, 4.8, 2.6, hair, 0.95);
    // eyes
    let eye_y = fy - 1.0 + rng.uniform_in(-0.4, 0.4);
    let eye_dx = rng.uniform_in(1.6, 2.2);
    let eye = gray(-0.9);
    c.disc(fx - eye_dx, eye_y, 0.7, eye, 0.95);
    c.disc(fx + eye_dx, eye_y, 0.7, eye, 0.95);
    // mouth: expression = curvature
    let smile = rng.uniform_in(-1.0, 1.0);
    let my = fy + 2.6;
    c.stroke(fx - 1.6, my, fx, my + smile * 0.8, 0.5, gray(-0.6));
    c.stroke(fx, my + smile * 0.8, fx + 1.6, my, 0.5, gray(-0.6));
    c.noise(rng, 0.03);
    c.finish()
}

/// ImageNet-like: 40 latent classes, 2–4 objects of mixed shape families,
/// textured backgrounds — the high-diversity end of the ladder.
pub fn imagenet_like(rng: &mut Pcg64) -> Vec<f32> {
    let class = rng.below(40);
    // class seeds a scene palette so images cluster by class
    let mut palette_rng = Pcg64::seed(0xDEAD_0000 + class as u64);
    let bg = random_color(&mut palette_rng);
    let mut c = Canvas::new(bg);
    if palette_rng.uniform() < 0.5 {
        c.stripes(0, 15, 2 + palette_rng.below(4) as i32, random_color(&mut palette_rng), 0.3);
    }
    c.noise(rng, 0.18);
    let n_obj = 3 + rng.below(3);
    for k in 0..n_obj {
        // object family fixed per (class, k); pose free per image
        let mut fam_rng = Pcg64::seed(0xBEEF_0000 + (class * 8 + k) as u64);
        let fg = random_color(&mut fam_rng);
        let fam = fam_rng.below(4);
        let cx = rng.uniform_in(3.0, 13.0);
        let cy = rng.uniform_in(3.0, 13.0);
        let scale = rng.uniform_in(1.5, 3.5);
        match fam {
            0 => c.ellipse(cx, cy, scale, scale * 0.8, fg, 0.9),
            1 => c.rect(
                (cx - scale) as i32,
                (cy - scale) as i32,
                (cx + scale) as i32,
                (cy + scale) as i32,
                fg,
                0.9,
            ),
            2 => c.stroke(cx - scale, cy - scale, cx + scale, cy + scale, scale * 0.4, fg),
            _ => c.disc(cx, cy, scale * 0.7, fg, 0.95),
        }
    }
    c.noise(rng, 0.06);
    c.finish()
}

/// Seed-isolated evaluation batch for one ladder rung — the sweep's data
/// hook. Every consumer (latent round-trips, coverage templates, figure
/// benches) draws its real-image batches through this so a cell is fully
/// determined by `(dataset, purpose-seed, n)` and never by iteration
/// order elsewhere in the run. Flat `[n, IMG_D]`.
pub fn eval_batch(ds: super::Dataset, seed: u64, n: usize) -> Vec<f32> {
    let mut rng = Pcg64::seed(
        seed ^ (ds.ladder_rank() as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15),
    );
    ds.batch(&mut rng, n)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn canvas_blend_clamps_bounds() {
        let mut c = Canvas::new(gray(0.0));
        c.blend(-5, 2, gray(1.0), 1.0); // no panic
        c.blend(2, 99, gray(1.0), 1.0);
        c.blend(2, 2, gray(1.0), 1.0);
        let px = c.finish();
        assert_eq!(px[(2 * IMG_HW + 2) * IMG_C], 1.0);
    }

    #[test]
    fn mnist_classes_differ() {
        // two fixed-class renders with fixed jitter should differ across classes
        let imgs: Vec<Vec<f32>> = (0..20)
            .map(|i| mnist_like(&mut Pcg64::seed(1000 + i)))
            .collect();
        let mut distinct = 0;
        for i in 0..imgs.len() {
            for j in (i + 1)..imgs.len() {
                let d: f32 = imgs[i]
                    .iter()
                    .zip(imgs[j].iter())
                    .map(|(a, b)| (a - b).abs())
                    .sum();
                if d > 1.0 {
                    distinct += 1;
                }
            }
        }
        assert!(distinct > 100, "distinct={distinct}");
    }

    #[test]
    fn mnist_is_sparse_imagenet_is_dense() {
        let mut rng = Pcg64::seed(4);
        let m = mnist_like(&mut rng);
        let dark = m.iter().filter(|&&p| p < -0.9).count();
        assert!(dark > IMG_D / 2, "mnist should be mostly background: {dark}");
        let mut var_sum = 0.0;
        for i in 0..8 {
            let im = imagenet_like(&mut Pcg64::seed(50 + i));
            let (_, v) = crate::stats::mean_var(&im);
            var_sum += v;
        }
        assert!(var_sum / 8.0 > 0.05, "imagenet-like should be high-variance");
    }

    #[test]
    fn celeba_has_continuous_attributes() {
        // faces from different seeds should differ smoothly but markedly
        let a = celeba_like(&mut Pcg64::seed(1));
        let b = celeba_like(&mut Pcg64::seed(2));
        let d: f32 = a.iter().zip(b.iter()).map(|(x, y)| (x - y).abs()).sum();
        assert!(d > 5.0);
    }

    #[test]
    fn all_generators_in_range() {
        for seed in 0..5 {
            for img in [
                mnist_like(&mut Pcg64::seed(seed)),
                fashion_like(&mut Pcg64::seed(seed)),
                cifar_like(&mut Pcg64::seed(seed)),
                celeba_like(&mut Pcg64::seed(seed)),
                imagenet_like(&mut Pcg64::seed(seed)),
            ] {
                assert_eq!(img.len(), IMG_D);
                assert!(img.iter().all(|p| (-1.0..=1.0).contains(p)));
            }
        }
    }

    /// The sweep's data hook: shape, determinism, and seed isolation
    /// between rungs (same purpose-seed, different datasets → different
    /// streams).
    #[test]
    fn eval_batch_is_deterministic_and_rung_isolated() {
        use super::super::Dataset;
        let a = eval_batch(Dataset::SynthMnist, 42, 3);
        let b = eval_batch(Dataset::SynthMnist, 42, 3);
        assert_eq!(a.len(), 3 * IMG_D);
        assert_eq!(a, b, "same (rung, seed, n) must reproduce exactly");
        let c = eval_batch(Dataset::SynthMnist, 43, 3);
        assert_ne!(a, c, "seed must matter");
        // ladder_rank orders the rungs and feeds the seed isolation
        for (i, ds) in Dataset::ALL.iter().enumerate() {
            assert_eq!(ds.ladder_rank(), i);
        }
    }
}
