//! Exposition: snapshot the registries into Prometheus text-format or
//! integer-exact JSON.
//!
//! Free functions, deliberately **not** methods on [`Hist`]/[`Metrics`]:
//! rendering allocates (strings, JSON trees), and the registry types are
//! enrolled wholesale in the `no_alloc` lint via wildcard roots — keeping
//! exposition outside those types keeps the lint wall airtight.
//!
//! Conventions (full catalogue: `docs/OBSERVABILITY.md`):
//!
//! * names are `fmq_<registry>_<stage>_<unit>`; counters end `_total`,
//!   durations are `_ns`;
//! * histogram `le` boundaries sit on octave edges `2^m - 1` so each
//!   cumulative count is a whole-bucket prefix sum — no sample is ever
//!   split across an `le` line;
//! * every histogram gets an `_approx` summary twin carrying
//!   p50/p95/p99 *upper-bound* estimates ([`HistSnapshot::quantile`]
//!   brackets the true quantile; the upper edge is reported so the
//!   estimate errs pessimistic).

use std::fmt::Write as _;

use crate::util::json::Json;

use super::hist::HistSnapshot;
use super::{Metrics, ENGINE};

/// The `le` octave edges emitted per histogram: `2^m - 1` for `m` in
/// `3..=63`, then `+Inf`.
const LE_OCTAVES: std::ops::RangeInclusive<u32> = 3..=63;

/// Quantiles exposed on every `_approx` summary family.
const QUANTILES: [(&str, f64); 3] = [("0.5", 0.5), ("0.95", 0.95), ("0.99", 0.99)];

/// Render one Prometheus text-format snapshot of the per-server registry
/// `m` plus the process-global [`ENGINE`] registry.
pub fn render_prometheus(m: &Metrics) -> String {
    let mut out = String::with_capacity(32 * 1024);

    for (name, help, v) in [
        ("fmq_server_requests_total", "Requests admitted (generate + encode).", m.requests.get()),
        ("fmq_server_batches_total", "Batches executed by variant workers.", m.batches.get()),
        ("fmq_server_samples_total", "Samples produced by generate requests.", m.samples.get()),
        ("fmq_server_encodes_total", "Encode requests served.", m.encodes.get()),
        ("fmq_server_errors_total", "Requests answered with an error reply.", m.errors.get()),
        (
            "fmq_server_worker_respawns_total",
            "Worker threads respawned by the supervisor after a panic.",
            m.worker_respawns.get(),
        ),
        (
            "fmq_server_shed_total",
            "Requests shed by admission control (queue full).",
            m.shed.get(),
        ),
        (
            "fmq_server_conn_drops_total",
            "Connections that died mid-reply.",
            m.conn_drops.get(),
        ),
    ] {
        counter_block(&mut out, name, help, v);
    }

    // one labelled sample per error class, same family
    let _ = writeln!(
        out,
        "# HELP fmq_server_errors_by_class_total Error replies by wire error class."
    );
    let _ = writeln!(out, "# TYPE fmq_server_errors_by_class_total counter");
    for (label, c) in super::ERROR_CLASSES.iter().zip(m.errors_by_class.iter()) {
        let _ = writeln!(
            out,
            "fmq_server_errors_by_class_total{{class=\"{label}\"}} {}",
            c.get()
        );
    }

    for (name, help, v) in [
        ("fmq_server_queue_depth", "Rows admitted but not yet completed.", m.queue_depth.get()),
        ("fmq_server_resident_bytes", "Packed model bytes resident.", m.resident_bytes.get()),
        (
            "fmq_server_workspace_bytes",
            "High-water workspace-arena bytes across workers.",
            m.workspace_bytes.get(),
        ),
    ] {
        gauge_block(&mut out, name, help, v);
    }

    for (name, help, h) in [
        (
            "fmq_server_request_latency_ns",
            "End-to-end request latency (admission to reply built).",
            &m.request_latency_ns,
        ),
        ("fmq_server_queue_wait_ns", "Admission to first batch assembly.", &m.queue_wait_ns),
        ("fmq_server_batch_assemble_ns", "Batch input assembly time.", &m.batch_assemble_ns),
        ("fmq_server_batch_run_ns", "Batch sampler execution time.", &m.batch_run_ns),
        ("fmq_server_batch_rows", "Rows per executed batch.", &m.batch_rows),
        (
            "fmq_server_reply_serialize_ns",
            "Reply serialization + socket write time.",
            &m.reply_serialize_ns,
        ),
    ] {
        hist_block(&mut out, name, help, &h.snapshot());
    }

    for (name, help, v) in [
        (
            "fmq_engine_tune_plans_total",
            "Autotune plan measurements (cache misses).",
            ENGINE.tune_plans_total.get(),
        ),
        (
            "fmq_engine_shard_jobs_total",
            "Shard jobs dispatched by the pool (row + column axes).",
            ENGINE.shard_jobs_total.get(),
        ),
    ] {
        counter_block(&mut out, name, help, v);
    }

    for (name, help, h) in [
        ("fmq_engine_ode_step_ns", "One Euler ODE step over a batch.", &ENGINE.ode_step_ns),
        (
            "fmq_engine_layer_sweep_ns",
            "One layer GEMM inside the fused forward.",
            &ENGINE.layer_sweep_ns,
        ),
        ("fmq_engine_v2_kernel_ns", "One v2 blocked-kernel stripe invocation.", &ENGINE.v2_kernel_ns),
    ] {
        hist_block(&mut out, name, help, &h.snapshot());
    }

    out
}

/// Render an integer-exact JSON snapshot (the `metrics` op's
/// `format: "json"` body): counters/gauges as [`Json::Int`], histograms
/// as `{count, sum, p50, p95, p99}` objects with upper-bound estimates.
pub fn render_json(m: &Metrics) -> Json {
    let server = Json::obj(vec![
        ("requests", Json::Int(m.requests.get() as i128)),
        ("batches", Json::Int(m.batches.get() as i128)),
        ("samples", Json::Int(m.samples.get() as i128)),
        ("encodes", Json::Int(m.encodes.get() as i128)),
        ("errors", Json::Int(m.errors.get() as i128)),
        (
            "errors_by_class",
            Json::obj(
                super::ERROR_CLASSES
                    .iter()
                    .zip(m.errors_by_class.iter())
                    .map(|(label, c)| (*label, Json::Int(c.get() as i128)))
                    .collect(),
            ),
        ),
        ("worker_respawns", Json::Int(m.worker_respawns.get() as i128)),
        ("shed", Json::Int(m.shed.get() as i128)),
        ("conn_drops", Json::Int(m.conn_drops.get() as i128)),
        ("queue_depth", Json::Int(m.queue_depth.get() as i128)),
        ("resident_bytes", Json::Int(m.resident_bytes.get() as i128)),
        ("workspace_bytes", Json::Int(m.workspace_bytes.get() as i128)),
        ("request_latency_ns", hist_json(&m.request_latency_ns.snapshot())),
        ("queue_wait_ns", hist_json(&m.queue_wait_ns.snapshot())),
        ("batch_assemble_ns", hist_json(&m.batch_assemble_ns.snapshot())),
        ("batch_run_ns", hist_json(&m.batch_run_ns.snapshot())),
        ("batch_rows", hist_json(&m.batch_rows.snapshot())),
        ("reply_serialize_ns", hist_json(&m.reply_serialize_ns.snapshot())),
    ]);
    let engine = Json::obj(vec![
        ("tune_plans_total", Json::Int(ENGINE.tune_plans_total.get() as i128)),
        ("shard_jobs_total", Json::Int(ENGINE.shard_jobs_total.get() as i128)),
        ("ode_step_ns", hist_json(&ENGINE.ode_step_ns.snapshot())),
        ("layer_sweep_ns", hist_json(&ENGINE.layer_sweep_ns.snapshot())),
        ("v2_kernel_ns", hist_json(&ENGINE.v2_kernel_ns.snapshot())),
    ]);
    Json::obj(vec![("server", server), ("engine", engine)])
}

fn counter_block(out: &mut String, name: &str, help: &str, v: u64) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} counter");
    let _ = writeln!(out, "{name} {v}");
}

fn gauge_block(out: &mut String, name: &str, help: &str, v: i64) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} gauge");
    let _ = writeln!(out, "{name} {v}");
}

fn hist_block(out: &mut String, name: &str, help: &str, s: &HistSnapshot) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} histogram");
    for m in LE_OCTAVES {
        let le = (1u64 << m) - 1;
        let cum = s.cumulative_at_octave(m);
        let _ = writeln!(out, "{name}_bucket{{le=\"{le}\"}} {cum}");
    }
    let _ = writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {}", s.count);
    let _ = writeln!(out, "{name}_sum {}", s.sum);
    let _ = writeln!(out, "{name}_count {}", s.count);

    // bracketed-quantile summary twin (upper bounds — pessimistic)
    let _ = writeln!(out, "# HELP {name}_approx Bucket-upper-bound quantile estimates of {name}.");
    let _ = writeln!(out, "# TYPE {name}_approx summary");
    for (label, q) in QUANTILES {
        let (_, hi) = s.quantile(q);
        let _ = writeln!(out, "{name}_approx{{quantile=\"{label}\"}} {hi}");
    }
    let _ = writeln!(out, "{name}_approx_sum {}", s.sum);
    let _ = writeln!(out, "{name}_approx_count {}", s.count);
}

fn hist_json(s: &HistSnapshot) -> Json {
    Json::obj(vec![
        ("count", Json::Int(s.count as i128)),
        ("sum", Json::Int(s.sum as i128)),
        ("p50", Json::Int(s.quantile(0.5).1 as i128)),
        ("p95", Json::Int(s.quantile(0.95).1 as i128)),
        ("p99", Json::Int(s.quantile(0.99).1 as i128)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn family_count(text: &str) -> usize {
        text.lines().filter(|l| l.starts_with("# TYPE ")).count()
    }

    #[test]
    fn prometheus_snapshot_has_required_families() {
        let m = Metrics::new();
        m.requests.add(3);
        m.request_latency_ns.record(1_500_000);
        m.batch_rows.record(8);
        let text = render_prometheus(&m);

        assert!(family_count(&text) >= 12, "families: {}", family_count(&text));
        for family in [
            "fmq_server_requests_total",
            "fmq_server_queue_depth",
            "fmq_server_request_latency_ns",
            "fmq_engine_ode_step_ns",
            "fmq_engine_tune_plans_total",
        ] {
            assert!(text.contains(&format!("# TYPE {family} ")), "missing {family}");
        }
        assert!(text.contains("fmq_server_requests_total 3"));
        // histogram plumbing: buckets are cumulative, +Inf == count
        assert!(text.contains("fmq_server_request_latency_ns_bucket{le=\"+Inf\"} 1"));
        assert!(text.contains("fmq_server_request_latency_ns_count 1"));
        assert!(text.contains("fmq_server_request_latency_ns_approx{quantile=\"0.5\"}"));
        assert!(text.contains("quantile=\"0.99\""));
    }

    #[test]
    fn error_class_and_robustness_counters_are_exposed() {
        let m = Metrics::new();
        m.error_class("deadline_exceeded").inc();
        m.error_class("deadline_exceeded").inc();
        m.error_class("not-a-real-class").inc(); // falls back to internal
        m.worker_respawns.inc();
        m.shed.add(3);
        let text = render_prometheus(&m);
        assert!(text.contains("fmq_server_errors_by_class_total{class=\"deadline_exceeded\"} 2"));
        assert!(text.contains("fmq_server_errors_by_class_total{class=\"internal\"} 1"));
        assert!(text.contains("fmq_server_worker_respawns_total 1"));
        assert!(text.contains("fmq_server_shed_total 3"));
        assert!(text.contains("fmq_server_conn_drops_total 0"));

        let j = render_json(&m);
        let server = j.get("server").unwrap();
        let by_class = server.get("errors_by_class").unwrap();
        assert_eq!(by_class.get("deadline_exceeded").unwrap().as_u64(), Some(2));
        assert_eq!(server.get("worker_respawns").unwrap().as_u64(), Some(1));
        assert_eq!(server.get("shed").unwrap().as_u64(), Some(3));
    }

    #[test]
    fn bucket_lines_are_cumulative_and_monotone() {
        let m = Metrics::new();
        for v in [1u64, 100, 10_000, 1_000_000, u64::MAX] {
            m.batch_run_ns.record(v);
        }
        let text = render_prometheus(&m);
        let mut prev = 0u64;
        let mut saw = 0;
        for line in text.lines() {
            if let Some(rest) = line.strip_prefix("fmq_server_batch_run_ns_bucket{le=") {
                let count: u64 = rest
                    .split_whitespace()
                    .next_back()
                    .unwrap()
                    .parse()
                    .unwrap();
                assert!(count >= prev, "bucket counts must be cumulative");
                prev = count;
                saw += 1;
            }
        }
        assert!(saw > 10, "expected many le lines, got {saw}");
        assert_eq!(prev, 5, "+Inf bucket must equal total count");
    }

    #[test]
    fn json_snapshot_is_integer_exact() {
        let m = Metrics::new();
        m.resident_bytes.set(9_007_199_254_740_993); // 2^53 + 1
        m.requests.inc();
        let j = render_json(&m);
        let server = j.get("server").unwrap();
        assert_eq!(
            server.get("resident_bytes").unwrap().as_i64(),
            Some(9_007_199_254_740_993)
        );
        assert_eq!(server.get("requests").unwrap().as_u64(), Some(1));
        // round-trips through the wire without precision loss
        let back = crate::util::json::parse(&j.to_string()).unwrap();
        assert_eq!(
            back.get("server").unwrap().get("resident_bytes").unwrap().as_i64(),
            Some(9_007_199_254_740_993)
        );
        assert!(back.get("engine").unwrap().get("ode_step_ns").is_some());
    }
}
